//! DFG extraction and manipulation (paper §III, Figs 2 & 4).
pub mod extract;
pub mod graph;
pub use extract::{extract, ExtractReject, OffloadDfg, OutMode, StreamIn, StreamOut};
pub use graph::{Dfg, DfgError, DfgStats, Node, NodeId, NodeKind};

//! DFG extraction and manipulation (paper §III, Figs 2 & 4).
pub mod extract;
pub mod graph;
pub mod partition;
pub use extract::{extract, ExtractReject, OffloadDfg, OutMode, StreamIn, StreamOut};
pub use graph::{Dfg, DfgError, DfgStats, Node, NodeId, NodeKind};
pub use partition::{
    needs_tiling, partition, PartitionError, TileBudget, TileDfg, TileSink, TileSource, TiledDfg,
};

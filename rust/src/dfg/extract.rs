//! DFG extraction: symbolic execution of an innermost-SCoP body into the
//! stream data-flow graph the DFE executes (paper §III, Fig 2).
//!
//! Per loop iteration ("stream element"):
//!   * each affine `load` becomes an external *input stream* (deduplicated
//!     by `(array, subscript)` — stencil overlap after unrolling shares
//!     streams, Fig 2C);
//!   * pure-affine values (e.g. the induction variable used as data)
//!     become host-generated iota streams;
//!   * scalar arithmetic becomes DFE calc nodes (constants are interned as
//!     constant-masked inputs, Fig 2D green boxes);
//!   * control-flow diamonds are if-converted: both arms are evaluated and
//!     differing registers merge through MUX nodes (Fig 4);
//!   * each `store` becomes an *output stream*. A store whose subscript is
//!     invariant in the innermost dimension must be a reduction
//!     (`X[..] = X[..] + e`); it is rewritten to emit the partial `e` and
//!     flagged `Accumulate` — the wrapper stub folds partials on the host,
//!     keeping DFE lanes independent (loop-carried chains never enter the
//!     fabric). Anything else that is loop-carried rejects the SCoP.
//!
//! Unrolling by `u` (Fig 2C) re-runs the extraction with the innermost iv
//! shifted by 0..u, sharing the input-interning table; reduction partials
//! from the copies are summed inside the DFE.
//!
//! Legality (paper §III-A): integer div/rem and any f32 type reject the
//! region — exactly the two Table-I failure columns.

use std::collections::HashMap;

use crate::analysis::affine::Affine;
use crate::analysis::scop::ScopInfo;
use crate::dfe::opcodes::Op;
use crate::dfg::graph::{Dfg, NodeId, NodeKind};
use crate::ir::func::Function;
use crate::ir::instr::{BinOp, BlockId, CmpPred, Inst, Reg, Term, Ty};

/// An input stream: values of `base[affine(ivs)]` per iteration, or a
/// host-generated affine iota when `base` is `None`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StreamIn {
    pub base: Option<Reg>,
    pub affine: Affine,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OutMode {
    /// `base[affine] = value` (distinct address every iteration).
    Assign,
    /// `base[affine] += value` folded on the host (reduction partial).
    Accumulate,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StreamOut {
    pub base: Reg,
    pub affine: Affine,
    pub mode: OutMode,
}

/// The offload package for one SCoP.
#[derive(Clone, Debug)]
pub struct OffloadDfg {
    pub dfg: Dfg,
    pub inputs: Vec<StreamIn>,
    pub outputs: Vec<StreamOut>,
    pub unroll: usize,
    pub scop: ScopInfo,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ExtractReject {
    /// Integer division/remainder: no DFE functional unit (Table I "No,
    /// divisions").
    Division,
    /// Any floating-point data ("No, fp data").
    FpData,
    /// Non-affine subscript (defeats the stream model → no SCoP).
    NonAffineAccess,
    /// Loop-carried dependence that is not a recognizable reduction.
    LoopCarried,
    /// A body block with no terminator: malformed IR that
    /// `ir::verify_function` rejects upstream
    /// ([`crate::ir::VerifyError::Unterminated`]); the extractor returns
    /// a structured error instead of unwrapping into a panic.
    MissingTerminator(BlockId),
    /// Shapes the extractor does not model.
    Unsupported(&'static str),
}

impl ExtractReject {
    pub fn label(&self) -> &'static str {
        match self {
            ExtractReject::Division => "No, divisions",
            ExtractReject::FpData => "No, fp data",
            ExtractReject::NonAffineAccess => "no SCoP",
            ExtractReject::LoopCarried => "No, loop-carried",
            ExtractReject::MissingTerminator(_) => "No, malformed IR",
            ExtractReject::Unsupported(_) => "No, unsupported",
        }
    }
}

/// Symbolic value: DFG node plus (optionally) an affine view for use as a
/// subscript.
#[derive(Clone, Debug)]
struct SymVal {
    node: Option<NodeId>,
    affine: Option<Affine>,
}

struct Extractor<'a> {
    f: &'a Function,
    scop: &'a ScopInfo,
    dfg: Dfg,
    inputs: Vec<StreamIn>,
    input_node: Vec<NodeId>,
    outputs: Vec<StreamOut>,
    out_srcs: Vec<NodeId>,
    const_nodes: HashMap<i32, NodeId>,
    /// Accumulate partials per (base, affine), summed across unroll copies.
    acc_partials: Vec<(StreamOut, NodeId)>,
}

type Env = HashMap<Reg, SymVal>;

impl<'a> Extractor<'a> {
    fn new(f: &'a Function, scop: &'a ScopInfo) -> Extractor<'a> {
        Extractor {
            f,
            scop,
            dfg: Dfg::new(),
            inputs: Vec::new(),
            input_node: Vec::new(),
            outputs: Vec::new(),
            out_srcs: Vec::new(),
            const_nodes: HashMap::new(),
            acc_partials: Vec::new(),
        }
    }

    fn intern_const(&mut self, v: i32) -> NodeId {
        if let Some(&n) = self.const_nodes.get(&v) {
            return n;
        }
        let n = self.dfg.constant(v);
        self.const_nodes.insert(v, n);
        n
    }

    fn intern_input(&mut self, base: Option<Reg>, affine: Affine) -> NodeId {
        let s = StreamIn { base, affine };
        if let Some(i) = self.inputs.iter().position(|x| *x == s) {
            return self.input_node[i];
        }
        let j = self.inputs.len();
        self.inputs.push(s);
        let n = self.dfg.input(j);
        self.input_node.push(n);
        n
    }

    /// Materialize a DFG node for a symbolic value (iota input for pure
    /// affine values that have no node yet).
    fn node_of(&mut self, v: &SymVal) -> Result<NodeId, ExtractReject> {
        if let Some(n) = v.node {
            return Ok(n);
        }
        match &v.affine {
            Some(a) if a.is_constant() => Ok(self.intern_const(a.k as i32)),
            Some(a) => Ok(self.intern_input(None, a.clone())),
            None => Err(ExtractReject::Unsupported("value with no node or affine form")),
        }
    }

    fn lookup(&self, env: &Env, r: Reg) -> SymVal {
        env.get(&r).cloned().unwrap_or(SymVal { node: None, affine: None })
    }

    fn map_binop(op: BinOp) -> Result<Op, ExtractReject> {
        Ok(match op {
            BinOp::Add => Op::Add,
            BinOp::Sub => Op::Sub,
            BinOp::Mul => Op::Mul,
            BinOp::Div | BinOp::Rem => return Err(ExtractReject::Division),
            BinOp::Min => Op::Min,
            BinOp::Max => Op::Max,
            BinOp::And => Op::And,
            BinOp::Or => Op::Or,
            BinOp::Xor => Op::Xor,
            BinOp::Shl => Op::Shl,
            BinOp::Shr => Op::Shr,
        })
    }

    fn map_cmp(p: CmpPred) -> Op {
        match p {
            CmpPred::Lt => Op::Lt,
            CmpPred::Gt => Op::Gt,
            CmpPred::Le => Op::Le,
            CmpPred::Ge => Op::Ge,
            CmpPred::Eq => Op::Eq,
            CmpPred::Ne => Op::Ne,
        }
    }

    /// Affine combination mirroring the SCoP rules (for subscripts).
    fn affine_bin(op: BinOp, a: &Option<Affine>, b: &Option<Affine>) -> Option<Affine> {
        match (op, a, b) {
            (BinOp::Add, Some(x), Some(y)) => Some(x.add(y)),
            (BinOp::Sub, Some(x), Some(y)) => Some(x.sub(y)),
            (BinOp::Mul, Some(x), Some(y)) => x.mul(y),
            (BinOp::Shl, Some(x), Some(y)) => y
                .as_constant()
                .filter(|s| (0..31).contains(s))
                .map(|s| x.scale(1 << s)),
            _ => None,
        }
    }

    /// Symbolically execute one instruction into `env`.
    fn step(&mut self, env: &mut Env, inst: &Inst, shift: i64) -> Result<(), ExtractReject> {
        let inner = self.scop.depth() - 1;
        match inst {
            Inst::ConstI32 { dst, v } => {
                env.insert(
                    *dst,
                    SymVal { node: None, affine: Some(Affine::constant(*v as i64)) },
                );
            }
            Inst::ConstF32 { .. } | Inst::IToF { .. } | Inst::FToI { .. } => {
                return Err(ExtractReject::FpData)
            }
            Inst::Mov { dst, a } => {
                let v = self.lookup(env, *a);
                env.insert(*dst, v);
            }
            Inst::Bin { ty: Ty::F32, .. } | Inst::Cmp { ty: Ty::F32, .. } => {
                return Err(ExtractReject::FpData)
            }
            Inst::Bin { dst, op, a, b, .. } => {
                let va = self.lookup(env, *a);
                let vb = self.lookup(env, *b);
                let affine = Self::affine_bin(*op, &va.affine, &vb.affine);
                // Anything affine is host-computable: defer node creation
                // (node_of materializes an iota stream only if the value
                // is ultimately consumed as data).
                let node = if affine.is_some() {
                    None
                } else {
                    let dfe_op = Self::map_binop(*op)?;
                    let na = self.node_of(&va)?;
                    let nb = self.node_of(&vb)?;
                    Some(self.dfg.calc(dfe_op, na, nb))
                };
                env.insert(*dst, SymVal { node, affine });
            }
            Inst::Cmp { dst, pred, a, b, .. } => {
                let va = self.lookup(env, *a);
                let vb = self.lookup(env, *b);
                let na = self.node_of(&va)?;
                let nb = self.node_of(&vb)?;
                let n = self.dfg.calc(Self::map_cmp(*pred), na, nb);
                env.insert(*dst, SymVal { node: Some(n), affine: None });
            }
            Inst::Select { dst, c, t, f } => {
                let (vc, vt, vf) =
                    (self.lookup(env, *c), self.lookup(env, *t), self.lookup(env, *f));
                let (nc, nt, nf) =
                    (self.node_of(&vc)?, self.node_of(&vt)?, self.node_of(&vf)?);
                let n = self.dfg.mux(nt, nf, nc);
                env.insert(*dst, SymVal { node: Some(n), affine: None });
            }
            Inst::Load { dst, ty, base, idx } => {
                if *ty == Ty::F32 {
                    return Err(ExtractReject::FpData);
                }
                let vi = self.lookup(env, *idx);
                let affine =
                    vi.affine.clone().ok_or(ExtractReject::NonAffineAccess)?.shift_iv(inner, shift);
                let n = self.intern_input(Some(*base), affine);
                env.insert(*dst, SymVal { node: Some(n), affine: None });
            }
            Inst::Store { ty, base, idx, val } => {
                if *ty == Ty::F32 {
                    return Err(ExtractReject::FpData);
                }
                let vi = self.lookup(env, *idx);
                let affine =
                    vi.affine.clone().ok_or(ExtractReject::NonAffineAccess)?.shift_iv(inner, shift);
                let vv = self.lookup(env, *val);
                let nv = self.node_of(&vv)?;
                self.emit_store(*base, affine, nv)?;
            }
            Inst::Call { .. } | Inst::Syscall { .. } => {
                return Err(ExtractReject::Unsupported("call in body (screen bug)"))
            }
        }
        Ok(())
    }

    /// Classify a store as Assign or Accumulate (reduction rewrite).
    fn emit_store(&mut self, base: Reg, affine: Affine, val: NodeId) -> Result<(), ExtractReject> {
        let inner = self.scop.depth() - 1;
        if affine.depends_on_iv(inner) {
            // Distinct address each iteration: plain assignment stream.
            self.outputs.push(StreamOut { base, affine, mode: OutMode::Assign });
            self.out_srcs.push(val);
            return Ok(());
        }
        // Innermost-invariant address: must be `X[a] = X[a] + e`.
        let self_input = self
            .inputs
            .iter()
            .position(|s| s.base == Some(base) && s.affine == affine)
            .map(|i| self.input_node[i]);
        let Some(self_in) = self_input else {
            return Err(ExtractReject::LoopCarried);
        };
        let NodeKind::Calc(Op::Add) = self.dfg.nodes[val].kind else {
            return Err(ExtractReject::LoopCarried);
        };
        let srcs = self.dfg.nodes[val].srcs.clone();
        let partial = if srcs[0] == self_in {
            srcs[1]
        } else if srcs[1] == self_in {
            srcs[0]
        } else {
            return Err(ExtractReject::LoopCarried);
        };
        let out = StreamOut { base, affine, mode: OutMode::Accumulate };
        // Merge with an existing partial for the same accumulator (unroll
        // copies): sum inside the DFE.
        if let Some(entry) = self.acc_partials.iter_mut().find(|(o, _)| *o == out) {
            entry.1 = self.dfg.calc(Op::Add, entry.1, partial);
        } else {
            self.acc_partials.push((out, partial));
        }
        Ok(())
    }

    /// Execute the innermost body region once with iv shifted by `shift`.
    fn run_copy(&mut self, shift: i64) -> Result<(), ExtractReject> {
        let inner_depth = self.scop.depth() - 1;
        let mut env: Env = HashMap::new();
        // Bind every nest iv to its affine dimension.
        for l in &self.scop.nest {
            env.insert(l.iv, SymVal { node: None, affine: Some(Affine::iv(l.depth)) });
        }
        // i32 params are affine parameters.
        for (i, p) in self.f.params.iter().enumerate() {
            if p.ty == Ty::I32 {
                let r = Reg(i as u32);
                env.entry(r)
                    .or_insert(SymVal { node: None, affine: Some(Affine::param(r)) });
            }
        }
        let _ = inner_depth;

        let mut cur = self.scop.body_entry;
        let header = self.scop.header;
        let mut guard = 0;
        loop {
            guard += 1;
            if guard > self.f.blocks.len() * 2 {
                return Err(ExtractReject::Unsupported("body region too complex"));
            }
            let block = self.f.block(cur).clone();
            let is_latch = matches!(block.term, Some(Term::Br(h)) if h == header);
            let insts: &[Inst] = if is_latch {
                // Drop the `const 1; add; mov iv` latch tail.
                &block.insts[..block.insts.len().saturating_sub(3)]
            } else {
                &block.insts
            };
            for inst in insts {
                self.step(&mut env, inst, shift)?;
            }
            let Some(term) = block.term.clone() else {
                // Terminator-less block: constructible through the IR
                // builder (`new_block` without `terminate`) and screened
                // by `ir::verify_function`; reject instead of panicking.
                return Err(ExtractReject::MissingTerminator(cur));
            };
            match term {
                Term::Br(h) if h == header => return Ok(()),
                Term::Br(next) => cur = next,
                Term::CondBr { c, t, f } => {
                    // If-conversion (paper Fig 4): evaluate both arms and
                    // merge differing registers through MUX nodes.
                    let vc = self.lookup(&env, c);
                    let nc = self.node_of(&vc)?;
                    let join = match (&self.f.block(t).term, &self.f.block(f).term) {
                        (Some(Term::Br(jt)), Some(Term::Br(jf))) if jt == jf => *jt,
                        _ => return Err(ExtractReject::Unsupported("unstructured diamond")),
                    };
                    let mut env_t = env.clone();
                    for inst in &self.f.block(t).insts {
                        self.step(&mut env_t, inst, shift)?;
                    }
                    let mut env_f = env.clone();
                    for inst in &self.f.block(f).insts {
                        self.step(&mut env_f, inst, shift)?;
                    }
                    let keys: Vec<Reg> = env_t
                        .keys()
                        .chain(env_f.keys())
                        .copied()
                        .collect::<std::collections::HashSet<_>>()
                        .into_iter()
                        .collect();
                    for k in keys {
                        let vt = env_t.get(&k).cloned();
                        let vf = env_f.get(&k).cloned();
                        match (vt, vf) {
                            (Some(a), Some(b)) => {
                                let same_node = a.node == b.node;
                                let same_affine =
                                    a.affine.is_some() && a.affine == b.affine;
                                if same_node && (a.node.is_some() || same_affine) {
                                    env.insert(k, a);
                                } else if same_affine {
                                    env.insert(k, a);
                                } else {
                                    let na = self.node_of(&a)?;
                                    let nb = self.node_of(&b)?;
                                    let m = self.dfg.mux(na, nb, nc);
                                    env.insert(
                                        k,
                                        SymVal { node: Some(m), affine: None },
                                    );
                                }
                            }
                            (Some(a), None) | (None, Some(a)) => {
                                env.insert(k, a);
                            }
                            (None, None) => {}
                        }
                    }
                    cur = join;
                }
                Term::Ret(_) => return Err(ExtractReject::Unsupported("ret in body")),
            }
        }
    }

    fn finish(mut self, unroll: usize) -> OffloadDfg {
        // Flush accumulator partials as outputs.
        for (out, partial) in std::mem::take(&mut self.acc_partials) {
            self.outputs.push(out);
            self.out_srcs.push(partial);
        }
        for (j, &src) in self.out_srcs.iter().enumerate() {
            self.dfg.output(j, src);
        }
        // Drop input streams that ended up unused (e.g. the self-load of a
        // rewritten reduction) and compact indices.
        let pruned = self.dfg.prune_dead();
        let mut used: Vec<bool> = vec![false; self.inputs.len()];
        for n in &pruned.nodes {
            if let NodeKind::Input(j) = n.kind {
                used[j] = true;
            }
        }
        let mut remap = vec![usize::MAX; self.inputs.len()];
        let mut new_inputs = Vec::new();
        for (j, u) in used.iter().enumerate() {
            if *u {
                remap[j] = new_inputs.len();
                new_inputs.push(self.inputs[j].clone());
            }
        }
        let mut dfg = pruned;
        for n in &mut dfg.nodes {
            if let NodeKind::Input(j) = &mut n.kind {
                *j = remap[*j];
            }
        }
        OffloadDfg {
            dfg,
            inputs: new_inputs,
            outputs: self.outputs,
            unroll,
            scop: self.scop.clone(),
        }
    }
}

/// Extract the offload DFG for `scop`, unrolled by `unroll` (>= 1).
pub fn extract(
    f: &Function,
    scop: &ScopInfo,
    unroll: usize,
) -> Result<OffloadDfg, ExtractReject> {
    assert!(unroll >= 1);
    let mut ex = Extractor::new(f, scop);
    for k in 0..unroll {
        ex.run_copy(k as i64)?;
    }
    let out = ex.finish(unroll);
    debug_assert!(out.dfg.validate().is_ok());
    // Dependence screen: a load from an array that is also stored must be
    // the read half of a same-address read-modify-write (its subscript
    // equals one of the store subscripts — gather-before-scatter keeps
    // that exact). Any other overlap is a potential loop-carried
    // dependence that parallel stream lanes would break, so the SCoP is
    // rejected. (Reductions were already rewritten to Accumulate partials
    // whose self-load got pruned.)
    for i in &out.inputs {
        let Some(base) = i.base else { continue };
        let stores: Vec<&StreamOut> =
            out.outputs.iter().filter(|o| o.base == base).collect();
        if !stores.is_empty() && !stores.iter().any(|o| o.affine == i.affine) {
            return Err(ExtractReject::LoopCarried);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scop::analyze_function;
    use crate::ir::func::FuncBuilder;

    fn fig2_func() -> Function {
        let mut b = FuncBuilder::new(
            "fig2",
            &[("C", Ty::Ptr), ("A", Ty::Ptr), ("B", Ty::Ptr), ("n", Ty::I32)],
        );
        let (c, a, bb, n) = (b.param(0), b.param(1), b.param(2), b.param(3));
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, i| {
            let av = b.load(Ty::I32, a, i);
            let bv = b.load(Ty::I32, bb, i);
            let c3 = b.const_i32(3);
            let t = b.mul(bv, c3);
            let s = b.add(av, t);
            let c1 = b.const_i32(1);
            let r = b.add(s, c1);
            b.store(Ty::I32, c, i, r);
        });
        b.ret(None)
    }

    #[test]
    fn fig2_extraction_shape_and_semantics() {
        let f = fig2_func();
        let an = analyze_function(&f);
        let off = extract(&f, &an.scops[0], 1).unwrap();
        let st = off.dfg.stats();
        assert_eq!((st.inputs, st.outputs, st.calc), (2, 1, 3));
        assert_eq!(off.outputs[0].mode, OutMode::Assign);
        // Per-element semantics: out = a + 3b + 1.
        assert_eq!(off.dfg.eval(&[10, 5]).unwrap(), vec![26]);
    }

    #[test]
    fn fig2_unroll4_matches_paper_fig2c() {
        let f = fig2_func();
        let an = analyze_function(&f);
        let off = extract(&f, &an.scops[0], 4).unwrap();
        let st = off.dfg.stats();
        assert_eq!(st.inputs, 8); // 4x {A[i+k], B[i+k]} disjoint
        assert_eq!(st.outputs, 4);
        assert_eq!(st.calc, 12);
        // Input affine subscripts shifted by copy index.
        let shifts: Vec<i64> = off.inputs.iter().map(|s| s.affine.k).collect();
        assert!(shifts.contains(&0) && shifts.contains(&3));
    }

    #[test]
    fn stencil_unroll_shares_inputs() {
        // B[i] = A[i-1] + A[i] + A[i+1]
        let mut b = FuncBuilder::new("stencil", &[("B", Ty::Ptr), ("A", Ty::Ptr), ("n", Ty::I32)]);
        let (bp, a, n) = (b.param(0), b.param(1), b.param(2));
        let one_c = b.const_i32(1);
        b.counted_loop(one_c, n, |b, i| {
            let one = b.const_i32(1);
            let im1 = b.sub(i, one);
            let ip1 = b.add(i, one);
            let v0 = b.load(Ty::I32, a, im1);
            let v1 = b.load(Ty::I32, a, i);
            let v2 = b.load(Ty::I32, a, ip1);
            let s = b.add(v0, v1);
            let s2 = b.add(s, v2);
            b.store(Ty::I32, bp, i, s2);
        });
        let f = b.ret(None);
        let an = analyze_function(&f);
        let off = extract(&f, &an.scops[0], 2).unwrap();
        // Unrolled x2: accesses {i-1,i,i+1} ∪ {i,i+1,i+2} = 4 distinct.
        assert_eq!(off.dfg.stats().inputs, 4);
        assert_eq!(off.dfg.stats().outputs, 2);
    }

    #[test]
    fn reduction_rewritten_to_accumulate() {
        // dot: acc[0] += A[i] * B[i]  (store subscript invariant in i)
        let mut b = FuncBuilder::new(
            "dot",
            &[("acc", Ty::Ptr), ("A", Ty::Ptr), ("B", Ty::Ptr), ("n", Ty::I32)],
        );
        let (acc, a, bb, n) = (b.param(0), b.param(1), b.param(2), b.param(3));
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, i| {
            let z = b.const_i32(0);
            let cur = b.load(Ty::I32, acc, z);
            let x = b.load(Ty::I32, a, i);
            let y = b.load(Ty::I32, bb, i);
            let p = b.mul(x, y);
            let s = b.add(cur, p);
            let z2 = b.const_i32(0);
            b.store(Ty::I32, acc, z2, s);
        });
        let f = b.ret(None);
        let an = analyze_function(&f);
        let off = extract(&f, &an.scops[0], 1).unwrap();
        assert_eq!(off.outputs.len(), 1);
        assert_eq!(off.outputs[0].mode, OutMode::Accumulate);
        // The self-load input was pruned: only A and B stream in.
        assert_eq!(off.inputs.len(), 2);
        // Partial = product only.
        assert_eq!(off.dfg.eval(&[6, 7]).unwrap(), vec![42]);
    }

    #[test]
    fn reduction_unrolled_sums_in_fabric() {
        let mut b = FuncBuilder::new(
            "dot4",
            &[("acc", Ty::Ptr), ("A", Ty::Ptr), ("B", Ty::Ptr), ("n", Ty::I32)],
        );
        let (acc, a, bb, n) = (b.param(0), b.param(1), b.param(2), b.param(3));
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, i| {
            let z = b.const_i32(0);
            let cur = b.load(Ty::I32, acc, z);
            let x = b.load(Ty::I32, a, i);
            let y = b.load(Ty::I32, bb, i);
            let p = b.mul(x, y);
            let s = b.add(cur, p);
            let z2 = b.const_i32(0);
            b.store(Ty::I32, acc, z2, s);
        });
        let f = b.ret(None);
        let an = analyze_function(&f);
        let off = extract(&f, &an.scops[0], 4).unwrap();
        assert_eq!(off.outputs.len(), 1, "one accumulator output");
        assert_eq!(off.inputs.len(), 8);
        // partial = sum of 4 products: eval with A=[1,2,3,4] B=[10,10,10,10]
        // inputs are interleaved per copy (A, B, A, B, ...)
        let vals = [1, 10, 2, 10, 3, 10, 4, 10];
        assert_eq!(off.dfg.eval(&vals).unwrap(), vec![100]);
    }

    #[test]
    fn division_rejected() {
        let mut b = FuncBuilder::new("divk", &[("A", Ty::Ptr), ("n", Ty::I32)]);
        let (a, n) = (b.param(0), b.param(1));
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, i| {
            let v = b.load(Ty::I32, a, i);
            let two = b.const_i32(2);
            let d = b.bin(BinOp::Div, Ty::I32, v, two);
            b.store(Ty::I32, a, i, d);
        });
        let f = b.ret(None);
        let an = analyze_function(&f);
        assert_eq!(extract(&f, &an.scops[0], 1).err(), Some(ExtractReject::Division));
    }

    #[test]
    fn fp_rejected() {
        let mut b = FuncBuilder::new("fpk", &[("A", Ty::Ptr), ("n", Ty::I32)]);
        let (a, n) = (b.param(0), b.param(1));
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, i| {
            let v = b.load(Ty::F32, a, i);
            let w = b.fmul(v, v);
            b.store(Ty::F32, a, i, w);
        });
        let f = b.ret(None);
        let an = analyze_function(&f);
        assert_eq!(extract(&f, &an.scops[0], 1).err(), Some(ExtractReject::FpData));
    }

    #[test]
    fn nonaffine_subscript_rejected() {
        // A[B[i]] = i  (indirect index)
        let mut b = FuncBuilder::new("ind", &[("A", Ty::Ptr), ("B", Ty::Ptr), ("n", Ty::I32)]);
        let (a, bb, n) = (b.param(0), b.param(1), b.param(2));
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, i| {
            let idx = b.load(Ty::I32, bb, i);
            b.store(Ty::I32, a, idx, i);
        });
        let f = b.ret(None);
        let an = analyze_function(&f);
        assert_eq!(extract(&f, &an.scops[0], 1).err(), Some(ExtractReject::NonAffineAccess));
    }

    #[test]
    fn branchy_body_ifconverts_to_mux() {
        use crate::ir::instr::Term;
        // Listing 1 authored with a real diamond (pure arms).
        let mut b = FuncBuilder::new(
            "branchy",
            &[("C", Ty::Ptr), ("A", Ty::Ptr), ("B", Ty::Ptr), ("n", Ty::I32)],
        );
        let (cp, a, bp, n) = (b.param(0), b.param(1), b.param(2), b.param(3));
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, i| {
            let av = b.load(Ty::I32, a, i);
            let bv = b.load(Ty::I32, bp, i);
            let c = b.cmp(CmpPred::Gt, av, bv);
            let r = b.fresh();
            let tb = b.new_block();
            let fb = b.new_block();
            let join = b.new_block();
            b.terminate(Term::CondBr { c, t: tb, f: fb });
            b.switch_to(tb);
            let c3 = b.const_i32(3);
            let t0 = b.mul(bv, c3);
            let t1 = b.add(av, t0);
            let one = b.const_i32(1);
            let t2 = b.add(t1, one);
            b.mov_into(r, t2);
            b.terminate(Term::Br(join));
            b.switch_to(fb);
            let c5 = b.const_i32(5);
            let e0 = b.mul(bv, c5);
            let e1 = b.sub(av, e0);
            let two = b.const_i32(2);
            let e2 = b.sub(e1, two);
            b.mov_into(r, e2);
            b.terminate(Term::Br(join));
            b.switch_to(join);
            b.store(Ty::I32, cp, i, r);
        });
        let f = b.ret(None);
        let an = analyze_function(&f);
        assert!(an.detected(), "{:?}", an.rejects);
        let off = extract(&f, &an.scops[0], 1).unwrap();
        // MUX present and semantics match Listing 1.
        assert!(off
            .dfg
            .nodes
            .iter()
            .any(|nd| matches!(nd.kind, NodeKind::Calc(Op::Mux))));
        assert_eq!(off.dfg.eval(&[10, 2]).unwrap(), vec![17]);
        assert_eq!(off.dfg.eval(&[2, 10]).unwrap(), vec![-50]);
    }

    #[test]
    fn unterminated_body_block_rejects_instead_of_panicking() {
        use crate::ir::verify::{verify_function, VerifyError};
        // Regression (ISSUE 4): a terminator-less block is constructible
        // through the IR builder (`new_block` without `terminate`); the
        // extractor used to `unwrap()` the terminator and panic. Build a
        // well-formed loop, record its SCoP, then strip the body block's
        // terminator.
        let mut b = FuncBuilder::new("unterm", &[("A", Ty::Ptr), ("n", Ty::I32)]);
        let (a, n) = (b.param(0), b.param(1));
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, i| {
            b.store(Ty::I32, a, i, i);
        });
        let mut f = b.ret(None);
        let scop = analyze_function(&f).scops[0].clone();
        f.blocks[scop.body_entry.0 as usize].term = None;

        // Upstream screen #1: the IR verifier rejects the function.
        assert!(matches!(
            verify_function(&f, None),
            Err(VerifyError::Unterminated(blk)) if blk == scop.body_entry
        ));
        // Upstream screen #2: SCoP analysis refuses it too, so
        // `try_offload` never hands malformed IR to the extractor.
        assert!(analyze_function(&f).scops.is_empty());
        // And the extractor itself returns a structured error — the
        // pre-fix code panicked here on `block.term.clone().unwrap()`.
        assert_eq!(
            extract(&f, &scop, 1).err(),
            Some(ExtractReject::MissingTerminator(scop.body_entry))
        );
        assert_eq!(
            ExtractReject::MissingTerminator(scop.body_entry).label(),
            "No, malformed IR"
        );
    }

    #[test]
    fn iv_as_data_becomes_iota_stream() {
        // A[i] = i * 2
        let mut b = FuncBuilder::new("iota", &[("A", Ty::Ptr), ("n", Ty::I32)]);
        let (a, n) = (b.param(0), b.param(1));
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, i| {
            let two = b.const_i32(2);
            let v = b.mul(i, two);
            b.store(Ty::I32, a, i, v);
        });
        let f = b.ret(None);
        let an = analyze_function(&f);
        let off = extract(&f, &an.scops[0], 1).unwrap();
        // The value i*2 is affine: the extractor streams it as an iota
        // input rather than computing it in fabric.
        assert_eq!(off.inputs.len(), 1);
        assert!(off.inputs[0].base.is_none());
        assert_eq!(off.inputs[0].affine.iv_coeff(0), 2);
    }
}

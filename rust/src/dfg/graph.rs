//! Data-flow graph — the unit the framework extracts from hot code and
//! maps onto the DFE (paper Fig 2/4).
//!
//! DFGs are acyclic (the framework never crosses loop boundaries, §III-A).
//! Node classes match the paper's Table-I statistics: external inputs,
//! constants (to be masked into DFE constant inputs), compute nodes, and
//! outputs. MUX nodes carry a third (selection) operand.

use std::collections::HashMap;
use std::fmt;

use crate::dfe::opcodes::Op;

pub type NodeId = usize;

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum NodeKind {
    /// External input `j` (one stream element per invocation).
    Input(usize),
    /// Compile-time constant (paper: green constant-masked boxes, Fig 2D).
    Const(i32),
    /// Functional-unit operation. `srcs` holds [a, b] or [a, b, sel] (MUX).
    Calc(Op),
    /// External output `j`; single source.
    Output(usize),
}

#[derive(Clone, Debug)]
pub struct Node {
    pub kind: NodeKind,
    pub srcs: Vec<NodeId>,
}

/// Table-I style statistics: `in/out/calc` counts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DfgStats {
    pub inputs: usize,
    pub outputs: usize,
    pub calc: usize,
    pub consts: usize,
}

impl fmt::Display for DfgStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{}/{}", self.inputs, self.outputs, self.calc)
    }
}

#[derive(Clone, Debug, Default)]
pub struct Dfg {
    pub nodes: Vec<Node>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DfgError {
    Cycle,
    BadArity { node: NodeId, got: usize, want: &'static str },
    DanglingSource { node: NodeId, src: NodeId },
    DuplicateInput(usize),
    DuplicateOutput(usize),
}

impl fmt::Display for DfgError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DfgError::Cycle => write!(f, "DFG contains a cycle"),
            DfgError::BadArity { node, got, want } => {
                write!(f, "node {node}: {got} sources, want {want}")
            }
            DfgError::DanglingSource { node, src } => {
                write!(f, "node {node} references missing node {src}")
            }
            DfgError::DuplicateInput(j) => write!(f, "duplicate input index {j}"),
            DfgError::DuplicateOutput(j) => write!(f, "duplicate output index {j}"),
        }
    }
}

impl std::error::Error for DfgError {}

impl Dfg {
    pub fn new() -> Dfg {
        Dfg::default()
    }

    pub fn add(&mut self, kind: NodeKind, srcs: Vec<NodeId>) -> NodeId {
        self.nodes.push(Node { kind, srcs });
        self.nodes.len() - 1
    }

    pub fn input(&mut self, j: usize) -> NodeId {
        self.add(NodeKind::Input(j), vec![])
    }

    pub fn constant(&mut self, v: i32) -> NodeId {
        self.add(NodeKind::Const(v), vec![])
    }

    pub fn calc(&mut self, op: Op, a: NodeId, b: NodeId) -> NodeId {
        self.add(NodeKind::Calc(op), vec![a, b])
    }

    pub fn mux(&mut self, a: NodeId, b: NodeId, sel: NodeId) -> NodeId {
        self.add(NodeKind::Calc(Op::Mux), vec![a, b, sel])
    }

    pub fn output(&mut self, j: usize, src: NodeId) -> NodeId {
        self.add(NodeKind::Output(j), vec![src])
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn stats(&self) -> DfgStats {
        let mut s = DfgStats { inputs: 0, outputs: 0, calc: 0, consts: 0 };
        for n in &self.nodes {
            match n.kind {
                NodeKind::Input(_) => s.inputs += 1,
                NodeKind::Output(_) => s.outputs += 1,
                NodeKind::Calc(_) => s.calc += 1,
                NodeKind::Const(_) => s.consts += 1,
            }
        }
        s
    }

    /// Structural validation: arity, dangling edges, acyclicity, unique
    /// input/output indices.
    pub fn validate(&self) -> Result<(), DfgError> {
        let mut seen_in = HashMap::new();
        let mut seen_out = HashMap::new();
        for (id, n) in self.nodes.iter().enumerate() {
            for &s in &n.srcs {
                if s >= self.nodes.len() {
                    return Err(DfgError::DanglingSource { node: id, src: s });
                }
            }
            match &n.kind {
                NodeKind::Input(j) => {
                    if !n.srcs.is_empty() {
                        return Err(DfgError::BadArity { node: id, got: n.srcs.len(), want: "0" });
                    }
                    if seen_in.insert(*j, id).is_some() {
                        return Err(DfgError::DuplicateInput(*j));
                    }
                }
                NodeKind::Const(_) => {
                    if !n.srcs.is_empty() {
                        return Err(DfgError::BadArity { node: id, got: n.srcs.len(), want: "0" });
                    }
                }
                NodeKind::Calc(Op::Mux) => {
                    if n.srcs.len() != 3 {
                        return Err(DfgError::BadArity { node: id, got: n.srcs.len(), want: "3" });
                    }
                }
                NodeKind::Calc(_) => {
                    if n.srcs.len() != 2 {
                        return Err(DfgError::BadArity { node: id, got: n.srcs.len(), want: "2" });
                    }
                }
                NodeKind::Output(j) => {
                    if n.srcs.len() != 1 {
                        return Err(DfgError::BadArity { node: id, got: n.srcs.len(), want: "1" });
                    }
                    if seen_out.insert(*j, id).is_some() {
                        return Err(DfgError::DuplicateOutput(*j));
                    }
                }
            }
        }
        self.topo_order().map(|_| ())
    }

    /// Kahn topological order; `Err(Cycle)` if cyclic.
    pub fn topo_order(&self) -> Result<Vec<NodeId>, DfgError> {
        let n = self.nodes.len();
        let mut indeg = vec![0usize; n];
        let mut consumers: Vec<Vec<NodeId>> = vec![Vec::new(); n];
        for (id, node) in self.nodes.iter().enumerate() {
            for &s in &node.srcs {
                if s < n {
                    indeg[id] += 1;
                    consumers[s].push(id);
                }
            }
        }
        let mut queue: Vec<NodeId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(id) = queue.pop() {
            order.push(id);
            for &c in &consumers[id] {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    queue.push(c);
                }
            }
        }
        if order.len() == n {
            Ok(order)
        } else {
            Err(DfgError::Cycle)
        }
    }

    /// Reference evaluation of one invocation. `inputs[j]` feeds
    /// `Input(j)`. Returns `outputs[j]` (dense up to the max output index).
    pub fn eval(&self, inputs: &[i32]) -> Result<Vec<i32>, DfgError> {
        let order = self.topo_order()?;
        let mut vals = vec![0i32; self.nodes.len()];
        let mut n_out = 0usize;
        for &id in &order {
            let node = &self.nodes[id];
            vals[id] = match &node.kind {
                NodeKind::Input(j) => inputs.get(*j).copied().unwrap_or(0),
                NodeKind::Const(v) => *v,
                NodeKind::Calc(op) => {
                    let a = vals[node.srcs[0]];
                    let b = vals[node.srcs[1]];
                    let s = node.srcs.get(2).map(|&i| vals[i]).unwrap_or(0);
                    op.eval(a, b, s)
                }
                NodeKind::Output(j) => {
                    n_out = n_out.max(j + 1);
                    vals[node.srcs[0]]
                }
            };
        }
        let mut out = vec![0i32; n_out];
        for (id, node) in self.nodes.iter().enumerate() {
            if let NodeKind::Output(j) = node.kind {
                out[j] = vals[id];
            }
        }
        Ok(out)
    }

    /// Number of distinct external input indices (paper's "in" column
    /// counts input nodes; equal when indices are dense and unique).
    pub fn max_input_index(&self) -> Option<usize> {
        self.nodes
            .iter()
            .filter_map(|n| match n.kind {
                NodeKind::Input(j) => Some(j),
                _ => None,
            })
            .max()
    }

    pub fn max_output_index(&self) -> Option<usize> {
        self.nodes
            .iter()
            .filter_map(|n| match n.kind {
                NodeKind::Output(j) => Some(j),
                _ => None,
            })
            .max()
    }

    /// Ids of calc nodes in topological order (what P&R places).
    pub fn calc_order(&self) -> Result<Vec<NodeId>, DfgError> {
        Ok(self
            .topo_order()?
            .into_iter()
            .filter(|&id| matches!(self.nodes[id].kind, NodeKind::Calc(_)))
            .collect())
    }

    /// Apply dead-node elimination: drop nodes not reachable (backwards)
    /// from any output. Keeps node ids stable by compacting with a remap.
    pub fn prune_dead(&self) -> Dfg {
        let n = self.nodes.len();
        let mut live = vec![false; n];
        let mut stack: Vec<NodeId> = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, node)| matches!(node.kind, NodeKind::Output(_)))
            .map(|(id, _)| id)
            .collect();
        while let Some(id) = stack.pop() {
            if live[id] {
                continue;
            }
            live[id] = true;
            stack.extend(self.nodes[id].srcs.iter().copied());
        }
        let mut remap = vec![usize::MAX; n];
        let mut out = Dfg::new();
        for id in 0..n {
            if live[id] {
                let node = &self.nodes[id];
                let srcs = node.srcs.iter().map(|&s| remap[s]).collect();
                remap[id] = out.add(node.kind.clone(), srcs);
            }
        }
        out
    }
}

/// Fig 2 (B): DFG for `C = A + 3B + 1` (single stream element).
pub fn fig2_dfg() -> Dfg {
    let mut g = Dfg::new();
    let a = g.input(0);
    let b = g.input(1);
    let c3 = g.constant(3);
    let c1 = g.constant(1);
    let m = g.calc(Op::Mul, b, c3);
    let s = g.calc(Op::Add, a, m);
    let r = g.calc(Op::Add, s, c1);
    g.output(0, r);
    g
}

/// Fig 4: DFG for Listing 1 (branch if-converted to MUX).
pub fn listing1_dfg() -> Dfg {
    let mut g = Dfg::new();
    let a = g.input(0);
    let b = g.input(1);
    let c3 = g.constant(3);
    let c1 = g.constant(1);
    let c5 = g.constant(5);
    let c2 = g.constant(2);
    let cond = g.calc(Op::Gt, a, b);
    let t0 = g.calc(Op::Mul, b, c3);
    let t1 = g.calc(Op::Add, a, t0);
    let then_v = g.calc(Op::Add, t1, c1);
    let e0 = g.calc(Op::Mul, b, c5);
    let e1 = g.calc(Op::Sub, a, e0);
    let else_v = g.calc(Op::Sub, e1, c2);
    let r = g.mux(then_v, else_v, cond);
    g.output(0, r);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_eval() {
        let g = fig2_dfg();
        g.validate().unwrap();
        assert_eq!(g.eval(&[10, 5]).unwrap(), vec![26]);
        assert_eq!(g.stats().to_string(), "2/1/3");
        assert_eq!(g.stats().consts, 2);
    }

    #[test]
    fn listing1_eval_both_branches() {
        let g = listing1_dfg();
        g.validate().unwrap();
        assert_eq!(g.eval(&[10, 2]).unwrap(), vec![10 + 6 + 1]);
        assert_eq!(g.eval(&[2, 10]).unwrap(), vec![2 - 50 - 2]);
    }

    #[test]
    fn cycle_detected() {
        let mut g = Dfg::new();
        let a = g.input(0);
        let c = g.add(NodeKind::Calc(Op::Add), vec![a, 2]); // forward ref to itself
        assert_eq!(c, 1);
        g.nodes[1].srcs[1] = 1;
        assert_eq!(g.topo_order(), Err(DfgError::Cycle));
    }

    #[test]
    fn arity_checked() {
        let mut g = Dfg::new();
        let a = g.input(0);
        g.add(NodeKind::Calc(Op::Add), vec![a]);
        assert!(matches!(g.validate(), Err(DfgError::BadArity { want: "2", .. })));

        let mut g2 = Dfg::new();
        let a2 = g2.input(0);
        g2.add(NodeKind::Calc(Op::Mux), vec![a2, a2]);
        assert!(matches!(g2.validate(), Err(DfgError::BadArity { want: "3", .. })));
    }

    #[test]
    fn duplicate_io_rejected() {
        let mut g = Dfg::new();
        g.input(0);
        g.input(0);
        assert_eq!(g.validate(), Err(DfgError::DuplicateInput(0)));
    }

    #[test]
    fn prune_dead_drops_unused() {
        let mut g = Dfg::new();
        let a = g.input(0);
        let b = g.input(1);
        let used = g.calc(Op::Add, a, b);
        let _dead = g.calc(Op::Mul, a, b);
        g.output(0, used);
        let pruned = g.prune_dead();
        assert_eq!(pruned.stats().calc, 1);
        assert_eq!(pruned.eval(&[3, 4]).unwrap(), vec![7]);
    }

    #[test]
    fn missing_inputs_default_zero() {
        let g = fig2_dfg();
        assert_eq!(g.eval(&[]).unwrap(), vec![1]); // 0 + 3*0 + 1
    }
}

//! Topological DFG partitioning: cut a DFG that is too big for the shard
//! grid into an ordered sequence of *feed-forward tiles*, each small
//! enough to place & route on its own, executed as a multi-pass schedule
//! over the same fabric (ROADMAP item 1; the automatic-tiling pattern of
//! the overlay literature applied to execution plans instead of
//! bitstreams).
//!
//! Invariants (the tiled conformance suite and `exec_fuzz` enforce them):
//!
//! * **Feed-forward**: tiles are consecutive chunks of the deterministic
//!   topological calc order, so every edge crosses tile boundaries
//!   forwards only — tile `t` never reads a value produced by tile
//!   `t' > t`. Cut edges become typed inter-tile *spill* streams
//!   ([`TileSource::Spill`]/[`TileSink::Spill`]) that round-trip through
//!   host staging between passes.
//! * **Budgeted**: each tile's calc count stays under a utilization
//!   headroom of the cell budget (a tile at 100% grid utilization would
//!   starve the Las-Vegas router of placement freedom) and its distinct
//!   input streams stay under an IO headroom of the grid perimeter.
//! * **Deterministic**: the same DFG under the same budget always yields
//!   the same tiling — tile boundaries, spill slot numbers, and per-tile
//!   local index assignments are all derived from the topological order,
//!   never from hash-map iteration. Plan cache keys depend on this.
//! * **Value-preserving**: constants are replicated into every tile that
//!   uses them; external input streams keep their original indices;
//!   [`TiledDfg::eval`] is bit-identical to [`Dfg::eval`] on the uncut
//!   graph.

use std::collections::HashMap;

use crate::dfe::grid::Grid;
use crate::dfg::graph::{Dfg, DfgError, NodeId, NodeKind};

/// Per-tile resource budget, derived from the routing grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TileBudget {
    /// Hard cell capacity (one calc node per cell).
    pub cells: usize,
    /// Grid perimeter IO ports (bounds distinct streams per tile).
    pub io: usize,
}

impl TileBudget {
    pub fn for_grid(grid: Grid) -> TileBudget {
        TileBudget { cells: grid.n_cells(), io: 2 * (grid.rows + grid.cols) }
    }

    /// Calc nodes per tile the partitioner actually targets: a third of
    /// the cell budget, so every tile routes in the same utilization
    /// regime the single-tile paths already exercise.
    pub fn eff_cells(&self) -> usize {
        (self.cells / 3).max(1)
    }

    /// Distinct input streams per tile the partitioner allows: two
    /// thirds of the perimeter (the router still needs output ports).
    pub fn eff_io(&self) -> usize {
        (self.io * 2 / 3).max(2)
    }
}

/// Where a tile's local input stream `jj` reads from.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TileSource {
    /// The original DFG's external input stream `j`.
    External(usize),
    /// Spill slot `k`: an intermediate produced by an earlier tile.
    Spill(usize),
}

/// Where a tile's local output stream `jj` writes to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TileSink {
    /// The original DFG's external output stream `j`.
    External(usize),
    /// Spill slot `k`, consumed by a later tile.
    Spill(usize),
}

/// One tile: a self-contained routable DFG plus the typed mapping of its
/// dense local input/output indices onto external streams and spill
/// slots.
#[derive(Clone, Debug)]
pub struct TileDfg {
    pub dfg: Dfg,
    /// `sources[jj]` feeds the tile's local `Input(jj)`.
    pub sources: Vec<TileSource>,
    /// `sinks[jj]` receives the tile's local `Output(jj)`.
    pub sinks: Vec<TileSink>,
}

/// The partitioned DFG: tiles in execution order plus the spill-buffer
/// count (slots are written exactly once, by their producer tile, and
/// read only by later tiles).
#[derive(Clone, Debug)]
pub struct TiledDfg {
    pub tiles: Vec<TileDfg>,
    pub n_spills: usize,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PartitionError {
    Dfg(DfgError),
    /// A single node's own distinct fan-in exceeds the per-tile input
    /// budget: no consecutive cut can ever make it fit.
    Infeasible { node: NodeId, needed: usize, io: usize },
}

impl std::fmt::Display for PartitionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PartitionError::Dfg(e) => write!(f, "{e}"),
            PartitionError::Infeasible { node, needed, io } => write!(
                f,
                "node {node} needs {needed} input streams but the tile budget allows {io}"
            ),
        }
    }
}

impl std::error::Error for PartitionError {}

/// Whether `dfg` exceeds the single-tile capacity (the exact condition
/// P&R would reject with `TooLarge`). Anything at or under capacity must
/// keep the bit-identical single-tile path.
pub fn needs_tiling(dfg: &Dfg, budget: TileBudget) -> bool {
    dfg.stats().calc > budget.cells
}

/// Intern an original-node source into a tile under construction,
/// returning its local node id. Constants replicate per tile; external
/// inputs and spilled intermediates become dense local input streams.
fn intern_src(
    dfg: &Dfg,
    spill_of: &HashMap<NodeId, usize>,
    g: &mut Dfg,
    local: &mut HashMap<NodeId, NodeId>,
    consts: &mut HashMap<i32, NodeId>,
    sources: &mut Vec<TileSource>,
    s: NodeId,
) -> NodeId {
    if let Some(&l) = local.get(&s) {
        return l;
    }
    let l = match dfg.nodes[s].kind {
        NodeKind::Const(v) => {
            if let Some(&l) = consts.get(&v) {
                l
            } else {
                let l = g.constant(v);
                consts.insert(v, l);
                l
            }
        }
        NodeKind::Input(j) => {
            let jj = sources.len();
            sources.push(TileSource::External(j));
            g.input(jj)
        }
        NodeKind::Calc(_) => {
            // A calc source outside this tile is, by the feed-forward
            // invariant, in an earlier tile and therefore spilled.
            let slot = spill_of[&s];
            let jj = sources.len();
            sources.push(TileSource::Spill(slot));
            g.input(jj)
        }
        NodeKind::Output(_) => unreachable!("outputs are never sources"),
    };
    local.insert(s, l);
    l
}

/// Distinct non-constant sources of `id` (the input streams it alone
/// would demand).
fn distinct_srcs(dfg: &Dfg, id: NodeId) -> Vec<NodeId> {
    let mut out = Vec::new();
    for &s in &dfg.nodes[id].srcs {
        if !matches!(dfg.nodes[s].kind, NodeKind::Const(_)) && !out.contains(&s) {
            out.push(s);
        }
    }
    out
}

/// Cut `dfg` into feed-forward tiles under `budget`.
///
/// Tiles are consecutive, balanced chunks of the deterministic
/// topological calc order: the minimal tile count at the utilization
/// headroom, then sizes evened out so the last tile is not a straggler.
/// A secondary IO guard cuts early when a tile's distinct input streams
/// (externals + spills + cross-tile intermediates) would exceed the
/// perimeter headroom. Output nodes ride with their producer tile
/// (pass-through outputs of inputs/constants land in tile 0).
pub fn partition(dfg: &Dfg, budget: TileBudget) -> Result<TiledDfg, PartitionError> {
    let calcs = dfg.calc_order().map_err(PartitionError::Dfg)?;
    let total = calcs.len();
    let eff = budget.eff_cells();
    let io_lim = budget.eff_io();
    for &id in &calcs {
        let need = distinct_srcs(dfg, id).len();
        if need > io_lim {
            return Err(PartitionError::Infeasible { node: id, needed: need, io: io_lim });
        }
    }
    let k = ((total + eff - 1) / eff).max(1);
    let target = ((total + k - 1) / k).max(1);

    // ---- assign calcs to consecutive tiles ----
    let mut tile_of = vec![usize::MAX; dfg.nodes.len()];
    let mut cur = 0usize;
    let mut cur_len = 0usize;
    // Distinct out-of-tile sources of the current tile (membership only —
    // never iterated, so determinism is unaffected).
    let mut cur_srcs: std::collections::HashSet<NodeId> = std::collections::HashSet::new();
    for &id in &calcs {
        let mut fresh: Vec<NodeId> = distinct_srcs(dfg, id)
            .into_iter()
            .filter(|s| tile_of[*s] != cur && !cur_srcs.contains(s))
            .collect();
        if cur_len > 0 && (cur_len >= target || cur_srcs.len() + fresh.len() > io_lim) {
            cur += 1;
            cur_len = 0;
            cur_srcs.clear();
            fresh = distinct_srcs(dfg, id);
        }
        tile_of[id] = cur;
        cur_len += 1;
        cur_srcs.extend(fresh);
    }
    let n_tiles = if total == 0 { 1 } else { cur + 1 };

    // Outputs ride with their producer (pass-throughs land in tile 0).
    for (id, node) in dfg.nodes.iter().enumerate() {
        if matches!(node.kind, NodeKind::Output(_)) {
            let s = node.srcs[0];
            tile_of[id] =
                if matches!(dfg.nodes[s].kind, NodeKind::Calc(_)) { tile_of[s] } else { 0 };
        }
    }

    // ---- spill slots, in producer topological order ----
    let mut spill_of: HashMap<NodeId, usize> = HashMap::new();
    let mut n_spills = 0usize;
    for &id in &calcs {
        let t = tile_of[id];
        let consumed_later = dfg
            .nodes
            .iter()
            .enumerate()
            .any(|(c, n)| n.srcs.contains(&id) && tile_of[c] != usize::MAX && tile_of[c] > t);
        if consumed_later {
            spill_of.insert(id, n_spills);
            n_spills += 1;
        }
    }

    // ---- materialize per-tile DFGs ----
    let mut tiles = Vec::with_capacity(n_tiles);
    for t in 0..n_tiles {
        let mut g = Dfg::new();
        let mut local: HashMap<NodeId, NodeId> = HashMap::new();
        let mut consts: HashMap<i32, NodeId> = HashMap::new();
        let mut sources: Vec<TileSource> = Vec::new();
        let mut sinks: Vec<TileSink> = Vec::new();
        for &id in &calcs {
            if tile_of[id] != t {
                continue;
            }
            let srcs: Vec<NodeId> = dfg.nodes[id]
                .srcs
                .clone()
                .into_iter()
                .map(|s| {
                    intern_src(dfg, &spill_of, &mut g, &mut local, &mut consts, &mut sources, s)
                })
                .collect();
            let l = g.add(dfg.nodes[id].kind.clone(), srcs);
            local.insert(id, l);
        }
        // Spill outputs first, slot-ascending; then external outputs in
        // original output-index order. Both orders are deterministic.
        let mut spilled: Vec<(usize, NodeId)> = calcs
            .iter()
            .filter(|&&id| tile_of[id] == t)
            .filter_map(|&id| spill_of.get(&id).map(|&slot| (slot, id)))
            .collect();
        spilled.sort_unstable();
        for (slot, id) in spilled {
            let jj = sinks.len();
            g.output(jj, local[&id]);
            sinks.push(TileSink::Spill(slot));
        }
        let mut exts: Vec<(usize, NodeId)> = dfg
            .nodes
            .iter()
            .enumerate()
            .filter(|&(oid, n)| matches!(n.kind, NodeKind::Output(_)) && tile_of[oid] == t)
            .map(|(_, n)| {
                let NodeKind::Output(j) = n.kind else { unreachable!() };
                (j, n.srcs[0])
            })
            .collect();
        exts.sort_unstable();
        for (j, src) in exts {
            let l = intern_src(dfg, &spill_of, &mut g, &mut local, &mut consts, &mut sources, src);
            let jj = sinks.len();
            g.output(jj, l);
            sinks.push(TileSink::External(j));
        }
        debug_assert!(g.validate().is_ok());
        tiles.push(TileDfg { dfg: g, sources, sinks });
    }
    Ok(TiledDfg { tiles, n_spills })
}

impl TiledDfg {
    pub fn n_tiles(&self) -> usize {
        self.tiles.len()
    }

    /// Reference evaluation of one stream element through the multi-pass
    /// schedule. Must be bit-identical to `Dfg::eval` on the uncut graph
    /// (the partition tests and `exec_fuzz` enforce it).
    pub fn eval(&self, inputs: &[i32]) -> Result<Vec<i32>, DfgError> {
        let mut spills = vec![0i32; self.n_spills];
        let mut ext: Vec<(usize, i32)> = Vec::new();
        let mut n_out = 0usize;
        for tile in &self.tiles {
            let local_in: Vec<i32> = tile
                .sources
                .iter()
                .map(|s| match *s {
                    TileSource::External(j) => inputs.get(j).copied().unwrap_or(0),
                    TileSource::Spill(k) => spills[k],
                })
                .collect();
            let out = tile.dfg.eval(&local_in)?;
            for (jj, sink) in tile.sinks.iter().enumerate() {
                match *sink {
                    TileSink::Spill(k) => spills[k] = out[jj],
                    TileSink::External(j) => {
                        n_out = n_out.max(j + 1);
                        ext.push((j, out[jj]));
                    }
                }
            }
        }
        let mut res = vec![0i32; n_out];
        for (j, v) in ext {
            res[j] = v;
        }
        Ok(res)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfe::opcodes::Op;
    use crate::dfg::graph::{fig2_dfg, listing1_dfg};

    /// A wider synthetic graph: a reduction tree over 8 products with a
    /// MUX at the root (17 calcs, 17 inputs).
    fn big_dfg() -> Dfg {
        let mut g = Dfg::new();
        let mut lvl: Vec<NodeId> = (0..8)
            .map(|i| {
                let a = g.input(2 * i);
                let b = g.input(2 * i + 1);
                g.calc(Op::Mul, a, b)
            })
            .collect();
        while lvl.len() > 1 {
            lvl = lvl.chunks(2).map(|p| g.calc(Op::Add, p[0], p[1])).collect();
        }
        let sel = g.input(16);
        let c7 = g.constant(7);
        let alt = g.calc(Op::Sub, lvl[0], c7);
        let r = g.mux(lvl[0], alt, sel);
        g.output(0, r);
        g.output(1, alt);
        g
    }

    fn check_equiv(dfg: &Dfg, budget: TileBudget, inputs: &[i32]) {
        let tiled = partition(dfg, budget).expect("partition");
        for t in &tiled.tiles {
            t.dfg.validate().expect("tile validates");
            assert!(t.dfg.stats().calc <= budget.cells, "tile busts cell budget");
            assert!(t.sources.len() <= budget.eff_io(), "tile busts io budget");
        }
        assert_eq!(tiled.eval(inputs).unwrap(), dfg.eval(inputs).unwrap());
    }

    #[test]
    fn fig2_tiles_one_calc_per_tile() {
        let g = fig2_dfg();
        let b = TileBudget { cells: 1, io: 8 };
        let tiled = partition(&g, b).unwrap();
        assert_eq!(tiled.n_tiles(), 3, "3 calcs at 1 per tile");
        assert_eq!(tiled.n_spills, 2, "mul and first add spill");
        assert_eq!(tiled.eval(&[10, 5]).unwrap(), vec![26]);
    }

    #[test]
    fn listing1_mux_survives_tiling() {
        let g = listing1_dfg();
        let b = TileBudget { cells: 6, io: 10 };
        check_equiv(&g, b, &[10, 2]);
        check_equiv(&g, b, &[2, 10]);
        assert!(partition(&g, b).unwrap().n_tiles() > 1);
    }

    #[test]
    fn under_capacity_stays_single_tile() {
        let g = fig2_dfg();
        let b = TileBudget::for_grid(Grid::new(4, 4));
        assert!(!needs_tiling(&g, b));
        let tiled = partition(&g, b).unwrap();
        assert_eq!(tiled.n_tiles(), 1);
        assert_eq!(tiled.n_spills, 0);
        // Local input order follows first use in the topological calc
        // order (the mul consumes B before the add consumes A).
        assert_eq!(tiled.tiles[0].sources, vec![TileSource::External(1), TileSource::External(0)]);
        assert_eq!(tiled.tiles[0].sinks, vec![TileSink::External(0)]);
        assert_eq!(tiled.eval(&[10, 5]).unwrap(), vec![26]);
    }

    #[test]
    fn big_graph_equivalent_under_many_budgets() {
        let g = big_dfg();
        for cells in [2usize, 3, 5, 8, 30] {
            let b = TileBudget { cells, io: 12 };
            check_equiv(&g, b, &[1, 2, 3, 4, 5, 6, 7, 8, 1, 1, 2, 2, 3, 3, 4, 4, 0]);
            check_equiv(&g, b, &[9, -3, 0, 7, -1, 4, 2, 2, 5, 5, 6, 1, 0, 0, 8, -8, 1]);
        }
    }

    #[test]
    fn tiling_is_deterministic() {
        let g = big_dfg();
        let b = TileBudget { cells: 4, io: 10 };
        let a = partition(&g, b).unwrap();
        let c = partition(&g, b).unwrap();
        assert_eq!(format!("{a:?}"), format!("{c:?}"), "same DFG + budget, same tiling");
    }

    #[test]
    fn spill_slots_are_producer_ordered() {
        let g = big_dfg();
        let b = TileBudget { cells: 4, io: 10 };
        let tiled = partition(&g, b).unwrap();
        let mut next = 0usize;
        for t in &tiled.tiles {
            for s in &t.sinks {
                if let TileSink::Spill(k) = s {
                    assert_eq!(*k, next, "slots assigned in producer order");
                    next += 1;
                }
            }
        }
        assert_eq!(next, tiled.n_spills);
    }

    #[test]
    fn infeasible_fanin_reports_structured_error() {
        let mut g = Dfg::new();
        let a = g.input(0);
        let b = g.input(1);
        let s = g.input(2);
        let m = g.mux(a, b, s);
        g.output(0, m);
        let err = partition(&g, TileBudget { cells: 1, io: 3 }).unwrap_err();
        assert!(matches!(err, PartitionError::Infeasible { needed: 3, io: 2, .. }), "{err:?}");
    }
}

//! Workload library: the PolyBench suite (Table I) and the video-conv
//! pipeline (§IV-C) authored on the mini-IR.
//!
//! The multi-tenant serving mixes built from these kernels live in
//! [`crate::offload::server`] (`polybench_mix` / `serve_mix`).
pub mod polybench;
pub mod video;

//! Workload library: the PolyBench suite (Table I) and the video-conv
//! pipeline (§IV-C) authored on the mini-IR.
pub mod polybench;
pub mod video;

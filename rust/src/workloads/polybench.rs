//! The PolyBench suite authored on the mini-IR (paper §IV-A, Table I).
//!
//! 25 kernels: the 21 Table-I rows plus `nussinov`, `floyd-warshall` (the
//! paper's two "no SCoP detected" cases) and `deriche`, `durbin` (standing
//! in for the paper's two unnamed kernels whose SCoPs are invalidated by
//! MUX-node handling — authored here with side-effecting branches that
//! defeat if-conversion).
//!
//! Kernels the paper marks offloadable are integer; `fdtd-2d` and the
//! `jacobi` stencils are f32 (rejected: "fp data"); `adi`, `lu`, `ludcmp`,
//! `seidel`, `trisolv` use integer division (rejected: "divisions").
//! `trmm` is authored out-of-place (writes `Bout`) so its stream form is
//! dependence-free; see DESIGN.md §Substitutions.

use crate::ir::func::{FuncBuilder, Function};
use crate::ir::instr::{BinOp, CmpPred, Reg, Term, Ty};

/// Paper's Table-I row for comparison in the bench harness.
#[derive(Clone, Copy, Debug)]
pub struct PaperRow {
    pub offload: &'static str,
    /// in/out/calc (empty when not offloaded).
    pub nodes: &'static str,
    pub analysis_us: u64,
}

pub struct Kernel {
    pub name: &'static str,
    pub func: Function,
    pub paper: PaperRow,
    /// Unroll factor used for the Table-I harness.
    pub unroll: usize,
}

fn p(offload: &'static str, nodes: &'static str, analysis_us: u64) -> PaperRow {
    PaperRow { offload, nodes, analysis_us }
}

/// 2D index helper: `base[i*cols + j]`.
fn idx2(b: &mut FuncBuilder, i: Reg, j: Reg, cols: Reg) -> Reg {
    let r = b.mul(i, cols);
    b.add(r, j)
}

/// `dst[i][j] += s` accumulate-style inner statement:
/// loads dst, adds, stores (recognized as a reduction when the subscript
/// is invariant in the innermost loop).
fn accum2(b: &mut FuncBuilder, dst: Reg, i: Reg, j: Reg, cols: Reg, s: Reg) {
    let ij = idx2(b, i, j, cols);
    let cur = b.load(Ty::I32, dst, ij);
    let nxt = b.add(cur, s);
    let ij2 = idx2(b, i, j, cols);
    b.store(Ty::I32, dst, ij2, nxt);
}

// ---------------- offloadable integer kernels ----------------

/// C[i][j] += alpha * A[i][k] * B[k][j]
fn gemm_like(name: &'static str, extra_mm: usize) -> Function {
    // extra_mm > 0 chains additional matmuls (2mm/3mm) over temps.
    let mut params = vec![
        ("C", Ty::Ptr),
        ("A", Ty::Ptr),
        ("B", Ty::Ptr),
        ("alpha", Ty::I32),
        ("n", Ty::I32),
    ];
    for t in 0..extra_mm {
        params.push((["T1", "T2"][t], Ty::Ptr));
    }
    let mut b = FuncBuilder::new(name, &params);
    let (c, a, bb, alpha, n) = (b.param(0), b.param(1), b.param(2), b.param(3), b.param(4));
    let mut mats = vec![(a, bb, c)];
    for t in 0..extra_mm {
        let tp = b.param(5 + t);
        let prev_out = mats.last().unwrap().2;
        mats.push((prev_out, bb, tp));
    }
    for (ma, mb, mc) in mats {
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, i| {
            let z = b.const_i32(0);
            b.counted_loop(z, n, |b, j| {
                let z2 = b.const_i32(0);
                b.counted_loop(z2, n, |b, k| {
                    let ik = idx2(b, i, k, n);
                    let kj = idx2(b, k, j, n);
                    let av = b.load(Ty::I32, ma, ik);
                    let bv = b.load(Ty::I32, mb, kj);
                    let t0 = b.mul(av, bv);
                    let t1 = b.mul(t0, alpha);
                    accum2(b, mc, i, j, n, t1);
                });
            });
        });
    }
    b.ret(None)
}

pub fn gemm() -> Function {
    gemm_like("gemm", 0)
}

pub fn two_mm() -> Function {
    gemm_like("2mm", 1)
}

pub fn three_mm() -> Function {
    gemm_like("3mm", 2)
}

/// atax: tmp[i] += A[i][j]*x[j]; then y[j] (second nest, RMW per j).
pub fn atax() -> Function {
    let mut b = FuncBuilder::new(
        "atax",
        &[("A", Ty::Ptr), ("x", Ty::Ptr), ("y", Ty::Ptr), ("tmp", Ty::Ptr), ("n", Ty::I32)],
    );
    let (a, x, y, tmp, n) = (b.param(0), b.param(1), b.param(2), b.param(3), b.param(4));
    let zero = b.const_i32(0);
    b.counted_loop(zero, n, |b, i| {
        let z = b.const_i32(0);
        b.counted_loop(z, n, |b, j| {
            let ij = idx2(b, i, j, n);
            let av = b.load(Ty::I32, a, ij);
            let xv = b.load(Ty::I32, x, j);
            let t = b.mul(av, xv);
            let cur = b.load(Ty::I32, tmp, i);
            let nxt = b.add(cur, t);
            b.store(Ty::I32, tmp, i, nxt);
        });
    });
    let zero2 = b.const_i32(0);
    b.counted_loop(zero2, n, |b, i| {
        let z = b.const_i32(0);
        b.counted_loop(z, n, |b, j| {
            let ij = idx2(b, i, j, n);
            let av = b.load(Ty::I32, a, ij);
            let tv = b.load(Ty::I32, tmp, i);
            let t = b.mul(av, tv);
            let cur = b.load(Ty::I32, y, j);
            let nxt = b.add(cur, t);
            b.store(Ty::I32, y, j, nxt);
        });
    });
    b.ret(None)
}

/// bicg: s[j] += r[i]*A[i][j];  q[i] += A[i][j]*p[j]
pub fn bicg() -> Function {
    let mut b = FuncBuilder::new(
        "bicg",
        &[
            ("A", Ty::Ptr),
            ("s", Ty::Ptr),
            ("q", Ty::Ptr),
            ("p", Ty::Ptr),
            ("r", Ty::Ptr),
            ("n", Ty::I32),
        ],
    );
    let (a, s, q, pp, r, n) =
        (b.param(0), b.param(1), b.param(2), b.param(3), b.param(4), b.param(5));
    let zero = b.const_i32(0);
    b.counted_loop(zero, n, |b, i| {
        let z = b.const_i32(0);
        b.counted_loop(z, n, |b, j| {
            let ij = idx2(b, i, j, n);
            let av = b.load(Ty::I32, a, ij);
            let rv = b.load(Ty::I32, r, i);
            let t = b.mul(rv, av);
            let cur = b.load(Ty::I32, s, j);
            let nxt = b.add(cur, t);
            b.store(Ty::I32, s, j, nxt);
        });
    });
    let zero2 = b.const_i32(0);
    b.counted_loop(zero2, n, |b, i| {
        let z = b.const_i32(0);
        b.counted_loop(z, n, |b, j| {
            let ij = idx2(b, i, j, n);
            let av = b.load(Ty::I32, a, ij);
            let pv = b.load(Ty::I32, pp, j);
            let t = b.mul(av, pv);
            let cur = b.load(Ty::I32, q, i);
            let nxt = b.add(cur, t);
            b.store(Ty::I32, q, i, nxt);
        });
    });
    b.ret(None)
}

/// mvt: x1[i] += A[i][j]*y1[j]; x2[i] += A[j][i]*y2[j]
pub fn mvt() -> Function {
    let mut b = FuncBuilder::new(
        "mvt",
        &[
            ("A", Ty::Ptr),
            ("x1", Ty::Ptr),
            ("x2", Ty::Ptr),
            ("y1", Ty::Ptr),
            ("y2", Ty::Ptr),
            ("n", Ty::I32),
        ],
    );
    let (a, x1, x2, y1, y2, n) =
        (b.param(0), b.param(1), b.param(2), b.param(3), b.param(4), b.param(5));
    for (x, y, transposed) in [(x1, y1, false), (x2, y2, true)] {
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, i| {
            let z = b.const_i32(0);
            b.counted_loop(z, n, |b, j| {
                let ij = if transposed { idx2(b, j, i, n) } else { idx2(b, i, j, n) };
                let av = b.load(Ty::I32, a, ij);
                let yv = b.load(Ty::I32, y, j);
                let t = b.mul(av, yv);
                let cur = b.load(Ty::I32, x, i);
                let nxt = b.add(cur, t);
                b.store(Ty::I32, x, i, nxt);
            });
        });
    }
    b.ret(None)
}

/// gemver-like: A[i][j] += u1[i]*v1[j] + u2[i]*v2[j]; x[i] += A?[j][i]*y[j]
pub fn gemver() -> Function {
    let mut b = FuncBuilder::new(
        "gemver",
        &[
            ("A", Ty::Ptr),
            ("u1", Ty::Ptr),
            ("v1", Ty::Ptr),
            ("u2", Ty::Ptr),
            ("v2", Ty::Ptr),
            ("x", Ty::Ptr),
            ("y", Ty::Ptr),
            ("n", Ty::I32),
        ],
    );
    let (a, u1, v1, u2, v2, x, y, n) = (
        b.param(0), b.param(1), b.param(2), b.param(3), b.param(4), b.param(5), b.param(6),
        b.param(7),
    );
    let zero = b.const_i32(0);
    b.counted_loop(zero, n, |b, i| {
        let z = b.const_i32(0);
        b.counted_loop(z, n, |b, j| {
            let ij = idx2(b, i, j, n);
            let av = b.load(Ty::I32, a, ij);
            let t1a = b.load(Ty::I32, u1, i);
            let t1b = b.load(Ty::I32, v1, j);
            let t1 = b.mul(t1a, t1b);
            let t2a = b.load(Ty::I32, u2, i);
            let t2b = b.load(Ty::I32, v2, j);
            let t2 = b.mul(t2a, t2b);
            let s = b.add(t1, t2);
            let nv = b.add(av, s);
            let ij2 = idx2(b, i, j, n);
            b.store(Ty::I32, a, ij2, nv);
        });
    });
    let zero2 = b.const_i32(0);
    b.counted_loop(zero2, n, |b, i| {
        let z = b.const_i32(0);
        b.counted_loop(z, n, |b, j| {
            let ji = idx2(b, j, i, n);
            let av = b.load(Ty::I32, a, ji);
            let yv = b.load(Ty::I32, y, j);
            let t = b.mul(av, yv);
            let cur = b.load(Ty::I32, x, i);
            let nxt = b.add(cur, t);
            b.store(Ty::I32, x, i, nxt);
        });
    });
    b.ret(None)
}

/// gesummv: tmp[i] += A[i][j]*x[j]; y[i] += B[i][j]*x[j] (then combine).
pub fn gesummv() -> Function {
    let mut b = FuncBuilder::new(
        "gesummv",
        &[
            ("A", Ty::Ptr),
            ("B", Ty::Ptr),
            ("x", Ty::Ptr),
            ("tmp", Ty::Ptr),
            ("y", Ty::Ptr),
            ("alpha", Ty::I32),
            ("beta", Ty::I32),
            ("n", Ty::I32),
        ],
    );
    let (a, bm, x, tmp, y, alpha, beta, n) = (
        b.param(0), b.param(1), b.param(2), b.param(3), b.param(4), b.param(5), b.param(6),
        b.param(7),
    );
    let zero = b.const_i32(0);
    b.counted_loop(zero, n, |b, i| {
        let z = b.const_i32(0);
        b.counted_loop(z, n, |b, j| {
            let ij = idx2(b, i, j, n);
            let av = b.load(Ty::I32, a, ij);
            let xv = b.load(Ty::I32, x, j);
            let ta = b.mul(av, xv);
            let tas = b.mul(ta, alpha);
            let cur = b.load(Ty::I32, tmp, i);
            let nxt = b.add(cur, tas);
            b.store(Ty::I32, tmp, i, nxt);
            let ij2 = idx2(b, i, j, n);
            let bv = b.load(Ty::I32, bm, ij2);
            let tb = b.mul(bv, xv);
            let tbs = b.mul(tb, beta);
            let cur2 = b.load(Ty::I32, y, i);
            let nxt2 = b.add(cur2, tbs);
            b.store(Ty::I32, y, i, nxt2);
        });
    });
    b.ret(None)
}

/// syrk: C[i][j] += alpha * A[i][k] * A[j][k]
pub fn syrk() -> Function {
    let mut b = FuncBuilder::new(
        "syrk",
        &[("C", Ty::Ptr), ("A", Ty::Ptr), ("alpha", Ty::I32), ("n", Ty::I32)],
    );
    let (c, a, alpha, n) = (b.param(0), b.param(1), b.param(2), b.param(3));
    let zero = b.const_i32(0);
    b.counted_loop(zero, n, |b, i| {
        let z = b.const_i32(0);
        b.counted_loop(z, n, |b, j| {
            let z2 = b.const_i32(0);
            b.counted_loop(z2, n, |b, k| {
                let ik = idx2(b, i, k, n);
                let jk = idx2(b, j, k, n);
                let av = b.load(Ty::I32, a, ik);
                let av2 = b.load(Ty::I32, a, jk);
                let t0 = b.mul(av, av2);
                let t1 = b.mul(t0, alpha);
                accum2(b, c, i, j, n, t1);
            });
        });
    });
    b.ret(None)
}

/// syr2k: C[i][j] += alpha*(A[i][k]*B[j][k] + B[i][k]*A[j][k])
pub fn syr2k() -> Function {
    let mut b = FuncBuilder::new(
        "syr2k",
        &[("C", Ty::Ptr), ("A", Ty::Ptr), ("B", Ty::Ptr), ("alpha", Ty::I32), ("n", Ty::I32)],
    );
    let (c, a, bm, alpha, n) = (b.param(0), b.param(1), b.param(2), b.param(3), b.param(4));
    let zero = b.const_i32(0);
    b.counted_loop(zero, n, |b, i| {
        let z = b.const_i32(0);
        b.counted_loop(z, n, |b, j| {
            let z2 = b.const_i32(0);
            b.counted_loop(z2, n, |b, k| {
                let ik = idx2(b, i, k, n);
                let jk = idx2(b, j, k, n);
                let a_ik = b.load(Ty::I32, a, ik);
                let b_jk = b.load(Ty::I32, bm, jk);
                let t0 = b.mul(a_ik, b_jk);
                let ik2 = idx2(b, i, k, n);
                let jk2 = idx2(b, j, k, n);
                let b_ik = b.load(Ty::I32, bm, ik2);
                let a_jk = b.load(Ty::I32, a, jk2);
                let t1 = b.mul(b_ik, a_jk);
                let s = b.add(t0, t1);
                let t2 = b.mul(s, alpha);
                accum2(b, c, i, j, n, t2);
            });
        });
    });
    b.ret(None)
}

/// symm (simplified): C[i][j] += alpha * A[i][k] * B[k][j]
pub fn symm() -> Function {
    let mut b = FuncBuilder::new(
        "symm",
        &[("C", Ty::Ptr), ("A", Ty::Ptr), ("B", Ty::Ptr), ("alpha", Ty::I32), ("n", Ty::I32)],
    );
    let (c, a, bm, alpha, n) = (b.param(0), b.param(1), b.param(2), b.param(3), b.param(4));
    let zero = b.const_i32(0);
    b.counted_loop(zero, n, |b, i| {
        let z = b.const_i32(0);
        b.counted_loop(z, n, |b, j| {
            let z2 = b.const_i32(0);
            b.counted_loop(z2, n, |b, k| {
                let ik = idx2(b, i, k, n);
                let kj = idx2(b, k, j, n);
                let av = b.load(Ty::I32, a, ik);
                let bv = b.load(Ty::I32, bm, kj);
                let t0 = b.mul(av, bv);
                let t1 = b.mul(t0, alpha);
                accum2(b, c, i, j, n, t1);
            });
        });
    });
    b.ret(None)
}

/// trmm (out-of-place; see module doc): Bout[i][j] += A[i][k] * B[k][j]
pub fn trmm() -> Function {
    let mut b = FuncBuilder::new(
        "trmm",
        &[("Bout", Ty::Ptr), ("A", Ty::Ptr), ("B", Ty::Ptr), ("n", Ty::I32)],
    );
    let (bo, a, bm, n) = (b.param(0), b.param(1), b.param(2), b.param(3));
    let zero = b.const_i32(0);
    b.counted_loop(zero, n, |b, i| {
        let z = b.const_i32(0);
        b.counted_loop(z, n, |b, j| {
            let z2 = b.const_i32(0);
            b.counted_loop(z2, n, |b, k| {
                let ik = idx2(b, i, k, n);
                let kj = idx2(b, k, j, n);
                let av = b.load(Ty::I32, a, ik);
                let bv = b.load(Ty::I32, bm, kj);
                let t = b.mul(av, bv);
                accum2(b, bo, i, j, n, t);
            });
        });
    });
    b.ret(None)
}

/// heat-3d (integer 3-D stencil, two ping-pong nests; the paper's largest
/// DFG — with unroll 4 the extraction lands near 300 nodes and the
/// 24x18 place&route fails, reproducing the Table-I note).
pub fn heat3d() -> Function {
    // `nn` is the plane stride (n*n), passed explicitly the way a C
    // frontend lowers `A[i][j][k]` on a [n][n][n] array.
    let mut b = FuncBuilder::new(
        "heat-3d",
        &[("A", Ty::Ptr), ("B", Ty::Ptr), ("n", Ty::I32), ("nn", Ty::I32)],
    );
    let (a, bm, n, nn) = (b.param(0), b.param(1), b.param(2), b.param(3));
    for (src, dst) in [(a, bm), (bm, a)] {
        let one = b.const_i32(1);
        let n1 = {
            let o = b.const_i32(1);
            b.sub(n, o)
        };
        b.counted_loop(one, n1, |b, i| {
            let o1 = b.const_i32(1);
            let ub = b.sub(n, o1);
            let lo = b.const_i32(1);
            b.counted_loop(lo, ub, |b, j| {
                let o2 = b.const_i32(1);
                let ub2 = b.sub(n, o2);
                let lo2 = b.const_i32(1);
                b.counted_loop(lo2, ub2, |b, k| {
                    // idx = (i*n + j)*n + k, neighbours along each axis
                    let mut load_at = |b: &mut FuncBuilder, di: i32, dj: i32, dk: i32| {
                        let ci = b.const_i32(di);
                        let ii = b.add(i, ci);
                        let cj = b.const_i32(dj);
                        let jj = b.add(j, cj);
                        let ck = b.const_i32(dk);
                        let kk = b.add(k, ck);
                        let t0 = b.mul(ii, nn);
                        let t1 = b.mul(jj, n);
                        let t2 = b.add(t0, t1);
                        let idx = b.add(t2, kk);
                        b.load(Ty::I32, src, idx)
                    };
                    let c0 = load_at(b, 0, 0, 0);
                    let xm = load_at(b, -1, 0, 0);
                    let xp = load_at(b, 1, 0, 0);
                    let ym = load_at(b, 0, -1, 0);
                    let yp = load_at(b, 0, 1, 0);
                    let zm = load_at(b, 0, 0, -1);
                    let zp = load_at(b, 0, 0, 1);
                    // Per-axis second difference, scaled and accumulated
                    // (the paper's 0.125*(..) - 2*(..) + .. form in
                    // fixed-point): r = c0 + Σ_axis ((m + p - 2c0) >> 3)
                    let two = b.const_i32(2);
                    let shift = b.const_i32(3);
                    let mut r = c0;
                    for (m, p) in [(xm, xp), (ym, yp), (zm, zp)] {
                        let s = b.add(m, p);
                        let c2 = b.mul(c0, two);
                        let d = b.sub(s, c2);
                        let dd = b.bin(BinOp::Shr, Ty::I32, d, shift);
                        r = b.add(r, dd);
                    }
                    let t0 = b.mul(i, nn);
                    let t1 = b.mul(j, n);
                    let t2 = b.add(t0, t1);
                    let idx = b.add(t2, k);
                    b.store(Ty::I32, dst, idx, r);
                });
            });
        });
    }
    b.ret(None)
}

// ---------------- rejected kernels ----------------

/// Integer division in the innermost statement → "No, divisions".
fn division_kernel(name: &'static str) -> Function {
    let mut b = FuncBuilder::new(name, &[("A", Ty::Ptr), ("n", Ty::I32)]);
    let (a, n) = (b.param(0), b.param(1));
    let zero = b.const_i32(0);
    b.counted_loop(zero, n, |b, i| {
        let z = b.const_i32(0);
        b.counted_loop(z, n, |b, j| {
            let ij = idx2(b, i, j, n);
            let v = b.load(Ty::I32, a, ij);
            let ii = idx2(b, i, i, n);
            let piv = b.load(Ty::I32, a, ii);
            let q = b.bin(BinOp::Div, Ty::I32, v, piv);
            let ij2 = idx2(b, i, j, n);
            b.store(Ty::I32, a, ij2, q);
        });
    });
    b.ret(None)
}

pub fn adi() -> Function {
    division_kernel("adi")
}
pub fn lu() -> Function {
    division_kernel("lu")
}
pub fn ludcmp() -> Function {
    division_kernel("ludcmp")
}
pub fn seidel() -> Function {
    division_kernel("seidel")
}
pub fn trisolv() -> Function {
    division_kernel("trisolv")
}

/// f32 stencil → "No, fp data".
fn fp_kernel(name: &'static str) -> Function {
    let mut b = FuncBuilder::new(name, &[("A", Ty::Ptr), ("B", Ty::Ptr), ("n", Ty::I32)]);
    let (a, bm, n) = (b.param(0), b.param(1), b.param(2));
    let one = b.const_i32(1);
    let ub = {
        let o = b.const_i32(1);
        b.sub(n, o)
    };
    b.counted_loop(one, ub, |b, i| {
        let o = b.const_i32(1);
        let im1 = b.sub(i, o);
        let ip1 = b.add(i, o);
        let v0 = b.load(Ty::F32, a, im1);
        let v1 = b.load(Ty::F32, a, i);
        let v2 = b.load(Ty::F32, a, ip1);
        let s = b.fadd(v0, v1);
        let s2 = b.fadd(s, v2);
        let third = b.const_f32(1.0 / 3.0);
        let r = b.fmul(s2, third);
        b.store(Ty::F32, bm, i, r);
    });
    b.ret(None)
}

pub fn fdtd_2d() -> Function {
    fp_kernel("fdtd-2d")
}
pub fn jacobi_1d() -> Function {
    fp_kernel("jacobi-1D")
}
pub fn jacobi_2d() -> Function {
    fp_kernel("jacobi-2D")
}

/// nussinov: indirect (data-dependent) subscript → no SCoP.
pub fn nussinov() -> Function {
    let mut b = FuncBuilder::new("nussinov", &[("T", Ty::Ptr), ("S", Ty::Ptr), ("n", Ty::I32)]);
    let (t, s, n) = (b.param(0), b.param(1), b.param(2));
    let zero = b.const_i32(0);
    b.counted_loop(zero, n, |b, i| {
        let z = b.const_i32(0);
        b.counted_loop(z, n, |b, j| {
            let sj = b.load(Ty::I32, s, j); // data-dependent index
            let v = b.load(Ty::I32, t, sj);
            let w = b.load(Ty::I32, t, i);
            let m = b.bin(BinOp::Max, Ty::I32, v, w);
            b.store(Ty::I32, t, i, m);
        });
    });
    b.ret(None)
}

/// floyd-warshall: authored with a non-canonical (down-counting) loop —
/// the shape a decompiler actually produces — so no SCoP is detected.
pub fn floyd_warshall() -> Function {
    let mut b = FuncBuilder::new("floyd-warshall", &[("P", Ty::Ptr), ("n", Ty::I32)]);
    let (pm, n) = (b.param(0), b.param(1));
    // k counts DOWN from n-1 to 0: header uses cmp.lt k, n with a
    // decrementing latch — not the canonical +1 form.
    let k = b.fresh();
    let one = b.const_i32(1);
    let nm1 = b.sub(n, one);
    b.mov_into(k, nm1);
    let header = b.new_block();
    let body = b.new_block();
    let exit = b.new_block();
    b.terminate(Term::Br(header));
    b.switch_to(header);
    let zero = b.const_i32(0);
    let c = b.cmp(CmpPred::Ge, k, zero);
    b.terminate(Term::CondBr { c, t: body, f: exit });
    b.switch_to(body);
    let kk = idx2(&mut b, k, k, n);
    let v = b.load(Ty::I32, pm, kk);
    let v2 = b.add(v, v);
    b.store(Ty::I32, pm, kk, v2);
    let one2 = b.const_i32(1);
    let next = b.sub(k, one2);
    b.mov_into(k, next);
    b.terminate(Term::Br(header));
    b.switch_to(exit);
    b.ret(None)
}

/// Side-effecting branch arms (stores under control flow) defeat the MUX
/// if-conversion → the paper's "problem managing MUX nodes" failure.
fn bad_mux_kernel(name: &'static str) -> Function {
    let mut b = FuncBuilder::new(name, &[("A", Ty::Ptr), ("B", Ty::Ptr), ("n", Ty::I32)]);
    let (a, bm, n) = (b.param(0), b.param(1), b.param(2));
    let zero = b.const_i32(0);
    b.counted_loop(zero, n, |b, i| {
        let v = b.load(Ty::I32, a, i);
        let z = b.const_i32(0);
        let c = b.cmp(CmpPred::Gt, v, z);
        let tb = b.new_block();
        let fb = b.new_block();
        let join = b.new_block();
        b.terminate(Term::CondBr { c, t: tb, f: fb });
        b.switch_to(tb);
        b.store(Ty::I32, bm, i, v); // store under control flow
        b.terminate(Term::Br(join));
        b.switch_to(fb);
        let nv = b.sub(z, v);
        b.store(Ty::I32, a, i, nv); // different array in the other arm
        b.terminate(Term::Br(join));
        b.switch_to(join);
    });
    b.ret(None)
}

pub fn deriche() -> Function {
    bad_mux_kernel("deriche")
}
pub fn durbin() -> Function {
    bad_mux_kernel("durbin")
}

// ---------------- host reference oracles ----------------
//
// Plain-Rust renditions of the kernels above, statement order and
// wrapping-i32 arithmetic matching the interpreter exactly. These are the
// conformance suite's ground truth: interpreter ≡ offloaded (any DFE
// backend) ≡ `*_reference`, bit for bit.

/// gemm_reference: C[i][j] += A[i][k] * B[k][j] * alpha.
pub fn gemm_reference(c: &mut [i32], a: &[i32], b: &[i32], alpha: i32, n: usize) {
    for i in 0..n {
        for j in 0..n {
            for k in 0..n {
                let t = a[i * n + k].wrapping_mul(b[k * n + j]).wrapping_mul(alpha);
                c[i * n + j] = c[i * n + j].wrapping_add(t);
            }
        }
    }
}

/// two_mm_reference: gemm, then T1[i][j] += C[i][k] * B[k][j] * alpha.
pub fn two_mm_reference(
    c: &mut [i32],
    a: &[i32],
    b: &[i32],
    t1: &mut [i32],
    alpha: i32,
    n: usize,
) {
    gemm_reference(c, a, b, alpha, n);
    let cc = c.to_vec();
    gemm_reference(t1, &cc, b, alpha, n);
}

/// three_mm_reference: 2mm, then T2[i][j] += T1[i][k] * B[k][j] * alpha.
pub fn three_mm_reference(
    c: &mut [i32],
    a: &[i32],
    b: &[i32],
    t1: &mut [i32],
    t2: &mut [i32],
    alpha: i32,
    n: usize,
) {
    two_mm_reference(c, a, b, t1, alpha, n);
    let tt = t1.to_vec();
    gemm_reference(t2, &tt, b, alpha, n);
}

/// atax_reference: tmp[i] += A[i][j]*x[j]; then y[j] += A[i][j]*tmp[i].
pub fn atax_reference(a: &[i32], x: &[i32], y: &mut [i32], tmp: &mut [i32], n: usize) {
    for i in 0..n {
        for j in 0..n {
            tmp[i] = tmp[i].wrapping_add(a[i * n + j].wrapping_mul(x[j]));
        }
    }
    for i in 0..n {
        for j in 0..n {
            y[j] = y[j].wrapping_add(a[i * n + j].wrapping_mul(tmp[i]));
        }
    }
}

/// bicg_reference: s[j] += r[i]*A[i][j]; then q[i] += A[i][j]*p[j].
pub fn bicg_reference(
    a: &[i32],
    s: &mut [i32],
    q: &mut [i32],
    p: &[i32],
    r: &[i32],
    n: usize,
) {
    for i in 0..n {
        for j in 0..n {
            s[j] = s[j].wrapping_add(r[i].wrapping_mul(a[i * n + j]));
        }
    }
    for i in 0..n {
        for j in 0..n {
            q[i] = q[i].wrapping_add(a[i * n + j].wrapping_mul(p[j]));
        }
    }
}

/// mvt_reference: x1[i] += A[i][j]*y1[j]; x2[i] += A[j][i]*y2[j].
pub fn mvt_reference(
    a: &[i32],
    x1: &mut [i32],
    x2: &mut [i32],
    y1: &[i32],
    y2: &[i32],
    n: usize,
) {
    for i in 0..n {
        for j in 0..n {
            x1[i] = x1[i].wrapping_add(a[i * n + j].wrapping_mul(y1[j]));
        }
    }
    for i in 0..n {
        for j in 0..n {
            x2[i] = x2[i].wrapping_add(a[j * n + i].wrapping_mul(y2[j]));
        }
    }
}

/// gemver_reference: A[i][j] += u1[i]*v1[j] + u2[i]*v2[j]; then
/// x[i] += A[j][i]*y[j].
#[allow(clippy::too_many_arguments)]
pub fn gemver_reference(
    a: &mut [i32],
    u1: &[i32],
    v1: &[i32],
    u2: &[i32],
    v2: &[i32],
    x: &mut [i32],
    y: &[i32],
    n: usize,
) {
    for i in 0..n {
        for j in 0..n {
            let s = u1[i]
                .wrapping_mul(v1[j])
                .wrapping_add(u2[i].wrapping_mul(v2[j]));
            a[i * n + j] = a[i * n + j].wrapping_add(s);
        }
    }
    for i in 0..n {
        for j in 0..n {
            x[i] = x[i].wrapping_add(a[j * n + i].wrapping_mul(y[j]));
        }
    }
}

/// gesummv_reference: tmp[i] += A[i][j]*x[j]*alpha; y[i] += B[i][j]*x[j]*beta.
#[allow(clippy::too_many_arguments)]
pub fn gesummv_reference(
    a: &[i32],
    b: &[i32],
    x: &[i32],
    tmp: &mut [i32],
    y: &mut [i32],
    alpha: i32,
    beta: i32,
    n: usize,
) {
    for i in 0..n {
        for j in 0..n {
            tmp[i] = tmp[i]
                .wrapping_add(a[i * n + j].wrapping_mul(x[j]).wrapping_mul(alpha));
            y[i] = y[i]
                .wrapping_add(b[i * n + j].wrapping_mul(x[j]).wrapping_mul(beta));
        }
    }
}

/// syrk_reference: C[i][j] += A[i][k]*A[j][k]*alpha.
pub fn syrk_reference(c: &mut [i32], a: &[i32], alpha: i32, n: usize) {
    for i in 0..n {
        for j in 0..n {
            for k in 0..n {
                let t = a[i * n + k].wrapping_mul(a[j * n + k]).wrapping_mul(alpha);
                c[i * n + j] = c[i * n + j].wrapping_add(t);
            }
        }
    }
}

/// syr2k_reference: C[i][j] += (A[i][k]*B[j][k] + B[i][k]*A[j][k])*alpha.
pub fn syr2k_reference(c: &mut [i32], a: &[i32], b: &[i32], alpha: i32, n: usize) {
    for i in 0..n {
        for j in 0..n {
            for k in 0..n {
                let s = a[i * n + k]
                    .wrapping_mul(b[j * n + k])
                    .wrapping_add(b[i * n + k].wrapping_mul(a[j * n + k]));
                c[i * n + j] = c[i * n + j].wrapping_add(s.wrapping_mul(alpha));
            }
        }
    }
}

/// symm_reference: C[i][j] += A[i][k]*B[k][j]*alpha (the simplified form
/// authored above).
pub fn symm_reference(c: &mut [i32], a: &[i32], b: &[i32], alpha: i32, n: usize) {
    gemm_reference(c, a, b, alpha, n);
}

/// trmm_reference: Bout[i][j] += A[i][k]*B[k][j].
pub fn trmm_reference(bout: &mut [i32], a: &[i32], b: &[i32], n: usize) {
    for i in 0..n {
        for j in 0..n {
            for k in 0..n {
                let t = a[i * n + k].wrapping_mul(b[k * n + j]);
                bout[i * n + j] = bout[i * n + j].wrapping_add(t);
            }
        }
    }
}

/// heat3d_reference: the two ping-pong passes (A→B then B→A) of the
/// fixed-point second-difference stencil.
pub fn heat3d_reference(a: &mut [i32], b: &mut [i32], n: usize) {
    let nn = n * n;
    let pass = |src: &[i32], dst: &mut [i32]| {
        for i in 1..n - 1 {
            for j in 1..n - 1 {
                for k in 1..n - 1 {
                    let at = |di: isize, dj: isize, dk: isize| {
                        let ii = (i as isize + di) as usize;
                        let jj = (j as isize + dj) as usize;
                        let kk = (k as isize + dk) as usize;
                        src[ii * nn + jj * n + kk]
                    };
                    let c0 = at(0, 0, 0);
                    let mut r = c0;
                    for (m, p) in [
                        (at(-1, 0, 0), at(1, 0, 0)),
                        (at(0, -1, 0), at(0, 1, 0)),
                        (at(0, 0, -1), at(0, 0, 1)),
                    ] {
                        let d = m.wrapping_add(p).wrapping_sub(c0.wrapping_mul(2));
                        r = r.wrapping_add(d >> 3);
                    }
                    dst[i * nn + j * n + k] = r;
                }
            }
        }
    };
    let snap = a.to_vec();
    pass(&snap, b);
    let snap = b.to_vec();
    pass(&snap, a);
}

/// division_kernel_reference: A[i][j] /= A[i][i], in loop order (the
/// pivot changes mid-row when j passes i).
pub fn division_kernel_reference(a: &mut [i32], n: usize) {
    for i in 0..n {
        for j in 0..n {
            let piv = a[i * n + i];
            a[i * n + j] = a[i * n + j].wrapping_div(piv);
        }
    }
}

/// nussinov_reference: T[i] = max(T[S[j]], T[i]) in loop order.
pub fn nussinov_reference(t: &mut [i32], s: &[i32], n: usize) {
    for i in 0..n {
        for j in 0..n {
            let v = t[s[j] as usize];
            t[i] = v.max(t[i]);
        }
    }
}

/// floyd_warshall_reference: the down-counting diagonal doubling.
pub fn floyd_warshall_reference(p: &mut [i32], n: usize) {
    for k in (0..n).rev() {
        let v = p[k * n + k];
        p[k * n + k] = v.wrapping_add(v);
    }
}

/// The full suite with the paper's Table-I rows.
pub fn suite() -> Vec<Kernel> {
    vec![
        Kernel { name: "2mm", func: two_mm(), paper: p("Yes", "6/2/61", 14209), unroll: 8 },
        Kernel { name: "3mm", func: three_mm(), paper: p("Yes", "9/3/85", 28921), unroll: 8 },
        Kernel { name: "adi", func: adi(), paper: p("No, divisions", "", 35249), unroll: 1 },
        Kernel { name: "atax", func: atax(), paper: p("Yes", "6/2/49", 8338), unroll: 8 },
        Kernel { name: "bicg", func: bicg(), paper: p("Yes", "6/2/49", 7658), unroll: 8 },
        Kernel {
            name: "deriche",
            func: deriche(),
            paper: p("No, MUX SCoP invalidated", "", 0),
            unroll: 1,
        },
        Kernel {
            name: "durbin",
            func: durbin(),
            paper: p("No, MUX SCoP invalidated", "", 0),
            unroll: 1,
        },
        Kernel {
            name: "fdtd-2d",
            func: fdtd_2d(),
            paper: p("No, fp data", "", 33052),
            unroll: 1,
        },
        Kernel { name: "gemm", func: gemm(), paper: p("Yes", "4/2/34", 7154), unroll: 8 },
        Kernel { name: "gemver", func: gemver(), paper: p("Yes", "13/4/95", 36500), unroll: 8 },
        Kernel {
            name: "gesummv",
            func: gesummv(),
            paper: p("Yes", "8/3/70", 11723),
            unroll: 8,
        },
        Kernel {
            name: "heat-3d",
            func: heat3d(),
            paper: p("Yes", "20/2/276", 107645),
            unroll: 4,
        },
        Kernel {
            name: "jacobi-1D",
            func: jacobi_1d(),
            paper: p("No, fp data", "", 7237),
            unroll: 1,
        },
        Kernel {
            name: "jacobi-2D",
            func: jacobi_2d(),
            paper: p("No, fp data", "", 17757),
            unroll: 1,
        },
        Kernel { name: "lu", func: lu(), paper: p("No, divisions", "", 18035), unroll: 1 },
        Kernel {
            name: "ludcmp",
            func: ludcmp(),
            paper: p("No, divisions", "", 37159),
            unroll: 1,
        },
        Kernel { name: "mvt", func: mvt(), paper: p("Yes", "6/2/40", 7028), unroll: 8 },
        Kernel {
            name: "floyd-warshall",
            func: floyd_warshall(),
            paper: p("No SCoP", "", 0),
            unroll: 1,
        },
        Kernel {
            name: "nussinov",
            func: nussinov(),
            paper: p("No SCoP", "", 0),
            unroll: 1,
        },
        Kernel {
            name: "seidel",
            func: seidel(),
            paper: p("No, divisions", "", 12296),
            unroll: 1,
        },
        Kernel { name: "symm", func: symm(), paper: p("Yes", "6/2/64", 14659), unroll: 8 },
        Kernel { name: "syr2k", func: syr2k(), paper: p("Yes", "6/2/52", 9112), unroll: 4 },
        Kernel { name: "syrk", func: syrk(), paper: p("Yes", "4/2/34", 5525), unroll: 8 },
        Kernel {
            name: "trisolv",
            func: trisolv(),
            paper: p("No, divisions", "", 6646),
            unroll: 1,
        },
        Kernel { name: "trmm", func: trmm(), paper: p("Yes", "4/2/30", 6540), unroll: 8 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scop::analyze_function;
    use crate::dfg::extract::extract;
    use crate::ir::verify::verify_function;

    #[test]
    fn all_kernels_verify() {
        for k in suite() {
            verify_function(&k.func, None).unwrap_or_else(|e| panic!("{}: {e}", k.name));
        }
    }

    #[test]
    fn classification_matches_paper() {
        for k in suite() {
            let an = analyze_function(&k.func);
            let expect_offload = k.paper.offload == "Yes";
            let mut got_offload = false;
            let mut labels = Vec::new();
            for scop in &an.scops {
                match extract(&k.func, scop, 1) {
                    Ok(_) => got_offload = true,
                    Err(e) => labels.push(e.label()),
                }
            }
            for r in &an.rejects {
                labels.push(r.label());
            }
            assert_eq!(
                got_offload, expect_offload,
                "{}: expected '{}', got offload={} labels={:?}",
                k.name, k.paper.offload, got_offload, labels
            );
            // Category spot checks.
            if k.paper.offload.contains("divisions") {
                assert!(labels.contains(&"No, divisions"), "{}: {labels:?}", k.name);
            }
            if k.paper.offload.contains("fp data") {
                assert!(labels.contains(&"No, fp data"), "{}: {labels:?}", k.name);
            }
            if k.paper.offload.contains("MUX") {
                assert!(labels.contains(&"MUX handling"), "{}: {labels:?}", k.name);
            }
            if k.paper.offload == "No SCoP" {
                // Either the CFG/bounds defeat detection outright or
                // every candidate dies on non-affine subscripts — both
                // are reported as "no SCoP", like the paper.
                assert!(
                    labels.iter().any(|l| *l == "no SCoP"),
                    "{}: {labels:?}",
                    k.name
                );
            }
        }
    }

    #[test]
    fn offloadable_kernels_extract_with_their_unroll() {
        for k in suite().into_iter().filter(|k| k.paper.offload == "Yes") {
            let an = analyze_function(&k.func);
            let mut ok = false;
            for scop in &an.scops {
                if let Ok(off) = extract(&k.func, scop, k.unroll) {
                    assert!(off.dfg.stats().calc > 0);
                    ok = true;
                }
            }
            assert!(ok, "{}: no extractable scop at unroll {}", k.name, k.unroll);
        }
    }

    #[test]
    fn heat3d_merged_dfg_is_large() {
        // The paper merges the extracted DFGs ("extract and merge the CFG
        // and DFG"): heat-3d's two ping-pong nests sum to the largest
        // Table-I entry (paper: 20/2/276; ours lands in the same class —
        // too big for small overlays).
        let k = heat3d();
        let an = analyze_function(&k);
        assert_eq!(an.scops.len(), 2);
        let mut calc = 0;
        for s in &an.scops {
            calc += extract(&k, s, 4).unwrap().dfg.stats().calc;
        }
        assert!(calc >= 100, "heat-3d merged should be large, got {calc}");
    }
}

//! The §IV-C video-processing case study: a convolution pipeline over a
//! synthetic frame stream (the paper uses OpenCV file decode; frame decode
//! here is a modeled host-work phase — DESIGN.md §Substitutions).
//!
//! The offloaded convolution is authored to extract exactly the paper's
//! DFG: **17 inputs / 1 output / 16 calc nodes** — 9 pixel taps + 8
//! coefficient streams (the center coefficient is the constant 1, one of
//! the paper's constant-masked inputs), 8 multiplies + 8 adds.

use crate::ir::func::{FuncBuilder, Function, Module};
use crate::ir::instr::Ty;
use crate::jit::interp::{Memory, Val};

/// Frame geometry: 160x120 keeps the modeled transfer volume in the range
/// where the paper's 31-vs-83 fps relationship emerges (§IV-C).
pub const FRAME_W: usize = 160;
pub const FRAME_H: usize = 120;

/// Modeled per-frame host work outside the framework (OpenCV decode +
/// colorspace in the paper; visible as the gaps in Fig 6).
pub const DECODE_MS: f64 = 10.3;

/// The pipeline's 8 neighbour coefficients (the center tap is the
/// constant 1, one of the paper's constant-masked inputs). Single source
/// of truth for `alloc_pipeline`, the reference conv and every harness.
pub const COEF: [i32; 8] = [1, -2, 1, 2, -2, 1, 2, -1];

/// conv: for y in 1..h-1, x in 1..w-1:
///   out[y][x] = in[y][x] + sum_{8 neighbours} coef[t] * in[y+dy][x+dx]
pub fn conv_func() -> Function {
    let mut b = FuncBuilder::new(
        "conv",
        &[
            ("out", Ty::Ptr),
            ("in", Ty::Ptr),
            ("coef", Ty::Ptr),
            ("w", Ty::I32),
            ("h", Ty::I32),
        ],
    );
    let (out, inp, coef, w, h) = (b.param(0), b.param(1), b.param(2), b.param(3), b.param(4));
    let one = b.const_i32(1);
    let hm1 = b.sub(h, one);
    let lo = b.const_i32(1);
    b.counted_loop(lo, hm1, |b, y| {
        let o = b.const_i32(1);
        let wm1 = b.sub(w, o);
        let lo2 = b.const_i32(1);
        b.counted_loop(lo2, wm1, |b, x| {
            let mut tap = |b: &mut FuncBuilder, dy: i32, dx: i32| {
                let cdy = b.const_i32(dy);
                let yy = b.add(y, cdy);
                let cdx = b.const_i32(dx);
                let xx = b.add(x, cdx);
                let row = b.mul(yy, w);
                let idx = b.add(row, xx);
                b.load(Ty::I32, inp, idx)
            };
            // Center tap: coefficient 1 (constant-masked).
            let center = tap(b, 0, 0);
            let offsets: [(i32, i32); 8] = [
                (-1, -1), (-1, 0), (-1, 1), (0, -1), (0, 1), (1, -1), (1, 0), (1, 1),
            ];
            let mut acc = center;
            for (t, (dy, dx)) in offsets.into_iter().enumerate() {
                let pv = tap(b, dy, dx);
                let ct = b.const_i32(t as i32);
                let cv = b.load(Ty::I32, coef, ct);
                let prod = b.mul(pv, cv);
                acc = b.add(acc, prod);
            }
            let row = b.mul(y, w);
            let idx = b.add(row, x);
            b.store(Ty::I32, out, idx, acc);
        });
    });
    b.ret(None)
}

pub fn video_module() -> Module {
    let mut m = Module::new();
    m.add(conv_func());
    m
}

/// Synthetic frame source (deterministic "video").
pub struct FrameSource {
    pub frame_no: u32,
}

impl FrameSource {
    pub fn new() -> FrameSource {
        FrameSource { frame_no: 0 }
    }

    /// Fill `buf` (w*h) with the next frame.
    pub fn next_frame(&mut self, buf: &mut [i32]) {
        let f = self.frame_no as i32;
        for (i, px) in buf.iter_mut().enumerate() {
            let (x, y) = ((i % FRAME_W) as i32, (i / FRAME_W) as i32);
            *px = ((x * 3 + y * 7 + f * 11) % 256 + 256) % 256;
        }
        self.frame_no += 1;
    }
}

impl Default for FrameSource {
    fn default() -> Self {
        Self::new()
    }
}

/// Host reference convolution (ground truth for the pipeline tests).
pub fn conv_reference(inp: &[i32], coef: &[i32], w: usize, h: usize) -> Vec<i32> {
    let mut out = vec![0i32; w * h];
    for y in 1..h - 1 {
        for x in 1..w - 1 {
            let mut acc = inp[y * w + x];
            let offsets: [(i32, i32); 8] = [
                (-1, -1), (-1, 0), (-1, 1), (0, -1), (0, 1), (1, -1), (1, 0), (1, 1),
            ];
            for (t, (dy, dx)) in offsets.into_iter().enumerate() {
                let yy = (y as i32 + dy) as usize;
                let xx = (x as i32 + dx) as usize;
                acc = acc.wrapping_add(inp[yy * w + xx].wrapping_mul(coef[t]));
            }
            out[y * w + x] = acc;
        }
    }
    out
}

/// Allocate pipeline memory; returns (out, in, coef) handles.
pub fn alloc_pipeline(mem: &mut Memory) -> (u32, u32, u32) {
    let out = mem.alloc_i32(FRAME_W * FRAME_H);
    let inp = mem.alloc_i32(FRAME_W * FRAME_H);
    let coef = mem.from_i32(&COEF);
    (out, inp, coef)
}

pub fn conv_args(out: u32, inp: u32, coef: u32) -> Vec<Val> {
    vec![
        Val::P(out),
        Val::P(inp),
        Val::P(coef),
        Val::I(FRAME_W as i32),
        Val::I(FRAME_H as i32),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scop::analyze_function;
    use crate::dfg::extract::extract;
    use crate::jit::engine::Engine;

    #[test]
    fn conv_dfg_matches_paper_17_1_16() {
        let f = conv_func();
        let an = analyze_function(&f);
        assert!(an.detected(), "{:?}", an.rejects);
        let off = extract(&f, &an.scops[0], 1).unwrap();
        let st = off.dfg.stats();
        assert_eq!(
            (st.inputs, st.outputs, st.calc),
            (17, 1, 16),
            "paper: 17 in / 1 out / 16 calc, got {st}"
        );
    }

    #[test]
    fn interpreter_matches_reference() {
        let mut engine = Engine::new(video_module()).unwrap();
        let mut mem = Memory::new();
        let (out, inp, coef) = alloc_pipeline(&mut mem);
        let mut src = FrameSource::new();
        let mut frame = vec![0i32; FRAME_W * FRAME_H];
        src.next_frame(&mut frame);
        mem.i32s_mut(inp).copy_from_slice(&frame);
        engine.call("conv", &mut mem, &conv_args(out, inp, coef)).unwrap();
        let want = conv_reference(&frame, &COEF, FRAME_W, FRAME_H);
        assert_eq!(mem.i32s(out), &want[..]);
    }

    #[test]
    fn offloaded_conv_matches_reference() {
        use crate::offload::{OffloadManager, OffloadParams};
        let mut engine = Engine::new(video_module()).unwrap();
        let mut mem = Memory::new();
        let (out, inp, coef) = alloc_pipeline(&mut mem);
        let mut src = FrameSource::new();
        let mut frame = vec![0i32; FRAME_W * FRAME_H];
        src.next_frame(&mut frame);
        mem.i32s_mut(inp).copy_from_slice(&frame);
        // Warm profile, then offload (sim backend), then re-run.
        engine.call("conv", &mut mem, &conv_args(out, inp, coef)).unwrap();
        let mut mgr =
            OffloadManager::new(OffloadParams { min_dfg_nodes: 1, ..Default::default() });
        let func = engine.func_index("conv").unwrap();
        mgr.try_offload(&mut engine, func, None).expect("offload conv");
        mem.i32s_mut(out).fill(0);
        engine.call("conv", &mut mem, &conv_args(out, inp, coef)).unwrap();
        let want = conv_reference(&frame, &COEF, FRAME_W, FRAME_H);
        assert_eq!(mem.i32s(out), &want[..]);
    }
}

//! Tiny argv parser (no clap in the offline image).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positionals, with
//! typed accessors and a generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    /// Option keys that take a value (everything else parses as a flag).
    value_keys: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. `value_keys` lists options that consume a value.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, value_keys: &[&str]) -> Args {
        let mut args = Args {
            value_keys: value_keys.iter().map(|s| s.to_string()).collect(),
            ..Default::default()
        };
        let mut it = argv.into_iter();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if args.value_keys.iter().any(|k| k == rest) {
                    match it.next() {
                        Some(v) => {
                            args.options.insert(rest.to_string(), v);
                        }
                        None => {
                            args.flags.push(rest.to_string());
                        }
                    }
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env(value_keys: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), value_keys)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn mixed_forms() {
        let a = Args::parse(
            argv(&["run", "--seed", "42", "--grid=8x8", "--verbose", "extra"]),
            &["seed", "grid"],
        );
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert_eq!(a.get_u64("seed", 0), 42);
        assert_eq!(a.get("grid"), Some("8x8"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(argv(&[]), &[]);
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_or("mode", "auto"), "auto");
    }

    #[test]
    fn equals_form_works_without_value_key() {
        let a = Args::parse(argv(&["--k=v"]), &[]);
        assert_eq!(a.get("k"), Some("v"));
    }
}

//! Minimal JSON parser — just enough to read `artifacts/manifest.json` and
//! write simple reports. No serde in the offline image.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8 starting at pos-1.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.b.len());
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
                None => return Err(self.err("eof in string")),
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

/// Escape a string for JSON output (used by report writers).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let text = r#"{
          "abi": {"n_consts": 16, "batch": 512},
          "variants": [
            {"name": "dfe_4x4", "rows": 4, "cols": 4, "file": "dfe_4x4.hlo.txt"},
            {"name": "dfe_8x8", "rows": 8, "cols": 8, "file": "dfe_8x8.hlo.txt"}
          ]
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("abi").unwrap().get("batch").unwrap().as_usize(), Some(512));
        let variants = v.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(variants.len(), 2);
        assert_eq!(variants[1].get("name").unwrap().as_str(), Some("dfe_8x8"));
    }

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parse_unicode_passthrough() {
        assert_eq!(Json::parse(r#""héllo µs""#).unwrap(), Json::Str("héllo µs".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn escape_roundtrip() {
        let s = "a\"b\\c\nd";
        let quoted = format!("\"{}\"", escape(s));
        assert_eq!(Json::parse(&quoted).unwrap(), Json::Str(s.into()));
    }
}

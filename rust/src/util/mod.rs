//! Small self-contained utilities.
//!
//! The build image is offline, so JSON parsing, PRNG, CLI parsing,
//! error plumbing and micro-benchmarking are implemented here instead of
//! pulling serde/rand/clap/anyhow/criterion.

pub mod bench;
pub mod cli;
pub mod err;
pub mod json;
pub mod prng;

/// Format a `std::time::Duration` compactly (µs/ms/s with 3 significant
/// digits), used by trace rendering and the bench harness.
pub fn fmt_duration(d: std::time::Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

/// Mean and population standard deviation of a sample.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// Median of a sample (sorts a copy).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_duration_ranges() {
        use std::time::Duration;
        assert_eq!(fmt_duration(Duration::from_nanos(10)), "10ns");
        assert_eq!(fmt_duration(Duration::from_micros(15)), "15.00us");
        assert_eq!(fmt_duration(Duration::from_millis(2)), "2.00ms");
        assert_eq!(fmt_duration(Duration::from_secs(3)), "3.00s");
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0, 4.0]);
        assert!((m - 2.5).abs() < 1e-12);
        assert!((s - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median(&[]), 0.0);
    }
}

//! Deterministic PRNG for the Las-Vegas place & route and the test suite.
//!
//! xoshiro256++ (public-domain reference algorithm) seeded via SplitMix64.
//! Not cryptographic; chosen for speed, quality and reproducibility — the
//! paper's P&R is a stochastic (Las Vegas) algorithm, and deterministic
//! seeding makes every experiment in EXPERIMENTS.md replayable.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`; `n` must be non-zero. Uses Lemire's method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Uniform i32 over the full range (for datapath fuzzing).
    #[inline]
    pub fn any_i32(&mut self) -> i32 {
        self.next_u64() as i32
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (used for the Gaussian position
    /// weighting of the placer, paper §III-B).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-18);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample an index proportionally to `weights` (all non-negative; at
    /// least one positive).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "weighted() needs positive mass");
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let (m, s) = crate::util::mean_std(&xs);
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((s - 1.0).abs() < 0.03, "std {s}");
    }

    #[test]
    fn weighted_respects_mass() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 0.0, 3.0])] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.5..3.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }
}

//! Micro-benchmark harness for `cargo bench` targets (no criterion in the
//! offline image; every bench sets `harness = false` and drives this).
//!
//! Measures wall-clock over warmup + timed iterations, reports
//! median/mean/std/min, and prints rows in a fixed table layout so every
//! paper table/figure regenerator has a uniform look. `--quick` (or env
//! `TLO_BENCH_QUICK=1`) shrinks iteration counts for CI.

use std::time::{Duration, Instant};

use super::{fmt_duration, mean_std, median};

#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl BenchConfig {
    pub fn from_env() -> Self {
        let quick = std::env::var("TLO_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
            || std::env::args().any(|a| a == "--quick");
        if quick {
            BenchConfig { warmup_iters: 1, iters: 3 }
        } else {
            BenchConfig { warmup_iters: 3, iters: 10 }
        }
    }
}

#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub std: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn per_iter_ns(&self) -> f64 {
        self.median.as_nanos() as f64
    }
}

/// Time `f` under `cfg`, returning summary stats.
pub fn bench<F: FnMut()>(name: &str, cfg: BenchConfig, mut f: F) -> Stats {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let (mean, std) = mean_std(&samples);
    let med = median(&samples);
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    Stats {
        name: name.to_string(),
        iters: cfg.iters,
        median: Duration::from_secs_f64(med),
        mean: Duration::from_secs_f64(mean),
        std: Duration::from_secs_f64(std),
        min: Duration::from_secs_f64(min),
    }
}

/// Print one stats row (aligned with `print_header`).
pub fn print_stats(s: &Stats) {
    println!(
        "{:<44} {:>12} {:>12} {:>12} {:>6}",
        s.name,
        fmt_duration(s.median),
        fmt_duration(s.mean),
        fmt_duration(s.std),
        s.iters
    );
}

pub fn print_header(title: &str) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>12} {:>12} {:>12} {:>6}",
        "benchmark", "median", "mean", "std", "iters"
    );
    println!("{}", "-".repeat(90));
}

/// Convenience: bench and print in one call.
pub fn run<F: FnMut()>(name: &str, cfg: BenchConfig, f: F) -> Stats {
    let s = bench(name, cfg, f);
    print_stats(&s);
    s
}

/// Prevent the optimizer from deleting a computed value (ptr read fence —
/// std::hint::black_box is stable but this keeps MSRV headroom).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let cfg = BenchConfig { warmup_iters: 1, iters: 5 };
        let mut acc = 0u64;
        let s = bench("spin", cfg, || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.median && s.median <= s.mean + s.std + s.std);
        assert!(s.median.as_nanos() > 0);
    }
}

//! Minimal error type with context chaining — a vendored stand-in for the
//! `anyhow` crate, which the offline build image does not ship (the image
//! has no crates.io registry; see Cargo.toml). API-compatible with the
//! subset this crate uses: `Result`, `Context::{context,with_context}`,
//! and the `anyhow!` / `bail!` macros (re-exported below).

use std::fmt;

/// An error as a chain of messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

/// `Result` defaulting to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from a printable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Prepend a context message (the new outermost frame).
    pub fn wrap(mut self, m: impl fmt::Display) -> Error {
        self.chain.insert(0, m.to_string());
        self
    }

    /// The message chain, outermost context first.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    /// `{}` prints the outermost message; `{:#}` prints the full chain.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

/// Any std error converts, capturing its source chain. (Like `anyhow`,
/// [`Error`] itself deliberately does not implement `std::error::Error`,
/// which keeps this blanket impl coherent.)
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Format an [`Error`] in place, `anyhow!`-style.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::util::err::Error::msg(format!($($arg)*)) };
}

/// Early-return with a formatted [`Error`], `anyhow::bail!`-style.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::util::err::Error::msg(format!($($arg)*))) };
}

// `#[macro_export]` places `anyhow!`/`bail!` at the crate root: import
// them with `use crate::{anyhow, bail};` (or invoke as `tlo::anyhow!`).

#[cfg(test)]
mod tests {
    use super::*;

    fn fail_io() -> Result<String> {
        std::fs::read_to_string("/nonexistent_tlo_err_test")
            .with_context(|| "reading config (run `make artifacts`)".to_string())
    }

    #[test]
    fn context_chain_renders() {
        let e = fail_io().unwrap_err();
        assert!(e.to_string().contains("make artifacts"));
        assert!(format!("{e:#}").contains("make artifacts"));
        assert!(e.chain().len() >= 2, "{:?}", e.chain());
    }

    #[test]
    fn macros_construct_and_bail() {
        fn inner(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative input {x}");
            }
            Ok(x * 2)
        }
        assert_eq!(inner(4).unwrap(), 8);
        let e = inner(-1).unwrap_err();
        assert_eq!(e.to_string(), "negative input -1");
        let e2 = anyhow!("code {}", 7);
        assert_eq!(e2.to_string(), "code 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn display_outermost_only_plain() {
        let e = Error::msg("root").wrap("outer");
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: root");
    }
}

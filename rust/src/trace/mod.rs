//! Phase tracing (the paper instruments its prototype with LTTng events;
//! Fig 6 is the rendered timeline). Events carry a phase tag and a span;
//! `render_timeline` prints the Fig-6-style summary the `video_pipeline`
//! example and the `fig6_phases` bench emit.

use std::time::{Duration, Instant};

/// The processing phases of Fig 6.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Phase {
    Analysis,     // 1 — hotspot assessment + DFG/CFG extraction
    Jit,          // 2 — stub compilation
    PlaceRoute,   // 3
    Configure,    // 4 — DFE configuration download
    Constants,    // 5 — constant transfer
    HostToDfe,    // 6 — input data transfer (PC->FPGA)
    DfeToHost,    // 7 — output data transfer (FPGA->PC)
    DfeExec,      //     fabric execution (negligible in the paper)
    HostWork,     //     application work outside the framework
    Queue,        //     serve layer: requests waiting for the link/shard
}

pub const ALL_PHASES: [Phase; 10] = [
    Phase::Analysis,
    Phase::Jit,
    Phase::PlaceRoute,
    Phase::Configure,
    Phase::Constants,
    Phase::HostToDfe,
    Phase::DfeToHost,
    Phase::DfeExec,
    Phase::HostWork,
    Phase::Queue,
];

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Analysis => "analysis",
            Phase::Jit => "jit",
            Phase::PlaceRoute => "place&route",
            Phase::Configure => "configuration",
            Phase::Constants => "constants",
            Phase::HostToDfe => "PC->FPGA",
            Phase::DfeToHost => "FPGA->PC",
            Phase::DfeExec => "dfe-exec",
            Phase::HostWork => "host-work",
            Phase::Queue => "queue-wait",
        }
    }

    /// The paper's Fig-6 label number, where applicable.
    pub fn fig6_tag(self) -> Option<u8> {
        match self {
            Phase::Analysis => Some(1),
            Phase::Jit => Some(2),
            Phase::PlaceRoute => Some(3),
            Phase::Configure => Some(4),
            Phase::Constants => Some(5),
            Phase::HostToDfe => Some(6),
            Phase::DfeToHost => Some(7),
            _ => None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Span {
    pub phase: Phase,
    pub start: Duration,
    pub len: Duration,
}

/// Event recorder. `simulated` spans (from the timing models) and
/// wall-clock spans share the same stream; `start` offsets are relative to
/// recorder creation.
pub struct Tracer {
    t0: Instant,
    /// Virtual clock for simulated spans (advances past wall time).
    vnow: Duration,
    pub spans: Vec<Span>,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tracer {
    pub fn new() -> Tracer {
        Tracer { t0: Instant::now(), vnow: Duration::ZERO, spans: Vec::new() }
    }

    /// Record a wall-clock span around `f`.
    pub fn span<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let s = self.t0.elapsed().max(self.vnow);
        let r = f();
        let e = self.t0.elapsed().max(s);
        self.spans.push(Span { phase, start: s, len: e - s });
        self.vnow = e.max(self.vnow);
        r
    }

    /// Record a simulated span of length `len` (advances the virtual
    /// clock; used for modeled transfer/configuration times).
    pub fn simulated(&mut self, phase: Phase, len: Duration) {
        let s = self.vnow.max(self.t0.elapsed());
        self.spans.push(Span { phase, start: s, len });
        self.vnow = s + len;
    }

    /// Total time attributed to a phase.
    pub fn total(&self, phase: Phase) -> Duration {
        self.spans.iter().filter(|s| s.phase == phase).map(|s| s.len).sum()
    }

    pub fn count(&self, phase: Phase) -> usize {
        self.spans.iter().filter(|s| s.phase == phase).count()
    }

    /// End-to-end makespan (latest span end).
    pub fn makespan(&self) -> Duration {
        self.spans.iter().map(|s| s.start + s.len).max().unwrap_or(Duration::ZERO)
    }

    /// Fig-6-style phase table.
    pub fn render_timeline(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<4} {:<14} {:>10} {:>12} {:>12}\n",
            "tag", "phase", "spans", "total", "mean"
        ));
        out.push_str(&"-".repeat(56));
        out.push('\n');
        for phase in ALL_PHASES {
            let n = self.count(phase);
            if n == 0 {
                continue;
            }
            let total = self.total(phase);
            let tag = phase.fig6_tag().map(|t| t.to_string()).unwrap_or_default();
            out.push_str(&format!(
                "{:<4} {:<14} {:>10} {:>12} {:>12}\n",
                tag,
                phase.name(),
                n,
                crate::util::fmt_duration(total),
                crate::util::fmt_duration(total / n as u32),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_and_simulated_spans_compose() {
        let mut t = Tracer::new();
        t.span(Phase::Analysis, || std::thread::sleep(Duration::from_millis(2)));
        t.simulated(Phase::HostToDfe, Duration::from_micros(35));
        t.simulated(Phase::DfeToHost, Duration::from_micros(16));
        assert_eq!(t.count(Phase::HostToDfe), 1);
        assert!(t.total(Phase::Analysis) >= Duration::from_millis(2));
        // Simulated spans are serialized after the analysis span.
        assert!(t.makespan() >= t.total(Phase::Analysis) + Duration::from_micros(51));
    }

    #[test]
    fn timeline_renders_tags() {
        let mut t = Tracer::new();
        t.simulated(Phase::PlaceRoute, Duration::from_millis(1180));
        t.simulated(Phase::Configure, Duration::from_micros(2100));
        let s = t.render_timeline();
        assert!(s.contains("place&route"));
        assert!(s.contains("3"));
        assert!(s.contains("1.18s"));
    }

    #[test]
    fn totals_sum_over_spans() {
        let mut t = Tracer::new();
        for _ in 0..3 {
            t.simulated(Phase::HostToDfe, Duration::from_micros(10));
        }
        assert_eq!(t.total(Phase::HostToDfe), Duration::from_micros(30));
        assert_eq!(t.count(Phase::HostToDfe), 3);
    }
}

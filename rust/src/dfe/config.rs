//! Physical DFE configuration: what "programming the overlay" means
//! (paper §III-A — "selecting all used inputs, outputs, and operators, and
//! routing all intermediate results").
//!
//! The configuration is faithful to Fig 3: per cell, the FU's two operand
//! muxes and selection mux each pick a cell input (or a masked constant —
//! the paper's transfer-saving extension), and each of the four cell
//! outputs picks a cell input (pass-through routing) or the FU result.
//!
//! `to_image()` linearizes a legal configuration into an [`ExecImage`] —
//! the operand form the AOT Pallas artifact executes. Placement/routing
//! geometry only affects the timing and resource models.

use std::collections::HashMap;
use std::fmt;

use super::grid::{CellCoord, Dir, Grid, DIRS};
use super::image::{ExecImage, ImageBuilder};
use super::opcodes::Op;

/// Source of a functional-unit operand.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FuSrc {
    /// Operand unused (NOP/PASS rhs, non-MUX sel).
    None,
    /// Driven by a cell input face.
    In(Dir),
    /// Masked to a constant (paper: "transformation of inputs into
    /// constants ... requires only masking one signal").
    Const(i32),
}

/// Driver of a cell output face.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum OutSrc {
    #[default]
    None,
    /// Pass-through from a cell input face (routing resource).
    In(Dir),
    /// The FU result.
    Fu,
}

/// One cell's configuration word.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CellConfig {
    pub op: Option<Op>,
    pub fu1: FuSrc,
    pub fu2: FuSrc,
    pub fsel: FuSrc,
    pub out: [OutSrc; 4],
}

impl Default for CellConfig {
    fn default() -> Self {
        CellConfig {
            op: None,
            fu1: FuSrc::None,
            fu2: FuSrc::None,
            fsel: FuSrc::None,
            out: [OutSrc::None; 4],
        }
    }
}

impl CellConfig {
    pub fn is_empty(&self) -> bool {
        *self == CellConfig::default()
    }

    /// Output faces currently unused (available to the router).
    pub fn free_outs(&self) -> impl Iterator<Item = Dir> + '_ {
        DIRS.into_iter().filter(|d| self.out[d.index()] == OutSrc::None)
    }
}

/// External I/O binding: stream `index` attached to border face `(cell, dir)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct IoAssign {
    pub cell: CellCoord,
    pub dir: Dir,
    pub index: usize,
}

/// A complete overlay configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct GridConfig {
    pub grid: Grid,
    pub cells: Vec<CellConfig>,
    /// External inputs: stream j injected at a border *input* face.
    pub inputs: Vec<IoAssign>,
    /// External outputs: stream j tapped from a border *output* face.
    pub outputs: Vec<IoAssign>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ConfigError {
    NotBorder(CellCoord, Dir),
    IoFaceReused(CellCoord, Dir),
    UndrivenInput { cell: CellCoord, dir: Dir },
    UndrivenOutput { cell: CellCoord, dir: Dir },
    NoFu(CellCoord),
    FuUnused(CellCoord),
    RoutingCycle(CellCoord, Dir),
    MissingOperand(CellCoord, &'static str),
    /// A bound external input stream is absent or shorter than the
    /// requested element count (`got` is the provided length; an entirely
    /// missing stream reports 0).
    StreamTooShort { index: usize, need: usize, got: usize },
    Image(super::image::ImageError),
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::NotBorder(p, d) => write!(f, "face {p}{d} is not on the border"),
            ConfigError::IoFaceReused(p, d) => write!(f, "I/O face {p}{d} bound twice"),
            ConfigError::UndrivenInput { cell, dir } => {
                write!(f, "cell {cell} input {dir} consumed but undriven")
            }
            ConfigError::UndrivenOutput { cell, dir } => {
                write!(f, "external output taps undriven face {cell}{dir}")
            }
            ConfigError::NoFu(p) => write!(f, "cell {p} routes FU result but has no op"),
            ConfigError::FuUnused(p) => write!(f, "cell {p} has an op but its result is unused"),
            ConfigError::RoutingCycle(p, d) => {
                write!(f, "pass-through routing cycle through {p} input {d}")
            }
            ConfigError::MissingOperand(p, which) => {
                write!(f, "cell {p} op is missing operand {which}")
            }
            ConfigError::StreamTooShort { index, need, got } => {
                write!(f, "input stream {index} has {got} elements, run needs {need}")
            }
            ConfigError::Image(e) => write!(f, "image build failed: {e}"),
        }
    }
}

impl std::error::Error for ConfigError {}

impl From<super::image::ImageError> for ConfigError {
    fn from(e: super::image::ImageError) -> Self {
        ConfigError::Image(e)
    }
}

/// What ultimately drives a traced value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Driver {
    ExternalInput(usize),
    FuOf(CellCoord),
    Const(i32),
}

/// Immediate driver of a cell input face: the neighbor's facing output
/// register, or an external input stream on a border face. Shared by both
/// execution engines (`dfe::sim`, `dfe::exec`) so their legality surfaces
/// cannot drift.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaceDriver {
    ExtIn(usize),
    Out(CellCoord, Dir),
}

/// Validate that every stream in `indices` exists and covers `n`
/// elements — the shared input-legality check of both execution engines
/// (an absent or short stream is a [`ConfigError::StreamTooShort`], never
/// a silent zero-fill). Callers must pass indices in ascending order so
/// both engines report the same index when several streams are short.
pub fn check_streams(
    indices: impl Iterator<Item = usize>,
    inputs: &[Vec<i32>],
    n: usize,
) -> Result<(), ConfigError> {
    for j in indices {
        let got = inputs.get(j).map(|s| s.len()).unwrap_or(0);
        if got < n {
            return Err(ConfigError::StreamTooShort { index: j, need: n, got });
        }
    }
    Ok(())
}

impl GridConfig {
    pub fn empty(grid: Grid) -> GridConfig {
        GridConfig {
            grid,
            cells: vec![CellConfig::default(); grid.n_cells()],
            inputs: Vec::new(),
            outputs: Vec::new(),
        }
    }

    pub fn cell(&self, p: CellCoord) -> &CellConfig {
        &self.cells[self.grid.index(p)]
    }

    pub fn cell_mut(&mut self, p: CellCoord) -> &mut CellConfig {
        &mut self.cells[self.grid.index(p)]
    }

    /// Immediate driver of cell input face `(p, d)`: the external input
    /// bound to a border face, or the neighbor's facing output register —
    /// erroring on undriven faces. The single source of truth for face
    /// resolution in `CycleSim::new` and `CompiledFabric::compile`.
    pub fn face_driver(&self, p: CellCoord, d: Dir) -> Result<FaceDriver, ConfigError> {
        match self.grid.neighbor(p, d) {
            None => {
                let io = self
                    .inputs
                    .iter()
                    .find(|io| io.cell == p && io.dir == d)
                    .ok_or(ConfigError::UndrivenInput { cell: p, dir: d })?;
                Ok(FaceDriver::ExtIn(io.index))
            }
            Some(q) => {
                let qd = d.opposite();
                if self.cell(q).out[qd.index()] == OutSrc::None {
                    Err(ConfigError::UndrivenInput { cell: p, dir: d })
                } else {
                    Ok(FaceDriver::Out(q, qd))
                }
            }
        }
    }

    /// Validate the provided input streams against this configuration's
    /// bound input indices, in ascending order (see [`check_streams`]).
    pub fn check_streams(&self, inputs: &[Vec<i32>], n: usize) -> Result<(), ConfigError> {
        let mut bound: Vec<usize> = self.inputs.iter().map(|io| io.index).collect();
        bound.sort_unstable();
        check_streams(bound.into_iter(), inputs, n)
    }

    /// Cells with a configured op (the "operator" role).
    pub fn op_cells(&self) -> impl Iterator<Item = CellCoord> + '_ {
        self.grid.iter_coords().filter(|&p| self.cell(p).op.is_some())
    }

    /// Count of cells used for anything (operator and/or routing).
    pub fn used_cells(&self) -> usize {
        self.cells.iter().filter(|c| !c.is_empty()).count()
    }

    /// Resolve the driver of cell input face `(p, d)`, walking pass-through
    /// chains. `visiting` detects routing cycles.
    fn trace_input(
        &self,
        p: CellCoord,
        d: Dir,
        visiting: &mut Vec<(CellCoord, Dir)>,
    ) -> Result<Driver, ConfigError> {
        if visiting.contains(&(p, d)) {
            return Err(ConfigError::RoutingCycle(p, d));
        }
        visiting.push((p, d));
        let res = (|| {
            match self.grid.neighbor(p, d) {
                None => {
                    // Border face: must carry an external input.
                    let io = self
                        .inputs
                        .iter()
                        .find(|io| io.cell == p && io.dir == d)
                        .ok_or(ConfigError::UndrivenInput { cell: p, dir: d })?;
                    Ok(Driver::ExternalInput(io.index))
                }
                Some(q) => {
                    // Driven by the neighbor's facing output.
                    let qd = d.opposite();
                    match self.cell(q).out[qd.index()] {
                        OutSrc::None => Err(ConfigError::UndrivenInput { cell: p, dir: d }),
                        OutSrc::Fu => {
                            if self.cell(q).op.is_none() {
                                return Err(ConfigError::NoFu(q));
                            }
                            Ok(Driver::FuOf(q))
                        }
                        OutSrc::In(d2) => self.trace_input(q, d2, visiting),
                    }
                }
            }
        })();
        visiting.pop();
        res
    }

    fn trace_fu_src(
        &self,
        p: CellCoord,
        src: FuSrc,
        which: &'static str,
        required: bool,
    ) -> Result<Option<Driver>, ConfigError> {
        match src {
            FuSrc::None => {
                if required {
                    Err(ConfigError::MissingOperand(p, which))
                } else {
                    Ok(None)
                }
            }
            FuSrc::Const(v) => Ok(Some(Driver::Const(v))),
            FuSrc::In(d) => Ok(Some(self.trace_input(p, d, &mut Vec::new())?)),
        }
    }

    /// Linearize into an [`ExecImage`]: trace every FU operand and every
    /// external output back to its driver, topologically order the FU
    /// cells, intern constants. Fails on illegal configurations
    /// (undriven consumers, routing cycles, unused FUs).
    pub fn to_image(&self) -> Result<ExecImage, ConfigError> {
        // 1. Gather FU cells and their operand drivers.
        struct FuInfo {
            op: Op,
            a: Driver,
            b: Option<Driver>,
            s: Option<Driver>,
        }
        let mut fus: HashMap<CellCoord, FuInfo> = HashMap::new();
        for p in self.op_cells() {
            let cc = self.cell(p);
            let op = cc.op.unwrap();
            let a = self
                .trace_fu_src(p, cc.fu1, "fu1", true)?
                .expect("required operand present");
            let b = self.trace_fu_src(p, cc.fu2, "fu2", op.uses_rhs())?;
            let s = self.trace_fu_src(p, cc.fsel, "sel", op.uses_sel())?;
            fus.insert(p, FuInfo { op, a, b, s });
        }

        // 2. External output drivers.
        let mut out_drivers: Vec<(usize, Driver)> = Vec::new();
        for io in &self.outputs {
            match self.cell(io.cell).out[io.dir.index()] {
                OutSrc::None => {
                    return Err(ConfigError::UndrivenOutput { cell: io.cell, dir: io.dir })
                }
                OutSrc::Fu => {
                    if self.cell(io.cell).op.is_none() {
                        return Err(ConfigError::NoFu(io.cell));
                    }
                    out_drivers.push((io.index, Driver::FuOf(io.cell)));
                }
                OutSrc::In(d) => {
                    out_drivers
                        .push((io.index, self.trace_input(io.cell, d, &mut Vec::new())?));
                }
            }
        }

        // 3. Topological order over FU cells (edges: FuOf dependencies).
        let coords: Vec<CellCoord> = fus.keys().copied().collect();
        let idx_of: HashMap<CellCoord, usize> =
            coords.iter().enumerate().map(|(i, &p)| (p, i)).collect();
        let mut indeg = vec![0usize; coords.len()];
        let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); coords.len()];
        for (&p, info) in &fus {
            let pi = idx_of[&p];
            for drv in [Some(info.a), info.b, info.s].into_iter().flatten() {
                if let Driver::FuOf(q) = drv {
                    let qi = idx_of[&q];
                    indeg[pi] += 1;
                    consumers[qi].push(pi);
                }
            }
        }
        let mut stack: Vec<usize> = (0..coords.len()).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(coords.len());
        while let Some(i) = stack.pop() {
            order.push(i);
            for &c in &consumers[i] {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    stack.push(c);
                }
            }
        }
        if order.len() != coords.len() {
            // An FU-level cycle can only arise via a routing cycle that
            // trace_input missed (it can't: FU deps are acyclic iff the
            // config is pipelinable); report on the first offender.
            let p = coords[(0..coords.len()).find(|i| indeg[*i] > 0).unwrap()];
            return Err(ConfigError::RoutingCycle(p, Dir::N));
        }

        // 4. Emit the image.
        let mut b = ImageBuilder::new();
        let mut slot_of_fu: HashMap<CellCoord, usize> = HashMap::new();
        let mut resolve = |b: &mut ImageBuilder,
                           slot_of_fu: &HashMap<CellCoord, usize>,
                           drv: Driver|
         -> usize {
            match drv {
                Driver::ExternalInput(j) => b.input(j),
                Driver::Const(v) => b.constant(v),
                Driver::FuOf(q) => slot_of_fu[&q],
            }
        };
        for &i in &order {
            let p = coords[i];
            let info = &fus[&p];
            let a = resolve(&mut b, &slot_of_fu, info.a);
            let rhs = info.b.map(|d| resolve(&mut b, &slot_of_fu, d)).unwrap_or(0);
            let sel = info.s.map(|d| resolve(&mut b, &slot_of_fu, d)).unwrap_or(0);
            let slot = b.cell_sel(info.op, a, rhs, sel);
            slot_of_fu.insert(p, slot);
        }
        let mut outs = out_drivers;
        outs.sort_by_key(|(j, _)| *j);
        for (_, drv) in outs {
            let slot = resolve(&mut b, &slot_of_fu, drv);
            b.output(slot);
        }
        Ok(b.build()?)
    }

    /// Structural validation beyond what `to_image` exercises: I/O faces
    /// on the border and unique, every configured FU result consumed.
    pub fn validate(&self) -> Result<(), ConfigError> {
        // A border face carries an inbound and an outbound wire — each can
        // be bound once (independently).
        for group in [&self.inputs, &self.outputs] {
            let mut seen = Vec::new();
            for io in group {
                if !self.grid.is_border_face(io.cell, io.dir) {
                    return Err(ConfigError::NotBorder(io.cell, io.dir));
                }
                if seen.contains(&(io.cell, io.dir)) {
                    return Err(ConfigError::IoFaceReused(io.cell, io.dir));
                }
                seen.push((io.cell, io.dir));
            }
        }
        // Every op cell's FU must drive something: an out face of the cell.
        for p in self.op_cells() {
            let used = DIRS.iter().any(|d| self.cell(p).out[d.index()] == OutSrc::Fu);
            if !used {
                return Err(ConfigError::FuUnused(p));
            }
        }
        self.to_image().map(|_| ())
    }

    /// Size of the configuration word stream (the paper's "download of the
    /// configuration", measured at 2.1 ms on the prototype): one word per
    /// mux setting plus constants. Used by the transport/timing model.
    pub fn config_words(&self) -> usize {
        let mut words = 0usize;
        for c in &self.cells {
            if c.is_empty() {
                continue;
            }
            words += 1 // opcode
                + 3 // fu operand muxes
                + 4; // out muxes
            for s in [c.fu1, c.fu2, c.fsel] {
                if matches!(s, FuSrc::Const(_)) {
                    words += 1; // constant payload word
                }
            }
        }
        words + self.inputs.len() + self.outputs.len()
    }
}

/// Hand-placed Fig 2(D)-style configuration of `C = A + 3B + 1` on a 2x2
/// grid, used by tests and the quickstart example as ground truth for the
/// config → image → PJRT path.
///
/// Layout (paper Fig 2D, adapted to our port semantics):
///   cell (0,0): MUL  b(W-in) * const 3      → out S
///   cell (1,0): ADD  a(W-in) + mul(N-in)    → out E
///   cell (1,1): ADD  sum(W-in) + const 1    → out E (border, output 0)
/// External inputs: B at (0,0).W, A at (1,0).W.
pub fn fig2_config() -> GridConfig {
    let grid = Grid::new(2, 2);
    let mut cfg = GridConfig::empty(grid);
    let c00 = CellCoord::new(0, 0);
    let c10 = CellCoord::new(1, 0);
    let c11 = CellCoord::new(1, 1);

    {
        let cell = cfg.cell_mut(c00);
        cell.op = Some(Op::Mul);
        cell.fu1 = FuSrc::In(Dir::W);
        cell.fu2 = FuSrc::Const(3);
        cell.out[Dir::S.index()] = OutSrc::Fu;
    }
    {
        let cell = cfg.cell_mut(c10);
        cell.op = Some(Op::Add);
        cell.fu1 = FuSrc::In(Dir::W);
        cell.fu2 = FuSrc::In(Dir::N);
        cell.out[Dir::E.index()] = OutSrc::Fu;
    }
    {
        let cell = cfg.cell_mut(c11);
        cell.op = Some(Op::Add);
        cell.fu1 = FuSrc::In(Dir::W);
        cell.fu2 = FuSrc::Const(1);
        cell.out[Dir::E.index()] = OutSrc::Fu;
    }
    cfg.inputs.push(IoAssign { cell: c00, dir: Dir::W, index: 1 }); // B
    cfg.inputs.push(IoAssign { cell: c10, dir: Dir::W, index: 0 }); // A
    cfg.outputs.push(IoAssign { cell: c11, dir: Dir::E, index: 0 });
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_config_to_image_matches_formula() {
        let cfg = fig2_config();
        cfg.validate().unwrap();
        let img = cfg.to_image().unwrap();
        assert_eq!(img.n_cells(), 3);
        assert_eq!(img.out_sel.len(), 1);
        for (a, b) in [(10, 5), (0, 0), (-7, 13)] {
            assert_eq!(img.eval_scalar(&[a, b]), vec![a + 3 * b + 1], "a={a} b={b}");
        }
    }

    #[test]
    fn pass_through_routing_traces() {
        // B enters at (0,0).W, passes through (0,0) W->E, then (0,1) takes
        // it as FU lhs, +const 5, out E (border) = output 0.
        let grid = Grid::new(1, 2);
        let mut cfg = GridConfig::empty(grid);
        let c0 = CellCoord::new(0, 0);
        let c1 = CellCoord::new(0, 1);
        cfg.cell_mut(c0).out[Dir::E.index()] = OutSrc::In(Dir::W);
        {
            let cell = cfg.cell_mut(c1);
            cell.op = Some(Op::Add);
            cell.fu1 = FuSrc::In(Dir::W);
            cell.fu2 = FuSrc::Const(5);
            cell.out[Dir::E.index()] = OutSrc::Fu;
        }
        cfg.inputs.push(IoAssign { cell: c0, dir: Dir::W, index: 0 });
        cfg.outputs.push(IoAssign { cell: c1, dir: Dir::E, index: 0 });
        cfg.validate().unwrap();
        let img = cfg.to_image().unwrap();
        assert_eq!(img.eval_scalar(&[37]), vec![42]);
    }

    #[test]
    fn undriven_input_rejected() {
        let grid = Grid::new(1, 1);
        let mut cfg = GridConfig::empty(grid);
        let p = CellCoord::new(0, 0);
        {
            let cell = cfg.cell_mut(p);
            cell.op = Some(Op::Pass);
            cell.fu1 = FuSrc::In(Dir::W); // no input bound there
            cell.out[Dir::E.index()] = OutSrc::Fu;
        }
        cfg.outputs.push(IoAssign { cell: p, dir: Dir::E, index: 0 });
        assert!(matches!(
            cfg.to_image(),
            Err(ConfigError::UndrivenInput { .. })
        ));
    }

    #[test]
    fn routing_cycle_rejected() {
        // 1x2 grid: (0,0).E driven by its own W input, which is driven by
        // (0,1).W output, which passes through from its W input — i.e. the
        // two cells bounce the signal between each other.
        let grid = Grid::new(1, 2);
        let mut cfg = GridConfig::empty(grid);
        let c0 = CellCoord::new(0, 0);
        let c1 = CellCoord::new(0, 1);
        cfg.cell_mut(c0).out[Dir::E.index()] = OutSrc::In(Dir::E);
        cfg.cell_mut(c1).out[Dir::W.index()] = OutSrc::In(Dir::W);
        {
            let cell = cfg.cell_mut(c1);
            cell.op = Some(Op::Pass);
            cell.fu1 = FuSrc::In(Dir::W);
            cell.out[Dir::E.index()] = OutSrc::Fu;
        }
        cfg.outputs.push(IoAssign { cell: c1, dir: Dir::E, index: 0 });
        assert!(matches!(cfg.to_image(), Err(ConfigError::RoutingCycle(..))));
    }

    #[test]
    fn io_on_border_enforced() {
        let grid = Grid::new(3, 3);
        let mut cfg = GridConfig::empty(grid);
        cfg.inputs.push(IoAssign { cell: CellCoord::new(1, 1), dir: Dir::N, index: 0 });
        assert!(matches!(cfg.validate(), Err(ConfigError::NotBorder(..))));
    }

    #[test]
    fn unused_fu_rejected() {
        let cfg0 = fig2_config();
        let mut cfg = cfg0.clone();
        // Disconnect the MUL cell's output: its FU becomes dead but the ADD
        // at (1,0) now has an undriven N input — either error is a reject;
        // check FuUnused via a standalone dead cell instead.
        let dead = CellCoord::new(0, 1);
        cfg.cell_mut(dead).op = Some(Op::Add);
        cfg.cell_mut(dead).fu1 = FuSrc::Const(1);
        cfg.cell_mut(dead).fu2 = FuSrc::Const(2);
        assert!(matches!(cfg.validate(), Err(ConfigError::FuUnused(_))));
    }

    #[test]
    fn config_words_counts_constants() {
        let cfg = fig2_config();
        // 3 used cells * 8 words + 2 const payloads + 3 io bindings
        assert_eq!(cfg.config_words(), 24 + 2 + 3);
    }
}

//! DFE functional-unit opcodes — the shared ABI with the Pallas kernel.
//!
//! Must stay in sync with `python/compile/kernels/opcodes.py`. The paper's
//! DFE (§III-A) supports 32-bit signed integer arithmetic, comparisons and
//! MUX nodes; integer division/remainder and floating point are explicitly
//! unsupported (that restriction drives the Table I outcomes).

use std::fmt;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(i32)]
pub enum Op {
    Nop = 0,
    Add = 1,
    Sub = 2,
    Mul = 3,
    Min = 4,
    Max = 5,
    Lt = 6,
    Gt = 7,
    Le = 8,
    Ge = 9,
    Eq = 10,
    Ne = 11,
    Mux = 12,
    And = 13,
    Or = 14,
    Xor = 15,
    Shl = 16,
    Shr = 17,
    Pass = 18,
}

pub const NUM_OPS: i32 = 19;

pub const ALL_OPS: [Op; 19] = [
    Op::Nop, Op::Add, Op::Sub, Op::Mul, Op::Min, Op::Max, Op::Lt, Op::Gt,
    Op::Le, Op::Ge, Op::Eq, Op::Ne, Op::Mux, Op::And, Op::Or, Op::Xor,
    Op::Shl, Op::Shr, Op::Pass,
];

impl Op {
    pub fn from_i32(v: i32) -> Option<Op> {
        ALL_OPS.get(v as usize).copied()
    }

    pub fn code(self) -> i32 {
        self as i32
    }

    /// Whether this op reads its second operand.
    pub fn uses_rhs(self) -> bool {
        !matches!(self, Op::Nop | Op::Pass)
    }

    /// Whether this op reads the selection input (only MUX does).
    pub fn uses_sel(self) -> bool {
        matches!(self, Op::Mux)
    }

    pub fn name(self) -> &'static str {
        match self {
            Op::Nop => "nop",
            Op::Add => "add",
            Op::Sub => "sub",
            Op::Mul => "mul",
            Op::Min => "min",
            Op::Max => "max",
            Op::Lt => "lt",
            Op::Gt => "gt",
            Op::Le => "le",
            Op::Ge => "ge",
            Op::Eq => "eq",
            Op::Ne => "ne",
            Op::Mux => "mux",
            Op::And => "and",
            Op::Or => "or",
            Op::Xor => "xor",
            Op::Shl => "shl",
            Op::Shr => "shr",
            Op::Pass => "pass",
        }
    }

    /// Evaluate the functional unit: `op(a, b, sel)` with the paper's
    /// 32-bit signed wrapping semantics. Single source of truth for the
    /// rust-side DFE simulation; mirrors `dfe_grid.fu` / `ref._py_fu`.
    #[inline]
    pub fn eval(self, a: i32, b: i32, s: i32) -> i32 {
        match self {
            Op::Nop => 0,
            Op::Add => a.wrapping_add(b),
            Op::Sub => a.wrapping_sub(b),
            Op::Mul => a.wrapping_mul(b),
            Op::Min => a.min(b),
            Op::Max => a.max(b),
            Op::Lt => (a < b) as i32,
            Op::Gt => (a > b) as i32,
            Op::Le => (a <= b) as i32,
            Op::Ge => (a >= b) as i32,
            Op::Eq => (a == b) as i32,
            Op::Ne => (a != b) as i32,
            Op::Mux => if s != 0 { a } else { b },
            Op::And => a & b,
            Op::Or => a | b,
            Op::Xor => a ^ b,
            Op::Shl => a.wrapping_shl(b.clamp(0, 31) as u32),
            Op::Shr => a.wrapping_shr(b.clamp(0, 31) as u32),
            Op::Pass => a,
        }
    }
}

impl fmt::Display for Op {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_codes() {
        for op in ALL_OPS {
            assert_eq!(Op::from_i32(op.code()), Some(op));
        }
        assert_eq!(Op::from_i32(NUM_OPS), None);
        assert_eq!(Op::from_i32(-1), None);
    }

    #[test]
    fn wrapping_semantics() {
        assert_eq!(Op::Add.eval(i32::MAX, 1, 0), i32::MIN);
        assert_eq!(Op::Mul.eval(1 << 30, 1 << 30, 0), 0);
        assert_eq!(Op::Sub.eval(i32::MIN, 1, 0), i32::MAX);
    }

    #[test]
    fn comparisons_are_01() {
        assert_eq!(Op::Lt.eval(1, 2, 0), 1);
        assert_eq!(Op::Ge.eval(1, 2, 0), 0);
        assert_eq!(Op::Eq.eval(7, 7, 0), 1);
        assert_eq!(Op::Ne.eval(7, 7, 0), 0);
    }

    #[test]
    fn mux_selects_on_nonzero() {
        assert_eq!(Op::Mux.eval(10, 20, 1), 10);
        assert_eq!(Op::Mux.eval(10, 20, -5), 10);
        assert_eq!(Op::Mux.eval(10, 20, 0), 20);
    }

    #[test]
    fn shifts_clamp() {
        assert_eq!(Op::Shl.eval(1, 40, 0), 1 << 31);
        assert_eq!(Op::Shl.eval(1, -3, 0), 1);
        assert_eq!(Op::Shr.eval(-64, 40, 0), -1); // arithmetic
        assert_eq!(Op::Shr.eval(-64, 2, 0), -16);
    }
}

//! Configuration cache (paper §III: "the programming details are stored in
//! a cache for later reuse ... switch between different configurations in
//! few milliseconds, so it makes sense to change configuration as often as
//! needed").
//!
//! Keyed by a structural hash of the DFG, so a hot function re-entering
//! the offload path skips the expensive Las-Vegas place & route entirely
//! and pays only the (millisecond-scale) configuration switch.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::rc::Rc;

use super::config::GridConfig;
use super::exec::CompiledFabric;
use super::grid::CellCoord;
use super::image::ExecImage;
use super::lower::LoweredKernel;
use super::plan::ExecutionPlan;
use crate::dfg::graph::{Dfg, NodeId, NodeKind};
use crate::par::lasvegas::ParStats;

/// Structural hash of a DFG (node kinds + edges, order-sensitive — DFGs
/// extracted from the same IR are built deterministically).
pub fn dfg_key(dfg: &Dfg) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    for node in &dfg.nodes {
        match &node.kind {
            NodeKind::Input(j) => (0u8, *j as i64).hash(&mut h),
            NodeKind::Const(v) => (1u8, *v as i64).hash(&mut h),
            NodeKind::Calc(op) => (2u8, op.code() as i64).hash(&mut h),
            NodeKind::Output(j) => (3u8, *j as i64).hash(&mut h),
        }
        node.srcs.hash(&mut h);
    }
    h.finish()
}

/// Specialization signature: the adaptive respecialization controller's
/// cache-key component (unroll factor × observed trip-count bucket), so
/// the generic artifact and any number of profile-chosen specializations
/// of the same source loop coexist in the cache and tier demotion is a
/// cache hit, never a re-route. `trip_bucket` is the log2 bucket
/// ([`crate::jit::engine::Histogram::bucket_of`]) of the batch size the
/// artifact was specialized for; 0 means "generic, no trip assumption".
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub struct SpecSignature {
    pub unroll: u32,
    pub trip_bucket: u32,
}

impl SpecSignature {
    pub fn new(unroll: usize, trip_bucket: usize) -> SpecSignature {
        SpecSignature { unroll: unroll as u32, trip_bucket: trip_bucket as u32 }
    }

    /// The generic tier's signature: no trip-count assumption.
    pub fn generic(unroll: usize) -> SpecSignature {
        SpecSignature::new(unroll, 0)
    }
}

/// Cache key of a DFG hash specialized under `sig`. Deliberately distinct
/// from the bare DFG key even for the default signature, so artifacts
/// routed through the specialization-aware path never collide with keys
/// minted by other schemes.
pub fn spec_key(dfg: u64, sig: SpecSignature) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    dfg.hash(&mut h);
    (sig.unroll as u64, sig.trip_bucket as u64).hash(&mut h);
    h.finish()
}

/// Tenant-agnostic cache key for the multi-tenant serve layer: the DFG's
/// structural hash combined with the shard-region geometry it was routed
/// for. Two tenants running the same kernel share the entry (the paper's
/// "stored in a cache for later reuse", across address spaces); the same
/// DFG routed for a differently-shaped region does not.
pub fn region_key(dfg: u64, grid: crate::dfe::grid::Grid) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    dfg.hash(&mut h);
    (grid.rows as u64, grid.cols as u64).hash(&mut h);
    h.finish()
}

/// A cached, ready-to-load configuration. Carries the compiled wave
/// executor (`dfe::exec`) lowered once at insert time, so a cache hit —
/// single-tenant re-offload or a second tenant of the same kernel — skips
/// both place & route *and* the lowering. `None` only for configurations
/// the lowering refuses (not feed-forward); those execute on `CycleSim`.
#[derive(Clone, Debug)]
pub struct CachedConfig {
    pub config: GridConfig,
    pub image: ExecImage,
    pub fabric: Option<Rc<CompiledFabric>>,
    /// The wave schedule specialized once more into vectorized
    /// straight-line batch kernels (`dfe::lower`): folding, fusion and
    /// per-op monomorphized sweeps. Built whenever `fabric` is — the
    /// serve/offload hot paths execute through this by default, with
    /// `fabric` as the `--no-lower` fallback. Verifier pass V6 re-proves
    /// it equivalent to the wave schedule on every debug-build insert.
    pub lowered: Option<Rc<LoweredKernel>>,
    /// Which artifact variant (grid size) it targets.
    pub variant: String,
    /// P&R seed that produced the artifact (the portfolio winner's derived
    /// seed; 0 for entries built without provenance). Replaying
    /// `place_and_route_seeded` with this seed *and the same warm hint the
    /// winning search used* reproduces the artifact; cold-compiled entries
    /// reproduce from the seed alone.
    pub seed: u64,
    /// Stats of the winning search — the compile cost a cache hit avoids
    /// (surfaced as `OffloadRecord::avoided` on hits).
    pub par_stats: Option<ParStats>,
    /// The winning placement: the warm seed for incremental placement
    /// reuse when this artifact's function respecializes to another tier.
    pub placement: Vec<(NodeId, CellCoord)>,
}

impl CachedConfig {
    /// Build an entry from a routed configuration, lowering the wave
    /// executor eagerly (routed configs are feed-forward, so in practice
    /// `fabric` is always `Some`; structural illegality can't happen for a
    /// config that already produced `image`).
    pub fn new(config: GridConfig, image: ExecImage, variant: String) -> CachedConfig {
        let fabric = CompiledFabric::compile(&config).ok().map(Rc::new);
        let lowered = fabric.as_ref().map(|f| Rc::new(LoweredKernel::lower(f)));
        CachedConfig {
            config,
            image,
            fabric,
            lowered,
            variant,
            seed: 0,
            par_stats: None,
            placement: Vec::new(),
        }
    }

    /// [`Self::new`] plus compile provenance: the winning seed, its search
    /// stats and its placement (warm-start hint for the next spec tier).
    pub fn with_provenance(
        config: GridConfig,
        image: ExecImage,
        variant: String,
        seed: u64,
        stats: ParStats,
        placement: Vec<(NodeId, CellCoord)>,
    ) -> CachedConfig {
        let mut c = CachedConfig::new(config, image, variant);
        c.seed = seed;
        c.par_stats = Some(stats);
        c.placement = placement;
        c
    }
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// LRU cache of placed-and-routed artifacts, in two keyed stores sharing
/// one capacity, one LRU clock and one stats block:
///
/// * single-tile configurations ([`CachedConfig`], weight 1 — the PR-5
///   semantics, bit-for-bit: a cache of only single-tile entries behaves
///   exactly like the old single-store LRU);
/// * tiled execution plans ([`ExecutionPlan`], weight = tile count — a
///   6-tile plan occupies six capacity units, so it cannot squat in "one
///   slot" and starve single-tile tenants).
///
/// Eviction is global-LRU by weight: an insert evicts least-recently
/// used victims from *either* store until the incoming artifact fits.
/// A plan wider than the whole capacity still lands (after evicting
/// everything else) — refusing it would deadlock the oversized tenant —
/// and is simply the first victim of the next insert.
pub struct ConfigCache {
    capacity: usize,
    map: HashMap<u64, (CachedConfig, u64)>,
    plans: HashMap<u64, (ExecutionPlan, u64)>,
    clock: u64,
    pub stats: CacheStats,
}

impl ConfigCache {
    pub fn new(capacity: usize) -> ConfigCache {
        assert!(capacity > 0);
        ConfigCache {
            capacity,
            map: HashMap::new(),
            plans: HashMap::new(),
            clock: 0,
            stats: CacheStats::default(),
        }
    }

    /// Resident artifacts (entries + plans), regardless of weight.
    pub fn len(&self) -> usize {
        self.map.len() + self.plans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty() && self.plans.is_empty()
    }

    /// Occupied capacity units: one per single-tile entry, tile count per
    /// plan. Bounded by `capacity` except for a lone over-wide plan.
    pub fn total_weight(&self) -> usize {
        self.map.len() + self.plans.values().map(|(p, _)| p.weight()).sum::<usize>()
    }

    /// Key presence without touching the LRU clock or the hit/miss stats
    /// (the compile service peeks before deciding to submit a job; a peek
    /// is not a lookup).
    pub fn contains(&self, key: u64) -> bool {
        self.map.contains_key(&key)
    }

    /// Value access without touching the LRU clock or the hit/miss stats
    /// (the compile slot reads back an entry it just landed; the caller
    /// already accounted its lookup).
    pub fn peek(&self, key: u64) -> Option<&CachedConfig> {
        self.map.get(&key).map(|(cfg, _)| cfg)
    }

    pub fn get(&mut self, key: u64) -> Option<&CachedConfig> {
        self.clock += 1;
        let clock = self.clock;
        match self.map.get_mut(&key) {
            Some((cfg, stamp)) => {
                *stamp = clock;
                self.stats.hits += 1;
                Some(&*cfg)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    pub fn insert(&mut self, key: u64, value: CachedConfig) {
        // Debug-build sanitizer (DESIGN.md §11): every artifact entering
        // the cache re-verifies V2/V3 from scratch, so any test that
        // exercises the pipeline transparently runs under the verifier.
        // Release builds pay nothing.
        #[cfg(debug_assertions)]
        {
            let diags = crate::analysis::verifier::verify_artifact(&value);
            assert!(
                !crate::analysis::diag::has_errors(&diags),
                "verify-on-insert: artifact {key:#018x} fails static verification\n{}",
                crate::analysis::diag::render_table(&diags)
            );
        }
        self.clock += 1;
        self.make_room(1, Residency::Entry(key));
        self.map.insert(key, (value, self.clock));
    }

    /// Plan-store mirror of [`Self::contains`].
    pub fn contains_plan(&self, key: u64) -> bool {
        self.plans.contains_key(&key)
    }

    /// Plan-store mirror of [`Self::peek`].
    pub fn peek_plan(&self, key: u64) -> Option<&ExecutionPlan> {
        self.plans.get(&key).map(|(p, _)| p)
    }

    /// Plan-store mirror of [`Self::get`]: bumps the shared clock and the
    /// shared hit/miss stats.
    pub fn get_plan(&mut self, key: u64) -> Option<&ExecutionPlan> {
        self.clock += 1;
        let clock = self.clock;
        match self.plans.get_mut(&key) {
            Some((p, stamp)) => {
                *stamp = clock;
                self.stats.hits += 1;
                Some(&*p)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Insert an assembled plan at its tile-count weight.
    pub fn insert_plan(&mut self, key: u64, plan: ExecutionPlan) {
        // Debug-build sanitizer: provenance-free V4 (plus per-tile V2/V3)
        // on every plan entering the store. See `Self::insert`.
        #[cfg(debug_assertions)]
        {
            let diags = crate::analysis::verifier::verify_plan(&plan);
            assert!(
                !crate::analysis::diag::has_errors(&diags),
                "verify-on-insert: plan {key:#018x} fails static verification\n{}",
                crate::analysis::diag::render_table(&diags)
            );
        }
        self.clock += 1;
        self.make_room(plan.weight(), Residency::Plan(key));
        self.plans.insert(key, (plan, self.clock));
    }

    /// Evict global-LRU victims (from either store) until `weight` more
    /// units fit. The key being overwritten contributes neither resident
    /// weight nor a victim candidate. Stops — possibly overweight — when
    /// nothing else is left to evict.
    fn make_room(&mut self, weight: usize, incoming: Residency) {
        loop {
            let replaced = match incoming {
                Residency::Entry(k) => self.map.get(&k).map(|_| 1).unwrap_or(0),
                Residency::Plan(k) => {
                    self.plans.get(&k).map(|(p, _)| p.weight()).unwrap_or(0)
                }
            };
            if self.total_weight() - replaced + weight <= self.capacity {
                return;
            }
            let entry_victim = self
                .map
                .iter()
                .filter(|(&k, _)| incoming != Residency::Entry(k))
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(&k, (_, stamp))| (*stamp, k));
            let plan_victim = self
                .plans
                .iter()
                .filter(|(&k, _)| incoming != Residency::Plan(k))
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(&k, (_, stamp))| (*stamp, k));
            match (entry_victim, plan_victim) {
                (Some((es, ek)), Some((ps, pk))) => {
                    if es <= ps {
                        self.map.remove(&ek);
                    } else {
                        self.plans.remove(&pk);
                    }
                }
                (Some((_, ek)), None) => {
                    self.map.remove(&ek);
                }
                (None, Some((_, pk))) => {
                    self.plans.remove(&pk);
                }
                (None, None) => return,
            }
            self.stats.evictions += 1;
        }
    }

    /// Every resident single-tile entry, LRU-silently (persistence walks
    /// the store to serialize it; a snapshot is not a lookup). Iteration
    /// order is unspecified — the on-disk writer sorts by key.
    pub fn iter_entries(&self) -> impl Iterator<Item = (u64, &CachedConfig)> {
        self.map.iter().map(|(&k, (c, _))| (k, c))
    }

    /// Plan-store mirror of [`Self::iter_entries`].
    pub fn iter_plans(&self) -> impl Iterator<Item = (u64, &ExecutionPlan)> {
        self.plans.iter().map(|(&k, (p, _))| (k, p))
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.stats.hits + self.stats.misses;
        if total == 0 {
            0.0
        } else {
            self.stats.hits as f64 / total as f64
        }
    }
}

/// Which store (and key) an insert is about to occupy.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Residency {
    Entry(u64),
    Plan(u64),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfe::config::fig2_config;
    use crate::dfg::graph::{fig2_dfg, listing1_dfg};

    fn dummy_entry() -> CachedConfig {
        let config = fig2_config();
        let image = config.to_image().unwrap();
        CachedConfig::new(config, image, "dfe_4x4".into())
    }

    #[test]
    fn cached_entry_carries_compiled_fabric() {
        let entry = dummy_entry();
        let fabric = entry.fabric.as_ref().expect("fig2 lowers to a wave schedule");
        assert!(fabric.n_ops() > 0);
    }

    #[test]
    fn key_is_structural() {
        assert_eq!(dfg_key(&fig2_dfg()), dfg_key(&fig2_dfg()));
        assert_ne!(dfg_key(&fig2_dfg()), dfg_key(&listing1_dfg()));
    }

    #[test]
    fn region_key_distinguishes_geometry_but_not_tenant() {
        use crate::dfe::grid::Grid;
        let k = dfg_key(&fig2_dfg());
        // Same DFG + same region shape -> shared entry across tenants.
        assert_eq!(region_key(k, Grid::new(4, 8)), region_key(k, Grid::new(4, 8)));
        // Same DFG routed for another region shape -> distinct entry.
        assert_ne!(region_key(k, Grid::new(4, 8)), region_key(k, Grid::new(8, 8)));
        assert_ne!(region_key(k, Grid::new(4, 8)), k);
    }

    #[test]
    fn spec_key_separates_signatures_and_preserves_identity() {
        let k = dfg_key(&fig2_dfg());
        // Same DFG + same signature -> same entry (cache hits across
        // respecializations back to a previously routed tier).
        assert_eq!(spec_key(k, SpecSignature::new(4, 7)), spec_key(k, SpecSignature::new(4, 7)));
        // Unroll and trip-bucket components both separate artifacts.
        assert_ne!(spec_key(k, SpecSignature::generic(1)), spec_key(k, SpecSignature::generic(4)));
        assert_ne!(spec_key(k, SpecSignature::new(4, 3)), spec_key(k, SpecSignature::new(4, 7)));
        // Never collides with the bare structural key.
        assert_ne!(spec_key(k, SpecSignature::default()), k);
        // Distinct DFGs stay distinct under any shared signature.
        let k2 = dfg_key(&listing1_dfg());
        assert_ne!(spec_key(k, SpecSignature::generic(2)), spec_key(k2, SpecSignature::generic(2)));
    }

    #[test]
    fn key_sensitive_to_constants() {
        let mut g1 = fig2_dfg();
        let g2 = fig2_dfg();
        // Change constant 3 -> 4.
        for n in &mut g1.nodes {
            if n.kind == NodeKind::Const(3) {
                n.kind = NodeKind::Const(4);
            }
        }
        assert_ne!(dfg_key(&g1), dfg_key(&g2));
    }

    #[test]
    fn lru_eviction() {
        let mut c = ConfigCache::new(2);
        c.insert(1, dummy_entry());
        c.insert(2, dummy_entry());
        assert!(c.get(1).is_some()); // 1 now more recent than 2
        c.insert(3, dummy_entry()); // evicts 2
        assert!(c.get(2).is_none());
        assert!(c.get(1).is_some());
        assert!(c.get(3).is_some());
        assert_eq!(c.stats.evictions, 1);
    }

    #[test]
    fn hit_rate_accounting() {
        let mut c = ConfigCache::new(4);
        assert!(c.get(9).is_none());
        c.insert(9, dummy_entry());
        assert!(c.get(9).is_some());
        assert!(c.get(9).is_some());
        assert!((c.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn hit_rate_with_zero_lookups_is_zero_not_nan() {
        let mut c = ConfigCache::new(2);
        assert_eq!(c.hit_rate(), 0.0);
        // Inserts alone are not lookups and must not move the rate.
        c.insert(1, dummy_entry());
        assert_eq!(c.hit_rate(), 0.0);
        assert_eq!(c.stats, CacheStats::default());
    }

    #[test]
    fn insert_over_existing_key_at_capacity_evicts_nothing() {
        let mut c = ConfigCache::new(2);
        c.insert(1, dummy_entry());
        c.insert(2, dummy_entry());
        // Overwriting a resident key must refresh in place: same length,
        // no eviction, both keys still resident.
        let mut updated = dummy_entry();
        updated.seed = 77;
        c.insert(1, updated);
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats.evictions, 0);
        assert_eq!(c.get(1).unwrap().seed, 77, "overwrite must replace the value");
        assert!(c.get(2).is_some());
        // The overwrite also counts as a use: inserting a third key now
        // evicts 2 (older stamp), not 1.
        c.insert(1, dummy_entry());
        c.get(1);
        c.insert(3, dummy_entry());
        assert!(c.contains(1) && c.contains(3) && !c.contains(2), "LRU order broken");
    }

    #[test]
    fn contains_does_not_perturb_stats_or_lru() {
        let mut c = ConfigCache::new(2);
        c.insert(1, dummy_entry());
        assert!(c.contains(1));
        assert!(!c.contains(9));
        assert_eq!(c.stats, CacheStats::default(), "peeks are not lookups");
    }

    fn dummy_plan(tiles: usize) -> ExecutionPlan {
        // A verifier-clean spill chain (verify-on-insert runs V4 under
        // debug_assertions): tile i feeds tile i+1 through spill slot i,
        // only the last tile lands the external output.
        use crate::dfg::partition::{TileSink, TileSource};
        let single = ExecutionPlan::single(dummy_entry(), 0);
        if tiles <= 1 {
            return single;
        }
        let mut ts = Vec::with_capacity(tiles);
        for i in 0..tiles {
            let mut t = single.tiles[0].clone();
            t.key = i as u64;
            if i > 0 {
                t.sources = vec![TileSource::Spill(i - 1), TileSource::External(1)];
            }
            if i + 1 < tiles {
                t.sinks = vec![TileSink::Spill(i)];
            }
            ts.push(t);
        }
        ExecutionPlan::from_tiles(ts, tiles - 1).unwrap()
    }

    #[test]
    fn plan_weight_counts_per_tile_in_eviction() {
        // Regression (ISSUE 6): a 3-tile plan must occupy three capacity
        // units, not one slot — inserting it into a full cache of
        // singles evicts as many LRU singles as its weight demands.
        let mut c = ConfigCache::new(4);
        for k in 1..=4 {
            c.insert(k, dummy_entry());
        }
        assert_eq!(c.total_weight(), 4);
        c.get(1); // 1 is now the most recent single
        c.insert_plan(100, dummy_plan(3));
        assert_eq!(c.stats.evictions, 3, "weight 3 forces three LRU evictions");
        assert_eq!(c.total_weight(), 4);
        assert!(c.contains(1), "the recently used single survives");
        assert!(!c.contains(2) && !c.contains(3) && !c.contains(4));
        assert!(c.contains_plan(100));
    }

    #[test]
    fn plans_are_lru_victims_for_single_inserts() {
        let mut c = ConfigCache::new(3);
        c.insert_plan(100, dummy_plan(2));
        c.insert(1, dummy_entry());
        assert_eq!(c.total_weight(), 3);
        // The plan is the LRU resident: one more single evicts it whole,
        // freeing both of its units at once.
        c.insert(2, dummy_entry());
        assert!(!c.contains_plan(100));
        assert_eq!(c.stats.evictions, 1);
        assert_eq!(c.total_weight(), 2);
        assert!(c.contains(1) && c.contains(2));
    }

    #[test]
    fn plan_lookups_share_clock_and_stats() {
        let mut c = ConfigCache::new(5);
        assert!(c.get_plan(100).is_none());
        c.insert_plan(100, dummy_plan(2));
        assert!(c.get_plan(100).is_some());
        c.insert(1, dummy_entry());
        assert!(c.get(1).is_some());
        assert_eq!(c.stats.hits, 2);
        assert_eq!(c.stats.misses, 1);
        assert!((c.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
        // A plan hit refreshes its stamp on the shared clock: the single
        // becomes the LRU victim when space runs out.
        c.get_plan(100);
        c.insert_plan(200, dummy_plan(3));
        assert!(c.contains_plan(100), "recently hit plan survives");
        assert!(!c.contains(1), "stale single evicted first");
    }

    #[test]
    fn contains_plan_and_peek_plan_are_silent() {
        let mut c = ConfigCache::new(2);
        c.insert_plan(100, dummy_plan(2));
        assert!(c.contains_plan(100));
        assert!(c.peek_plan(100).is_some());
        assert!(c.peek_plan(9).is_none());
        assert!(!c.contains_plan(9));
        assert_eq!(c.stats, CacheStats::default(), "peeks are not lookups");
    }

    #[test]
    fn over_wide_plan_lands_after_evicting_everything() {
        let mut c = ConfigCache::new(2);
        c.insert(1, dummy_entry());
        c.insert(2, dummy_entry());
        c.insert_plan(100, dummy_plan(5));
        assert!(c.contains_plan(100), "refusing would deadlock the oversized tenant");
        assert_eq!(c.stats.evictions, 2);
        assert_eq!(c.total_weight(), 5, "temporarily overweight");
        // ... and it is the first victim of the next insert.
        c.insert(3, dummy_entry());
        assert!(!c.contains_plan(100));
        assert_eq!(c.total_weight(), 1);
    }

    #[test]
    fn plan_overwrite_at_capacity_evicts_nothing() {
        let mut c = ConfigCache::new(4);
        c.insert_plan(100, dummy_plan(3));
        c.insert(1, dummy_entry());
        // Re-landing the same plan key (same weight) must refresh in
        // place, exactly like the single-store overwrite semantics.
        c.insert_plan(100, dummy_plan(3));
        assert_eq!(c.stats.evictions, 0);
        assert_eq!(c.total_weight(), 4);
        assert!(c.contains(1) && c.contains_plan(100));
    }

    #[test]
    fn provenance_survives_the_cache() {
        use crate::par::lasvegas::ParStats;
        let config = fig2_config();
        let image = config.to_image().unwrap();
        let stats = ParStats { placements: 5, route_calls: 9, ..Default::default() };
        let placement = vec![(2usize, crate::dfe::grid::CellCoord::new(0, 1))];
        let e = CachedConfig::with_provenance(
            config,
            image,
            "dfe_4x4".into(),
            0xABCD,
            stats,
            placement.clone(),
        );
        let mut c = ConfigCache::new(2);
        c.insert(4, e);
        let got = c.get(4).unwrap();
        assert_eq!(got.seed, 0xABCD);
        assert_eq!(got.par_stats.unwrap().route_calls, 9);
        assert_eq!(got.placement, placement);
    }
}

//! Execution image: the topologically-linearized form of a placed DFE
//! configuration — exactly the operand layout of the AOT artifacts.
//!
//! The coordinator (place & route → `crate::par`) produces a *physical*
//! `dfe::config::GridConfig`; `GridConfig::to_image()` linearizes it into
//! this schedule. Numerics only depend on the image; physical placement
//! feeds the timing/resource model. `ExecImage::eval*` is the rust-side
//! functional oracle, cross-validated against the PJRT artifact in
//! `rust/tests/runtime_artifacts.rs`.

use std::fmt;

use super::abi;
use super::opcodes::Op;

/// One DFE cell in schedule order: `result = op(plane[src1], plane[src2],
/// plane[sel])`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ImageCell {
    pub op: Op,
    pub src1: usize,
    pub src2: usize,
    pub sel: usize,
}

/// A complete execution image.
#[derive(Clone, Debug, PartialEq)]
pub struct ExecImage {
    pub cells: Vec<ImageCell>,
    /// Constant pool (length <= abi::N_CONSTS).
    pub consts: Vec<i32>,
    /// Number of external inputs used (<= abi::N_INPUTS).
    pub n_inputs: usize,
    /// Plane slots routed to external outputs (length <= abi::N_OUTPUTS).
    pub out_sel: Vec<usize>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ImageError {
    TooManyConsts(usize),
    TooManyInputs(usize),
    TooManyOutputs(usize),
    TooManyCells(usize, usize),
    ForwardReference { cell: usize, slot: usize, limit: usize },
    BadOutputSlot { index: usize, slot: usize },
}

impl fmt::Display for ImageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ImageError::TooManyConsts(n) => write!(f, "{n} consts > {}", abi::N_CONSTS),
            ImageError::TooManyInputs(n) => write!(f, "{n} inputs > {}", abi::N_INPUTS),
            ImageError::TooManyOutputs(n) => write!(f, "{n} outputs > {}", abi::N_OUTPUTS),
            ImageError::TooManyCells(n, max) => write!(f, "{n} cells > grid capacity {max}"),
            ImageError::ForwardReference { cell, slot, limit } => write!(
                f,
                "cell {cell} reads slot {slot}, but only slots < {limit} are written"
            ),
            ImageError::BadOutputSlot { index, slot } => {
                write!(f, "output {index} reads out-of-range slot {slot}")
            }
        }
    }
}

impl std::error::Error for ImageError {}

impl ExecImage {
    pub fn n_cells(&self) -> usize {
        self.cells.len()
    }

    pub fn n_slots(&self) -> usize {
        abi::n_slots(self.cells.len())
    }

    /// Check the ABI bounds and the topological-schedule invariant the
    /// Pallas kernel relies on (sources must already be written).
    pub fn validate(&self) -> Result<(), ImageError> {
        if self.consts.len() > abi::N_CONSTS {
            return Err(ImageError::TooManyConsts(self.consts.len()));
        }
        if self.n_inputs > abi::N_INPUTS {
            return Err(ImageError::TooManyInputs(self.n_inputs));
        }
        if self.out_sel.len() > abi::N_OUTPUTS {
            return Err(ImageError::TooManyOutputs(self.out_sel.len()));
        }
        for (i, c) in self.cells.iter().enumerate() {
            let limit = abi::CELL_BASE + i;
            for slot in [c.src1, c.src2, c.sel] {
                if slot >= limit {
                    return Err(ImageError::ForwardReference { cell: i, slot, limit });
                }
            }
        }
        let n_slots = self.n_slots();
        for (index, &slot) in self.out_sel.iter().enumerate() {
            if slot >= n_slots {
                return Err(ImageError::BadOutputSlot { index, slot });
            }
        }
        Ok(())
    }

    /// Evaluate one lane. `inputs` supplies the external-input slots (its
    /// length must be >= n_inputs; extras ignored). Returns one value per
    /// out_sel entry.
    pub fn eval_scalar(&self, inputs: &[i32]) -> Vec<i32> {
        debug_assert!(inputs.len() >= self.n_inputs);
        let mut plane = vec![0i32; self.n_slots()];
        for (k, &c) in self.consts.iter().enumerate() {
            plane[abi::const_slot(k)] = c;
        }
        for j in 0..self.n_inputs {
            plane[abi::input_slot(j)] = inputs[j];
        }
        for (i, c) in self.cells.iter().enumerate() {
            plane[abi::cell_slot(i)] =
                c.op.eval(plane[c.src1], plane[c.src2], plane[c.sel]);
        }
        self.out_sel.iter().map(|&s| plane[s]).collect()
    }

    /// Evaluate a batch laid out slot-major (`x[j * batch + lane]`), the
    /// artifact ABI layout. Returns outputs slot-major (`[n_out, batch]`).
    pub fn eval_batch(&self, x: &[i32], batch: usize) -> Vec<i32> {
        debug_assert_eq!(x.len(), self.n_inputs * batch);
        let mut out = vec![0i32; self.out_sel.len() * batch];
        let mut lane_in = vec![0i32; self.n_inputs];
        for lane in 0..batch {
            for j in 0..self.n_inputs {
                lane_in[j] = x[j * batch + lane];
            }
            let r = self.eval_scalar(&lane_in);
            for (j, v) in r.into_iter().enumerate() {
                out[j * batch + lane] = v;
            }
        }
        out
    }

    /// Operand arrays padded to a variant's fixed shapes, ready for the
    /// PJRT call: (opcode, src1, src2, sel, consts, out_sel), each i32.
    /// Padding cells are NOPs reading slot 0, padded outputs read slot 0.
    pub fn padded_operands(
        &self,
        n_cells: usize,
    ) -> Result<([Vec<i32>; 4], Vec<i32>, Vec<i32>), ImageError> {
        self.validate()?;
        if self.cells.len() > n_cells {
            return Err(ImageError::TooManyCells(self.cells.len(), n_cells));
        }
        let mut opcode = vec![Op::Nop.code(); n_cells];
        let mut src1 = vec![0i32; n_cells];
        let mut src2 = vec![0i32; n_cells];
        let mut sel = vec![0i32; n_cells];
        for (i, c) in self.cells.iter().enumerate() {
            opcode[i] = c.op.code();
            src1[i] = c.src1 as i32;
            src2[i] = c.src2 as i32;
            sel[i] = c.sel as i32;
        }
        let mut consts = vec![0i32; abi::N_CONSTS];
        for (k, &c) in self.consts.iter().enumerate() {
            consts[k] = c;
        }
        let mut out_sel = vec![0i32; abi::N_OUTPUTS];
        for (j, &s) in self.out_sel.iter().enumerate() {
            out_sel[j] = s as i32;
        }
        Ok(([opcode, src1, src2, sel], consts, out_sel))
    }
}

/// Convenience builder used by tests, examples and the DFG lowering.
#[derive(Default, Debug)]
pub struct ImageBuilder {
    cells: Vec<ImageCell>,
    consts: Vec<i32>,
    n_inputs: usize,
    out_sel: Vec<usize>,
}

impl ImageBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reserve external input `j`, returning its plane slot.
    pub fn input(&mut self, j: usize) -> usize {
        self.n_inputs = self.n_inputs.max(j + 1);
        abi::input_slot(j)
    }

    /// Intern a constant in the pool, returning its plane slot. Zero maps
    /// to the dedicated zero slot, duplicates are shared (the paper's
    /// constant-masking reduces transfers; interning reduces pool usage).
    pub fn constant(&mut self, v: i32) -> usize {
        if v == 0 {
            return 0;
        }
        if let Some(k) = self.consts.iter().position(|&c| c == v) {
            return abi::const_slot(k);
        }
        self.consts.push(v);
        abi::const_slot(self.consts.len() - 1)
    }

    pub fn n_consts(&self) -> usize {
        self.consts.len()
    }

    /// Append a cell; returns the plane slot of its result.
    pub fn cell(&mut self, op: Op, src1: usize, src2: usize) -> usize {
        self.cell_sel(op, src1, src2, 0)
    }

    pub fn cell_sel(&mut self, op: Op, src1: usize, src2: usize, sel: usize) -> usize {
        self.cells.push(ImageCell { op, src1, src2, sel });
        abi::cell_slot(self.cells.len() - 1)
    }

    pub fn output(&mut self, slot: usize) -> usize {
        self.out_sel.push(slot);
        self.out_sel.len() - 1
    }

    pub fn build(self) -> Result<ExecImage, ImageError> {
        let img = ExecImage {
            cells: self.cells,
            consts: self.consts,
            n_inputs: self.n_inputs,
            out_sel: self.out_sel,
        };
        img.validate()?;
        Ok(img)
    }
}

/// The Fig-2 example `C = A + 3B + 1` as an execution image (two inputs).
pub fn fig2_image() -> ExecImage {
    let mut b = ImageBuilder::new();
    let a = b.input(0);
    let bb = b.input(1);
    let c3 = b.constant(3);
    let c1 = b.constant(1);
    let t0 = b.cell(Op::Mul, bb, c3);
    let t1 = b.cell(Op::Add, a, t0);
    let t2 = b.cell(Op::Add, t1, c1);
    b.output(t2);
    b.build().expect("fig2 image is valid")
}

/// Listing-1 / Fig-4: `C = (A > B) ? A + 3B + 1 : A - 5B - 2`.
pub fn listing1_image() -> ExecImage {
    let mut b = ImageBuilder::new();
    let a = b.input(0);
    let bb = b.input(1);
    let c3 = b.constant(3);
    let c1 = b.constant(1);
    let c5 = b.constant(5);
    let c2 = b.constant(2);
    let cond = b.cell(Op::Gt, a, bb);
    let t3b = b.cell(Op::Mul, bb, c3);
    let then1 = b.cell(Op::Add, a, t3b);
    let then2 = b.cell(Op::Add, then1, c1);
    let t5b = b.cell(Op::Mul, bb, c5);
    let else1 = b.cell(Op::Sub, a, t5b);
    let else2 = b.cell(Op::Sub, else1, c2);
    let r = b.cell_sel(Op::Mux, then2, else2, cond);
    b.output(r);
    b.build().expect("listing1 image is valid")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_matches_formula() {
        let img = fig2_image();
        for (a, b) in [(0, 0), (5, -7), (1000, 999), (i32::MAX, 1)] {
            let got = img.eval_scalar(&[a, b]);
            let want = a.wrapping_add(b.wrapping_mul(3)).wrapping_add(1);
            assert_eq!(got, vec![want], "a={a} b={b}");
        }
    }

    #[test]
    fn listing1_matches_branch() {
        let img = listing1_image();
        for (a, b) in [(10, 2), (2, 10), (-5, -5), (100, -100)] {
            let got = img.eval_scalar(&[a, b]);
            let want = if a > b { a + 3 * b + 1 } else { a - 5 * b - 2 };
            assert_eq!(got, vec![want], "a={a} b={b}");
        }
    }

    #[test]
    fn builder_interns_constants() {
        let mut b = ImageBuilder::new();
        assert_eq!(b.constant(0), 0);
        let s1 = b.constant(42);
        let s2 = b.constant(42);
        assert_eq!(s1, s2);
        assert_eq!(b.n_consts(), 1);
    }

    #[test]
    fn validate_rejects_forward_reference() {
        let img = ExecImage {
            cells: vec![ImageCell {
                op: Op::Add,
                src1: abi::cell_slot(0), // own result
                src2: 0,
                sel: 0,
            }],
            consts: vec![],
            n_inputs: 0,
            out_sel: vec![],
        };
        assert!(matches!(
            img.validate(),
            Err(ImageError::ForwardReference { cell: 0, .. })
        ));
    }

    #[test]
    fn validate_rejects_bad_output() {
        let img = ExecImage {
            cells: vec![],
            consts: vec![],
            n_inputs: 0,
            out_sel: vec![abi::CELL_BASE],
        };
        assert!(matches!(img.validate(), Err(ImageError::BadOutputSlot { .. })));
    }

    #[test]
    fn eval_batch_is_slotmajor() {
        let img = fig2_image();
        let batch = 4;
        // x[0][lane] = lane, x[1][lane] = 10*lane
        let mut x = vec![0i32; 2 * batch];
        for lane in 0..batch {
            x[lane] = lane as i32;
            x[batch + lane] = 10 * lane as i32;
        }
        let out = img.eval_batch(&x, batch);
        for lane in 0..batch {
            let (a, b) = (lane as i32, 10 * lane as i32);
            assert_eq!(out[lane], a + 3 * b + 1);
        }
    }

    #[test]
    fn padded_operands_roundtrip() {
        let img = fig2_image();
        let ([opcode, src1, _, _], consts, out_sel) = img.padded_operands(16).unwrap();
        assert_eq!(opcode.len(), 16);
        assert_eq!(opcode[0], Op::Mul.code());
        assert_eq!(opcode[3], Op::Nop.code());
        assert_eq!(consts.len(), abi::N_CONSTS);
        assert_eq!(out_sel.len(), abi::N_OUTPUTS);
        assert_eq!(src1[1] as usize, abi::input_slot(0));
    }

    #[test]
    fn padded_operands_rejects_overflow() {
        let img = fig2_image();
        assert!(matches!(
            img.padded_operands(2),
            Err(ImageError::TooManyCells(3, 2))
        ));
    }
}

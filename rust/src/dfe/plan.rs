//! Plan-level artifacts: the cached unit of offload generalized from
//! "one DFG → one [`CachedConfig`]" to "one DFG → an [`ExecutionPlan`]
//! of one or more feed-forward tiles" (ROADMAP item 1).
//!
//! A plan is what actually loads onto a shard: tiles execute as a
//! multi-pass schedule over the same grid, spilled intermediates
//! round-tripping through host staging between passes
//! ([`crate::transport::PlanTimeline`] models the overlap). The
//! single-tile plan is the degenerate case and is *never* constructed on
//! the legacy path — DFGs that fit the grid keep the exact PR-5
//! `CachedConfig` flow so existing artifacts stay byte-identical.
//!
//! Caching is two-level, both stores inside the one [`super::cache::ConfigCache`]:
//! the assembled plan is cached under the same spec/region key the
//! single-tile artifact would use (weighted by tile count for LRU
//! accounting), and each tile is *also* cached individually under
//! [`tile_key`] so tiles warm-start independently — a respecialized plan
//! reuses every tile whose cut DFG is unchanged, and the compile service
//! races each tile's seed portfolio as its own job.

use std::hash::{Hash, Hasher};

use super::cache::CachedConfig;
use crate::dfg::partition::{TileSink, TileSource};

/// One routed tile of an execution plan: the cached artifact plus the
/// typed mapping of its dense local streams onto external streams and
/// spill slots.
#[derive(Clone, Debug)]
pub struct PlanTile {
    pub cached: CachedConfig,
    /// `sources[jj]` feeds the tile's local input stream `jj`.
    pub sources: Vec<TileSource>,
    /// `sinks[jj]` receives the tile's local output stream `jj`.
    pub sinks: Vec<TileSink>,
    /// The tile's own cache key ([`tile_key`]) — its warm-start identity
    /// in the per-tile store and in the compile service.
    pub key: u64,
}

/// A DFG's executable artifact: one or more feed-forward tiles executed
/// in order as a multi-pass schedule over the shard grid.
#[derive(Clone, Debug)]
pub struct ExecutionPlan {
    pub tiles: Vec<PlanTile>,
    /// Host spill buffer slots (each written once by its producer tile,
    /// read only by later tiles).
    pub n_spills: usize,
}

impl ExecutionPlan {
    /// The degenerate plan-of-one: an existing single-tile artifact
    /// viewed as a plan (identity stream mapping, no spills). Used by
    /// tests and the plan comparator; the install path keeps the legacy
    /// single-tile flow.
    pub fn single(cached: CachedConfig, key: u64) -> ExecutionPlan {
        let sources = (0..cached.image.n_inputs).map(TileSource::External).collect();
        let sinks = (0..cached.image.out_sel.len()).map(TileSink::External).collect();
        ExecutionPlan { tiles: vec![PlanTile { cached, sources, sinks, key }], n_spills: 0 }
    }

    /// Checked constructor: `None` when `tiles` is empty, making the
    /// zero-tile plan unrepresentable at the construction sites instead
    /// of panicking later inside the timing comparator
    /// (`plan_invocation_time` dereferences the last tile). All assembly
    /// paths go through this; `single` is non-empty by construction.
    pub fn from_tiles(tiles: Vec<PlanTile>, n_spills: usize) -> Option<ExecutionPlan> {
        if tiles.is_empty() {
            return None;
        }
        Some(ExecutionPlan { tiles, n_spills })
    }

    pub fn n_tiles(&self) -> usize {
        self.tiles.len()
    }

    pub fn is_single(&self) -> bool {
        self.tiles.len() == 1
    }

    /// Configuration words summed over all tiles (every pass reloads the
    /// grid, so the full plan download pays all of them).
    pub fn config_words(&self) -> u64 {
        self.tiles.iter().map(|t| t.cached.config.config_words() as u64).sum()
    }

    /// Cache weight: capacity units the plan occupies in the shared LRU
    /// (one per tile — a 6-tile plan must not squat in a single slot).
    pub fn weight(&self) -> usize {
        self.tiles.len().max(1)
    }
}

/// Per-tile cache key: the plan's key combined with the tile's position
/// and its cut DFG's structural hash. Tiles of the same plan never
/// collide; identical cut DFGs at the same position of the same plan key
/// (e.g. across serve tenants running the same oversized kernel) share
/// an entry and warm-start independently of the other tiles.
pub fn tile_key(plan_key: u64, idx: usize, tile_dfg: u64) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    plan_key.hash(&mut h);
    (idx as u64).hash(&mut h);
    tile_dfg.hash(&mut h);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfe::config::fig2_config;

    fn dummy_cached() -> CachedConfig {
        let config = fig2_config();
        let image = config.to_image().unwrap();
        CachedConfig::new(config, image, "dfe_4x4".into())
    }

    #[test]
    fn single_plan_is_the_identity_mapping() {
        let c = dummy_cached();
        let n_in = c.image.n_inputs;
        let n_out = c.image.out_sel.len();
        let p = ExecutionPlan::single(c, 42);
        assert!(p.is_single());
        assert_eq!(p.n_spills, 0);
        assert_eq!(p.weight(), 1);
        assert_eq!(p.tiles[0].key, 42);
        assert_eq!(
            p.tiles[0].sources,
            (0..n_in).map(TileSource::External).collect::<Vec<_>>()
        );
        assert_eq!(p.tiles[0].sinks, (0..n_out).map(TileSink::External).collect::<Vec<_>>());
        assert_eq!(p.config_words(), p.tiles[0].cached.config.config_words() as u64);
    }

    #[test]
    fn from_tiles_rejects_the_empty_plan() {
        assert!(ExecutionPlan::from_tiles(Vec::new(), 0).is_none());
        let c = dummy_cached();
        let single = ExecutionPlan::single(c, 7);
        let rebuilt = ExecutionPlan::from_tiles(single.tiles.clone(), single.n_spills)
            .expect("non-empty tile list must construct");
        assert_eq!(rebuilt.n_tiles(), 1);
        assert_eq!(rebuilt.tiles[0].key, 7);
    }

    #[test]
    fn tile_keys_are_deterministic_and_positional() {
        assert_eq!(tile_key(7, 0, 99), tile_key(7, 0, 99));
        assert_ne!(tile_key(7, 0, 99), tile_key(7, 1, 99), "position separates tiles");
        assert_ne!(tile_key(7, 0, 99), tile_key(8, 0, 99), "plan identity separates");
        assert_ne!(tile_key(7, 0, 99), tile_key(7, 0, 98), "cut DFG separates");
    }
}

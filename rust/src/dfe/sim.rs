//! Cycle-level functional simulation of a configured DFE.
//!
//! The paper's overlay ([11] Capalija & Abdelrahman, FPL'13) is a *fully
//! pipelined data-flow* fabric: every cell output carries an elastic
//! (valid/ready) register stage, so reconvergent paths of different length
//! self-synchronize through backpressure instead of requiring balanced
//! delays. We model exactly that: every producer (cell output face, FU
//! result, external input head) is a 1-deep token buffer with fork
//! semantics — a token retires only when *all* statically-known consumers
//! have taken it.
//!
//! The simulator serves three roles:
//!   * independent functional ground truth for config → image → PJRT
//!     cross-validation (same values must fall out of all three),
//!   * latency / initiation-interval measurement for the timing model
//!     (Fig 6's "DFE execution time is negligible" claim is checked
//!     against fill latency + II at the modeled Fmax),
//!   * failure injection surface for the test suite.

use std::collections::HashMap;

use super::config::{ConfigError, FuSrc, GridConfig, OutSrc};
use super::grid::{CellCoord, Dir, DIRS};

/// A producer endpoint in the elastic network.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum Producer {
    /// Cell output face (registered).
    Out(CellCoord, Dir),
    /// FU result register of a cell.
    Fu(CellCoord),
    /// Head of external input stream `j`.
    ExtIn(usize),
}

/// A consumer endpoint.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum Consumer {
    /// FU operand `slot` (0 = lhs, 1 = rhs, 2 = sel) of a cell.
    FuOperand(CellCoord, u8),
    /// Pass-through into a cell output face.
    Route(CellCoord, Dir),
    /// External output stream `j`.
    ExtOut(usize),
}

#[derive(Clone, Debug, Default)]
struct TokenBuf {
    val: i32,
    full: bool,
    /// Consumers that already took the current token.
    taken: u64,
}

/// Result of a streaming run.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Output streams, indexed by external output index.
    pub outputs: Vec<Vec<i32>>,
    /// Cycles until the first output token appeared (pipeline fill).
    pub fill_latency: u64,
    /// Total cycles for the whole stream.
    pub cycles: u64,
    /// Steady-state initiation interval estimate (cycles per element).
    pub initiation_interval: f64,
}

impl SimResult {
    /// Per-chunk busy intervals `(start, end)` in cycles of the streamed
    /// batch split into `chunks` back-to-back submissions, derived from
    /// the *measured* fill and initiation interval — the elastic-model
    /// counterpart of `dfe::exec::CompiledFabric::busy_intervals`, feeding
    /// the same overlapped-transport scheduler for configurations that
    /// did not lower.
    pub fn busy_intervals(&self, chunks: usize) -> Vec<(f64, f64)> {
        let lanes = self.outputs.iter().map(Vec::len).max().unwrap_or(0);
        crate::dfe::exec::busy_intervals_model(
            self.fill_latency as f64,
            self.initiation_interval.max(1.0),
            lanes,
            chunks,
        )
    }
}

pub struct CycleSim<'a> {
    cfg: &'a GridConfig,
    producers: Vec<Producer>,
    prod_idx: HashMap<Producer, usize>,
    /// consumers[p] = consumer endpoints fed by producer p.
    consumers: Vec<Vec<Consumer>>,
    /// Which producer feeds each consumer (reverse edge).
    source_of: HashMap<Consumer, usize>,
    bufs: Vec<TokenBuf>,
    /// Operand latches per consumer.
    latches: HashMap<Consumer, Option<i32>>,
}

impl<'a> CycleSim<'a> {
    /// Build the elastic network from a configuration. Fails on undriven
    /// consumers (same legality surface as `GridConfig::to_image`).
    pub fn new(cfg: &'a GridConfig) -> Result<CycleSim<'a>, ConfigError> {
        // Producer of a cell input face, via the shared resolver.
        let driver_of_face = |p: CellCoord, d: Dir| -> Result<Producer, ConfigError> {
            Ok(match cfg.face_driver(p, d)? {
                super::config::FaceDriver::ExtIn(j) => Producer::ExtIn(j),
                super::config::FaceDriver::Out(q, qd) => Producer::Out(q, qd),
            })
        };

        let mut producers = Vec::new();
        let mut prod_idx = HashMap::new();
        let mut intern = |producers: &mut Vec<Producer>,
                          prod_idx: &mut HashMap<Producer, usize>,
                          p: Producer| {
            *prod_idx.entry(p).or_insert_with(|| {
                producers.push(p);
                producers.len() - 1
            })
        };

        let mut edges: Vec<(usize, Consumer)> = Vec::new();

        for p in cfg.grid.iter_coords() {
            let cell = cfg.cell(p);
            // FU operands.
            if let Some(op) = cell.op {
                let operands: [(FuSrc, u8, bool); 3] = [
                    (cell.fu1, 0, true),
                    (cell.fu2, 1, op.uses_rhs()),
                    (cell.fsel, 2, op.uses_sel()),
                ];
                for (src, slot, required) in operands {
                    match src {
                        FuSrc::In(d) => {
                            let prod = driver_of_face(p, d)?;
                            let pi = intern(&mut producers, &mut prod_idx, prod);
                            edges.push((pi, Consumer::FuOperand(p, slot)));
                        }
                        FuSrc::Const(_) => {} // always available
                        FuSrc::None => {
                            if required {
                                return Err(ConfigError::MissingOperand(
                                    p,
                                    ["fu1", "fu2", "sel"][slot as usize],
                                ));
                            }
                        }
                    }
                }
            }
            // Out faces.
            for d in DIRS {
                match cell.out[d.index()] {
                    OutSrc::None => {}
                    OutSrc::Fu => {
                        if cell.op.is_none() {
                            return Err(ConfigError::NoFu(p));
                        }
                        let pi = intern(&mut producers, &mut prod_idx, Producer::Fu(p));
                        edges.push((pi, Consumer::Route(p, d)));
                    }
                    OutSrc::In(d2) => {
                        let prod = driver_of_face(p, d2)?;
                        let pi = intern(&mut producers, &mut prod_idx, prod);
                        edges.push((pi, Consumer::Route(p, d)));
                    }
                }
            }
        }
        // External outputs consume from the tapped border face.
        for io in &cfg.outputs {
            if cfg.cell(io.cell).out[io.dir.index()] == OutSrc::None {
                return Err(ConfigError::UndrivenOutput { cell: io.cell, dir: io.dir });
            }
            let pi = intern(&mut producers, &mut prod_idx, Producer::Out(io.cell, io.dir));
            edges.push((pi, Consumer::ExtOut(io.index)));
        }
        // Register every Out/Fu producer even if created above; make sure
        // all Out faces that exist as producers are interned (they are, via
        // edges), and build consumer lists.
        let mut consumers: Vec<Vec<Consumer>> = vec![Vec::new(); producers.len()];
        let mut source_of = HashMap::new();
        for (pi, c) in edges {
            consumers[pi].push(c);
            source_of.insert(c, pi);
        }
        let latches = source_of
            .keys()
            .filter(|c| !matches!(c, Consumer::ExtOut(_)))
            .map(|&c| (c, None))
            .collect();
        let bufs = vec![TokenBuf::default(); producers.len()];
        Ok(CycleSim { cfg, producers, prod_idx, consumers, source_of, bufs, latches })
    }

    /// Run `n` stream elements through the fabric. `inputs[j]` is the
    /// stream for external input j; every bound input stream must cover
    /// all `n` elements or the run is rejected with
    /// [`ConfigError::StreamTooShort`] (an absent or short stream used to
    /// be silently zero-filled, corrupting outputs).
    pub fn run_stream(&mut self, inputs: &[Vec<i32>], n: usize) -> Result<SimResult, ConfigError> {
        self.cfg.check_streams(inputs, n)?;
        let n_out_streams = self
            .cfg
            .outputs
            .iter()
            .map(|io| io.index + 1)
            .max()
            .unwrap_or(0);
        let mut outputs: Vec<Vec<i32>> = vec![Vec::new(); n_out_streams];
        let mut in_pos: Vec<usize> = vec![0; inputs.len().max(
            self.cfg.inputs.iter().map(|io| io.index + 1).max().unwrap_or(0),
        )];
        for b in &mut self.bufs {
            *b = TokenBuf::default();
        }
        for l in self.latches.values_mut() {
            *l = None;
        }

        let mut cycle: u64 = 0;
        let mut fill_latency: u64 = 0;
        let mut first_out_seen = false;
        // Upper bound: a legal pipeline advances every element within one
        // producer-graph round trip — reconvergent forks with depth
        // imbalance throttle the 1-deep elastic buffers to at worst
        // II ≈ round trip (slack mismatch), never zero progress — so a
        // run exceeding roundtrip cycles per element plus fill slack is a
        // deadlock (illegal config).
        let roundtrip = 2 * self.producers.len() as u64 + 8;
        let budget = 256 + (n as u64 + 4) * roundtrip;

        let done = |outputs: &Vec<Vec<i32>>, cfgo: &GridConfig| {
            cfgo.outputs.iter().all(|io| outputs[io.index].len() >= n)
        };

        while !done(&outputs, self.cfg) {
            if cycle > budget {
                // Deadlock: report as a routing cycle at an arbitrary port.
                let p = self.cfg.grid.coord(0);
                return Err(ConfigError::RoutingCycle(p, Dir::N));
            }
            cycle += 1;
            self.step(inputs, n, &mut in_pos, &mut outputs);
            if !first_out_seen && outputs.iter().any(|o| !o.is_empty()) {
                first_out_seen = true;
                fill_latency = cycle;
            }
        }
        // Initiation interval: steady-state cycles per element. The first
        // element emerges after `fill_latency` cycles; the remaining n-1
        // each cost II cycles, so II = (total - fill) / (n - 1). A
        // feed-forward fabric pipelines to II ≈ 1; reconvergent paths of
        // unequal depth can push it toward 2 through the 1-deep elastic
        // buffers.
        let initiation_interval = if n > 1 {
            (cycle - fill_latency) as f64 / (n as f64 - 1.0)
        } else {
            1.0
        };
        Ok(SimResult { outputs, fill_latency, cycles: cycle, initiation_interval })
    }

    /// One synchronous cycle: transfer tokens to latches, then fire units.
    fn step(
        &mut self,
        inputs: &[Vec<i32>],
        n: usize,
        in_pos: &mut [usize],
        outputs: &mut [Vec<i32>],
    ) {
        // Phase 1: producers offer tokens to consumers.
        for pi in 0..self.producers.len() {
            // External input heads refill lazily.
            if let Producer::ExtIn(j) = self.producers[pi] {
                if !self.bufs[pi].full && in_pos[j] < n {
                    // Streams are length-validated in run_stream, so the
                    // head element always exists.
                    self.bufs[pi].val = inputs[j][in_pos[j]];
                    self.bufs[pi].full = true;
                    self.bufs[pi].taken = 0;
                    in_pos[j] += 1;
                }
            }
            if !self.bufs[pi].full {
                continue;
            }
            let val = self.bufs[pi].val;
            let mut all_taken = true;
            for (ci, cons) in self.consumers[pi].iter().enumerate() {
                let bit = 1u64 << ci;
                if self.bufs[pi].taken & bit != 0 {
                    continue;
                }
                match cons {
                    Consumer::ExtOut(j) => {
                        // External sink always accepts.
                        outputs[*j].push(val);
                        self.bufs[pi].taken |= bit;
                    }
                    c => {
                        let latch = self.latches.get_mut(c).expect("latch exists");
                        if latch.is_none() {
                            *latch = Some(val);
                            self.bufs[pi].taken |= bit;
                        } else {
                            all_taken = false;
                        }
                    }
                }
            }
            if all_taken && self.bufs[pi].taken.count_ones() as usize == self.consumers[pi].len()
            {
                self.bufs[pi].full = false;
                self.bufs[pi].taken = 0;
            }
        }

        // Phase 2: fire FUs and routing stages whose outputs are free.
        for p in self.cfg.grid.iter_coords() {
            let cell = self.cfg.cell(p);
            // FU fire.
            if let Some(op) = cell.op {
                if let Some(&fu_pi) = self.prod_idx.get(&Producer::Fu(p)) {
                    if !self.bufs[fu_pi].full {
                        let operand = |slot: u8, src: FuSrc, used: bool| -> Option<i32> {
                            if !used {
                                return Some(0);
                            }
                            match src {
                                FuSrc::Const(v) => Some(v),
                                FuSrc::In(_) => self
                                    .latches
                                    .get(&Consumer::FuOperand(p, slot))
                                    .copied()
                                    .flatten(),
                                FuSrc::None => Some(0),
                            }
                        };
                        let a = operand(0, cell.fu1, true);
                        let b = operand(1, cell.fu2, op.uses_rhs());
                        let s = operand(2, cell.fsel, op.uses_sel());
                        if let (Some(a), Some(b), Some(s)) = (a, b, s) {
                            self.bufs[fu_pi].val = op.eval(a, b, s);
                            self.bufs[fu_pi].full = true;
                            self.bufs[fu_pi].taken = 0;
                            // Consume operand latches.
                            for (slot, src, used) in [
                                (0u8, cell.fu1, true),
                                (1, cell.fu2, op.uses_rhs()),
                                (2, cell.fsel, op.uses_sel()),
                            ] {
                                if used && matches!(src, FuSrc::In(_)) {
                                    if let Some(l) =
                                        self.latches.get_mut(&Consumer::FuOperand(p, slot))
                                    {
                                        *l = None;
                                    }
                                }
                            }
                        }
                    }
                }
            }
            // Routing stages: move latched value into the out-face buffer.
            for d in DIRS {
                if cell.out[d.index()] == OutSrc::None {
                    continue;
                }
                if let Some(&out_pi) = self.prod_idx.get(&Producer::Out(p, d)) {
                    if self.bufs[out_pi].full {
                        continue;
                    }
                    if let Some(l) = self.latches.get_mut(&Consumer::Route(p, d)) {
                        if let Some(v) = l.take() {
                            self.bufs[out_pi].val = v;
                            self.bufs[out_pi].full = true;
                            self.bufs[out_pi].taken = 0;
                        }
                    }
                }
            }
        }
    }
}

/// Convenience: run `n` elements through the fastest engine for the
/// configuration — the compiled wave executor (`dfe::exec`) when it
/// lowers, this module's elastic `CycleSim` otherwise. Timing fields come
/// from the engine that ran (analytic on the wave path, measured on the
/// cycle path).
pub fn simulate(
    cfg: &GridConfig,
    inputs: &[Vec<i32>],
    n: usize,
) -> Result<SimResult, ConfigError> {
    super::exec::execute(cfg, inputs, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfe::config::fig2_config;

    #[test]
    fn fig2_stream_matches_formula() {
        let cfg = fig2_config();
        let a: Vec<i32> = (0..20).collect();
        let b: Vec<i32> = (0..20).map(|x| 2 * x - 7).collect();
        let res = simulate(&cfg, &[a.clone(), b.clone()], 20).unwrap();
        let want: Vec<i32> = (0..20).map(|i| a[i as usize] + 3 * b[i as usize] + 1).collect();
        assert_eq!(res.outputs[0], want);
        assert!(res.fill_latency >= 3, "needs pipeline fill, got {}", res.fill_latency);
    }

    #[test]
    fn sim_matches_image_semantics() {
        let cfg = fig2_config();
        let img = cfg.to_image().unwrap();
        let a: Vec<i32> = vec![5, -9, 1 << 20, 0];
        let b: Vec<i32> = vec![-1, 7, 3, i32::MAX];
        let res = simulate(&cfg, &[a.clone(), b.clone()], 4).unwrap();
        for i in 0..4 {
            assert_eq!(res.outputs[0][i], img.eval_scalar(&[a[i], b[i]])[0]);
        }
    }

    #[test]
    fn pipelining_achieves_low_ii() {
        // A balanced pipeline should approach II == 1 (one result/cycle,
        // the overlay's headline property).
        let cfg = fig2_config();
        let n = 200;
        let a: Vec<i32> = (0..n as i32).collect();
        let b: Vec<i32> = (0..n as i32).rev().collect();
        let res = simulate(&cfg, &[a, b], n).unwrap();
        assert!(
            res.initiation_interval <= 2.0,
            "II {} too high",
            res.initiation_interval
        );
    }

    #[test]
    fn single_element() {
        let cfg = fig2_config();
        let res = simulate(&cfg, &[vec![4], vec![10]], 1).unwrap();
        assert_eq!(res.outputs[0], vec![4 + 30 + 1]);
    }

    #[test]
    fn deadlocked_config_detected() {
        use crate::dfe::grid::Grid;
        use crate::dfe::config::{GridConfig, IoAssign, OutSrc};
        // Two cells passing a token in a ring with no source: the external
        // output never fires -> budget exceeded -> reported as cycle.
        let grid = Grid::new(1, 2);
        let mut cfg = GridConfig::empty(grid);
        let c0 = CellCoord::new(0, 0);
        let c1 = CellCoord::new(0, 1);
        cfg.cell_mut(c0).out[Dir::E.index()] = OutSrc::In(Dir::E);
        cfg.cell_mut(c1).out[Dir::W.index()] = OutSrc::In(Dir::W);
        cfg.cell_mut(c1).out[Dir::E.index()] = OutSrc::In(Dir::W);
        cfg.outputs.push(IoAssign { cell: c1, dir: Dir::E, index: 0 });
        let r = simulate(&cfg, &[], 1);
        assert!(r.is_err());
    }
}

//! Compiled wave executor: the DFE hot path, lowered once per
//! configuration instead of re-simulated every cycle.
//!
//! [`super::sim::CycleSim`] is the ground-truth elastic-pipeline model —
//! every producer a 1-deep token buffer, every cycle a full sweep over
//! cells with `HashMap` latch lookups. That is O(cells × cycles) with
//! hashing per stream element: exactly the wrong shape for a fabric whose
//! raison d'être is that "optimizations are made at run-time" must cost
//! almost nothing (paper §I; ROADMAP north star "as fast as the hardware
//! allows").
//!
//! [`CompiledFabric`] lowers a validated [`GridConfig`] **once** into a
//! flat, topologically ordered wave schedule:
//!   * every producer endpoint (external input head, FU result register,
//!     cell output face) becomes a dense `usize` — zero HashMaps survive
//!     into the run loop;
//!   * pass-through routes are resolved to aliases at compile time, so the
//!     schedule contains only FU firings over a slot-major SoA buffer;
//!   * elements stream through in chunks of [`CHUNK`] lanes, op-outer /
//!     lane-inner, so the inner loop is branch-light and cache-friendly;
//!   * fill latency and initiation interval are derived *analytically*
//!     from the registered-stage depth of the producer graph (see
//!     [`CompiledFabric::fill_latency`]) instead of observed cycle counts.
//!
//! Only cleanly feed-forward configurations lower. Anything the elastic
//! model would stall on is refused with [`CompileError::NotFeedForward`]
//! and the caller falls back to `CycleSim`, which handles (or deadlock-
//! detects) it: a producer-graph cycle (even a dead routing ring off to
//! the side), a dangling producer nobody consumes, or a configured-but-
//! unread FU operand. [`execute`] packages that fallback; `SimResult`
//! stays the single result type so callers don't change. Differential
//! fuzzing (`tests/exec_fuzz.rs`) holds the two engines bit-identical on
//! every configuration the lowering accepts.

use std::collections::HashMap;

use super::config::{ConfigError, FaceDriver, FuSrc, GridConfig, OutSrc};
use super::grid::{CellCoord, Dir, DIRS};
use super::opcodes::Op;
use super::sim::{CycleSim, SimResult};

/// Lanes per wave: the SoA working set is `n_slots × CHUNK × 4` bytes, so
/// 256 keeps even a fully used 24×18 overlay (~600 slots) inside L2 while
/// amortizing the per-op schedule walk over enough lanes to hide it.
pub const CHUNK: usize = 256;

/// Why a configuration did not lower to a [`CompiledFabric`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CompileError {
    /// Structurally illegal — `CycleSim` rejects it identically, so there
    /// is nothing to fall back to.
    Illegal(ConfigError),
    /// The producer graph has a cycle (or one the lowering cannot rule
    /// out): not wave-schedulable. The caller should fall back to the
    /// elastic cycle-level simulator.
    NotFeedForward { at: CellCoord, dir: Dir },
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CompileError::Illegal(e) => write!(f, "{e}"),
            CompileError::NotFeedForward { at, dir } => {
                write!(f, "producer graph not feed-forward through {at}{dir}")
            }
        }
    }
}

impl std::error::Error for CompileError {}

/// One scheduled FU firing: `slot[dst] = op(slot[a], slot[b], slot[s])`,
/// all operands resolved to dense slot indices at compile time (constants
/// live in pre-filled slots; unused operands read the zero slot).
#[derive(Clone, Copy, Debug)]
pub(crate) struct WaveOp {
    pub(crate) op: Op,
    pub(crate) dst: usize,
    pub(crate) a: usize,
    pub(crate) b: usize,
    pub(crate) s: usize,
}

/// A configuration lowered to a wave schedule. Immutable after
/// compilation; `run_stream`/`run_batch` are `&self`, so one compiled
/// artifact serves any number of invocations (and cache hits skip the
/// lowering entirely — see `dfe::cache::CachedConfig`).
#[derive(Clone, Debug)]
pub struct CompiledFabric {
    /// Value slots: `[0] = zero`, then constants, then one per external
    /// input stream, then one per FU in schedule order. Crate-visible so
    /// the static verifier (`analysis::verifier` pass V3) can re-derive
    /// the schedule independently and diff it against this one.
    pub(crate) n_slots: usize,
    /// Slot pre-image for constants: (slot, value), filled once per wave
    /// buffer and never overwritten.
    pub(crate) consts: Vec<(usize, i32)>,
    /// External input bindings: (slot, stream index).
    pub(crate) ext_ins: Vec<(usize, usize)>,
    /// FU firings in topological order.
    pub(crate) ops: Vec<WaveOp>,
    /// External output taps: (stream index, slot), sorted by stream index.
    pub(crate) outs: Vec<(usize, usize)>,
    /// Dense output stream count (max bound index + 1).
    pub(crate) n_out_streams: usize,
    /// Registered-stage depth of the deepest tapped path (drives the
    /// total-cycles model: the last stream finishes at `drain_depth +
    /// (n - 1)` with II = 1).
    pub(crate) drain_depth: u64,
    /// Number of input streams the fabric reads (max bound index + 1).
    pub n_inputs: usize,
    /// Cycles until the first element emerges, derived analytically as
    /// `1 + min(tap depths)`: each FU result register and each routed
    /// cell output face on the shallowest input→output path costs one
    /// cycle (external input heads cost zero — they refill and offer in
    /// the same phase), plus one cycle for the external sink to consume.
    /// This matches `CycleSim`'s transfer-then-fire cycle structure
    /// exactly: the first wavefront never sees backpressure, so the
    /// measured fill equals the analytic one on every feed-forward
    /// configuration (enforced by `tests/exec_fuzz.rs`).
    pub fill_latency: u64,
    /// Steady-state cycles per element. A feed-forward overlay is fully
    /// pipelined, so the analytic model is II = 1.0 — the paper's headline
    /// property, which the physical overlay ([11] Capalija & Abdelrahman)
    /// reaches through sufficiently deep elastic FIFOs. `CycleSim`'s
    /// conservative 1-deep buffers can throttle reconvergent forks with
    /// depth imbalance (slack mismatch) up to ~one pipeline round trip per
    /// element; the documented tolerance (measured II ∈ [1, drain depth +
    /// slack]) lives in `tests/exec_fuzz.rs`.
    pub initiation_interval: f64,
}

/// Producer endpoints, mirrored from `CycleSim` but compiled away before
/// the run loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum Producer {
    Out(CellCoord, Dir),
    Fu(CellCoord),
    ExtIn(usize),
}

impl CompiledFabric {
    /// Lower `cfg` into a wave schedule. Fails with
    /// [`CompileError::Illegal`] on the same legality surface as
    /// `CycleSim::new` / `GridConfig::to_image` (undriven consumers,
    /// missing operands, untapped outputs), and with
    /// [`CompileError::NotFeedForward`] on anything the elastic model can
    /// still represent but a wave schedule cannot reproduce faithfully: a
    /// producer-graph cycle, a dangling producer nobody consumes, or a
    /// configured-but-unread FU operand (the latter two stall `CycleSim`'s
    /// fork-retire semantics). Callers fall back instead of erroring.
    pub fn compile(cfg: &GridConfig) -> Result<CompiledFabric, CompileError> {
        let ill = CompileError::Illegal;

        // Producer of a cell input face, via the shared resolver
        // (`GridConfig::face_driver`) so the legality surface cannot
        // drift from `CycleSim::new`.
        let driver_of_face = |p: CellCoord, d: Dir| -> Result<Producer, CompileError> {
            Ok(match cfg.face_driver(p, d).map_err(ill)? {
                FaceDriver::ExtIn(j) => Producer::ExtIn(j),
                FaceDriver::Out(q, qd) => Producer::Out(q, qd),
            })
        };

        // ---- 1. intern producers, collect dependency edges ----
        let mut producers: Vec<Producer> = Vec::new();
        let mut prod_idx: HashMap<Producer, usize> = HashMap::new();
        // deps[p] = producers that must fire before p (compile-time only).
        let mut deps: Vec<Vec<usize>> = Vec::new();
        let mut intern = |producers: &mut Vec<Producer>,
                          deps: &mut Vec<Vec<usize>>,
                          prod_idx: &mut HashMap<Producer, usize>,
                          p: Producer| {
            *prod_idx.entry(p).or_insert_with(|| {
                producers.push(p);
                deps.push(Vec::new());
                producers.len() - 1
            })
        };

        // Every producer that exists in the configuration is interned —
        // including ones feeding nothing on the way to an output, and
        // including both halves of a dead routing ring. A cycle anywhere
        // refuses the lowering (NotFeedForward) rather than silently
        // pruning it, so the fallback semantics stay CycleSim's.
        for p in cfg.grid.iter_coords() {
            let cell = cfg.cell(p);
            if let Some(op) = cell.op {
                let fi = intern(&mut producers, &mut deps, &mut prod_idx, Producer::Fu(p));
                let operands: [(FuSrc, bool); 3] = [
                    (cell.fu1, true),
                    (cell.fu2, op.uses_rhs()),
                    (cell.fsel, op.uses_sel()),
                ];
                for (k, (src, required)) in operands.into_iter().enumerate() {
                    match src {
                        FuSrc::In(d) => {
                            // Resolve first so undriven faces error exactly
                            // like CycleSim::new, whether or not the
                            // operand is read.
                            let drv = driver_of_face(p, d)?;
                            if !required {
                                // A configured-but-unread In operand fills
                                // an elastic latch CycleSim never drains —
                                // the upstream producer stalls. Not wave-
                                // schedulable; fall back so both engines
                                // keep identical behavior.
                                return Err(CompileError::NotFeedForward {
                                    at: p,
                                    dir: d,
                                });
                            }
                            let di =
                                intern(&mut producers, &mut deps, &mut prod_idx, drv);
                            deps[fi].push(di);
                        }
                        FuSrc::Const(_) => {}
                        FuSrc::None => {
                            if required {
                                return Err(ill(ConfigError::MissingOperand(
                                    p,
                                    ["fu1", "fu2", "sel"][k],
                                )));
                            }
                        }
                    }
                }
            }
            for d in DIRS {
                match cell.out[d.index()] {
                    OutSrc::None => {}
                    OutSrc::Fu => {
                        if cell.op.is_none() {
                            return Err(ill(ConfigError::NoFu(p)));
                        }
                        let oi = intern(
                            &mut producers,
                            &mut deps,
                            &mut prod_idx,
                            Producer::Out(p, d),
                        );
                        let fi =
                            intern(&mut producers, &mut deps, &mut prod_idx, Producer::Fu(p));
                        deps[oi].push(fi);
                    }
                    OutSrc::In(d2) => {
                        let drv = driver_of_face(p, d2)?;
                        let oi = intern(
                            &mut producers,
                            &mut deps,
                            &mut prod_idx,
                            Producer::Out(p, d),
                        );
                        let di = intern(&mut producers, &mut deps, &mut prod_idx, drv);
                        deps[oi].push(di);
                    }
                }
            }
        }
        // External outputs tap border faces.
        let mut out_taps: Vec<(usize, usize)> = Vec::new(); // (stream j, producer)
        for io in &cfg.outputs {
            if cfg.cell(io.cell).out[io.dir.index()] == OutSrc::None {
                return Err(ill(ConfigError::UndrivenOutput { cell: io.cell, dir: io.dir }));
            }
            let pi = intern(
                &mut producers,
                &mut deps,
                &mut prod_idx,
                Producer::Out(io.cell, io.dir),
            );
            out_taps.push((io.index, pi));
        }

        // ---- 2. Kahn topological order; a leftover node means a cycle ----
        let n = producers.len();
        let mut indeg = vec![0usize; n];
        let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (pi, ds) in deps.iter().enumerate() {
            indeg[pi] = ds.len();
            for &d in ds {
                consumers[d].push(pi);
            }
        }
        let mut stack: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order: Vec<usize> = Vec::with_capacity(n);
        while let Some(i) = stack.pop() {
            order.push(i);
            for &c in &consumers[i] {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    stack.push(c);
                }
            }
        }
        if order.len() != n {
            let offender = (0..n).find(|&i| indeg[i] > 0).unwrap();
            let (at, dir) = match producers[offender] {
                Producer::Out(p, d) => (p, d),
                Producer::Fu(p) => (p, Dir::N),
                Producer::ExtIn(_) => (cfg.grid.coord(0), Dir::N),
            };
            return Err(CompileError::NotFeedForward { at, dir });
        }
        // A producer nobody consumes — no dependent and no external tap
        // (a dangling out face, or an FU whose result no face routes) —
        // stalls the elastic model: CycleSim either never interns it (its
        // route latch fills and is never drained) or never fires it, so
        // the upstream fork deadlocks into the budget. Not wave-
        // schedulable; fall back so both engines keep identical behavior.
        // (ExtIn producers are only interned when consumed, so they never
        // trigger this.)
        let mut tapped = vec![false; n];
        for &(_, pi) in &out_taps {
            tapped[pi] = true;
        }
        for i in 0..n {
            if consumers[i].is_empty() && !tapped[i] {
                match producers[i] {
                    Producer::Out(p, d) => {
                        return Err(CompileError::NotFeedForward { at: p, dir: d })
                    }
                    Producer::Fu(p) => {
                        return Err(CompileError::NotFeedForward { at: p, dir: Dir::N })
                    }
                    Producer::ExtIn(_) => {}
                }
            }
        }

        // ---- 3. analytic pipeline depth over the topological order ----
        // FU result registers and routed out-face registers are one stage
        // each: depth[p] = 1 + max(depth[deps]), constants contributing 0.
        // External input heads are depth 0 — the elastic model refills and
        // offers the head buffer within one phase, so the first operand
        // reaches its latch in the same cycle the stream starts.
        let mut depth = vec![0u64; n];
        for &i in &order {
            depth[i] = match producers[i] {
                Producer::ExtIn(_) => 0,
                _ => 1 + deps[i].iter().map(|&d| depth[d]).max().unwrap_or(0),
            };
        }

        // ---- 4. assign value slots; routes become aliases ----
        // Layout: slot 0 = zero, then interned constants, then external
        // input streams (one slot per bound index), then FU results.
        let mut consts: Vec<(usize, i32)> = Vec::new();
        let mut const_slot_of: HashMap<i32, usize> = HashMap::new();
        let mut next_slot = 1usize; // slot 0 is the zero slot

        let n_inputs = cfg.inputs.iter().map(|io| io.index + 1).max().unwrap_or(0);
        let mut ext_slot = vec![usize::MAX; n_inputs];
        let mut ext_ins: Vec<(usize, usize)> = Vec::new();

        // Constants first so their slots are stable before FU slots.
        for p in cfg.grid.iter_coords() {
            let cell = cfg.cell(p);
            if let Some(op) = cell.op {
                let used = [true, op.uses_rhs(), op.uses_sel()];
                for (k, src) in [cell.fu1, cell.fu2, cell.fsel].into_iter().enumerate() {
                    if let FuSrc::Const(v) = src {
                        if used[k] && v != 0 {
                            const_slot_of.entry(v).or_insert_with(|| {
                                let s = next_slot;
                                next_slot += 1;
                                consts.push((s, v));
                                s
                            });
                        }
                    }
                }
            }
        }
        for (j, slot) in ext_slot.iter_mut().enumerate() {
            if cfg.inputs.iter().any(|io| io.index == j) {
                *slot = next_slot;
                next_slot += 1;
                ext_ins.push((*slot, j));
            }
        }

        // slot_of[producer]: FUs get fresh slots in topo order, routes and
        // input heads alias their source (topo order guarantees the source
        // is resolved first).
        let mut slot_of = vec![usize::MAX; n];
        let mut ops: Vec<WaveOp> = Vec::new();
        for &i in &order {
            match producers[i] {
                Producer::ExtIn(j) => slot_of[i] = ext_slot[j],
                Producer::Out(p, d) => {
                    // Single dependency: FU result or pass-through source.
                    debug_assert_eq!(deps[i].len(), 1, "out face {p}{d} has one driver");
                    slot_of[i] = slot_of[deps[i][0]];
                }
                Producer::Fu(p) => {
                    let cell = cfg.cell(p);
                    let op = cell.op.expect("Fu producer implies an op");
                    let dst = next_slot;
                    next_slot += 1;
                    slot_of[i] = dst;
                    let resolve = |src: FuSrc, used: bool| -> usize {
                        if !used {
                            return 0; // zero slot
                        }
                        match src {
                            FuSrc::Const(0) | FuSrc::None => 0,
                            FuSrc::Const(v) => const_slot_of[&v],
                            FuSrc::In(d) => {
                                // Re-derive the driver; interned above, so
                                // the lookups cannot fail.
                                let drv = match cfg
                                    .face_driver(p, d)
                                    .expect("validated above")
                                {
                                    FaceDriver::ExtIn(j) => Producer::ExtIn(j),
                                    FaceDriver::Out(q, qd) => Producer::Out(q, qd),
                                };
                                slot_of[prod_idx[&drv]]
                            }
                        }
                    };
                    ops.push(WaveOp {
                        op,
                        dst,
                        a: resolve(cell.fu1, true),
                        b: resolve(cell.fu2, op.uses_rhs()),
                        s: resolve(cell.fsel, op.uses_sel()),
                    });
                }
            }
        }

        // ---- 5. output taps + analytic timing ----
        let mut outs: Vec<(usize, usize)> = out_taps
            .iter()
            .map(|&(j, pi)| (j, slot_of[pi]))
            .collect();
        outs.sort_by_key(|&(j, _)| j);
        let n_out_streams = cfg.outputs.iter().map(|io| io.index + 1).max().unwrap_or(0);
        // +1: the external sink consumes the tapped face's buffer one
        // cycle after it fills. Fill tracks the *first* output token
        // (CycleSim's definition), drain the deepest stream.
        let fill_latency =
            1 + out_taps.iter().map(|&(_, pi)| depth[pi]).min().unwrap_or(0);
        let drain_depth =
            1 + out_taps.iter().map(|&(_, pi)| depth[pi]).max().unwrap_or(0);

        Ok(CompiledFabric {
            n_slots: next_slot,
            consts,
            ext_ins,
            ops,
            outs,
            n_out_streams,
            drain_depth,
            n_inputs,
            fill_latency,
            initiation_interval: 1.0,
        })
    }

    /// Number of scheduled FU firings (one per configured op cell).
    pub fn n_ops(&self) -> usize {
        self.ops.len()
    }

    /// Mutation hook for the verifier self-test harness
    /// (`tests/verifier.rs`): swap two firings in the stored schedule so
    /// pass V3 can prove it detects ordering hazards. Never called by
    /// production code.
    #[doc(hidden)]
    pub fn swap_schedule_slots(&mut self, i: usize, j: usize) {
        self.ops.swap(i, j);
    }

    /// Mutation hook for the verifier self-test harness: corrupt the
    /// stored fill latency so pass V3's timing re-derivation has a
    /// documented positive control. Never called by production code.
    #[doc(hidden)]
    pub fn set_fill_latency(&mut self, v: u64) {
        self.fill_latency = v;
    }

    /// Fabric cycles to stream one batch of `lanes` elements: the
    /// transport pipeline's per-batch execution cost.
    pub fn batch_cycles(&self, lanes: usize) -> f64 {
        if lanes == 0 {
            0.0
        } else {
            self.fill_latency as f64 + (lanes as f64 - 1.0) * self.initiation_interval
        }
    }

    /// Per-chunk busy intervals `(start, end)` in cycles for a
    /// `lanes`-element batch submitted as `chunks` back-to-back chunks —
    /// what the overlapped transport schedules uploads/downloads around.
    /// Chunk `c` covering lanes `[a, b)` owns `[a·II, fill + (b-1)·II]`:
    /// contiguous chunks keep the pipeline streaming, so only the first
    /// pays the fill (the analytic mirror of
    /// [`super::sim::SimResult::busy_intervals`]).
    pub fn busy_intervals(&self, lanes: usize, chunks: usize) -> Vec<(f64, f64)> {
        busy_intervals_model(
            self.fill_latency as f64,
            self.initiation_interval,
            lanes,
            chunks,
        )
    }

    /// Stream `n` elements through the compiled schedule. Same contract
    /// and result type as `CycleSim::run_stream`; outputs are bit-identical
    /// on any feed-forward configuration, timing fields are the analytic
    /// model (fill = pipeline depth, II = 1).
    pub fn run_stream(
        &self,
        inputs: &[Vec<i32>],
        n: usize,
    ) -> Result<SimResult, ConfigError> {
        // ext_ins is built in ascending stream-index order, so the shared
        // check reports the same index as `GridConfig::check_streams`.
        super::config::check_streams(self.ext_ins.iter().map(|&(_, j)| j), inputs, n)?;
        let mut outputs: Vec<Vec<i32>> =
            (0..self.n_out_streams).map(|_| Vec::with_capacity(n)).collect();

        let mut buf = vec![0i32; self.n_slots * CHUNK];
        for &(slot, v) in &self.consts {
            buf[slot * CHUNK..(slot + 1) * CHUNK].fill(v);
        }

        let mut at = 0usize;
        while at < n {
            let m = CHUNK.min(n - at);
            for &(slot, j) in &self.ext_ins {
                buf[slot * CHUNK..slot * CHUNK + m]
                    .copy_from_slice(&inputs[j][at..at + m]);
            }
            self.wave(&mut buf, m);
            for &(j, slot) in &self.outs {
                outputs[j].extend_from_slice(&buf[slot * CHUNK..slot * CHUNK + m]);
            }
            at += m;
        }

        // Total cycles: the deepest stream's last element arrives at
        // drain_depth + (n - 1) under the steady-state II of 1.
        let cycles = if n == 0 {
            0
        } else {
            self.drain_depth
                + ((n as f64 - 1.0) * self.initiation_interval).ceil() as u64
        };
        Ok(SimResult {
            outputs,
            fill_latency: self.fill_latency,
            cycles,
            initiation_interval: self.initiation_interval,
        })
    }

    /// Batch entry point in the artifact ABI layout (`x[j * lanes + lane]`
    /// slot-major in, `[n_out, lanes]` slot-major out, rows in bound-output
    /// index order exactly like `ExecImage::out_sel`) — the drop-in
    /// replacement for `ExecImage::eval_batch` on the offload hot path.
    pub fn run_batch(&self, x: &[i32], lanes: usize) -> Vec<i32> {
        debug_assert!(x.len() >= self.n_inputs * lanes);
        let mut out = vec![0i32; self.outs.len() * lanes];
        let mut buf = vec![0i32; self.n_slots * CHUNK];
        for &(slot, v) in &self.consts {
            buf[slot * CHUNK..(slot + 1) * CHUNK].fill(v);
        }
        let mut at = 0usize;
        while at < lanes {
            let m = CHUNK.min(lanes - at);
            for &(slot, j) in &self.ext_ins {
                buf[slot * CHUNK..slot * CHUNK + m]
                    .copy_from_slice(&x[j * lanes + at..j * lanes + at + m]);
            }
            self.wave(&mut buf, m);
            for (row, &(_, slot)) in self.outs.iter().enumerate() {
                out[row * lanes + at..row * lanes + at + m]
                    .copy_from_slice(&buf[slot * CHUNK..slot * CHUNK + m]);
            }
            at += m;
        }
        out
    }

    /// Fire the whole schedule over `m` lanes of the wave buffer. Op-outer,
    /// lane-inner: each firing reads three resolved slot rows and writes
    /// one, so the inner loop is a straight-line arithmetic sweep.
    #[inline]
    fn wave(&self, buf: &mut [i32], m: usize) {
        for w in &self.ops {
            let (a0, b0, s0, d0) = (w.a * CHUNK, w.b * CHUNK, w.s * CHUNK, w.dst * CHUNK);
            let op = w.op;
            for lane in 0..m {
                let r = op.eval(buf[a0 + lane], buf[b0 + lane], buf[s0 + lane]);
                buf[d0 + lane] = r;
            }
        }
    }
}

/// Busy windows for an explicit chunk plan (`(start, len)` slices of a
/// back-to-back streamed batch): chunk over lanes `[a, b)` occupies
/// `[a·ii, fill + (b-1)·ii]` cycles. Only the first chunk pays the fill;
/// window-end deltas are exactly the per-chunk execution costs the
/// transport pipeline's stub charges (`offload::stub`,
/// `offload::invocation_time`), so chunking re-times transfers but never
/// inflates total fabric time.
pub fn busy_windows(fill: f64, ii: f64, plan: &[(usize, usize)]) -> Vec<(f64, f64)> {
    plan.iter()
        .filter(|&&(_, m)| m > 0)
        .map(|&(at, m)| (at as f64 * ii, fill + (at + m - 1) as f64 * ii))
        .collect()
}

/// Shared busy-interval model: even split of `lanes` into `chunks`, then
/// [`busy_windows`].
pub(crate) fn busy_intervals_model(
    fill: f64,
    ii: f64,
    lanes: usize,
    chunks: usize,
) -> Vec<(f64, f64)> {
    if lanes == 0 {
        return Vec::new();
    }
    let chunks = chunks.clamp(1, lanes);
    let chunk = lanes.div_ceil(chunks);
    let mut plan = Vec::with_capacity(chunks);
    let mut at = 0usize;
    while at < lanes {
        let m = chunk.min(lanes - at);
        plan.push((at, m));
        at += m;
    }
    busy_windows(fill, ii, &plan)
}

/// Execute `n` stream elements on the fastest engine that can represent
/// the configuration: the compiled wave executor when the lowering proves
/// the fabric feed-forward (the common case for anything `dfg::extract` +
/// `par::route` emit), the elastic [`CycleSim`] otherwise. Structural
/// illegality errors out of both paths identically.
pub fn execute(
    cfg: &GridConfig,
    inputs: &[Vec<i32>],
    n: usize,
) -> Result<SimResult, ConfigError> {
    match CompiledFabric::compile(cfg) {
        Ok(fabric) => fabric.run_stream(inputs, n),
        Err(CompileError::Illegal(e)) => Err(e),
        Err(CompileError::NotFeedForward { .. }) => {
            CycleSim::new(cfg)?.run_stream(inputs, n)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfe::config::{fig2_config, IoAssign};
    use crate::dfe::grid::Grid;

    #[test]
    fn fig2_wave_matches_formula_and_cyclesim() {
        let cfg = fig2_config();
        let fabric = CompiledFabric::compile(&cfg).expect("fig2 is feed-forward");
        let n = 100;
        let a: Vec<i32> = (0..n as i32).collect();
        let b: Vec<i32> = (0..n as i32).map(|x| 3 * x - 11).collect();
        let res = fabric.run_stream(&[a.clone(), b.clone()], n).unwrap();
        let want: Vec<i32> = (0..n).map(|i| a[i] + 3 * b[i] + 1).collect();
        assert_eq!(res.outputs[0], want);

        let cyc = CycleSim::new(&cfg).unwrap().run_stream(&[a, b], n).unwrap();
        assert_eq!(res.outputs, cyc.outputs, "wave ≡ CycleSim");
        // Analytic fill equals the measured fill on this contention-free
        // pipeline: ExtIn → Fu(0,0) → Out(0,0)S → Fu(1,0) → Out(1,0)E →
        // Fu(1,1) → Out(1,1)E = 7 registered stages.
        assert_eq!(res.fill_latency, 7);
        assert_eq!(cyc.fill_latency, 7, "CycleSim measures the same depth");
        assert_eq!(res.initiation_interval, 1.0);
    }

    #[test]
    fn busy_intervals_tile_the_batch_and_agree_with_cyclesim() {
        let cfg = fig2_config();
        let fabric = CompiledFabric::compile(&cfg).unwrap();
        let iv = fabric.busy_intervals(100, 4);
        assert_eq!(iv.len(), 4);
        assert_eq!(iv[0].0, 0.0, "first chunk starts with the stream");
        assert_eq!(iv[0].1, fabric.fill_latency as f64 + 24.0);
        for w in iv.windows(2) {
            // Back-to-back chunks stream continuously: each starts one II
            // after the previous chunk's last issue slot, overlapping its
            // drain (the window the async transport hides transfers in).
            assert!(w[1].0 < w[0].1, "chunks pipeline, not serialize");
            assert!(w[1].0 > w[0].0 && w[1].1 > w[0].1);
        }
        assert_eq!(iv[3].1, fabric.batch_cycles(100), "last chunk drains the batch");
        // The stub's transport pipeline derives per-chunk fabric costs
        // from these windows via the production chunk plan: the deltas
        // sum to the one-shot batch time (fill paid once).
        let plan = crate::transport::chunk_plan(
            100,
            crate::transport::TransportMode::Async { depth: 2 },
        );
        let w = busy_windows(fabric.fill_latency as f64, fabric.initiation_interval, &plan);
        assert_eq!(w, fabric.busy_intervals(100, plan.len()));
        assert_eq!(w.last().unwrap().1, fabric.batch_cycles(100));
        // The measured elastic model exposes the same interface and, on
        // this contention-free chain (II exactly 1), the same windows.
        let a: Vec<i32> = (0..100).collect();
        let b: Vec<i32> = (0..100).rev().collect();
        let res = CycleSim::new(&cfg).unwrap().run_stream(&[a, b], 100).unwrap();
        assert_eq!(res.initiation_interval, 1.0);
        assert_eq!(res.busy_intervals(4), fabric.busy_intervals(100, 4));
        // Degenerate shapes.
        assert!(fabric.busy_intervals(0, 4).is_empty());
        assert_eq!(fabric.busy_intervals(3, 8).len(), 3);
    }

    #[test]
    fn chunk_boundaries_are_seamless() {
        let cfg = fig2_config();
        let fabric = CompiledFabric::compile(&cfg).unwrap();
        for n in [CHUNK - 1, CHUNK, CHUNK + 1, 3 * CHUNK + 17] {
            let a: Vec<i32> = (0..n as i32).collect();
            let b: Vec<i32> = (0..n as i32).rev().collect();
            let res = fabric.run_stream(&[a.clone(), b.clone()], n).unwrap();
            for i in 0..n {
                assert_eq!(res.outputs[0][i], a[i] + 3 * b[i] + 1, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn run_batch_matches_image_eval_batch() {
        let cfg = fig2_config();
        let fabric = CompiledFabric::compile(&cfg).unwrap();
        let img = cfg.to_image().unwrap();
        let lanes = 300;
        let x: Vec<i32> = (0..2 * lanes as i32).map(|v| v * 7 - 900).collect();
        assert_eq!(fabric.run_batch(&x, lanes), img.eval_batch(&x, lanes));
    }

    #[test]
    fn short_stream_is_an_error_not_zero_fill() {
        let cfg = fig2_config();
        let fabric = CompiledFabric::compile(&cfg).unwrap();
        // Stream 1 too short.
        let r = fabric.run_stream(&[vec![1, 2, 3], vec![4, 5]], 3);
        assert_eq!(
            r.unwrap_err(),
            ConfigError::StreamTooShort { index: 1, need: 3, got: 2 }
        );
        // Stream entirely absent.
        let r = fabric.run_stream(&[vec![1, 2, 3]], 3);
        assert_eq!(
            r.unwrap_err(),
            ConfigError::StreamTooShort { index: 1, need: 3, got: 0 }
        );
    }

    #[test]
    fn zero_elements_is_fine() {
        let cfg = fig2_config();
        let fabric = CompiledFabric::compile(&cfg).unwrap();
        let res = fabric.run_stream(&[vec![], vec![]], 0).unwrap();
        assert!(res.outputs[0].is_empty());
        assert_eq!(res.cycles, 0);
    }

    #[test]
    fn dead_ring_refuses_to_lower_and_execute_falls_back() {
        use crate::dfe::config::OutSrc;
        use crate::dfe::opcodes::Op;
        // A legal feed-forward path (row 0) plus a dead two-cell routing
        // ring (row 1) that never receives a token. CycleSim runs this
        // fine — the ring just never fires — but the lowering cannot wave-
        // schedule it, so it must refuse rather than mis-lower.
        let grid = Grid::new(2, 2);
        let mut cfg = GridConfig::empty(grid);
        let c00 = CellCoord::new(0, 0);
        let c10 = CellCoord::new(1, 0);
        let c11 = CellCoord::new(1, 1);
        {
            let cell = cfg.cell_mut(c00);
            cell.op = Some(Op::Add);
            cell.fu1 = FuSrc::In(Dir::W);
            cell.fu2 = FuSrc::Const(5);
            cell.out[Dir::E.index()] = OutSrc::Fu;
        }
        cfg.inputs.push(IoAssign { cell: c00, dir: Dir::W, index: 0 });
        cfg.outputs.push(IoAssign { cell: CellCoord::new(0, 1), dir: Dir::E, index: 0 });
        cfg.cell_mut(CellCoord::new(0, 1)).out[Dir::E.index()] = OutSrc::In(Dir::W);
        // The ring: (1,0).E ← its own E input ← (1,1).W out ← (1,1)'s W
        // input ← (1,0).E out.
        cfg.cell_mut(c10).out[Dir::E.index()] = OutSrc::In(Dir::E);
        cfg.cell_mut(c11).out[Dir::W.index()] = OutSrc::In(Dir::W);

        assert!(matches!(
            CompiledFabric::compile(&cfg),
            Err(CompileError::NotFeedForward { .. })
        ));
        // execute() falls back to CycleSim and completes.
        let a: Vec<i32> = (0..20).collect();
        let res = execute(&cfg, &[a.clone()], 20).unwrap();
        let cyc = CycleSim::new(&cfg).unwrap().run_stream(&[a], 20).unwrap();
        assert_eq!(res.outputs, cyc.outputs);
        assert_eq!(res.outputs[0], (5..25).collect::<Vec<i32>>());
    }

    #[test]
    fn dangling_fork_falls_back_to_cyclesim() {
        use crate::dfe::config::OutSrc;
        // fig2 plus an extra, never-consumed OutSrc::Fu face on (1,1):
        // CycleSim never interns that face's producer, so its route latch
        // fills once and never drains — the FU's fork stalls and the run
        // deadlocks into the budget. The lowering must refuse so execute()
        // reproduces CycleSim's behavior instead of silently succeeding.
        let mut cfg = fig2_config();
        cfg.cell_mut(CellCoord::new(1, 1)).out[Dir::N.index()] = OutSrc::Fu;
        assert!(matches!(
            CompiledFabric::compile(&cfg),
            Err(CompileError::NotFeedForward { .. })
        ));
        let n = 8;
        let a: Vec<i32> = (0..n as i32).collect();
        let b: Vec<i32> = (0..n as i32).collect();
        let via_exec = execute(&cfg, &[a.clone(), b.clone()], n);
        let via_cyc = CycleSim::new(&cfg).unwrap().run_stream(&[a, b], n);
        assert_eq!(via_exec.unwrap_err(), via_cyc.unwrap_err());
    }

    #[test]
    fn unread_in_operand_falls_back_to_cyclesim() {
        // fig2 with (1,1)'s unused sel mux pointed at a driven face: the
        // elastic model latches the value but never consumes it, stalling
        // the upstream fork. The lowering refuses; both engines then
        // report the same deadlock.
        let mut cfg = fig2_config();
        cfg.cell_mut(CellCoord::new(1, 1)).fsel = FuSrc::In(Dir::W); // Add: sel unread
        assert!(matches!(
            CompiledFabric::compile(&cfg),
            Err(CompileError::NotFeedForward { .. })
        ));
        let n = 8;
        let a: Vec<i32> = (0..n as i32).collect();
        let b: Vec<i32> = (0..n as i32).collect();
        let via_exec = execute(&cfg, &[a.clone(), b.clone()], n);
        let via_cyc = CycleSim::new(&cfg).unwrap().run_stream(&[a, b], n);
        assert_eq!(via_exec.unwrap_err(), via_cyc.unwrap_err());
    }

    #[test]
    fn illegal_config_errors_in_both_paths() {
        let grid = Grid::new(1, 1);
        let mut cfg = GridConfig::empty(grid);
        let p = CellCoord::new(0, 0);
        {
            let cell = cfg.cell_mut(p);
            cell.op = Some(Op::Pass);
            cell.fu1 = FuSrc::In(Dir::W); // undriven
            cell.out[Dir::E.index()] = OutSrc::Fu;
        }
        cfg.outputs.push(IoAssign { cell: p, dir: Dir::E, index: 0 });
        assert!(matches!(
            CompiledFabric::compile(&cfg),
            Err(CompileError::Illegal(ConfigError::UndrivenInput { .. }))
        ));
        assert!(execute(&cfg, &[], 1).is_err());
    }
}

//! The Data Flow Engine (paper §III-A): overlay model, configuration,
//! functional + cycle simulation, the compiled wave executor (the hot
//! path), execution images, configuration cache and the per-device
//! resource model (Table II).

pub mod abi;
pub mod cache;
pub mod config;
pub mod exec;
pub mod grid;
pub mod image;
pub mod lower;
pub mod opcodes;
pub mod persist;
pub mod plan;
pub mod resource;
pub mod sim;

pub use config::{CellConfig, ConfigError, FuSrc, GridConfig, IoAssign, OutSrc};
pub use exec::{execute, CompileError, CompiledFabric};
pub use grid::{CellCoord, Dir, Grid, Port};
pub use lower::{LoweredKernel, Scratch};
pub use image::{ExecImage, ImageBuilder, ImageCell, ImageError};
pub use opcodes::Op;
pub use plan::{tile_key, ExecutionPlan, PlanTile};

//! DFE overlay topology (paper §III-A, Fig 3).
//!
//! A parametric `rows x cols` matrix of cells in a Manhattan topology.
//! Each cell exposes four inputs and four outputs (N/E/S/W); inside the
//! cell a functional unit takes two operands plus a selection input, and
//! each cell output can be driven by any cell input (pass-through routing)
//! or by the FU result — a cell can serve "as an operator, as a routing
//! resource, or both". Border faces are the external I/O interfaces; their
//! count equals the grid perimeter, which is why the placer biases I/O
//! nodes toward the border (§III-B).

use std::fmt;

/// Cardinal direction / cell face.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Dir {
    N = 0,
    E = 1,
    S = 2,
    W = 3,
}

pub const DIRS: [Dir; 4] = [Dir::N, Dir::E, Dir::S, Dir::W];

impl Dir {
    pub fn opposite(self) -> Dir {
        match self {
            Dir::N => Dir::S,
            Dir::E => Dir::W,
            Dir::S => Dir::N,
            Dir::W => Dir::E,
        }
    }

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn from_index(i: usize) -> Dir {
        DIRS[i]
    }
}

impl fmt::Display for Dir {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Dir::N => "N",
            Dir::E => "E",
            Dir::S => "S",
            Dir::W => "W",
        })
    }
}

/// Cell position (row 0 at the top).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CellCoord {
    pub r: usize,
    pub c: usize,
}

impl CellCoord {
    pub fn new(r: usize, c: usize) -> CellCoord {
        CellCoord { r, c }
    }

    /// Manhattan distance.
    pub fn dist(self, other: CellCoord) -> usize {
        self.r.abs_diff(other.r) + self.c.abs_diff(other.c)
    }
}

impl fmt::Display for CellCoord {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "({},{})", self.r, self.c)
    }
}

/// A directed port on the fabric: the input or output face of a cell.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Port {
    In(CellCoord, Dir),
    Out(CellCoord, Dir),
}

/// Grid geometry (no configuration — see [`super::config::GridConfig`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Grid {
    pub rows: usize,
    pub cols: usize,
}

impl Grid {
    pub fn new(rows: usize, cols: usize) -> Grid {
        assert!(rows > 0 && cols > 0, "grid must be non-empty");
        Grid { rows, cols }
    }

    pub fn n_cells(self) -> usize {
        self.rows * self.cols
    }

    pub fn contains(self, p: CellCoord) -> bool {
        p.r < self.rows && p.c < self.cols
    }

    pub fn index(self, p: CellCoord) -> usize {
        debug_assert!(self.contains(p));
        p.r * self.cols + p.c
    }

    pub fn coord(self, idx: usize) -> CellCoord {
        debug_assert!(idx < self.n_cells());
        CellCoord::new(idx / self.cols, idx % self.cols)
    }

    pub fn center(self) -> (f64, f64) {
        ((self.rows as f64 - 1.0) / 2.0, (self.cols as f64 - 1.0) / 2.0)
    }

    /// Neighbor in direction `d`, if in bounds.
    pub fn neighbor(self, p: CellCoord, d: Dir) -> Option<CellCoord> {
        let (r, c) = (p.r as isize, p.c as isize);
        let (nr, nc) = match d {
            Dir::N => (r - 1, c),
            Dir::E => (r, c + 1),
            Dir::S => (r + 1, c),
            Dir::W => (r, c - 1),
        };
        if nr < 0 || nc < 0 {
            return None;
        }
        let q = CellCoord::new(nr as usize, nc as usize);
        if self.contains(q) {
            Some(q)
        } else {
            None
        }
    }

    /// Whether face `(p, d)` is on the border (an external I/O interface).
    pub fn is_border_face(self, p: CellCoord, d: Dir) -> bool {
        self.contains(p) && self.neighbor(p, d).is_none()
    }

    /// All border faces, row-major then by direction — the paper's
    /// perimeter I/O interfaces. Count = 2 * (rows + cols).
    pub fn border_faces(self) -> Vec<(CellCoord, Dir)> {
        let mut v = Vec::with_capacity(2 * (self.rows + self.cols));
        for r in 0..self.rows {
            for c in 0..self.cols {
                let p = CellCoord::new(r, c);
                for d in DIRS {
                    if self.is_border_face(p, d) {
                        v.push((p, d));
                    }
                }
            }
        }
        v
    }

    /// Distance of a cell to the nearest border.
    pub fn border_dist(self, p: CellCoord) -> usize {
        p.r.min(self.rows - 1 - p.r).min(p.c).min(self.cols - 1 - p.c)
    }

    pub fn iter_coords(self) -> impl Iterator<Item = CellCoord> {
        let cols = self.cols;
        (0..self.n_cells()).map(move |i| CellCoord::new(i / cols, i % cols))
    }

    /// Partition the grid into `k` disjoint shard [`Region`]s — contiguous
    /// strips along the longer axis, balanced to within one row/column —
    /// for the multi-tenant offload server. Each region is an independent
    /// place-&-route domain with its own border I/O along the cut (the
    /// overlay instantiates per-region stream interfaces, like the
    /// application-specific multi-region overlays of Mbongue et al.).
    pub fn partition(self, k: usize) -> Result<Vec<Region>, String> {
        if k == 0 {
            return Err("cannot partition a grid into 0 regions".to_string());
        }
        let along_rows = self.rows >= self.cols;
        let span = if along_rows { self.rows } else { self.cols };
        if k > span {
            return Err(format!(
                "{k} regions need {k} strips but a {}x{} grid only has {span} along its longer axis",
                self.rows, self.cols
            ));
        }
        let (base, extra) = (span / k, span % k);
        let mut regions = Vec::with_capacity(k);
        let mut at = 0usize;
        for i in 0..k {
            let len = base + usize::from(i < extra);
            regions.push(if along_rows {
                Region { origin: CellCoord::new(at, 0), grid: Grid::new(len, self.cols) }
            } else {
                Region { origin: CellCoord::new(0, at), grid: Grid::new(self.rows, len) }
            });
            at += len;
        }
        Ok(regions)
    }
}

/// A rectangular sub-region of a device grid: one independently
/// placed-and-routed DFE shard. `grid` holds the region's own dimensions;
/// `origin` anchors it on the full device grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Region {
    pub origin: CellCoord,
    pub grid: Grid,
}

impl Region {
    pub fn n_cells(self) -> usize {
        self.grid.n_cells()
    }

    /// Whether `p` (a coordinate on the *full* grid) lies in this region.
    pub fn contains(self, p: CellCoord) -> bool {
        p.r >= self.origin.r
            && p.r < self.origin.r + self.grid.rows
            && p.c >= self.origin.c
            && p.c < self.origin.c + self.grid.cols
    }

    /// All cells of the region in full-grid coordinates.
    pub fn cells(self) -> impl Iterator<Item = CellCoord> {
        let o = self.origin;
        self.grid.iter_coords().map(move |p| CellCoord::new(o.r + p.r, o.c + p.c))
    }

    /// Whether two regions share any cell.
    pub fn overlaps(self, other: Region) -> bool {
        let r_overlap = self.origin.r < other.origin.r + other.grid.rows
            && other.origin.r < self.origin.r + self.grid.rows;
        let c_overlap = self.origin.c < other.origin.c + other.grid.cols
            && other.origin.c < self.origin.c + self.grid.cols;
        r_overlap && c_overlap
    }
}

impl fmt::Display for Region {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}@{}", self.grid.rows, self.grid.cols, self.origin)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neighbors_and_borders() {
        let g = Grid::new(3, 4);
        assert_eq!(g.n_cells(), 12);
        let p = CellCoord::new(0, 0);
        assert_eq!(g.neighbor(p, Dir::N), None);
        assert_eq!(g.neighbor(p, Dir::W), None);
        assert_eq!(g.neighbor(p, Dir::S), Some(CellCoord::new(1, 0)));
        assert_eq!(g.neighbor(p, Dir::E), Some(CellCoord::new(0, 1)));
        assert!(g.is_border_face(p, Dir::N));
        assert!(!g.is_border_face(p, Dir::E));
    }

    #[test]
    fn perimeter_count() {
        for (r, c) in [(2, 2), (3, 4), (8, 8), (24, 18)] {
            let g = Grid::new(r, c);
            assert_eq!(g.border_faces().len(), 2 * (r + c), "{r}x{c}");
        }
    }

    #[test]
    fn index_roundtrip() {
        let g = Grid::new(5, 7);
        for i in 0..g.n_cells() {
            assert_eq!(g.index(g.coord(i)), i);
        }
    }

    #[test]
    fn border_dist() {
        let g = Grid::new(5, 5);
        assert_eq!(g.border_dist(CellCoord::new(2, 2)), 2);
        assert_eq!(g.border_dist(CellCoord::new(0, 3)), 0);
        assert_eq!(g.border_dist(CellCoord::new(1, 3)), 1);
    }

    #[test]
    fn opposite_involution() {
        for d in DIRS {
            assert_eq!(d.opposite().opposite(), d);
        }
    }

    #[test]
    fn partition_covers_without_overlap() {
        for (r, c, k) in [(8, 8, 2), (8, 8, 4), (12, 12, 3), (3, 9, 4), (5, 4, 5)] {
            let g = Grid::new(r, c);
            let regions = g.partition(k).unwrap_or_else(|e| panic!("{r}x{c}/{k}: {e}"));
            assert_eq!(regions.len(), k);
            let mut seen = std::collections::HashSet::new();
            for region in &regions {
                for cell in region.cells() {
                    assert!(g.contains(cell), "{region} spills off the grid");
                    assert!(seen.insert(cell), "cell {cell} shared between regions");
                }
            }
            assert_eq!(seen.len(), g.n_cells(), "{r}x{c}/{k} partition must cover");
            for i in 0..k {
                for j in i + 1..k {
                    assert!(!regions[i].overlaps(regions[j]));
                }
            }
        }
    }

    #[test]
    fn partition_is_balanced() {
        let g = Grid::new(10, 4);
        let regions = g.partition(4).unwrap();
        let sizes: Vec<usize> = regions.iter().map(|r| r.n_cells()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 40);
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= g.cols);
    }

    #[test]
    fn partition_rejects_degenerate_counts() {
        assert!(Grid::new(4, 4).partition(0).is_err());
        assert!(Grid::new(4, 4).partition(5).is_err());
        assert_eq!(Grid::new(4, 4).partition(1).unwrap()[0].grid, Grid::new(4, 4));
    }

    #[test]
    fn region_contains_matches_cells() {
        let g = Grid::new(6, 5);
        let regions = g.partition(2).unwrap();
        for region in &regions {
            for cell in g.iter_coords() {
                let in_cells = region.cells().any(|p| p == cell);
                assert_eq!(region.contains(cell), in_cells, "{region} {cell}");
            }
        }
    }
}

//! Compile-time ABI shared with the AOT artifacts (python/compile/model.py).
//!
//! Every artifact variant shares the constant-pool / input / output widths
//! and the batch size; only the cell count differs. The plane-slot layout:
//!
//! ```text
//! slot 0                      constant zero
//! slots 1 ..= N_CONSTS        constant pool
//! next N_INPUTS slots         external inputs
//! next n_cells slots          cell results, schedule order
//! ```

/// Constant-pool width (paper: constant-masked inputs, Fig 2 green boxes).
pub const N_CONSTS: usize = 16;
/// Maximum external inputs per configuration.
pub const N_INPUTS: usize = 32;
/// Maximum external outputs per configuration.
pub const N_OUTPUTS: usize = 8;
/// Lanes per PJRT execution (data words per input slot per call).
pub const BATCH: usize = 512;

/// First input slot.
pub const INPUT_BASE: usize = 1 + N_CONSTS;

/// First cell-result slot.
pub const CELL_BASE: usize = 1 + N_CONSTS + N_INPUTS;

/// Plane slot of constant-pool entry `k`.
#[inline]
pub fn const_slot(k: usize) -> usize {
    debug_assert!(k < N_CONSTS);
    1 + k
}

/// Plane slot of external input `j`.
#[inline]
pub fn input_slot(j: usize) -> usize {
    debug_assert!(j < N_INPUTS);
    INPUT_BASE + j
}

/// Plane slot of cell result `i`.
#[inline]
pub fn cell_slot(i: usize) -> usize {
    CELL_BASE + i
}

/// Total plane slots for an image with `n_cells` cells.
#[inline]
pub fn n_slots(n_cells: usize) -> usize {
    CELL_BASE + n_cells
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_is_contiguous() {
        assert_eq!(const_slot(0), 1);
        assert_eq!(const_slot(N_CONSTS - 1) + 1, input_slot(0));
        assert_eq!(input_slot(N_INPUTS - 1) + 1, cell_slot(0));
        assert_eq!(n_slots(10), cell_slot(9) + 1);
    }
}

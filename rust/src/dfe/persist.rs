//! Warm-restart persistence for the configuration cache.
//!
//! Serializes every resident artifact — single-tile entries and tiled
//! execution plans, with their placement/seed provenance — to one
//! human-readable text file under a cache directory, and loads it back
//! into a fresh [`ConfigCache`] on restart (`tlo serve --cache-dir`).
//! A reloaded server's admission lookups all hit, so a restart performs
//! **zero** place & route invocations: the PR-5 warm-start machinery
//! becomes fleet-restart resilience.
//!
//! Design notes:
//! * Hand-rolled line-based format, zero external crates (the repo's
//!   dependency section is deliberately empty). Writers sort by key, so
//!   the file is byte-deterministic for a given cache content.
//! * Only the [`GridConfig`] and provenance are persisted. The execution
//!   image and compiled wave fabric are *rebuilt* on load (`to_image()` +
//!   `CompiledFabric::compile`) — re-lowering is microseconds, carries no
//!   cross-process pointer state, and is not a P&R invocation, so the
//!   zero-recompile guarantee is preserved.
//! * A missing file is a cold start, not an error; a corrupt file is an
//!   `InvalidData` error so a truncated write cannot silently serve a
//!   half-cache.
//! * **Verifier pass V5** (DESIGN.md §11): parsing is not trust. Every
//!   artifact that parses is re-verified (V2–V4 via
//!   `analysis::verifier`) before it enters the cache, so a byte-valid
//!   but semantically corrupt snapshot — a flipped route hop, a
//!   re-pointed spill — is rejected at load instead of served.

// Snapshot loading feeds the serve hot path on restart; a panic here
// takes the fleet node down instead of falling back to a cold start.
#![cfg_attr(not(test), deny(clippy::disallowed_methods))]

use std::fs;
use std::io::{self, ErrorKind};
use std::path::{Path, PathBuf};
use std::time::Duration;

use super::cache::{CachedConfig, ConfigCache};
use super::config::{FuSrc, GridConfig, IoAssign, OutSrc};
use super::grid::{CellCoord, Dir, Grid};
use super::opcodes::Op;
use super::plan::{ExecutionPlan, PlanTile};
use crate::dfg::graph::NodeId;
use crate::dfg::partition::{TileSink, TileSource};
use crate::par::lasvegas::ParStats;

/// File the snapshot lives in, inside the cache directory.
pub const CACHE_FILE: &str = "config-cache.tlo";

const HEADER: &str = "tlo-cache v1";

/// What a successful load brought back.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheSnapshot {
    pub entries: usize,
    pub plans: usize,
}

/// All snapshot rejections — parse failures and semantic re-verification
/// failures alike — carry the V5 banner: from the loader's point of view
/// a truncated section and a corrupted route are the same defect class
/// (the persisted artifact cannot be trusted), and the mutation harness
/// attributes both to pass V5.
fn bad(msg: impl Into<String>) -> io::Error {
    io::Error::new(ErrorKind::InvalidData, format!("V5 snapshot integrity: {}", msg.into()))
}

// ---------------------------------------------------------------------------
// Writing
// ---------------------------------------------------------------------------

fn fu_token(s: FuSrc) -> String {
    match s {
        FuSrc::None => "-".into(),
        FuSrc::In(d) => format!("i{}", d.index()),
        FuSrc::Const(v) => format!("c{v}"),
    }
}

fn out_token(s: OutSrc) -> String {
    match s {
        OutSrc::None => "-".into(),
        OutSrc::In(d) => format!("i{}", d.index()),
        OutSrc::Fu => "f".into(),
    }
}

/// The shared payload of a cached artifact: provenance + configuration +
/// placement. Used verbatim for single-tile entries and per plan tile.
fn write_payload(buf: &mut String, c: &CachedConfig) {
    buf.push_str(&format!("variant {}\n", c.variant));
    buf.push_str(&format!("seed {}\n", c.seed));
    if let Some(s) = c.par_stats {
        buf.push_str(&format!(
            "stats {} {} {} {} {} {} {} {}\n",
            s.placements,
            s.route_calls,
            s.pos_retries,
            s.backtracks,
            s.restarts,
            s.elapsed.as_nanos(),
            s.attempt_elapsed.as_nanos(),
            s.warm_placed,
        ));
    }
    let g = c.config.grid;
    buf.push_str(&format!("grid {} {}\n", g.rows, g.cols));
    for (idx, cell) in c.config.cells.iter().enumerate() {
        if cell.is_empty() {
            continue;
        }
        let op = cell.op.map(|o| o.code().to_string()).unwrap_or_else(|| "-".into());
        buf.push_str(&format!(
            "cell {} {} {} {} {} {} {} {} {}\n",
            idx,
            op,
            fu_token(cell.fu1),
            fu_token(cell.fu2),
            fu_token(cell.fsel),
            out_token(cell.out[0]),
            out_token(cell.out[1]),
            out_token(cell.out[2]),
            out_token(cell.out[3]),
        ));
    }
    for io in &c.config.inputs {
        buf.push_str(&format!("in {} {} {} {}\n", io.cell.r, io.cell.c, io.dir.index(), io.index));
    }
    for io in &c.config.outputs {
        buf.push_str(&format!(
            "out {} {} {} {}\n",
            io.cell.r, io.cell.c, io.dir.index(), io.index
        ));
    }
    for (n, p) in &c.placement {
        buf.push_str(&format!("place {} {} {}\n", n, p.r, p.c));
    }
}

fn stream_token(s: &TileSource) -> String {
    match s {
        TileSource::External(j) => format!("e{j}"),
        TileSource::Spill(k) => format!("s{k}"),
    }
}

fn sink_token(s: &TileSink) -> String {
    match s {
        TileSink::External(j) => format!("e{j}"),
        TileSink::Spill(k) => format!("s{k}"),
    }
}

/// Serialize every resident artifact of `cache` into `dir/CACHE_FILE`.
/// Creates the directory; returns the file path written.
pub fn save_cache(cache: &ConfigCache, dir: &Path) -> io::Result<PathBuf> {
    fs::create_dir_all(dir)?;
    let mut buf = String::from(HEADER);
    buf.push('\n');
    let mut entries: Vec<_> = cache.iter_entries().collect();
    entries.sort_by_key(|(k, _)| *k);
    for (key, c) in entries {
        buf.push_str(&format!("entry {key}\n"));
        write_payload(&mut buf, c);
        buf.push_str("end\n");
    }
    let mut plans: Vec<_> = cache.iter_plans().collect();
    plans.sort_by_key(|(k, _)| *k);
    for (key, plan) in plans {
        buf.push_str(&format!("plan {} {}\n", key, plan.n_spills));
        for t in &plan.tiles {
            buf.push_str(&format!("tile {}\n", t.key));
            write_payload(&mut buf, &t.cached);
            let srcs: Vec<String> = t.sources.iter().map(stream_token).collect();
            buf.push_str(&format!("srcs {}\n", srcs.join(" ")));
            let sinks: Vec<String> = t.sinks.iter().map(sink_token).collect();
            buf.push_str(&format!("sinks {}\n", sinks.join(" ")));
            buf.push_str("endtile\n");
        }
        buf.push_str("endplan\n");
    }
    let path = dir.join(CACHE_FILE);
    fs::write(&path, buf)?;
    Ok(path)
}

// ---------------------------------------------------------------------------
// Reading
// ---------------------------------------------------------------------------

struct Cursor<'a> {
    lines: Vec<&'a str>,
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(text: &'a str) -> Cursor<'a> {
        Cursor { lines: text.lines().collect(), pos: 0 }
    }

    fn peek(&self) -> Option<&'a str> {
        self.lines.get(self.pos).copied()
    }

    fn next(&mut self) -> Option<&'a str> {
        let l = self.peek();
        if l.is_some() {
            self.pos += 1;
        }
        l
    }
}

fn parse_num<T: std::str::FromStr>(tok: Option<&&str>, what: &str) -> io::Result<T> {
    tok.ok_or_else(|| bad(format!("missing {what}")))?
        .parse::<T>()
        .map_err(|_| bad(format!("malformed {what}")))
}

fn parse_dir(i: usize) -> io::Result<Dir> {
    if i < 4 {
        Ok(Dir::from_index(i))
    } else {
        Err(bad(format!("direction index {i} out of range")))
    }
}

fn parse_fu(tok: &str) -> io::Result<FuSrc> {
    if tok == "-" {
        Ok(FuSrc::None)
    } else if let Some(r) = tok.strip_prefix('i') {
        let i: usize = r.parse().map_err(|_| bad(format!("malformed fu mux {tok}")))?;
        Ok(FuSrc::In(parse_dir(i)?))
    } else if let Some(r) = tok.strip_prefix('c') {
        let v: i32 = r.parse().map_err(|_| bad(format!("malformed fu const {tok}")))?;
        Ok(FuSrc::Const(v))
    } else {
        Err(bad(format!("unknown fu source token {tok}")))
    }
}

fn parse_out(tok: &str) -> io::Result<OutSrc> {
    if tok == "-" {
        Ok(OutSrc::None)
    } else if tok == "f" {
        Ok(OutSrc::Fu)
    } else if let Some(r) = tok.strip_prefix('i') {
        let i: usize = r.parse().map_err(|_| bad(format!("malformed out mux {tok}")))?;
        Ok(OutSrc::In(parse_dir(i)?))
    } else {
        Err(bad(format!("unknown out source token {tok}")))
    }
}

#[derive(Default)]
struct Payload {
    variant: String,
    seed: u64,
    stats: Option<ParStats>,
    config: Option<GridConfig>,
    placement: Vec<(NodeId, CellCoord)>,
}

/// Consume payload lines (variant/seed/stats/grid/cell/in/out/place) up to
/// — but not including — the caller's terminator keyword.
fn parse_payload(cur: &mut Cursor<'_>) -> io::Result<Payload> {
    let mut p = Payload::default();
    while let Some(line) = cur.peek() {
        let toks: Vec<&str> = line.split_whitespace().collect();
        match toks.first().copied() {
            Some("variant") => {
                p.variant = toks.get(1).copied().unwrap_or("").to_string();
            }
            Some("seed") => {
                p.seed = parse_num(toks.get(1), "seed")?;
            }
            Some("stats") => {
                let elapsed_ns: u64 = parse_num(toks.get(6), "stats elapsed")?;
                let attempt_ns: u64 = parse_num(toks.get(7), "stats attempt")?;
                p.stats = Some(ParStats {
                    placements: parse_num(toks.get(1), "stats placements")?,
                    route_calls: parse_num(toks.get(2), "stats route_calls")?,
                    pos_retries: parse_num(toks.get(3), "stats pos_retries")?,
                    backtracks: parse_num(toks.get(4), "stats backtracks")?,
                    restarts: parse_num(toks.get(5), "stats restarts")?,
                    elapsed: Duration::from_nanos(elapsed_ns),
                    attempt_elapsed: Duration::from_nanos(attempt_ns),
                    warm_placed: parse_num(toks.get(8), "stats warm_placed")?,
                });
            }
            Some("grid") => {
                let rows: usize = parse_num(toks.get(1), "grid rows")?;
                let cols: usize = parse_num(toks.get(2), "grid cols")?;
                if rows == 0 || cols == 0 {
                    return Err(bad("degenerate grid in cache file"));
                }
                p.config = Some(GridConfig::empty(Grid::new(rows, cols)));
            }
            Some("cell") => {
                let cfg = p.config.as_mut().ok_or_else(|| bad("cell before grid"))?;
                let idx: usize = parse_num(toks.get(1), "cell index")?;
                if idx >= cfg.cells.len() || toks.len() < 10 {
                    return Err(bad(format!("malformed cell line: {line}")));
                }
                let cell = &mut cfg.cells[idx];
                cell.op = match toks[2] {
                    "-" => None,
                    t => {
                        let code: i32 =
                            t.parse().map_err(|_| bad(format!("malformed opcode {t}")))?;
                        Some(
                            Op::from_i32(code)
                                .ok_or_else(|| bad(format!("unknown opcode {code}")))?,
                        )
                    }
                };
                cell.fu1 = parse_fu(toks[3])?;
                cell.fu2 = parse_fu(toks[4])?;
                cell.fsel = parse_fu(toks[5])?;
                for (i, t) in toks[6..10].iter().enumerate() {
                    cell.out[i] = parse_out(t)?;
                }
            }
            Some("in") | Some("out") => {
                let cfg = p.config.as_mut().ok_or_else(|| bad("io before grid"))?;
                let r: usize = parse_num(toks.get(1), "io row")?;
                let c: usize = parse_num(toks.get(2), "io col")?;
                let d: usize = parse_num(toks.get(3), "io dir")?;
                let index: usize = parse_num(toks.get(4), "io stream index")?;
                let io = IoAssign { cell: CellCoord::new(r, c), dir: parse_dir(d)?, index };
                if toks[0] == "in" {
                    cfg.inputs.push(io);
                } else {
                    cfg.outputs.push(io);
                }
            }
            Some("place") => {
                let n: NodeId = parse_num(toks.get(1), "placement node")?;
                let r: usize = parse_num(toks.get(2), "placement row")?;
                let c: usize = parse_num(toks.get(3), "placement col")?;
                p.placement.push((n, CellCoord::new(r, c)));
            }
            _ => break,
        }
        cur.next();
    }
    Ok(p)
}

/// Rebuild a full [`CachedConfig`] from a parsed payload: re-lower the
/// execution image and wave fabric from the persisted configuration.
fn build_entry(p: Payload) -> io::Result<CachedConfig> {
    let config = p.config.ok_or_else(|| bad("artifact payload missing its grid"))?;
    let image = config
        .to_image()
        .map_err(|e| bad(format!("persisted configuration fails to lower: {e}")))?;
    let mut c = CachedConfig::new(config, image, p.variant);
    c.seed = p.seed;
    c.par_stats = p.stats;
    c.placement = p.placement;
    Ok(c)
}

fn expect(cur: &mut Cursor<'_>, keyword: &str) -> io::Result<()> {
    match cur.next() {
        Some(l) if l.trim() == keyword => Ok(()),
        other => Err(bad(format!("expected {keyword:?}, found {other:?}"))),
    }
}

fn parse_stream_line<'a>(cur: &mut Cursor<'a>, keyword: &str) -> io::Result<Vec<&'a str>> {
    let line = cur.next().ok_or_else(|| bad(format!("missing {keyword} line")))?;
    let toks: Vec<&str> = line.split_whitespace().collect();
    if toks.first().copied() != Some(keyword) {
        return Err(bad(format!("expected {keyword} line, found {line:?}")));
    }
    Ok(toks[1..].to_vec())
}

fn parse_source(tok: &str) -> io::Result<TileSource> {
    if let Some(r) = tok.strip_prefix('e') {
        Ok(TileSource::External(r.parse().map_err(|_| bad("malformed source"))?))
    } else if let Some(r) = tok.strip_prefix('s') {
        Ok(TileSource::Spill(r.parse().map_err(|_| bad("malformed source"))?))
    } else {
        Err(bad(format!("unknown tile source {tok}")))
    }
}

fn parse_sink(tok: &str) -> io::Result<TileSink> {
    if let Some(r) = tok.strip_prefix('e') {
        Ok(TileSink::External(r.parse().map_err(|_| bad("malformed sink"))?))
    } else if let Some(r) = tok.strip_prefix('s') {
        Ok(TileSink::Spill(r.parse().map_err(|_| bad("malformed sink"))?))
    } else {
        Err(bad(format!("unknown tile sink {tok}")))
    }
}

/// Load a persisted snapshot from `dir` into `cache`. `Ok(None)` when no
/// snapshot exists (cold start); `Err` on a corrupt file. Artifacts are
/// inserted in ascending key order, so the LRU stamps of a fresh load are
/// deterministic.
pub fn load_cache(cache: &mut ConfigCache, dir: &Path) -> io::Result<Option<CacheSnapshot>> {
    let path = dir.join(CACHE_FILE);
    let text = match fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e),
    };
    let mut cur = Cursor::new(&text);
    match cur.next() {
        Some(h) if h.trim() == HEADER => {}
        other => return Err(bad(format!("bad cache header: {other:?}"))),
    }
    let mut snap = CacheSnapshot::default();
    while let Some(line) = cur.next() {
        let toks: Vec<&str> = line.split_whitespace().collect();
        match toks.first().copied() {
            None => continue,
            Some("entry") => {
                let key: u64 = parse_num(toks.get(1), "entry key")?;
                let payload = parse_payload(&mut cur)?;
                expect(&mut cur, "end")?;
                let entry = build_entry(payload)?;
                // V5: the snapshot parsed, but parsing is not trust —
                // re-prove V2/V3 before the artifact can be served.
                let diags = crate::analysis::verifier::verify_artifact(&entry);
                crate::analysis::verifier::snapshot_gate("entry", key, &diags)
                    .map_err(|m| io::Error::new(ErrorKind::InvalidData, m))?;
                cache.insert(key, entry);
                snap.entries += 1;
            }
            Some("plan") => {
                let key: u64 = parse_num(toks.get(1), "plan key")?;
                let n_spills: usize = parse_num(toks.get(2), "plan spills")?;
                let mut tiles = Vec::new();
                loop {
                    let line = cur.next().ok_or_else(|| bad("unterminated plan block"))?;
                    let t: Vec<&str> = line.split_whitespace().collect();
                    match t.first().copied() {
                        Some("tile") => {
                            let tile_key: u64 = parse_num(t.get(1), "tile key")?;
                            let payload = parse_payload(&mut cur)?;
                            let sources = parse_stream_line(&mut cur, "srcs")?
                                .iter()
                                .map(|t| parse_source(t))
                                .collect::<io::Result<Vec<_>>>()?;
                            let sinks = parse_stream_line(&mut cur, "sinks")?
                                .iter()
                                .map(|t| parse_sink(t))
                                .collect::<io::Result<Vec<_>>>()?;
                            expect(&mut cur, "endtile")?;
                            tiles.push(PlanTile {
                                cached: build_entry(payload)?,
                                sources,
                                sinks,
                                key: tile_key,
                            });
                        }
                        Some("endplan") => break,
                        _ => return Err(bad(format!("unexpected plan line: {line}"))),
                    }
                }
                let plan = ExecutionPlan::from_tiles(tiles, n_spills)
                    .ok_or_else(|| bad("persisted plan has no tiles"))?;
                // V5: re-prove plan soundness (V4, plus per-tile V2/V3)
                // before the plan can be served.
                let diags = crate::analysis::verifier::verify_plan(&plan);
                crate::analysis::verifier::snapshot_gate("plan", key, &diags)
                    .map_err(|m| io::Error::new(ErrorKind::InvalidData, m))?;
                cache.insert_plan(key, plan);
                snap.plans += 1;
            }
            Some(_) => return Err(bad(format!("unexpected line in cache file: {line}"))),
        }
    }
    Ok(Some(snap))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfe::config::fig2_config;

    fn scratch_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tlo-persist-{}-{}", tag, std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn provenance_entry(seed: u64) -> CachedConfig {
        let config = fig2_config();
        let image = config.to_image().unwrap();
        let stats = ParStats {
            placements: 12,
            route_calls: 34,
            restarts: 1,
            elapsed: Duration::from_micros(5),
            attempt_elapsed: Duration::from_micros(3),
            warm_placed: 2,
            ..Default::default()
        };
        let placement = vec![(2, CellCoord::new(0, 0)), (4, CellCoord::new(1, 1))];
        CachedConfig::with_provenance(config, image, "dfe_2x2".into(), seed, stats, placement)
    }

    #[test]
    fn entries_round_trip_with_provenance() {
        let dir = scratch_dir("entries");
        let mut cache = ConfigCache::new(8);
        cache.insert(0xA1, provenance_entry(7));
        cache.insert(0xB2, provenance_entry(9));
        save_cache(&cache, &dir).unwrap();
        let mut back = ConfigCache::new(8);
        let snap = load_cache(&mut back, &dir).unwrap().expect("snapshot exists");
        assert_eq!(snap, CacheSnapshot { entries: 2, plans: 0 });
        for key in [0xA1u64, 0xB2] {
            let orig = cache.peek(key).unwrap();
            let got = back.peek(key).unwrap();
            assert_eq!(got.config, orig.config, "configuration must survive the disk");
            assert_eq!(got.seed, orig.seed);
            assert_eq!(got.variant, orig.variant);
            assert_eq!(got.placement, orig.placement);
            let (a, b) = (got.par_stats.unwrap(), orig.par_stats.unwrap());
            assert_eq!(a.route_calls, b.route_calls);
            assert_eq!(a.elapsed, b.elapsed);
            assert!(got.fabric.is_some(), "fabric re-lowers on load");
            // The rebuilt image computes the same function.
            assert_eq!(got.image.eval_scalar(&[10, 5]), orig.image.eval_scalar(&[10, 5]));
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn plans_round_trip_and_missing_dir_is_cold_start() {
        let dir = scratch_dir("plans");
        let mut cache = ConfigCache::new(8);
        let mut plan = ExecutionPlan::single(provenance_entry(3), 77);
        plan.tiles[0].sinks = vec![TileSink::Spill(0)];
        let mut second = plan.tiles[0].clone();
        second.key = 78;
        second.sources = vec![TileSource::Spill(0), TileSource::External(1)];
        second.sinks = vec![TileSink::External(0)];
        plan.tiles.push(second);
        plan.n_spills = 1;
        cache.insert_plan(0xC3, plan);
        save_cache(&cache, &dir).unwrap();
        let mut back = ConfigCache::new(8);
        let snap = load_cache(&mut back, &dir).unwrap().unwrap();
        assert_eq!(snap, CacheSnapshot { entries: 0, plans: 1 });
        let got = back.peek_plan(0xC3).unwrap();
        let orig = cache.peek_plan(0xC3).unwrap();
        assert_eq!(got.n_spills, 1);
        assert_eq!(got.tiles.len(), 2);
        for (g, o) in got.tiles.iter().zip(&orig.tiles) {
            assert_eq!(g.key, o.key);
            assert_eq!(g.sources, o.sources);
            assert_eq!(g.sinks, o.sinks);
            assert_eq!(g.cached.config, o.cached.config);
        }
        // No snapshot at all is a cold start, not an error.
        let empty = scratch_dir("cold");
        let mut fresh = ConfigCache::new(4);
        assert!(load_cache(&mut fresh, &empty).unwrap().is_none());
        assert!(fresh.is_empty());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_snapshots_error_instead_of_half_loading() {
        let dir = scratch_dir("corrupt");
        fs::create_dir_all(&dir).unwrap();
        fs::write(dir.join(CACHE_FILE), "not a cache\n").unwrap();
        let mut c = ConfigCache::new(4);
        assert!(load_cache(&mut c, &dir).is_err(), "bad header must refuse");
        fs::write(dir.join(CACHE_FILE), format!("{HEADER}\nentry 5\ngrid 2 2\n")).unwrap();
        let err = load_cache(&mut c, &dir).expect_err("unterminated entry must refuse");
        assert!(err.to_string().contains("V5"), "truncation attributes to V5: {err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn semantically_corrupt_snapshot_is_rejected_not_served() {
        // Regression (ISSUE 9): the load path used to trust anything that
        // parsed. This snapshot stays byte-valid — every line parses and
        // every tile still lowers — but a flipped sink token re-points
        // tile 0's spill at the external output, so the plan writes
        // external stream 0 twice and never feeds tile 1. V5 must reject
        // it with the underlying V4 diagnostic instead of serving it.
        let dir = scratch_dir("semantic");
        let mut cache = ConfigCache::new(8);
        let mut plan = ExecutionPlan::single(provenance_entry(3), 77);
        plan.tiles[0].sinks = vec![TileSink::Spill(0)];
        let mut second = plan.tiles[0].clone();
        second.key = 78;
        second.sources = vec![TileSource::Spill(0), TileSource::External(1)];
        second.sinks = vec![TileSink::External(0)];
        plan.tiles.push(second);
        plan.n_spills = 1;
        cache.insert_plan(0xC3, plan);
        let path = save_cache(&cache, &dir).unwrap();

        let text = fs::read_to_string(&path).unwrap();
        assert!(text.contains("sinks s0"), "fixture writes the spill sink");
        fs::write(&path, text.replace("sinks s0", "sinks e0")).unwrap();

        let mut back = ConfigCache::new(8);
        let err = load_cache(&mut back, &dir).expect_err("corrupt plan must refuse");
        assert_eq!(err.kind(), ErrorKind::InvalidData);
        let msg = err.to_string();
        assert!(
            msg.contains("V5") && msg.contains("V4"),
            "gate banner plus the root-cause pass: {msg}"
        );
        assert!(back.is_empty(), "nothing from the corrupt snapshot may be served");
        let _ = fs::remove_dir_all(&dir);
    }
}

//! Per-device resource & Fmax model, calibrated on the paper's Table II.
//!
//! The original numbers come from vendor synthesis (ISE/Vivado/Quartus) we
//! cannot run; Table II itself provides enough anchor points to fit a
//! linear per-cell cost model (resources scale with cell count — each cell
//! instantiates one FU + routing muxes — plus a fixed I/O/control base)
//! and a piecewise-linear Fmax degradation curve. Device capacities are
//! recovered from the paper's own utilization percentages.
//!
//! Routability follows the paper's observation that "routing our DFE is
//! particularly critical once the size of the system exceeds ~80% of the
//! available logic": per-toolchain LUT-utilization ceilings reproduce each
//! device's largest routed DFE exactly (ISE 80%, Vivado 88%, Quartus 80%).

use std::fmt;

/// One Table II anchor row.
#[derive(Clone, Copy, Debug)]
pub struct Anchor {
    pub rows: usize,
    pub cols: usize,
    pub fmax_mhz: f64,
    pub ff: u64,
    pub luts: u64,
    pub dsp: u64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Toolchain {
    Ise,
    Vivado,
    Quartus,
}

impl Toolchain {
    /// LUT-utilization ceiling above which routing fails (see module doc).
    pub fn route_ceiling_pct(self) -> f64 {
        match self {
            Toolchain::Ise => 80.0,
            Toolchain::Vivado => 88.0,
            Toolchain::Quartus => 80.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Toolchain::Ise => "ISE 14.7",
            Toolchain::Vivado => "Vivado 2015.2.1",
            Toolchain::Quartus => "Quartus II 13.1",
        }
    }
}

/// An FPGA device with Table II anchors.
#[derive(Clone, Debug)]
pub struct Device {
    pub name: &'static str,
    pub part: &'static str,
    pub tool: Toolchain,
    /// Device capacity (FF, LUT-equivalent, DSP blocks) recovered from the
    /// paper's utilization percentages.
    pub cap_ff: u64,
    pub cap_luts: u64,
    pub cap_dsp: u64,
    /// Names of the three resource columns for this vendor.
    pub col_names: [&'static str; 3],
    pub anchors: Vec<Anchor>,
}

/// Resource estimate for a DFE size on a device.
#[derive(Clone, Copy, Debug)]
pub struct Estimate {
    pub rows: usize,
    pub cols: usize,
    pub fmax_mhz: f64,
    pub ff: u64,
    pub luts: u64,
    pub dsp: u64,
    pub ff_pct: f64,
    pub lut_pct: f64,
    pub dsp_pct: f64,
    pub routable: bool,
}

impl fmt::Display for Estimate {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}x{}: {:.0} MHz, FF {} ({:.1}%), LUT {} ({:.1}%), DSP {} ({:.1}%){}",
            self.rows,
            self.cols,
            self.fmax_mhz,
            self.ff,
            self.ff_pct,
            self.luts,
            self.lut_pct,
            self.dsp,
            self.dsp_pct,
            if self.routable { "" } else { "  [UNROUTABLE]" }
        )
    }
}

fn a(rows: usize, cols: usize, fmax: f64, ff: u64, luts: u64, dsp: u64) -> Anchor {
    Anchor { rows, cols, fmax_mhz: fmax, ff, luts, dsp }
}

/// The five Table II devices.
pub fn devices() -> Vec<Device> {
    vec![
        Device {
            name: "Spartan 6",
            part: "xc6slx150t-3fgg900",
            tool: Toolchain::Ise,
            // 11521 FF = 6.3%, 10968 LUT = 11.9%, 9 DSP = 5.0%
            cap_ff: 184_304,
            cap_luts: 92_152,
            cap_dsp: 180,
            col_names: ["Slice Reg (FF)", "LUTs", "DSP48"],
            anchors: vec![
                a(3, 3, 140.0, 11_521, 10_968, 9),
                a(6, 6, 85.0, 38_340, 36_505, 36),
                a(8, 8, 68.0, 65_547, 62_451, 64),
            ],
        },
        Device {
            name: "Virtex 7",
            part: "xc7vx690t-3ffg1761",
            tool: Toolchain::Vivado,
            cap_ff: 866_400,
            cap_luts: 433_200,
            cap_dsp: 3_600,
            col_names: ["Slice Reg (FF)", "LUTs", "DSP48"],
            anchors: vec![
                a(3, 3, 240.0, 11_639, 9_916, 9),
                a(9, 9, 192.0, 83_022, 70_547, 81),
                a(15, 15, 192.0, 222_298, 187_764, 225),
                a(24, 18, 155.0, 420_981, 353_057, 432),
            ],
        },
        Device {
            name: "Virtex 7 (VC707)",
            part: "xc7vx485t-2ffg1761",
            tool: Toolchain::Vivado,
            cap_ff: 607_200,
            cap_luts: 303_600,
            cap_dsp: 2_800,
            col_names: ["Slice Reg (FF)", "LUTs", "DSP48"],
            anchors: vec![
                // Only the 18x18 row appears in the paper; borrow the
                // 690t per-cell slopes (same family/tool) anchored here.
                a(3, 3, 215.0, 11_639, 9_916, 9),
                a(18, 18, 167.0, 317_517, 265_641, 324),
            ],
        },
        Device {
            name: "Cyclone IV",
            part: "EP4CGX150DF31I7AD",
            tool: Toolchain::Quartus,
            cap_ff: 152_960,
            cap_luts: 149_760,
            cap_dsp: 720,
            col_names: ["Registers", "LEs", "MULT9x9"],
            anchors: vec![
                a(3, 3, 120.0, 7_495, 12_496, 18),
                a(6, 6, 115.0, 24_740, 43_988, 72),
                a(9, 9, 106.0, 52_982, 95_670, 162),
                a(10, 10, 105.0, 64_839, 117_634, 200),
            ],
        },
        Device {
            name: "Stratix V",
            part: "5SGSED8N2F45I2L",
            tool: Toolchain::Quartus,
            cap_ff: 524_800,
            cap_luts: 262_400,
            cap_dsp: 1_963,
            col_names: ["Registers", "ALMs", "DSP Block"],
            anchors: vec![
                a(3, 3, 250.0, 7_857, 6_412, 9),
                a(9, 9, 232.0, 56_295, 45_992, 81),
                a(15, 15, 220.0, 150_292, 122_805, 225),
                a(24, 18, 185.0, 282_304, 209_227, 432),
            ],
        },
    ]
}

pub fn device_by_name(name: &str) -> Option<Device> {
    devices().into_iter().find(|d| d.name.eq_ignore_ascii_case(name) || d.part == name)
}

impl Device {
    /// Per-cell DSP cost (exact in Table II: 1/cell Xilinx & Stratix,
    /// 2/cell Cyclone's 9-bit multipliers).
    fn dsp_per_cell(&self) -> f64 {
        let last = self.anchors.last().unwrap();
        last.dsp as f64 / (last.rows * last.cols) as f64
    }

    /// Linear fit `base + slope * n_cells` through first & last anchor.
    fn linfit(&self, pick: impl Fn(&Anchor) -> u64) -> (f64, f64) {
        let f = &self.anchors[0];
        let l = self.anchors.last().unwrap();
        let (n0, n1) = ((f.rows * f.cols) as f64, (l.rows * l.cols) as f64);
        let (y0, y1) = (pick(f) as f64, pick(l) as f64);
        if (n1 - n0).abs() < f64::EPSILON {
            return (0.0, y0 / n0);
        }
        let slope = (y1 - y0) / (n1 - n0);
        (y0 - slope * n0, slope)
    }

    /// Piecewise-linear Fmax over cell count; clamped extrapolation.
    fn fmax(&self, n_cells: f64) -> f64 {
        let pts: Vec<(f64, f64)> = self
            .anchors
            .iter()
            .map(|an| ((an.rows * an.cols) as f64, an.fmax_mhz))
            .collect();
        if n_cells <= pts[0].0 {
            return pts[0].1;
        }
        for w in pts.windows(2) {
            let ((x0, y0), (x1, y1)) = (w[0], w[1]);
            if n_cells <= x1 {
                return y0 + (y1 - y0) * (n_cells - x0) / (x1 - x0);
            }
        }
        // Extrapolate the last segment, floored at 40% of the last anchor.
        let ((x0, y0), (x1, y1)) = (pts[pts.len() - 2], pts[pts.len() - 1]);
        let v = y0 + (y1 - y0) * (n_cells - x0) / (x1 - x0);
        v.max(0.4 * y1)
    }

    /// Estimate resources/Fmax/routability for a `rows x cols` DFE.
    pub fn estimate(&self, rows: usize, cols: usize) -> Estimate {
        let n = (rows * cols) as f64;
        // If the exact size is an anchor, report the paper's own numbers.
        if let Some(an) = self.anchors.iter().find(|a| a.rows == rows && a.cols == cols) {
            return self.finish(rows, cols, an.fmax_mhz, an.ff as f64, an.luts as f64, an.dsp as f64);
        }
        let (ffb, ffs) = self.linfit(|a| a.ff);
        let (lb, ls) = self.linfit(|a| a.luts);
        let ff = ffb + ffs * n;
        let luts = lb + ls * n;
        let dsp = self.dsp_per_cell() * n;
        self.finish(rows, cols, self.fmax(n), ff, luts, dsp)
    }

    fn finish(&self, rows: usize, cols: usize, fmax: f64, ff: f64, luts: f64, dsp: f64) -> Estimate {
        let ff_pct = 100.0 * ff / self.cap_ff as f64;
        let lut_pct = 100.0 * luts / self.cap_luts as f64;
        let dsp_pct = 100.0 * dsp / self.cap_dsp as f64;
        Estimate {
            rows,
            cols,
            fmax_mhz: fmax,
            ff: ff.round() as u64,
            luts: luts.round() as u64,
            dsp: dsp.round() as u64,
            ff_pct,
            lut_pct,
            dsp_pct,
            routable: lut_pct <= self.tool.route_ceiling_pct()
                && ff_pct <= 100.0
                && dsp_pct <= 100.0,
        }
    }

    /// Largest square-ish DFE this device can route. Aspect ratio is
    /// bounded at 4:3 (the paper's widest reported shape is 24x18): long
    /// thin grids would technically fit more cells but starve the router
    /// of border I/O along one axis.
    pub fn largest_routable(&self) -> (usize, usize) {
        let mut best = (0, 0);
        for r in 1..=32usize {
            for c in 1..=32usize {
                if 3 * r.max(c) > 4 * r.min(c) {
                    continue;
                }
                if self.estimate(r, c).routable && r * c > best.0 * best.1 {
                    best = (r, c);
                }
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_reproduce_paper_rows() {
        for d in devices() {
            for an in &d.anchors {
                let e = d.estimate(an.rows, an.cols);
                assert_eq!(e.ff, an.ff, "{} {}x{}", d.name, an.rows, an.cols);
                assert_eq!(e.luts, an.luts);
                assert_eq!(e.dsp, an.dsp);
                assert!((e.fmax_mhz - an.fmax_mhz).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn utilization_pcts_match_paper() {
        // Spot-check the percentages the paper prints.
        let s6 = device_by_name("Spartan 6").unwrap();
        let e = s6.estimate(8, 8);
        assert!((e.ff_pct - 35.6).abs() < 0.3, "{}", e.ff_pct);
        assert!((e.lut_pct - 67.8).abs() < 0.3, "{}", e.lut_pct);
        let v7 = device_by_name("Virtex 7").unwrap();
        let e = v7.estimate(24, 18);
        assert!((e.lut_pct - 81.5).abs() < 0.3, "{}", e.lut_pct);
    }

    #[test]
    fn largest_routable_matches_paper_maxima() {
        // The paper's per-device largest routed DFEs.
        let cases = [
            ("Spartan 6", 64),          // 8x8
            ("Virtex 7", 432),          // 24x18
            ("Virtex 7 (VC707)", 324),  // 18x18
            ("Cyclone IV", 100),        // 10x10
            ("Stratix V", 432),         // 24x18
        ];
        for (name, cells) in cases {
            let d = device_by_name(name).unwrap();
            // The paper's largest reported size must be routable...
            let last = d.anchors.last().unwrap();
            assert!(
                d.estimate(last.rows, last.cols).routable,
                "{name} largest anchor unroutable"
            );
            // ...and one grid step further must not be.
            let (r, c) = (last.rows, last.cols);
            let bigger = d.estimate(r + 1, c + 1);
            assert!(!bigger.routable, "{name} {}x{} should not route", r + 1, c + 1);
            assert_eq!(last.rows * last.cols, cells, "{name} anchor mismatch");
        }
    }

    #[test]
    fn interpolated_sizes_monotone() {
        let v7 = device_by_name("Virtex 7").unwrap();
        let mut prev = 0u64;
        for s in 3..=24 {
            let e = v7.estimate(s, s.min(18));
            assert!(e.luts >= prev, "LUTs not monotone at {s}");
            prev = e.luts;
        }
    }

    #[test]
    fn fmax_degrades_with_size() {
        for d in devices() {
            let small = d.estimate(3, 3).fmax_mhz;
            let last = d.anchors.last().unwrap();
            let big = d.estimate(last.rows, last.cols).fmax_mhz;
            assert!(big <= small, "{}: {big} > {small}", d.name);
        }
    }

    #[test]
    fn dsp_per_cell_exact() {
        assert_eq!(device_by_name("Cyclone IV").unwrap().estimate(5, 5).dsp, 50);
        assert_eq!(device_by_name("Stratix V").unwrap().estimate(5, 5).dsp, 25);
    }
}

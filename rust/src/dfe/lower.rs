//! Lowered batch kernels: the wave schedule specialized into vectorized
//! straight-line code.
//!
//! [`super::exec::CompiledFabric`] already turns a routed configuration
//! into a static firing schedule, but its run loop still *interprets*
//! that schedule — a 19-way `Op::eval` match per lane, bounds-checked
//! `buf[a0 + lane]` indexing, and fresh `out`/`buf` allocations on every
//! `run_batch` call. The schedule is fully static per artifact, so this
//! module lowers it once more, into a [`LoweredKernel`]:
//!
//!   * **dispatch removal** — every firing executes through a
//!     monomorphized per-`Op` lane sweep ([`apply`]): one match per
//!     firing instead of one per element, and each arm is a closed
//!     `zip`-iterated loop the compiler can autovectorize;
//!   * **folding** — `Nop`/`Pass` firings and firings whose operands are
//!     all compile-time constants disappear at lowering time (`Nop`
//!     aliases the zero slot, `Pass` is pure slot aliasing, constant
//!     results join the prefill image);
//!   * **fusion** — a producer whose result feeds exactly one operand of
//!     one later firing (and no output tap) is chained into its consumer
//!     and executed in one pass over the lanes, the intermediate living
//!     in a stack accumulator instead of a buffer slot;
//!   * **SIMD shaping** — sweeps run over exact-length slice windows
//!     carved with `split_at_mut` (legal because every operand slot is
//!     strictly below its destination slot — see `CompiledFabric::compile`'s
//!     monotone slot assignment), so bounds checks hoist and the scalar
//!     loops vectorize; an optional `std::arch` SSE2 path for `Add`/`Sub`
//!     sits behind the off-by-default `simd` cargo feature;
//!   * **allocation removal** — the wave buffer lives in a reusable
//!     [`Scratch`] arena primed once per artifact (keyed by the kernel
//!     [`LoweredKernel::fingerprint`]), not rebuilt per invocation.
//!
//! Numerics are bit-identical to the wave executor and `CycleSim` by
//! construction (`Op::eval` stays the single source of truth — the
//! specialized arms in [`apply`] are its 19 cases spelled out, locked by
//! a unit test below) and by translation validation: verifier pass V6
//! (`analysis::verifier::verify_lowered`) independently re-derives the
//! folding/aliasing abstract state from the fabric and re-proves the
//! kernel equivalent, on every cache insert in debug builds.

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};

use super::exec::{CompiledFabric, CHUNK};
use super::opcodes::Op;

/// Fixed sub-chunk window width for fused-chain execution: intermediates
/// live in `[i32; LANE_W]` stack arrays, so one chain pass touches each
/// lane once while staying register-resident.
pub const LANE_W: usize = 16;

/// Operand source for a fused chain member.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub(crate) enum Src {
    /// Read this buffer slot's lane window.
    Buf(usize),
    /// Read the running accumulator (the previous member's result).
    Acc,
}

/// One member of a fused firing chain: the same `op(a, b, s)` shape as a
/// wave firing, but operands may read the chain accumulator and only the
/// tail member's result is written back to the buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub(crate) struct ChainOp {
    pub(crate) op: Op,
    pub(crate) a: Src,
    pub(crate) b: Src,
    pub(crate) s: Src,
}

/// One lowered execution step.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub(crate) enum Step {
    /// One surviving firing swept over the full lane window:
    /// `buf[dst] = op(buf[a], buf[b], buf[s])` for every lane.
    Sweep { op: Op, dst: usize, a: usize, b: usize, s: usize },
    /// A fused producer→single-consumer chain, executed at the *tail*
    /// consumer's schedule position (deferral is safe: every slot is
    /// written exactly once per wave pass, so no step between a producer
    /// and its sole consumer can clobber the producer's operands).
    /// Exactly one buffer write — the tail's `dst`.
    Chain { ops: Vec<ChainOp>, dst: usize },
}

/// A wave schedule lowered to specialized batch kernels. Immutable after
/// lowering; shared through the config cache exactly like the
/// [`CompiledFabric`] it was lowered from. Slot numbering is inherited
/// unchanged from the fabric (folded slots simply go unwritten), which
/// keeps the V6 equivalence proof a direct slot-for-slot re-derivation.
#[derive(Clone, Debug, PartialEq)]
pub struct LoweredKernel {
    /// Value-slot count, identical to the source fabric's.
    pub(crate) n_slots: usize,
    /// Pre-image written once per [`Scratch`] priming: the fabric's
    /// constants plus every constant-folded firing result, pruned to the
    /// slots a surviving step or output tap actually reads.
    pub(crate) prefill: Vec<(usize, i32)>,
    /// External input bindings `(slot, stream index)`, verbatim from the
    /// fabric.
    pub(crate) ext_ins: Vec<(usize, usize)>,
    /// Surviving steps, in schedule order.
    pub(crate) steps: Vec<Step>,
    /// Output taps `(stream index, slot)` with aliases resolved (a tap on
    /// a folded `Pass` reads the pass-through source; a tap on a `Nop`
    /// reads the zero slot).
    pub(crate) outs: Vec<(usize, usize)>,
    /// Number of input streams the kernel reads (ABI: `x[j * lanes + i]`).
    pub n_inputs: usize,
    /// Deterministic structural hash of everything above — the
    /// [`Scratch`] priming key: a scratch arena primed for this
    /// fingerprint needs no const refill on the next invocation.
    pub fingerprint: u64,
    /// Firings removed by folding (`Nop`, `Pass`, all-constant operands).
    pub folded: usize,
    /// Producer→consumer edges removed by fusion.
    pub fused: usize,
}

/// Reusable execution arena: the wave buffer plus the priming state that
/// makes the constant prefill a once-per-artifact cost instead of a
/// once-per-invocation cost. One per tenant in the serve layer (each
/// backend owns its scratch), so tenants never observe each other's lane
/// data.
#[derive(Clone, Debug, Default)]
pub struct Scratch {
    buf: Vec<i32>,
    /// Fingerprint of the kernel the buffer is currently primed for.
    primed: Option<u64>,
    /// How many times the constant prefill ran — regression-tested to be
    /// once per artifact, not once per invocation.
    pub const_fills: u64,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }
}

impl LoweredKernel {
    /// Lower a compiled wave schedule. Total: every fabric lowers (the
    /// fallback-worthy failure modes — cycles, dangling producers — were
    /// already rejected by `CompiledFabric::compile`).
    pub fn lower(fab: &CompiledFabric) -> LoweredKernel {
        let n_slots = fab.n_slots;

        // Abstract state over slots: `known[s]` = the compile-time
        // constant in `s` (zero slot, fabric consts, folded results);
        // `alias[s]` = the slot actually holding `s`'s value at run time
        // (identity except through folded `Pass`/`Nop` firings).
        let mut known: Vec<Option<i32>> = vec![None; n_slots];
        if n_slots > 0 {
            known[0] = Some(0);
        }
        for &(slot, v) in &fab.consts {
            known[slot] = Some(v);
        }
        let mut alias: Vec<usize> = (0..n_slots).collect();

        let mut folded = 0usize;
        let mut sweeps: Vec<(Op, usize, usize, usize, usize)> = Vec::new();
        for w in &fab.ops {
            let (a, b, s) = (alias[w.a], alias[w.b], alias[w.s]);
            match w.op {
                // `Nop` is 0 regardless of operands: alias to the zero
                // slot (slot 0 is never written, always zero).
                Op::Nop => {
                    alias[w.dst] = 0;
                    known[w.dst] = Some(0);
                    folded += 1;
                }
                // `Pass` forwards its first operand: pure slot aliasing.
                Op::Pass => {
                    alias[w.dst] = a;
                    known[w.dst] = known[a];
                    folded += 1;
                }
                op => {
                    // Unused operands were resolved to the zero slot by
                    // the fabric compiler, so `known` is `Some(0)` there
                    // and the fold below reproduces `eval` exactly.
                    if let (Some(ka), Some(kb), Some(ks)) = (known[a], known[b], known[s]) {
                        known[w.dst] = Some(op.eval(ka, kb, ks));
                        folded += 1;
                    } else {
                        sweeps.push((op, w.dst, a, b, s));
                    }
                }
            }
        }

        // Output taps through the alias map; tapped slots are fusion
        // barriers (their value must land in the buffer).
        let outs: Vec<(usize, usize)> =
            fab.outs.iter().map(|&(j, slot)| (j, alias[slot])).collect();
        let mut tapped = vec![false; n_slots];
        for &(_, slot) in &outs {
            tapped[slot] = true;
        }

        // Reader census over the surviving sweeps: a producer fuses into
        // its consumer only if exactly one (firing, operand) pair reads
        // its destination and no tap does.
        let mut readers = vec![0usize; n_slots];
        for &(_, _, a, b, s) in &sweeps {
            readers[a] += 1;
            readers[b] += 1;
            readers[s] += 1;
        }

        // Greedy chain building, in schedule order. `made[i]` holds the
        // step currently ending at position `i` (tombstoned when absorbed
        // into a later consumer); `produced_at[slot]` locates the step
        // producing `slot`.
        let mut made: Vec<Option<Step>> = Vec::with_capacity(sweeps.len());
        let mut produced_at: Vec<Option<usize>> = vec![None; n_slots];
        let mut fused = 0usize;
        for &(op, dst, a, b, s) in &sweeps {
            // First fusable operand wins (deterministic: a, then b, then
            // s). An operand read twice by this firing fails the
            // single-reader census, so `Acc` is unambiguous.
            let fusable = |slot: usize| {
                slot != 0
                    && readers[slot] == 1
                    && !tapped[slot]
                    && produced_at[slot].is_some()
            };
            let pick = [a, b, s].into_iter().find(|&o| fusable(o));
            let step = match pick {
                Some(src_slot) => {
                    let pi = produced_at[src_slot].expect("fusable implies produced");
                    let prev = made[pi].take().expect("producer not yet absorbed");
                    produced_at[src_slot] = None;
                    let mut ops = match prev {
                        Step::Sweep { op, a, b, s, .. } => vec![ChainOp {
                            op,
                            a: Src::Buf(a),
                            b: Src::Buf(b),
                            s: Src::Buf(s),
                        }],
                        Step::Chain { ops, .. } => ops,
                    };
                    let pickb = |o: usize| {
                        if o == src_slot {
                            Src::Acc
                        } else {
                            Src::Buf(o)
                        }
                    };
                    ops.push(ChainOp { op, a: pickb(a), b: pickb(b), s: pickb(s) });
                    fused += 1;
                    Step::Chain { ops, dst }
                }
                None => Step::Sweep { op, dst, a, b, s },
            };
            produced_at[dst] = Some(made.len());
            made.push(Some(step));
        }
        let steps: Vec<Step> = made.into_iter().flatten().collect();

        // Prefill = known slots a surviving step or tap actually reads
        // (slot 0 is excluded: the scratch arena zero-fills on priming).
        let mut read = vec![false; n_slots];
        for step in &steps {
            let mut mark = |src: Src| {
                if let Src::Buf(slot) = src {
                    read[slot] = true;
                }
            };
            match step {
                Step::Sweep { a, b, s, .. } => {
                    read[*a] = true;
                    read[*b] = true;
                    read[*s] = true;
                }
                Step::Chain { ops, .. } => {
                    for c in ops {
                        mark(c.a);
                        mark(c.b);
                        mark(c.s);
                    }
                }
            }
        }
        for &(_, slot) in &outs {
            read[slot] = true;
        }
        let prefill: Vec<(usize, i32)> = (1..n_slots)
            .filter(|&slot| read[slot])
            .filter_map(|slot| known[slot].map(|v| (slot, v)))
            .collect();

        let mut k = LoweredKernel {
            n_slots,
            prefill,
            ext_ins: fab.ext_ins.clone(),
            steps,
            outs,
            n_inputs: fab.n_inputs,
            fingerprint: 0,
            folded,
            fused,
        };
        k.fingerprint = k.structural_hash();
        k
    }

    /// Deterministic structural hash over everything execution-relevant.
    /// Crate-visible so verifier pass V6 can re-prove the stored
    /// `fingerprint` (a drifted fingerprint would let a stale scratch
    /// arena skip re-priming).
    pub(crate) fn structural_hash(&self) -> u64 {
        let mut h = DefaultHasher::new();
        self.n_slots.hash(&mut h);
        self.prefill.hash(&mut h);
        self.ext_ins.hash(&mut h);
        self.steps.hash(&mut h);
        self.outs.hash(&mut h);
        self.n_inputs.hash(&mut h);
        h.finish()
    }

    /// Surviving steps (post folding/fusion).
    pub fn n_steps(&self) -> usize {
        self.steps.len()
    }

    /// Batch entry point, same ABI as [`CompiledFabric::run_batch`]
    /// (`x[j * lanes + lane]` slot-major in, `[n_out, lanes]` slot-major
    /// out), executing through the reusable `scratch` arena: the wave
    /// buffer is (re)allocated and const-prefilled only when the arena
    /// was last primed for a different artifact.
    pub fn run_batch(&self, x: &[i32], lanes: usize, scratch: &mut Scratch) -> Vec<i32> {
        debug_assert!(x.len() >= self.n_inputs * lanes);
        let want = self.n_slots * CHUNK;
        if scratch.primed != Some(self.fingerprint) || scratch.buf.len() != want {
            scratch.buf.clear();
            scratch.buf.resize(want, 0);
            for &(slot, v) in &self.prefill {
                scratch.buf[slot * CHUNK..(slot + 1) * CHUNK].fill(v);
            }
            scratch.primed = Some(self.fingerprint);
            scratch.const_fills += 1;
        }
        let buf = &mut scratch.buf[..];
        let mut out = vec![0i32; self.outs.len() * lanes];
        let mut at = 0usize;
        while at < lanes {
            let m = CHUNK.min(lanes - at);
            for &(slot, j) in &self.ext_ins {
                buf[slot * CHUNK..slot * CHUNK + m]
                    .copy_from_slice(&x[j * lanes + at..j * lanes + at + m]);
            }
            self.fire(buf, m);
            for (row, &(_, slot)) in self.outs.iter().enumerate() {
                out[row * lanes + at..row * lanes + at + m]
                    .copy_from_slice(&buf[slot * CHUNK..slot * CHUNK + m]);
            }
            at += m;
        }
        out
    }

    /// Execute every step over `m` lanes of the wave buffer.
    #[inline]
    fn fire(&self, buf: &mut [i32], m: usize) {
        for step in &self.steps {
            match step {
                Step::Sweep { op, dst, a, b, s } => sweep(buf, m, *op, *dst, *a, *b, *s),
                Step::Chain { ops, dst } => chain(buf, m, ops, *dst),
            }
        }
    }

    /// Mutation hook for the verifier self-test harness
    /// (`tests/verifier.rs`): swap two lowered steps so pass V6's
    /// scoreboard/probe has a documented positive control for ordering
    /// corruption. Never called by production code.
    #[doc(hidden)]
    pub fn swap_steps(&mut self, i: usize, j: usize) {
        self.steps.swap(i, j);
    }

    /// Mutation hook for the verifier self-test harness: corrupt the
    /// first prefill value so V6's constant re-derivation has a positive
    /// control. Never called by production code.
    #[doc(hidden)]
    pub fn corrupt_prefill(&mut self) {
        if let Some(e) = self.prefill.first_mut() {
            e.1 = e.1.wrapping_add(1);
        }
    }

    /// Mutation hook for the verifier self-test harness: re-point the
    /// first output tap at the zero slot so V6's tap re-derivation has a
    /// positive control. Never called by production code.
    #[doc(hidden)]
    pub fn retarget_out(&mut self) {
        if let Some(o) = self.outs.first_mut() {
            o.1 = 0;
        }
    }
}

/// One surviving firing over `m` lanes. The slot invariant `a, b, s <
/// dst` (monotone slot assignment in `CompiledFabric::compile`, preserved
/// by alias resolution — aliases only ever point earlier) makes
/// `split_at_mut` carve aliasing-free operand/destination windows, so the
/// borrow checker proves disjointness and the exact-length slices let the
/// compiler hoist every bounds check out of the lane loop.
#[inline]
fn sweep(buf: &mut [i32], m: usize, op: Op, dst: usize, a: usize, b: usize, s: usize) {
    debug_assert!(a < dst && b < dst && s < dst);
    let (lo, hi) = buf.split_at_mut(dst * CHUNK);
    let d = &mut hi[..m];
    let a = &lo[a * CHUNK..a * CHUNK + m];
    let b = &lo[b * CHUNK..b * CHUNK + m];
    let s = &lo[s * CHUNK..s * CHUNK + m];
    apply(op, d, a, b, s);
}

/// One fused chain over `m` lanes in [`LANE_W`]-wide windows: gather the
/// members' operand windows, thread the accumulator, write only the tail
/// destination. Every `Buf` slot in the chain is strictly below `dst`
/// (member operands < member dst ≤ tail dst), so the same `split_at_mut`
/// carve applies.
#[inline]
fn chain(buf: &mut [i32], m: usize, ops: &[ChainOp], dst: usize) {
    let (lo, hi) = buf.split_at_mut(dst * CHUNK);
    let d = &mut hi[..m];
    let mut at = 0usize;
    while at < m {
        let w = LANE_W.min(m - at);
        let mut acc = [0i32; LANE_W];
        for c in ops {
            let mut aw = [0i32; LANE_W];
            let mut bw = [0i32; LANE_W];
            let mut sw = [0i32; LANE_W];
            gather(lo, c.a, at, w, &acc, &mut aw);
            gather(lo, c.b, at, w, &acc, &mut bw);
            gather(lo, c.s, at, w, &acc, &mut sw);
            let mut tmp = [0i32; LANE_W];
            apply(c.op, &mut tmp[..w], &aw[..w], &bw[..w], &sw[..w]);
            acc = tmp;
        }
        d[at..at + w].copy_from_slice(&acc[..w]);
        at += w;
    }
}

#[inline(always)]
fn gather(
    lo: &[i32],
    src: Src,
    at: usize,
    w: usize,
    acc: &[i32; LANE_W],
    out: &mut [i32; LANE_W],
) {
    match src {
        Src::Buf(slot) => {
            out[..w].copy_from_slice(&lo[slot * CHUNK + at..slot * CHUNK + at + w])
        }
        Src::Acc => out[..w].copy_from_slice(&acc[..w]),
    }
}

/// Two-operand lane sweep, monomorphized per call site: each closure
/// below compiles to its own closed loop over exact-length slices.
#[inline(always)]
fn lanes2(d: &mut [i32], a: &[i32], b: &[i32], f: impl Fn(i32, i32) -> i32) {
    for ((d, &a), &b) in d.iter_mut().zip(a).zip(b) {
        *d = f(a, b);
    }
}

/// Three-operand lane sweep (MUX only).
#[inline(always)]
fn lanes3(d: &mut [i32], a: &[i32], b: &[i32], s: &[i32], f: impl Fn(i32, i32, i32) -> i32) {
    for (((d, &a), &b), &s) in d.iter_mut().zip(a).zip(b).zip(s) {
        *d = f(a, b, s);
    }
}

/// `Add` lane sweep: explicit SSE2 when the `simd` feature is on (and
/// numerically identical — wrapping i32 lane adds), the autovectorized
/// scalar closure otherwise.
#[inline(always)]
fn add_lanes(d: &mut [i32], a: &[i32], b: &[i32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    simd::add(d, a, b);
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    lanes2(d, a, b, |x, y| x.wrapping_add(y));
}

/// `Sub` lane sweep; see [`add_lanes`].
#[inline(always)]
fn sub_lanes(d: &mut [i32], a: &[i32], b: &[i32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    simd::sub(d, a, b);
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    lanes2(d, a, b, |x, y| x.wrapping_sub(y));
}

/// The specialized dispatch: one 19-arm match *per firing* (or per
/// [`LANE_W`] window inside a chain), each arm a distinct monomorphized
/// lane loop. The arms are `Op::eval`'s cases spelled out one-for-one —
/// `eval_agrees_lane_for_lane` below locks the correspondence.
fn apply(op: Op, d: &mut [i32], a: &[i32], b: &[i32], s: &[i32]) {
    match op {
        Op::Nop => d.fill(0),
        Op::Add => add_lanes(d, a, b),
        Op::Sub => sub_lanes(d, a, b),
        Op::Mul => lanes2(d, a, b, |x, y| x.wrapping_mul(y)),
        Op::Min => lanes2(d, a, b, |x, y| x.min(y)),
        Op::Max => lanes2(d, a, b, |x, y| x.max(y)),
        Op::Lt => lanes2(d, a, b, |x, y| (x < y) as i32),
        Op::Gt => lanes2(d, a, b, |x, y| (x > y) as i32),
        Op::Le => lanes2(d, a, b, |x, y| (x <= y) as i32),
        Op::Ge => lanes2(d, a, b, |x, y| (x >= y) as i32),
        Op::Eq => lanes2(d, a, b, |x, y| (x == y) as i32),
        Op::Ne => lanes2(d, a, b, |x, y| (x != y) as i32),
        Op::Mux => lanes3(d, a, b, s, |x, y, sel| if sel != 0 { x } else { y }),
        Op::And => lanes2(d, a, b, |x, y| x & y),
        Op::Or => lanes2(d, a, b, |x, y| x | y),
        Op::Xor => lanes2(d, a, b, |x, y| x ^ y),
        Op::Shl => lanes2(d, a, b, |x, y| x.wrapping_shl(y.clamp(0, 31) as u32)),
        Op::Shr => lanes2(d, a, b, |x, y| x.wrapping_shr(y.clamp(0, 31) as u32)),
        Op::Pass => d.copy_from_slice(a),
    }
}

/// Explicit `std::arch` lane sweeps for the baseline-SSE2 ops. Only
/// `Add`/`Sub` qualify: `_mm_mullo_epi32` is SSE4.1, beyond the x86_64
/// baseline, so `Mul` and everything else stay on the autovectorized
/// scalar path. Numerics are identical by construction (packed 32-bit
/// adds/subs wrap exactly like `wrapping_add`/`wrapping_sub`). Off by
/// default; CI never enables it.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod simd {
    use std::arch::x86_64::{
        __m128i, _mm_add_epi32, _mm_loadu_si128, _mm_storeu_si128, _mm_sub_epi32,
    };

    #[inline(always)]
    pub(super) fn add(d: &mut [i32], a: &[i32], b: &[i32]) {
        binop(d, a, b, |x, y| unsafe { _mm_add_epi32(x, y) }, i32::wrapping_add)
    }

    #[inline(always)]
    pub(super) fn sub(d: &mut [i32], a: &[i32], b: &[i32]) {
        binop(d, a, b, |x, y| unsafe { _mm_sub_epi32(x, y) }, i32::wrapping_sub)
    }

    #[inline(always)]
    fn binop(
        d: &mut [i32],
        a: &[i32],
        b: &[i32],
        v: impl Fn(__m128i, __m128i) -> __m128i,
        scalar: impl Fn(i32, i32) -> i32,
    ) {
        debug_assert!(a.len() >= d.len() && b.len() >= d.len());
        let n = d.len();
        let mut i = 0usize;
        while i + 4 <= n {
            // SAFETY: `i + 4 <= n <= a.len(), b.len()` keeps every
            // unaligned 4-lane load/store in bounds; `d` is `&mut` while
            // `a`/`b` are `&`, so the windows cannot alias.
            unsafe {
                let x = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
                let y = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
                _mm_storeu_si128(d.as_mut_ptr().add(i) as *mut __m128i, v(x, y));
            }
            i += 4;
        }
        while i < n {
            d[i] = scalar(a[i], b[i]);
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::config::{FuSrc, GridConfig, IoAssign, OutSrc};
    use super::super::grid::{CellCoord, Dir, Grid};
    use super::super::opcodes::{Op, ALL_OPS};
    use super::*;

    /// Lock the specialized dispatch to `Op::eval` lane for lane, over
    /// operand values that exercise wrapping, clamping and sign edges.
    #[test]
    fn eval_agrees_lane_for_lane() {
        let probes: [i32; 8] = [0, 1, -1, 7, -13, i32::MAX, i32::MIN, 40];
        for op in ALL_OPS {
            for &x in &probes {
                for &y in &probes {
                    for &sel in &[0i32, 1, -5] {
                        let a = [x; 3];
                        let b = [y; 3];
                        let s = [sel; 3];
                        let mut d = [0i32; 3];
                        apply(op, &mut d, &a, &b, &s);
                        assert_eq!(
                            d,
                            [op.eval(x, y, sel); 3],
                            "{op} mismatch at a={x} b={y} s={sel}"
                        );
                    }
                }
            }
        }
    }

    /// Hand-built 1x3 pipeline: in → add const → pass → out. The `Pass`
    /// folds to an alias and the lowered output matches the wave
    /// executor bit for bit through a reused scratch arena.
    fn pipeline_cfg() -> GridConfig {
        let mut cfg = GridConfig::empty(Grid::new(1, 3));
        let c0 = CellCoord::new(0, 0);
        let c1 = CellCoord::new(0, 1);
        let c2 = CellCoord::new(0, 2);
        cfg.inputs.push(IoAssign { cell: c0, dir: Dir::W, index: 0 });
        cfg.cell_mut(c0).op = Some(Op::Add);
        cfg.cell_mut(c0).fu1 = FuSrc::In(Dir::W);
        cfg.cell_mut(c0).fu2 = FuSrc::Const(5);
        cfg.cell_mut(c0).out[Dir::E.index()] = OutSrc::Fu;
        cfg.cell_mut(c1).op = Some(Op::Pass);
        cfg.cell_mut(c1).fu1 = FuSrc::In(Dir::W);
        cfg.cell_mut(c1).out[Dir::E.index()] = OutSrc::Fu;
        cfg.cell_mut(c2).op = Some(Op::Mul);
        cfg.cell_mut(c2).fu1 = FuSrc::In(Dir::W);
        cfg.cell_mut(c2).fu2 = FuSrc::Const(3);
        cfg.cell_mut(c2).out[Dir::E.index()] = OutSrc::Fu;
        cfg.outputs.push(IoAssign { cell: c2, dir: Dir::E, index: 0 });
        cfg
    }

    #[test]
    fn lowered_matches_wave_and_folds_pass() {
        let cfg = pipeline_cfg();
        let fab = CompiledFabric::compile(&cfg).expect("feed-forward");
        let k = LoweredKernel::lower(&fab);
        assert!(k.folded >= 1, "the Pass firing must fold");
        let lanes = 2 * CHUNK + 37; // full, full, partial chunk
        let x: Vec<i32> = (0..lanes).map(|i| (i as i32).wrapping_mul(3) - 40).collect();
        let want = fab.run_batch(&x, lanes);
        let mut scratch = Scratch::new();
        assert_eq!(k.run_batch(&x, lanes, &mut scratch), want);
        // Second invocation through the same arena: identical numerics,
        // no re-prime.
        assert_eq!(k.run_batch(&x, lanes, &mut scratch), want);
        assert_eq!(scratch.const_fills, 1, "prefill must run once per artifact");
    }

    #[test]
    fn fusion_chains_single_consumer_producers() {
        // add → mul is a producer with exactly one reader and no tap:
        // the lowering must fuse them into one chain step.
        let cfg = pipeline_cfg();
        let fab = CompiledFabric::compile(&cfg).expect("feed-forward");
        let k = LoweredKernel::lower(&fab);
        assert!(k.fused >= 1, "add→mul must fuse, got steps {:?}", k.steps);
        assert!(
            k.steps.iter().any(|s| matches!(s, Step::Chain { .. })),
            "expected a fused chain"
        );
    }

    #[test]
    fn lowering_is_deterministic() {
        let cfg = pipeline_cfg();
        let fab = CompiledFabric::compile(&cfg).expect("feed-forward");
        let k1 = LoweredKernel::lower(&fab);
        let k2 = LoweredKernel::lower(&fab);
        assert_eq!(k1, k2);
        assert_eq!(k1.fingerprint, k2.fingerprint);
    }

    #[test]
    fn scratch_reprimes_across_artifacts() {
        let cfg = pipeline_cfg();
        let fab = CompiledFabric::compile(&cfg).expect("feed-forward");
        let k = LoweredKernel::lower(&fab);

        // A second, different artifact: drop the Pass stage's const.
        let mut cfg2 = pipeline_cfg();
        cfg2.cell_mut(CellCoord::new(0, 2)).fu2 = FuSrc::Const(7);
        let fab2 = CompiledFabric::compile(&cfg2).expect("feed-forward");
        let k2 = LoweredKernel::lower(&fab2);
        assert_ne!(k.fingerprint, k2.fingerprint);

        let lanes = 100;
        let x: Vec<i32> = (0..lanes as i32).collect();
        let mut scratch = Scratch::new();
        assert_eq!(k.run_batch(&x, lanes, &mut scratch), fab.run_batch(&x, lanes));
        assert_eq!(k2.run_batch(&x, lanes, &mut scratch), fab2.run_batch(&x, lanes));
        // Back to the first artifact: the arena must re-prime, not serve
        // the other kernel's constants.
        assert_eq!(k.run_batch(&x, lanes, &mut scratch), fab.run_batch(&x, lanes));
        assert_eq!(scratch.const_fills, 3);
    }
}

//! Low-overhead performance monitor (paper §III, the `perf_event` role):
//! samples the engine's per-function counters, maintains exponentially
//! weighted rates and flags hot functions worth the analysis phase.

use std::time::Duration;

use crate::jit::engine::Engine;

/// Monitor tunables.
#[derive(Clone, Copy, Debug)]
pub struct MonitorParams {
    /// Minimum share of total observed cycles to call a function hot.
    pub hot_cycle_share: f64,
    /// Minimum absolute cycles before any decision (warm-up guard).
    pub min_cycles: u64,
    /// Minimum invocations (one-shot functions are not worth offloading).
    pub min_invocations: u64,
    /// EWMA smoothing for deltas between samples.
    pub alpha: f64,
}

impl Default for MonitorParams {
    fn default() -> Self {
        MonitorParams {
            hot_cycle_share: 0.25,
            min_cycles: 10_000,
            min_invocations: 2,
            alpha: 0.4,
        }
    }
}

/// One sampled row.
#[derive(Clone, Copy, Debug, Default)]
pub struct Sample {
    pub cycles: u64,
    pub mem_accesses: u64,
    pub invocations: u64,
    pub wall: Duration,
    /// EWMA of per-sample cycle deltas (activity rate).
    pub rate: f64,
}

/// A hotspot decision.
#[derive(Clone, Debug, PartialEq)]
pub struct Hotspot {
    pub func: u32,
    pub name: String,
    pub cycle_share: f64,
    pub cycles: u64,
    pub mem_accesses: u64,
    pub invocations: u64,
}

/// Scheduling weight for the serve layer's hotness-weighted round robin:
/// total interpreter cycles observed for `func` (the same signal the
/// hotspot monitor ranks by — hotter tenants earn proportionally more
/// scheduling slots).
pub fn hotness(engine: &Engine, func: u32) -> f64 {
    engine.profile(func).counters.cycles as f64
}

pub struct Monitor {
    pub params: MonitorParams,
    last: Vec<Sample>,
}

impl Monitor {
    pub fn new(params: MonitorParams) -> Monitor {
        Monitor { params, last: Vec::new() }
    }

    /// Sample all function counters and return hotspots, hottest first.
    /// (The real system samples perf_event fds on a timer; we sample the
    /// interpreter's counters at the same cadence from the coordinator.)
    pub fn sample(&mut self, engine: &Engine) -> Vec<Hotspot> {
        let n = engine.n_funcs();
        self.last.resize(n, Sample::default());
        let mut rows: Vec<(u32, Sample)> = Vec::with_capacity(n);
        let mut total_cycles = 0u64;
        for f in 0..n as u32 {
            let p = engine.profile(f);
            let prev = self.last[f as usize];
            let delta = p.counters.cycles.saturating_sub(prev.cycles);
            let rate =
                self.params.alpha * delta as f64 + (1.0 - self.params.alpha) * prev.rate;
            let s = Sample {
                cycles: p.counters.cycles,
                mem_accesses: p.counters.mem_accesses,
                invocations: p.counters.invocations,
                wall: p.wall,
                rate,
            };
            total_cycles += p.counters.cycles;
            rows.push((f, s));
            self.last[f as usize] = s;
        }
        if total_cycles == 0 {
            return Vec::new();
        }
        let mut hot: Vec<Hotspot> = rows
            .into_iter()
            .filter_map(|(f, s)| {
                let share = s.cycles as f64 / total_cycles as f64;
                (share >= self.params.hot_cycle_share
                    && s.cycles >= self.params.min_cycles
                    && s.invocations >= self.params.min_invocations)
                    .then(|| Hotspot {
                        func: f,
                        name: engine.func_name(f).to_string(),
                        cycle_share: share,
                        cycles: s.cycles,
                        mem_accesses: s.mem_accesses,
                        invocations: s.invocations,
                    })
            })
            .collect();
        rank_hotspots(&mut hot);
        hot
    }

    /// Last sampled activity rate for a function (EWMA of cycle deltas).
    pub fn rate(&self, func: u32) -> f64 {
        self.last.get(func as usize).map(|s| s.rate).unwrap_or(0.0)
    }
}

/// Rank hotspots hottest-first. Uses `total_cmp`, never
/// `partial_cmp(..).unwrap()`: a NaN `cycle_share` (a zero-total-cycle
/// snapshot taken right after a `take_profile` patch-time reset divides
/// 0/0) must sort last, not panic the monitor thread. NaN maps to -inf
/// first — `total_cmp` alone would order a positive NaN *above* +inf,
/// i.e. report a garbage row as the #1 hotspot.
pub fn rank_hotspots(hot: &mut [Hotspot]) {
    let key = |s: f64| if s.is_nan() { f64::NEG_INFINITY } else { s };
    hot.sort_by(|a, b| key(b.cycle_share).total_cmp(&key(a.cycle_share)));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::func::{FuncBuilder, Module};
    use crate::ir::instr::Ty;
    use crate::jit::interp::{Memory, Val};

    fn hot_and_cold_module() -> Module {
        let mut m = Module::new();
        for (name, inner) in [("hot", 64), ("cold", 1)] {
            let mut b = FuncBuilder::new(name, &[("A", Ty::Ptr), ("n", Ty::I32)]);
            let (a, n) = (b.param(0), b.param(1));
            let zero = b.const_i32(0);
            let reps = b.const_i32(inner);
            b.counted_loop(zero, reps, |b, _| {
                let z2 = b.const_i32(0);
                b.counted_loop(z2, n, |b, i| {
                    let v = b.load(Ty::I32, a, i);
                    let w = b.add(v, v);
                    b.store(Ty::I32, a, i, w);
                });
            });
            m.add(b.ret(None));
        }
        m
    }

    #[test]
    fn detects_hot_function() {
        let mut e = Engine::new(hot_and_cold_module()).unwrap();
        let mut mem = Memory::new();
        let h = mem.alloc_i32(256);
        for _ in 0..3 {
            e.call("hot", &mut mem, &[Val::P(h), Val::I(256)]).unwrap();
            e.call("cold", &mut mem, &[Val::P(h), Val::I(256)]).unwrap();
        }
        let mut mon = Monitor::new(MonitorParams::default());
        let hot = mon.sample(&e);
        assert_eq!(hot.len(), 1, "{hot:?}");
        assert_eq!(hot[0].name, "hot");
        assert!(hot[0].cycle_share > 0.9);
    }

    #[test]
    fn warmup_guard_suppresses_early_decisions() {
        let mut e = Engine::new(hot_and_cold_module()).unwrap();
        let mut mem = Memory::new();
        let h = mem.alloc_i32(4);
        // One tiny invocation: under min_cycles and min_invocations.
        e.call("hot", &mut mem, &[Val::P(h), Val::I(1)]).unwrap();
        let mut mon = Monitor::new(MonitorParams::default());
        assert!(mon.sample(&e).is_empty());
    }

    #[test]
    fn rate_tracks_activity() {
        let mut e = Engine::new(hot_and_cold_module()).unwrap();
        let mut mem = Memory::new();
        let h = mem.alloc_i32(64);
        let mut mon = Monitor::new(MonitorParams::default());
        mon.sample(&e);
        e.call("hot", &mut mem, &[Val::P(h), Val::I(64)]).unwrap();
        mon.sample(&e);
        let f = e.func_index("hot").unwrap();
        assert!(mon.rate(f) > 0.0);
        // No further activity: rate decays.
        let r1 = mon.rate(f);
        mon.sample(&e);
        assert!(mon.rate(f) < r1);
    }

    #[test]
    fn empty_engine_no_hotspots() {
        let e = Engine::new(Module::new()).unwrap();
        let mut mon = Monitor::new(MonitorParams::default());
        assert!(mon.sample(&e).is_empty());
    }

    #[test]
    fn zero_sample_snapshot_after_profile_reset_does_not_panic() {
        // Regression (ISSUE 4): sampling an engine whose only activity was
        // snapshot/reset away by `take_profile` (the patch-time reset)
        // sees zero total cycles. That must yield "no hotspots", never a
        // NaN cycle-share panic inside the ranking sort.
        use crate::jit::interp::{Memory, Val};
        let mut e = Engine::new(hot_and_cold_module()).unwrap();
        let mut mem = Memory::new();
        let h = mem.alloc_i32(256);
        for _ in 0..3 {
            e.call("hot", &mut mem, &[Val::P(h), Val::I(256)]).unwrap();
        }
        let hot = e.func_index("hot").unwrap();
        let cold = e.func_index("cold").unwrap();
        let snap = e.take_profile(hot);
        assert!(snap.counters.cycles > 0, "snapshot carries the history");
        e.take_profile(cold);
        let mut mon = Monitor::new(MonitorParams::default());
        assert!(mon.sample(&e).is_empty(), "zero-sample engine has no hotspots");
    }

    #[test]
    fn rank_hotspots_with_nan_share_sorts_last_instead_of_panicking() {
        // Regression (ISSUE 4): the pre-fix `partial_cmp(..).unwrap()`
        // panics the moment one row carries a NaN cycle_share (0/0 from a
        // zero-total-cycle snapshot). `total_cmp` must rank it last.
        let row = |name: &str, share: f64| Hotspot {
            func: 0,
            name: name.into(),
            cycle_share: share,
            cycles: 1,
            mem_accesses: 0,
            invocations: 1,
        };
        let mut hot = vec![row("nan", f64::NAN), row("warm", 0.3), row("hot", 0.7)];
        rank_hotspots(&mut hot);
        assert_eq!(hot[0].name, "hot");
        assert_eq!(hot[1].name, "warm");
        assert!(hot[2].cycle_share.is_nan(), "NaN ranks last, never panics");
    }
}

//! PJRT runtime: load AOT artifacts (HLO text) and execute DFE images.
//!
//! Pattern: `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute` (see /opt/xla-example/src/bin/load_hlo.rs).

pub mod client;
pub mod manifest;

pub use client::{DfeExecutable, PjrtRuntime};
pub use manifest::{Manifest, VariantInfo};

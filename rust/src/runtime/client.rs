//! PJRT-backed DFE datapath: load AOT HLO-text artifacts, compile once per
//! variant, execute configured images over batched data.
//!
//! This is the runtime analogue of the paper's pre-programmed FPGA
//! bitstream: compilation happens once (artifact load ≈ bitstream flash),
//! while per-DFG behaviour arrives as operands (≈ overlay reconfiguration,
//! which the paper measures in milliseconds).
//!
//! The XLA bindings are behind the `pjrt` cargo feature: the offline build
//! image has no crates.io registry, so the default build compiles a stub
//! whose `load` fails gracefully and every caller falls back to the rust
//! functional simulator (`dfe::image::ExecImage::eval*` — numerically
//! identical by the contract tested in rust/tests/runtime_artifacts.rs).
//! Interchange is HLO *text* — see python/compile/aot.py for why serialized
//! protos are rejected by xla_extension 0.5.1.

use crate::util::err::{Context as _, Result};

use super::manifest::{Manifest, VariantInfo};

// ---------------------------------------------------------------------------
// Real implementation (requires a vendored `xla` crate; see Cargo.toml).
// ---------------------------------------------------------------------------
#[cfg(feature = "pjrt")]
mod imp {
    use std::collections::HashMap;
    use std::path::Path;

    use super::{Manifest, VariantInfo};
    use crate::bail;
    use crate::dfe::abi;
    use crate::dfe::image::ExecImage;
    use crate::util::err::{Context, Result};

    /// A compiled DFE executor for one grid-size variant.
    pub struct DfeExecutable {
        pub info: VariantInfo,
        pub batch: usize,
        exe: xla::PjRtLoadedExecutable,
    }

    impl DfeExecutable {
        /// Execute `image` over a slot-major batch `x` (`n_inputs * batch`
        /// words; `batch` must equal the ABI batch). Returns the
        /// out_sel-many output rows, slot-major.
        pub fn run_batch(&self, image: &ExecImage, x: &[i32]) -> Result<Vec<i32>> {
            if x.len() != image.n_inputs * self.batch {
                bail!(
                    "input length {} != n_inputs {} * batch {}",
                    x.len(),
                    image.n_inputs,
                    self.batch
                );
            }
            let ([opcode, src1, src2, sel], consts, out_sel) =
                image.padded_operands(self.info.n_cells)?;

            // Pad external inputs to the fixed NI rows of the artifact.
            let mut xp = vec![0i32; abi::N_INPUTS * self.batch];
            xp[..x.len()].copy_from_slice(x);

            let lit = |v: &[i32]| xla::Literal::vec1(v);
            let x_lit = xla::Literal::vec1(&xp)
                .reshape(&[abi::N_INPUTS as i64, self.batch as i64])
                .context("reshape x")?;
            let args = [
                lit(&opcode),
                lit(&src1),
                lit(&src2),
                lit(&sel),
                lit(&consts),
                lit(&out_sel),
                x_lit,
            ];
            let result = self
                .exe
                .execute::<xla::Literal>(&args)
                .context("PJRT execute")?[0][0]
                .to_literal_sync()
                .context("device->host")?;
            // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
            let out = result.to_tuple1().context("unwrap result tuple")?;
            let full = out.to_vec::<i32>().context("literal to vec")?;
            debug_assert_eq!(full.len(), abi::N_OUTPUTS * self.batch);
            Ok(full[..image.out_sel.len() * self.batch].to_vec())
        }

        /// Execute over an arbitrary number of lanes by chunking into ABI
        /// batches (the paper's DMA-block streaming); lanes beyond `n` in
        /// the final chunk are zero-padded and discarded.
        pub fn run_lanes(
            &self,
            image: &ExecImage,
            x: &[i32],
            n_lanes: usize,
        ) -> Result<Vec<i32>> {
            if x.len() != image.n_inputs * n_lanes {
                bail!(
                    "input length {} != n_inputs {} * lanes {}",
                    x.len(),
                    image.n_inputs,
                    n_lanes
                );
            }
            let n_out = image.out_sel.len();
            let mut out = vec![0i32; n_out * n_lanes];
            let mut chunk = vec![0i32; image.n_inputs * self.batch];
            let mut lane = 0;
            while lane < n_lanes {
                let take = (n_lanes - lane).min(self.batch);
                chunk.fill(0);
                for j in 0..image.n_inputs {
                    let src = &x[j * n_lanes + lane..j * n_lanes + lane + take];
                    chunk[j * self.batch..j * self.batch + take].copy_from_slice(src);
                }
                let r = self.run_batch(image, &chunk)?;
                for j in 0..n_out {
                    out[j * n_lanes + lane..j * n_lanes + lane + take]
                        .copy_from_slice(&r[j * self.batch..j * self.batch + take]);
                }
                lane += take;
            }
            Ok(out)
        }
    }

    /// Owns the PJRT client and the per-variant compiled executables.
    ///
    /// NOT `Send`: PJRT handles are raw pointers. The coordinator confines
    /// the runtime to its executor thread and communicates over channels.
    pub struct PjrtRuntime {
        pub manifest: Manifest,
        client: xla::PjRtClient,
        compiled: HashMap<String, std::rc::Rc<DfeExecutable>>,
    }

    impl PjrtRuntime {
        pub fn load(artifacts_dir: &Path) -> Result<PjrtRuntime> {
            let manifest = Manifest::load(artifacts_dir)?;
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(PjrtRuntime { manifest, client, compiled: HashMap::new() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch cached) the executor for a named variant.
        pub fn executable(&mut self, name: &str) -> Result<std::rc::Rc<DfeExecutable>> {
            if let Some(e) = self.compiled.get(name) {
                return Ok(e.clone());
            }
            let info = self
                .manifest
                .by_name(name)
                .with_context(|| format!("unknown variant '{name}'"))?
                .clone();
            let proto = xla::HloModuleProto::from_text_file(
                info.file.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing {}", info.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe =
                self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
            let wrapped = std::rc::Rc::new(DfeExecutable {
                info,
                batch: self.manifest.batch,
                exe,
            });
            self.compiled.insert(name.to_string(), wrapped.clone());
            Ok(wrapped)
        }
    }
}

// ---------------------------------------------------------------------------
// Stub implementation (default build): same surface, `load` always fails
// with an actionable message and callers fall back to the rust simulator.
// ---------------------------------------------------------------------------
#[cfg(not(feature = "pjrt"))]
mod imp {
    use std::path::Path;

    use super::{Manifest, VariantInfo};
    use crate::bail;
    use crate::dfe::image::ExecImage;
    use crate::util::err::Result;

    /// Stub executor: never constructed (``load`` always errors), but the
    /// type keeps `offload::stub::DfeBackend::Pjrt` well-formed.
    pub struct DfeExecutable {
        pub info: VariantInfo,
        pub batch: usize,
    }

    impl DfeExecutable {
        pub fn run_batch(&self, _image: &ExecImage, _x: &[i32]) -> Result<Vec<i32>> {
            bail!("PJRT datapath not built (enable the `pjrt` cargo feature)")
        }

        pub fn run_lanes(
            &self,
            _image: &ExecImage,
            _x: &[i32],
            _n_lanes: usize,
        ) -> Result<Vec<i32>> {
            bail!("PJRT datapath not built (enable the `pjrt` cargo feature)")
        }
    }

    /// Stub runtime: validates the artifact directory, then reports that
    /// the PJRT backend is compiled out.
    pub struct PjrtRuntime {
        pub manifest: Manifest,
    }

    impl PjrtRuntime {
        pub fn load(artifacts_dir: &Path) -> Result<PjrtRuntime> {
            // Surface the *right* message: missing artifacts point at the
            // top-level `make artifacts`; present artifacts point at the
            // compiled-out feature.
            Manifest::load(artifacts_dir)?;
            bail!(
                "artifacts found at {} but this binary was built without the \
                 `pjrt` cargo feature; executing on the rust DFE simulator instead",
                artifacts_dir.display()
            )
        }

        pub fn platform(&self) -> String {
            "pjrt-disabled".to_string()
        }

        pub fn executable(&mut self, name: &str) -> Result<std::rc::Rc<DfeExecutable>> {
            bail!("PJRT datapath not built (enable the `pjrt` cargo feature): {name}")
        }
    }
}

pub use imp::{DfeExecutable, PjrtRuntime};

impl PjrtRuntime {
    /// Load from the default artifact directory (see
    /// [`Manifest::default_dir`]); the error message tells the user to run
    /// `make artifacts` at the repo root when the artifacts are missing.
    pub fn load_default() -> Result<PjrtRuntime> {
        Self::load(&Manifest::default_dir())
    }

    /// Executor for the smallest variant that fits `n_cells`.
    pub fn executable_fitting(&mut self, n_cells: usize) -> Result<std::rc::Rc<DfeExecutable>> {
        let name = self
            .manifest
            .smallest_fitting(n_cells)
            .with_context(|| {
                format!(
                    "no artifact variant fits {n_cells} cells (largest: {})",
                    self.manifest.variants.last().map(|v| v.n_cells).unwrap_or(0)
                )
            })?
            .name
            .clone();
        self.executable(&name)
    }
}

//! `artifacts/manifest.json` — the ABI contract written by python/compile/aot.py.

use std::path::{Path, PathBuf};

use crate::util::err::{Context, Result};
use crate::{anyhow, bail};

use crate::dfe::abi;
use crate::util::json::Json;

/// One AOT-compiled DFE executor variant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VariantInfo {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub n_cells: usize,
    pub file: PathBuf,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub batch: usize,
    pub variants: Vec<VariantInfo>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| {
                format!("reading {} (run `make artifacts` at the repo root)", path.display())
            })?;
        let v = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;

        let abi_obj = v.get("abi").ok_or_else(|| anyhow!("manifest missing 'abi'"))?;
        let field = |name: &str| -> Result<usize> {
            abi_obj
                .get(name)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest abi missing '{name}'"))
        };
        // The rust ABI constants are compile-time; refuse to run against
        // artifacts lowered with a different layout.
        let (k, ni, no, batch) =
            (field("n_consts")?, field("n_inputs")?, field("n_outputs")?, field("batch")?);
        if k != abi::N_CONSTS || ni != abi::N_INPUTS || no != abi::N_OUTPUTS {
            bail!(
                "artifact ABI mismatch: manifest K/NI/NO = {k}/{ni}/{no}, \
                 binary expects {}/{}/{} — re-run `make artifacts`",
                abi::N_CONSTS,
                abi::N_INPUTS,
                abi::N_OUTPUTS
            );
        }

        let mut variants = Vec::new();
        for item in v
            .get("variants")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'variants'"))?
        {
            let name = item
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("variant missing name"))?
                .to_string();
            let get = |f: &str| {
                item.get(f)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("variant {name} missing '{f}'"))
            };
            let rows = get("rows")?;
            let cols = get("cols")?;
            let n_cells = get("n_cells")?;
            if n_cells != rows * cols {
                bail!("variant {name}: n_cells {n_cells} != {rows}x{cols}");
            }
            let file = dir.join(
                item.get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("variant {name} missing 'file'"))?,
            );
            variants.push(VariantInfo { name, rows, cols, n_cells, file });
        }
        if variants.is_empty() {
            bail!("manifest has no variants");
        }
        variants.sort_by_key(|v| v.n_cells);
        Ok(Manifest { dir: dir.to_path_buf(), batch, variants })
    }

    /// Smallest variant whose grid holds `n_cells` cells.
    pub fn smallest_fitting(&self, n_cells: usize) -> Option<&VariantInfo> {
        self.variants.iter().find(|v| v.n_cells >= n_cells)
    }

    pub fn by_name(&self, name: &str) -> Option<&VariantInfo> {
        self.variants.iter().find(|v| v.name == name)
    }

    /// Default artifact dir: `$TLO_ARTIFACTS`, `rust/artifacts`, the repo
    /// root `artifacts/` (where the top-level `make artifacts` writes), or
    /// `./artifacts` relative to the cwd, in that order.
    pub fn default_dir() -> PathBuf {
        if let Ok(dir) = std::env::var("TLO_ARTIFACTS") {
            return PathBuf::from(dir);
        }
        // CARGO_MANIFEST_DIR (rust/) is baked at compile time; the Makefile
        // target writes to its parent. Fall back to cwd.
        let manifest_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        for candidate in [manifest_dir.join("artifacts"), manifest_dir.join("../artifacts")] {
            if candidate.exists() {
                return candidate;
            }
        }
        PathBuf::from("artifacts")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tlo_manifest_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn loads_valid_manifest() {
        let d = tmpdir("ok");
        write_manifest(
            &d,
            r#"{"abi": {"n_consts": 16, "n_inputs": 32, "n_outputs": 8, "batch": 512},
               "variants": [
                 {"name": "dfe_8x8", "rows": 8, "cols": 8, "n_cells": 64, "file": "dfe_8x8.hlo.txt"},
                 {"name": "dfe_4x4", "rows": 4, "cols": 4, "n_cells": 16, "file": "dfe_4x4.hlo.txt"}
               ]}"#,
        );
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.batch, 512);
        // sorted by capacity
        assert_eq!(m.variants[0].name, "dfe_4x4");
        assert_eq!(m.smallest_fitting(17).unwrap().name, "dfe_8x8");
        assert_eq!(m.smallest_fitting(64).unwrap().name, "dfe_8x8");
        assert!(m.smallest_fitting(65).is_none());
        assert!(m.by_name("dfe_4x4").is_some());
    }

    #[test]
    fn rejects_abi_mismatch() {
        let d = tmpdir("bad_abi");
        write_manifest(
            &d,
            r#"{"abi": {"n_consts": 8, "n_inputs": 32, "n_outputs": 8, "batch": 512},
               "variants": [{"name": "x", "rows": 1, "cols": 1, "n_cells": 1, "file": "x"}]}"#,
        );
        let err = Manifest::load(&d).unwrap_err().to_string();
        assert!(err.contains("ABI mismatch"), "{err}");
    }

    #[test]
    fn rejects_inconsistent_cells() {
        let d = tmpdir("bad_cells");
        write_manifest(
            &d,
            r#"{"abi": {"n_consts": 16, "n_inputs": 32, "n_outputs": 8, "batch": 512},
               "variants": [{"name": "x", "rows": 2, "cols": 2, "n_cells": 5, "file": "x"}]}"#,
        );
        assert!(Manifest::load(&d).is_err());
    }

    #[test]
    fn missing_dir_mentions_make_artifacts() {
        let err = Manifest::load(Path::new("/nonexistent_tlo")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}

//! The wrapper stub (paper §III: "the run-time replaces all calls to the
//! host processor function with a wrapper stub that handles all memory
//! transfers to and from the FPGA, and only then starts execution on it").
//!
//! Responsibilities per invocation:
//!   * enumerate the SCoP's iteration space (affine bounds evaluated with
//!     the live arguments; the innermost dimension advances by the unroll
//!     factor) — each point is one DFE stream element;
//!   * gather input streams (array reads / iota generation) into the
//!     slot-major batch layout, accounting the PC→FPGA transfer on the
//!     PCIe model;
//!   * execute on the DFE datapath (PJRT artifact or the rust functional
//!     simulator — both run the same execution image);
//!   * scatter outputs (assignment stores or reduction-partial folds),
//!     accounting the FPGA→PC transfer;
//!   * run the < unroll remainder of the innermost loop exactly, by host
//!     evaluation of the single-iteration DFG.
//!
//! Timing discipline: *numerics* are real (the paper's correctness), but
//! *performance* is virtual — interpreter cycles model host time and the
//! PCIe/DFE models yield transfer/execution time, so the Fig-6 phase
//! timeline and the fps comparison (§IV-C) are reproducible regardless of
//! the machine this simulator runs on.

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Duration;

use crate::dfe::config::GridConfig;
use crate::dfe::image::ExecImage;
use crate::dfe::plan::ExecutionPlan;
use crate::dfe::sim::CycleSim;
use crate::dfg::extract::{OffloadDfg, OutMode};
use crate::dfg::partition::{TileSink, TileSource};
use crate::jit::engine::Hook;
use crate::jit::interp::{Memory, Trap, Val};
use crate::runtime::DfeExecutable;
use crate::trace::{Phase, Tracer};
use crate::transport::{chunk_plan, ChunkTimeline, PcieSim, PlanTimeline, TransportMode};

use super::RuntimeState;

/// Where the DFE numerics run.
pub enum DfeBackend {
    /// Rust functional simulator (always available; used by tests/benches).
    Sim,
    /// The compiled wave executor (`dfe::exec`) — same numerics as `Sim`,
    /// lowered once per configuration and shared via the config cache.
    /// The `--no-lower` fallback since the lowered kernels landed.
    Fabric(std::rc::Rc<crate::dfe::exec::CompiledFabric>),
    /// The wave schedule specialized into vectorized batch kernels
    /// (`dfe::lower`) — the default sim-side hot path. The scratch arena
    /// is owned per backend, and backends are built per tenant (each hook
    /// closure owns its own), so the buffer reuse is tenant-isolated and
    /// the constant prefill runs once per installed artifact.
    Lowered {
        kernel: std::rc::Rc<crate::dfe::lower::LoweredKernel>,
        scratch: RefCell<crate::dfe::lower::Scratch>,
    },
    /// The cycle-accurate elastic overlay simulator — the slowest but
    /// fully independent numerics path, pinned by the differential
    /// conformance suite so interpreter ≡ CycleSim ≡ wave executor is
    /// checked end-to-end through the real offload stub.
    Cycle(std::rc::Rc<GridConfig>),
    /// The AOT Pallas artifact through PJRT (the shipped datapath).
    Pjrt(std::rc::Rc<DfeExecutable>),
}

impl DfeBackend {
    /// The default sim-side backend ladder for a cached artifact: the
    /// lowered batch kernels when present and permitted (`lower`, the
    /// `--no-lower` switch), the compiled wave executor otherwise, and
    /// per-lane image eval when the config refused to lower at all.
    /// Each call mints a fresh scratch arena, so per-tenant/per-tile
    /// backends never share lane buffers.
    pub fn sim_for(cached: &crate::dfe::cache::CachedConfig, lower: bool) -> DfeBackend {
        match (&cached.lowered, &cached.fabric) {
            (Some(k), _) if lower => DfeBackend::Lowered {
                kernel: k.clone(),
                scratch: RefCell::new(crate::dfe::lower::Scratch::new()),
            },
            (_, Some(f)) => DfeBackend::Fabric(f.clone()),
            _ => DfeBackend::Sim,
        }
    }

    fn run(&self, image: &ExecImage, x: &[i32], lanes: usize) -> Result<Vec<i32>, Trap> {
        match self {
            DfeBackend::Sim => Ok(image.eval_batch(x, lanes)),
            DfeBackend::Fabric(fabric) => Ok(fabric.run_batch(x, lanes)),
            DfeBackend::Lowered { kernel, scratch } => {
                Ok(kernel.run_batch(x, lanes, &mut scratch.borrow_mut()))
            }
            DfeBackend::Cycle(cfg) => {
                // Reshape the slot-major batch into per-stream vectors,
                // stream them through the elastic network, and flatten
                // back to the `[n_out, lanes]` ABI layout.
                let n_in = x.len() / lanes.max(1);
                let streams: Vec<Vec<i32>> = (0..n_in)
                    .map(|j| x[j * lanes..(j + 1) * lanes].to_vec())
                    .collect();
                let r = CycleSim::new(cfg)
                    .and_then(|mut s| s.run_stream(&streams, lanes))
                    .map_err(|e| Trap::OutOfBounds {
                        handle: u32::MAX,
                        idx: -1,
                        len: e.to_string().len(),
                    })?;
                let mut out = vec![0i32; r.outputs.len() * lanes];
                for (j, s) in r.outputs.iter().enumerate() {
                    out[j * lanes..j * lanes + s.len()].copy_from_slice(s);
                }
                Ok(out)
            }
            DfeBackend::Pjrt(exe) => exe
                .run_lanes(image, x, lanes)
                .map_err(|e| Trap::OutOfBounds {
                    // Surface PJRT failures as a trap; the coordinator
                    // rolls back on repeated failures.
                    handle: u32::MAX,
                    idx: -1,
                    len: e.to_string().len(),
                }),
        }
    }
}

/// Timing model constants for the virtual clock.
#[derive(Clone, Copy, Debug)]
pub struct TimeModel {
    /// Seconds per interpreter abstract cycle (host "native" speed).
    pub sec_per_cycle: f64,
    /// DFE clock (from the resource model's Fmax for the chosen device).
    pub fmax_hz: f64,
    /// Pipeline characteristics measured once on the cycle simulator.
    pub fill_latency: f64,
    pub initiation_interval: f64,
}

impl TimeModel {
    pub fn dfe_exec_time(&self, n_elements: u64) -> Duration {
        if n_elements == 0 {
            return Duration::ZERO;
        }
        let cycles = self.fill_latency + (n_elements as f64 - 1.0) * self.initiation_interval;
        Duration::from_secs_f64(cycles / self.fmax_hz)
    }
}

/// Per-invocation virtual-time report.
#[derive(Clone, Copy, Debug, Default)]
pub struct StubReport {
    pub elements: u64,
    pub host_to_dfe: Duration,
    pub dfe_to_host: Duration,
    pub dfe_exec: Duration,
    pub remainder_elements: u64,
    /// Payload bytes moved each way (consumed by the serve layer's shared
    /// link model, which re-times them under batching + contention).
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    /// End-to-end invocation wall time. Synchronous transport: the serial
    /// sum of the three phases. Asynchronous transport: the overlapped
    /// pipeline makespan (< sum — transfer hides under compute and the two
    /// link directions run concurrently).
    pub wall: Duration,
}

impl StubReport {
    pub fn offload_time(&self) -> Duration {
        self.wall
    }

    /// Per-phase occupancy sum (≥ `offload_time()` once transfers
    /// overlap; equal under the synchronous transport).
    pub fn occupancy(&self) -> Duration {
        self.host_to_dfe + self.dfe_to_host + self.dfe_exec
    }
}

/// Build the call-table hook shared by the single-tenant manager and the
/// serve layer: run the offload stub, fold the per-invocation report into
/// the shared [`RuntimeState`] (invocation counts, batch histogram,
/// element totals), optionally mirror the phase times into a tracer, and
/// flag failures so the rollback pass can demote the function. One
/// definition, two installers — the respecialization swap barrier relies
/// on both paths folding state identically.
#[allow(clippy::too_many_arguments)]
pub fn make_offload_hook(
    off: OffloadDfg,
    single: OffloadDfg,
    image: ExecImage,
    backend: DfeBackend,
    tm: TimeModel,
    pcie: Rc<RefCell<PcieSim>>,
    mode: TransportMode,
    state: Rc<RefCell<RuntimeState>>,
    tracer: Option<Rc<RefCell<Tracer>>>,
) -> Hook {
    let hook_unroll = off.unroll.max(1) as u64;
    Box::new(move |mem, args| {
        let mut link = pcie.borrow_mut();
        match run_offloaded_with(
            &off, &single, &image, &backend, &tm, &mut link, mode, mem, args,
        ) {
            Ok(report) => {
                let mut st = state.borrow_mut();
                st.invocations += 1;
                st.virtual_offload += report.offload_time();
                let elements = report.elements * hook_unroll + report.remainder_elements;
                st.batch_hist.record(elements);
                st.total_elements += elements;
                st.last_report = report;
                drop(st);
                if let Some(t) = &tracer {
                    let mut t = t.borrow_mut();
                    t.simulated(Phase::HostToDfe, report.host_to_dfe);
                    t.simulated(Phase::DfeExec, report.dfe_exec);
                    t.simulated(Phase::DfeToHost, report.dfe_to_host);
                }
                Ok(None)
            }
            Err(trap) => {
                state.borrow_mut().failed = true;
                Err(trap)
            }
        }
    })
}

/// [`make_offload_hook`]'s multi-tile sibling: run the SCoP as an
/// [`ExecutionPlan`] of feed-forward tiles ([`run_plan_with`]) and fold
/// the report into [`RuntimeState`] with the exact same accounting, so
/// the rollback comparator and the adapt controller treat tiled and
/// single-tile offloads uniformly.
#[allow(clippy::too_many_arguments)]
pub fn make_plan_hook(
    off: OffloadDfg,
    single: OffloadDfg,
    plan: Rc<ExecutionPlan>,
    backends: Rc<Vec<DfeBackend>>,
    tms: Rc<Vec<TimeModel>>,
    reconfig_epsilon: Duration,
    pcie: Rc<RefCell<PcieSim>>,
    mode: TransportMode,
    state: Rc<RefCell<RuntimeState>>,
    tracer: Option<Rc<RefCell<Tracer>>>,
) -> Hook {
    let hook_unroll = off.unroll.max(1) as u64;
    Box::new(move |mem, args| {
        let mut link = pcie.borrow_mut();
        match run_plan_with(
            &plan,
            &off,
            &single,
            &backends,
            &tms,
            reconfig_epsilon,
            &mut link,
            mode,
            mem,
            args,
        ) {
            Ok(report) => {
                let mut st = state.borrow_mut();
                st.invocations += 1;
                st.virtual_offload += report.offload_time();
                let elements = report.elements * hook_unroll + report.remainder_elements;
                st.batch_hist.record(elements);
                st.total_elements += elements;
                st.last_report = report;
                drop(st);
                if let Some(t) = &tracer {
                    let mut t = t.borrow_mut();
                    t.simulated(Phase::HostToDfe, report.host_to_dfe);
                    t.simulated(Phase::DfeExec, report.dfe_exec);
                    t.simulated(Phase::DfeToHost, report.dfe_to_host);
                }
                Ok(None)
            }
            Err(trap) => {
                state.borrow_mut().failed = true;
                Err(trap)
            }
        }
    })
}

/// Resolve a `Reg`-indexed argument as i32 (affine parameter).
fn param_i32(args: &[Val], r: crate::ir::instr::Reg) -> i64 {
    args.get(r.0 as usize).map(|v| v.as_i32() as i64).unwrap_or(0)
}

/// Enumerate the iteration space: returns the iv-vectors of each *group*
/// (innermost stepping by `unroll`) plus the remainder iv-vectors
/// (stepping by 1).
pub fn iteration_groups(
    off: &OffloadDfg,
    args: &[Val],
) -> (Vec<Vec<i64>>, Vec<Vec<i64>>) {
    let nest = &off.scop.nest;
    let depth = nest.len();
    let u = off.unroll as i64;
    let mut groups = Vec::new();
    let mut remainder = Vec::new();
    let params = |r| param_i32(args, r);

    // Iterative nested enumeration.
    let mut ivs: Vec<i64> = Vec::with_capacity(depth);
    fn recurse(
        nest: &[crate::analysis::scop::LoopInfo],
        d: usize,
        u: i64,
        ivs: &mut Vec<i64>,
        params: &dyn Fn(crate::ir::instr::Reg) -> i64,
        groups: &mut Vec<Vec<i64>>,
        remainder: &mut Vec<Vec<i64>>,
    ) {
        let l = &nest[d];
        let lb = l.lb.eval(ivs, params);
        let ub = l.ub.eval(ivs, params);
        if d + 1 == nest.len() {
            let n = (ub - lb).max(0);
            let main = n - n % u;
            let mut iv = lb;
            while iv < lb + main {
                ivs.push(iv);
                groups.push(ivs.clone());
                ivs.pop();
                iv += u;
            }
            while iv < ub {
                ivs.push(iv);
                remainder.push(ivs.clone());
                ivs.pop();
                iv += 1;
            }
        } else {
            let mut iv = lb;
            while iv < ub {
                ivs.push(iv);
                recurse(nest, d + 1, u, ivs, params, groups, remainder);
                ivs.pop();
                iv += 1;
            }
        }
    }
    if depth > 0 {
        recurse(nest, 0, u, &mut ivs, &params, &mut groups, &mut remainder);
    }
    (groups, remainder)
}

/// Gather/scatter + execute one invocation. Returns the virtual-time
/// report; numeric effects land in `mem`. `single` is the u=1 extraction
/// of the same SCoP, used for the < unroll remainder (pass `off` itself
/// when `off.unroll == 1`).
///
/// The batch is submitted in chunks ([`chunk_plan`]): under the
/// asynchronous transport each chunk's upload, execution and download are
/// scheduled on a [`ChunkTimeline`] so chunk *k+1*'s upload and chunk
/// *k-1*'s download overlap chunk *k*'s fabric run (the synchronous mode
/// degenerates to one blocking chunk — bit-for-bit the old behavior,
/// enforced by `tests/exec_fuzz.rs`). Chunking only re-times the
/// invocation; the values streamed through the backend are identical.
#[allow(clippy::too_many_arguments)]
pub fn run_offloaded_with(
    off: &OffloadDfg,
    single: &OffloadDfg,
    image: &ExecImage,
    backend: &DfeBackend,
    tm: &TimeModel,
    pcie: &mut PcieSim,
    mode: TransportMode,
    mem: &mut Memory,
    args: &[Val],
) -> Result<StubReport, Trap> {
    let (groups, remainder) = iteration_groups(off, args);
    let n = groups.len();
    let n_in = off.inputs.len();
    let n_out = off.outputs.len();
    let params = |r| param_i32(args, r);
    let mut report = StubReport {
        elements: n as u64,
        remainder_elements: remainder.len() as u64,
        ..Default::default()
    };

    if n > 0 {
        // Gather: slot-major [n_in, n].
        let mut x = vec![0i32; n_in * n];
        for (lane, ivs) in groups.iter().enumerate() {
            for (j, s) in off.inputs.iter().enumerate() {
                let v = match s.base {
                    Some(base) => {
                        let h = args[base.0 as usize].as_ptr();
                        let idx = s.affine.eval(ivs, &params);
                        let arr = mem.i32s(h);
                        *arr.get(idx as usize).ok_or(Trap::OutOfBounds {
                            handle: h,
                            idx: idx as i32,
                            len: arr.len(),
                        })?
                    }
                    None => s.affine.eval(ivs, &params) as i32,
                };
                x[j * n + lane] = v;
            }
        }
        report.h2d_bytes = (n_in * n * 4) as u64;
        report.d2h_bytes = (n_out * n * 4) as u64;

        // Chunked submission over the transport pipeline. Each chunk's
        // payload rides the link separately (PC->FPGA then FPGA->PC; the
        // tagged protocol quadruples it on the wire). Per-chunk fabric
        // cost is the window-end delta of the busy-interval model
        // (`dfe::exec::busy_windows`): back-to-back chunks keep the
        // pipeline streaming, so only the first pays the fill and the
        // chunk costs sum exactly to the one-shot batch time — chunking
        // re-times transfers, never the fabric.
        let plan = chunk_plan(n, mode);
        let windows =
            crate::dfe::exec::busy_windows(tm.fill_latency, tm.initiation_interval, &plan);
        let mut out: Vec<i32> = Vec::new();
        let mut tl = ChunkTimeline::new(mode);
        let mut exec_done = 0.0f64;
        for (&(start, m), &(_, busy_end)) in plan.iter().zip(&windows) {
            let up = pcie.transfer((n_in * m * 4) as u64);
            if m == n {
                // Single full-range chunk (always the case in sync mode):
                // the gathered batch is already in the ABI layout — no
                // staging copies.
                out = backend.run(image, &x, n)?;
            } else {
                let mut xc = vec![0i32; n_in * m];
                for j in 0..n_in {
                    xc[j * m..(j + 1) * m]
                        .copy_from_slice(&x[j * n + start..j * n + start + m]);
                }
                let oc = backend.run(image, &xc, m)?;
                if out.is_empty() {
                    out = vec![0i32; n_out * n];
                }
                for j in 0..n_out {
                    out[j * n + start..j * n + start + m]
                        .copy_from_slice(&oc[j * m..(j + 1) * m]);
                }
            }
            let exec_secs = (busy_end - exec_done) / tm.fmax_hz;
            exec_done = busy_end;
            let down = pcie.transfer((n_out * m * 4) as u64);
            tl.step(up.secs, exec_secs, down.secs);
            report.host_to_dfe += up.time;
            report.dfe_exec += Duration::from_secs_f64(exec_secs);
            report.dfe_to_host += down.time;
        }
        report.wall = match mode {
            // Serial sum, in the exact Duration arithmetic the
            // pre-pipeline stub used.
            TransportMode::Sync => report.host_to_dfe + report.dfe_exec + report.dfe_to_host,
            TransportMode::Async { .. } => Duration::from_secs_f64(tl.wall),
        };

        // Scatter.
        for (j, o) in off.outputs.iter().enumerate() {
            let h = args[o.base.0 as usize].as_ptr();
            match o.mode {
                OutMode::Assign => {
                    for (lane, ivs) in groups.iter().enumerate() {
                        let idx = o.affine.eval(ivs, &params);
                        let arr = mem.i32s_mut(h);
                        let len = arr.len();
                        *arr.get_mut(idx as usize).ok_or(Trap::OutOfBounds {
                            handle: h,
                            idx: idx as i32,
                            len,
                        })? = out[j * n + lane];
                    }
                }
                OutMode::Accumulate => {
                    // Fold all partials into the (iteration-invariant in
                    // the innermost dim) accumulator addresses.
                    for (lane, ivs) in groups.iter().enumerate() {
                        let idx = o.affine.eval(ivs, &params);
                        let arr = mem.i32s_mut(h);
                        let len = arr.len();
                        let slot = arr.get_mut(idx as usize).ok_or(Trap::OutOfBounds {
                            handle: h,
                            idx: idx as i32,
                            len,
                        })?;
                        *slot = slot.wrapping_add(out[j * n + lane]);
                    }
                }
            }
        }
    }

    // Remainder (< unroll innermost iterations): exact host evaluation of
    // the single-iteration DFG (cheap, keeps semantics exact without a
    // second fabric configuration).
    if !remainder.is_empty() {
        run_remainder(single, &remainder, mem, args)?;
    }
    Ok(report)
}

/// Gather/scatter + execute one invocation of a multi-tile
/// [`ExecutionPlan`]: the tiled sibling of [`run_offloaded_with`].
///
/// Tiles execute in order as passes over the same grid. Each pass:
///   * reloads the grid with the tile's configuration (the bitstream
///     rides the upload link; the switch epsilon occupies the fabric,
///     folded into the first chunk's exec so later passes' uploads can
///     hide under it);
///   * stages the tile's dense local input batch from external streams
///     and host spill slots, streams it through the tile's backend in
///     the same chunked schedule the single path uses;
///   * lands each local output on its sink — a host spill slot (read by
///     a later tile) or an external output row.
///
/// Timing rides a [`PlanTimeline`]: pass *t*'s chunk-*c* upload is
/// additionally gated on pass *t−1*'s chunk-*c* download (the spill
/// round-trips through host staging), so under the asynchronous
/// transport tile *t+1*'s upload overlaps tile *t*'s execute without
/// ever outrunning its own spilled operands. The synchronous mode is
/// the serial Duration sum, exactly like the single-tile stub. Numerics
/// are chunk-invariant and pass-exact: the plan computes bit-identical
/// values to the un-tiled DFG (`dfg::partition` invariant, pinned by
/// `tests/conformance.rs` and `tests/exec_fuzz.rs`).
#[allow(clippy::too_many_arguments)]
pub fn run_plan_with(
    plan: &ExecutionPlan,
    off: &OffloadDfg,
    single: &OffloadDfg,
    backends: &[DfeBackend],
    tms: &[TimeModel],
    reconfig_epsilon: Duration,
    pcie: &mut PcieSim,
    mode: TransportMode,
    mem: &mut Memory,
    args: &[Val],
) -> Result<StubReport, Trap> {
    assert_eq!(backends.len(), plan.tiles.len());
    assert_eq!(tms.len(), plan.tiles.len());
    let (groups, remainder) = iteration_groups(off, args);
    let n = groups.len();
    let n_in = off.inputs.len();
    let n_out = off.outputs.len();
    let params = |r| param_i32(args, r);
    let mut report = StubReport {
        elements: n as u64,
        remainder_elements: remainder.len() as u64,
        ..Default::default()
    };

    if n > 0 {
        // Gather external inputs once: slot-major [n_in, n], identical to
        // the single-tile path.
        let mut x = vec![0i32; n_in * n];
        for (lane, ivs) in groups.iter().enumerate() {
            for (j, s) in off.inputs.iter().enumerate() {
                let v = match s.base {
                    Some(base) => {
                        let h = args[base.0 as usize].as_ptr();
                        let idx = s.affine.eval(ivs, &params);
                        let arr = mem.i32s(h);
                        *arr.get(idx as usize).ok_or(Trap::OutOfBounds {
                            handle: h,
                            idx: idx as i32,
                            len: arr.len(),
                        })?
                    }
                    None => s.affine.eval(ivs, &params) as i32,
                };
                x[j * n + lane] = v;
            }
        }

        let mut spills: Vec<Vec<i32>> = vec![Vec::new(); plan.n_spills];
        let mut out = vec![0i32; n_out * n];
        let chunks = chunk_plan(n, mode);
        let mut tl = PlanTimeline::new(mode);
        let eps = reconfig_epsilon.as_secs_f64();

        for (t, tile) in plan.tiles.iter().enumerate() {
            if t > 0 {
                tl.next_pass();
            }
            let tm = &tms[t];
            let backend = &backends[t];
            let image = &tile.cached.image;
            let t_in = tile.sources.len();
            let t_out = tile.sinks.len();

            // Stage the tile's local input batch [t_in, n].
            let mut xt = vec![0i32; t_in * n];
            for (jj, src) in tile.sources.iter().enumerate() {
                let row: &[i32] = match src {
                    TileSource::External(j) => &x[j * n..(j + 1) * n],
                    TileSource::Spill(s) => &spills[*s],
                };
                xt[jj * n..(jj + 1) * n].copy_from_slice(row);
            }

            // Per-pass reconfiguration: the tile's bitstream on the
            // upload link plus the configuration-switch epsilon on the
            // fabric.
            let cfg_bytes = tile.cached.config.config_words() as u64 * 4;
            let cfg = pcie.transfer(cfg_bytes);
            report.h2d_bytes += cfg_bytes;
            report.host_to_dfe += cfg.time;
            report.dfe_exec += reconfig_epsilon;
            let mut reconfig = cfg.secs + eps;

            let windows =
                crate::dfe::exec::busy_windows(tm.fill_latency, tm.initiation_interval, &chunks);
            let mut ot: Vec<i32> = Vec::new();
            let mut exec_done = 0.0f64;
            for (&(start, m), &(_, busy_end)) in chunks.iter().zip(&windows) {
                let up = pcie.transfer((t_in * m * 4) as u64);
                if m == n {
                    ot = backend.run(image, &xt, n)?;
                } else {
                    let mut xc = vec![0i32; t_in * m];
                    for j in 0..t_in {
                        xc[j * m..(j + 1) * m]
                            .copy_from_slice(&xt[j * n + start..j * n + start + m]);
                    }
                    let oc = backend.run(image, &xc, m)?;
                    if ot.is_empty() {
                        ot = vec![0i32; t_out * n];
                    }
                    for j in 0..t_out {
                        ot[j * n + start..j * n + start + m]
                            .copy_from_slice(&oc[j * m..(j + 1) * m]);
                    }
                }
                let exec_secs = (busy_end - exec_done) / tm.fmax_hz;
                exec_done = busy_end;
                let down = pcie.transfer((t_out * m * 4) as u64);
                // The reconfiguration gates (and is hidden by) only the
                // first chunk of the pass on the timeline.
                tl.step(up.secs, exec_secs + reconfig, down.secs);
                reconfig = 0.0;
                report.h2d_bytes += (t_in * m * 4) as u64;
                report.d2h_bytes += (t_out * m * 4) as u64;
                report.host_to_dfe += up.time;
                report.dfe_exec += Duration::from_secs_f64(exec_secs);
                report.dfe_to_host += down.time;
            }

            // Land local outputs on their sinks.
            for (jj, sink) in tile.sinks.iter().enumerate() {
                let row = &ot[jj * n..(jj + 1) * n];
                match sink {
                    TileSink::Spill(s) => spills[*s] = row.to_vec(),
                    TileSink::External(j) => {
                        out[j * n..(j + 1) * n].copy_from_slice(row)
                    }
                }
            }
        }
        report.wall = match mode {
            // Serial sum in Duration arithmetic, like the single path.
            TransportMode::Sync => report.host_to_dfe + report.dfe_exec + report.dfe_to_host,
            TransportMode::Async { .. } => Duration::from_secs_f64(tl.wall()),
        };

        // Scatter external outputs (identical to the single-tile path).
        for (j, o) in off.outputs.iter().enumerate() {
            let h = args[o.base.0 as usize].as_ptr();
            match o.mode {
                OutMode::Assign => {
                    for (lane, ivs) in groups.iter().enumerate() {
                        let idx = o.affine.eval(ivs, &params);
                        let arr = mem.i32s_mut(h);
                        let len = arr.len();
                        *arr.get_mut(idx as usize).ok_or(Trap::OutOfBounds {
                            handle: h,
                            idx: idx as i32,
                            len,
                        })? = out[j * n + lane];
                    }
                }
                OutMode::Accumulate => {
                    for (lane, ivs) in groups.iter().enumerate() {
                        let idx = o.affine.eval(ivs, &params);
                        let arr = mem.i32s_mut(h);
                        let len = arr.len();
                        let slot = arr.get_mut(idx as usize).ok_or(Trap::OutOfBounds {
                            handle: h,
                            idx: idx as i32,
                            len,
                        })?;
                        *slot = slot.wrapping_add(out[j * n + lane]);
                    }
                }
            }
        }
    }

    if !remainder.is_empty() {
        run_remainder(single, &remainder, mem, args)?;
    }
    Ok(report)
}

/// Host-exact evaluation of remainder iterations on the u=1 DFG.
pub fn run_remainder(
    single: &OffloadDfg,
    remainder: &[Vec<i64>],
    mem: &mut Memory,
    args: &[Val],
) -> Result<(), Trap> {
    let params = |r| param_i32(args, r);
    for ivs in remainder {
        let mut inputs = Vec::with_capacity(single.inputs.len());
        for s in &single.inputs {
            let v = match s.base {
                Some(base) => {
                    let h = args[base.0 as usize].as_ptr();
                    let idx = s.affine.eval(ivs, &params);
                    let arr = mem.i32s(h);
                    *arr.get(idx as usize).ok_or(Trap::OutOfBounds {
                        handle: h,
                        idx: idx as i32,
                        len: arr.len(),
                    })?
                }
                None => s.affine.eval(ivs, &params) as i32,
            };
            inputs.push(v);
        }
        let outs = single.dfg.eval(&inputs).map_err(|_| Trap::BadHandle(u32::MAX))?;
        for (j, o) in single.outputs.iter().enumerate() {
            let h = args[o.base.0 as usize].as_ptr();
            let idx = o.affine.eval(ivs, &params);
            let arr = mem.i32s_mut(h);
            let len = arr.len();
            let slot = arr.get_mut(idx as usize).ok_or(Trap::OutOfBounds {
                handle: h,
                idx: idx as i32,
                len,
            })?;
            match o.mode {
                OutMode::Assign => *slot = outs[j],
                OutMode::Accumulate => *slot = slot.wrapping_add(outs[j]),
            }
        }
    }
    Ok(())
}

//! Fault-tolerant fleet serving: the multi-tenant server scaled past one
//! host onto N remote DFE nodes reached over lossy datagram links
//! (ROADMAP item 2; the degradation philosophy of Cong et al.'s
//! best-effort framing).
//!
//! Failure is a first-class input. Every node carries a seeded
//! [`NetLink`] fault schedule (drop / duplicate / reorder / jitter /
//! crash windows — `transport::net`), and the scheduler wraps it in the
//! standard reliability ladder:
//!
//!   * **idempotent invocation keys** — a result datagram applies at most
//!     once, so duplicated or reordered deliveries never double-apply;
//!   * **capped exponential backoff with jitter** on retransmit
//!     ([`backoff_delay`]);
//!   * **a circuit breaker** per node (closed → open → half-open probe →
//!     closed, [`Breaker`]): drops open it after a consecutive-failure
//!     threshold, a crash-window refusal opens it immediately;
//!   * **admission backpressure** — remote-eligible requests defer a
//!     round instead of piling onto a saturated healthy fleet;
//!   * **graceful degradation** — a request that exhausts its retry
//!     budget (or finds no usable node) falls back to the *local* shard
//!     fabric, and tenants with no fabric path at all serve on the
//!     interpreter.
//!
//! The crate's timing discipline makes degradation total-order-safe by
//! construction: numerics always execute locally through the tenant's
//! patched engine (the network only decides *where the virtual time is
//! spent*), so serve output is bit-identical to the no-fault run under
//! any fault schedule — faults cost latency and retry/fallback counters,
//! never correctness (`tests/fleet.rs` enforces this against the
//! single-tenant oracle).

// Fleet hot path: recoverable faults are the normal case here — a panic
// would defeat the whole degradation ladder. See clippy.toml.
#![cfg_attr(not(test), deny(clippy::disallowed_methods))]

use std::collections::HashSet;
use std::fmt;
use std::time::Duration;

use crate::transport::{
    expected_sends, Attempt, FaultProfile, NetLink, NetParams, NetStats, NodeTimeline,
};
use crate::util::err::{Error, Result};
use crate::util::prng::Rng;

use super::server::{
    pick_batch, pick_shard, OffloadServer, ServeError, ServeParams, ServeReport, TenantSpec,
    WARMUP_REQUESTS,
};

/// Fleet topology + reliability tunables.
#[derive(Clone, Debug)]
pub struct FleetParams {
    /// Remote DFE nodes.
    pub nodes: usize,
    /// Shared link model; `net.fault` is the default per-node profile.
    pub net: NetParams,
    /// Per-node fault overrides (index-matched; missing entries use
    /// `net.fault`) — e.g. one dead node in an otherwise healthy fleet.
    pub node_faults: Vec<FaultProfile>,
    /// Seeds every node's fault schedule and the backoff jitter stream;
    /// one seed replays an entire chaos run bit-for-bit.
    pub fault_seed: u64,
    /// Retransmit attempts after the first send.
    pub max_retries: u32,
    /// First backoff envelope in seconds (doubles per attempt).
    pub backoff_base: f64,
    /// Backoff envelope ceiling in seconds.
    pub backoff_cap: f64,
    /// Consecutive failures that open a node's circuit breaker.
    pub breaker_threshold: u32,
    /// Seconds an open breaker waits before admitting a half-open probe.
    pub breaker_cooldown: f64,
    /// Exchanges a node accepts per scheduling round before the admission
    /// controller defers further remote work (backpressure).
    pub node_depth: usize,
}

impl Default for FleetParams {
    fn default() -> Self {
        FleetParams {
            nodes: 2,
            net: NetParams::lan_like(),
            node_faults: Vec::new(),
            fault_seed: 0xF1EE7,
            max_retries: 4,
            backoff_base: 0.5e-3,
            backoff_cap: 8e-3,
            breaker_threshold: 3,
            breaker_cooldown: 20e-3,
            node_depth: 4,
        }
    }
}

/// Per-node circuit breaker state.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Breaker {
    /// Healthy: exchanges flow.
    Closed,
    /// Tripped: no exchanges until `until`, then a half-open probe.
    Open { until: f64 },
    /// Probing: one exchange decides — success closes, failure reopens.
    HalfOpen,
}

impl fmt::Display for Breaker {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Breaker::Closed => write!(f, "closed"),
            Breaker::Open { .. } => write!(f, "open"),
            Breaker::HalfOpen => write!(f, "half-open"),
        }
    }
}

/// Deterministic backoff envelope for retransmit `attempt` (0-based):
/// `base * 2^attempt`, capped at `cap`.
pub fn backoff_envelope(base: f64, cap: f64, attempt: u32) -> f64 {
    (base * 2f64.powi(attempt.min(62) as i32)).min(cap)
}

/// Jittered backoff delay: uniform in `[envelope/2, envelope]` (decorrelates
/// retransmit storms across tenants without ever exceeding the envelope).
pub fn backoff_delay(base: f64, cap: f64, attempt: u32, rng: &mut Rng) -> f64 {
    let env = backoff_envelope(base, cap, attempt);
    env * (0.5 + 0.5 * rng.f64())
}

/// Idempotency key for one invocation of one tenant: stable across
/// retransmits (a retry reuses the key, so a late or duplicated result
/// for the same invocation can never apply twice). SplitMix64-style
/// finalizer over (tenant, seq).
pub fn invocation_key(tenant: usize, seq: u64) -> u64 {
    let mut x = (tenant as u64 + 1)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(seq.wrapping_mul(0xBF58_476D_1CE4_E5B9));
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// One remote DFE node: its fault-scheduled link, occupancy timeline and
/// health tracking.
#[derive(Clone, Debug)]
pub struct FleetNode {
    pub link: NetLink,
    pub timeline: NodeTimeline,
    pub breaker: Breaker,
    pub consecutive_failures: u32,
    /// Exchanges admitted this round (reset at every round boundary —
    /// the backpressure budget).
    pub inflight: usize,
    /// Configuration resident on the node's fabric (a cache key).
    pub resident: Option<u64>,
    pub served: u64,
    pub reconfigs: u64,
    pub breaker_opens: u64,
    pub breaker_closes: u64,
}

impl FleetNode {
    pub fn new(net: NetParams, node: usize, seed: u64) -> FleetNode {
        FleetNode {
            link: NetLink::new(net, node, seed),
            timeline: NodeTimeline::new(),
            breaker: Breaker::Closed,
            consecutive_failures: 0,
            inflight: 0,
            resident: None,
            served: 0,
            reconfigs: 0,
            breaker_opens: 0,
            breaker_closes: 0,
        }
    }

    /// Promote an expired open window to half-open (one probe allowed).
    pub fn probe(&mut self, now: f64) {
        if let Breaker::Open { until } = self.breaker {
            if now >= until {
                self.breaker = Breaker::HalfOpen;
            }
        }
    }

    /// One failed exchange: a half-open probe reopens immediately, a
    /// closed breaker opens at `threshold` consecutive failures.
    pub fn record_failure(&mut self, now: f64, threshold: u32, cooldown: f64) {
        self.consecutive_failures += 1;
        match self.breaker {
            Breaker::HalfOpen => {
                self.breaker = Breaker::Open { until: now + cooldown };
                self.breaker_opens += 1;
            }
            Breaker::Closed if self.consecutive_failures >= threshold => {
                self.breaker = Breaker::Open { until: now + cooldown };
                self.breaker_opens += 1;
            }
            _ => {}
        }
    }

    /// A crash-window refusal: the node is observably down for a long
    /// window, so the breaker opens immediately — the consecutive-failure
    /// threshold is for flaky links (drops), not dead nodes.
    pub fn record_crash(&mut self, now: f64, cooldown: f64) {
        self.consecutive_failures += 1;
        if !matches!(self.breaker, Breaker::Open { .. }) {
            self.breaker = Breaker::Open { until: now + cooldown };
            self.breaker_opens += 1;
        }
    }

    /// One delivered exchange: resets the failure streak; a successful
    /// half-open probe closes the breaker.
    pub fn record_success(&mut self) {
        self.consecutive_failures = 0;
        if self.breaker == Breaker::HalfOpen {
            self.breaker = Breaker::Closed;
            self.breaker_closes += 1;
        }
    }

    /// Observed per-exchange loss rate (drops + crash refusals), falling
    /// back to the configured drop probability before any evidence — the
    /// transport-aware placement penalty's input.
    pub fn drop_estimate(&self) -> f64 {
        let s = &self.link.stats;
        if s.exchanges == 0 {
            return self.link.params.fault.drop;
        }
        (s.dropped + s.crash_windows) as f64 / s.exchanges as f64
    }
}

/// Fleet-level counters (sums of the per-tenant counters plus the
/// idempotency ledger — `tests/fleet.rs` asserts their invariants).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FleetCounters {
    /// Remote-eligible requests dispatched to the fleet.
    pub remote_requests: u64,
    /// Results applied through the idempotency ledger (exactly one per
    /// delivered remote request).
    pub applied_results: u64,
    /// Duplicate result datagrams absorbed by the ledger.
    pub dup_suppressed: u64,
    /// Reordered result datagrams absorbed (keyed application makes
    /// ordering irrelevant).
    pub reordered_absorbed: u64,
    /// Retransmit attempts across all tenants.
    pub retries: u64,
    /// Requests deferred a round by backpressure.
    pub deferred: u64,
    /// Requests degraded to the local shard fabric.
    pub fallback_local: u64,
    /// Requests served on the interpreter (no fabric path).
    pub fallback_software: u64,
    /// Requests shed to the host by SLO admission control (distinct from
    /// `fallback_software`: shedding is a policy decision on a healthy
    /// fabric path, not a degradation rung).
    pub shed: u64,
}

/// The fleet scheduler: wraps the single-host [`OffloadServer`] (which
/// keeps owning tenants, shards, cache and compile service) and replaces
/// its link scheduling with per-node datagram exchanges plus the
/// reliability ladder.
pub struct FleetServer {
    pub server: OffloadServer,
    pub params: FleetParams,
    pub nodes: Vec<FleetNode>,
    pub counters: FleetCounters,
    /// Backoff-jitter stream (distinct from every node's fault stream).
    rng: Rng,
    /// The idempotency ledger: invocation keys whose result has applied.
    applied: HashSet<u64>,
    /// Virtual fleet clock in f64 seconds.
    clock: f64,
}

impl FleetServer {
    pub fn new(
        serve: ServeParams,
        mut fleet: FleetParams,
        specs: Vec<TenantSpec>,
    ) -> Result<FleetServer> {
        if fleet.nodes == 0 {
            return Err(Error::msg(ServeError::NoNodes));
        }
        // A zero depth would deadlock the backpressure controller.
        fleet.node_depth = fleet.node_depth.max(1);
        let server = OffloadServer::new(serve, specs)?;
        let nodes = (0..fleet.nodes)
            .map(|i| {
                let fault = fleet.node_faults.get(i).copied().unwrap_or(fleet.net.fault);
                FleetNode::new(NetParams { fault, ..fleet.net }, i, fleet.fault_seed)
            })
            .collect();
        let rng = Rng::new(fleet.fault_seed ^ 0xB0FF_0FF5_EED5_EED1);
        Ok(FleetServer {
            server,
            params: fleet,
            nodes,
            counters: FleetCounters::default(),
            rng,
            applied: HashSet::new(),
            clock: 0.0,
        })
    }

    pub fn n_tenants(&self) -> usize {
        self.server.n_tenants()
    }

    /// A tenant's observable output arrays (for verification).
    pub fn tenant_outputs(&self, i: usize) -> Vec<Vec<i32>> {
        self.server.tenant_outputs(i)
    }

    /// Serve `requests_per_tenant` per tenant across the fleet. Same
    /// numerics block as [`OffloadServer::run`] (execute → trap rollback →
    /// decide placement); the virtual-time block dispatches offloaded
    /// requests to remote nodes with retries, breakers and degradation
    /// instead of onto the local shared link.
    pub fn run(&mut self, requests_per_tenant: u64) -> FleetReport {
        let n_t = self.server.tenants.len();
        let window = if self.server.params.batch_window == 0 {
            n_t
        } else {
            self.server.params.batch_window
        };
        let mut remaining: Vec<u64> = vec![requests_per_tenant; n_t];
        let mut host_free = self.clock;

        while remaining.iter().any(|&r| r > 0) {
            self.server.pump_compiles();
            let round_start = self.clock;
            for n in self.nodes.iter_mut() {
                n.inflight = 0;
            }

            // ---- admission: priority- and hotness-weighted round robin ----
            // Same discipline as the single-host server: weights clamp
            // hotness at the fairness floor before scaling by the SLO
            // class, and `total_cmp` keeps the order total (a NaN
            // hotness can no longer make two fleet replays diverge).
            let weights: Vec<f64> = self
                .server
                .tenants
                .iter()
                .map(|t| t.hotness.max(1.0) * f64::from(t.spec.priority.max(1)))
                .collect();
            let mut order: Vec<usize> = (0..n_t).filter(|&i| remaining[i] > 0).collect();
            order.sort_by(|&a, &b| weights[b].total_cmp(&weights[a]).then(a.cmp(&b)));
            let mut batch = pick_batch(&order, &weights, &remaining, window);
            batch.sort_by_key(|&ti| {
                (
                    std::cmp::Reverse(self.server.tenants[ti].spec.priority),
                    self.server.tenants[ti].offload.as_ref().map(|o| o.key).unwrap_or(0),
                )
            });
            let top_priority = batch
                .iter()
                .map(|&ti| self.server.tenants[ti].spec.priority)
                .max()
                .unwrap_or(0);

            let mut round_load = vec![0u32; self.server.shards.len()];
            let mut round_end = round_start;
            // Projected remote fabric occupancy this round (SLO admission
            // control, mirroring the single-host server).
            let mut projected = 0f64;

            for &ti in &batch {
                // Backpressure: defer a remote-eligible request when every
                // healthy node's round budget is spent. Budgets reset each
                // round and depth >= 1, so the round's first eligible
                // request always proceeds — progress is guaranteed.
                let eligible = {
                    let t = &self.server.tenants[ti];
                    !t.rolled_back && t.offload.is_some() && t.engine.is_patched(t.func)
                };
                if eligible {
                    let healthy = |n: &FleetNode| {
                        !matches!(n.breaker, Breaker::Open { until } if round_start < until)
                            && !n.link.is_down(round_start)
                    };
                    let any_healthy = self.nodes.iter().any(healthy);
                    let any_capacity = self
                        .nodes
                        .iter()
                        .any(|n| healthy(n) && n.inflight < self.params.node_depth);
                    if any_healthy && !any_capacity {
                        self.counters.deferred += 1;
                        continue; // remaining[ti] untouched: next round.
                    }
                }
                remaining[ti] -= 1;
                let seq = WARMUP_REQUESTS + self.server.tenants[ti].served;

                // ---- numerics now; virtual time modeled below ----
                {
                    let tenant = &mut self.server.tenants[ti];
                    if let Some(refresh) = tenant.spec.refresh {
                        refresh(&mut tenant.mem, &tenant.args, seq);
                    }
                }
                let snapshot: Option<Vec<(u32, Vec<i32>)>> = {
                    let t = &self.server.tenants[ti];
                    (!t.rolled_back && t.offload.is_some() && t.engine.is_patched(t.func))
                        .then(|| {
                            t.out_handles
                                .iter()
                                .map(|&h| (h, t.mem.i32s(h).to_vec()))
                                .collect()
                        })
                };
                let call_ok = {
                    let tenant = &mut self.server.tenants[ti];
                    tenant
                        .engine
                        .call_idx(tenant.func, &mut tenant.mem, &tenant.args)
                        .is_ok()
                };
                if !call_ok {
                    // Trap in the offloaded path: restore, roll back to
                    // software and replay — the same failure rollback as
                    // the single-host server.
                    let tenant = &mut self.server.tenants[ti];
                    tenant.engine.unpatch(tenant.func);
                    tenant.rolled_back = true;
                    if let Some(snap) = snapshot {
                        for (h, data) in snap {
                            tenant.mem.i32s_mut(h).copy_from_slice(&data);
                        }
                    }
                    if let Err(e) =
                        tenant.engine.call_idx(tenant.func, &mut tenant.mem, &tenant.args)
                    {
                        tenant.reject = Some(format!("software replay failed: {e}"));
                    }
                }

                // ---- virtual time: remote, degraded-local, or software ----
                // Unwrap-free offload identity: a tenant with a missing
                // offload record or runtime state (never offloaded, or
                // demoted mid-run) rides the software rung instead of
                // panicking the fleet loop.
                let offload_info = {
                    let t = &self.server.tenants[ti];
                    if t.rolled_back || !t.engine.is_patched(t.func) {
                        None
                    } else {
                        t.offload.as_ref().zip(t.state.as_ref()).map(|(o, state)| {
                            let r = state.borrow().last_report;
                            (
                                o.key,
                                o.config_words * 4,
                                r.h2d_bytes,
                                r.d2h_bytes,
                                r.dfe_exec.as_secs_f64(),
                            )
                        })
                    }
                };
                // SLO admission control, fleet flavor: once this round's
                // projected fabric seconds exceed the objective, requests
                // below the batch's top class stay on the host. Numerics
                // already ran — only the virtual-time arm changes.
                let shed = match (&offload_info, self.server.params.slo) {
                    (Some((_, _, _, _, exec)), Some(slo)) => {
                        self.server.tenants[ti].spec.priority < top_priority
                            && projected + exec > slo
                    }
                    _ => false,
                };
                match offload_info {
                    Some((key, cfg_bytes, h2d, d2h, exec)) if !shed => {
                        self.counters.remote_requests += 1;
                        let inv_key = invocation_key(ti, seq);
                        match self.serve_remote(
                            ti, inv_key, key, cfg_bytes, h2d, d2h, exec, round_start,
                        ) {
                            Some(done) => {
                                self.server.tenants[ti].remote_served += 1;
                                round_end = round_end.max(done);
                                self.server.tenants[ti].latency.record(
                                    Duration::from_secs_f64((done - round_start).max(0.0)),
                                );
                            }
                            None => {
                                // Degradation rung 1: the local shard fabric.
                                let done = self.fallback_local(
                                    key, cfg_bytes, h2d, d2h, exec, round_start,
                                    &mut round_load,
                                );
                                self.counters.fallback_local += 1;
                                let t = &mut self.server.tenants[ti];
                                t.fallback_local += 1;
                                t.latency.record(Duration::from_secs_f64(
                                    (done - round_start).max(0.0),
                                ));
                                round_end = round_end.max(done);
                            }
                        }
                        projected += exec;
                    }
                    _ => {
                        // Degradation rung 2: the interpreter (one
                        // serialized host core) — also the shed tier.
                        let t = &mut self.server.tenants[ti];
                        host_free =
                            host_free.max(round_start) + t.baseline_per_inv.as_secs_f64();
                        if shed {
                            t.shed += 1;
                            self.counters.shed += 1;
                        } else {
                            t.fallback_software += 1;
                            self.counters.fallback_software += 1;
                        }
                        t.latency.record(t.baseline_per_inv);
                        round_end = round_end.max(host_free);
                    }
                }
                self.server.tenants[ti].served += 1;
            }

            self.clock = round_end.max(round_start);
            self.server.clock = Duration::from_secs_f64(self.clock);

            // ---- per-tenant rollback pass over this round ----
            for &ti in &batch {
                let t = &mut self.server.tenants[ti];
                if t.rolled_back {
                    continue;
                }
                let Some(state) = t.state.clone() else { continue };
                let st = state.borrow();
                let decided =
                    st.failed || st.invocations >= self.server.params.rollback_window;
                if decided && st.invocations > 0 {
                    let per_inv = st.virtual_offload / st.invocations as u32;
                    if st.failed || per_inv > t.baseline_per_inv {
                        drop(st);
                        t.engine.unpatch(t.func);
                        t.rolled_back = true;
                    }
                }
            }

            // ---- per-tenant adaptive respecialization pass ----
            if let Some(ap) = self.server.params.adapt.clone() {
                for ti in 0..n_t {
                    self.server.adapt_tenant(ti, &ap);
                }
            }
        }
        self.report()
    }

    /// Dispatch one remote exchange with retries. Returns the completion
    /// time on success; `None` when the retry budget is exhausted or no
    /// node is usable (the caller degrades to the local fabric).
    #[allow(clippy::too_many_arguments)]
    fn serve_remote(
        &mut self,
        ti: usize,
        inv_key: u64,
        cfg_key: u64,
        cfg_bytes: u64,
        h2d: u64,
        d2h: u64,
        exec: f64,
        round_start: f64,
    ) -> Option<f64> {
        let mut now = round_start;
        for attempt in 0..=self.params.max_retries {
            let node = self.pick_node(cfg_key, now)?;
            let (up_payload, exec_total, reconfig) = {
                let n = &self.nodes[node];
                if n.resident == Some(cfg_key) {
                    (h2d, exec, false)
                } else {
                    let eps = self.server.params.reconfig_epsilon.as_secs_f64();
                    (cfg_bytes + h2d, exec + eps, true)
                }
            };
            match self.nodes[node].link.exchange(up_payload, d2h, exec_total, now) {
                Attempt::Delivered { up, down, dup, reordered } => {
                    let n = &mut self.nodes[node];
                    if reconfig {
                        n.resident = Some(cfg_key);
                        n.reconfigs += 1;
                    }
                    let (_, done) = n.timeline.exchange(up, exec_total, down, now);
                    n.inflight += 1;
                    n.served += 1;
                    n.record_success();
                    // Idempotent application: the first result for this
                    // invocation key applies, every later copy — a
                    // duplicate datagram or a reordered straggler — is a
                    // ledger no-op.
                    if self.applied.insert(inv_key) {
                        self.counters.applied_results += 1;
                    } else {
                        self.counters.dup_suppressed += 1;
                    }
                    if dup && !self.applied.insert(inv_key) {
                        self.counters.dup_suppressed += 1;
                    }
                    if reordered {
                        self.counters.reordered_absorbed += 1;
                    }
                    return Some(done);
                }
                Attempt::Lost { wait } => {
                    now += wait;
                    self.nodes[node].record_failure(
                        now,
                        self.params.breaker_threshold,
                        self.params.breaker_cooldown,
                    );
                }
                Attempt::Down { until: _ } => {
                    // The caller only learns from its own timer, not the
                    // crash window's true span. A crash opens the breaker
                    // immediately (no threshold): the node is down for a
                    // whole window, not flaking on one datagram.
                    now += self.params.net.timeout;
                    self.nodes[node].record_crash(now, self.params.breaker_cooldown);
                }
            }
            if attempt < self.params.max_retries {
                self.counters.retries += 1;
                self.server.tenants[ti].retries += 1;
                now += backoff_delay(
                    self.params.backoff_base,
                    self.params.backoff_cap,
                    attempt,
                    &mut self.rng,
                );
            }
        }
        None
    }

    /// Pick the node for `cfg_key` at `now`: configuration affinity first
    /// among usable nodes, otherwise the transport-aware score — earliest
    /// availability plus the expected retransmit cost of the node's
    /// observed loss rate — so flaky nodes lose placements to healthy
    /// ones.
    fn pick_node(&mut self, cfg_key: u64, now: f64) -> Option<usize> {
        for n in self.nodes.iter_mut() {
            n.probe(now);
        }
        let depth = self.params.node_depth;
        let usable = |n: &FleetNode| {
            !matches!(n.breaker, Breaker::Open { .. })
                && !n.link.is_down(now)
                && n.inflight < depth
        };
        if let Some(i) =
            self.nodes.iter().position(|n| usable(n) && n.resident == Some(cfg_key))
        {
            return Some(i);
        }
        let mut best: Option<(usize, f64)> = None;
        for (i, n) in self.nodes.iter().enumerate() {
            if !usable(n) {
                continue;
            }
            let penalty = (expected_sends(n.drop_estimate(), self.params.max_retries) - 1.0)
                * n.link.params.timeout;
            let score = n.timeline.available(now) + penalty;
            if best.map(|(_, s)| score < s).unwrap_or(true) {
                best = Some((i, score));
            }
        }
        best.map(|(i, _)| i)
    }

    /// Degradation rung 1: run the exchange on the local shard fabric
    /// with the single-host sync accounting (PCIe up → exec → PCIe down,
    /// serialized per shard). Returns the completion time.
    #[allow(clippy::too_many_arguments)]
    fn fallback_local(
        &mut self,
        key: u64,
        cfg_bytes: u64,
        h2d: u64,
        d2h: u64,
        exec: f64,
        now: f64,
        round_load: &mut [u32],
    ) -> f64 {
        let shard = pick_shard(&self.server.shards, round_load, key);
        round_load[shard] += 1;
        let pcie = self.server.params.pcie;
        let eps = self.server.params.reconfig_epsilon.as_secs_f64();
        let mut cost = pcie.transfer_secs(h2d) + exec + pcie.transfer_secs(d2h);
        let s = &mut self.server.shards[shard];
        if s.resident != Some(key) {
            s.resident = Some(key);
            s.reconfigs += 1;
            cost += eps + pcie.transfer_secs(cfg_bytes);
        }
        let start = s.busy_secs.max(now);
        s.busy_secs = start + cost;
        s.busy_until = Duration::from_secs_f64(s.busy_secs);
        s.executed += 1;
        s.busy_secs
    }

    /// Assemble the fleet report (the wrapped serve report plus per-node
    /// health/traffic and the reliability counters).
    pub fn report(&self) -> FleetReport {
        FleetReport {
            serve: self.server.report(),
            nodes: self
                .nodes
                .iter()
                .enumerate()
                .map(|(i, n)| NodeReport {
                    node: i,
                    served: n.served,
                    reconfigs: n.reconfigs,
                    breaker_opens: n.breaker_opens,
                    breaker_closes: n.breaker_closes,
                    breaker: n.breaker,
                    net: n.link.stats,
                })
                .collect(),
            counters: self.counters,
        }
    }
}

/// One node's slice of the fleet report.
#[derive(Clone, Copy, Debug)]
pub struct NodeReport {
    pub node: usize,
    pub served: u64,
    pub reconfigs: u64,
    pub breaker_opens: u64,
    pub breaker_closes: u64,
    pub breaker: Breaker,
    pub net: NetStats,
}

/// The aggregate fleet report: the wrapped [`ServeReport`] plus per-node
/// health and the reliability-ladder counters.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub serve: ServeReport,
    pub nodes: Vec<NodeReport>,
    pub counters: FleetCounters,
}

impl fmt::Display for FleetReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{}", self.serve)?;
        for n in &self.nodes {
            writeln!(
                f,
                "node {} [{}]: {} served, {} reconfigs, breaker {}x open/{}x closed, \
                 net {}ex/{}del/{}drop/{}dup/{}reord/{}crash",
                n.node,
                n.breaker,
                n.served,
                n.reconfigs,
                n.breaker_opens,
                n.breaker_closes,
                n.net.exchanges,
                n.net.delivered,
                n.net.dropped,
                n.net.duplicated,
                n.net.reordered,
                n.net.crash_windows,
            )?;
        }
        let c = &self.counters;
        write!(
            f,
            "fleet: {} remote ({} applied, {} dup suppressed, {} reordered absorbed), \
             {} retries, {} deferred, {} fell back local, {} software, {} shed",
            c.remote_requests,
            c.applied_results,
            c.dup_suppressed,
            c.reordered_absorbed,
            c.retries,
            c.deferred,
            c.fallback_local,
            c.fallback_software,
            c.shed,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node() -> FleetNode {
        FleetNode::new(NetParams::lan_like(), 0, 1)
    }

    #[test]
    fn breaker_opens_probes_and_recovers() {
        let mut n = node();
        // Two failures stay closed at threshold 3.
        n.record_failure(0.0, 3, 1.0);
        n.record_failure(0.0, 3, 1.0);
        assert_eq!(n.breaker, Breaker::Closed);
        // Third consecutive failure trips it.
        n.record_failure(0.0, 3, 1.0);
        assert_eq!(n.breaker, Breaker::Open { until: 1.0 });
        assert_eq!(n.breaker_opens, 1);
        // Cooldown not elapsed: stays open. Elapsed: half-open probe.
        n.probe(0.5);
        assert!(matches!(n.breaker, Breaker::Open { .. }));
        n.probe(1.0);
        assert_eq!(n.breaker, Breaker::HalfOpen);
        // A failed probe reopens immediately (no threshold wait).
        n.record_failure(1.0, 3, 1.0);
        assert_eq!(n.breaker, Breaker::Open { until: 2.0 });
        assert_eq!(n.breaker_opens, 2);
        // A successful probe closes and resets the streak.
        n.probe(2.0);
        n.record_success();
        assert_eq!(n.breaker, Breaker::Closed);
        assert_eq!(n.breaker_closes, 1);
        assert_eq!(n.consecutive_failures, 0);
    }

    #[test]
    fn crash_refusal_opens_the_breaker_immediately() {
        let mut n = node();
        // No threshold wait: one crash-window refusal trips it.
        n.record_crash(5.0, 1.0);
        assert_eq!(n.breaker, Breaker::Open { until: 6.0 });
        assert_eq!(n.breaker_opens, 1);
        // A second refusal while already open does not double-count.
        n.record_crash(5.5, 1.0);
        assert_eq!(n.breaker_opens, 1);
        // The usual recovery path still applies.
        n.probe(6.0);
        assert_eq!(n.breaker, Breaker::HalfOpen);
        n.record_success();
        assert_eq!(n.breaker, Breaker::Closed);
    }

    #[test]
    fn backoff_envelope_doubles_then_caps() {
        let (base, cap) = (1e-3, 6e-3);
        assert_eq!(backoff_envelope(base, cap, 0), 1e-3);
        assert_eq!(backoff_envelope(base, cap, 1), 2e-3);
        assert_eq!(backoff_envelope(base, cap, 2), 4e-3);
        assert_eq!(backoff_envelope(base, cap, 3), 6e-3, "capped");
        assert_eq!(backoff_envelope(base, cap, 60), 6e-3, "stays capped");
        let mut rng = Rng::new(9);
        for attempt in 0..8 {
            let d = backoff_delay(base, cap, attempt, &mut rng);
            let env = backoff_envelope(base, cap, attempt);
            assert!(d > 0.0 && d <= env, "jitter must stay inside the envelope");
            assert!(d >= env / 2.0, "jitter floor is half the envelope");
        }
    }

    #[test]
    fn invocation_keys_are_distinct_per_tenant_and_seq() {
        let mut seen = std::collections::HashSet::new();
        for tenant in 0..16 {
            for seq in 0..256 {
                assert!(
                    seen.insert(invocation_key(tenant, seq)),
                    "collision at ({tenant}, {seq})"
                );
                // Retransmits reuse the key: stability is the whole point.
                assert_eq!(invocation_key(tenant, seq), invocation_key(tenant, seq));
            }
        }
    }

    #[test]
    fn fleet_rejects_zero_nodes_structurally() {
        let err = FleetServer::new(
            ServeParams::default(),
            FleetParams { nodes: 0, ..Default::default() },
            vec![super::super::server::gemm_spec()],
        )
        .unwrap_err();
        assert!(err.to_string().contains("at least one node"), "{err}");
    }

    #[test]
    fn drop_estimate_prior_then_observed() {
        let fault = FaultProfile { drop: 0.25, ..FaultProfile::healthy() };
        let mut n = FleetNode::new(
            NetParams { fault, ..NetParams::lan_like() },
            0,
            3,
        );
        assert_eq!(n.drop_estimate(), 0.25, "configured prior before evidence");
        for _ in 0..200 {
            n.link.exchange(64, 64, 0.0, 0.0);
        }
        let est = n.drop_estimate();
        assert!((0.1..0.45).contains(&est), "observed rate near 0.25, got {est}");
    }
}

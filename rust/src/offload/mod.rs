//! The offload manager — the paper's runtime decision engine (Fig 1).
//!
//! Pipeline per hot function: analysis (SCoP + extraction + legality +
//! size threshold) → place & route (with the configuration cache) →
//! configuration download (modeled) → call-table patch with the wrapper
//! stub → continuous monitoring with rollback ("we continuously monitor
//! the execution time and we roll back to the initial software should the
//! produced implementation perform worse than the original one").

pub mod adapt;
pub mod fleet;
pub mod latency;
pub mod server;
pub mod stub;

use std::cell::RefCell;
use std::collections::{HashMap, HashSet};
use std::rc::Rc;
use std::time::{Duration, Instant};

use crate::analysis::scop::analyze_function;
use crate::dfe::cache::{dfg_key, spec_key, CachedConfig, ConfigCache, SpecSignature};
use crate::dfe::grid::Grid;
use crate::dfe::plan::{tile_key, ExecutionPlan, PlanTile};
use crate::dfe::resource::{device_by_name, Device};
use crate::dfe::sim::CycleSim;
use crate::dfg::extract::{extract, OffloadDfg};
use crate::dfg::graph::Dfg;
use crate::dfg::partition::{needs_tiling, partition, PartitionError, TileBudget, TiledDfg};
use crate::jit::engine::{Engine, FnProfile, Histogram};
use crate::par::{
    place_and_route_portfolio, CompileJob, CompileService, ParError, ParParams, ParSeed,
    ParStats, PortfolioParams,
};
use crate::trace::{Phase, Tracer};
use crate::transport::{
    chunk_plan, ChunkTimeline, PcieParams, PcieSim, PlanTimeline, TransportMode,
};

use stub::{make_offload_hook, make_plan_hook, DfeBackend, StubReport, TimeModel};

/// Configuration-switch FSM epsilon charged per grid (re)load — at
/// install, and per pass of a multi-tile plan (the serve layer's
/// `reconfig_epsilon` parameter defaults to the same value).
pub(crate) const RECONFIG_EPSILON: Duration = Duration::from_micros(600);

/// Which sim-side numerics engine the stub runs when no PJRT runtime is
/// attached. `Auto` is the production choice; the pinned variants exist
/// for the differential conformance suite, which asserts bit-identity of
/// every backend through the real offload stub.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SimBackendChoice {
    /// Compiled wave executor when the config lowered, image eval otherwise.
    #[default]
    Auto,
    /// Cycle-accurate elastic overlay simulation (slowest, independent).
    CycleSim,
    /// Per-lane execution-image evaluation.
    Image,
}

/// Manager tunables.
#[derive(Clone, Debug)]
pub struct OffloadParams {
    pub grid: Grid,
    /// Minimum DFG size worth the transfer overhead (paper: "discard
    /// small DFGs"; must be tuned per implementation).
    pub min_dfg_nodes: usize,
    /// Innermost-loop unroll factor for extraction (Fig 2C).
    pub unroll: usize,
    pub par: ParParams,
    /// Invocations observed before a rollback decision.
    pub rollback_window: u64,
    /// Device powering the Fmax estimate (Table II name).
    pub device: String,
    pub pcie: PcieParams,
    pub seed: u64,
    /// Seconds per interpreter cycle (virtual host clock).
    pub sec_per_cycle: f64,
    pub cache_capacity: usize,
    /// Sim-side numerics backend (conformance suite pins this).
    pub sim_backend: SimBackendChoice,
    /// Execute `Auto`-selected sim backends through the lowered batch
    /// kernels (`dfe::lower`) instead of the interpreted wave schedule.
    /// Default on; `false` pins the wave-executor fallback (`--no-lower`).
    /// Numerics are identical either way (verifier pass V6 + the
    /// conformance/fuzz suites hold the two bit-for-bit).
    pub lower: bool,
    /// Transfer scheduling discipline: the paper's blocking prototype
    /// (`Sync`) or the overlapped double-buffered pipeline
    /// (`transport::pipeline`). Changes timing only, never numerics.
    pub transport: TransportMode,
    /// P&R seeds raced per compile (K >= 1). The winner is deterministic
    /// for a given `(cache key, K)` — see `par::service::derive_seed`.
    pub portfolio: usize,
    /// Compile-service worker threads. 0 = synchronous compiles (every
    /// cache miss stalls the caller inside place & route, the paper's
    /// behaviour); N > 0 = respecializations compile in the background
    /// and swap in at the next tier decision, never stalling a caller.
    pub compile_threads: usize,
    /// Deadline for one blocking wait on the background compile service
    /// (`CompileSlot::compile` with `defer = false`, and `drain`). A job
    /// still pending when it expires surfaces as the structured
    /// [`RejectReason::CompileTimeout`] instead of silently stalling.
    pub drain_timeout: Duration,
}

impl Default for OffloadParams {
    fn default() -> Self {
        OffloadParams {
            grid: Grid::new(8, 8),
            min_dfg_nodes: 6,
            unroll: 1,
            par: ParParams::default(),
            rollback_window: 4,
            device: "Virtex 7 (VC707)".into(),
            pcie: PcieParams::default(),
            seed: 0xD0E,
            sec_per_cycle: 1e-9,
            cache_capacity: 32,
            sim_backend: SimBackendChoice::Auto,
            lower: true,
            transport: TransportMode::Sync,
            portfolio: 1,
            compile_threads: 0,
            drain_timeout: Duration::from_secs(30),
        }
    }
}

/// Compile-side state shared by the single-tenant manager and the serve
/// layer: the optional background [`CompileService`], in-flight and dead
/// job keys, and the portfolio/grid shape every job compiles against.
pub struct CompileSlot {
    pub service: Option<CompileService>,
    pending: HashSet<u64>,
    /// Keys whose compile failed (unroutable): never resubmitted, the
    /// error is replayed to callers instead of looping the service.
    dead: HashMap<u64, String>,
    pub portfolio: usize,
    pub threads: usize,
    grid: Grid,
    par: ParParams,
    /// XORed into every job's cache key to anchor seed derivation, so the
    /// configured `params.seed` still picks the artifact family while the
    /// winner stays a pure function of `(key, K, seed)` — independent of
    /// the order compiles run in.
    seed: u64,
    variant: String,
    /// Deadline for one blocking wait on the service (see
    /// [`OffloadParams::drain_timeout`]); callers override after `new`.
    pub drain_timeout: Duration,
    /// Priority stamped onto the next submitted [`CompileJob`] (higher
    /// races first); 0 keeps the service's plain-FIFO order.
    pub priority: u64,
    /// Place-&-route invocations actually performed (blocking races plus
    /// landed background jobs) — cache hits and warm-restart reloads do
    /// not count, which is what lets the persistence CI leg assert "zero
    /// recompiles after reload".
    pub compiled: u64,
}

impl CompileSlot {
    pub fn new(
        portfolio: usize,
        threads: usize,
        grid: Grid,
        par: ParParams,
        seed: u64,
    ) -> CompileSlot {
        CompileSlot {
            service: (threads > 0).then(|| CompileService::new(threads)),
            pending: HashSet::new(),
            dead: HashMap::new(),
            portfolio: portfolio.max(1),
            threads,
            grid,
            par,
            seed,
            variant: format!("dfe_{}x{}", grid.rows, grid.cols),
            drain_timeout: Duration::from_secs(30),
            priority: 0,
            compiled: 0,
        }
    }

    /// Jobs submitted but not yet landed.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    pub fn is_pending(&self, key: u64) -> bool {
        self.pending.contains(&key)
    }

    fn entry(&self, o: crate::par::PortfolioOutcome) -> CachedConfig {
        CachedConfig::with_provenance(
            o.result.config,
            o.result.image,
            self.variant.clone(),
            o.seed,
            o.result.stats,
            o.result.placement,
        )
    }

    /// Compile `dfg` for `key` right now (blocking portfolio race), or —
    /// when `defer` is set and a background service exists — submit a job
    /// and return `Ok(None)`: the artifact lands via [`Self::pump`] and
    /// the caller keeps executing its current tier meanwhile.
    pub fn compile(
        &mut self,
        cache: &mut ConfigCache,
        dfg: &Dfg,
        key: u64,
        warm: ParSeed,
        defer: bool,
    ) -> Result<Option<(CachedConfig, ParStats)>, RejectReason> {
        if let Some(msg) = self.dead.get(&key) {
            return Err(RejectReason::Unroutable(msg.clone()));
        }
        if defer && self.service.is_some() {
            if self.pending.insert(key) {
                let job = CompileJob {
                    key,
                    base_seed: key ^ self.seed,
                    dfg: dfg.clone(),
                    grid: self.grid,
                    params: self.par,
                    portfolio: self.portfolio,
                    warm,
                    priority: self.priority,
                };
                if let Some(svc) = self.service.as_mut() {
                    svc.submit(job);
                }
            }
            return Ok(None);
        }
        // A background job for this key may already be racing (submitted
        // by a deferring caller): land finished jobs and wait for it
        // instead of duplicating the whole portfolio race — the blocking
        // caller gets the identical artifact the deferred path would.
        if self.service.is_some() {
            self.pump(cache);
            while self.pending.contains(&key) {
                let Some(svc) = self.service.as_mut() else { break };
                match svc.recv_timeout(self.drain_timeout) {
                    Some(d) => {
                        self.land(cache, d);
                    }
                    None => {
                        // The deadline expired with zero completions from
                        // any worker while this key is still in flight: a
                        // wedged job. Surface the structured timeout so
                        // the caller can account the stall instead of
                        // silently re-running the whole race on top of it.
                        if self.pending.contains(&key) {
                            return Err(RejectReason::CompileTimeout(self.drain_timeout));
                        }
                        break;
                    }
                }
            }
            if let Some(msg) = self.dead.get(&key) {
                return Err(RejectReason::Unroutable(msg.clone()));
            }
            if let Some(c) = cache.peek(key) {
                let stats = c.par_stats.unwrap_or_default();
                return Ok(Some((c.clone(), stats)));
            }
        }
        let pf = PortfolioParams {
            k: self.portfolio,
            base_seed: key ^ self.seed,
            threads: self.threads.max(1),
        };
        let outcome = place_and_route_portfolio(dfg, self.grid, &self.par, &warm, &pf)
            .map_err(|e| reject_of(&e))?;
        self.compiled += 1;
        let stats = outcome.result.stats;
        let c = self.entry(outcome);
        cache.insert(key, c.clone());
        Ok(Some((c, stats)))
    }

    /// Fold one finished job into `cache` (or the dead list). Returns the
    /// key if an artifact landed.
    fn land(&mut self, cache: &mut ConfigCache, done: crate::par::CompileDone) -> Option<u64> {
        self.pending.remove(&done.key);
        match done.outcome {
            Ok(o) => {
                self.compiled += 1;
                let entry = self.entry(o);
                cache.insert(done.key, entry);
                Some(done.key)
            }
            Err(e) => {
                self.dead.insert(done.key, e.to_string());
                None
            }
        }
    }

    /// Land every artifact the background service finished into `cache`.
    /// Returns the landed keys (failed jobs go to the dead list instead).
    pub fn pump(&mut self, cache: &mut ConfigCache) -> Vec<u64> {
        let done: Vec<_> = match self.service.as_mut() {
            Some(svc) => svc.poll(),
            None => return Vec::new(),
        };
        done.into_iter().filter_map(|d| self.land(cache, d)).collect()
    }

    /// Block until every in-flight job has landed (test barrier / orderly
    /// shutdown — the serving hot path only ever pumps). Gives up after
    /// `timeout` without a completion rather than hanging.
    pub fn drain(&mut self, cache: &mut ConfigCache, timeout: Duration) -> Vec<u64> {
        let mut landed = self.pump(cache);
        while !self.pending.is_empty() {
            let Some(svc) = self.service.as_mut() else { break };
            match svc.recv_timeout(timeout) {
                Some(d) => landed.extend(self.land(cache, d)),
                None => break,
            }
        }
        landed
    }
}

/// Why a function was not offloaded.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RejectReason {
    NoScop(String),
    Illegal(String),
    TooSmall { nodes: usize, min: usize },
    /// The DFG exceeds the fabric capacity *and* cannot be tiled: a
    /// structured resource verdict raised before place & route ever runs
    /// (the admission layer distinguishes "would never fit" from a
    /// routing search that merely failed).
    TooLarge { needed: usize, budget: usize },
    Unroutable(String),
    /// A blocking wait on the background compile service expired with the
    /// job still in flight (a wedged worker): the caller keeps its current
    /// tier and accounts the stall instead of panicking or silently
    /// re-racing. Carries the deadline that expired.
    CompileTimeout(Duration),
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::NoScop(s) => write!(f, "no SCoP: {s}"),
            RejectReason::Illegal(s) => write!(f, "{s}"),
            RejectReason::TooSmall { nodes, min } => {
                write!(f, "DFG too small ({nodes} < {min} nodes)")
            }
            RejectReason::TooLarge { needed, budget } => {
                write!(f, "DFG too large ({needed} needed, budget {budget})")
            }
            RejectReason::Unroutable(s) => write!(f, "unroutable: {s}"),
            RejectReason::CompileTimeout(d) => {
                write!(f, "compile service timed out after {:.3}s", d.as_secs_f64())
            }
        }
    }
}

/// Map a P&R failure to the structured reject: capacity verdicts keep
/// their numbers ([`RejectReason::TooLarge`]), search failures stay
/// stringly ([`RejectReason::Unroutable`]).
fn reject_of(e: &ParError) -> RejectReason {
    match e {
        ParError::TooLarge { calc, cells } => {
            RejectReason::TooLarge { needed: *calc, budget: *cells }
        }
        other => RejectReason::Unroutable(other.to_string()),
    }
}

/// A successful offload record.
#[derive(Clone, Debug)]
pub struct OffloadRecord {
    pub func: u32,
    pub name: String,
    pub dfg_nodes: usize,
    pub inputs: usize,
    pub outputs: usize,
    pub calc: usize,
    /// Extraction unroll factor of the installed artifact.
    pub unroll: usize,
    /// Tiles in the installed execution plan (1 = the classic single-tile
    /// artifact; > 1 = the DFG exceeded the grid and was partitioned).
    pub tiles: usize,
    pub par_stats: Option<ParStats>,
    pub cache_hit: bool,
    /// On a cache hit: the winning search's stats carried by the entry —
    /// the compile cost this hit avoided paying.
    pub avoided: Option<ParStats>,
    pub config_time: Duration,
    pub constants_time: Duration,
}

/// Live monitoring state shared with the stub hook. A respecialization
/// swap installs a *fresh* state on purpose: the rollback window and
/// per-invocation averages are per-tier, so a new artifact is judged on
/// its own samples (the serve layer folds retired states into cumulative
/// report totals; `baseline_per_inv` and `pre_patch` carry across swaps).
#[derive(Debug, Default)]
pub struct RuntimeState {
    pub invocations: u64,
    pub virtual_offload: Duration,
    pub baseline_per_inv: Duration,
    pub last_report: StubReport,
    pub failed: bool,
    pub rolled_back: bool,
    /// Per-invocation batch sizes (innermost iterations served), the
    /// offloaded-side counterpart of the engine's trip-count histogram.
    pub batch_hist: Histogram,
    /// Total innermost iterations served through the stub.
    pub total_elements: u64,
    /// Software-era profile snapshot taken when the call table was
    /// patched (the engine row is reset at that moment so the monitor
    /// only sees post-patch data).
    pub pre_patch: FnProfile,
}

/// The artifact currently patched in for a function — respecialization
/// bookkeeping: [`OffloadManager::reconfigure`] compares the live
/// artifact against candidates with the analytic pipeline model.
#[derive(Clone)]
pub struct ActiveOffload {
    pub unroll: usize,
    pub sig: SpecSignature,
    pub key: u64,
    /// Representative artifact — the whole config for a single-tile
    /// offload, tile 0 for a plan (its placement warm-starts the next
    /// respecialization either way).
    pub cached: CachedConfig,
    /// The full plan when the live artifact is multi-tile; `None` keeps
    /// the bit-identical single-tile bookkeeping.
    pub plan: Option<ExecutionPlan>,
}

/// Outcome of a respecialization attempt ([`OffloadManager::reconfigure`]).
#[derive(Clone, Debug)]
pub enum Reconfig {
    /// The candidate artifact modeled better and was patched in place.
    Swapped {
        record: OffloadRecord,
        /// 0 when nothing was live before (fresh install).
        from_unroll: usize,
    },
    /// The live artifact still models better at the observed batch size.
    Kept {
        current_unroll: usize,
        candidate_unroll: usize,
        current: Duration,
        candidate: Duration,
    },
    /// The candidate's artifact is compiling in the background: the caller
    /// keeps executing its current tier (software or the previous
    /// specialization) and the swap happens at a later tier decision,
    /// once the artifact has landed in the cache — never a P&R stall.
    Deferred { key: u64, unroll: usize },
}

pub struct OffloadManager {
    pub params: OffloadParams,
    pub cache: ConfigCache,
    pub pcie: Rc<RefCell<PcieSim>>,
    pub tracer: Rc<RefCell<Tracer>>,
    pub device: Device,
    /// Portfolio/compile-service state (see [`CompileSlot`]).
    pub compile: CompileSlot,
    /// Wall time spent blocked inside place & route by respecializations
    /// (`reconfigure` with no background service). 0 with the compile
    /// service on — the non-blocking-promotion invariant.
    pub compile_stall: Duration,
    states: HashMap<u32, Rc<RefCell<RuntimeState>>>,
    active: HashMap<u32, ActiveOffload>,
    /// `(func, unroll, trip_bucket)` → cache key of an in-flight compile:
    /// repeat tier decisions for the same target return `Deferred` without
    /// re-running SCoP analysis + extraction every tick.
    pending_specs: HashMap<(u32, usize, usize), u64>,
}

impl OffloadManager {
    pub fn new(params: OffloadParams) -> OffloadManager {
        // An unknown --device degrades to the Table-II default rather
        // than panicking the serve path; the final fallback to the first
        // table row only fires if the compiled-in device table itself is
        // edited to drop "Virtex 7".
        let device = device_by_name(&params.device)
            .or_else(|| device_by_name("Virtex 7"))
            .or_else(|| crate::dfe::resource::devices().into_iter().next())
            .expect("compiled-in device table is never empty");
        OffloadManager {
            pcie: Rc::new(RefCell::new(PcieSim::new(params.pcie))),
            tracer: Rc::new(RefCell::new(Tracer::new())),
            cache: ConfigCache::new(params.cache_capacity),
            compile: CompileSlot::new(
                params.portfolio,
                params.compile_threads,
                params.grid,
                params.par,
                params.seed,
            ),
            compile_stall: Duration::ZERO,
            device,
            states: HashMap::new(),
            active: HashMap::new(),
            pending_specs: HashMap::new(),
            params,
        }
    }

    /// Land any artifacts the background compile service finished; they
    /// enter the configuration cache so the next tier decision swaps them
    /// in without blocking. Returns the landed cache keys.
    pub fn pump_compiles(&mut self) -> Vec<u64> {
        self.compile.pump(&mut self.cache)
    }

    /// Block until every in-flight compile job has landed (test barrier /
    /// orderly shutdown; the hot path only ever pumps).
    pub fn drain_compiles(&mut self) -> Vec<u64> {
        let timeout = self.params.drain_timeout;
        self.compile.drain(&mut self.cache, timeout)
    }

    pub fn state(&self, func: u32) -> Option<Rc<RefCell<RuntimeState>>> {
        self.states.get(&func).cloned()
    }

    /// The artifact currently patched in for `func`, if any.
    pub fn active(&self, func: u32) -> Option<&ActiveOffload> {
        self.active.get(&func)
    }

    /// Analysis phase only (used by the Table-I harness): SCoPs, DFG
    /// extraction and legality for every innermost loop of `func`.
    pub fn analyze(
        &mut self,
        engine: &Engine,
        func: u32,
        unroll: usize,
    ) -> (Vec<OffloadDfg>, Vec<String>, Duration) {
        let f = &engine.module.funcs[func as usize];
        let t0 = std::time::Instant::now();
        let an = analyze_function(f);
        let mut offs = Vec::new();
        let mut rejects: Vec<String> =
            an.rejects.iter().map(|r| r.label().to_string()).collect();
        for scop in &an.scops {
            match extract(f, scop, unroll) {
                Ok(off) => offs.push(off),
                Err(e) => rejects.push(e.label().to_string()),
            }
        }
        (offs, rejects, t0.elapsed())
    }

    /// Full offload attempt on `func` at the params' static unroll. On
    /// success the engine's call table is patched; numerics subsequently
    /// flow through the DFE backend. The adaptive controller
    /// ([`adapt::AdaptController`]) uses [`Self::reconfigure`] instead.
    pub fn try_offload(
        &mut self,
        engine: &mut Engine,
        func: u32,
        pjrt: Option<&mut crate::runtime::PjrtRuntime>,
    ) -> Result<OffloadRecord, RejectReason> {
        let unroll = self.params.unroll;
        self.offload_with(engine, func, unroll, SpecSignature::generic(unroll), pjrt)
    }

    /// Cache-or-route `dfg` under `key`; returns the entry, whether it
    /// hit, and the P&R stats on a miss. A miss runs the blocking
    /// portfolio race seeded by `key` (deterministic winner) and warmed by
    /// `warm`; `CachedConfig::with_provenance` lowers the wave executor
    /// once here, so every later cache hit reuses the compiled artifact.
    fn route_cached(
        &mut self,
        dfg: &Dfg,
        key: u64,
        warm: ParSeed,
        count_stall: bool,
    ) -> Result<(CachedConfig, bool, Option<ParStats>), RejectReason> {
        if let Some(c) = self.cache.get(key) {
            return Ok((c.clone(), true, None));
        }
        let tracer = self.tracer.clone();
        let slot = &mut self.compile;
        let cache = &mut self.cache;
        let t0 = Instant::now();
        let routed = tracer
            .borrow_mut()
            .span(Phase::PlaceRoute, || slot.compile(cache, dfg, key, warm, false))?;
        if count_stall {
            self.compile_stall += t0.elapsed();
        }
        // `CompileSlot::compile(defer=false)` contractually returns an
        // artifact; surface a structured rejection instead of panicking
        // the serve path if that contract ever regresses.
        let (c, stats) = routed.ok_or_else(|| {
            RejectReason::Unroutable("compile slot returned no artifact in blocking mode".into())
        })?;
        Ok((c, false, Some(stats)))
    }

    /// The full pipeline at an explicit unroll factor and specialization
    /// signature: analysis → cache/P&R (keyed by [`spec_key`]) → config
    /// download → call-table patch. Patching over a live hook is the
    /// in-place respecialization swap: callers never observe a window
    /// where the function is unpatched.
    pub(crate) fn offload_with(
        &mut self,
        engine: &mut Engine,
        func: u32,
        unroll: usize,
        sig: SpecSignature,
        pjrt: Option<&mut crate::runtime::PjrtRuntime>,
    ) -> Result<OffloadRecord, RejectReason> {
        // ---- 1. analysis (Fig 6 phase 1) ----
        let tracer = self.tracer.clone();
        let (off, single) = tracer.borrow_mut().span(Phase::Analysis, {
            let f = &engine.module.funcs[func as usize];
            move || extract_single_scop(f, unroll)
        })?;
        self.install_extracted(engine, func, unroll, sig, off, single, pjrt)
    }

    /// Phases 2–5 of the pipeline, starting from an already-extracted
    /// DFG pair — [`Self::reconfigure`] extracts once to compute the
    /// cache key and must not pay (or double-trace) the analysis twice.
    fn install_extracted(
        &mut self,
        engine: &mut Engine,
        func: u32,
        unroll: usize,
        sig: SpecSignature,
        off: OffloadDfg,
        single: OffloadDfg,
        pjrt: Option<&mut crate::runtime::PjrtRuntime>,
    ) -> Result<OffloadRecord, RejectReason> {
        let tracer = self.tracer.clone();
        let name = engine.func_name(func).to_string();

        let stats = off.dfg.stats();
        let nodes = off.dfg.len();
        if nodes < self.params.min_dfg_nodes {
            return Err(RejectReason::TooSmall { nodes, min: self.params.min_dfg_nodes });
        }

        // ---- 1b. capacity check: a DFG bigger than the grid is cut into
        //          a multi-tile execution plan instead of being rejected;
        //          anything at or under capacity keeps the bit-identical
        //          single-tile path below ----
        let budget = TileBudget::for_grid(self.params.grid);
        if needs_tiling(&off.dfg, budget) {
            return self.install_tiled(engine, func, unroll, sig, off, single, pjrt, budget);
        }

        // ---- 2. place & route, via the configuration cache (keyed by
        //         structure × specialization signature, so generic and
        //         specialized artifacts coexist). A live artifact's
        //         placement warm-starts the search: respecializing tier
        //         N→N+1 re-places only the DFG delta ----
        let warm = self
            .active
            .get(&func)
            .filter(|a| !a.cached.placement.is_empty())
            .map(|a| ParSeed::Warm(a.cached.placement.clone()))
            .unwrap_or(ParSeed::Cold);
        let key = spec_key(dfg_key(&off.dfg), sig);
        let (cached, cache_hit, par_stats) = self.route_cached(&off.dfg, key, warm, false)?;
        let avoided = if cache_hit { cached.par_stats } else { None };

        // ---- 3. configuration + constants download (modeled) ----
        let cfg_words = cached.config.config_words() as u64;
        // Each configuration word rides the same tagged link + FSM epsilon.
        let config_time = {
            let mut pcie = self.pcie.borrow_mut();
            pcie.transfer(cfg_words * 4).time + Duration::from_micros(600)
        };
        tracer.borrow_mut().simulated(Phase::Configure, config_time);
        let constants_time = {
            let mut pcie = self.pcie.borrow_mut();
            pcie.transfer(cached.image.consts.len().max(1) as u64 * 4).time
        };
        tracer.borrow_mut().simulated(Phase::Constants, constants_time);

        // ---- 4. timing model (Fmax from Table II, fill/II analytic from
        //         the compiled fabric; cycle-sim measurement only for
        //         configs that didn't lower) ----
        let est = self.device.estimate(self.params.grid.rows, self.params.grid.cols);
        let (fill, ii) = pipeline_model(&cached);
        let tm = TimeModel {
            sec_per_cycle: self.params.sec_per_cycle,
            fmax_hz: est.fmax_mhz * 1e6,
            fill_latency: fill,
            initiation_interval: ii,
        };

        // ---- 5. backend + stub patch (Fig 6 phase 2 is the stub JIT;
        //         engine lowering measured at Engine::new) ----
        let backend = match pjrt {
            Some(rt) => {
                let exe = rt
                    .executable_fitting(cached.image.n_cells())
                    .map_err(|e| RejectReason::Unroutable(format!("artifact: {e}")))?;
                DfeBackend::Pjrt(exe)
            }
            None => match self.params.sim_backend {
                SimBackendChoice::CycleSim => {
                    DfeBackend::Cycle(Rc::new(cached.config.clone()))
                }
                SimBackendChoice::Image => DfeBackend::Sim,
                // Sim side: lowered batch kernels → wave executor →
                // image eval, best available first.
                SimBackendChoice::Auto => DfeBackend::sim_for(&cached, self.params.lower),
            },
        };
        let jit_time = engine.jit_times.get(func as usize).copied().unwrap_or_default();
        tracer.borrow_mut().simulated(Phase::Jit, jit_time.max(Duration::from_micros(50)));

        let state = self.fresh_state(engine, func);

        let hook = make_offload_hook(
            off,
            single,
            cached.image.clone(),
            backend,
            tm,
            self.pcie.clone(),
            self.params.transport,
            state,
            Some(tracer.clone()),
        );
        engine.patch_hook(func, hook);
        self.active.insert(func, ActiveOffload { unroll, sig, key, cached, plan: None });

        Ok(OffloadRecord {
            func,
            name,
            dfg_nodes: nodes,
            inputs: stats.inputs,
            outputs: stats.outputs,
            calc: stats.calc,
            unroll,
            tiles: 1,
            par_stats,
            cache_hit,
            avoided,
            config_time,
            constants_time,
        })
    }

    /// Patch-time monitoring state, shared by both installers.
    ///
    /// Snapshot/reset discipline: the monitor must only see post-patch
    /// data — pre-offload interpreter samples would pollute the
    /// post-offload wall-time averages. On a respecialization the profile
    /// row is hook-era (zero cycles), so the software baseline and the
    /// software-era snapshot established at the original patch carry
    /// forward instead.
    fn fresh_state(&mut self, engine: &mut Engine, func: u32) -> Rc<RefCell<RuntimeState>> {
        let profile = engine.profile(func);
        let prev = self.states.get(&func).map(|s| {
            let b = s.borrow();
            (b.baseline_per_inv, b.pre_patch)
        });
        let baseline_per_inv = if profile.counters.cycles > 0 {
            Duration::from_secs_f64(
                self.params.sec_per_cycle * profile.counters.cycles as f64
                    / profile.counters.invocations.max(1) as f64,
            )
        } else {
            prev.map(|p| p.0).unwrap_or_default()
        };
        let snap = engine.take_profile(func);
        let pre_patch =
            if snap.counters.cycles > 0 { snap } else { prev.map(|p| p.1).unwrap_or(snap) };
        let state = Rc::new(RefCell::new(RuntimeState {
            baseline_per_inv,
            pre_patch,
            ..Default::default()
        }));
        self.states.insert(func, state.clone());
        state
    }

    /// Fetch-or-build the [`ExecutionPlan`] for `tiled` under `plan_key`:
    /// a plan-store hit returns the assembled artifact whole; a miss
    /// routes each tile through the per-tile store ([`tile_key`] — tiles
    /// warm-start independently, and a respecialized plan reuses every
    /// tile whose cut DFG is unchanged), chaining each tile's winning
    /// placement as the next tile's warm seed, then caches the assembly
    /// at its tile-count weight.
    fn plan_cached(
        &mut self,
        tiled: &TiledDfg,
        plan_key: u64,
        count_stall: bool,
    ) -> Result<(ExecutionPlan, bool, Option<ParStats>), RejectReason> {
        if let Some(p) = self.cache.get_plan(plan_key) {
            return Ok((p.clone(), true, None));
        }
        let mut tiles = Vec::with_capacity(tiled.tiles.len());
        let mut par_stats: Option<ParStats> = None;
        let mut warm = ParSeed::Cold;
        for (idx, t) in tiled.tiles.iter().enumerate() {
            let tk = tile_key(plan_key, idx, dfg_key(&t.dfg));
            let (cached, _, stats) = self.route_cached(&t.dfg, tk, warm, count_stall)?;
            if idx == 0 {
                // Tile 0's search stats stand in for the whole plan in
                // records (the dominant tile under balanced cuts).
                par_stats = stats.or(cached.par_stats);
            }
            warm = if cached.placement.is_empty() {
                ParSeed::Cold
            } else {
                ParSeed::Warm(cached.placement.clone())
            };
            tiles.push(PlanTile {
                cached,
                sources: t.sources.clone(),
                sinks: t.sinks.clone(),
                key: tk,
            });
        }
        let plan = ExecutionPlan::from_tiles(tiles, tiled.n_spills).ok_or_else(|| {
            RejectReason::Illegal("partition produced an empty execution plan".into())
        })?;
        self.cache.insert_plan(plan_key, plan.clone());
        Ok((plan, false, par_stats))
    }

    /// The multi-tile install: partition → per-tile cache/P&R → plan
    /// assembly → config/constants download (summed over tiles) → plan
    /// hook patch. Mirrors the single-tile phases; numerics flow through
    /// [`stub::run_plan_with`].
    #[allow(clippy::too_many_arguments)]
    fn install_tiled(
        &mut self,
        engine: &mut Engine,
        func: u32,
        unroll: usize,
        sig: SpecSignature,
        off: OffloadDfg,
        single: OffloadDfg,
        pjrt: Option<&mut crate::runtime::PjrtRuntime>,
        budget: TileBudget,
    ) -> Result<OffloadRecord, RejectReason> {
        // The PJRT AOT artifact is one fixed-capacity datapath; it cannot
        // be time-multiplexed per pass, so oversized DFGs stay rejected
        // on that backend.
        if pjrt.is_some() {
            return Err(RejectReason::Unroutable(
                "multi-tile plans are sim-side only (PJRT artifact has fixed capacity)".into(),
            ));
        }
        let tracer = self.tracer.clone();
        let name = engine.func_name(func).to_string();
        let stats = off.dfg.stats();
        let nodes = off.dfg.len();
        let tiled = partition(&off.dfg, budget).map_err(|e| match e {
            PartitionError::Infeasible { needed, io, .. } => {
                RejectReason::TooLarge { needed, budget: io }
            }
            PartitionError::Dfg(d) => RejectReason::Illegal(d.to_string()),
        })?;
        let key = spec_key(dfg_key(&off.dfg), sig);
        let (plan, cache_hit, par_stats) = self.plan_cached(&tiled, key, false)?;
        let avoided = if cache_hit { plan.tiles[0].cached.par_stats } else { None };

        // Config + constants download, summed over tiles (every pass
        // reloads the grid; run-time passes re-pay the config transfer,
        // this install-time accounting mirrors the single path's).
        let config_time = {
            let mut pcie = self.pcie.borrow_mut();
            pcie.transfer(plan.config_words() * 4).time + RECONFIG_EPSILON
        };
        tracer.borrow_mut().simulated(Phase::Configure, config_time);
        let const_words: u64 =
            plan.tiles.iter().map(|t| t.cached.image.consts.len().max(1) as u64).sum();
        let constants_time = {
            let mut pcie = self.pcie.borrow_mut();
            pcie.transfer(const_words * 4).time
        };
        tracer.borrow_mut().simulated(Phase::Constants, constants_time);

        // Per-tile timing models and backends (each tile is its own
        // routed configuration with its own fill/II).
        let est = self.device.estimate(self.params.grid.rows, self.params.grid.cols);
        let tms: Vec<TimeModel> = plan
            .tiles
            .iter()
            .map(|t| {
                let (fill, ii) = pipeline_model(&t.cached);
                TimeModel {
                    sec_per_cycle: self.params.sec_per_cycle,
                    fmax_hz: est.fmax_mhz * 1e6,
                    fill_latency: fill,
                    initiation_interval: ii,
                }
            })
            .collect();
        let backends: Vec<DfeBackend> = plan
            .tiles
            .iter()
            .map(|t| match self.params.sim_backend {
                SimBackendChoice::CycleSim => DfeBackend::Cycle(Rc::new(t.cached.config.clone())),
                SimBackendChoice::Image => DfeBackend::Sim,
                SimBackendChoice::Auto => DfeBackend::sim_for(&t.cached, self.params.lower),
            })
            .collect();
        let jit_time = engine.jit_times.get(func as usize).copied().unwrap_or_default();
        tracer.borrow_mut().simulated(Phase::Jit, jit_time.max(Duration::from_micros(50)));

        let state = self.fresh_state(engine, func);
        let n_tiles = plan.n_tiles();
        let hook = make_plan_hook(
            off,
            single,
            Rc::new(plan.clone()),
            Rc::new(backends),
            Rc::new(tms),
            RECONFIG_EPSILON,
            self.pcie.clone(),
            self.params.transport,
            state,
            Some(tracer.clone()),
        );
        engine.patch_hook(func, hook);
        self.active.insert(
            func,
            ActiveOffload {
                unroll,
                sig,
                key,
                cached: plan.tiles[0].cached.clone(),
                plan: Some(plan),
            },
        );

        Ok(OffloadRecord {
            func,
            name,
            dfg_nodes: nodes,
            inputs: stats.inputs,
            outputs: stats.outputs,
            calc: stats.calc,
            unroll,
            tiles: n_tiles,
            par_stats,
            cache_hit,
            avoided,
            config_time,
            constants_time,
        })
    }

    /// Live respecialization: re-extract at `unroll`, fetch or
    /// place-&-route the artifact under the specialization signature
    /// (unroll × trip bucket), and swap the call-table stub in place iff
    /// the analytic pipeline model prefers the candidate at the observed
    /// batch size (`None` = unconditional swap). Ties favor the smaller
    /// unroll — the simpler artifact. With a background compile service
    /// (`compile_threads > 0`), a cache miss submits a job and returns
    /// [`Reconfig::Deferred`] instead of stalling; the caller's next tier
    /// decision finds the landed artifact as a cache hit and swaps then.
    /// Sim-side only: PJRT artifacts are installed once by
    /// [`Self::try_offload`] and not respecialized.
    pub fn reconfigure(
        &mut self,
        engine: &mut Engine,
        func: u32,
        unroll: usize,
        trip_bucket: usize,
        observed_batch: Option<u64>,
    ) -> Result<Reconfig, RejectReason> {
        // Land anything the background service finished first, so a
        // previously deferred candidate becomes a cache hit right here.
        self.pump_compiles();
        let sig = SpecSignature::new(unroll, trip_bucket);
        let current = self.active.get(&func).cloned().filter(|_| engine.is_patched(func));
        if let (Some(cur), Some(_)) = (&current, observed_batch) {
            if cur.unroll == unroll {
                return Ok(Reconfig::Kept {
                    current_unroll: cur.unroll,
                    candidate_unroll: unroll,
                    current: Duration::ZERO,
                    candidate: Duration::ZERO,
                });
            }
        }
        // This exact target already compiling in the background: stay
        // deferred without re-running analysis + extraction every tick.
        if let Some(&key) = self.pending_specs.get(&(func, unroll, trip_bucket)) {
            if self.compile.is_pending(key) {
                return Ok(Reconfig::Deferred { key, unroll });
            }
            self.pending_specs.remove(&(func, unroll, trip_bucket));
        }
        // Extract once: the cache key decides between a hit (proceed
        // synchronously — no P&R happens) and a background submission,
        // and the pair feeds the eventual install directly (no
        // re-extraction).
        let (off, single) = {
            let f = &engine.module.funcs[func as usize];
            extract_single_scop(f, unroll)?
        };
        let nodes = off.dfg.len();
        if nodes < self.params.min_dfg_nodes {
            return Err(RejectReason::TooSmall { nodes, min: self.params.min_dfg_nodes });
        }
        let key = spec_key(dfg_key(&off.dfg), sig);
        // A candidate above grid capacity respecializes as a multi-tile
        // plan — partition it up front so the deferred path can race each
        // tile as its own background job.
        let budget = TileBudget::for_grid(self.params.grid);
        let tiled = if needs_tiling(&off.dfg, budget) {
            Some(partition(&off.dfg, budget).map_err(|e| match e {
                PartitionError::Infeasible { needed, io, .. } => {
                    RejectReason::TooLarge { needed, budget: io }
                }
                PartitionError::Dfg(d) => RejectReason::Illegal(d.to_string()),
            })?)
        } else {
            None
        };
        if self.compile.service.is_some() {
            // Non-blocking promotion: submit (deduped; warm-started from
            // the live artifact's placement) and keep the current tier —
            // software or the previous specialization — until it lands.
            let warm_placement = current
                .as_ref()
                .filter(|c| !c.cached.placement.is_empty())
                .map(|c| c.cached.placement.clone());
            match &tiled {
                None if !self.cache.contains(key) => {
                    let warm = warm_placement.map(ParSeed::Warm).unwrap_or(ParSeed::Cold);
                    self.compile.compile(&mut self.cache, &off.dfg, key, warm, true)?;
                    self.pending_specs.insert((func, unroll, trip_bucket), key);
                    return Ok(Reconfig::Deferred { key, unroll });
                }
                Some(td) if !self.cache.contains_plan(key) => {
                    // Each missing tile compiles as its own job; once
                    // every tile has landed, the fall-through assembles
                    // the plan from pure per-tile cache hits — no stall.
                    let mut outstanding = None;
                    for (idx, t) in td.tiles.iter().enumerate() {
                        let tk = tile_key(key, idx, dfg_key(&t.dfg));
                        if self.cache.contains(tk) {
                            continue;
                        }
                        let warm = warm_placement
                            .clone()
                            .map(ParSeed::Warm)
                            .unwrap_or(ParSeed::Cold);
                        self.compile.compile(&mut self.cache, &t.dfg, tk, warm, true)?;
                        outstanding = Some(tk);
                    }
                    if let Some(tk) = outstanding {
                        self.pending_specs.insert((func, unroll, trip_bucket), tk);
                        return Ok(Reconfig::Deferred { key, unroll });
                    }
                }
                _ => {}
            }
        }
        let (cur, batch) = match (current, observed_batch) {
            (Some(cur), Some(batch)) => (cur, batch),
            (cur, _) => {
                // Nothing live to compare against (or no profile yet):
                // install unconditionally.
                let from_unroll = cur.map(|c| c.unroll).unwrap_or(0);
                let record =
                    self.install_extracted(engine, func, unroll, sig, off, single, None)?;
                return Ok(Reconfig::Swapped { record, from_unroll });
            }
        };
        // Route (or cache-hit) the candidate, then let the analytic
        // pipeline model pick the better artifact at this batch size.
        let est = self.device.estimate(self.params.grid.rows, self.params.grid.cols);
        let fmax = est.fmax_mhz * 1e6;
        let link = (self.params.pcie, self.params.transport);
        let t_cand = match &tiled {
            Some(td) => {
                let (cand_plan, _, _) = self.plan_cached(td, key, true)?;
                plan_invocation_time(&cand_plan, unroll, batch, fmax, link)
            }
            None => {
                let warm = (!cur.cached.placement.is_empty())
                    .then(|| ParSeed::Warm(cur.cached.placement.clone()))
                    .unwrap_or(ParSeed::Cold);
                let (cand, _, _) = self.route_cached(&off.dfg, key, warm, true)?;
                invocation_time(&cand, unroll, batch, fmax, link)
            }
        };
        let t_cur = match &cur.plan {
            Some(p) => plan_invocation_time(p, cur.unroll, batch, fmax, link),
            None => invocation_time(&cur.cached, cur.unroll, batch, fmax, link),
        };
        let keep = if unroll < cur.unroll { t_cand > t_cur } else { t_cand >= t_cur };
        if keep {
            return Ok(Reconfig::Kept {
                current_unroll: cur.unroll,
                candidate_unroll: unroll,
                current: t_cur,
                candidate: t_cand,
            });
        }
        let record = self.install_extracted(engine, func, unroll, sig, off, single, None)?;
        Ok(Reconfig::Swapped { record, from_unroll: cur.unroll })
    }

    /// Rollback pass ("roll back to the initial software should the
    /// produced implementation perform worse"): compares modeled offload
    /// time per invocation with the software baseline. Returns functions
    /// rolled back.
    pub fn check_rollback(&mut self, engine: &mut Engine) -> Vec<u32> {
        let mut rolled = Vec::new();
        for (&func, state) in &self.states {
            if !engine.is_patched(func) {
                continue;
            }
            let mut st = state.borrow_mut();
            let decided = st.invocations >= self.params.rollback_window || st.failed;
            if !decided {
                continue;
            }
            let per_inv = st.virtual_offload / st.invocations.max(1) as u32;
            if st.failed || per_inv > st.baseline_per_inv {
                engine.unpatch(func);
                st.rolled_back = true;
                rolled.push(func);
            }
        }
        for f in &rolled {
            self.active.remove(f);
        }
        rolled
    }
}

/// Analysis + extraction under the one-SCoP-per-function offload
/// contract, shared by the single-tenant manager and the serve layer.
///
/// The stub patch replaces the *whole* function, so the offload is only
/// sound when a single SCoP covers the body: patching a multi-nest
/// function (atax, bicg, mvt, gemver, ...) would silently drop every
/// nest but the first. Such functions stay in software until DFG merging
/// lands (paper: "extract and merge"). Returns the unrolled and the
/// single-iteration (remainder) extractions.
pub(crate) fn extract_single_scop(
    f: &crate::ir::func::Function,
    unroll: usize,
) -> Result<(OffloadDfg, OffloadDfg), RejectReason> {
    let an = analyze_function(f);
    if an.scops.is_empty() {
        let why = an
            .rejects
            .first()
            .map(|r| r.label().to_string())
            .unwrap_or_else(|| "no loops".into());
        return Err(RejectReason::NoScop(why));
    }
    if an.scops.len() > 1 {
        return Err(RejectReason::Illegal(format!(
            "{} SCoPs; multi-SCoP functions are not offloaded",
            an.scops.len()
        )));
    }
    let scop = &an.scops[0];
    match (extract(f, scop, unroll), extract(f, scop, 1)) {
        (Ok(o), Ok(s)) => Ok((o, s)),
        (Err(e), _) | (_, Err(e)) => Err(RejectReason::Illegal(e.label().to_string())),
    }
}

/// Pipeline fill latency and initiation interval for the timing model:
/// analytic (registered-stage depth, II = 1) when the configuration
/// lowered to a compiled fabric, otherwise measured on the cycle
/// simulator with a short synthetic stream.
pub(crate) fn pipeline_model(cached: &CachedConfig) -> (f64, f64) {
    match &cached.fabric {
        Some(f) => (f.fill_latency as f64, f.initiation_interval),
        None => measure_pipeline(&cached.config, cached.image.n_inputs),
    }
}

/// Modeled DFE execution time for one offloaded batch of `batch`
/// innermost iterations on `cached` at `unroll`: `lanes = batch / unroll`
/// stream elements (remainder iterations are charged one lane each —
/// they execute host-exact but still cost the caller), `fill +
/// (lanes - 1) · II` cycles at `fmax_hz`. Transfer volume is identical
/// across unroll factors (same total words), so it cancels out of the
/// comparison — this is how `pipeline_model` picks the analytically
/// better artifact per observed batch size.
pub fn batch_time(cached: &CachedConfig, unroll: usize, batch: u64, fmax_hz: f64) -> Duration {
    if batch == 0 {
        return Duration::ZERO;
    }
    let (fill, ii) = pipeline_model(cached);
    let u = unroll.max(1) as u64;
    let lanes = batch / u + batch % u;
    let cycles = fill + lanes.saturating_sub(1) as f64 * ii;
    Duration::from_secs_f64(cycles / fmax_hz.max(1.0))
}

/// Full modeled invocation time for one offloaded batch, transport
/// discipline included — the promotion/respecialization comparator.
///
/// Synchronous transport: transfer volume is (near-)identical across
/// unroll factors — same total words, framed the same way — so it cancels
/// out of any tier comparison and [`batch_time`] (execution only) is the
/// whole signal, exactly the pre-pipeline model.
///
/// Asynchronous transport: transfers overlap execution on the
/// [`ChunkTimeline`] the stub itself schedules with, so the makespan is
/// `≈ max(transfer, compute)` — once the link hides the fabric time, a
/// deeper specialized pipeline stops paying for its fill and the model
/// (correctly) stops preferring it. "Transfer hidden under compute
/// changes which unroll tier wins" is not a side effect; it is the point.
pub fn invocation_time(
    cached: &CachedConfig,
    unroll: usize,
    batch: u64,
    fmax_hz: f64,
    link: (PcieParams, TransportMode),
) -> Duration {
    let (pcie, mode) = link;
    if batch == 0 {
        return Duration::ZERO;
    }
    if !mode.is_async() {
        return batch_time(cached, unroll, batch, fmax_hz);
    }
    let (fill, ii) = pipeline_model(cached);
    let fmax = fmax_hz.max(1.0);
    let u = unroll.max(1) as u64;
    let lanes = (batch / u) as usize;
    let n_in = cached.image.n_inputs.max(1);
    let n_out = cached.image.out_sel.len().max(1);
    // Per-chunk fabric cost = busy-window deltas (only the first chunk
    // pays the fill), exactly what the stub charges — the model and the
    // runtime cannot drift.
    let plan = chunk_plan(lanes, mode);
    let windows = crate::dfe::exec::busy_windows(fill, ii, &plan);
    let mut tl = ChunkTimeline::new(mode);
    let mut exec_done = 0.0f64;
    for (&(_, m), &(_, busy_end)) in plan.iter().zip(&windows) {
        let up = pcie.transfer_secs((n_in * m * 4) as u64);
        let exec = (busy_end - exec_done) / fmax;
        exec_done = busy_end;
        let down = pcie.transfer_secs((n_out * m * 4) as u64);
        tl.step(up, exec, down);
    }
    // Remainder iterations execute host-exact but still cost the caller:
    // charge them one initiation interval each, as `batch_time` does.
    let rem_secs = (batch % u) as f64 * ii / fmax;
    Duration::from_secs_f64(tl.wall + rem_secs)
}

/// [`invocation_time`] generalized to execution plans — the comparator
/// the respecialization gate uses when either side is multi-tile.
///
/// The single-tile plan delegates to [`invocation_time`] exactly (the
/// degenerate case models identically to the legacy path). A multi-tile
/// plan models every pass: per-pass grid reload (config transfer + the
/// switch epsilon, folded into the first chunk's exec — the same fold
/// [`stub::run_plan_with`] charges, so model and runtime cannot drift)
/// and, under the asynchronous transport, [`PlanTimeline`] gating of
/// pass *t*'s chunk-*c* upload on pass *t−1*'s chunk-*c* download (the
/// spill round-trip). The synchronous arm is the conservative serial
/// sum *including* transfers: unlike the single-tile case they do not
/// cancel across tiers, because tile count and spill volume differ.
pub fn plan_invocation_time(
    plan: &ExecutionPlan,
    unroll: usize,
    batch: u64,
    fmax_hz: f64,
    link: (PcieParams, TransportMode),
) -> Duration {
    if plan.is_single() {
        return invocation_time(&plan.tiles[0].cached, unroll, batch, fmax_hz, link);
    }
    let (pcie, mode) = link;
    if batch == 0 {
        return Duration::ZERO;
    }
    let fmax = fmax_hz.max(1.0);
    let u = unroll.max(1) as u64;
    let lanes = (batch / u) as usize;
    let eps = RECONFIG_EPSILON.as_secs_f64();
    // `ExecutionPlan::from_tiles` makes empty plans unrepresentable at
    // construction; if one slips through anyway, model it as infinitely
    // slow (the comparator then never swaps it in) rather than panicking.
    let Some(last_tile) = plan.tiles.last() else {
        debug_assert!(false, "ExecutionPlan invariant violated: empty tile list");
        return Duration::MAX;
    };
    let ii_last = pipeline_model(&last_tile.cached).1;
    let rem_secs = (batch % u) as f64 * ii_last / fmax;
    if lanes == 0 {
        return Duration::from_secs_f64(rem_secs);
    }
    if !mode.is_async() {
        let mut total = 0.0f64;
        for t in &plan.tiles {
            let (fill, ii) = pipeline_model(&t.cached);
            let n_in = t.sources.len().max(1);
            let n_out = t.sinks.len().max(1);
            total += pcie.transfer_secs(t.cached.config.config_words() as u64 * 4) + eps;
            total += pcie.transfer_secs((n_in * lanes * 4) as u64);
            total += (fill + (lanes as f64 - 1.0) * ii) / fmax;
            total += pcie.transfer_secs((n_out * lanes * 4) as u64);
        }
        return Duration::from_secs_f64(total + rem_secs);
    }
    let chunks = chunk_plan(lanes, mode);
    let mut tl = PlanTimeline::new(mode);
    for (t_idx, t) in plan.tiles.iter().enumerate() {
        if t_idx > 0 {
            tl.next_pass();
        }
        let (fill, ii) = pipeline_model(&t.cached);
        let n_in = t.sources.len().max(1);
        let n_out = t.sinks.len().max(1);
        let windows = crate::dfe::exec::busy_windows(fill, ii, &chunks);
        let mut reconfig =
            pcie.transfer_secs(t.cached.config.config_words() as u64 * 4) + eps;
        let mut exec_done = 0.0f64;
        for (&(_, m), &(_, busy_end)) in chunks.iter().zip(&windows) {
            let up = pcie.transfer_secs((n_in * m * 4) as u64);
            let exec = (busy_end - exec_done) / fmax + reconfig;
            reconfig = 0.0;
            exec_done = busy_end;
            let down = pcie.transfer_secs((n_out * m * 4) as u64);
            tl.step(up, exec, down);
        }
    }
    Duration::from_secs_f64(tl.wall() + rem_secs)
}

/// Measure pipeline fill latency and initiation interval on the cycle
/// simulator with a short synthetic stream (fallback for configurations
/// the wave lowering refused).
fn measure_pipeline(config: &crate::dfe::config::GridConfig, n_inputs: usize) -> (f64, f64) {
    let n = 16;
    let streams: Vec<Vec<i32>> = (0..n_inputs.max(1))
        .map(|j| (0..n as i32).map(|t| t + j as i32).collect())
        .collect();
    match CycleSim::new(config).and_then(|mut s| s.run_stream(&streams, n)) {
        Ok(r) => (r.fill_latency as f64, r.initiation_interval.max(1.0)),
        Err(_) => (config.grid.n_cells() as f64, 1.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::func::{FuncBuilder, Module};
    use crate::ir::instr::Ty;
    use crate::jit::interp::{Memory, Val};

    /// Fig-2 kernel module (C = A + 3B + 1 over n elements).
    fn fig2_module() -> Module {
        let mut m = Module::new();
        let mut b = FuncBuilder::new(
            "fig2",
            &[("C", Ty::Ptr), ("A", Ty::Ptr), ("B", Ty::Ptr), ("n", Ty::I32)],
        );
        let (c, a, bb, n) = (b.param(0), b.param(1), b.param(2), b.param(3));
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, i| {
            let av = b.load(Ty::I32, a, i);
            let bv = b.load(Ty::I32, bb, i);
            let c3 = b.const_i32(3);
            let t = b.mul(bv, c3);
            let s = b.add(av, t);
            let c1 = b.const_i32(1);
            let r = b.add(s, c1);
            b.store(Ty::I32, c, i, r);
        });
        m.add(b.ret(None));
        m
    }

    fn run_fig2(engine: &mut Engine, mem: &mut Memory, c: u32, a: u32, b: u32, n: i32) {
        engine
            .call("fig2", mem, &[Val::P(c), Val::P(a), Val::P(b), Val::I(n)])
            .unwrap();
    }

    #[test]
    fn offload_preserves_semantics_sim_backend() {
        let mut engine = Engine::new(fig2_module()).unwrap();
        let mut mem = Memory::new();
        let n = 1000;
        let a: Vec<i32> = (0..n).map(|i| i * 7 - 300).collect();
        let b: Vec<i32> = (0..n).map(|i| -i + 11).collect();
        let (ha, hb) = (mem.from_i32(&a), mem.from_i32(&b));
        let hc_sw = mem.alloc_i32(n as usize);
        let hc_hw = mem.alloc_i32(n as usize);

        // Software baseline (also warms the profile for the baseline time).
        run_fig2(&mut engine, &mut mem, hc_sw, ha, hb, n);

        let mut mgr = OffloadManager::new(OffloadParams {
            min_dfg_nodes: 1,
            unroll: 4,
            ..Default::default()
        });
        let func = engine.func_index("fig2").unwrap();
        let rec = mgr.try_offload(&mut engine, func, None).expect("offload");
        assert!(engine.is_patched(func));
        assert_eq!(rec.outputs, 4); // unrolled x4

        // Offloaded run, n NOT divisible by 4 exercises the remainder.
        run_fig2(&mut engine, &mut mem, hc_hw, ha, hb, n - 3);
        for i in 0..(n - 3) as usize {
            assert_eq!(
                mem.i32s(hc_hw)[i],
                a[i] + 3 * b[i] + 1,
                "element {i} mismatch"
            );
        }
        // Virtual time accounted.
        let st = mgr.state(func).unwrap();
        assert!(st.borrow().virtual_offload > Duration::ZERO);
        assert_eq!(st.borrow().last_report.remainder_elements as i32, (n - 3) % 4);
    }

    #[test]
    fn profile_reset_at_patch_monitor_sees_only_post_patch_data() {
        let mut engine = Engine::new(fig2_module()).unwrap();
        let mut mem = Memory::new();
        let n = 500;
        let (ha, hb) = (mem.alloc_i32(n), mem.alloc_i32(n));
        let hc = mem.alloc_i32(n);
        run_fig2(&mut engine, &mut mem, hc, ha, hb, n as i32);
        let func = engine.func_index("fig2").unwrap();
        assert!(engine.profile(func).counters.cycles > 0, "warm-up must profile");

        let mut mgr =
            OffloadManager::new(OffloadParams { min_dfg_nodes: 1, ..Default::default() });
        mgr.try_offload(&mut engine, func, None).unwrap();
        // Patch time snapshot/reset: the row is zeroed, the software-era
        // counters and the baseline survive in the runtime state.
        assert_eq!(
            engine.profile(func).counters,
            crate::jit::interp::FnCounters::default()
        );
        let st = mgr.state(func).unwrap();
        assert!(st.borrow().pre_patch.counters.cycles > 0);
        assert!(st.borrow().baseline_per_inv > Duration::ZERO);

        // Post-patch data is hook-only: invocations tick, cycles stay 0,
        // so wall-time averages are not polluted by pre-offload samples.
        run_fig2(&mut engine, &mut mem, hc, ha, hb, n as i32);
        run_fig2(&mut engine, &mut mem, hc, ha, hb, n as i32);
        let prof = engine.profile(func);
        assert_eq!(prof.counters.invocations, 2);
        assert_eq!(prof.counters.cycles, 0);
        let mut mon = crate::profile::Monitor::new(Default::default());
        assert!(mon.sample(&engine).is_empty(), "no interpreter cycles post-patch");
        // The stub tracked the offloaded batch sizes.
        assert_eq!(st.borrow().batch_hist.total(), 2);
        assert_eq!(st.borrow().total_elements, 2 * n as u64);
    }

    #[test]
    fn cycle_sim_backend_is_bit_identical() {
        let mut engine = Engine::new(fig2_module()).unwrap();
        let mut mem = Memory::new();
        let n = 97;
        let a: Vec<i32> = (0..n).map(|i| i * 3 - 40).collect();
        let b: Vec<i32> = (0..n).map(|i| 9 - i).collect();
        let (ha, hb) = (mem.from_i32(&a), mem.from_i32(&b));
        let hc = mem.alloc_i32(n as usize);
        let mut mgr = OffloadManager::new(OffloadParams {
            min_dfg_nodes: 1,
            unroll: 2,
            sim_backend: SimBackendChoice::CycleSim,
            ..Default::default()
        });
        let func = engine.func_index("fig2").unwrap();
        mgr.try_offload(&mut engine, func, None).expect("offload");
        run_fig2(&mut engine, &mut mem, hc, ha, hb, n);
        for i in 0..n as usize {
            assert_eq!(mem.i32s(hc)[i], a[i] + 3 * b[i] + 1, "element {i}");
        }
    }

    #[test]
    fn threshold_rejects_small_dfgs() {
        let mut engine = Engine::new(fig2_module()).unwrap();
        let mut mgr = OffloadManager::new(OffloadParams {
            min_dfg_nodes: 1000,
            ..Default::default()
        });
        let func = engine.func_index("fig2").unwrap();
        assert!(matches!(
            mgr.try_offload(&mut engine, func, None),
            Err(RejectReason::TooSmall { .. })
        ));
    }

    #[test]
    fn cache_hits_on_reoffload() {
        let mut engine = Engine::new(fig2_module()).unwrap();
        let mut mgr =
            OffloadManager::new(OffloadParams { min_dfg_nodes: 1, ..Default::default() });
        let func = engine.func_index("fig2").unwrap();
        let r1 = mgr.try_offload(&mut engine, func, None).unwrap();
        assert!(!r1.cache_hit);
        engine.unpatch(func);
        let r2 = mgr.try_offload(&mut engine, func, None).unwrap();
        assert!(r2.cache_hit);
        assert!(r2.par_stats.is_none(), "P&R skipped on hit");
        // The entry carries the winning search's stats: a hit reports the
        // compile cost it avoided paying.
        let avoided = r2.avoided.expect("hit must report avoided compile cost");
        let paid = r1.par_stats.unwrap();
        assert_eq!(avoided.placements, paid.placements);
        assert_eq!(avoided.route_calls, paid.route_calls);
        assert!(r1.avoided.is_none(), "a miss avoided nothing");
    }

    #[test]
    fn background_compile_defers_then_swaps_on_cache_hit() {
        let mut engine = Engine::new(fig2_module()).unwrap();
        let mut mem = Memory::new();
        let n = 500;
        let a: Vec<i32> = (0..n).map(|i| i * 3 - 100).collect();
        let b: Vec<i32> = (0..n).map(|i| 50 - i).collect();
        let (ha, hb) = (mem.from_i32(&a), mem.from_i32(&b));
        let hc = mem.alloc_i32(n as usize);
        run_fig2(&mut engine, &mut mem, hc, ha, hb, n);

        let mut mgr = OffloadManager::new(OffloadParams {
            min_dfg_nodes: 1,
            compile_threads: 2,
            portfolio: 4,
            ..Default::default()
        });
        let func = engine.func_index("fig2").unwrap();
        // First decision: nothing cached -> the job is submitted and the
        // caller keeps its current tier (software), unpatched.
        let r = mgr.reconfigure(&mut engine, func, 2, 0, None).unwrap();
        assert!(matches!(r, Reconfig::Deferred { unroll: 2, .. }), "{r:?}");
        assert!(!engine.is_patched(func), "caller must keep executing software");
        // A repeat decision while the job is in flight stays deferred and
        // must not resubmit (key dedup).
        let r = mgr.reconfigure(&mut engine, func, 2, 0, None);
        assert!(matches!(r, Ok(Reconfig::Deferred { .. })), "{r:?}");
        // Test barrier: wait for the artifact to land in the cache...
        let landed = mgr.drain_compiles();
        assert_eq!(landed.len(), 1, "exactly one job for the deduped key");
        // ...then the next decision swaps it in as a pure cache hit.
        match mgr.reconfigure(&mut engine, func, 2, 0, None).unwrap() {
            Reconfig::Swapped { record, from_unroll } => {
                assert_eq!(from_unroll, 0);
                assert!(record.cache_hit, "the swap must be a cache hit, not a route");
                assert!(record.avoided.is_some());
            }
            other => panic!("expected a swap after landing, got {other:?}"),
        }
        assert!(engine.is_patched(func));
        assert_eq!(
            mgr.compile_stall,
            Duration::ZERO,
            "the caller never blocked inside place & route"
        );
        // Numerics are exact through the background-compiled artifact.
        run_fig2(&mut engine, &mut mem, hc, ha, hb, n);
        for i in 0..n as usize {
            assert_eq!(mem.i32s(hc)[i], a[i] + 3 * b[i] + 1, "element {i}");
        }
    }

    #[test]
    fn rollback_when_offload_slower() {
        let mut engine = Engine::new(fig2_module()).unwrap();
        let mut mem = Memory::new();
        let n = 64; // tiny: transfer overhead dominates -> offload loses
        let (ha, hb) = (mem.alloc_i32(n), mem.alloc_i32(n));
        let hc = mem.alloc_i32(n);
        run_fig2(&mut engine, &mut mem, hc, ha, hb, n as i32);

        let mut mgr = OffloadManager::new(OffloadParams {
            min_dfg_nodes: 1,
            rollback_window: 2,
            ..Default::default()
        });
        let func = engine.func_index("fig2").unwrap();
        mgr.try_offload(&mut engine, func, None).unwrap();
        for _ in 0..3 {
            run_fig2(&mut engine, &mut mem, hc, ha, hb, n as i32);
        }
        let rolled = mgr.check_rollback(&mut engine);
        assert_eq!(rolled, vec![func]);
        assert!(!engine.is_patched(func));
        // Software path works again.
        run_fig2(&mut engine, &mut mem, hc, ha, hb, n as i32);
    }

    #[test]
    fn no_rollback_when_offload_wins() {
        // Make the baseline artificially slow (huge sec_per_cycle is not
        // available per-side, so shrink transfer cost instead: RIFFA-like
        // link and large n).
        let mut engine = Engine::new(fig2_module()).unwrap();
        let mut mem = Memory::new();
        let n = 20_000;
        let (ha, hb) = (mem.alloc_i32(n), mem.alloc_i32(n));
        let hc = mem.alloc_i32(n);
        run_fig2(&mut engine, &mut mem, hc, ha, hb, n as i32);

        let mut mgr = OffloadManager::new(OffloadParams {
            min_dfg_nodes: 1,
            rollback_window: 2,
            unroll: 4,
            pcie: crate::transport::PcieParams::riffa_like(),
            ..Default::default()
        });
        let func = engine.func_index("fig2").unwrap();
        mgr.try_offload(&mut engine, func, None).unwrap();
        for _ in 0..3 {
            run_fig2(&mut engine, &mut mem, hc, ha, hb, n as i32);
        }
        let rolled = mgr.check_rollback(&mut engine);
        assert!(rolled.is_empty(), "offload should win at this scale");
        assert!(engine.is_patched(func));
    }

    #[test]
    fn par_capacity_verdict_is_structured_too_large() {
        // The pre-search capacity check must surface with its numbers,
        // distinct from a stringly routing failure.
        let mut cache = ConfigCache::new(4);
        let mut slot =
            CompileSlot::new(1, 0, Grid::new(1, 1), ParParams::default(), 0xD0E);
        let dfg = crate::dfg::graph::fig2_dfg(); // 3 calc nodes, 1 cell
        let err = slot.compile(&mut cache, &dfg, 7, ParSeed::Cold, false).unwrap_err();
        assert_eq!(err, RejectReason::TooLarge { needed: 3, budget: 1 });
        assert!(!matches!(err, RejectReason::Unroutable(_)));
        assert!(err.to_string().contains("too large"), "{err}");
    }

    #[test]
    fn oversized_dfg_offloads_as_multi_tile_plan_bit_identical() {
        // 4x4 grid = 16 cells; unroll 8 extracts 24 calc nodes — above
        // capacity, so the manager must install a multi-tile plan where
        // PR 5 rejected. Numerics stay exact, remainder included.
        let mut engine = Engine::new(fig2_module()).unwrap();
        let mut mem = Memory::new();
        let n = 1000;
        let a: Vec<i32> = (0..n).map(|i| i * 7 - 300).collect();
        let b: Vec<i32> = (0..n).map(|i| -i + 11).collect();
        let (ha, hb) = (mem.from_i32(&a), mem.from_i32(&b));
        let hc = mem.alloc_i32(n as usize);
        run_fig2(&mut engine, &mut mem, hc, ha, hb, n);

        let mut mgr = OffloadManager::new(OffloadParams {
            min_dfg_nodes: 1,
            unroll: 8,
            grid: Grid::new(4, 4),
            ..Default::default()
        });
        let func = engine.func_index("fig2").unwrap();
        let rec = mgr.try_offload(&mut engine, func, None).expect("tiled offload");
        assert!(rec.tiles > 1, "24 calcs on 16 cells must tile, got {}", rec.tiles);
        assert!(engine.is_patched(func));
        let active = mgr.active(func).unwrap();
        let plan = active.plan.clone().expect("active offload carries its plan");
        assert_eq!(plan.n_tiles(), rec.tiles);
        assert!(plan.n_spills > 0 || plan.n_tiles() == 1);
        assert!(mgr.cache.contains_plan(active.key), "plan cached under the spec key");

        // n - 3 exercises the host-exact remainder through the plan hook.
        run_fig2(&mut engine, &mut mem, hc, ha, hb, n - 3);
        for i in 0..(n - 3) as usize {
            assert_eq!(mem.i32s(hc)[i], a[i] + 3 * b[i] + 1, "element {i} mismatch");
        }
        let st = mgr.state(func).unwrap();
        assert!(st.borrow().virtual_offload > Duration::ZERO);

        // The plan comparator: overlapped multi-pass makespan never loses
        // to the serial sum (acceptance: makespan(async) <= makespan(sync)).
        let fmax = 150.0e6;
        let pcie = PcieParams::default();
        for batch in [64u64, 1024, 4096] {
            let ts = plan_invocation_time(&plan, 8, batch, fmax, (pcie, TransportMode::Sync));
            let ta = plan_invocation_time(
                &plan,
                8,
                batch,
                fmax,
                (pcie, TransportMode::async_default()),
            );
            assert!(ta <= ts, "batch {batch}: async {ta:?} > sync {ts:?}");
        }
        // Degenerate plan-of-one delegates to the single-tile comparator
        // exactly.
        let single_plan = ExecutionPlan::single(plan.tiles[0].cached.clone(), 1);
        let link = (pcie, TransportMode::async_default());
        assert_eq!(
            plan_invocation_time(&single_plan, 2, 512, fmax, link),
            invocation_time(&plan.tiles[0].cached, 2, 512, fmax, link),
        );
    }

    #[test]
    fn tiled_offload_is_bit_identical_to_single_tile_offload() {
        // Same kernel, same inputs: once offloaded whole on a big grid,
        // once as a forced multi-tile plan on a small grid. Outputs must
        // match bit-for-bit (and both match software).
        let n = 257;
        let a: Vec<i32> = (0..n).map(|i| i * 13 - 999).collect();
        let b: Vec<i32> = (0..n).map(|i| 7 * i - 400).collect();
        let run_grid = |grid: Grid| -> (Vec<i32>, usize) {
            let mut engine = Engine::new(fig2_module()).unwrap();
            let mut mem = Memory::new();
            let (ha, hb) = (mem.from_i32(&a), mem.from_i32(&b));
            let hc = mem.alloc_i32(n as usize);
            run_fig2(&mut engine, &mut mem, hc, ha, hb, n as i32);
            let mut mgr = OffloadManager::new(OffloadParams {
                min_dfg_nodes: 1,
                unroll: 8,
                grid,
                ..Default::default()
            });
            let func = engine.func_index("fig2").unwrap();
            let rec = mgr.try_offload(&mut engine, func, None).expect("offload");
            run_fig2(&mut engine, &mut mem, hc, ha, hb, n as i32);
            (mem.i32s(hc).to_vec(), rec.tiles)
        };
        let (big, tiles_big) = run_grid(Grid::new(8, 8));
        let (small, tiles_small) = run_grid(Grid::new(4, 4));
        assert_eq!(tiles_big, 1, "24 calcs fit 64 cells whole");
        assert!(tiles_small > 1, "24 calcs on 16 cells must tile");
        assert_eq!(big, small, "tiling must never change numerics");
        for i in 0..n as usize {
            assert_eq!(big[i], a[i] + 3 * b[i] + 1, "element {i}");
        }
    }

    #[test]
    fn multi_scop_functions_are_not_patched() {
        // atax has two loop nests; patching the whole function with a
        // stub for the first nest would silently drop the second.
        let mut m = Module::new();
        m.add(crate::workloads::polybench::atax());
        let mut engine = Engine::new(m).unwrap();
        let mut mgr =
            OffloadManager::new(OffloadParams { min_dfg_nodes: 1, ..Default::default() });
        let func = engine.func_index("atax").unwrap();
        let err = mgr.try_offload(&mut engine, func, None).unwrap_err();
        assert!(
            matches!(err, RejectReason::Illegal(ref s) if s.contains("SCoP")),
            "{err}"
        );
        assert!(!engine.is_patched(func));
    }

    #[test]
    fn async_transport_is_bit_identical_and_overlaps() {
        let n = 1000;
        let a: Vec<i32> = (0..n).map(|i| i * 5 - 211).collect();
        let b: Vec<i32> = (0..n).map(|i| 17 - i * 2).collect();
        let run_mode = |mode: TransportMode| -> (Vec<i32>, Duration, Duration) {
            let mut engine = Engine::new(fig2_module()).unwrap();
            let mut mem = Memory::new();
            let (ha, hb) = (mem.from_i32(&a), mem.from_i32(&b));
            let hc = mem.alloc_i32(n as usize);
            run_fig2(&mut engine, &mut mem, hc, ha, hb, n);
            let mut mgr = OffloadManager::new(OffloadParams {
                min_dfg_nodes: 1,
                unroll: 4,
                transport: mode,
                ..Default::default()
            });
            let func = engine.func_index("fig2").unwrap();
            mgr.try_offload(&mut engine, func, None).expect("offload");
            run_fig2(&mut engine, &mut mem, hc, ha, hb, n - 3);
            let st = mgr.state(func).unwrap();
            let report = st.borrow().last_report;
            (mem.i32s(hc).to_vec(), report.offload_time(), report.occupancy())
        };
        let (out_sync, wall_sync, occ_sync) = run_mode(TransportMode::Sync);
        let (out_async, wall_async, occ_async) =
            run_mode(TransportMode::async_default());
        assert_eq!(out_sync, out_async, "transport mode must never change numerics");
        // Sync: wall is the serial phase sum. Async: transfers overlap the
        // fabric and each other, so the makespan is strictly below the
        // occupancy sum (and below the sync wall).
        assert_eq!(wall_sync, occ_sync);
        assert!(
            wall_async < occ_async,
            "async wall {wall_async:?} !< occupancy {occ_async:?}"
        );
        assert!(wall_async < wall_sync, "{wall_async:?} !< {wall_sync:?}");
    }

    #[test]
    fn invocation_time_models_sync_as_batch_time_and_async_as_overlap() {
        let mut engine = Engine::new(fig2_module()).unwrap();
        let mut mgr =
            OffloadManager::new(OffloadParams { min_dfg_nodes: 1, ..Default::default() });
        let func = engine.func_index("fig2").unwrap();
        mgr.try_offload(&mut engine, func, None).unwrap();
        let cached = mgr.active(func).unwrap().cached.clone();
        let fmax = 150.0e6;
        let pcie = PcieParams::default();
        let batch = 4096;
        assert_eq!(
            invocation_time(&cached, 1, batch, fmax, (pcie, TransportMode::Sync)),
            batch_time(&cached, 1, batch, fmax),
            "sync comparator stays the transfer-cancelling execution model"
        );
        let sync_full = batch_time(&cached, 1, batch, fmax)
            + Duration::from_secs_f64(
                pcie.transfer_secs(cached.image.n_inputs as u64 * batch * 4)
                    + pcie.transfer_secs(cached.image.out_sel.len() as u64 * batch * 4),
            );
        let pipelined =
            invocation_time(&cached, 1, batch, fmax, (pcie, TransportMode::async_default()));
        assert!(pipelined > Duration::ZERO);
        assert!(
            pipelined < sync_full,
            "overlap must beat the serial sum: {pipelined:?} vs {sync_full:?}"
        );
    }

    #[test]
    fn phases_recorded_in_tracer() {
        let mut engine = Engine::new(fig2_module()).unwrap();
        let mut mgr =
            OffloadManager::new(OffloadParams { min_dfg_nodes: 1, ..Default::default() });
        let func = engine.func_index("fig2").unwrap();
        mgr.try_offload(&mut engine, func, None).unwrap();
        let tracer = mgr.tracer.borrow();
        for phase in [Phase::Analysis, Phase::PlaceRoute, Phase::Configure, Phase::Constants] {
            assert!(tracer.count(phase) > 0, "{phase:?} missing");
        }
    }
}

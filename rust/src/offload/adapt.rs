//! Adaptive respecialization controller (the paper's "live" loop made
//! actually live).
//!
//! The paper motivates run-time offloading with workloads that "may fit
//! particular datasets or usage scenarios, something which is rarely
//! foreseeable at design or compile time" — yet a one-shot offload bakes
//! in a static unroll factor forever. This module closes the loop: the
//! monitor's per-function [`FnProfile`] rows grow per-call-site
//! trip-count histograms ([`Engine::trip_hist`]) while the stub grows
//! batch-size histograms (`RuntimeState::batch_hist`), and a tier policy
//! walks each hot function through
//!
//! ```text
//! Interpreter ──hot──▶ Generic ──profile──▶ Specialized
//!      ▲                  │  ▲                  │
//!      └───rollback───────┘  └────demotion──────┘
//! ```
//!
//! * **Interpreter → Generic**: once the function is hot (cycles +
//!   invocations over the promotion thresholds) and its dominant trip
//!   count clears the batch floor, the generic artifact (unroll =
//!   `generic_unroll`) is routed and patched in.
//! * **Generic → Specialized**: every `decision_window` offloaded
//!   invocations the observed mean batch size picks a target unroll
//!   ([`target_unroll`]); [`OffloadManager::reconfigure`] re-extracts the
//!   DFG at that factor (reusing `dfg/extract`'s unroll machinery),
//!   routes it under the [`SpecSignature`] cache key — generic and
//!   specialized artifacts coexist — and swaps the call-table stub in
//!   place iff the analytic pipeline model prefers it at the observed
//!   batch size.
//! * **Demotion**: a batch-size shift that makes the specialized artifact
//!   model worse swaps the generic artifact back (a cache hit, never a
//!   re-route); the manager's existing rollback window still demotes any
//!   offloaded tier to the interpreter when it loses to software.
//!
//! Every transition is traced ([`TierTransition`]) so tests and the CLI
//! can assert "the trace shows a tier transition".
//!
//! Tiers compose with tiled execution plans transparently: a tier whose
//! re-extracted DFG exceeds the grid budget routes per tile through the
//! same cache/service machinery (`tile_key` entries warm-start
//! independently), and the swap decision compares the *whole* plan's
//! `plan_invocation_time` against the incumbent — a multi-pass artifact
//! is never flattered by timing its first tile alone.

use std::collections::HashMap;

use crate::jit::engine::{Engine, Histogram};
use crate::offload::{OffloadManager, Reconfig};

/// Execution tier of one function.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// Software bytecode (profiled, not offloaded).
    Interpreter,
    /// Offloaded with the generic (no-trip-assumption) artifact.
    Generic,
    /// Offloaded with a profile-chosen unroll specialization.
    Specialized,
}

impl std::fmt::Display for Tier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Tier::Interpreter => write!(f, "interpreter"),
            Tier::Generic => write!(f, "generic"),
            Tier::Specialized => write!(f, "specialized"),
        }
    }
}

/// Controller tunables.
#[derive(Clone, Debug)]
pub struct AdaptParams {
    /// Interpreter cycles before a function is considered hot.
    pub hot_cycles: u64,
    /// Invocations before a function is considered hot.
    pub hot_invocations: u64,
    /// Unroll factor of the generic tier.
    pub generic_unroll: usize,
    /// Specialization candidates (profile-chosen among these).
    pub candidate_unrolls: Vec<usize>,
    /// A candidate `u` is viable only when `batch / u >= min_lanes` —
    /// lanes must still amortize the pipeline fill.
    pub min_lanes: u64,
    /// Dominant trip counts below this stay on the interpreter (transfer
    /// overhead can never win on tiny batches).
    pub min_batch: u64,
    /// Offloaded invocations between tier decisions.
    pub decision_window: u64,
}

impl Default for AdaptParams {
    fn default() -> Self {
        AdaptParams {
            hot_cycles: 10_000,
            hot_invocations: 2,
            generic_unroll: 1,
            candidate_unrolls: vec![2, 4, 8],
            min_lanes: 4,
            min_batch: 4,
            decision_window: 4,
        }
    }
}

/// One traced tier transition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TierTransition {
    pub from: Tier,
    pub to: Tier,
    /// Unroll factor of the artifact live *after* the transition (1 …;
    /// the generic factor when `to` is `Interpreter`-adjacent bookkeeping).
    pub unroll: usize,
    /// Total invocations (interpreted + offloaded) observed by the
    /// controller when the transition fired.
    pub at_invocations: u64,
}

/// Per-function controller state.
#[derive(Clone, Debug)]
pub struct FnAdapt {
    pub tier: Tier,
    /// Unroll of the live artifact (generic factor while on the
    /// interpreter — the factor a promotion would install).
    pub unroll: usize,
    /// Offloaded batch sizes observed by the controller (lifetime).
    pub batch_hist: Histogram,
    pub transitions: Vec<TierTransition>,
    /// Generic→Specialized swaps performed.
    pub respecializations: u64,
    /// Sticky analysis rejection (no point re-trying extraction).
    pub reject: Option<String>,
    total_invocations: u64,
    // Interpreter-tier deltas against the engine's cumulative row.
    last_seen_invocations: u64,
    // Offloaded-tier deltas against the RuntimeState row.
    last_state_invocations: u64,
    last_state_elements: u64,
    // Decision-window accumulators (reset at every decision).
    window_count: u64,
    window_elements: u64,
}

impl FnAdapt {
    fn new(generic_unroll: usize) -> FnAdapt {
        FnAdapt {
            tier: Tier::Interpreter,
            unroll: generic_unroll,
            batch_hist: Histogram::new(),
            transitions: Vec::new(),
            respecializations: 0,
            reject: None,
            total_invocations: 0,
            last_seen_invocations: 0,
            last_state_invocations: 0,
            last_state_elements: 0,
            window_count: 0,
            window_elements: 0,
        }
    }

    fn transition(&mut self, to: Tier, unroll: usize) -> TierTransition {
        let t = TierTransition {
            from: self.tier,
            to,
            unroll,
            at_invocations: self.total_invocations,
        };
        self.transitions.push(t);
        self.tier = to;
        self.unroll = unroll;
        self.window_count = 0;
        self.window_elements = 0;
        self.last_state_invocations = 0;
        self.last_state_elements = 0;
        t
    }
}

/// Profile-chosen unroll factor: the largest candidate whose lane count
/// at the observed batch still amortizes the pipeline fill, else the
/// generic tier's factor.
pub fn target_unroll(params: &AdaptParams, observed_batch: u64) -> usize {
    let mut best = params.generic_unroll;
    let mut cands = params.candidate_unrolls.clone();
    cands.sort_unstable();
    for &u in &cands {
        if u > params.generic_unroll && observed_batch / u as u64 >= params.min_lanes {
            best = u;
        }
    }
    best
}

pub struct AdaptController {
    pub params: AdaptParams,
    states: HashMap<u32, FnAdapt>,
}

impl AdaptController {
    pub fn new(params: AdaptParams) -> AdaptController {
        AdaptController { params, states: HashMap::new() }
    }

    pub fn state(&self, func: u32) -> Option<&FnAdapt> {
        self.states.get(&func)
    }

    pub fn tier(&self, func: u32) -> Tier {
        self.states.get(&func).map(|s| s.tier).unwrap_or(Tier::Interpreter)
    }

    pub fn unroll(&self, func: u32) -> usize {
        self.states.get(&func).map(|s| s.unroll).unwrap_or(self.params.generic_unroll)
    }

    pub fn transitions(&self, func: u32) -> &[TierTransition] {
        self.states.get(&func).map(|s| s.transitions.as_slice()).unwrap_or(&[])
    }

    pub fn respecializations(&self, func: u32) -> u64 {
        self.states.get(&func).map(|s| s.respecializations).unwrap_or(0)
    }

    /// One monitor tick for `func`: fold new profile/stub observations
    /// into the histograms, then run the tier policy. Returns the
    /// transition if one fired.
    pub fn observe(
        &mut self,
        mgr: &mut OffloadManager,
        engine: &mut Engine,
        func: u32,
    ) -> Option<TierTransition> {
        let p = self.params.clone();
        let st = self
            .states
            .entry(func)
            .or_insert_with(|| FnAdapt::new(p.generic_unroll));

        if st.tier != Tier::Interpreter && !engine.is_patched(func) {
            // The manager's rollback window (or a trap) demoted the
            // function to software behind our back: track it.
            let prof = engine.profile(func);
            st.last_seen_invocations = prof.counters.invocations;
            return Some(st.transition(Tier::Interpreter, p.generic_unroll));
        }

        match st.tier {
            Tier::Interpreter => {
                let prof = engine.profile(func);
                let d = prof.counters.invocations.saturating_sub(st.last_seen_invocations);
                st.last_seen_invocations = prof.counters.invocations;
                st.total_invocations += d;
                if st.reject.is_some() {
                    return None;
                }
                if prof.counters.cycles < p.hot_cycles
                    || prof.counters.invocations < p.hot_invocations
                {
                    return None;
                }
                // Size threshold: tiny trip counts never amortize the
                // transfer, stay in software.
                if engine.trip_hist(func).dominant_floor() < p.min_batch {
                    return None;
                }
                // Promotion goes through `reconfigure` (with nothing live
                // it installs unconditionally) so the compile service can
                // defer it: the function keeps interpreting until the
                // generic artifact lands, then a later tick promotes via
                // a cache hit — the interpreter→generic stall is gone too.
                match mgr.reconfigure(engine, func, p.generic_unroll, 0, None) {
                    Ok(Reconfig::Swapped { .. }) => {
                        Some(st.transition(Tier::Generic, p.generic_unroll))
                    }
                    Ok(Reconfig::Deferred { .. }) | Ok(Reconfig::Kept { .. }) => None,
                    Err(reason) => {
                        st.reject = Some(reason.to_string());
                        None
                    }
                }
            }
            Tier::Generic | Tier::Specialized => {
                let rt = mgr.state(func)?;
                // Exact per-invocation deltas from the stub's cumulative
                // counters — a tick folding several invocations must not
                // charge the last batch size to all of them.
                let (inv, elements) = {
                    let s = rt.borrow();
                    (s.invocations, s.total_elements)
                };
                let d = inv.saturating_sub(st.last_state_invocations);
                if d == 0 {
                    return None;
                }
                let d_elems = elements.saturating_sub(st.last_state_elements);
                st.last_state_invocations = inv;
                st.last_state_elements = elements;
                st.total_invocations += d;
                st.batch_hist.record_n(d_elems / d, d);
                st.window_count += d;
                st.window_elements += d_elems;
                if st.window_count < p.decision_window {
                    return None;
                }
                let observed = st.window_elements / st.window_count.max(1);
                st.window_count = 0;
                st.window_elements = 0;
                let target = target_unroll(&p, observed);
                if target == st.unroll {
                    return None;
                }
                // Demotion back to the generic tier re-uses the generic
                // signature — a guaranteed cache hit, never a re-route.
                let bucket = if target == p.generic_unroll {
                    0
                } else {
                    Histogram::bucket_of(observed)
                };
                match mgr.reconfigure(engine, func, target, bucket, Some(observed)) {
                    Ok(Reconfig::Swapped { .. }) => {
                        let to = if target > p.generic_unroll {
                            Tier::Specialized
                        } else {
                            Tier::Generic
                        };
                        if to == Tier::Specialized {
                            st.respecializations += 1;
                        }
                        Some(st.transition(to, target))
                    }
                    // The model still prefers the live artifact (or the
                    // candidate failed to extract/route): stay put. A
                    // deferred candidate also stays put — the current tier
                    // keeps serving until the background compile lands.
                    Ok(Reconfig::Kept { .. }) | Ok(Reconfig::Deferred { .. }) | Err(_) => None,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::func::{FuncBuilder, Module};
    use crate::ir::instr::Ty;
    use crate::jit::interp::{Memory, Val};
    use crate::offload::{OffloadManager, OffloadParams};

    fn fig2_module() -> Module {
        let mut m = Module::new();
        let mut b = FuncBuilder::new(
            "fig2",
            &[("C", Ty::Ptr), ("A", Ty::Ptr), ("B", Ty::Ptr), ("n", Ty::I32)],
        );
        let (c, a, bb, n) = (b.param(0), b.param(1), b.param(2), b.param(3));
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, i| {
            let av = b.load(Ty::I32, a, i);
            let bv = b.load(Ty::I32, bb, i);
            let c3 = b.const_i32(3);
            let t = b.mul(bv, c3);
            let s = b.add(av, t);
            let c1 = b.const_i32(1);
            let r = b.add(s, c1);
            b.store(Ty::I32, c, i, r);
        });
        m.add(b.ret(None));
        m
    }

    #[test]
    fn target_unroll_is_profile_driven() {
        let p = AdaptParams {
            candidate_unrolls: vec![2, 4, 8],
            min_lanes: 4,
            generic_unroll: 1,
            ..Default::default()
        };
        assert_eq!(target_unroll(&p, 0), 1);
        assert_eq!(target_unroll(&p, 7), 1); // 7/2 = 3 lanes < 4
        assert_eq!(target_unroll(&p, 8), 2);
        assert_eq!(target_unroll(&p, 16), 4);
        assert_eq!(target_unroll(&p, 1000), 8);
    }

    #[test]
    fn tiny_trip_counts_stay_on_the_interpreter() {
        let mut engine = crate::jit::engine::Engine::new(fig2_module()).unwrap();
        let mut mem = Memory::new();
        let (ha, hb, hc) = (mem.alloc_i32(4), mem.alloc_i32(4), mem.alloc_i32(4));
        let args = [Val::P(hc), Val::P(ha), Val::P(hb), Val::I(2)];
        let mut mgr =
            OffloadManager::new(OffloadParams { min_dfg_nodes: 1, ..Default::default() });
        let mut ctl = AdaptController::new(AdaptParams {
            hot_cycles: 1,
            hot_invocations: 1,
            min_batch: 16,
            ..Default::default()
        });
        let func = engine.func_index("fig2").unwrap();
        for _ in 0..8 {
            engine.call_idx(func, &mut mem, &args).unwrap();
            assert!(ctl.observe(&mut mgr, &mut engine, func).is_none());
        }
        assert_eq!(ctl.tier(func), Tier::Interpreter);
        assert!(!engine.is_patched(func), "size threshold must keep it in software");
    }

    #[test]
    fn rejected_function_sticks_to_interpreter() {
        // atax is multi-SCoP: the promotion attempt must fail once and
        // never be retried.
        let mut m = Module::new();
        m.add(crate::workloads::polybench::atax());
        let mut engine = crate::jit::engine::Engine::new(m).unwrap();
        let mut mem = Memory::new();
        let n = 6usize;
        let ha = mem.from_i32(&vec![1; n * n]);
        let hx = mem.from_i32(&vec![2; n]);
        let hy = mem.alloc_i32(n);
        let htmp = mem.alloc_i32(n);
        let args =
            [Val::P(ha), Val::P(hx), Val::P(hy), Val::P(htmp), Val::I(n as i32)];
        let mut mgr =
            OffloadManager::new(OffloadParams { min_dfg_nodes: 1, ..Default::default() });
        let mut ctl = AdaptController::new(AdaptParams {
            hot_cycles: 1,
            hot_invocations: 1,
            min_batch: 1,
            ..Default::default()
        });
        let func = engine.func_index("atax").unwrap();
        for _ in 0..3 {
            engine.call_idx(func, &mut mem, &args).unwrap();
            assert!(ctl.observe(&mut mgr, &mut engine, func).is_none());
        }
        assert_eq!(ctl.tier(func), Tier::Interpreter);
        let reject = ctl.state(func).unwrap().reject.clone().unwrap();
        assert!(reject.contains("SCoP"), "{reject}");
    }
}

//! The multi-tenant offload server: the paper's one-engine decision loop
//! generalized to N independently placed-and-routed DFE shard regions on a
//! single device, serving several concurrent workload streams.
//!
//! Layered on the existing machinery:
//!   * the device grid is partitioned into disjoint shard [`Region`]s
//!     (validated against the `dfe::resource` budgets — echoing the
//!     application-specific multi-region overlays of Mbongue et al.);
//!   * one LRU [`ConfigCache`] is shared across tenants, keyed by
//!     [`region_key`] (DFG structure + region geometry), so tenants running
//!     the same kernel share one place-&-route result;
//!   * the PCIe link is one arbitrated resource: per-shard configuration
//!     downloads and data transfers are coalesced per scheduling round on a
//!     [`BatchQueue`] (single setup per batch), in the spirit of the
//!     batched shared-accelerator serving of Cong et al.;
//!   * requests are admitted by a hotness-weighted round robin, with the
//!     paper's per-tenant rollback: a tenant whose offloaded path loses to
//!     its own software baseline is unpatched and served in software;
//!   * place & route runs through the compile service (`par::service` via
//!     [`CompileSlot`]): misses race a seed portfolio, respecialization
//!     misses compile in the background and swap in at a round boundary —
//!     after admission no tenant ever blocks inside P&R
//!     (`compile_stall_secs == 0`, `tests/serve.rs` S7).
//!
//! Timing discipline matches the rest of the crate: numerics are real
//! (every request executes through the tenant's engine), performance is
//! virtual (link/shard occupancy on the transport and DFE models), so the
//! throughput-scaling results are machine-independent. Outputs are
//! bit-identical to the single-tenant offload path by construction —
//! placement affects timing, never values — and `tests/serve.rs` plus
//! `tlo serve --verify` enforce it.

// Serve hot path: a stray unwrap here takes every tenant down at once.
// Recoverable conditions must degrade (software tier / structured error),
// never panic — enforced via clippy.toml's disallowed_methods.
#![cfg_attr(not(test), deny(clippy::disallowed_methods))]

use std::cell::RefCell;
use std::fmt;
use std::rc::Rc;
use std::time::{Duration, Instant};

use crate::dfe::cache::{
    dfg_key, region_key, spec_key, CacheStats, CachedConfig, ConfigCache, SpecSignature,
};
use crate::dfe::grid::{Grid, Region};
use crate::dfe::plan::{tile_key, ExecutionPlan, PlanTile};
use crate::dfg::extract::OffloadDfg;
use crate::dfg::partition::{needs_tiling, partition, PartitionError, TileBudget};
use crate::dfe::resource::{device_by_name, Device};
use crate::ir::func::Module;
use crate::jit::engine::{Engine, Histogram};
use crate::jit::interp::{Memory, Val};
use crate::par::{ParParams, ParSeed};
use crate::trace::{Phase, Tracer};
use crate::transport::{AsyncLink, BatchQueue, PcieParams, PcieSim, TransportMode};
use crate::util::err::{Error, Result};
use crate::{anyhow, bail};
use crate::util::fmt_duration;
use crate::workloads::{polybench, video};

use super::adapt::{target_unroll, AdaptParams};
use super::latency::LatencyHist;
use super::stub::{make_offload_hook, make_plan_hook, DfeBackend, TimeModel};
use super::{CompileSlot, OffloadManager, OffloadParams, RejectReason, RuntimeState};

/// Software warmup invocations per tenant before the offload decision
/// (establishes the rollback baseline, like the paper's "after running the
/// application for a few seconds").
pub const WARMUP_REQUESTS: u64 = 2;

/// Structured serve-layer construction errors. These were panics/bails in
/// the pre-fleet server; a fleet supervisor has to be able to reject a bad
/// topology (zero shards, a partition the grid cannot host) without dying,
/// so they are a real enum the caller can match on.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// No tenant specs were provided.
    NoTenants,
    /// `shards == 0`.
    NoShards,
    /// Fleet construction with zero remote nodes.
    NoNodes,
    /// The grid partition produced no regions.
    EmptyPartition { shards: usize },
    /// More shards requested than the grid has cells to host.
    InfeasiblePartition { shards: usize, rows: usize, cols: usize },
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::NoTenants => write!(f, "serve needs at least one tenant"),
            ServeError::NoShards => write!(f, "serve needs at least one shard"),
            ServeError::NoNodes => write!(f, "fleet needs at least one node"),
            ServeError::EmptyPartition { shards } => {
                write!(f, "grid partition into {shards} shard(s) produced no regions")
            }
            ServeError::InfeasiblePartition { shards, rows, cols } => {
                write!(f, "cannot partition a {rows}x{cols} grid into {shards} shards")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Server tunables.
#[derive(Clone, Debug)]
pub struct ServeParams {
    /// Number of shard regions the device grid is partitioned into.
    pub shards: usize,
    /// Full overlay grid on the device (partitioned, then each shard is an
    /// independent place-&-route domain).
    pub grid: Grid,
    /// Device powering the resource/Fmax model (Table II name).
    pub device: String,
    /// Shared-link parameters. Default is the packed RIFFA-like protocol:
    /// the serving path is the paper's own "fix the transport" projection;
    /// pass `PcieParams::default()` for the tagged prototype protocol.
    pub pcie: PcieParams,
    pub par: ParParams,
    pub min_dfg_nodes: usize,
    /// Offloaded invocations observed before a rollback decision.
    pub rollback_window: u64,
    pub cache_capacity: usize,
    /// Seconds per interpreter cycle (virtual host clock).
    pub sec_per_cycle: f64,
    pub seed: u64,
    /// Configuration-FSM latency per overlay reconfiguration (the same
    /// epsilon the single-tenant manager charges).
    pub reconfig_epsilon: Duration,
    /// Requests admitted per scheduling round; transfers for the same
    /// shard within a round are coalesced. 0 = one slot per tenant.
    pub batch_window: usize,
    /// Per-tenant adaptive respecialization (`offload::adapt` policy):
    /// after each scheduling round, every offloaded tenant's observed
    /// batch sizes pick a target unroll and the shard-resident artifact
    /// is respecialized through the shared cache when the pipeline model
    /// prefers it — shards specialize independently under the
    /// hotness-weighted scheduler. `None` keeps the static PR-2 behavior.
    pub adapt: Option<AdaptParams>,
    /// Shared-link scheduling discipline. `Sync` is the paper's blocking
    /// prototype: every round's uploads, executions and downloads complete
    /// before the next round starts. `Async` removes the round barrier:
    /// the link is full-duplex, each shard keeps `depth` staging buffers,
    /// and round *r+1*'s uploads overlap round *r*'s executions and round
    /// *r-1*'s downloads. Numerics are identical by construction
    /// (`tests/serve.rs` S6 diffs the two bit-for-bit).
    pub transport: TransportMode,
    /// P&R seeds raced per compile (K >= 1); the winner is deterministic
    /// per `(cache key, K, seed)`.
    pub portfolio: usize,
    /// Compile-service worker threads. 0 = synchronous compiles: a
    /// respecialization miss stalls the adapt pass inside place & route
    /// (counted in `compile_stall_secs`). N > 0 = respecs compile in the
    /// background and swap in at a later round boundary — no tenant ever
    /// blocks on P&R after admission (`tests/serve.rs` S7).
    pub compile_threads: usize,
    /// Per-round service-level objective in virtual seconds. When the
    /// projected fabric occupancy of a scheduling round exceeds it, the
    /// remaining requests of tenants *below* the batch's top priority
    /// class are shed to the software tier (numerics still execute; only
    /// the virtual-time accounting and the `shed` counter change).
    /// `None` = no admission control (the historical behavior).
    pub slo: Option<f64>,
    /// Directory holding the [`ConfigCache`] snapshot. When set, the
    /// server reloads routed artifacts, plans and provenance at
    /// construction (a warm restart performs zero P&R invocations) and
    /// `tlo serve` re-serializes the cache after the run.
    pub cache_dir: Option<std::path::PathBuf>,
    /// Deadline for one blocking wait on the compile service (admission
    /// drains and shutdown barriers). An expired wait surfaces as
    /// [`RejectReason::CompileTimeout`] instead of blocking forever.
    pub drain_timeout: Duration,
    /// Execute tenant numerics through the lowered batch kernels
    /// (`dfe::lower`, the default). `false` (`tlo serve --no-lower`) pins
    /// the interpreted wave executor — the fallback CI exercises once per
    /// run so it can never rot. Numerics are identical either way.
    pub lower: bool,
}

impl Default for ServeParams {
    fn default() -> Self {
        ServeParams {
            shards: 2,
            grid: Grid::new(12, 12),
            device: "Virtex 7 (VC707)".into(),
            pcie: PcieParams::riffa_like(),
            par: ParParams::default(),
            min_dfg_nodes: 1,
            rollback_window: 8,
            cache_capacity: 32,
            sec_per_cycle: 1e-9,
            seed: 0x5EED,
            reconfig_epsilon: Duration::from_micros(600),
            batch_window: 0,
            adapt: None,
            transport: TransportMode::Sync,
            portfolio: 1,
            compile_threads: 0,
            slo: None,
            cache_dir: None,
            drain_timeout: Duration::from_secs(30),
            lower: true,
        }
    }
}

/// One tenant's workload stream, as data the server can drive and the
/// verification path can replay: module builder, memory setup, optional
/// per-request input refresh, and the handles that constitute the
/// tenant's observable output.
#[derive(Clone)]
pub struct TenantSpec {
    pub name: String,
    pub module: fn() -> Module,
    /// Function to serve (must exist in `module`).
    pub func: &'static str,
    /// Innermost-loop unroll factor for extraction.
    pub unroll: usize,
    /// Allocates the tenant's buffers and returns the call arguments.
    pub setup: fn(&mut Memory) -> Vec<Val>,
    /// Optional per-request input refresh; `seq` counts all invocations
    /// including warmup, so replays are exact.
    pub refresh: Option<fn(&mut Memory, &[Val], u64)>,
    /// Handles whose final contents are the tenant's observable output.
    /// Must enumerate *every* array the function writes: this set is both
    /// the bit-identity verification surface and the restore set for the
    /// failure rollback (a trapped offload replays in software after
    /// restoring these handles to their pre-call contents).
    pub outputs: fn(&[Val]) -> Vec<u32>,
    /// SLO class: scheduling weight multiplier and shed ordering. Higher
    /// classes are admitted first, race their compiles first, and are
    /// shed last under an overloaded `ServeParams::slo`. Equal priorities
    /// (the default, 1) reproduce the historical scheduler bit-for-bit.
    pub priority: u32,
}

/// A tenant's accepted offload, as scheduled on the shards.
#[derive(Clone, Debug)]
pub struct TenantOffload {
    /// Shared cache key ([`region_key`] over [`spec_key`]) — doubles as
    /// the shard-resident configuration identity.
    pub key: u64,
    /// Whether admission reused another tenant's routed configuration.
    pub cache_hit: bool,
    pub config_words: u64,
}

/// One live respecialization on the serve path (tier-transition trace).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RespecEvent {
    /// Requests the tenant had served when the swap fired.
    pub at_request: u64,
    pub from_unroll: usize,
    pub to_unroll: usize,
}

/// One admitted tenant: its own engine + address space, plus the live
/// offload/rollback state.
pub struct Tenant {
    pub spec: TenantSpec,
    pub engine: Engine,
    pub mem: Memory,
    pub args: Vec<Val>,
    pub func: u32,
    pub out_handles: Vec<u32>,
    /// Scheduling weight (observed interpreter cycles at admission).
    pub hotness: f64,
    pub baseline_per_inv: Duration,
    pub served: u64,
    pub rolled_back: bool,
    /// Why the tenant serves in software, when it does.
    pub reject: Option<String>,
    pub offload: Option<TenantOffload>,
    pub state: Option<Rc<RefCell<RuntimeState>>>,
    /// Per-tenant (uncontended) transfer accounting — the same numbers the
    /// single-tenant manager would produce, used for rollback economics.
    pub pcie: Rc<RefCell<PcieSim>>,
    /// Unroll factor of the live artifact (the spec's factor until the
    /// adaptive pass respecializes).
    pub active_unroll: usize,
    /// The live artifact, kept for the pipeline-model comparison when a
    /// respecialization candidate is routed. For a tiled tenant this is
    /// tile 0 (the representative artifact — its placement warm-starts
    /// respecialization searches); the full plan lives in `plan`.
    pub cached: Option<CachedConfig>,
    /// The live multi-tile plan, when the tenant's DFG exceeds the shard
    /// budget. `None` on the single-tile path — single-tile artifacts
    /// never travel as plans, so the legacy flow stays byte-identical.
    pub plan: Option<ExecutionPlan>,
    /// Respecialization trace (tier transitions on the serve path).
    pub respecs: Vec<RespecEvent>,
    /// Offloaded totals folded in from runtime states retired by earlier
    /// respecializations (each swap starts a fresh per-tier state; the
    /// report sums these with the live state so totals stay cumulative).
    pub retired_invocations: u64,
    pub retired_virtual: Duration,
    pub retired_elements: u64,
    /// Offloaded invocations/elements already folded into the decision
    /// window (mirrors `adapt::FnAdapt`'s delta tracking — keep in sync).
    adapt_seen: u64,
    adapt_seen_elements: u64,
    window_count: u64,
    window_elements: u64,
    /// Wall time this tenant's serving path blocked inside place & route
    /// after admission (respecialization misses compiled synchronously).
    /// The S7 invariant: identically zero with the compile service on.
    pub compile_stall: Duration,
    /// Requests that completed on a remote fleet node (fleet mode only;
    /// 0 on the single-host path).
    pub remote_served: u64,
    /// Network retry attempts spent on this tenant's remote exchanges.
    pub retries: u64,
    /// Requests that exhausted the remote retry budget (or found no
    /// healthy node) and fell back to the local shard fabric.
    pub fallback_local: u64,
    /// Requests served by the interpreter in fleet mode because no fabric
    /// path applied (rollback, rejection, software tenant).
    pub fallback_software: u64,
    /// Respecialization compiles that failed structurally — the tenant
    /// was demoted or kept its live tier instead of the server panicking.
    pub compile_failures: u64,
    /// Respec target whose compile is in flight: `(unroll, trip_bucket,
    /// cache key)`. While pending, decision windows for the same target
    /// return immediately — no re-extraction, no spurious cache-miss
    /// accounting for a compile that is already running.
    pending_spec: Option<(usize, usize, u64)>,
    /// Per-request virtual latency distribution (fixed log2 buckets, so
    /// percentiles are deterministic and mergeable across nodes).
    pub latency: LatencyHist,
    /// Requests shed to the software tier by SLO admission control.
    pub shed: u64,
}

/// One shard region's live state.
#[derive(Clone, Copy, Debug)]
pub struct ShardState {
    pub region: Region,
    /// Configuration currently loaded (a [`region_key`]).
    pub resident: Option<u64>,
    pub busy_until: Duration,
    /// The same instant in exact f64 seconds (the async scheduler's
    /// working representation; `busy_until` is its rounded mirror).
    pub busy_secs: f64,
    pub reconfigs: u64,
    pub executed: u64,
}

/// The serve layer's shared PCIe link, in either scheduling discipline.
pub enum ServeLink {
    /// Round-barriered half-duplex coalescing (the paper's discipline).
    Sync(BatchQueue),
    /// Full-duplex double-buffered pipeline (`transport::pipeline`).
    Async(AsyncLink),
}

impl ServeLink {
    /// The shared accounting core (totals for reports).
    pub fn sim(&self) -> &PcieSim {
        match self {
            ServeLink::Sync(q) => &q.sim,
            ServeLink::Async(l) => &l.sim,
        }
    }
}

pub struct OffloadServer {
    pub params: ServeParams,
    pub device: Device,
    pub regions: Vec<Region>,
    /// Common routing grid: the smallest region shape, so every cached
    /// configuration loads onto any shard.
    pub route_grid: Grid,
    pub cache: ConfigCache,
    pub tenants: Vec<Tenant>,
    pub shards: Vec<ShardState>,
    pub link: ServeLink,
    pub tracer: Rc<RefCell<Tracer>>,
    /// Virtual server clock (advanced per scheduling round).
    pub clock: Duration,
    /// Portfolio/compile-service state shared by admission and the
    /// adaptive pass (see [`CompileSlot`]).
    pub compile: CompileSlot,
}

impl OffloadServer {
    pub fn new(params: ServeParams, specs: Vec<TenantSpec>) -> Result<OffloadServer> {
        if specs.is_empty() {
            return Err(Error::msg(ServeError::NoTenants));
        }
        if params.shards == 0 {
            return Err(Error::msg(ServeError::NoShards));
        }
        let device = device_by_name(&params.device)
            .ok_or_else(|| anyhow!("unknown device '{}'", params.device))?;
        let est = device.estimate(params.grid.rows, params.grid.cols);
        if !est.routable {
            bail!(
                "overlay {}x{} exceeds the {} resource budget ({:.1}% LUTs, ceiling {:.0}%)",
                params.grid.rows,
                params.grid.cols,
                device.name,
                est.lut_pct,
                device.tool.route_ceiling_pct()
            );
        }
        if params.shards > params.grid.rows * params.grid.cols {
            return Err(Error::msg(ServeError::InfeasiblePartition {
                shards: params.shards,
                rows: params.grid.rows,
                cols: params.grid.cols,
            }));
        }
        let regions = params.grid.partition(params.shards).map_err(Error::msg)?;
        // Per-region budget validation: every shard must itself be a
        // routable overlay on this device.
        for r in &regions {
            let e = device.estimate(r.grid.rows, r.grid.cols);
            if !e.routable {
                bail!("shard region {r} unroutable on {}", device.name);
            }
        }
        // Common routing grid: the smallest region shape. An empty
        // partition is a structured error, never an unwrap panic.
        let route_grid = match (
            regions.iter().map(|r| r.grid.rows).min(),
            regions.iter().map(|r| r.grid.cols).min(),
        ) {
            (Some(rows), Some(cols)) => Grid::new(rows, cols),
            _ => {
                return Err(Error::msg(ServeError::EmptyPartition {
                    shards: params.shards,
                }))
            }
        };
        let shards = regions
            .iter()
            .map(|&region| ShardState {
                region,
                resident: None,
                busy_until: Duration::ZERO,
                busy_secs: 0.0,
                reconfigs: 0,
                executed: 0,
            })
            .collect();
        let link = match params.transport {
            TransportMode::Sync => ServeLink::Sync(BatchQueue::new(params.pcie, params.shards)),
            TransportMode::Async { depth } => {
                ServeLink::Async(AsyncLink::new(params.pcie, params.shards, depth))
            }
        };
        let mut compile = CompileSlot::new(
            params.portfolio,
            params.compile_threads,
            route_grid,
            params.par,
            params.seed,
        );
        compile.drain_timeout = params.drain_timeout;
        let mut server = OffloadServer {
            device,
            regions: regions.clone(),
            route_grid,
            cache: ConfigCache::new(params.cache_capacity),
            tenants: Vec::new(),
            shards,
            link,
            tracer: Rc::new(RefCell::new(Tracer::new())),
            clock: Duration::ZERO,
            compile,
            params,
        };
        // Warm restart: reload the persisted cache snapshot *before*
        // admission, so every tenant's artifact and plan resolves as a
        // pure hit — zero P&R invocations on a restarted server.
        if let Some(dir) = server.params.cache_dir.clone() {
            crate::dfe::persist::load_cache(&mut server.cache, &dir)
                .map_err(|e| anyhow!("cache snapshot in {}: {e}", dir.display()))?;
        }
        for spec in specs {
            server.admit(spec)?;
        }
        Ok(server)
    }

    pub fn n_tenants(&self) -> usize {
        self.tenants.len()
    }

    /// A tenant's observable output arrays (for verification).
    pub fn tenant_outputs(&self, i: usize) -> Vec<Vec<i32>> {
        let t = &self.tenants[i];
        t.out_handles.iter().map(|&h| t.mem.i32s(h).to_vec()).collect()
    }

    /// Admit one tenant: warm its software profile, then attempt the
    /// offload through the shared cache onto the route grid. Offload
    /// rejection is not an error — the tenant serves in software.
    fn admit(&mut self, spec: TenantSpec) -> Result<()> {
        let mut engine = Engine::new((spec.module)())?;
        let mut mem = Memory::new();
        let args = (spec.setup)(&mut mem);
        let func = engine
            .func_index(spec.func)
            .ok_or_else(|| anyhow!("tenant {}: unknown function '{}'", spec.name, spec.func))?;
        for seq in 0..WARMUP_REQUESTS {
            if let Some(refresh) = spec.refresh {
                refresh(&mut mem, &args, seq);
            }
            engine
                .call_idx(func, &mut mem, &args)
                .map_err(|e| anyhow!("tenant {} warmup: {e}", spec.name))?;
        }
        let prof = engine.profile(func);
        let baseline_per_inv = Duration::from_secs_f64(
            self.params.sec_per_cycle * prof.counters.cycles as f64
                / prof.counters.invocations.max(1) as f64,
        );
        let hotness = crate::profile::hotness(&engine, func);
        let out_handles = (spec.outputs)(&args);
        let mut tenant = Tenant {
            spec,
            engine,
            mem,
            args,
            func,
            out_handles,
            hotness,
            baseline_per_inv,
            served: 0,
            rolled_back: false,
            reject: None,
            offload: None,
            state: None,
            pcie: Rc::new(RefCell::new(PcieSim::new(self.params.pcie))),
            active_unroll: 0,
            cached: None,
            plan: None,
            respecs: Vec::new(),
            retired_invocations: 0,
            retired_virtual: Duration::ZERO,
            retired_elements: 0,
            adapt_seen: 0,
            adapt_seen_elements: 0,
            window_count: 0,
            window_elements: 0,
            compile_stall: Duration::ZERO,
            remote_served: 0,
            retries: 0,
            fallback_local: 0,
            fallback_software: 0,
            compile_failures: 0,
            pending_spec: None,
            latency: LatencyHist::new(),
            shed: 0,
        };
        let unroll = tenant.spec.unroll;
        // Admission compiles synchronously (warmup): the tenant is not
        // serving yet, so this is the one P&R that may block.
        if let Err(reason) = offload_tenant_impl(
            &mut self.cache,
            &mut self.compile,
            &self.device,
            &self.params,
            self.route_grid,
            &mut tenant,
            unroll,
            0,
            None,
            false,
        ) {
            tenant.reject = Some(reason.to_string());
        }
        self.tenants.push(tenant);
        Ok(())
    }

    /// Land any artifacts the background compile service finished into
    /// the shared cache (round-boundary barrier: the adaptive pass then
    /// swaps them in as cache hits). Returns the landed keys.
    pub fn pump_compiles(&mut self) -> Vec<u64> {
        self.compile.pump(&mut self.cache)
    }

    /// Block until every in-flight compile job has landed (test barrier /
    /// orderly shutdown; `run` only ever pumps).
    pub fn drain_compiles(&mut self) -> Vec<u64> {
        let timeout = self.params.drain_timeout;
        self.compile.drain(&mut self.cache, timeout)
    }

    /// Post-round adaptive pass: fold each offloaded tenant's observed
    /// batch sizes into its decision window and respecialize the
    /// shard-resident artifact when the profile picks a different unroll
    /// and the pipeline model agrees (`offload::adapt` policy, per
    /// tenant, against the *shared* cache — so a second tenant reaching
    /// the same specialization is a cache hit).
    pub(crate) fn adapt_tenant(&mut self, ti: usize, ap: &AdaptParams) {
        // Exact per-invocation deltas from the stub's cumulative counters
        // (mirrors `adapt::AdaptController::observe` — keep in sync).
        let (inv, elements) = {
            let t = &self.tenants[ti];
            if t.rolled_back || t.offload.is_none() || !t.engine.is_patched(t.func) {
                return;
            }
            let Some(state) = &t.state else { return };
            let s = state.borrow();
            (s.invocations, s.total_elements)
        };
        let (observed, target) = {
            let t = &mut self.tenants[ti];
            let d = inv.saturating_sub(t.adapt_seen);
            if d == 0 {
                return;
            }
            let d_elems = elements.saturating_sub(t.adapt_seen_elements);
            t.adapt_seen = inv;
            t.adapt_seen_elements = elements;
            t.window_count += d;
            t.window_elements += d_elems;
            if t.window_count < ap.decision_window {
                return;
            }
            let observed = t.window_elements / t.window_count.max(1);
            t.window_count = 0;
            t.window_elements = 0;
            // On the serve path the "generic" tier is the tenant's
            // admission unroll; candidates only specialize beyond it.
            let mut ap_t = ap.clone();
            ap_t.generic_unroll = t.spec.unroll;
            let target = target_unroll(&ap_t, observed);
            if target == t.active_unroll {
                return;
            }
            (observed, target)
        };
        let from = self.tenants[ti].active_unroll;
        // Demotion back to the spec'd unroll re-uses the admission
        // signature — a guaranteed cache hit, never a re-route.
        let bucket = if target == self.tenants[ti].spec.unroll {
            0
        } else {
            Histogram::bucket_of(observed)
        };
        // Background jobs race in tenant-importance order: hot/high-class
        // tenants' respecializations jump the compile queue. Scheduling
        // only — the landed artifact stays a pure function of the key.
        self.compile.priority = {
            let t = &self.tenants[ti];
            t.spec.priority as u64 * (t.hotness.max(0.0) as u64).max(1)
        };
        let swapped = offload_tenant_impl(
            &mut self.cache,
            &mut self.compile,
            &self.device,
            &self.params,
            self.route_grid,
            &mut self.tenants[ti],
            target,
            bucket,
            Some(observed),
            true,
        );
        match swapped {
            Ok(true) => {
                let t = &mut self.tenants[ti];
                let at_request = t.served;
                t.respecs.push(RespecEvent {
                    at_request,
                    from_unroll: from,
                    to_unroll: target,
                });
            }
            Ok(false) => {}
            Err(reason) => {
                // Structured compile failure: the serve loop survives. A
                // tenant whose live tier still works keeps serving it; one
                // left unpatched is demoted to software with the reason
                // recorded for the report.
                let t = &mut self.tenants[ti];
                // A compile-service stall is tail latency, not a crash:
                // the expired deadline lands in the histogram so p99
                // reflects it.
                if let RejectReason::CompileTimeout(d) = &reason {
                    t.latency.record(*d);
                }
                t.compile_failures += 1;
                if !t.engine.is_patched(t.func) {
                    t.offload = None;
                    t.reject = Some(format!("respecialization compile failed: {reason}"));
                }
            }
        }
    }

    /// Serve `requests_per_tenant` requests per tenant to completion and
    /// return the aggregate report. Numerics execute immediately; link and
    /// shard occupancy advance the virtual clock round by round.
    ///
    /// Under the synchronous transport every round is a barrier: all
    /// uploads, executions and downloads complete before the next round's
    /// transfers start. Under the asynchronous transport only admission
    /// stays round-based — the link timelines, shard busy intervals and
    /// staging rings carry across rounds, so round *r+1*'s uploads overlap
    /// round *r*'s fabric time and round *r-1*'s downloads.
    pub fn run(&mut self, requests_per_tenant: u64) -> ServeReport {
        let n_t = self.tenants.len();
        let window = if self.params.batch_window == 0 { n_t } else { self.params.batch_window };
        let epsilon = self.params.reconfig_epsilon;
        let barrier = !self.params.transport.is_async();
        let mut remaining: Vec<u64> = vec![requests_per_tenant; n_t];
        let mut host_free = self.clock;

        while remaining.iter().any(|&r| r > 0) {
            // Round boundary: land any background-compiled artifacts into
            // the shared cache before scheduling, so this round's adaptive
            // pass can swap them in as pure cache hits.
            self.pump_compiles();
            let round_start = self.clock;

            // ---- admission: priority- and hotness-weighted round robin ----
            // The weight clamps hotness at 1.0 (exactly what `pick_batch`
            // does internally) before scaling by the SLO class, so a NaN
            // hotness degrades to the fairness floor instead of poisoning
            // the sort; `total_cmp` keeps the order total and replayable
            // either way. All-default priorities reproduce the historical
            // hotness order bit-for-bit.
            let weights: Vec<f64> = self
                .tenants
                .iter()
                .map(|t| t.hotness.max(1.0) * f64::from(t.spec.priority.max(1)))
                .collect();
            let mut order: Vec<usize> = (0..n_t).filter(|&i| remaining[i] > 0).collect();
            order.sort_by(|&a, &b| weights[b].total_cmp(&weights[a]).then(a.cmp(&b)));
            let mut batch = pick_batch(&order, &weights, &remaining, window);
            // High classes schedule first (their fabric time accrues
            // before the SLO projection trips), then shard affinity keeps
            // same-configuration requests back-to-back within a class.
            batch.sort_by_key(|&ti| {
                (
                    std::cmp::Reverse(self.tenants[ti].spec.priority),
                    self.tenants[ti].offload.as_ref().map(|o| o.key).unwrap_or(0),
                )
            });
            let top_priority =
                batch.iter().map(|&ti| self.tenants[ti].spec.priority).max().unwrap_or(0);

            struct PendingExec {
                shard: usize,
                exec: Duration,
                d2h: u64,
            }
            let mut pending: Vec<PendingExec> = Vec::new();
            let mut up_payloads: Vec<Vec<u64>> = vec![Vec::new(); self.shards.len()];
            let mut recfg_extra = vec![Duration::ZERO; self.shards.len()];
            let mut round_load = vec![0u32; self.shards.len()];
            let mut sw_time = Duration::ZERO;
            // Projected fabric occupancy this round, for SLO admission
            // control (deterministic: per-invocation model times, not
            // wall clock).
            let mut projected = Duration::ZERO;

            for &ti in &batch {
                remaining[ti] -= 1;
                let seq = WARMUP_REQUESTS + self.tenants[ti].served;
                // Numerics now; virtual time modeled below.
                {
                    let tenant = &mut self.tenants[ti];
                    if let Some(refresh) = tenant.spec.refresh {
                        refresh(&mut tenant.mem, &tenant.args, seq);
                    }
                }
                // Snapshot the observable outputs before an offloaded
                // call: a trap mid-scatter can leave Accumulate outputs
                // partially folded, and a blind software replay on top
                // would double-count them.
                let snapshot: Option<Vec<(u32, Vec<i32>)>> = {
                    let t = &self.tenants[ti];
                    (!t.rolled_back && t.offload.is_some() && t.engine.is_patched(t.func))
                        .then(|| {
                            t.out_handles
                                .iter()
                                .map(|&h| (h, t.mem.i32s(h).to_vec()))
                                .collect()
                        })
                };
                let call_ok = {
                    let tenant = &mut self.tenants[ti];
                    tenant
                        .engine
                        .call_idx(tenant.func, &mut tenant.mem, &tenant.args)
                        .is_ok()
                };
                if !call_ok {
                    // Trap in the offloaded path: restore the pre-call
                    // outputs, roll back to software and replay the
                    // request exactly (failure rollback).
                    let tenant = &mut self.tenants[ti];
                    tenant.engine.unpatch(tenant.func);
                    tenant.rolled_back = true;
                    if let Some(snap) = snapshot {
                        for (h, data) in snap {
                            tenant.mem.i32s_mut(h).copy_from_slice(&data);
                        }
                    }
                    if let Err(e) =
                        tenant.engine.call_idx(tenant.func, &mut tenant.mem, &tenant.args)
                    {
                        tenant.reject = Some(format!("software replay failed: {e}"));
                    }
                }
                // Offloaded identity without unwraps: a tenant whose
                // offload record or runtime state is missing (however it
                // got into that state) rides the software arm instead of
                // panicking the serve loop.
                let offload_info = {
                    let t = &self.tenants[ti];
                    if t.rolled_back || !t.engine.is_patched(t.func) {
                        None
                    } else {
                        t.offload.as_ref().zip(t.state.as_ref()).map(|(o, state)| {
                            (o.key, o.config_words * 4, state.borrow().last_report)
                        })
                    }
                };
                // SLO admission control: once the round's projected
                // fabric time exceeds the objective, requests below the
                // batch's top class are shed to the software tier. The
                // numerics already executed above — shedding only changes
                // which virtual-time arm accounts the request.
                let shed = match (&offload_info, self.params.slo) {
                    (Some((_, _, report)), Some(slo)) => {
                        self.tenants[ti].spec.priority < top_priority
                            && (projected + report.dfe_exec).as_secs_f64() > slo
                    }
                    _ => false,
                };
                match offload_info {
                    Some((key, cfg_bytes, report)) if !shed => {
                        let shard = pick_shard(&self.shards, &round_load, key);
                        round_load[shard] += 1;
                        if self.shards[shard].resident != Some(key) {
                            self.shards[shard].resident = Some(key);
                            self.shards[shard].reconfigs += 1;
                            recfg_extra[shard] += epsilon;
                            up_payloads[shard].push(cfg_bytes);
                            self.tracer.borrow_mut().simulated(Phase::Configure, epsilon);
                        }
                        up_payloads[shard].push(report.h2d_bytes);
                        pending.push(PendingExec {
                            shard,
                            exec: report.dfe_exec,
                            d2h: report.d2h_bytes,
                        });
                        projected += report.dfe_exec;
                        self.tenants[ti].latency.record(report.offload_time());
                    }
                    _ => {
                        // Software request: the host is one serialized core
                        // (it only waits on the round barrier when there is
                        // one).
                        let t = &mut self.tenants[ti];
                        if barrier {
                            host_free = host_free.max(round_start);
                        }
                        host_free += t.baseline_per_inv;
                        sw_time += t.baseline_per_inv;
                        if shed {
                            t.shed += 1;
                        }
                        t.latency.record(t.baseline_per_inv);
                    }
                }
                self.tenants[ti].served += 1;
            }

            // ---- transfers + execution on the shared link ----
            let mut queue_wait = Duration::ZERO;
            let end = match &mut self.link {
                ServeLink::Sync(link) => {
                    // Upstream: coalesced per-shard batches, serialized on
                    // the half-duplex link, all gated on the round start.
                    for (s, ps) in up_payloads.iter().enumerate() {
                        for &p in ps {
                            link.enqueue(s, p);
                        }
                    }
                    let up_done_list = link.flush(round_start);
                    let mut up_done = vec![round_start; self.shards.len()];
                    for (s, done) in up_done_list {
                        up_done[s] = done;
                    }

                    // Execute: serially per shard, overlapped across shards.
                    for p in &pending {
                        let s = p.shard;
                        let mut start =
                            up_done[s].max(self.shards[s].busy_until).max(round_start);
                        start += std::mem::take(&mut recfg_extra[s]);
                        queue_wait += start.saturating_sub(round_start);
                        self.shards[s].busy_until = start + p.exec;
                        self.shards[s].busy_secs = self.shards[s].busy_until.as_secs_f64();
                        self.shards[s].executed += 1;
                    }

                    // Downstream: coalesced per shard after its last exec.
                    for p in &pending {
                        link.enqueue(p.shard, p.d2h);
                    }
                    let ready: Vec<Duration> =
                        self.shards.iter().map(|s| s.busy_until).collect();
                    let down_done = link.flush_after(&ready);

                    let mut end = round_start.max(host_free);
                    for s in &self.shards {
                        end = end.max(s.busy_until);
                    }
                    for (_, done) in down_done {
                        end = end.max(done);
                    }
                    end
                }
                ServeLink::Async(link) => {
                    // Upstream: the same per-shard coalesced batches, but
                    // gated only by the upload direction and the shard's
                    // staging ring — never by the previous round's
                    // executions or downloads.
                    let mut up_done = vec![0f64; self.shards.len()];
                    for (s, ps) in up_payloads.iter().enumerate() {
                        if !ps.is_empty() {
                            up_done[s] = link.upload(s, ps, 0.0).1;
                        }
                    }

                    // Execute serially per shard on its own timeline.
                    let mut round_exec = vec![false; self.shards.len()];
                    for p in &pending {
                        let s = p.shard;
                        let mut start = up_done[s].max(self.shards[s].busy_secs);
                        start += std::mem::take(&mut recfg_extra[s]).as_secs_f64();
                        if !round_exec[s] {
                            queue_wait +=
                                Duration::from_secs_f64((start - up_done[s]).max(0.0));
                            round_exec[s] = true;
                        }
                        self.shards[s].busy_secs = start + p.exec.as_secs_f64();
                        self.shards[s].busy_until =
                            Duration::from_secs_f64(self.shards[s].busy_secs);
                        self.shards[s].executed += 1;
                    }

                    // Retire this round's staging buffers and schedule the
                    // coalesced downloads on the opposite direction (they
                    // overlap the next round's uploads).
                    let mut down_payloads: Vec<Vec<u64>> =
                        vec![Vec::new(); self.shards.len()];
                    for p in &pending {
                        down_payloads[p.shard].push(p.d2h);
                    }
                    let mut end_secs = host_free.as_secs_f64();
                    for s in 0..self.shards.len() {
                        if round_exec[s] {
                            link.retire_exec(s, self.shards[s].busy_secs);
                        }
                        end_secs = end_secs.max(self.shards[s].busy_secs);
                        if !down_payloads[s].is_empty() {
                            let (_, dend) =
                                link.download(s, &down_payloads[s], self.shards[s].busy_secs);
                            end_secs = end_secs.max(dend);
                        }
                    }
                    self.clock.max(Duration::from_secs_f64(end_secs))
                }
            };
            {
                let mut tr = self.tracer.borrow_mut();
                if sw_time > Duration::ZERO {
                    tr.simulated(Phase::HostWork, sw_time);
                }
                if queue_wait > Duration::ZERO {
                    tr.simulated(Phase::Queue, queue_wait);
                }
            }
            self.clock = end;

            // ---- per-tenant rollback pass over this round ----
            for &ti in &batch {
                let t = &mut self.tenants[ti];
                if t.rolled_back {
                    continue;
                }
                let Some(state) = t.state.clone() else { continue };
                let st = state.borrow();
                let decided =
                    st.failed || st.invocations >= self.params.rollback_window;
                if decided && st.invocations > 0 {
                    let per_inv = st.virtual_offload / st.invocations as u32;
                    if st.failed || per_inv > t.baseline_per_inv {
                        drop(st);
                        t.engine.unpatch(t.func);
                        t.rolled_back = true;
                    }
                }
            }

            // ---- per-tenant adaptive respecialization pass ----
            if let Some(ap) = self.params.adapt.clone() {
                for ti in 0..n_t {
                    self.adapt_tenant(ti, &ap);
                }
            }
        }
        self.report()
    }

    /// Assemble the aggregate report from the current server state
    /// (public so fleet-layer wrappers can report after their own loop).
    pub fn report(&self) -> ServeReport {
        let tenants: Vec<TenantReport> = self
            .tenants
            .iter()
            .map(|t| TenantReport {
                name: t.spec.name.clone(),
                requests: t.served,
                offloaded: t.offload.is_some(),
                cache_hit: t.offload.as_ref().map(|o| o.cache_hit).unwrap_or(false),
                rolled_back: t.rolled_back,
                reject: t.reject.clone(),
                unroll: t.active_unroll,
                tiles: if t.offload.is_some() {
                    t.plan.as_ref().map(|p| p.n_tiles()).unwrap_or(1)
                } else {
                    0
                },
                respecializations: t.respecs.len() as u64,
                baseline_per_inv: t.baseline_per_inv,
                // Cumulative across respecializations: states retired by
                // earlier swaps plus the live one.
                virtual_offload: t.retired_virtual
                    + t.state
                        .as_ref()
                        .map(|s| s.borrow().virtual_offload)
                        .unwrap_or_default(),
                invocations: t.retired_invocations
                    + t.state.as_ref().map(|s| s.borrow().invocations).unwrap_or(0),
                elements: t.retired_elements
                    + t.state.as_ref().map(|s| s.borrow().total_elements).unwrap_or(0),
                compile_stall_secs: t.compile_stall.as_secs_f64(),
                remote_served: t.remote_served,
                retries: t.retries,
                fallback_local: t.fallback_local,
                fallback_software: t.fallback_software,
                compile_failures: t.compile_failures,
                priority: t.spec.priority,
                shed: t.shed,
                p50_secs: t.latency.p50().as_secs_f64(),
                p95_secs: t.latency.p95().as_secs_f64(),
                p99_secs: t.latency.p99().as_secs_f64(),
            })
            .collect();
        let shards = self
            .shards
            .iter()
            .map(|s| ShardReport {
                region: s.region,
                executed: s.executed,
                reconfigs: s.reconfigs,
                busy: s.busy_until,
            })
            .collect();
        let total_elements = tenants.iter().map(|t| t.elements).sum();
        let compile_stall_secs = tenants.iter().map(|t| t.compile_stall_secs).sum();
        ServeReport {
            shards,
            makespan: self.clock,
            total_requests: self.tenants.iter().map(|t| t.served).sum(),
            total_elements,
            transport: self.params.transport,
            link_payload: self.link.sim().total_payload,
            link_wire: self.link.sim().total_wire,
            link_batches: self.link.sim().transfers,
            cache: self.cache.stats,
            cache_hit_rate: self.cache.hit_rate(),
            compile_stall_secs,
            pending_compiles: self.compile.pending(),
            pr_compiles: self.compile.compiled,
            shed: self.tenants.iter().map(|t| t.shed).sum(),
            tenants,
        }
    }
}

/// The single-tenant pipeline (analysis → shared cache/P&R → patch) at an
/// explicit unroll factor, against the shard route grid. Free function
/// with split borrows so the adaptive pass can respecialize a tenant that
/// already lives inside the server. When `observed` is given and an
/// artifact is already live, the candidate is only swapped in if the
/// analytic pipeline model prefers it at that batch size (ties favor the
/// smaller unroll). A respecialization miss (`respec`) either stalls here
/// synchronously (counted in the tenant's `compile_stall`) or — with the
/// compile service on — submits a warm-started background job and returns
/// `Ok(false)`: the tenant keeps serving its current tier and a later
/// window swaps the landed artifact in as a cache hit. Returns whether
/// the call table was (re)patched.
#[allow(clippy::too_many_arguments)]
fn offload_tenant_impl(
    cache: &mut ConfigCache,
    compile: &mut CompileSlot,
    device: &Device,
    params: &ServeParams,
    route_grid: Grid,
    t: &mut Tenant,
    unroll: usize,
    trip_bucket: usize,
    observed: Option<u64>,
    respec: bool,
) -> std::result::Result<bool, RejectReason> {
    // A compile for this exact target already in flight: skip the
    // re-extraction and the cache lookup entirely — one background job,
    // one recorded miss (stale entries for a finished or retargeted job
    // are cleared and fall through).
    if respec {
        if let Some((u, b, key)) = t.pending_spec {
            if compile.is_pending(key) {
                if (u, b) == (unroll, trip_bucket) {
                    return Ok(false);
                }
            } else {
                t.pending_spec = None;
            }
        }
    }
    let extraction = {
        let f = &t.engine.module.funcs[t.func as usize];
        super::extract_single_scop(f, unroll)
    };
    let (off, single) = extraction?;

    let nodes = off.dfg.len();
    if nodes < params.min_dfg_nodes {
        return Err(RejectReason::TooSmall { nodes, min: params.min_dfg_nodes });
    }

    let sig = SpecSignature::new(unroll, trip_bucket);
    let key = region_key(spec_key(dfg_key(&off.dfg), sig), route_grid);
    // Oversized for the shard budget: virtualize the grid with a tiled
    // execution plan instead of rejecting. DFGs that fit keep the exact
    // single-tile flow below.
    let budget = TileBudget::for_grid(route_grid);
    if needs_tiling(&off.dfg, budget) {
        return offload_tenant_tiled(
            cache, compile, device, params, route_grid, t, unroll, trip_bucket, observed,
            respec, off, single, key, budget,
        );
    }
    if respec && compile.is_pending(key) {
        // Another tenant already has this key compiling: wait for it at a
        // later window without charging a second miss.
        t.pending_spec = Some((unroll, trip_bucket, key));
        return Ok(false);
    }
    let mut cache_hit = true;
    let cached = if let Some(c) = cache.get(key) {
        c.clone()
    } else {
        cache_hit = false;
        // Warm hint: the live artifact's placement seeds the tier-N+1
        // search, so only the DFG delta is re-placed/re-routed.
        let warm = t
            .cached
            .as_ref()
            .filter(|c| !c.placement.is_empty())
            .map(|c| ParSeed::Warm(c.placement.clone()))
            .unwrap_or(ParSeed::Cold);
        if respec && compile.service.is_some() {
            // Non-blocking promotion: submit (deduped by key across
            // tenants) and keep executing the current tier.
            compile.compile(cache, &off.dfg, key, warm, true)?;
            t.pending_spec = Some((unroll, trip_bucket, key));
            return Ok(false);
        }
        let t0 = Instant::now();
        // Blocking portfolio race; the entry carries provenance (winning
        // seed, stats, placement) and the lowered wave executor, so
        // tenants hitting it skip P&R *and* the lowering.
        let (c, _) = compile.compile(cache, &off.dfg, key, warm, false)?.ok_or_else(|| {
            RejectReason::Unroutable("blocking compile produced no artifact".into())
        })?;
        if respec {
            t.compile_stall += t0.elapsed();
        }
        c
    };

    let est = device.estimate(route_grid.rows, route_grid.cols);
    // Respecialization gate: the model must prefer the candidate at the
    // observed batch size, else the live artifact stays. The comparator
    // is transport-aware: under the async pipeline, transfer hidden under
    // compute can change which unroll tier wins.
    if let (Some(batch), Some(cur)) = (observed, t.cached.as_ref()) {
        if t.engine.is_patched(t.func) {
            let fmax = est.fmax_mhz * 1e6;
            let link = (params.pcie, params.transport);
            // A tiled incumbent is timed as its full multi-pass plan —
            // tile 0 alone would flatter it.
            let t_cur = match &t.plan {
                Some(p) => super::plan_invocation_time(p, t.active_unroll, batch, fmax, link),
                None => super::invocation_time(cur, t.active_unroll, batch, fmax, link),
            };
            let t_cand = super::invocation_time(&cached, unroll, batch, fmax, link);
            let keep =
                if unroll < t.active_unroll { t_cand > t_cur } else { t_cand >= t_cur };
            if keep {
                return Ok(false);
            }
        }
    }

    let (fill, ii) = super::pipeline_model(&cached);
    let tm = TimeModel {
        sec_per_cycle: params.sec_per_cycle,
        fmax_hz: est.fmax_mhz * 1e6,
        fill_latency: fill,
        initiation_interval: ii,
    };

    // Retire the outgoing state's totals (the report stays cumulative
    // across respecializations) and keep the original software-era
    // snapshot: a re-patch over a live hook only ever sees a hook-era
    // (zero-cycle) row.
    let mut prev_pre_patch = None;
    if let Some(old) = &t.state {
        let o = old.borrow();
        t.retired_invocations += o.invocations;
        t.retired_virtual += o.virtual_offload;
        t.retired_elements += o.total_elements;
        prev_pre_patch = Some(o.pre_patch);
    }
    // Patch-time snapshot/reset (the monitor only sees post-patch data);
    // the software baseline was established at admission and survives
    // every respecialization.
    let snap = t.engine.take_profile(t.func);
    let pre_patch =
        if snap.counters.cycles > 0 { snap } else { prev_pre_patch.unwrap_or(snap) };
    let state = Rc::new(RefCell::new(RuntimeState {
        baseline_per_inv: t.baseline_per_inv,
        pre_patch,
        ..Default::default()
    }));
    let config_words = cached.config.config_words() as u64;
    // Numerics run on the lowered batch kernels shared through the cache
    // (each tenant hook owns its backend, hence its scratch arena); the
    // wave executor under `--no-lower`, image eval if the lowering
    // refused.
    let backend = DfeBackend::sim_for(&cached, params.lower);
    let hook = make_offload_hook(
        off,
        single,
        cached.image.clone(),
        backend,
        tm,
        t.pcie.clone(),
        params.transport,
        state.clone(),
        None,
    );
    t.engine.patch_hook(t.func, hook);
    t.offload = Some(TenantOffload { key, cache_hit, config_words });
    t.state = Some(state);
    t.cached = Some(cached);
    t.plan = None;
    t.active_unroll = unroll;
    t.adapt_seen = 0;
    t.adapt_seen_elements = 0;
    t.window_count = 0;
    t.window_elements = 0;
    t.pending_spec = None;
    Ok(true)
}

/// The tiled arm of [`offload_tenant_impl`]: the extracted DFG exceeds
/// the shard budget, so it is partitioned into a feed-forward
/// [`ExecutionPlan`] and served as a multi-pass schedule over the shard
/// grid. Tiles compile (and warm-start) independently through the same
/// shared cache and compile service — a deferred respecialization
/// submits one background job per missing tile and a later window
/// assembles the plan from pure cache hits. Tenants whose DFG fits the
/// shard never reach here.
#[allow(clippy::too_many_arguments)]
fn offload_tenant_tiled(
    cache: &mut ConfigCache,
    compile: &mut CompileSlot,
    device: &Device,
    params: &ServeParams,
    route_grid: Grid,
    t: &mut Tenant,
    unroll: usize,
    trip_bucket: usize,
    observed: Option<u64>,
    respec: bool,
    off: OffloadDfg,
    single: OffloadDfg,
    key: u64,
    budget: TileBudget,
) -> std::result::Result<bool, RejectReason> {
    let mut cache_hit = true;
    let plan = if let Some(p) = cache.get_plan(key) {
        p.clone()
    } else {
        cache_hit = false;
        let tiled = partition(&off.dfg, budget).map_err(|e| match e {
            PartitionError::Infeasible { needed, io, .. } => {
                RejectReason::TooLarge { needed, budget: io }
            }
            PartitionError::Dfg(d) => RejectReason::Illegal(d.to_string()),
        })?;
        // Warm hint: the live artifact's placement seeds every tile's
        // search. Tiles are independent jobs, so they all share the same
        // seed rather than chaining placements that have not landed yet.
        let warm_placement = t
            .cached
            .as_ref()
            .filter(|c| !c.placement.is_empty())
            .map(|c| c.placement.clone());
        if respec && compile.service.is_some() {
            // Non-blocking promotion: one background job per missing
            // tile (deduped by tile key across tenants); the first
            // outstanding tile key stands in as the pending-spec marker.
            let mut rep = None;
            for (idx, tile) in tiled.tiles.iter().enumerate() {
                let tk = tile_key(key, idx, dfg_key(&tile.dfg));
                if cache.contains(tk) {
                    continue;
                }
                if !compile.is_pending(tk) {
                    let warm = warm_placement
                        .clone()
                        .map(ParSeed::Warm)
                        .unwrap_or(ParSeed::Cold);
                    compile.compile(cache, &tile.dfg, tk, warm, true)?;
                }
                if rep.is_none() {
                    rep = Some(tk);
                }
            }
            if let Some(tk) = rep {
                t.pending_spec = Some((unroll, trip_bucket, tk));
                return Ok(false);
            }
            // Every tile already landed: assemble below as pure hits.
        }
        let t0 = Instant::now();
        let mut tiles = Vec::with_capacity(tiled.tiles.len());
        for (idx, tile) in tiled.tiles.iter().enumerate() {
            let tk = tile_key(key, idx, dfg_key(&tile.dfg));
            let cached = if let Some(c) = cache.get(tk) {
                c.clone()
            } else {
                let warm = warm_placement
                    .clone()
                    .map(ParSeed::Warm)
                    .unwrap_or(ParSeed::Cold);
                let (c, _) =
                    compile.compile(cache, &tile.dfg, tk, warm, false)?.ok_or_else(|| {
                        RejectReason::Unroutable(
                            "blocking tile compile produced no artifact".into(),
                        )
                    })?;
                c
            };
            tiles.push(PlanTile {
                cached,
                sources: tile.sources.clone(),
                sinks: tile.sinks.clone(),
                key: tk,
            });
        }
        if respec {
            t.compile_stall += t0.elapsed();
        }
        let plan = ExecutionPlan { tiles, n_spills: tiled.n_spills };
        cache.insert_plan(key, plan.clone());
        plan
    };

    let est = device.estimate(route_grid.rows, route_grid.cols);
    // Respecialization gate, plan-aware on both sides: the incumbent is
    // timed as whatever actually serves (plan or single artifact), the
    // candidate as its full multi-pass plan.
    if let (Some(batch), Some(cur)) = (observed, t.cached.as_ref()) {
        if t.engine.is_patched(t.func) {
            let fmax = est.fmax_mhz * 1e6;
            let link = (params.pcie, params.transport);
            let t_cur = match &t.plan {
                Some(p) => super::plan_invocation_time(p, t.active_unroll, batch, fmax, link),
                None => super::invocation_time(cur, t.active_unroll, batch, fmax, link),
            };
            let t_cand = super::plan_invocation_time(&plan, unroll, batch, fmax, link);
            let keep =
                if unroll < t.active_unroll { t_cand > t_cur } else { t_cand >= t_cur };
            if keep {
                return Ok(false);
            }
        }
    }

    // Per-tile time models and backends: each pass runs its own routed
    // artifact's fill/II on the same shard clock.
    let fmax_hz = est.fmax_mhz * 1e6;
    let mut tms = Vec::with_capacity(plan.tiles.len());
    let mut backends = Vec::with_capacity(plan.tiles.len());
    for tile in &plan.tiles {
        let (fill, ii) = super::pipeline_model(&tile.cached);
        tms.push(TimeModel {
            sec_per_cycle: params.sec_per_cycle,
            fmax_hz,
            fill_latency: fill,
            initiation_interval: ii,
        });
        backends.push(DfeBackend::sim_for(&tile.cached, params.lower));
    }

    // Retire the outgoing state's totals and carry the software-era
    // snapshot — same discipline as the single-tile arm.
    let mut prev_pre_patch = None;
    if let Some(old) = &t.state {
        let o = old.borrow();
        t.retired_invocations += o.invocations;
        t.retired_virtual += o.virtual_offload;
        t.retired_elements += o.total_elements;
        prev_pre_patch = Some(o.pre_patch);
    }
    let snap = t.engine.take_profile(t.func);
    let pre_patch =
        if snap.counters.cycles > 0 { snap } else { prev_pre_patch.unwrap_or(snap) };
    let state = Rc::new(RefCell::new(RuntimeState {
        baseline_per_inv: t.baseline_per_inv,
        pre_patch,
        ..Default::default()
    }));
    // The resident-switch reconfiguration charges the full plan reload:
    // every pass rewrites the grid, so the configuration stream is the
    // sum over tiles.
    let config_words = plan.config_words();
    let hook = make_plan_hook(
        off,
        single,
        Rc::new(plan.clone()),
        Rc::new(backends),
        Rc::new(tms),
        params.reconfig_epsilon,
        t.pcie.clone(),
        params.transport,
        state.clone(),
        None,
    );
    t.engine.patch_hook(t.func, hook);
    t.offload = Some(TenantOffload { key, cache_hit, config_words });
    t.state = Some(state);
    t.cached = Some(plan.tiles[0].cached.clone());
    t.plan = Some(plan);
    t.active_unroll = unroll;
    t.adapt_seen = 0;
    t.adapt_seen_elements = 0;
    t.window_count = 0;
    t.window_elements = 0;
    t.pending_spec = None;
    Ok(true)
}

/// Prefer the shard already holding `key`'s configuration; otherwise the
/// least-loaded shard (fewest requests assigned this round, then earliest
/// idle — `busy_until` alone is stale inside a round).
pub(crate) fn pick_shard(shards: &[ShardState], round_load: &[u32], key: u64) -> usize {
    for (i, s) in shards.iter().enumerate() {
        if s.resident == Some(key) {
            return i;
        }
    }
    let mut best = 0;
    for i in 1..shards.len() {
        if (round_load[i], shards[i].busy_until) < (round_load[best], shards[best].busy_until) {
            best = i;
        }
    }
    best
}

/// Hotness-weighted round robin: every active tenant gets at least one
/// slot per pass (fairness), hotter tenants claim the leftover window
/// proportionally to their weight.
pub(crate) fn pick_batch(
    order: &[usize],
    hotness: &[f64],
    remaining: &[u64],
    window: usize,
) -> Vec<usize> {
    if order.is_empty() || window == 0 {
        return Vec::new();
    }
    let total: f64 = order.iter().map(|&t| hotness[t].max(1.0)).sum();
    let mut credit: Vec<u64> = remaining.to_vec();
    let mut batch = Vec::with_capacity(window);
    for &t in order {
        let share = ((window as f64) * hotness[t].max(1.0) / total).floor() as usize;
        for _ in 0..share.max(1) {
            if credit[t] > 0 && batch.len() < window {
                batch.push(t);
                credit[t] -= 1;
            }
        }
    }
    loop {
        let mut progressed = false;
        for &t in order {
            if batch.len() >= window {
                return batch;
            }
            if credit[t] > 0 {
                batch.push(t);
                credit[t] -= 1;
                progressed = true;
            }
        }
        if !progressed {
            return batch;
        }
    }
}

// ---------------------------------------------------------------------------
// Reports
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct TenantReport {
    pub name: String,
    pub requests: u64,
    pub offloaded: bool,
    pub cache_hit: bool,
    pub rolled_back: bool,
    pub reject: Option<String>,
    /// Unroll of the live artifact (0 when never offloaded).
    pub unroll: usize,
    /// Tiles in the live execution plan: 1 for a single-tile artifact,
    /// >1 when the tenant's DFG exceeds the shard budget and serves as a
    /// multi-pass plan, 0 when never offloaded.
    pub tiles: usize,
    /// Adaptive respecializations performed on the serve path.
    pub respecializations: u64,
    pub baseline_per_inv: Duration,
    pub virtual_offload: Duration,
    pub invocations: u64,
    /// Innermost iterations served through the offload stub (cumulative
    /// across respecializations; 0 for software-only tenants).
    pub elements: u64,
    /// Wall seconds this tenant's serving path blocked inside place &
    /// route after admission. 0 with the compile service on (S7).
    pub compile_stall_secs: f64,
    /// Requests completed on a remote fleet node (0 single-host).
    pub remote_served: u64,
    /// Network retry attempts spent on this tenant's remote exchanges.
    pub retries: u64,
    /// Requests that fell back from the fleet to the local shard fabric.
    pub fallback_local: u64,
    /// Fleet-mode requests served by the interpreter.
    pub fallback_software: u64,
    /// Structured respecialization-compile failures (tenant demoted or
    /// tier kept; the serve loop never died).
    pub compile_failures: u64,
    /// SLO class the tenant was admitted with (1 = default).
    pub priority: u32,
    /// Requests shed to the software tier by SLO admission control.
    pub shed: u64,
    /// Per-request virtual latency percentiles (log2-bucket floors, so
    /// they are deterministic and comparable across runs and nodes).
    pub p50_secs: f64,
    pub p95_secs: f64,
    pub p99_secs: f64,
}

#[derive(Clone, Copy, Debug)]
pub struct ShardReport {
    pub region: Region,
    pub executed: u64,
    pub reconfigs: u64,
    pub busy: Duration,
}

#[derive(Clone, Debug)]
pub struct ServeReport {
    pub tenants: Vec<TenantReport>,
    pub shards: Vec<ShardReport>,
    pub makespan: Duration,
    pub total_requests: u64,
    /// Innermost iterations served through the offload stubs — the
    /// serve-path element count behind [`Self::elements_per_sec`].
    pub total_elements: u64,
    pub transport: TransportMode,
    pub link_payload: u64,
    pub link_wire: u64,
    pub link_batches: u64,
    pub cache: CacheStats,
    pub cache_hit_rate: f64,
    /// Total wall seconds tenants blocked inside place & route after
    /// admission (sum over tenants; 0 with the compile service on).
    pub compile_stall_secs: f64,
    /// Compile jobs still in flight when the report was taken.
    pub pending_compiles: usize,
    /// Place-&-route invocations actually performed (blocking races plus
    /// landed background jobs). Cache hits — including a warm restart
    /// from a persisted snapshot — do not count: a restarted server with
    /// a full cache reports 0.
    pub pr_compiles: u64,
    /// Requests shed to the software tier by SLO admission control
    /// (sum over tenants).
    pub shed: u64,
}

impl ServeReport {
    /// Aggregate request throughput over the virtual makespan.
    pub fn throughput_rps(&self) -> f64 {
        if self.makespan.is_zero() {
            0.0
        } else {
            self.total_requests as f64 / self.makespan.as_secs_f64()
        }
    }

    /// Serve-path element throughput (offloaded innermost iterations per
    /// virtual second) — the sync-vs-async ablation metric (A7).
    pub fn elements_per_sec(&self) -> f64 {
        if self.makespan.is_zero() {
            0.0
        } else {
            self.total_elements as f64 / self.makespan.as_secs_f64()
        }
    }
}

impl fmt::Display for ServeReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "{:<16} {:>6} {:>8} {:>10} {:>13} {:>13}  status",
            "tenant", "reqs", "offload", "cache", "baseline/req", "offload/req"
        )?;
        for t in &self.tenants {
            let per_inv = if t.invocations > 0 {
                t.virtual_offload / t.invocations as u32
            } else {
                Duration::ZERO
            };
            let status = if t.rolled_back {
                "rolled-back".to_string()
            } else if t.respecializations > 0 {
                format!("ok (respec x{} -> u{})", t.respecializations, t.unroll)
            } else {
                t.reject.as_deref().unwrap_or("ok").to_string()
            };
            let status = if t.tiles > 1 {
                format!("{status} [{} tiles]", t.tiles)
            } else {
                status
            };
            writeln!(
                f,
                "{:<16} {:>6} {:>8} {:>10} {:>13} {:>13}  {}",
                t.name,
                t.requests,
                if t.offloaded { "yes" } else { "no" },
                if t.cache_hit {
                    "hit"
                } else if t.offloaded {
                    "miss"
                } else {
                    "-"
                },
                fmt_duration(t.baseline_per_inv),
                fmt_duration(per_inv),
                status
            )?;
        }
        for (i, s) in self.shards.iter().enumerate() {
            writeln!(
                f,
                "shard {i} [{}]: {} execs, {} reconfigs, busy {}",
                s.region,
                s.executed,
                s.reconfigs,
                fmt_duration(s.busy)
            )?;
        }
        writeln!(
            f,
            "link: {} coalesced batches, {:.2} MB payload, {:.2} MB wire",
            self.link_batches,
            self.link_payload as f64 / 1e6,
            self.link_wire as f64 / 1e6
        )?;
        writeln!(
            f,
            "config cache: {} hits / {} misses ({:.0}% hit rate), {} evictions",
            self.cache.hits,
            self.cache.misses,
            100.0 * self.cache_hit_rate,
            self.cache.evictions
        )?;
        for t in &self.tenants {
            if t.requests == 0 {
                continue;
            }
            writeln!(
                f,
                "latency {:<16} p50 {:>10} p95 {:>10} p99 {:>10}  class {} ({} shed)",
                t.name,
                fmt_duration(Duration::from_secs_f64(t.p50_secs)),
                fmt_duration(Duration::from_secs_f64(t.p95_secs)),
                fmt_duration(Duration::from_secs_f64(t.p99_secs)),
                t.priority,
                t.shed
            )?;
        }
        writeln!(
            f,
            "compile: {} stall after warmup, {} job(s) still in flight",
            fmt_duration(Duration::from_secs_f64(self.compile_stall_secs)),
            self.pending_compiles
        )?;
        writeln!(f, "pr-compiles: {} ({} request(s) shed)", self.pr_compiles, self.shed)?;
        write!(
            f,
            "makespan {} for {} requests ({} transport) -> {:.1} req/s, {:.2e} el/s aggregate",
            fmt_duration(self.makespan),
            self.total_requests,
            self.transport,
            self.throughput_rps(),
            self.elements_per_sec()
        )
    }
}

// ---------------------------------------------------------------------------
// Workload mixes (PolyBench + the §IV-C video pipeline)
// ---------------------------------------------------------------------------

const GEMM_N: usize = 10;
const TRMM_N: usize = 10;
const SYR2K_N: usize = 8;
const GESUMMV_N: usize = 20;

fn gemm_module() -> Module {
    let mut m = Module::new();
    m.add(polybench::gemm());
    m
}

fn trmm_module() -> Module {
    let mut m = Module::new();
    m.add(polybench::trmm());
    m
}

fn syr2k_module() -> Module {
    let mut m = Module::new();
    m.add(polybench::syr2k());
    m
}

fn gesummv_module() -> Module {
    let mut m = Module::new();
    m.add(polybench::gesummv());
    m
}

fn mat(n: usize, f: impl Fn(usize) -> i32) -> Vec<i32> {
    (0..n).map(f).collect()
}

/// gemm(C, A, B, alpha, n): C accumulates across requests.
fn gemm_setup(mem: &mut Memory) -> Vec<Val> {
    let n = GEMM_N;
    let ha = mem.from_i32(&mat(n * n, |i| (i as i32 % 13) - 6));
    let hb = mem.from_i32(&mat(n * n, |i| (i as i32 % 7) - 3));
    let hc = mem.alloc_i32(n * n);
    vec![Val::P(hc), Val::P(ha), Val::P(hb), Val::I(2), Val::I(n as i32)]
}

/// trmm(Bout, A, B, n).
fn trmm_setup(mem: &mut Memory) -> Vec<Val> {
    let n = TRMM_N;
    let ha = mem.from_i32(&mat(n * n, |i| (i as i32 % 11) - 5));
    let hb = mem.from_i32(&mat(n * n, |i| (i as i32 % 5) - 2));
    let hbo = mem.alloc_i32(n * n);
    vec![Val::P(hbo), Val::P(ha), Val::P(hb), Val::I(n as i32)]
}

/// syr2k(C, A, B, alpha, n).
fn syr2k_setup(mem: &mut Memory) -> Vec<Val> {
    let n = SYR2K_N;
    let ha = mem.from_i32(&mat(n * n, |i| (i as i32 % 9) - 4));
    let hb = mem.from_i32(&mat(n * n, |i| (i as i32 % 6) - 3));
    let hc = mem.alloc_i32(n * n);
    vec![Val::P(hc), Val::P(ha), Val::P(hb), Val::I(3), Val::I(n as i32)]
}

/// gesummv(A, B, x, tmp, y, alpha, beta, n).
fn gesummv_setup(mem: &mut Memory) -> Vec<Val> {
    let n = GESUMMV_N;
    let ha = mem.from_i32(&mat(n * n, |i| (i as i32 % 8) - 4));
    let hb = mem.from_i32(&mat(n * n, |i| (i as i32 % 10) - 5));
    let hx = mem.from_i32(&mat(n, |i| (i as i32 % 15) - 7));
    let htmp = mem.alloc_i32(n);
    let hy = mem.alloc_i32(n);
    vec![
        Val::P(ha),
        Val::P(hb),
        Val::P(hx),
        Val::P(htmp),
        Val::P(hy),
        Val::I(3),
        Val::I(2),
        Val::I(n as i32),
    ]
}

fn conv_setup(mem: &mut Memory) -> Vec<Val> {
    let (out, inp, coef) = video::alloc_pipeline(mem);
    video::conv_args(out, inp, coef)
}

fn conv_refresh(mem: &mut Memory, args: &[Val], seq: u64) {
    let mut src = video::FrameSource { frame_no: seq as u32 };
    let mut frame = vec![0i32; video::FRAME_W * video::FRAME_H];
    src.next_frame(&mut frame);
    mem.i32s_mut(args[1].as_ptr()).copy_from_slice(&frame);
}

fn out0(args: &[Val]) -> Vec<u32> {
    vec![args[0].as_ptr()]
}

fn out_gesummv(args: &[Val]) -> Vec<u32> {
    vec![args[3].as_ptr(), args[4].as_ptr()]
}

pub fn gemm_spec() -> TenantSpec {
    TenantSpec {
        name: "gemm".into(),
        module: gemm_module,
        func: "gemm",
        unroll: 2,
        setup: gemm_setup,
        refresh: None,
        outputs: out0,
        priority: 1,
    }
}

pub fn trmm_spec() -> TenantSpec {
    TenantSpec {
        name: "trmm".into(),
        module: trmm_module,
        func: "trmm",
        unroll: 2,
        setup: trmm_setup,
        refresh: None,
        outputs: out0,
        priority: 1,
    }
}

pub fn syr2k_spec() -> TenantSpec {
    TenantSpec {
        name: "syr2k".into(),
        module: syr2k_module,
        func: "syr2k",
        unroll: 2,
        setup: syr2k_setup,
        refresh: None,
        outputs: out0,
        priority: 1,
    }
}

pub fn gesummv_spec() -> TenantSpec {
    TenantSpec {
        name: "gesummv".into(),
        module: gesummv_module,
        func: "gesummv",
        unroll: 2,
        setup: gesummv_setup,
        refresh: None,
        outputs: out_gesummv,
        priority: 1,
    }
}

pub fn conv_spec() -> TenantSpec {
    TenantSpec {
        name: "conv".into(),
        module: video::video_module,
        func: "conv",
        unroll: 1,
        setup: conv_setup,
        refresh: Some(conv_refresh),
        outputs: out0,
        priority: 1,
    }
}

/// The PolyBench serving mix: four structurally distinct offloadable
/// kernels (distinct DFGs, so distinct shard configurations), cycled over
/// `tenants` streams.
pub fn polybench_mix(tenants: usize) -> Vec<TenantSpec> {
    let base = [gemm_spec(), trmm_spec(), syr2k_spec(), gesummv_spec()];
    (0..tenants)
        .map(|i| {
            let mut s = base[i % base.len()].clone();
            s.name = format!("{}-t{i}", s.name);
            s
        })
        .collect()
}

/// The full mix: PolyBench plus the §IV-C video convolution pipeline.
pub fn serve_mix(tenants: usize) -> Vec<TenantSpec> {
    let base =
        [gemm_spec(), trmm_spec(), syr2k_spec(), gesummv_spec(), conv_spec()];
    (0..tenants)
        .map(|i| {
            let mut s = base[i % base.len()].clone();
            s.name = format!("{}-t{i}", s.name);
            s
        })
        .collect()
}

/// Replay one tenant's exact request stream through the *single-tenant*
/// offload path (fresh engine + [`OffloadManager`]), returning its
/// observable outputs — the serve layer's bit-identity oracle.
pub fn run_single_tenant(spec: &TenantSpec, requests: u64) -> Result<Vec<Vec<i32>>> {
    let mut engine = Engine::new((spec.module)())?;
    let mut mem = Memory::new();
    let args = (spec.setup)(&mut mem);
    let func = engine
        .func_index(spec.func)
        .ok_or_else(|| anyhow!("unknown function '{}'", spec.func))?;
    for seq in 0..WARMUP_REQUESTS {
        if let Some(refresh) = spec.refresh {
            refresh(&mut mem, &args, seq);
        }
        engine.call_idx(func, &mut mem, &args)?;
    }
    let mut mgr = OffloadManager::new(OffloadParams {
        min_dfg_nodes: 1,
        unroll: spec.unroll,
        ..Default::default()
    });
    // Offload rejection is fine: the software path is the same numerics.
    let _ = mgr.try_offload(&mut engine, func, None);
    for k in 0..requests {
        let seq = WARMUP_REQUESTS + k;
        if let Some(refresh) = spec.refresh {
            refresh(&mut mem, &args, seq);
        }
        engine.call_idx(func, &mut mem, &args)?;
    }
    Ok((spec.outputs)(&args).into_iter().map(|h| mem.i32s(h).to_vec()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_validates_resource_budget() {
        // 24x18 busts the Spartan 6 budget outright.
        let params = ServeParams {
            grid: Grid::new(24, 18),
            device: "Spartan 6".into(),
            ..Default::default()
        };
        let err = OffloadServer::new(params, vec![gemm_spec()]).unwrap_err();
        assert!(err.to_string().contains("resource budget"), "{err}");
    }

    #[test]
    fn structured_serve_errors_instead_of_panics() {
        let err = OffloadServer::new(ServeParams::default(), vec![]).unwrap_err();
        assert!(err.to_string().contains("at least one tenant"), "{err}");
        let err = OffloadServer::new(
            ServeParams { shards: 0, ..Default::default() },
            vec![gemm_spec()],
        )
        .unwrap_err();
        assert!(err.to_string().contains("at least one shard"), "{err}");
        // More shards than grid cells: a structured error, not a panic
        // from partition internals or the route-grid min().
        let err = OffloadServer::new(
            ServeParams { shards: 7, grid: Grid::new(2, 3), ..Default::default() },
            vec![gemm_spec()],
        )
        .unwrap_err();
        assert!(err.to_string().contains("cannot partition"), "{err}");
    }

    #[test]
    fn serve_error_displays_are_stable() {
        assert_eq!(ServeError::NoNodes.to_string(), "fleet needs at least one node");
        assert_eq!(
            ServeError::InfeasiblePartition { shards: 9, rows: 2, cols: 2 }.to_string(),
            "cannot partition a 2x2 grid into 9 shards"
        );
        assert_eq!(
            ServeError::EmptyPartition { shards: 3 }.to_string(),
            "grid partition into 3 shard(s) produced no regions"
        );
    }

    #[test]
    fn regions_are_disjoint_and_cover() {
        let server =
            OffloadServer::new(ServeParams::default(), polybench_mix(2)).expect("server");
        let grid = server.params.grid;
        let mut seen = std::collections::HashSet::new();
        for r in &server.regions {
            for cell in r.cells() {
                assert!(seen.insert(cell), "cell {cell} in two regions");
            }
        }
        assert_eq!(seen.len(), grid.n_cells());
    }

    #[test]
    fn serve_offloads_and_completes() {
        let mut server =
            OffloadServer::new(ServeParams::default(), polybench_mix(4)).expect("server");
        let offloaded = server.tenants.iter().filter(|t| t.offload.is_some()).count();
        assert!(offloaded >= 3, "only {offloaded}/4 tenants offloaded");
        let report = server.run(4);
        assert_eq!(report.total_requests, 16);
        assert!(report.makespan > Duration::ZERO);
        assert!(report.throughput_rps() > 0.0);
        let executed: u64 = report.shards.iter().map(|s| s.executed).sum();
        assert!(executed > 0, "no shard executions recorded");
    }

    #[test]
    fn shared_cache_hits_across_same_kernel_tenants() {
        // Four tenants of the same kernel: one P&R, three shared hits.
        let specs: Vec<TenantSpec> = (0..4)
            .map(|i| {
                let mut s = gemm_spec();
                s.name = format!("gemm-{i}");
                s
            })
            .collect();
        let server = OffloadServer::new(ServeParams::default(), specs).expect("server");
        assert!(server.cache.stats.hits >= 3, "{:?}", server.cache.stats);
        let hits = server.tenants.iter().filter(|t| {
            t.offload.as_ref().map(|o| o.cache_hit).unwrap_or(false)
        });
        assert_eq!(hits.count(), 3);
    }

    #[test]
    fn multi_scop_tenant_serves_in_software_correctly() {
        // atax has two loop nests; patching the whole function would drop
        // the second, so the server must keep it in software.
        fn atax_module() -> Module {
            let mut m = Module::new();
            m.add(polybench::atax());
            m
        }
        fn atax_setup(mem: &mut Memory) -> Vec<Val> {
            let n = 8usize;
            let ha = mem.from_i32(&mat(n * n, |i| (i as i32 % 5) - 2));
            let hx = mem.from_i32(&mat(n, |i| i as i32 - 3));
            let hy = mem.alloc_i32(n);
            let htmp = mem.alloc_i32(n);
            vec![Val::P(ha), Val::P(hx), Val::P(hy), Val::P(htmp), Val::I(n as i32)]
        }
        fn atax_outs(args: &[Val]) -> Vec<u32> {
            vec![args[2].as_ptr(), args[3].as_ptr()]
        }
        let spec = TenantSpec {
            name: "atax".into(),
            module: atax_module,
            func: "atax",
            unroll: 2,
            setup: atax_setup,
            refresh: None,
            outputs: atax_outs,
            priority: 1,
        };
        let mut server =
            OffloadServer::new(ServeParams::default(), vec![spec.clone()]).expect("server");
        assert!(server.tenants[0].offload.is_none());
        assert!(server.tenants[0].reject.as_deref().unwrap_or("").contains("SCoP"));
        server.run(3);
        let want = run_single_tenant(&spec, 3).expect("single-tenant replay");
        assert_eq!(server.tenant_outputs(0), want);
    }

    #[test]
    fn serve_adaptive_pass_respecializes_hot_tenant() {
        // gemm at n=10 streams 1000 innermost iterations per request:
        // the profile should pick the u=4 specialization, the swap must
        // be traced, and numerics must stay bit-identical to the static
        // single-tenant path.
        let params = ServeParams {
            shards: 1,
            adapt: Some(AdaptParams {
                decision_window: 2,
                candidate_unrolls: vec![4],
                min_lanes: 4,
                ..Default::default()
            }),
            ..Default::default()
        };
        let spec = gemm_spec();
        let mut server =
            OffloadServer::new(params, vec![spec.clone()]).expect("server");
        assert_eq!(server.tenants[0].active_unroll, 2, "admitted at the spec unroll");
        let report = server.run(6);
        let t = &report.tenants[0];
        assert!(
            t.respecializations >= 1,
            "trace must show a tier transition: {t:?}"
        );
        assert_eq!(t.unroll, 4, "profile-chosen unroll installed");
        assert_eq!(
            server.tenants[0].respecs[0].from_unroll,
            2,
            "{:?}",
            server.tenants[0].respecs
        );
        let want = run_single_tenant(&spec, 6).expect("single-tenant replay");
        assert_eq!(server.tenant_outputs(0), want, "respecialization changed numerics");
    }

    #[test]
    fn async_transport_serves_bit_identical_and_faster() {
        // Same mix, same seeds, both transports: outputs must match
        // bit-for-bit (the mode only re-times transfers) and the
        // overlapped pipeline must shorten the makespan on the
        // transfer-bound tagged link.
        let run_mode = |transport: TransportMode| {
            let params = ServeParams {
                shards: 2,
                transport,
                pcie: PcieParams::default(), // tagged: transfer-bound
                rollback_window: u64::MAX,
                ..Default::default()
            };
            let mut server =
                OffloadServer::new(params, polybench_mix(4)).expect("server");
            let report = server.run(4);
            let outs: Vec<Vec<Vec<i32>>> =
                (0..server.n_tenants()).map(|i| server.tenant_outputs(i)).collect();
            (outs, report)
        };
        let (outs_sync, rep_sync) = run_mode(TransportMode::Sync);
        let (outs_async, rep_async) = run_mode(TransportMode::async_default());
        assert_eq!(outs_sync, outs_async, "transport must never change numerics");
        assert_eq!(rep_sync.total_elements, rep_async.total_elements);
        assert!(rep_async.total_elements > 0, "mix must offload");
        assert!(
            rep_async.makespan < rep_sync.makespan,
            "overlap must win on the tagged link: async {:?} vs sync {:?}",
            rep_async.makespan,
            rep_sync.makespan
        );
    }

    #[test]
    fn pick_batch_weights_hot_tenants() {
        let order = [0usize, 1];
        let hotness = [3000.0, 1000.0];
        let remaining = [10u64, 10];
        let batch = pick_batch(&order, &hotness, &remaining, 4);
        assert_eq!(batch.len(), 4);
        let hot = batch.iter().filter(|&&t| t == 0).count();
        let cold = batch.iter().filter(|&&t| t == 1).count();
        assert!(hot >= cold, "hot {hot} vs cold {cold}");
        assert!(cold >= 1, "fairness floor violated");
    }

    #[test]
    fn pick_shard_prefers_resident_configuration() {
        let region = Region { origin: crate::dfe::grid::CellCoord::new(0, 0), grid: Grid::new(2, 2) };
        let mk = |resident, busy_ms| ShardState {
            region,
            resident,
            busy_until: Duration::from_millis(busy_ms),
            busy_secs: busy_ms as f64 * 1e-3,
            reconfigs: 0,
            executed: 0,
        };
        let shards = vec![mk(Some(7), 100), mk(None, 0)];
        assert_eq!(pick_shard(&shards, &[0, 0], 7), 0, "affinity beats idleness");
        assert_eq!(pick_shard(&shards, &[0, 0], 9), 1, "miss goes to the idle shard");
        // Same-round load breaks ties before busy_until.
        assert_eq!(pick_shard(&shards, &[0, 3], 9), 0, "round load dominates");
    }

    #[test]
    fn nan_hotness_keeps_the_batch_order_stable_and_replayable() {
        // A NaN scheduling weight (e.g. a poisoned profile) used to hit
        // the `partial_cmp(..).unwrap_or(Equal)` sort, where the outcome
        // depends on the comparison order the sort happens to take. With
        // `total_cmp` over the clamped weights the schedule is total:
        // two identically poisoned servers replay the same batches.
        let run_poisoned = || {
            let mut server = OffloadServer::new(ServeParams::default(), polybench_mix(3))
                .expect("server");
            server.tenants[1].hotness = f64::NAN;
            let report = server.run(4);
            let outs: Vec<Vec<Vec<i32>>> =
                (0..server.n_tenants()).map(|i| server.tenant_outputs(i)).collect();
            let served: Vec<u64> = report.tenants.iter().map(|t| t.requests).collect();
            let offl: Vec<bool> = report.tenants.iter().map(|t| t.offloaded).collect();
            (outs, served, offl, report.total_elements)
        };
        let a = run_poisoned();
        let b = run_poisoned();
        assert_eq!(a.1, b.1, "served counts must replay under NaN hotness");
        assert_eq!(a.2, b.2, "offload decisions must replay under NaN hotness");
        assert_eq!(a.3, b.3, "element totals must replay under NaN hotness");
        assert_eq!(a.0, b.0, "outputs must replay bit-identically under NaN hotness");
        assert_eq!(a.1, vec![4, 4, 4], "every tenant still serves its quota");
    }
}

//! Deterministic per-tenant latency histogram for tail observability.
//!
//! Same log2 fixed-bucket shape as the JIT profiler's trip-count
//! [`Histogram`](crate::jit::engine::Histogram), applied to virtual-time
//! latencies in nanoseconds: bucket `b` covers `[2^(b-1), 2^b)` ns with
//! bucket 0 reserved for zero. Fixed buckets make the percentile readout
//! a pure function of the recorded multiset — replayable across runs,
//! processes and hosts, which is what lets the serve tests assert on
//! p50/p95/p99 at all. The floor-of-bucket readout under-reports by at
//! most 2x (one octave), a deliberate trade for determinism: an exact
//! streaming quantile would need per-sample storage or randomized
//! sketches, both of which break the bit-replayable-report invariant.

use std::time::Duration;

/// Number of log2 buckets: zero + one per bit of a u64 latency in ns
/// (bucket 32 absorbs everything >= 2^31 ns ~ 2.1 s, far beyond any
/// virtual-time latency the serve model produces).
pub const LAT_BUCKETS: usize = 33;

/// Fixed-bucket log2 latency histogram over nanoseconds.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LatencyHist {
    counts: [u64; LAT_BUCKETS],
    total: u64,
}

impl LatencyHist {
    pub fn new() -> LatencyHist {
        LatencyHist { counts: [0; LAT_BUCKETS], total: 0 }
    }

    /// log2 bucket of a nanosecond latency (0 stays in bucket 0).
    pub fn bucket_of(ns: u64) -> usize {
        if ns == 0 {
            0
        } else {
            ((64 - ns.leading_zeros()) as usize).min(LAT_BUCKETS - 1)
        }
    }

    /// Lower edge of bucket `b` in nanoseconds.
    pub fn bucket_floor(b: usize) -> u64 {
        if b == 0 {
            0
        } else {
            1u64 << (b - 1)
        }
    }

    /// Record one invocation latency.
    pub fn record(&mut self, latency: Duration) {
        let ns = latency.as_nanos().min(u64::MAX as u128) as u64;
        self.counts[Self::bucket_of(ns)] += 1;
        self.total += 1;
    }

    /// Samples recorded so far.
    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn counts(&self) -> &[u64; LAT_BUCKETS] {
        &self.counts
    }

    /// Fold another histogram into this one (report aggregation).
    pub fn merge(&mut self, other: &LatencyHist) {
        for (c, o) in self.counts.iter_mut().zip(other.counts.iter()) {
            *c += o;
        }
        self.total += other.total;
    }

    pub fn clear(&mut self) {
        self.counts = [0; LAT_BUCKETS];
        self.total = 0;
    }

    /// The `p`-th percentile (0 < p <= 1) as the floor of the bucket
    /// holding the ceil(p * total)-th smallest sample; `Duration::ZERO`
    /// when nothing was recorded. Monotone in `p` by construction.
    pub fn percentile(&self, p: f64) -> Duration {
        if self.total == 0 {
            return Duration::ZERO;
        }
        let p = p.clamp(0.0, 1.0);
        let rank = ((p * self.total as f64).ceil() as u64).max(1).min(self.total);
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Duration::from_nanos(Self::bucket_floor(b));
            }
        }
        // Unreachable while counts sum to total; conservative fallback.
        Duration::from_nanos(Self::bucket_floor(LAT_BUCKETS - 1))
    }

    pub fn p50(&self) -> Duration {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> Duration {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> Duration {
        self.percentile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_cover_the_u64_range_without_gaps() {
        assert_eq!(LatencyHist::bucket_of(0), 0);
        assert_eq!(LatencyHist::bucket_of(1), 1);
        assert_eq!(LatencyHist::bucket_of(2), 2);
        assert_eq!(LatencyHist::bucket_of(3), 2);
        assert_eq!(LatencyHist::bucket_of(4), 3);
        assert_eq!(LatencyHist::bucket_of(u64::MAX), LAT_BUCKETS - 1);
        for b in 1..LAT_BUCKETS - 1 {
            let lo = LatencyHist::bucket_floor(b);
            assert_eq!(LatencyHist::bucket_of(lo), b, "floor lands in its own bucket");
            assert_eq!(LatencyHist::bucket_of(2 * lo - 1), b, "top edge stays in bucket");
        }
    }

    #[test]
    fn percentiles_are_monotone_and_conserve_counts() {
        let mut h = LatencyHist::new();
        for ns in [0u64, 1, 5, 5, 100, 1000, 1000, 50_000, 1_000_000] {
            h.record(Duration::from_nanos(ns));
        }
        assert_eq!(h.total(), 9);
        assert_eq!(h.counts().iter().sum::<u64>(), h.total());
        let (p50, p95, p99) = (h.p50(), h.p95(), h.p99());
        assert!(p50 <= p95 && p95 <= p99, "{p50:?} {p95:?} {p99:?}");
        // p100 floor never exceeds the max sample; p50 floor is within one
        // octave below the true median (100ns -> floor 64ns).
        assert!(h.percentile(1.0) <= Duration::from_nanos(1_000_000));
        assert_eq!(p50, Duration::from_nanos(64));
    }

    #[test]
    fn empty_histogram_reads_zero_and_merge_folds() {
        let mut a = LatencyHist::new();
        assert_eq!(a.p99(), Duration::ZERO);
        let mut b = LatencyHist::new();
        b.record(Duration::from_nanos(300));
        b.record(Duration::from_nanos(700));
        a.merge(&b);
        assert_eq!(a.total(), 2);
        assert_eq!(a.p50(), b.p50());
        a.clear();
        assert_eq!(a.total(), 0);
        assert_eq!(a.counts().iter().sum::<u64>(), 0);
    }
}

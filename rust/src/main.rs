//! `tlo` — leader entrypoint. Subcommands mirror the examples so the
//! shipped binary can regenerate every experiment:
//!   tlo table1             Table-I analysis over the PolyBench suite
//!   tlo table2 [--device]  Table-II resource/Fmax model
//!   tlo video [--riffa]    §IV-C video pipeline (Fig 6 + fps)
//!   tlo serve [--tenants N --shards K]
//!                          multi-tenant DFE offload server (shard
//!                          scheduler + shared config cache + batched
//!                          PCIe link), verified bit-identical to the
//!                          single-tenant path
//!   tlo lint               static artifact verifier (DESIGN.md §11)
//!                          over every PolyBench kernel: extract,
//!                          route, compile, tile — then re-verify all
//!                          of it and print the diagnostic table
//!   tlo devices            list modeled FPGA devices
use tlo::util::cli::Args;

const USAGE: &str = "subcommands: table1 | table2 [--device NAME] | lint [--grid RxC] \
| video [--frames N --riffa] \
| serve [--tenants N --shards K --requests R --grid RxC --transport sync|async|async:D \
--compile-threads N --par-portfolio K --tagged --no-adapt --no-verify --no-lower \
--slo SECS --cache-dir DIR --drain-timeout SECS \
--fleet N --fault-profile drop=P,dup=P,reorder=P,jitter=F,crash=P --fault-seed S] \
| devices";

fn main() {
    let args = Args::from_env(&[
        "device", "frames", "n", "seed", "tenants", "shards", "requests", "grid", "transport",
        "compile-threads", "par-portfolio", "fleet", "fault-profile", "fault-seed",
        "slo", "cache-dir", "drain-timeout",
    ]);
    match args.positional.first().map(String::as_str) {
        Some("table1") => table1(),
        Some("table2") => table2(&args),
        Some("lint") => lint(&args),
        Some("video") => video(&args),
        Some("serve") => serve(&args),
        Some("devices") => {
            for d in tlo::dfe::resource::devices() {
                let (r, c) = d.largest_routable();
                println!("{:<18} {:<22} {}  largest routable DFE: {}x{}", d.name, d.part, d.tool.name(), r, c);
            }
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}'");
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
        None => {
            println!("tlo — Transparent Live Code Offloading (simulated DFE overlay)");
            println!("{USAGE}");
            println!("experiments: see examples/ and `cargo bench` (DESIGN.md §4)");
        }
    }
}

fn table1() {
    // Same harness as examples/polybench_analysis.rs, kept thin here.
    use tlo::analysis::scop::analyze_function;
    use tlo::dfg::extract::extract;
    for k in tlo::workloads::polybench::suite() {
        let an = analyze_function(&k.func);
        let mut ok = Vec::new();
        for s in &an.scops {
            if let Ok(off) = extract(&k.func, s, k.unroll) {
                ok.push(off.dfg.stats().to_string());
            }
        }
        println!("{:<16} {:?}", k.name, if ok.is_empty() { vec!["-".to_string()] } else { ok });
    }
}

/// `tlo lint` — run the full pipeline over every PolyBench kernel and
/// re-verify everything it produced with the static verifier
/// (`analysis::verifier`, DESIGN.md §11): V1 at the extraction boundary,
/// V2/V3/V6 on each routed single-tile artifact (V6 re-proves the
/// lowered batch kernels equivalent to the wave schedule), and V4 on a
/// tiled plan cut for an undersized grid. Prints one line per artifact plus a
/// diagnostic table for anything flagged; exits nonzero on any error.
fn lint(args: &Args) {
    use tlo::analysis::diag::{has_errors, render_table, Diag};
    use tlo::analysis::scop::analyze_function;
    use tlo::analysis::verifier::{
        verify_artifact, verify_offload, verify_plan_with_provenance,
    };
    use tlo::dfe::cache::{dfg_key, spec_key, CachedConfig, SpecSignature};
    use tlo::dfe::grid::Grid;
    use tlo::dfe::{tile_key, ExecutionPlan, PlanTile};
    use tlo::dfg::extract::extract;
    use tlo::dfg::partition::{needs_tiling, partition, TileBudget};
    use tlo::par::{place_and_route, ParParams};
    use tlo::util::prng::Rng;

    let grid = match args.get("grid") {
        None => Grid::new(8, 8),
        Some(s) => match parse_grid(s) {
            Some(g) => g,
            None => {
                eprintln!("bad --grid '{s}' (expected RxC, e.g. 8x8)");
                std::process::exit(2);
            }
        },
    };

    // Las-Vegas P&R: a single seed may fail on a routable DFG, so retry
    // a bounded seed schedule before declaring the kernel unroutable.
    let route = |dfg: &tlo::dfg::graph::Dfg, grid: Grid, salt: u64| {
        (0..64u64).find_map(|seed| {
            let mut rng = Rng::new(0x71E5 + seed * 997 + salt);
            place_and_route(dfg, grid, &ParParams::default(), &mut rng).ok()
        })
    };

    let mut artifacts = 0usize;
    let mut flagged: Vec<(String, Vec<Diag>)> = Vec::new();
    let mut report = |name: String, diags: Vec<Diag>| {
        let verdict = if has_errors(&diags) {
            "FAIL"
        } else if diags.is_empty() {
            "clean"
        } else {
            "warn"
        };
        println!("  {name:<28} {verdict}");
        if !diags.is_empty() {
            flagged.push((name, diags));
        }
    };

    println!("lint: static verification over the PolyBench suite ({}x{} overlay)", grid.rows, grid.cols);
    for k in tlo::workloads::polybench::suite() {
        let an = analyze_function(&k.func);
        for (si, s) in an.scops.iter().enumerate() {
            let Ok(off) = extract(&k.func, s, k.unroll) else { continue };
            artifacts += 1;
            report(format!("{} scop{si} u{} [V1]", k.name, k.unroll), verify_offload(&k.func, &off));
            let budget = TileBudget::for_grid(grid);
            if needs_tiling(&off.dfg, budget) {
                // Oversized for one pass: cut a tiled plan and run the
                // plan-level passes with full provenance.
                let Ok(tiled) = partition(&off.dfg, budget) else {
                    report(format!("{} scop{si} u{} [V4]", k.name, k.unroll), vec![Diag::error(
                        tlo::analysis::diag::Pass::V4PlanSoundness,
                        "partition",
                        "kernel needs tiling but the partitioner refuses it",
                    )]);
                    continue;
                };
                let plan_key = spec_key(dfg_key(&off.dfg), SpecSignature::generic(k.unroll));
                let mut ptiles = Vec::with_capacity(tiled.n_tiles());
                for (idx, t) in tiled.tiles.iter().enumerate() {
                    let Some(res) = route(&t.dfg, grid, idx as u64) else {
                        ptiles.clear();
                        break;
                    };
                    let Ok(image) = res.config.to_image() else {
                        ptiles.clear();
                        break;
                    };
                    ptiles.push(PlanTile {
                        cached: CachedConfig::new(res.config, image, format!("tile{idx}")),
                        sources: t.sources.clone(),
                        sinks: t.sinks.clone(),
                        key: tile_key(plan_key, idx, dfg_key(&t.dfg)),
                    });
                }
                if ptiles.len() != tiled.n_tiles() {
                    println!("  {:<28} (unroutable tile — skipped)", k.name);
                    continue;
                }
                artifacts += 1;
                let plan = ExecutionPlan { tiles: ptiles, n_spills: tiled.n_spills };
                report(
                    format!("{} scop{si} u{} [V4 {}t]", k.name, k.unroll, plan.n_tiles()),
                    verify_plan_with_provenance(&plan, plan_key, &off.dfg, &tiled),
                );
            } else if let Some(res) = route(&off.dfg, grid, si as u64) {
                let Ok(image) = res.config.to_image() else {
                    report(format!("{} scop{si} u{} [V2]", k.name, k.unroll), vec![Diag::error(
                        tlo::analysis::diag::Pass::V2GridLegality,
                        "image",
                        "routed configuration fails to lower to an image",
                    )]);
                    continue;
                };
                artifacts += 1;
                let cached = CachedConfig::new(res.config, image, format!("lint_{}", k.name));
                report(
                    format!("{} scop{si} u{} [V2/V3/V6]", k.name, k.unroll),
                    verify_artifact(&cached),
                );
            } else {
                println!("  {:<28} (unroutable on this grid — skipped)", k.name);
            }
        }
    }

    let errors = flagged.iter().filter(|(_, d)| has_errors(d)).count();
    for (name, diags) in &flagged {
        println!("\n{name}:\n{}", render_table(diags));
    }
    println!(
        "\nlint: {artifacts} artifact(s) verified, {} flagged, {errors} with errors",
        flagged.len()
    );
    if errors > 0 {
        std::process::exit(1);
    }
}

fn table2(args: &Args) {
    let filter = args.get("device");
    for d in tlo::dfe::resource::devices() {
        if let Some(f) = filter {
            if !d.name.eq_ignore_ascii_case(f) {
                continue;
            }
        }
        println!("\n{} ({}, {})", d.name, d.part, d.tool.name());
        for (r, c) in [(3, 3), (6, 6), (8, 8), (9, 9), (10, 10), (15, 15), (18, 18), (24, 18)] {
            println!("  {}", d.estimate(r, c));
        }
    }
}

/// The §IV-C video pipeline (the doc header advertised this subcommand
/// long before it existed — it is the compact rendition of
/// examples/video_pipeline.rs over `workloads::video`).
fn video(args: &Args) {
    use std::time::Duration;
    use tlo::jit::engine::Engine;
    use tlo::jit::interp::Memory;
    use tlo::offload::{OffloadManager, OffloadParams};
    use tlo::trace::Phase;
    use tlo::transport::PcieParams;
    use tlo::util::fmt_duration;
    use tlo::workloads::video as vw;

    let frames = args.get_usize("frames", 24).max(1);
    let riffa = args.flag("riffa");

    let mut engine = Engine::new(vw::video_module()).expect("video module");
    let mut mem = Memory::new();
    let (out, inp, coef) = vw::alloc_pipeline(&mut mem);
    let mut src = vw::FrameSource::new();
    let mut frame = vec![0i32; vw::FRAME_W * vw::FRAME_H];
    let func = engine.func_index("conv").unwrap();
    let decode = Duration::from_secs_f64(vw::DECODE_MS * 1e-3);

    // Software phase: a few frames to establish the baseline.
    let warm = 4.min(frames);
    for _ in 0..warm {
        src.next_frame(&mut frame);
        mem.i32s_mut(inp).copy_from_slice(&frame);
        engine.call("conv", &mut mem, &vw::conv_args(out, inp, coef)).expect("conv");
    }
    let prof = engine.profile(func);
    let sw_frame =
        decode + Duration::from_secs_f64(1e-9 * prof.counters.cycles as f64 / warm as f64);

    let mut params = OffloadParams {
        min_dfg_nodes: 8,
        seed: args.get_u64("seed", 42),
        ..Default::default()
    };
    if riffa {
        params.pcie = PcieParams::riffa_like();
    }
    let mut mgr = OffloadManager::new(params);
    let rec = match mgr.try_offload(&mut engine, func, None) {
        Ok(rec) => rec,
        Err(e) => {
            eprintln!("offload rejected: {e}");
            std::process::exit(1);
        }
    };
    println!(
        "offloaded conv: DFG {} in / {} out / {} calc (paper: 17/1/16)",
        rec.inputs, rec.outputs, rec.calc
    );

    for _ in warm..frames {
        src.next_frame(&mut frame);
        mem.i32s_mut(inp).copy_from_slice(&frame);
        mgr.tracer.borrow_mut().simulated(Phase::HostWork, decode);
        engine.call("conv", &mut mem, &vw::conv_args(out, inp, coef)).expect("conv");
    }
    // Numerics check on the last frame against the host reference.
    let want = vw::conv_reference(&frame, &vw::COEF, vw::FRAME_W, vw::FRAME_H);
    assert_eq!(mem.i32s(out), &want[..], "offloaded convolution numerics");

    let st = mgr.state(func).unwrap();
    let st = st.borrow();
    let off_frame = decode + st.virtual_offload / st.invocations.max(1) as u32;
    println!(
        "software  {} / frame -> {:.1} fps",
        fmt_duration(sw_frame),
        1.0 / sw_frame.as_secs_f64()
    );
    println!(
        "offloaded {} / frame -> {:.1} fps  ({})",
        fmt_duration(off_frame),
        1.0 / off_frame.as_secs_f64(),
        if riffa {
            "packed/RIFFA-like protocol"
        } else {
            "tagged protocol: transfer-bound, as in the paper (31 vs 83 fps)"
        }
    );
    println!("\n== Fig-6 phase timeline ==\n{}", mgr.tracer.borrow().render_timeline());
}

/// Multi-tenant offload server over N shard regions (see
/// `offload::server`). Verifies per-tenant outputs bit-identical to the
/// single-tenant offload path unless --no-verify.
fn serve(args: &Args) {
    use tlo::dfe::grid::Grid;
    use tlo::offload::server::{run_single_tenant, OffloadServer, ServeParams, serve_mix};
    use tlo::transport::{PcieParams, TransportMode};

    let tenants = args.get_usize("tenants", 4).max(1);
    let shards = args.get_usize("shards", 2).max(1);
    let requests = args.get_u64("requests", 8).max(1);
    // The overlapped pipeline is the production default; `--transport
    // sync` keeps the paper's blocking prototype for the A7 ablation and
    // the bit-for-bit conformance diff.
    let transport = match args.get("transport") {
        None => TransportMode::async_default(),
        Some(s) => match TransportMode::parse(s) {
            Some(m) => m,
            None => {
                eprintln!("bad --transport '{s}' (expected sync | async | async:D)");
                std::process::exit(2);
            }
        },
    };
    let grid = match args.get("grid") {
        None => Grid::new(12, 12),
        Some(s) => match parse_grid(s) {
            Some(g) => g,
            None => {
                eprintln!("bad --grid '{s}' (expected RxC, e.g. 12x12)");
                std::process::exit(2);
            }
        },
    };
    // The non-blocking compile service is the production default:
    // respecialization P&R races a 4-seed portfolio on 2 background
    // threads and swaps in at round boundaries. `--compile-threads 0`
    // restores the paper's synchronous (stalling) compiles;
    // `--par-portfolio 1` restores single-seed search.
    let compile_threads = args.get_usize("compile-threads", 2);
    let portfolio = args.get_usize("par-portfolio", 4).max(1);
    // --slo S: per-round fabric-time budget in virtual seconds. Overload
    // sheds lowest-priority classes to the software tier (numerics are
    // unaffected — a shed request still executes, on the host).
    let slo = match args.get("slo") {
        None => None,
        Some(s) => match s.parse::<f64>() {
            Ok(v) if v > 0.0 => Some(v),
            _ => {
                eprintln!("bad --slo '{s}' (expected positive seconds, e.g. 0.002)");
                std::process::exit(2);
            }
        },
    };
    let mut params = ServeParams {
        shards,
        grid,
        seed: args.get_u64("seed", 0x5EED),
        transport,
        // Live adaptive respecialization is on by default on the serve
        // path; --no-adapt pins every tenant to its spec'd unroll.
        adapt: (!args.flag("no-adapt"))
            .then(tlo::offload::adapt::AdaptParams::default),
        portfolio,
        compile_threads,
        slo,
        // --cache-dir DIR: load a configuration-cache snapshot at startup
        // and persist one at shutdown, so a restarted server serves its
        // working set with zero recompiles (warm restart).
        cache_dir: args.get("cache-dir").map(std::path::PathBuf::from),
        drain_timeout: std::time::Duration::from_secs_f64(
            args.get_f64("drain-timeout", 30.0).max(0.001),
        ),
        // --no-lower pins the interpreted wave executor instead of the
        // lowered batch kernels (numerics identical; CI runs it once per
        // pipeline so the fallback cannot rot).
        lower: !args.flag("no-lower"),
        ..Default::default()
    };
    if args.flag("tagged") {
        params.pcie = PcieParams::default();
    }
    let specs = serve_mix(tenants);
    println!(
        "serving {tenants} tenants on {shards} shard(s) of a {}x{} overlay ({} protocol, {} transport)",
        grid.rows,
        grid.cols,
        if args.flag("tagged") { "tagged 128b/32b" } else { "packed/RIFFA-like" },
        transport
    );
    println!(
        "compile service: {} (portfolio K={portfolio})",
        if compile_threads > 0 {
            format!("{compile_threads} background thread(s), non-blocking respecialization")
        } else {
            "off — synchronous P&R on every miss".to_string()
        }
    );
    // --fleet N: serve across N remote DFE nodes over the lossy datagram
    // transport instead of the local PCIe-attached shards.
    let fleet_nodes = args.get_usize("fleet", 0);
    if fleet_nodes > 0 {
        serve_fleet(args, params, specs, fleet_nodes, requests);
        return;
    }
    let mut server = match OffloadServer::new(params, specs.clone()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("serve setup failed: {e:#}");
            std::process::exit(1);
        }
    };
    for (i, r) in server.regions.iter().enumerate() {
        println!("  shard {i}: region {r}");
    }
    let report = server.run(requests);
    println!("\n{report}");
    if server.params.cache_dir.is_some() {
        // Orderly shutdown: land in-flight background compiles first, so
        // the snapshot holds the whole working set and a restart really
        // does serve with zero recompiles.
        server.drain_compiles();
    }
    if let Some(dir) = server.params.cache_dir.clone() {
        match tlo::dfe::persist::save_cache(&server.cache, &dir) {
            Ok(path) => println!(
                "cache snapshot: {} config(s) -> {}",
                server.cache.len(),
                path.display()
            ),
            Err(e) => eprintln!("cache snapshot to {} failed: {e}", dir.display()),
        }
    }
    for t in &server.tenants {
        for r in &t.respecs {
            println!(
                "adapt: {} respecialized u{} -> u{} after {} requests",
                t.spec.name, r.from_unroll, r.to_unroll, r.at_request
            );
        }
    }

    if !args.flag("no-verify") {
        let mut ok = true;
        for (i, spec) in specs.iter().enumerate() {
            let want = match run_single_tenant(spec, requests) {
                Ok(w) => w,
                Err(e) => {
                    eprintln!("verify {}: single-tenant replay failed: {e:#}", spec.name);
                    std::process::exit(1);
                }
            };
            if server.tenant_outputs(i) != want {
                eprintln!("verify {}: outputs DIVERGE from the single-tenant path", spec.name);
                ok = false;
            }
        }
        if !ok {
            std::process::exit(1);
        }
        println!(
            "\nverified: all {} tenant outputs bit-identical to the single-tenant offload path",
            specs.len()
        );
    }
}

/// Fleet mode: tenants scheduled across N remote DFE nodes over seeded
/// lossy datagram links (`offload::fleet`). Output stays bit-identical to
/// the single-tenant path under any fault schedule — faults cost retries
/// and fallbacks, never numerics — and is verified unless --no-verify.
fn serve_fleet(
    args: &Args,
    params: tlo::offload::server::ServeParams,
    specs: Vec<tlo::offload::server::TenantSpec>,
    nodes: usize,
    requests: u64,
) {
    use tlo::offload::fleet::{FleetParams, FleetServer};
    use tlo::offload::server::run_single_tenant;
    use tlo::transport::{FaultProfile, NetParams};

    let fault = match args.get("fault-profile") {
        None => FaultProfile::healthy(),
        Some(s) => match FaultProfile::parse(s) {
            Some(f) => f,
            None => {
                eprintln!(
                    "bad --fault-profile '{s}' (expected \
                     drop=P,dup=P,reorder=P,jitter=F,crash=P, values in [0,1])"
                );
                std::process::exit(2);
            }
        },
    };
    let fleet_params = FleetParams {
        nodes,
        net: NetParams { fault, ..NetParams::lan_like() },
        fault_seed: args.get_u64("fault-seed", 0xF1EE7),
        ..Default::default()
    };
    println!(
        "fleet: {nodes} remote node(s), fault profile drop={} dup={} reorder={} jitter={} \
         crash={}, fault seed {:#x}",
        fault.drop, fault.dup, fault.reorder, fault.jitter, fault.crash, fleet_params.fault_seed
    );
    let mut fleet = match FleetServer::new(params, fleet_params, specs.clone()) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("fleet setup failed: {e:#}");
            std::process::exit(1);
        }
    };
    let report = fleet.run(requests);
    println!("\n{report}");
    if fleet.server.params.cache_dir.is_some() {
        fleet.server.drain_compiles();
    }
    if let Some(dir) = fleet.server.params.cache_dir.clone() {
        match tlo::dfe::persist::save_cache(&fleet.server.cache, &dir) {
            Ok(path) => println!(
                "cache snapshot: {} config(s) -> {}",
                fleet.server.cache.len(),
                path.display()
            ),
            Err(e) => eprintln!("cache snapshot to {} failed: {e}", dir.display()),
        }
    }

    if !args.flag("no-verify") {
        let mut ok = true;
        for (i, spec) in specs.iter().enumerate() {
            let want = match run_single_tenant(spec, requests) {
                Ok(w) => w,
                Err(e) => {
                    eprintln!("verify {}: single-tenant replay failed: {e:#}", spec.name);
                    std::process::exit(1);
                }
            };
            if fleet.tenant_outputs(i) != want {
                eprintln!(
                    "verify {}: outputs DIVERGE under the fault schedule",
                    spec.name
                );
                ok = false;
            }
        }
        if !ok {
            std::process::exit(1);
        }
        println!(
            "\nverified: all {} tenant outputs bit-identical to the single-tenant path \
             under the fault schedule",
            specs.len()
        );
    }
}

fn parse_grid(s: &str) -> Option<tlo::dfe::grid::Grid> {
    let (r, c) = s.split_once('x')?;
    let (r, c): (usize, usize) = (r.trim().parse().ok()?, c.trim().parse().ok()?);
    if r == 0 || c == 0 {
        return None;
    }
    Some(tlo::dfe::grid::Grid::new(r, c))
}

//! `tlo` — leader entrypoint. Subcommands mirror the examples so the
//! shipped binary can regenerate every experiment:
//!   tlo table1            Table-I analysis over the PolyBench suite
//!   tlo table2 [--device] Table-II resource/Fmax model
//!   tlo video [--riffa]   §IV-C video pipeline (Fig 6 + fps)
//!   tlo devices           list modeled FPGA devices
use tlo::util::cli::Args;

fn main() {
    let args = Args::from_env(&["device", "frames", "n", "seed"]);
    match args.positional.first().map(String::as_str) {
        Some("table1") => table1(),
        Some("table2") => table2(&args),
        Some("devices") => {
            for d in tlo::dfe::resource::devices() {
                let (r, c) = d.largest_routable();
                println!("{:<18} {:<22} {}  largest routable DFE: {}x{}", d.name, d.part, d.tool.name(), r, c);
            }
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}'");
            std::process::exit(2);
        }
        None => {
            println!("tlo — Transparent Live Code Offloading (simulated DFE overlay)");
            println!("subcommands: table1 | table2 [--device NAME] | devices");
            println!("experiments: see examples/ and `cargo bench` (DESIGN.md §4)");
        }
    }
}

fn table1() {
    // Same harness as examples/polybench_analysis.rs, kept thin here.
    use tlo::analysis::scop::analyze_function;
    use tlo::dfg::extract::extract;
    for k in tlo::workloads::polybench::suite() {
        let an = analyze_function(&k.func);
        let mut ok = Vec::new();
        for s in &an.scops {
            if let Ok(off) = extract(&k.func, s, k.unroll) {
                ok.push(off.dfg.stats().to_string());
            }
        }
        println!("{:<16} {:?}", k.name, if ok.is_empty() { vec!["-".to_string()] } else { ok });
    }
}

fn table2(args: &Args) {
    let filter = args.get("device");
    for d in tlo::dfe::resource::devices() {
        if let Some(f) = filter {
            if !d.name.eq_ignore_ascii_case(f) {
                continue;
            }
        }
        println!("\n{} ({}, {})", d.name, d.part, d.tool.name());
        for (r, c) in [(3, 3), (6, 6), (8, 8), (9, 9), (10, 10), (15, 15), (18, 18), (24, 18)] {
            println!("  {}", d.estimate(r, c));
        }
    }
}

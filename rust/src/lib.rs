//! `tlo` — Transparent Live Code Offloading on a (simulated) FPGA overlay.
//!
//! Reproduction of *Transparent Live Code Offloading on FPGA* (Rigamonti,
//! Delporte, Convers, Dassatti — HEIG-VD, 2016) as a three-layer
//! rust + JAX + Pallas stack. See DESIGN.md for the architecture and
//! EXPERIMENTS.md for the paper-vs-measured record.
//!
//! Layer map:
//! * L3 (this crate): the paper's framework — mini-IR substrate ([`ir`]),
//!   JIT-style bytecode engine ([`jit`]), hotspot monitor ([`profile`]),
//!   SCoP analysis ([`analysis`]), DFG extraction ([`dfg`]), Las-Vegas
//!   place & route ([`par`]), DFE overlay model ([`dfe`]), PCIe transport
//!   simulation ([`transport`]), the offload manager with rollback
//!   ([`offload`]) and phase tracing ([`trace`]).
//! * Serve layer ([`offload::server`]): the manager generalized to a
//!   multi-tenant scheduler — N placed-and-routed shard regions on one
//!   device ([`dfe::grid::Region`]), a cross-tenant LRU configuration
//!   cache, and per-round transfer coalescing on the shared PCIe link:
//!   blocking ([`transport::BatchQueue`]) or double-buffered full-duplex
//!   ([`transport::pipeline`], the default in `tlo serve`; `--transport
//!   sync` keeps the paper's discipline). `tlo serve --tenants N
//!   --shards K`.
//! * L2/L1 (build-time python): the DFE datapath as a Pallas kernel,
//!   AOT-lowered to HLO text and executed via PJRT ([`runtime`], behind
//!   the `pjrt` cargo feature; the default build uses the rust DFE
//!   simulator and the vendored utilities in [`util`]).

pub mod analysis;
pub mod dfe;
pub mod ir;
pub mod jit;
pub mod profile;
pub mod trace;
pub mod transport;
pub mod dfg;
pub mod offload;
pub mod par;
pub mod runtime;
pub mod util;
pub mod workloads;

//! PCIe transport simulation (paper §IV-C).
//!
//! The prototype moves data over a PCIe Gen2 x8 link with a deliberately
//! simple protocol: every 32-bit payload word is wrapped in a 128-bit
//! tagged packet ("we send 128 bits for each 32 bits"), i.e. a fixed 75 %
//! protocol overhead; transfers above a programmable threshold go through
//! DMA. The paper measures ~230 MB/s of raw link rate on this setup, so
//! the *effective* payload rate is ~230/4 MB/s. The suggested fix — a
//! RIFFA-like packed protocol approaching the 4 GB/s theoretical limit —
//! is implemented here as the `Packed` variant and benchmarked as an
//! ablation (EXPERIMENTS.md A1).
//!
//! The simulator is an accounting model: given a payload size it produces
//! wire bytes and transfer time, plus PIO/DMA setup latencies and an
//! arbitration stall model (PCIe "is an arbitrated resource not always
//! available", visible as gaps in Fig 6(c)).

use std::time::Duration;

pub mod net;
pub mod pipeline;

pub use net::{Attempt, FaultProfile, NetLink, NetParams, NetStats};
pub use pipeline::{
    chunk_plan, expected_sends, AsyncLink, ChunkTimeline, NodeTimeline, PlanTimeline,
    TransportMode,
};

/// Wire protocol used for payload framing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Protocol {
    /// The paper's prototype: one 32-bit word per 128-bit tagged packet.
    Tagged128,
    /// RIFFA-like packed streaming (ablation A1): dense payload plus a
    /// small per-block header.
    Packed,
}

impl Protocol {
    /// Bytes on the wire for `payload_bytes` of useful data. A zero-byte
    /// transfer frames nothing and costs zero wire bytes under *both*
    /// protocols (the FSM never emits an empty packet or block header).
    pub fn wire_bytes(self, payload_bytes: u64) -> u64 {
        if payload_bytes == 0 {
            return 0;
        }
        match self {
            // 4 bytes payload -> 16 bytes on the wire.
            Protocol::Tagged128 => payload_bytes * 4,
            // 16-byte header per 4 KiB block.
            Protocol::Packed => payload_bytes + 16 * payload_bytes.div_ceil(4096),
        }
    }

    /// Protocol overhead as a percentage of wire traffic; 0 for the
    /// zero-payload case (no traffic, no overhead — avoids 0/0).
    pub fn overhead_pct(self, payload_bytes: u64) -> f64 {
        let wire = self.wire_bytes(payload_bytes) as f64;
        if wire == 0.0 {
            return 0.0;
        }
        100.0 * (wire - payload_bytes as f64) / wire
    }
}

/// Link + controller parameters.
#[derive(Clone, Copy, Debug)]
pub struct PcieParams {
    /// Raw achievable link rate in bytes/s (paper: ~230 MB/s measured on
    /// the prototype's Gen2 x8 with simple glue logic).
    pub link_rate: f64,
    /// Payload threshold above which DMA is used (paper: "if the
    /// requested data transfer is above a programmable threshold, a DMA
    /// transfer is started").
    pub dma_threshold: u64,
    /// Per-transfer setup latency for PIO and DMA.
    pub pio_setup: Duration,
    pub dma_setup: Duration,
    /// Fraction of time the bus is unavailable (arbitration).
    pub arbitration_stall: f64,
    pub protocol: Protocol,
}

impl Default for PcieParams {
    fn default() -> Self {
        PcieParams {
            link_rate: 230.0e6,
            dma_threshold: 4096,
            pio_setup: Duration::from_micros(1),
            dma_setup: Duration::from_micros(8),
            arbitration_stall: 0.10,
            protocol: Protocol::Tagged128,
        }
    }
}

impl PcieParams {
    /// The paper's theoretical Gen2 x8 limit (for the RIFFA comparison).
    pub fn riffa_like() -> PcieParams {
        PcieParams {
            link_rate: 3.2e9, // RIFFA 2.1 gets "very close" to 4 GB/s
            protocol: Protocol::Packed,
            ..Default::default()
        }
    }

    /// Stall-adjusted achievable link rate (bytes/s).
    pub fn effective_link_rate(&self) -> f64 {
        self.link_rate * (1.0 - self.arbitration_stall)
    }

    /// Modeled one-way transfer time for `payload_bytes`, in f64 seconds
    /// end-to-end — the model-side primitive. `Duration` is only minted at
    /// the accounting edge ([`PcieSim::transfer`]): integer-nanosecond
    /// rounding on sub-microsecond chunk transfers would quantize tiny
    /// batches to zero and make them look free to the promotion model.
    pub fn transfer_secs(&self, payload_bytes: u64) -> f64 {
        let wire = self.protocol.wire_bytes(payload_bytes);
        let setup = if payload_bytes >= self.dma_threshold {
            self.dma_setup
        } else {
            self.pio_setup
        };
        setup.as_secs_f64() + wire as f64 / self.effective_link_rate()
    }
}

/// One accounted transfer.
#[derive(Clone, Copy, Debug)]
pub struct Transfer {
    pub payload_bytes: u64,
    pub wire_bytes: u64,
    pub time: Duration,
    /// The same quantity in f64 seconds, exact (model paths consume this;
    /// `time` is the nanosecond-rounded rendition for reports).
    pub secs: f64,
    pub used_dma: bool,
}

/// Accounting state: cumulative traffic for reports.
#[derive(Clone, Debug)]
pub struct PcieSim {
    pub params: PcieParams,
    pub total_payload: u64,
    pub total_wire: u64,
    pub total_time: Duration,
    /// Exact occupancy in f64 seconds (sum of `Transfer::secs`).
    pub total_secs: f64,
    pub transfers: u64,
}

impl PcieSim {
    pub fn new(params: PcieParams) -> PcieSim {
        PcieSim {
            params,
            total_payload: 0,
            total_wire: 0,
            total_time: Duration::ZERO,
            total_secs: 0.0,
            transfers: 0,
        }
    }

    /// Account one host->DFE or DFE->host transfer of `payload_bytes`.
    pub fn transfer(&mut self, payload_bytes: u64) -> Transfer {
        let wire = self.params.protocol.wire_bytes(payload_bytes);
        let used_dma = payload_bytes >= self.params.dma_threshold;
        let setup = if used_dma { self.params.dma_setup } else { self.params.pio_setup };
        let rate = self.params.effective_link_rate();
        let wire_secs = wire as f64 / rate;
        let time = setup + Duration::from_secs_f64(wire_secs);
        let secs = setup.as_secs_f64() + wire_secs;
        self.total_payload += payload_bytes;
        self.total_wire += wire;
        self.total_time += time;
        self.total_secs += secs;
        self.transfers += 1;
        Transfer { payload_bytes, wire_bytes: wire, time, secs, used_dma }
    }

    /// Account a *coalesced* batch of transfers: each item still pays its
    /// protocol framing, but the batch pays a single PIO/DMA setup and one
    /// arbitration-stalled link occupancy — the serve layer's
    /// configuration/data download coalescing (DMA descriptor chaining).
    pub fn transfer_batch(&mut self, payloads: &[u64]) -> BatchedTransfer {
        let payload: u64 = payloads.iter().sum();
        let wire: u64 = payloads.iter().map(|&p| self.params.protocol.wire_bytes(p)).sum();
        if payloads.is_empty() || payload == 0 {
            return BatchedTransfer::default();
        }
        let used_dma = payload >= self.params.dma_threshold;
        let setup = if used_dma { self.params.dma_setup } else { self.params.pio_setup };
        let rate = self.params.effective_link_rate();
        let wire_secs = wire as f64 / rate;
        let time = setup + Duration::from_secs_f64(wire_secs);
        let secs = setup.as_secs_f64() + wire_secs;
        self.total_payload += payload;
        self.total_wire += wire;
        self.total_time += time;
        self.total_secs += secs;
        self.transfers += 1;
        BatchedTransfer {
            items: payloads.len(),
            payload_bytes: payload,
            wire_bytes: wire,
            time,
            secs,
            used_dma,
        }
    }

    /// Effective payload throughput observed so far.
    pub fn effective_rate(&self) -> f64 {
        if self.total_secs <= 0.0 {
            0.0
        } else {
            self.total_payload as f64 / self.total_secs
        }
    }
}

/// One accounted coalesced batch (see [`PcieSim::transfer_batch`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchedTransfer {
    pub items: usize,
    pub payload_bytes: u64,
    pub wire_bytes: u64,
    pub time: Duration,
    /// Exact occupancy in f64 seconds (see [`Transfer::secs`]).
    pub secs: f64,
    pub used_dma: bool,
}

/// Per-shard coalescing queue over one shared PCIe link (serve layer).
///
/// Transfers destined for the same shard region within a scheduling round
/// are staged with [`BatchQueue::enqueue`] and drained by
/// [`BatchQueue::flush_after`], which serializes the per-shard batches on
/// the link (it is one arbitrated resource) while amortizing setup inside
/// each batch. `link_free` is the virtual time at which the link next
/// becomes idle.
#[derive(Clone, Debug)]
pub struct BatchQueue {
    pub sim: PcieSim,
    pending: Vec<Vec<u64>>,
    pub link_free: Duration,
}

impl BatchQueue {
    pub fn new(params: PcieParams, shards: usize) -> BatchQueue {
        assert!(shards > 0, "need at least one shard lane");
        BatchQueue {
            sim: PcieSim::new(params),
            pending: vec![Vec::new(); shards],
            link_free: Duration::ZERO,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.pending.len()
    }

    /// Stage `payload_bytes` for `shard`. Zero-byte transfers are free on
    /// the wire and are dropped here (consistent with
    /// [`Protocol::wire_bytes`]).
    pub fn enqueue(&mut self, shard: usize, payload_bytes: u64) {
        if payload_bytes > 0 {
            self.pending[shard].push(payload_bytes);
        }
    }

    pub fn pending_bytes(&self, shard: usize) -> u64 {
        self.pending[shard].iter().sum()
    }

    /// Drain every non-empty per-shard batch, in shard order, serially on
    /// the link. `ready[s]` is the earliest virtual time shard `s`'s batch
    /// may start (e.g. "its DFE finished executing"). Returns each shard's
    /// batch completion time.
    pub fn flush_after(&mut self, ready: &[Duration]) -> Vec<(usize, Duration)> {
        let mut done = Vec::new();
        for s in 0..self.pending.len() {
            if self.pending[s].is_empty() {
                continue;
            }
            let start = self.link_free.max(ready.get(s).copied().unwrap_or(Duration::ZERO));
            let batch = std::mem::take(&mut self.pending[s]);
            let tr = self.sim.transfer_batch(&batch);
            let end = start + tr.time;
            self.link_free = end;
            done.push((s, end));
        }
        done
    }

    /// Drain with a single earliest-start time for every shard.
    pub fn flush(&mut self, now: Duration) -> Vec<(usize, Duration)> {
        let ready = vec![now; self.pending.len()];
        self.flush_after(&ready)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tagged_protocol_is_75pct_overhead() {
        let p = Protocol::Tagged128;
        assert_eq!(p.wire_bytes(4), 16);
        assert_eq!(p.wire_bytes(4096), 16384);
        assert!((p.overhead_pct(1 << 20) - 75.0).abs() < 1e-9);
    }

    #[test]
    fn packed_protocol_near_zero_overhead() {
        let p = Protocol::Packed;
        assert!(p.overhead_pct(1 << 20) < 1.0);
        // Small transfers still pay the header.
        assert!(p.overhead_pct(4) > 50.0);
    }

    #[test]
    fn dma_threshold_switches_mode() {
        let mut sim = PcieSim::new(PcieParams::default());
        assert!(!sim.transfer(128).used_dma);
        assert!(sim.transfer(8192).used_dma);
    }

    #[test]
    fn effective_rate_divided_by_four() {
        // Large transfer: effective payload rate ≈ link*(1-stall)/4.
        let mut sim = PcieSim::new(PcieParams::default());
        sim.transfer(64 << 20);
        let want = 230.0e6 * 0.9 / 4.0;
        let got = sim.effective_rate();
        assert!((got - want).abs() / want < 0.02, "got {got:.3e} want {want:.3e}");
    }

    #[test]
    fn riffa_ablation_is_an_order_faster() {
        let mut tagged = PcieSim::new(PcieParams::default());
        let mut packed = PcieSim::new(PcieParams::riffa_like());
        let t1 = tagged.transfer(16 << 20).time;
        let t2 = packed.transfer(16 << 20).time;
        assert!(
            t1.as_secs_f64() / t2.as_secs_f64() > 10.0,
            "tagged {t1:?} vs packed {t2:?}"
        );
    }

    #[test]
    fn accounting_accumulates() {
        let mut sim = PcieSim::new(PcieParams::default());
        sim.transfer(1000);
        sim.transfer(3000);
        assert_eq!(sim.transfers, 2);
        assert_eq!(sim.total_payload, 4000);
        assert_eq!(sim.total_wire, 16000);
    }

    #[test]
    fn zero_payload_costs_zero_wire_bytes_on_both_protocols() {
        assert_eq!(Protocol::Tagged128.wire_bytes(0), 0);
        assert_eq!(Protocol::Packed.wire_bytes(0), 0);
        // The 0/0 overhead case is defined as 0 %.
        assert_eq!(Protocol::Tagged128.overhead_pct(0), 0.0);
        assert_eq!(Protocol::Packed.overhead_pct(0), 0.0);
        // Non-zero payloads still pay framing.
        assert_eq!(Protocol::Packed.wire_bytes(1), 1 + 16);
        assert_eq!(Protocol::Tagged128.wire_bytes(4), 16);
    }

    #[test]
    fn zero_payload_transfer_accounts_no_traffic() {
        for params in [PcieParams::default(), PcieParams::riffa_like()] {
            let mut sim = PcieSim::new(params);
            let t = sim.transfer(0);
            assert_eq!(t.wire_bytes, 0);
            assert!(!t.used_dma);
            assert_eq!(sim.total_wire, 0);
            // Only the PIO setup is charged for the degenerate doorbell.
            assert_eq!(t.time, params.pio_setup);
        }
    }

    #[test]
    fn batched_transfer_amortizes_setup() {
        let payloads = [256u64, 256, 256, 256];
        let mut single = PcieSim::new(PcieParams::default());
        let serial: Duration = payloads.iter().map(|&p| single.transfer(p).time).sum();
        let mut batched = PcieSim::new(PcieParams::default());
        let b = batched.transfer_batch(&payloads);
        assert_eq!(b.items, 4);
        assert_eq!(b.payload_bytes, 1024);
        // Same wire bytes (framing is per item), strictly less time (one
        // setup instead of four).
        assert_eq!(batched.total_wire, single.total_wire);
        assert!(b.time < serial, "batched {:?} vs serial {serial:?}", b.time);
        assert_eq!(batched.transfers, 1);
    }

    #[test]
    fn empty_batch_is_free() {
        let mut sim = PcieSim::new(PcieParams::default());
        let b = sim.transfer_batch(&[]);
        assert_eq!(b.time, Duration::ZERO);
        assert_eq!(sim.transfers, 0);
        let b = sim.transfer_batch(&[0, 0]);
        assert_eq!(b.wire_bytes, 0);
        assert_eq!(sim.transfers, 0);
    }

    #[test]
    fn batch_queue_serializes_shards_on_the_link() {
        let mut q = BatchQueue::new(PcieParams::default(), 3);
        q.enqueue(0, 4096);
        q.enqueue(2, 4096);
        q.enqueue(2, 1024);
        q.enqueue(1, 0); // dropped
        let done = q.flush(Duration::ZERO);
        assert_eq!(done.len(), 2);
        let (s0, t0) = done[0];
        let (s2, t2) = done[1];
        assert_eq!((s0, s2), (0, 2));
        // Shard 2's batch starts only after shard 0's finished.
        assert!(t2 > t0);
        assert_eq!(q.link_free, t2);
        assert_eq!(q.pending_bytes(2), 0);
        // Coalescing is visible in the accounting: 2 link occupancies for
        // 3 logical transfers.
        assert_eq!(q.sim.transfers, 2);
    }

    #[test]
    fn transfer_secs_is_exact_and_matches_the_accounted_transfer() {
        for params in [PcieParams::default(), PcieParams::riffa_like()] {
            let mut sim = PcieSim::new(params);
            for p in [1u64, 3, 5, 100, 4095, 4096, 5000, 1 << 20] {
                let t = sim.transfer(p);
                assert_eq!(t.secs, params.transfer_secs(p), "payload {p}");
                // Sub-microsecond payloads must never model as free.
                assert!(t.secs > 0.0, "payload {p} quantized to zero");
            }
            assert!((sim.total_secs - sim.total_time.as_secs_f64()).abs() < 1e-6);
        }
    }

    #[test]
    fn batch_queue_respects_ready_times() {
        let mut q = BatchQueue::new(PcieParams::default(), 2);
        q.enqueue(1, 512);
        let ready = [Duration::ZERO, Duration::from_millis(5)];
        let done = q.flush_after(&ready);
        assert_eq!(done.len(), 1);
        assert!(done[0].1 >= Duration::from_millis(5));
    }
}

//! PCIe transport simulation (paper §IV-C).
//!
//! The prototype moves data over a PCIe Gen2 x8 link with a deliberately
//! simple protocol: every 32-bit payload word is wrapped in a 128-bit
//! tagged packet ("we send 128 bits for each 32 bits"), i.e. a fixed 75 %
//! protocol overhead; transfers above a programmable threshold go through
//! DMA. The paper measures ~230 MB/s of raw link rate on this setup, so
//! the *effective* payload rate is ~230/4 MB/s. The suggested fix — a
//! RIFFA-like packed protocol approaching the 4 GB/s theoretical limit —
//! is implemented here as the `Packed` variant and benchmarked as an
//! ablation (EXPERIMENTS.md A1).
//!
//! The simulator is an accounting model: given a payload size it produces
//! wire bytes and transfer time, plus PIO/DMA setup latencies and an
//! arbitration stall model (PCIe "is an arbitrated resource not always
//! available", visible as gaps in Fig 6(c)).

use std::time::Duration;

/// Wire protocol used for payload framing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Protocol {
    /// The paper's prototype: one 32-bit word per 128-bit tagged packet.
    Tagged128,
    /// RIFFA-like packed streaming (ablation A1): dense payload plus a
    /// small per-block header.
    Packed,
}

impl Protocol {
    /// Bytes on the wire for `payload_bytes` of useful data.
    pub fn wire_bytes(self, payload_bytes: u64) -> u64 {
        match self {
            // 4 bytes payload -> 16 bytes on the wire.
            Protocol::Tagged128 => payload_bytes * 4,
            // 16-byte header per 4 KiB block.
            Protocol::Packed => {
                let blocks = payload_bytes.div_ceil(4096).max(1);
                payload_bytes + 16 * blocks
            }
        }
    }

    pub fn overhead_pct(self, payload_bytes: u64) -> f64 {
        let wire = self.wire_bytes(payload_bytes) as f64;
        100.0 * (wire - payload_bytes as f64) / wire
    }
}

/// Link + controller parameters.
#[derive(Clone, Copy, Debug)]
pub struct PcieParams {
    /// Raw achievable link rate in bytes/s (paper: ~230 MB/s measured on
    /// the prototype's Gen2 x8 with simple glue logic).
    pub link_rate: f64,
    /// Payload threshold above which DMA is used (paper: "if the
    /// requested data transfer is above a programmable threshold, a DMA
    /// transfer is started").
    pub dma_threshold: u64,
    /// Per-transfer setup latency for PIO and DMA.
    pub pio_setup: Duration,
    pub dma_setup: Duration,
    /// Fraction of time the bus is unavailable (arbitration).
    pub arbitration_stall: f64,
    pub protocol: Protocol,
}

impl Default for PcieParams {
    fn default() -> Self {
        PcieParams {
            link_rate: 230.0e6,
            dma_threshold: 4096,
            pio_setup: Duration::from_micros(1),
            dma_setup: Duration::from_micros(8),
            arbitration_stall: 0.10,
            protocol: Protocol::Tagged128,
        }
    }
}

impl PcieParams {
    /// The paper's theoretical Gen2 x8 limit (for the RIFFA comparison).
    pub fn riffa_like() -> PcieParams {
        PcieParams {
            link_rate: 3.2e9, // RIFFA 2.1 gets "very close" to 4 GB/s
            protocol: Protocol::Packed,
            ..Default::default()
        }
    }
}

/// One accounted transfer.
#[derive(Clone, Copy, Debug)]
pub struct Transfer {
    pub payload_bytes: u64,
    pub wire_bytes: u64,
    pub time: Duration,
    pub used_dma: bool,
}

/// Accounting state: cumulative traffic for reports.
#[derive(Clone, Debug)]
pub struct PcieSim {
    pub params: PcieParams,
    pub total_payload: u64,
    pub total_wire: u64,
    pub total_time: Duration,
    pub transfers: u64,
}

impl PcieSim {
    pub fn new(params: PcieParams) -> PcieSim {
        PcieSim {
            params,
            total_payload: 0,
            total_wire: 0,
            total_time: Duration::ZERO,
            transfers: 0,
        }
    }

    /// Account one host->DFE or DFE->host transfer of `payload_bytes`.
    pub fn transfer(&mut self, payload_bytes: u64) -> Transfer {
        let wire = self.params.protocol.wire_bytes(payload_bytes);
        let used_dma = payload_bytes >= self.params.dma_threshold;
        let setup = if used_dma { self.params.dma_setup } else { self.params.pio_setup };
        let rate = self.params.link_rate * (1.0 - self.params.arbitration_stall);
        let time = setup + Duration::from_secs_f64(wire as f64 / rate);
        self.total_payload += payload_bytes;
        self.total_wire += wire;
        self.total_time += time;
        self.transfers += 1;
        Transfer { payload_bytes, wire_bytes: wire, time, used_dma }
    }

    /// Effective payload throughput observed so far.
    pub fn effective_rate(&self) -> f64 {
        if self.total_time.is_zero() {
            0.0
        } else {
            self.total_payload as f64 / self.total_time.as_secs_f64()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tagged_protocol_is_75pct_overhead() {
        let p = Protocol::Tagged128;
        assert_eq!(p.wire_bytes(4), 16);
        assert_eq!(p.wire_bytes(4096), 16384);
        assert!((p.overhead_pct(1 << 20) - 75.0).abs() < 1e-9);
    }

    #[test]
    fn packed_protocol_near_zero_overhead() {
        let p = Protocol::Packed;
        assert!(p.overhead_pct(1 << 20) < 1.0);
        // Small transfers still pay the header.
        assert!(p.overhead_pct(4) > 50.0);
    }

    #[test]
    fn dma_threshold_switches_mode() {
        let mut sim = PcieSim::new(PcieParams::default());
        assert!(!sim.transfer(128).used_dma);
        assert!(sim.transfer(8192).used_dma);
    }

    #[test]
    fn effective_rate_divided_by_four() {
        // Large transfer: effective payload rate ≈ link*(1-stall)/4.
        let mut sim = PcieSim::new(PcieParams::default());
        sim.transfer(64 << 20);
        let want = 230.0e6 * 0.9 / 4.0;
        let got = sim.effective_rate();
        assert!((got - want).abs() / want < 0.02, "got {got:.3e} want {want:.3e}");
    }

    #[test]
    fn riffa_ablation_is_an_order_faster() {
        let mut tagged = PcieSim::new(PcieParams::default());
        let mut packed = PcieSim::new(PcieParams::riffa_like());
        let t1 = tagged.transfer(16 << 20).time;
        let t2 = packed.transfer(16 << 20).time;
        assert!(
            t1.as_secs_f64() / t2.as_secs_f64() > 10.0,
            "tagged {t1:?} vs packed {t2:?}"
        );
    }

    #[test]
    fn accounting_accumulates() {
        let mut sim = PcieSim::new(PcieParams::default());
        sim.transfer(1000);
        sim.transfer(3000);
        assert_eq!(sim.transfers, 2);
        assert_eq!(sim.total_payload, 4000);
        assert_eq!(sim.total_wire, 16000);
    }
}

//! Datagram network transport for fleet serving (ROADMAP item 2).
//!
//! The serve layer scales past one host by talking to *remote* DFE nodes
//! over a lossy datagram link — the shape of the UDP-attached Nexys4DDR
//! offloader (SNIPPETS.md Snippet 3): command packets out, result packets
//! back, no reliable-stream fiction in between. Failure is a first-class
//! input here, not an afterthought: every link carries a per-node
//! [`FaultProfile`] (drop, duplicate, reorder, latency jitter, and node
//! crash/recover windows), and every fault draw comes from one seeded
//! [`Rng`] stream per node, so an entire chaos run is bit-reproducible
//! from a single `--fault-seed`.
//!
//! Same discipline as the PCIe model next door ([`super::PcieParams`] /
//! [`super::PcieSim`]): this is an *accounting* model in virtual f64
//! seconds. [`NetLink::exchange`] decides the fate and flight times of one
//! command→execute→result exchange; the fleet scheduler
//! (`offload::fleet`) owns the occupancy timelines, retries, and the
//! idempotent result application — faults may cost time, never
//! correctness.

use crate::util::prng::Rng;

/// Per-node fault profile. `drop`, `dup` and `reorder` are per-exchange
/// probabilities (an exchange is one command/result datagram pair),
/// `crash` is the per-exchange probability of entering a crash window
/// (the node stays down for a seed-derived span, then recovers), and
/// `jitter` scales each flight by a uniform factor in `[1, 1+jitter]`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultProfile {
    pub drop: f64,
    pub dup: f64,
    pub reorder: f64,
    pub jitter: f64,
    pub crash: f64,
}

impl FaultProfile {
    /// No faults: the datagram link behaves like a reliable transport.
    pub fn healthy() -> FaultProfile {
        FaultProfile::default()
    }

    pub fn is_healthy(&self) -> bool {
        self.drop == 0.0
            && self.dup == 0.0
            && self.reorder == 0.0
            && self.jitter == 0.0
            && self.crash == 0.0
    }

    /// CLI spelling: `drop=P,dup=P,reorder=P,jitter=F,crash=P`
    /// (comma-separated, every key optional, probabilities in `[0, 1]`).
    pub fn parse(s: &str) -> Option<FaultProfile> {
        let mut f = FaultProfile::default();
        for part in s.split(',').filter(|p| !p.trim().is_empty()) {
            let (k, v) = part.split_once('=')?;
            let v: f64 = v.trim().parse().ok()?;
            if !(0.0..=1.0).contains(&v) {
                return None;
            }
            match k.trim() {
                "drop" => f.drop = v,
                "dup" | "duplicate" => f.dup = v,
                "reorder" => f.reorder = v,
                "jitter" => f.jitter = v,
                "crash" => f.crash = v,
                _ => return None,
            }
        }
        Some(f)
    }
}

/// Datagram link + NIC parameters (the fleet-side sibling of
/// [`super::PcieParams`]).
#[derive(Clone, Copy, Debug)]
pub struct NetParams {
    /// Payload rate of the NIC in bytes/s.
    pub rate: f64,
    /// One-way propagation latency in seconds.
    pub latency: f64,
    /// Payload bytes per datagram.
    pub mtu: u64,
    /// Per-datagram header bytes on the wire (Ethernet + IP + UDP).
    pub header: u64,
    /// Retransmit timer: how long the caller waits on a lost exchange
    /// before declaring it failed (floor — slow exchanges extend it).
    pub timeout: f64,
    pub fault: FaultProfile,
}

impl Default for NetParams {
    fn default() -> Self {
        NetParams::lan_like()
    }
}

impl NetParams {
    /// A switched-GbE LAN, the Nexys4DDR offloader's environment: 125 MB/s
    /// on the wire, ~50 µs one-way, 1472-byte UDP payloads.
    pub fn lan_like() -> NetParams {
        NetParams {
            rate: 125.0e6,
            latency: 50e-6,
            mtu: 1472,
            header: 42,
            timeout: 2e-3,
            fault: FaultProfile::healthy(),
        }
    }

    /// Datagrams needed for `payload` bytes (an empty command still sends
    /// one doorbell datagram).
    pub fn datagrams(&self, payload: u64) -> u64 {
        payload.div_ceil(self.mtu).max(1)
    }

    /// Bytes on the wire for `payload` bytes of useful data.
    pub fn wire_bytes(&self, payload: u64) -> u64 {
        payload + self.header * self.datagrams(payload)
    }

    /// Modeled one-way flight time for `payload` bytes, in f64 seconds.
    pub fn transfer_secs(&self, payload: u64) -> f64 {
        self.latency + self.wire_bytes(payload) as f64 / self.rate
    }
}

/// The fate of one command→execute→result exchange.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Attempt {
    /// Both flights arrived. `up`/`down` are the (jittered) flight times
    /// in seconds; `down` already includes any reorder delay. `dup` means
    /// the result datagram also arrived a second time, `reordered` that
    /// it arrived after a later exchange's result — both are idempotency
    /// hazards the caller must absorb without double-applying.
    Delivered { up: f64, down: f64, dup: bool, reordered: bool },
    /// One of the flights was lost; the caller notices after `wait`
    /// seconds (its retransmit timer, floored by the exchange's own
    /// modeled span so slow exchanges are not declared dead early).
    Lost { wait: f64 },
    /// The node is inside a crash window until `until`; nothing was sent.
    Down { until: f64 },
}

/// Cumulative per-link accounting, for reports and chaos assertions.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NetStats {
    /// Exchanges attempted (including ones refused by a crash window).
    pub exchanges: u64,
    pub delivered: u64,
    pub dropped: u64,
    pub duplicated: u64,
    pub reordered: u64,
    /// Crash windows entered.
    pub crash_windows: u64,
    pub payload_bytes: u64,
    pub wire_bytes: u64,
}

/// One remote node's datagram link: fault draws + wire accounting. The
/// occupancy timeline lives with the scheduler
/// ([`super::pipeline::NodeTimeline`]) — this type only decides *what
/// happens* to each exchange and what the flights cost, deterministically
/// from `(fleet seed, node index)`.
#[derive(Clone, Debug)]
pub struct NetLink {
    pub params: NetParams,
    pub node: usize,
    rng: Rng,
    /// Virtual time the current crash window ends, if one is open.
    down_until: Option<f64>,
    pub stats: NetStats,
}

impl NetLink {
    /// Distinct per-node fault streams from one fleet seed: the node
    /// index is mixed in with the golden-ratio constant so node 0 with
    /// seed S and node 1 with seed S never replay each other's schedule.
    pub fn new(params: NetParams, node: usize, seed: u64) -> NetLink {
        let mixed = seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(node as u64 + 1);
        NetLink { params, node, rng: Rng::new(mixed), down_until: None, stats: NetStats::default() }
    }

    /// Whether the node is inside a crash window at `now`.
    pub fn is_down(&self, now: f64) -> bool {
        self.down_until.map(|u| now < u).unwrap_or(false)
    }

    /// Attempt one exchange starting at `now`: `h2d` command payload
    /// bytes out, `exec` seconds of remote fabric time, `d2h` result
    /// payload bytes back. All fault draws come from this link's seeded
    /// stream in a fixed order, so identical seeds replay identical
    /// fault schedules exchange-for-exchange.
    pub fn exchange(&mut self, h2d: u64, d2h: u64, exec: f64, now: f64) -> Attempt {
        self.stats.exchanges += 1;
        let f = self.params.fault;
        // Standing crash window: nothing transmits until it closes.
        if let Some(until) = self.down_until {
            if now < until {
                return Attempt::Down { until };
            }
            self.down_until = None;
        }
        // Fresh crash? The window span is seed-derived (8–32 timeouts),
        // so crash *and* recovery replay from the same seed.
        if f.crash > 0.0 && self.rng.chance(f.crash) {
            let span = self.params.timeout * (8 + self.rng.below(24)) as f64;
            let until = now + span;
            self.down_until = Some(until);
            self.stats.crash_windows += 1;
            return Attempt::Down { until };
        }
        let jit_up = 1.0 + f.jitter * self.rng.f64();
        let jit_down = 1.0 + f.jitter * self.rng.f64();
        let up = self.params.transfer_secs(h2d) * jit_up;
        let down = self.params.transfer_secs(d2h) * jit_down;
        if f.drop > 0.0 && self.rng.chance(f.drop) {
            // Either flight lost: the command datagrams hit the wire
            // regardless (that traffic is spent), the result never lands.
            self.stats.dropped += 1;
            self.stats.payload_bytes += h2d;
            self.stats.wire_bytes += self.params.wire_bytes(h2d);
            return Attempt::Lost { wait: self.params.timeout.max(up + exec + down) };
        }
        let dup = f.dup > 0.0 && self.rng.chance(f.dup);
        let reordered = f.reorder > 0.0 && self.rng.chance(f.reorder);
        // A reordered result arrives behind a later exchange's result:
        // model it as 1–3 extra propagation delays on the down flight.
        let down = if reordered {
            down + self.params.latency * (1 + self.rng.below(3)) as f64
        } else {
            down
        };
        self.stats.delivered += 1;
        self.stats.payload_bytes += h2d + d2h;
        self.stats.wire_bytes += self.params.wire_bytes(h2d) + self.params.wire_bytes(d2h);
        if dup {
            self.stats.duplicated += 1;
            // The duplicate result datagram also rides the wire.
            self.stats.payload_bytes += d2h;
            self.stats.wire_bytes += self.params.wire_bytes(d2h);
        }
        if reordered {
            self.stats.reordered += 1;
        }
        Attempt::Delivered { up, down, dup, reordered }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_profile_parses_and_rejects() {
        let f = FaultProfile::parse("drop=0.05,reorder=0.1,crash=0.3").unwrap();
        assert_eq!(f.drop, 0.05);
        assert_eq!(f.reorder, 0.1);
        assert_eq!(f.crash, 0.3);
        assert_eq!(f.dup, 0.0);
        assert!(FaultProfile::parse("").unwrap().is_healthy());
        assert!(FaultProfile::parse(" dup=0.2 , jitter=0.5 ").is_some());
        assert!(FaultProfile::parse("drop=1.5").is_none(), "out-of-range probability");
        assert!(FaultProfile::parse("lag=0.1").is_none(), "unknown key");
        assert!(FaultProfile::parse("drop").is_none(), "missing value");
    }

    #[test]
    fn wire_accounting_frames_per_datagram() {
        let p = NetParams::lan_like();
        assert_eq!(p.datagrams(0), 1);
        assert_eq!(p.datagrams(1472), 1);
        assert_eq!(p.datagrams(1473), 2);
        assert_eq!(p.wire_bytes(1472), 1472 + 42);
        assert_eq!(p.wire_bytes(3000), 3000 + 3 * 42);
        // Latency floor: even a doorbell costs a propagation delay.
        assert!(p.transfer_secs(0) >= p.latency);
        assert!(p.transfer_secs(1 << 20) > p.transfer_secs(1 << 10));
    }

    #[test]
    fn identical_seeds_replay_identical_fault_schedules() {
        let fault = FaultProfile {
            drop: 0.3,
            dup: 0.3,
            reorder: 0.3,
            jitter: 0.5,
            crash: 0.1,
        };
        let params = NetParams { fault, ..NetParams::lan_like() };
        let mut a = NetLink::new(params, 2, 0xC0FFEE);
        let mut b = NetLink::new(params, 2, 0xC0FFEE);
        let mut now = 0.0;
        for i in 0..500u64 {
            let ra = a.exchange(100 + i, 200, 1e-5, now);
            let rb = b.exchange(100 + i, 200, 1e-5, now);
            assert_eq!(ra, rb, "exchange {i} diverged");
            now += 1e-3;
        }
        assert_eq!(a.stats, b.stats);
        // The chaos profile actually exercised every fault class.
        assert!(a.stats.dropped > 0 && a.stats.duplicated > 0);
        assert!(a.stats.reordered > 0 && a.stats.crash_windows > 0);
    }

    #[test]
    fn distinct_nodes_have_distinct_schedules() {
        let fault = FaultProfile { drop: 0.5, ..FaultProfile::healthy() };
        let params = NetParams { fault, ..NetParams::lan_like() };
        let mut a = NetLink::new(params, 0, 42);
        let mut b = NetLink::new(params, 1, 42);
        let outcomes: (Vec<_>, Vec<_>) = (0..64)
            .map(|_| (a.exchange(64, 64, 0.0, 0.0), b.exchange(64, 64, 0.0, 0.0)))
            .unzip();
        assert_ne!(outcomes.0, outcomes.1, "node streams must not be correlated");
    }

    #[test]
    fn crash_window_refuses_then_recovers() {
        let fault = FaultProfile { crash: 1.0, ..FaultProfile::healthy() };
        let params = NetParams { fault, ..NetParams::lan_like() };
        let mut link = NetLink::new(params, 0, 7);
        let Attempt::Down { until } = link.exchange(64, 64, 0.0, 0.0) else {
            panic!("crash=1.0 must enter a window on the first exchange");
        };
        assert!(link.is_down(until / 2.0));
        assert_eq!(link.exchange(64, 64, 0.0, until / 2.0), Attempt::Down { until });
        assert!(!link.is_down(until));
        // After the window the node draws afresh (and crashes again under
        // crash=1.0 — but the standing window is cleared first).
        match link.exchange(64, 64, 0.0, until) {
            Attempt::Down { until: u2 } => assert!(u2 > until, "new window, not the old one"),
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(link.stats.crash_windows, 2);
    }

    #[test]
    fn healthy_link_always_delivers() {
        let mut link = NetLink::new(NetParams::lan_like(), 0, 1);
        for _ in 0..100 {
            match link.exchange(4096, 1024, 1e-6, 0.0) {
                Attempt::Delivered { up, down, dup, reordered } => {
                    assert!(up > 0.0 && down > 0.0);
                    assert!(!dup && !reordered);
                }
                other => panic!("healthy link produced {other:?}"),
            }
        }
        assert_eq!(link.stats.delivered, 100);
        assert_eq!(link.stats.dropped, 0);
    }
}

//! Overlapped asynchronous transport pipeline (the paper's Fig-6(c) gap
//! killer).
//!
//! The prototype's link behaves exactly like Fig 6(c): "an arbitrated
//! resource not always available" — every offloaded batch blocks on the
//! full upload, then executes, then blocks on the full download, so serve
//! throughput is bounded by `transfer + compute`. PCIe is full-duplex and
//! the controller has staging BRAM on both sides of the link, so the
//! overlapped regime is `max(transfer, compute)`: batch *k+1*'s upload and
//! batch *k-1*'s download ride the link while batch *k* streams through
//! the fabric (cf. the overlapped host↔accelerator staging of Cong et
//! al., Best-Effort FPGA Programming).
//!
//! Three pieces, all in virtual f64 seconds (no `Duration` rounding in
//! any model path — sub-microsecond chunk transfers must never quantize
//! to zero):
//!   * [`TransportMode`] — `Sync` (the paper's prototype discipline) or
//!     `Async { depth }` with `depth` in-flight staging buffers per
//!     direction. Conformance diffs the two bit-for-bit: the mode only
//!     ever changes *timing*, never numerics.
//!   * [`ChunkTimeline`] — one invocation's upload/execute/download
//!     schedule over chunked submissions. Shared verbatim by the wrapper
//!     stub (which accounts real transfers) and the promotion model in
//!     `offload::invocation_time` (which feeds it analytic times), so the
//!     model can never drift from what the stub actually charges.
//!   * [`AsyncLink`] — the serve layer's shared full-duplex link: one
//!     occupancy timeline per direction, per-shard staging rings, and the
//!     same per-round batch coalescing as the synchronous
//!     [`super::BatchQueue`].

use std::collections::VecDeque;

use super::{PcieParams, PcieSim};

/// Default in-flight staging buffers per direction (double buffering).
pub const DEFAULT_DEPTH: usize = 2;

/// How the offload stack schedules host↔DFE transfers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportMode {
    /// The paper's prototype: upload → execute → download, strictly
    /// serial, one half-duplex link occupancy at a time.
    Sync,
    /// Double-buffered full-duplex pipeline with `depth` in-flight
    /// staging buffers per direction.
    Async { depth: usize },
}

impl Default for TransportMode {
    fn default() -> Self {
        TransportMode::Sync
    }
}

impl TransportMode {
    /// The production async mode (double buffering).
    pub fn async_default() -> TransportMode {
        TransportMode::Async { depth: DEFAULT_DEPTH }
    }

    pub fn is_async(self) -> bool {
        matches!(self, TransportMode::Async { .. })
    }

    /// Staging depth (1 in sync mode: one buffer, always drained before
    /// the next transfer starts).
    pub fn depth(self) -> usize {
        match self {
            TransportMode::Sync => 1,
            TransportMode::Async { depth } => depth.max(1),
        }
    }

    /// CLI spelling: `sync` | `async` | `async:N`.
    pub fn parse(s: &str) -> Option<TransportMode> {
        match s {
            "sync" => Some(TransportMode::Sync),
            "async" => Some(TransportMode::async_default()),
            _ => {
                let depth: usize = s.strip_prefix("async:")?.parse().ok()?;
                (depth > 0).then_some(TransportMode::Async { depth })
            }
        }
    }
}

impl std::fmt::Display for TransportMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportMode::Sync => write!(f, "sync"),
            TransportMode::Async { depth } => write!(f, "async:{depth}"),
        }
    }
}

/// Chunk plan for one batch of `lanes` stream elements: `(start, len)`
/// slices. Async mode splits into `2 × depth` chunks so the pipeline has
/// work in every stage; sync mode is always one blocking chunk.
pub fn chunk_plan(lanes: usize, mode: TransportMode) -> Vec<(usize, usize)> {
    if lanes == 0 {
        return Vec::new();
    }
    let n_chunks = match mode {
        TransportMode::Sync => 1,
        TransportMode::Async { depth } => (2 * depth.max(1)).min(lanes),
    };
    let chunk = lanes.div_ceil(n_chunks);
    let mut plan = Vec::with_capacity(n_chunks);
    let mut at = 0;
    while at < lanes {
        let m = chunk.min(lanes - at);
        plan.push((at, m));
        at += m;
    }
    plan
}

/// One invocation's (or one request stream's) overlap schedule. Feed it
/// per-chunk upload/execute/download times in seconds; it maintains the
/// three resource timelines (upload direction, fabric, download
/// direction) plus the staging-buffer ring, and accumulates the wall
/// clock. In `Sync` mode the three stages serialize on one timeline —
/// exactly the pre-pipeline behavior.
#[derive(Clone, Debug)]
pub struct ChunkTimeline {
    mode: TransportMode,
    up_free: f64,
    exec_free: f64,
    down_free: f64,
    /// Execution-end times of in-flight chunks; upload `k` may only start
    /// once chunk `k - depth`'s execution has drained its staging buffer.
    exec_ends: VecDeque<f64>,
    /// Total busy time per stage (for reports/asserts).
    pub up_busy: f64,
    pub exec_busy: f64,
    pub down_busy: f64,
    /// Virtual wall clock: completion time of everything scheduled.
    pub wall: f64,
}

impl ChunkTimeline {
    pub fn new(mode: TransportMode) -> ChunkTimeline {
        ChunkTimeline {
            mode,
            up_free: 0.0,
            exec_free: 0.0,
            down_free: 0.0,
            exec_ends: VecDeque::new(),
            up_busy: 0.0,
            exec_busy: 0.0,
            down_busy: 0.0,
            wall: 0.0,
        }
    }

    /// Schedule one chunk: returns its `(upload_end, exec_end,
    /// download_end)` in virtual seconds.
    pub fn step(&mut self, up: f64, exec: f64, down: f64) -> (f64, f64, f64) {
        self.step_ready(up, exec, down, 0.0)
    }

    /// [`Self::step`] with an external readiness gate: the chunk's upload
    /// may not start before `ready` (multi-pass tiled plans: a spilled
    /// intermediate must round-trip through host staging before the next
    /// tile's pass re-uploads it). `ready = 0.0` is exactly `step`.
    pub fn step_ready(&mut self, up: f64, exec: f64, down: f64, ready: f64) -> (f64, f64, f64) {
        self.up_busy += up;
        self.exec_busy += exec;
        self.down_busy += down;
        match self.mode {
            TransportMode::Sync => {
                // One half-duplex occupancy: strictly serial (the wall
                // already covers every earlier download, so the gate only
                // binds when an external event outruns the timeline).
                let u = self.wall.max(ready) + up;
                let e = u + exec;
                let d = e + down;
                self.up_free = u;
                self.exec_free = e;
                self.down_free = d;
                self.wall = d;
                (u, e, d)
            }
            TransportMode::Async { depth } => {
                let depth = depth.max(1);
                // A staging buffer frees when the chunk it held drained
                // through the fabric.
                let stage_ready = if self.exec_ends.len() >= depth {
                    self.exec_ends.pop_front().unwrap_or(0.0)
                } else {
                    0.0
                };
                let up_start = self.up_free.max(stage_ready).max(ready);
                let up_end = up_start + up;
                self.up_free = up_end;
                let exec_start = up_end.max(self.exec_free);
                let exec_end = exec_start + exec;
                self.exec_free = exec_end;
                self.exec_ends.push_back(exec_end);
                let down_start = exec_end.max(self.down_free);
                let down_end = down_start + down;
                self.down_free = down_end;
                self.wall = self.wall.max(down_end);
                (up_end, exec_end, down_end)
            }
        }
    }
}

/// Multi-pass schedule for a tiled execution plan: one [`ChunkTimeline`]
/// carried across tile passes, plus the per-chunk spill round-trip gate.
/// Pass *t*'s chunk *c* re-uploads intermediates that pass *t-1*'s chunk
/// *c* spilled, so its upload may not start before that chunk's download
/// completed — but it *may* (async mode) overlap pass *t-1*'s later
/// chunks still executing or downloading. In sync mode the shared
/// timeline serializes everything, so the plan degenerates to the strict
/// upload→execute→download sum — exactly the single-tile discipline
/// repeated per tile.
#[derive(Clone, Debug)]
pub struct PlanTimeline {
    tl: ChunkTimeline,
    /// Download-end per chunk index of the previous pass.
    prev: Vec<f64>,
    /// Download-ends accumulating for the current pass.
    cur: Vec<f64>,
    /// Chunk index within the current pass.
    chunk: usize,
}

impl PlanTimeline {
    pub fn new(mode: TransportMode) -> PlanTimeline {
        PlanTimeline { tl: ChunkTimeline::new(mode), prev: Vec::new(), cur: Vec::new(), chunk: 0 }
    }

    /// Advance to the next tile pass: the chunks scheduled so far become
    /// the spill gates for the chunks of the pass about to start.
    pub fn next_pass(&mut self) {
        self.prev = std::mem::take(&mut self.cur);
        self.chunk = 0;
    }

    /// Schedule the current pass's next chunk (same return as
    /// [`ChunkTimeline::step`]).
    pub fn step(&mut self, up: f64, exec: f64, down: f64) -> (f64, f64, f64) {
        let ready = self.prev.get(self.chunk).copied().unwrap_or(0.0);
        self.chunk += 1;
        let r = self.tl.step_ready(up, exec, down, ready);
        self.cur.push(r.2);
        r
    }

    pub fn wall(&self) -> f64 {
        self.tl.wall
    }

    pub fn timeline(&self) -> &ChunkTimeline {
        &self.tl
    }
}

/// The serve layer's shared full-duplex link: per-direction occupancy
/// timelines (each direction still pays the arbitration stall baked into
/// the link rate), per-shard staging rings of `depth` buffers, and the
/// same per-round per-shard batch coalescing as [`super::BatchQueue`] —
/// but without the round barrier: a shard's round-*r+1* upload may start
/// while other shards (or the downloads of round *r-1*) still own the
/// opposite direction.
#[derive(Clone, Debug)]
pub struct AsyncLink {
    pub sim: PcieSim,
    pub depth: usize,
    /// Upload / download direction timelines (virtual seconds).
    pub up_free: f64,
    pub down_free: f64,
    /// Per-shard ring of in-flight upload batches' execution-end times.
    stage: Vec<VecDeque<f64>>,
}

impl AsyncLink {
    pub fn new(params: PcieParams, shards: usize, depth: usize) -> AsyncLink {
        assert!(shards > 0, "need at least one shard lane");
        AsyncLink {
            sim: PcieSim::new(params),
            depth: depth.max(1),
            up_free: 0.0,
            down_free: 0.0,
            stage: vec![VecDeque::new(); shards],
        }
    }

    pub fn n_shards(&self) -> usize {
        self.stage.len()
    }

    /// Schedule a coalesced upload batch for `shard` (one setup, summed
    /// framing — the same accounting as `PcieSim::transfer_batch`).
    /// Starts when the upload direction is free, the earliest of the
    /// shard's `depth` staging buffers has drained, and `ready` has
    /// passed. Returns `(start, end)` in virtual seconds; a zero batch is
    /// free and returns `(ready, ready)`.
    pub fn upload(&mut self, shard: usize, payloads: &[u64], ready: f64) -> (f64, f64) {
        let tr = self.sim.transfer_batch(payloads);
        if tr.items == 0 {
            return (ready, ready);
        }
        let stage_ready = if self.stage[shard].len() >= self.depth {
            self.stage[shard].pop_front().unwrap_or(0.0)
        } else {
            0.0
        };
        let start = self.up_free.max(stage_ready).max(ready);
        let end = start + tr.secs;
        self.up_free = end;
        (start, end)
    }

    /// Record that `shard`'s execution consuming its oldest staged upload
    /// finished at `at` (frees that staging buffer for a future upload).
    pub fn retire_exec(&mut self, shard: usize, at: f64) {
        self.stage[shard].push_back(at);
    }

    /// Schedule a coalesced download batch for `shard`, earliest `ready`
    /// (its execution end). Contends only on the download direction.
    pub fn download(&mut self, shard: usize, payloads: &[u64], ready: f64) -> (f64, f64) {
        let tr = self.sim.transfer_batch(payloads);
        if tr.items == 0 {
            return (ready, ready);
        }
        let start = self.down_free.max(ready);
        let end = start + tr.secs;
        self.down_free = end;
        (start, end)
    }
}

/// One remote fleet node's full-duplex link timeline (the per-node
/// sibling of [`AsyncLink`], in the same virtual f64 seconds). The fleet
/// scheduler consults [`NodeTimeline::available`] when placing a request
/// and occupies both directions with [`NodeTimeline::exchange`] once the
/// datagram model (`transport::net`) has decided the exchange's fate —
/// occupancy and fault draws stay separate so a replayed fault schedule
/// never depends on scheduling order.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct NodeTimeline {
    pub up_free: f64,
    pub down_free: f64,
}

impl NodeTimeline {
    pub fn new() -> NodeTimeline {
        NodeTimeline::default()
    }

    /// Earliest time a new exchange could start at `now`.
    pub fn available(&self, now: f64) -> f64 {
        self.up_free.max(now)
    }

    /// Occupy the node for one command→execute→result exchange: `up`
    /// seconds on the command direction, `exec` on the remote fabric,
    /// `down` on the result direction. Returns `(start, done)`.
    pub fn exchange(&mut self, up: f64, exec: f64, down: f64, now: f64) -> (f64, f64) {
        let start = self.available(now);
        self.up_free = start + up;
        let exec_done = start + up + exec;
        let down_start = exec_done.max(self.down_free);
        let done = down_start + down;
        self.down_free = done;
        (start, done)
    }
}

/// Expected datagram transmissions per *delivered* exchange on a link
/// that drops with i.i.d. probability `p`, given at most `retries`
/// retransmissions: the truncated geometric series
/// `1 + p + p² + … + p^retries`. This is the fleet scheduler's
/// transport-aware penalty — a flaky node's modeled exchange time is
/// scaled by it, so flaky nodes lose placements (and promotions) to
/// healthy ones even when their raw link is idle.
pub fn expected_sends(p: f64, retries: u32) -> f64 {
    let p = p.clamp(0.0, 1.0);
    if p >= 1.0 {
        return (retries + 1) as f64;
    }
    (1.0 - p.powi(retries as i32 + 1)) / (1.0 - p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parses_and_prints() {
        assert_eq!(TransportMode::parse("sync"), Some(TransportMode::Sync));
        assert_eq!(
            TransportMode::parse("async"),
            Some(TransportMode::Async { depth: DEFAULT_DEPTH })
        );
        assert_eq!(TransportMode::parse("async:4"), Some(TransportMode::Async { depth: 4 }));
        assert_eq!(TransportMode::parse("async:0"), None);
        assert_eq!(TransportMode::parse("bogus"), None);
        assert_eq!(TransportMode::Async { depth: 3 }.to_string(), "async:3");
        assert_eq!(TransportMode::Sync.depth(), 1);
    }

    #[test]
    fn chunk_plan_covers_exactly_once() {
        for lanes in [0usize, 1, 3, 7, 256, 1000] {
            for mode in [TransportMode::Sync, TransportMode::Async { depth: 2 }] {
                let plan = chunk_plan(lanes, mode);
                let total: usize = plan.iter().map(|&(_, m)| m).sum();
                assert_eq!(total, lanes, "lanes {lanes} mode {mode}");
                let mut at = 0;
                for &(start, m) in &plan {
                    assert_eq!(start, at);
                    assert!(m > 0);
                    at += m;
                }
                if mode == TransportMode::Sync && lanes > 0 {
                    assert_eq!(plan.len(), 1, "sync is one blocking chunk");
                }
            }
        }
        assert_eq!(chunk_plan(1000, TransportMode::Async { depth: 2 }).len(), 4);
    }

    #[test]
    fn sync_timeline_is_the_serial_sum() {
        let mut tl = ChunkTimeline::new(TransportMode::Sync);
        tl.step(10.0, 2.0, 5.0);
        tl.step(10.0, 2.0, 5.0);
        assert_eq!(tl.wall, 34.0);
        assert_eq!(tl.up_busy, 20.0);
    }

    #[test]
    fn async_timeline_overlaps_transfer_and_compute() {
        // Transfer-bound: upload 10, exec 2, download 5 per chunk, 4
        // chunks. Sync = 4·17 = 68; async = upload chain 40 + last exec 2
        // + last download 5 = 47 (downloads hide under later uploads).
        let mut sync = ChunkTimeline::new(TransportMode::Sync);
        let mut pipe = ChunkTimeline::new(TransportMode::Async { depth: 2 });
        for _ in 0..4 {
            sync.step(10.0, 2.0, 5.0);
            pipe.step(10.0, 2.0, 5.0);
        }
        assert_eq!(sync.wall, 68.0);
        assert_eq!(pipe.wall, 47.0);
        assert!(pipe.wall >= pipe.up_busy, "the link is one resource per direction");
    }

    #[test]
    fn async_timeline_respects_staging_depth() {
        // Compute-bound (exec 100 ≫ upload 1): with depth 1 the next
        // upload waits for the previous exec to drain its only buffer, so
        // uploads serialize behind execs; with depth 2 they pre-stage.
        let mut single = ChunkTimeline::new(TransportMode::Async { depth: 1 });
        let mut double = ChunkTimeline::new(TransportMode::Async { depth: 2 });
        for _ in 0..3 {
            single.step(1.0, 100.0, 1.0);
            double.step(1.0, 100.0, 1.0);
        }
        // depth 2: execs back-to-back -> 1 + 300 + 1.
        assert_eq!(double.wall, 302.0);
        // depth 1: upload k starts at exec k-1 end -> fill shifts by 1s each.
        assert!(single.wall > double.wall);
        // Both are still far better than sync (306).
        assert!(single.wall < 306.0);
    }

    #[test]
    fn step_ready_zero_gate_is_exactly_step() {
        for mode in [TransportMode::Sync, TransportMode::Async { depth: 2 }] {
            let mut a = ChunkTimeline::new(mode);
            let mut b = ChunkTimeline::new(mode);
            for (u, e, d) in [(10.0, 2.0, 5.0), (1.0, 9.0, 3.0), (4.0, 4.0, 4.0)] {
                assert_eq!(a.step(u, e, d), b.step_ready(u, e, d, 0.0));
            }
            assert_eq!(a.wall, b.wall);
        }
    }

    #[test]
    fn plan_timeline_gates_reupload_on_spill_roundtrip() {
        // Download-bound chunks (up 1, exec 1, down 10): pass 1's chunk 0
        // re-uploads pass 0 chunk 0's spill, so it must wait for that
        // download (ends at 12) even though the upload direction and the
        // staging ring are free at t = 2.
        let mut plan = PlanTimeline::new(TransportMode::Async { depth: 2 });
        let (_, _, d0) = plan.step(1.0, 1.0, 10.0);
        assert_eq!(d0, 12.0);
        let (_, _, d1) = plan.step(1.0, 1.0, 10.0);
        assert_eq!(d1, 22.0);
        plan.next_pass();
        let (u, e, d) = plan.step(1.0, 1.0, 10.0);
        assert_eq!(u, 13.0, "upload gated on the spill download at 12");
        assert_eq!(e, 14.0);
        assert_eq!(d, 32.0, "download direction still serializes");
        // Ungated, the same chunk's upload would have ended at 3.
        let mut free = ChunkTimeline::new(TransportMode::Async { depth: 2 });
        free.step(1.0, 1.0, 10.0);
        free.step(1.0, 1.0, 10.0);
        assert_eq!(free.step(1.0, 1.0, 10.0).0, 3.0);
    }

    #[test]
    fn multi_pass_async_never_loses_to_sync() {
        // Two passes of three chunks in both disciplines: the async plan
        // overlaps pass 1's uploads with pass 0's tail, sync repeats the
        // strict serial sum per tile.
        let run = |mode| {
            let mut plan = PlanTimeline::new(mode);
            for _ in 0..3 {
                plan.step(10.0, 2.0, 5.0);
            }
            plan.next_pass();
            for _ in 0..3 {
                plan.step(10.0, 2.0, 5.0);
            }
            plan.wall()
        };
        let sync = run(TransportMode::Sync);
        let pipe = run(TransportMode::async_default());
        assert_eq!(sync, 102.0, "6 chunks x 17s strictly serial");
        assert!(pipe < sync, "multi-pass overlap must win: {pipe} vs {sync}");
        assert!(pipe >= 60.0, "the upload direction alone is 60s of work");
    }

    #[test]
    fn async_link_full_duplex_overlaps_directions() {
        let params = PcieParams::default();
        let mut link = AsyncLink::new(params, 2, 2);
        let (u0s, u0e) = link.upload(0, &[1 << 20], 0.0);
        assert_eq!(u0s, 0.0);
        link.retire_exec(0, u0e + 1e-6);
        // A download scheduled while the next upload owns the up
        // direction starts immediately: the directions are independent.
        let (u1s, _u1e) = link.upload(1, &[1 << 20], 0.0);
        assert_eq!(u1s, u0e, "uploads serialize on the up direction");
        let (d0s, d0e) = link.download(0, &[1 << 20], u0e + 1e-6);
        assert!(d0s < link.up_free, "download overlaps the in-flight upload");
        assert_eq!(link.down_free, d0e);
        // Accounting flows through the shared PcieSim core.
        assert_eq!(link.sim.transfers, 3);
        assert_eq!(link.sim.total_payload, 3 << 20);
    }

    #[test]
    fn async_link_staging_ring_throttles_runaway_uploads() {
        let params = PcieParams::default();
        let mut link = AsyncLink::new(params, 1, 1);
        let (_, e0) = link.upload(0, &[4096], 0.0);
        // Buffer not yet retired: the ring is empty so the second upload
        // only waits on the direction...
        let (s1, _) = link.upload(0, &[4096], 0.0);
        assert_eq!(s1, e0);
        // ...but once depth uploads are in flight, the third waits for the
        // first execution to retire.
        link.retire_exec(0, 10.0);
        link.retire_exec(0, 20.0);
        let (s2, _) = link.upload(0, &[4096], 0.0);
        assert_eq!(s2, 10.0, "staging buffer frees at the retired exec end");
    }

    #[test]
    fn empty_upload_is_free_and_unscheduled() {
        let mut link = AsyncLink::new(PcieParams::default(), 1, 2);
        let (s, e) = link.upload(0, &[], 3.0);
        assert_eq!((s, e), (3.0, 3.0));
        let (s, e) = link.download(0, &[0, 0], 5.0);
        assert_eq!((s, e), (5.0, 5.0));
        assert_eq!(link.sim.transfers, 0);
        assert_eq!(link.up_free, 0.0);
    }

    #[test]
    fn node_timeline_serializes_exchanges_full_duplex() {
        let mut tl = NodeTimeline::new();
        let (s0, d0) = tl.exchange(2.0, 1.0, 3.0, 0.0);
        assert_eq!((s0, d0), (0.0, 6.0));
        // The next exchange starts when the up direction frees (t=2), its
        // download waits behind the first result flight.
        let (s1, d1) = tl.exchange(2.0, 1.0, 3.0, 0.0);
        assert_eq!(s1, 2.0);
        assert_eq!(d1, 9.0, "down direction is one resource");
        assert_eq!(tl.available(100.0), 100.0);
    }

    #[test]
    fn expected_sends_is_monotone_and_bounded() {
        assert_eq!(expected_sends(0.0, 4), 1.0);
        assert_eq!(expected_sends(1.0, 4), 5.0);
        let mut prev = 0.0;
        for i in 0..=10 {
            let p = i as f64 / 10.0;
            let e = expected_sends(p, 3);
            assert!(e >= prev, "monotone in p: {e} < {prev}");
            assert!((1.0..=4.0).contains(&e));
            prev = e;
        }
        // More retry budget, more expected sends on a lossy link.
        assert!(expected_sends(0.5, 6) > expected_sends(0.5, 1));
    }
}

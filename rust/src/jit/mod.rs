//! "JIT" execution layer: IR → bytecode lowering, threaded interpreter
//! with perf counters, and the patchable call table the offload manager
//! uses to redirect hot functions (paper Fig 1).
pub mod bytecode;
pub mod engine;
pub mod interp;
pub use bytecode::{compile_fn, Bc, CompileError, CompiledFn};
pub use engine::{Engine, EngineError, FnProfile, Histogram, Hook};
pub use interp::{ArrayBuf, FnCounters, Frame, Memory, Trap, Val};

//! Threaded interpreter + host memory model.
//!
//! Executes [`super::bytecode::CompiledFn`] bodies over a register frame,
//! updating per-function performance counters (abstract cycles + memory
//! accesses) that the monitor consumes — the stand-in for `perf_event`.

use std::fmt;

use crate::ir::instr::{BinOp, CmpPred};

use super::bytecode::{Bc, CompiledFn};

/// Runtime value. The baseline uses a tagged enum; the §Perf pass keeps it
/// because dispatch, not tagging, dominates (see EXPERIMENTS.md §Perf).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Val {
    I(i32),
    F(f32),
    /// Array handle into [`Memory`].
    P(u32),
    Undef,
}

impl Val {
    #[inline]
    pub fn as_i32(self) -> i32 {
        match self {
            Val::I(v) => v,
            Val::F(v) => v as i32,
            Val::P(v) => v as i32,
            Val::Undef => 0,
        }
    }

    #[inline]
    pub fn as_f32(self) -> f32 {
        match self {
            Val::F(v) => v,
            Val::I(v) => v as f32,
            _ => 0.0,
        }
    }

    #[inline]
    pub fn as_ptr(self) -> u32 {
        match self {
            Val::P(v) => v,
            Val::I(v) => v as u32,
            _ => u32::MAX,
        }
    }
}

/// Typed array buffer.
#[derive(Clone, Debug)]
pub enum ArrayBuf {
    I32(Vec<i32>),
    F32(Vec<f32>),
}

impl ArrayBuf {
    pub fn len(&self) -> usize {
        match self {
            ArrayBuf::I32(v) => v.len(),
            ArrayBuf::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Host memory pool: arrays addressed by handle (the `Ptr` values).
#[derive(Clone, Debug, Default)]
pub struct Memory {
    pub arrays: Vec<ArrayBuf>,
}

impl Memory {
    pub fn new() -> Memory {
        Memory::default()
    }

    pub fn alloc_i32(&mut self, len: usize) -> u32 {
        self.arrays.push(ArrayBuf::I32(vec![0; len]));
        self.arrays.len() as u32 - 1
    }

    pub fn alloc_f32(&mut self, len: usize) -> u32 {
        self.arrays.push(ArrayBuf::F32(vec![0.0; len]));
        self.arrays.len() as u32 - 1
    }

    pub fn from_i32(&mut self, data: &[i32]) -> u32 {
        self.arrays.push(ArrayBuf::I32(data.to_vec()));
        self.arrays.len() as u32 - 1
    }

    pub fn i32s(&self, h: u32) -> &[i32] {
        match &self.arrays[h as usize] {
            ArrayBuf::I32(v) => v,
            _ => panic!("array {h} is not i32"),
        }
    }

    pub fn i32s_mut(&mut self, h: u32) -> &mut Vec<i32> {
        match &mut self.arrays[h as usize] {
            ArrayBuf::I32(v) => v,
            _ => panic!("array {h} is not i32"),
        }
    }

    pub fn f32s(&self, h: u32) -> &[f32] {
        match &self.arrays[h as usize] {
            ArrayBuf::F32(v) => v,
            _ => panic!("array {h} is not f32"),
        }
    }

    pub fn f32s_mut(&mut self, h: u32) -> &mut Vec<f32> {
        match &mut self.arrays[h as usize] {
            ArrayBuf::F32(v) => v,
            _ => panic!("array {h} is not f32"),
        }
    }

    #[inline]
    fn load_i32(&self, h: u32, idx: i32) -> Result<i32, Trap> {
        let a = self.arrays.get(h as usize).ok_or(Trap::BadHandle(h))?;
        match a {
            ArrayBuf::I32(v) => v
                .get(idx as usize)
                .copied()
                .ok_or(Trap::OutOfBounds { handle: h, idx, len: v.len() }),
            ArrayBuf::F32(_) => Err(Trap::TypeMismatch(h)),
        }
    }

    #[inline]
    fn load_f32(&self, h: u32, idx: i32) -> Result<f32, Trap> {
        let a = self.arrays.get(h as usize).ok_or(Trap::BadHandle(h))?;
        match a {
            ArrayBuf::F32(v) => v
                .get(idx as usize)
                .copied()
                .ok_or(Trap::OutOfBounds { handle: h, idx, len: v.len() }),
            ArrayBuf::I32(_) => Err(Trap::TypeMismatch(h)),
        }
    }
}

/// Execution trap.
#[derive(Debug, Clone, PartialEq)]
pub enum Trap {
    BadHandle(u32),
    OutOfBounds { handle: u32, idx: i32, len: usize },
    TypeMismatch(u32),
    DivByZero,
    /// Fuel exhausted (runaway-loop guard in tests).
    OutOfFuel,
}

impl fmt::Display for Trap {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Trap::BadHandle(h) => write!(f, "bad array handle {h}"),
            Trap::OutOfBounds { handle, idx, len } => {
                write!(f, "index {idx} out of bounds for array {handle} (len {len})")
            }
            Trap::TypeMismatch(h) => write!(f, "array {h} accessed with wrong type"),
            Trap::DivByZero => write!(f, "integer division by zero"),
            Trap::OutOfFuel => write!(f, "execution fuel exhausted"),
        }
    }
}

impl std::error::Error for Trap {}

/// Per-function performance counters (the perf_event substitute).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FnCounters {
    pub invocations: u64,
    pub cycles: u64,
    pub mem_accesses: u64,
    pub insts: u64,
    /// Backward jumps taken (loop back-edges). Per completed invocation
    /// this is the observed trip count of the function's loops — the
    /// signal the adaptive respecialization controller buckets to choose
    /// unroll factors (`offload::adapt`).
    pub loop_trips: u64,
}

/// A request to run a callee made from inside the interpreter; the engine
/// dispatches it through the patchable call table.
pub struct CallRequest {
    pub func: u32,
    pub args: Vec<Val>,
}

/// Outcome of running a body: returned value or a nested call to perform.
pub enum RunOutcome {
    Done(Option<Val>),
    /// Hit a Call at `pc`: engine must execute it, write the result into
    /// `dst`, then resume at `pc + 1`.
    NeedCall { pc: u32, req: CallRequest, dst: Option<u32> },
}

/// Interpreter state for one frame (resumable across calls).
pub struct Frame {
    pub slots: Vec<Val>,
    pub pc: u32,
    pub counters: FnCounters,
}

impl Frame {
    pub fn new(f: &CompiledFn, args: &[Val]) -> Frame {
        assert_eq!(args.len(), f.n_params, "{}: arg count", f.name);
        let mut slots = vec![Val::Undef; f.n_slots as usize];
        slots[..args.len()].copy_from_slice(args);
        Frame { slots, pc: 0, counters: FnCounters { invocations: 1, ..Default::default() } }
    }

    /// Interpret until return, trap, fuel exhaustion or a `Call`.
    pub fn run(
        &mut self,
        f: &CompiledFn,
        mem: &mut Memory,
        fuel: &mut u64,
    ) -> Result<RunOutcome, Trap> {
        macro_rules! slot {
            ($i:expr) => {
                self.slots[$i as usize]
            };
        }
        // §Perf note: accumulating these counters in locals and flushing
        // on exit was tried and measured at <5% (slightly negative) — the
        // struct stores stay (EXPERIMENTS.md §Perf iteration log).
        loop {
            if *fuel == 0 {
                return Err(Trap::OutOfFuel);
            }
            let bc = &f.code[self.pc as usize];
            *fuel -= 1;
            self.counters.insts += 1;
            self.counters.cycles += bc.cost();
            if bc.is_mem() {
                self.counters.mem_accesses += 1;
            }
            match bc {
                Bc::ConstI32 { dst, v } => slot!(*dst) = Val::I(*v),
                Bc::ConstF32 { dst, v } => slot!(*dst) = Val::F(*v),
                Bc::BinI32 { dst, op, a, b } => {
                    let (x, y) = (slot!(*a).as_i32(), slot!(*b).as_i32());
                    let r = match op {
                        BinOp::Add => x.wrapping_add(y),
                        BinOp::Sub => x.wrapping_sub(y),
                        BinOp::Mul => x.wrapping_mul(y),
                        BinOp::Div => {
                            if y == 0 {
                                return Err(Trap::DivByZero);
                            }
                            x.wrapping_div(y)
                        }
                        BinOp::Rem => {
                            if y == 0 {
                                return Err(Trap::DivByZero);
                            }
                            x.wrapping_rem(y)
                        }
                        BinOp::Min => x.min(y),
                        BinOp::Max => x.max(y),
                        BinOp::And => x & y,
                        BinOp::Or => x | y,
                        BinOp::Xor => x ^ y,
                        BinOp::Shl => x.wrapping_shl(y.clamp(0, 31) as u32),
                        BinOp::Shr => x.wrapping_shr(y.clamp(0, 31) as u32),
                    };
                    slot!(*dst) = Val::I(r);
                }
                Bc::BinF32 { dst, op, a, b } => {
                    let (x, y) = (slot!(*a).as_f32(), slot!(*b).as_f32());
                    let r = match op {
                        BinOp::Add => x + y,
                        BinOp::Sub => x - y,
                        BinOp::Mul => x * y,
                        BinOp::Div => x / y,
                        BinOp::Rem => x % y,
                        BinOp::Min => x.min(y),
                        BinOp::Max => x.max(y),
                        _ => f32::NAN, // bitwise on f32 is not authorable
                    };
                    slot!(*dst) = Val::F(r);
                }
                Bc::CmpI32 { dst, pred, a, b } => {
                    let r = pred.eval_i32(slot!(*a).as_i32(), slot!(*b).as_i32());
                    slot!(*dst) = Val::I(r as i32);
                }
                Bc::CmpF32 { dst, pred, a, b } => {
                    let r = pred.eval_f32(slot!(*a).as_f32(), slot!(*b).as_f32());
                    slot!(*dst) = Val::I(r as i32);
                }
                Bc::Select { dst, c, t, f: fv } => {
                    slot!(*dst) = if slot!(*c).as_i32() != 0 { slot!(*t) } else { slot!(*fv) };
                }
                Bc::LoadI32 { dst, base, idx } => {
                    let v = mem.load_i32(slot!(*base).as_ptr(), slot!(*idx).as_i32())?;
                    slot!(*dst) = Val::I(v);
                }
                Bc::LoadF32 { dst, base, idx } => {
                    let v = mem.load_f32(slot!(*base).as_ptr(), slot!(*idx).as_i32())?;
                    slot!(*dst) = Val::F(v);
                }
                Bc::StoreI32 { base, idx, val } => {
                    let (h, i, v) =
                        (slot!(*base).as_ptr(), slot!(*idx).as_i32(), slot!(*val).as_i32());
                    let arr = mem.arrays.get_mut(h as usize).ok_or(Trap::BadHandle(h))?;
                    match arr {
                        ArrayBuf::I32(vec) => {
                            let len = vec.len();
                            *vec.get_mut(i as usize).ok_or(Trap::OutOfBounds {
                                handle: h,
                                idx: i,
                                len,
                            })? = v;
                        }
                        ArrayBuf::F32(_) => return Err(Trap::TypeMismatch(h)),
                    }
                }
                Bc::StoreF32 { base, idx, val } => {
                    let (h, i, v) =
                        (slot!(*base).as_ptr(), slot!(*idx).as_i32(), slot!(*val).as_f32());
                    let arr = mem.arrays.get_mut(h as usize).ok_or(Trap::BadHandle(h))?;
                    match arr {
                        ArrayBuf::F32(vec) => {
                            let len = vec.len();
                            *vec.get_mut(i as usize).ok_or(Trap::OutOfBounds {
                                handle: h,
                                idx: i,
                                len,
                            })? = v;
                        }
                        ArrayBuf::I32(_) => return Err(Trap::TypeMismatch(h)),
                    }
                }
                Bc::IToF { dst, a } => slot!(*dst) = Val::F(slot!(*a).as_i32() as f32),
                Bc::FToI { dst, a } => slot!(*dst) = Val::I(slot!(*a).as_f32() as i32),
                Bc::Mov { dst, a } => slot!(*dst) = slot!(*a),
                Bc::Call { dst, func, args } => {
                    let req = CallRequest {
                        func: *func,
                        args: args.iter().map(|&a| slot!(a)).collect(),
                    };
                    return Ok(RunOutcome::NeedCall { pc: self.pc, req, dst: *dst });
                }
                Bc::Syscall => { /* opaque host effect; cost accounted */ }
                Bc::Jmp { to } => {
                    if *to <= self.pc {
                        self.counters.loop_trips += 1;
                    }
                    self.pc = *to;
                    continue;
                }
                Bc::JmpIf { c, t, f: fb } => {
                    let target = if slot!(*c).as_i32() != 0 { *t } else { *fb };
                    if target <= self.pc {
                        self.counters.loop_trips += 1;
                    }
                    self.pc = target;
                    continue;
                }
                Bc::Ret { v } => {
                    return Ok(RunOutcome::Done(v.map(|r| slot!(r))));
                }
            }
            self.pc += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::func::FuncBuilder;
    use crate::ir::instr::Ty;
    use crate::jit::bytecode::compile_fn;

    fn run_simple(f: &crate::ir::func::Function, mem: &mut Memory, args: &[Val]) -> Option<Val> {
        let c = compile_fn(f, &|_| None).unwrap();
        let mut frame = Frame::new(&c, args);
        let mut fuel = u64::MAX;
        match frame.run(&c, mem, &mut fuel).unwrap() {
            RunOutcome::Done(v) => v,
            _ => panic!("unexpected call"),
        }
    }

    #[test]
    fn loop_sum() {
        // sum = 0; for i in 0..n { sum += A[i] }; return sum
        let mut b = FuncBuilder::new("sum", &[("A", Ty::Ptr), ("n", Ty::I32)]);
        let (a, n) = (b.param(0), b.param(1));
        let acc = b.const_i32(0);
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, i| {
            let v = b.load(Ty::I32, a, i);
            let s = b.add(acc, v);
            b.mov_into(acc, s);
        });
        let f = b.ret(Some(acc));
        let mut mem = Memory::new();
        let h = mem.from_i32(&[1, 2, 3, 4, 5]);
        let out = run_simple(&f, &mut mem, &[Val::P(h), Val::I(5)]);
        assert_eq!(out, Some(Val::I(15)));
    }

    #[test]
    fn counters_accumulate() {
        let mut b = FuncBuilder::new("k", &[("A", Ty::Ptr), ("n", Ty::I32)]);
        let (a, n) = (b.param(0), b.param(1));
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, i| {
            let v = b.load(Ty::I32, a, i);
            let w = b.add(v, v);
            b.store(Ty::I32, a, i, w);
        });
        let f = b.ret(None);
        let c = compile_fn(&f, &|_| None).unwrap();
        let mut mem = Memory::new();
        let h = mem.alloc_i32(10);
        let mut frame = Frame::new(&c, &[Val::P(h), Val::I(10)]);
        let mut fuel = u64::MAX;
        frame.run(&c, &mut mem, &mut fuel).unwrap();
        assert_eq!(frame.counters.mem_accesses, 20); // 10 loads + 10 stores
        assert!(frame.counters.cycles > frame.counters.insts);
        assert_eq!(frame.counters.invocations, 1);
        assert_eq!(frame.counters.loop_trips, 10, "one back-edge per iteration");
    }

    #[test]
    fn traps_out_of_bounds() {
        let mut b = FuncBuilder::new("oob", &[("A", Ty::Ptr)]);
        let a = b.param(0);
        let idx = b.const_i32(99);
        let _ = b.load(Ty::I32, a, idx);
        let f = b.ret(None);
        let c = compile_fn(&f, &|_| None).unwrap();
        let mut mem = Memory::new();
        let h = mem.alloc_i32(4);
        let mut frame = Frame::new(&c, &[Val::P(h)]);
        let mut fuel = u64::MAX;
        let r = frame.run(&c, &mut mem, &mut fuel).err();
        assert!(matches!(r, Some(Trap::OutOfBounds { idx: 99, .. })));
    }

    #[test]
    fn traps_div_by_zero() {
        use crate::ir::instr::BinOp;
        let mut b = FuncBuilder::new("d0", &[]);
        let x = b.const_i32(1);
        let z = b.const_i32(0);
        let _ = b.bin(BinOp::Div, Ty::I32, x, z);
        let f = b.ret(None);
        let c = compile_fn(&f, &|_| None).unwrap();
        let mut mem = Memory::new();
        let mut frame = Frame::new(&c, &[]);
        let mut fuel = u64::MAX;
        assert_eq!(frame.run(&c, &mut mem, &mut fuel).err(), Some(Trap::DivByZero));
    }

    #[test]
    fn fuel_guard() {
        // Infinite loop trips OutOfFuel instead of hanging.
        use crate::ir::instr::{BlockId, Term};
        let mut b = FuncBuilder::new("spin", &[]);
        b.terminate(Term::Br(BlockId(0)));
        let f = b.finish();
        let c = compile_fn(&f, &|_| None).unwrap();
        let mut mem = Memory::new();
        let mut frame = Frame::new(&c, &[]);
        let mut fuel = 1000;
        assert_eq!(frame.run(&c, &mut mem, &mut fuel).err(), Some(Trap::OutOfFuel));
    }

    #[test]
    fn f32_arithmetic() {
        let mut b = FuncBuilder::new("faddk", &[("A", Ty::Ptr)]);
        let a = b.param(0);
        let i0 = b.const_i32(0);
        let v = b.load(Ty::F32, a, i0);
        let w = b.fmul(v, v);
        b.store(Ty::F32, a, i0, w);
        let f = b.ret(None);
        let c = compile_fn(&f, &|_| None).unwrap();
        let mut mem = Memory::new();
        let h = mem.alloc_f32(1);
        mem.f32s_mut(h)[0] = 1.5;
        let mut frame = Frame::new(&c, &[Val::P(h)]);
        let mut fuel = u64::MAX;
        frame.run(&c, &mut mem, &mut fuel).unwrap();
        assert!((mem.f32s(h)[0] - 2.25).abs() < 1e-6);
    }
}

//! Execution engine: compiled-function store + the *patchable call table*.
//!
//! The table is the paper's redirect mechanism: "the run-time replaces all
//! calls to the host processor function with a wrapper stub that handles
//! all memory transfers to and from the FPGA". Here every call — including
//! top-level dispatch — goes through `CallTarget`; the offload manager
//! swaps a function's entry for a hook and can swap it back on rollback,
//! transparently to all callers.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::ir::func::Module;
use crate::ir::verify::verify_module;

use super::bytecode::{compile_fn, CompiledFn};
use super::interp::{FnCounters, Frame, Memory, RunOutcome, Trap, Val};

/// A host-side hook standing in for native/offloaded code.
pub type Hook = Box<dyn FnMut(&mut Memory, &[Val]) -> Result<Option<Val>, Trap>>;

enum CallTarget {
    Bytecode(usize),
    Hook(Hook),
}

/// Per-function profile row (counters + wall time), read by the monitor.
#[derive(Clone, Copy, Debug, Default)]
pub struct FnProfile {
    pub counters: FnCounters,
    pub wall: Duration,
}

/// Bucket count for [`Histogram`]: bucket 0 holds the value 0, bucket k
/// holds `[2^(k-1), 2^k)`, the top bucket absorbs everything above.
pub const HIST_BUCKETS: usize = 33;

/// Log2-bucketed histogram of per-invocation observations (trip counts,
/// offloaded batch sizes). Cheap enough to update on every completed
/// invocation; the adaptive respecialization controller reads the
/// dominant bucket to pick unroll factors and tier boundaries.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Histogram {
    pub buckets: [u64; HIST_BUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { buckets: [0; HIST_BUCKETS] }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Bucket index for a value (0 → 0, otherwise `1 + floor(log2 v)`).
    pub fn bucket_of(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            (64 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1)
        }
    }

    /// Smallest value falling into bucket `b` (its representative).
    pub fn bucket_floor(b: usize) -> u64 {
        if b == 0 {
            0
        } else {
            1u64 << (b - 1)
        }
    }

    pub fn record(&mut self, v: u64) {
        self.record_n(v, 1);
    }

    pub fn record_n(&mut self, v: u64, n: u64) {
        self.buckets[Self::bucket_of(v)] += n;
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.total() == 0
    }

    /// Most-populated bucket (ties resolve to the larger bucket, i.e. the
    /// larger observed values — the safer side for unroll decisions).
    pub fn dominant_bucket(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c > 0 && best.map(|b| c >= self.buckets[b]).unwrap_or(true) {
                best = Some(i);
            }
        }
        best
    }

    /// Representative (floor) of the dominant bucket; 0 when empty.
    pub fn dominant_floor(&self) -> u64 {
        self.dominant_bucket().map(Self::bucket_floor).unwrap_or(0)
    }

    pub fn clear(&mut self) {
        self.buckets = [0; HIST_BUCKETS];
    }
}

#[derive(Debug)]
pub enum EngineError {
    Verify(String),
    Compile(String),
    UnknownFunction(String),
    Trap(Trap),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::Verify(e) => write!(f, "verify: {e}"),
            EngineError::Compile(e) => write!(f, "compile: {e}"),
            EngineError::UnknownFunction(n) => write!(f, "unknown function @{n}"),
            EngineError::Trap(t) => write!(f, "trap: {t}"),
        }
    }
}

impl std::error::Error for EngineError {}

pub struct Engine {
    pub module: Module,
    compiled: Vec<CompiledFn>,
    table: Vec<CallTarget>,
    name_to_idx: HashMap<String, u32>,
    profiles: Vec<FnProfile>,
    /// Per-function trip-count histograms: one observation (the frame's
    /// back-edge count) per completed bytecode invocation. Offloaded
    /// (hook) invocations are tracked as batch-size histograms by the
    /// stub's `RuntimeState` instead.
    trip_hists: Vec<Histogram>,
    /// JIT-compile wall time per function (Fig 6 phase 2).
    pub jit_times: Vec<Duration>,
    /// Execution fuel ceiling per top-level call (tests override).
    pub fuel_limit: u64,
}

impl Engine {
    /// Verify, "JIT-compile" (lower to bytecode) and index every function.
    pub fn new(module: Module) -> Result<Engine, EngineError> {
        verify_module(&module).map_err(|(f, e)| EngineError::Verify(format!("@{f}: {e}")))?;
        let name_to_idx: HashMap<String, u32> = module
            .funcs
            .iter()
            .enumerate()
            .map(|(i, f)| (f.name.clone(), i as u32))
            .collect();
        let mut compiled = Vec::with_capacity(module.funcs.len());
        let mut jit_times = Vec::with_capacity(module.funcs.len());
        for f in &module.funcs {
            let t0 = Instant::now();
            let resolve = |name: &str| name_to_idx.get(name).copied();
            let c = compile_fn(f, &resolve).map_err(|e| EngineError::Compile(e.to_string()))?;
            jit_times.push(t0.elapsed());
            compiled.push(c);
        }
        let table = (0..compiled.len()).map(CallTarget::Bytecode).collect();
        let profiles = vec![FnProfile::default(); compiled.len()];
        let trip_hists = vec![Histogram::default(); compiled.len()];
        Ok(Engine {
            module,
            compiled,
            table,
            name_to_idx,
            profiles,
            trip_hists,
            jit_times,
            fuel_limit: u64::MAX,
        })
    }

    pub fn func_index(&self, name: &str) -> Option<u32> {
        self.name_to_idx.get(name).copied()
    }

    pub fn func_name(&self, idx: u32) -> &str {
        &self.compiled[idx as usize].name
    }

    pub fn n_funcs(&self) -> usize {
        self.compiled.len()
    }

    pub fn compiled_fn(&self, idx: u32) -> &CompiledFn {
        &self.compiled[idx as usize]
    }

    /// Redirect `func` to a hook (offload stub). Returns the previous kind
    /// ("bytecode" or "hook") for bookkeeping.
    pub fn patch_hook(&mut self, func: u32, hook: Hook) -> &'static str {
        let prev = match self.table[func as usize] {
            CallTarget::Bytecode(_) => "bytecode",
            CallTarget::Hook(_) => "hook",
        };
        self.table[func as usize] = CallTarget::Hook(hook);
        prev
    }

    /// Restore the original bytecode entry (rollback).
    pub fn unpatch(&mut self, func: u32) {
        self.table[func as usize] = CallTarget::Bytecode(func as usize);
    }

    pub fn is_patched(&self, func: u32) -> bool {
        matches!(self.table[func as usize], CallTarget::Hook(_))
    }

    /// Profile row (counters summed over completed invocations).
    pub fn profile(&self, func: u32) -> FnProfile {
        self.profiles[func as usize]
    }

    pub fn reset_profiles(&mut self) {
        for p in &mut self.profiles {
            *p = FnProfile::default();
        }
    }

    /// Snapshot-and-reset one function's profile row. Called by the
    /// offload manager at call-table patch time so the monitor only ever
    /// sees post-patch data — pre-offload interpreter samples must not
    /// pollute post-offload wall-time averages.
    pub fn take_profile(&mut self, func: u32) -> FnProfile {
        std::mem::take(&mut self.profiles[func as usize])
    }

    /// Per-invocation loop-trip histogram observed for `func` (bytecode
    /// invocations only).
    pub fn trip_hist(&self, func: u32) -> &Histogram {
        &self.trip_hists[func as usize]
    }

    /// Call a function by name.
    pub fn call(
        &mut self,
        name: &str,
        mem: &mut Memory,
        args: &[Val],
    ) -> Result<Option<Val>, EngineError> {
        let idx = self
            .func_index(name)
            .ok_or_else(|| EngineError::UnknownFunction(name.to_string()))?;
        self.call_idx(idx, mem, args)
    }

    /// Call through the patchable table (what `Bc::Call` also uses).
    pub fn call_idx(
        &mut self,
        func: u32,
        mem: &mut Memory,
        args: &[Val],
    ) -> Result<Option<Val>, EngineError> {
        let mut fuel = self.fuel_limit;
        self.dispatch(func, mem, args, &mut fuel).map_err(EngineError::Trap)
    }

    fn dispatch(
        &mut self,
        func: u32,
        mem: &mut Memory,
        args: &[Val],
        fuel: &mut u64,
    ) -> Result<Option<Val>, Trap> {
        match &mut self.table[func as usize] {
            CallTarget::Hook(h) => {
                // Hooks account wall time but no interpreter counters.
                let t0 = Instant::now();
                let r = h(mem, args);
                self.profiles[func as usize].wall += t0.elapsed();
                self.profiles[func as usize].counters.invocations += 1;
                r
            }
            CallTarget::Bytecode(cidx) => {
                let cidx = *cidx;
                let t0 = Instant::now();
                // Clone nothing: run the frame, pausing on nested calls.
                let compiled = &self.compiled[cidx];
                let mut frame = Frame::new(compiled, args);
                let result = loop {
                    // Split borrows: frame.run needs &CompiledFn while we
                    // hold &mut self for nested dispatch, so re-fetch per
                    // iteration and keep the nested call outside the borrow.
                    let outcome = {
                        let compiled = &self.compiled[cidx];
                        frame.run(compiled, mem, fuel)?
                    };
                    match outcome {
                        RunOutcome::Done(v) => break v,
                        RunOutcome::NeedCall { pc, req, dst } => {
                            let r = self.dispatch(req.func, mem, &req.args, fuel)?;
                            if let Some(d) = dst {
                                frame.slots[d as usize] = r.unwrap_or(Val::Undef);
                            }
                            frame.pc = pc + 1;
                        }
                    }
                };
                let p = &mut self.profiles[func as usize];
                p.counters.invocations += frame.counters.invocations;
                p.counters.cycles += frame.counters.cycles;
                p.counters.mem_accesses += frame.counters.mem_accesses;
                p.counters.insts += frame.counters.insts;
                p.counters.loop_trips += frame.counters.loop_trips;
                p.wall += t0.elapsed();
                self.trip_hists[func as usize].record(frame.counters.loop_trips);
                Ok(result)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::func::{FuncBuilder, Module};
    use crate::ir::instr::{Inst, Ty};

    fn module_with_square_and_driver() -> Module {
        // square(x) = x*x ; driver(A, n): for i in 0..n { A[i] = square(A[i]) }
        let mut m = Module::new();
        let mut b = FuncBuilder::new("square", &[("x", Ty::I32)]);
        let x = b.param(0);
        let r = b.mul(x, x);
        m.add(b.ret(Some(r)));

        let mut b = FuncBuilder::new("driver", &[("A", Ty::Ptr), ("n", Ty::I32)]);
        let (a, n) = (b.param(0), b.param(1));
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, i| {
            let v = b.load(Ty::I32, a, i);
            let dst = b.fresh();
            b.push(Inst::Call { dst: Some(dst), callee: "square".into(), args: vec![v] });
            b.store(Ty::I32, a, i, dst);
        });
        m.add(b.ret(None));
        m
    }

    #[test]
    fn nested_calls_work() {
        let mut e = Engine::new(module_with_square_and_driver()).unwrap();
        let mut mem = Memory::new();
        let h = mem.from_i32(&[1, 2, 3, 4]);
        e.call("driver", &mut mem, &[Val::P(h), Val::I(4)]).unwrap();
        assert_eq!(mem.i32s(h), &[1, 4, 9, 16]);
        // Both functions profiled.
        let d = e.func_index("driver").unwrap();
        let s = e.func_index("square").unwrap();
        assert_eq!(e.profile(d).counters.invocations, 1);
        assert_eq!(e.profile(s).counters.invocations, 4);
    }

    #[test]
    fn patch_hook_redirects_and_unpatch_restores() {
        let mut e = Engine::new(module_with_square_and_driver()).unwrap();
        let s = e.func_index("square").unwrap();
        // Hook: returns x+100 instead of x*x.
        e.patch_hook(
            s,
            Box::new(|_mem, args| Ok(Some(Val::I(args[0].as_i32() + 100)))),
        );
        assert!(e.is_patched(s));
        let mut mem = Memory::new();
        let h = mem.from_i32(&[1, 2]);
        e.call("driver", &mut mem, &[Val::P(h), Val::I(2)]).unwrap();
        assert_eq!(mem.i32s(h), &[101, 102]);

        e.unpatch(s);
        assert!(!e.is_patched(s));
        let h2 = mem.from_i32(&[3]);
        e.call("driver", &mut mem, &[Val::P(h2), Val::I(1)]).unwrap();
        assert_eq!(mem.i32s(h2), &[9]);
    }

    #[test]
    fn unknown_function_errors() {
        let mut e = Engine::new(Module::new()).unwrap();
        let mut mem = Memory::new();
        assert!(matches!(
            e.call("ghost", &mut mem, &[]),
            Err(EngineError::UnknownFunction(_))
        ));
    }

    #[test]
    fn fuel_limit_enforced() {
        use crate::ir::instr::{BlockId, Term};
        let mut m = Module::new();
        let mut b = FuncBuilder::new("spin", &[]);
        b.terminate(Term::Br(BlockId(0)));
        m.add(b.finish());
        let mut e = Engine::new(m).unwrap();
        e.fuel_limit = 10_000;
        let mut mem = Memory::new();
        assert!(matches!(
            e.call("spin", &mut mem, &[]),
            Err(EngineError::Trap(Trap::OutOfFuel))
        ));
    }

    #[test]
    fn jit_times_recorded() {
        let e = Engine::new(module_with_square_and_driver()).unwrap();
        assert_eq!(e.jit_times.len(), 2);
    }

    #[test]
    fn histogram_buckets_and_dominant() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 1);
        assert_eq!(Histogram::bucket_of(2), 2);
        assert_eq!(Histogram::bucket_of(3), 2);
        assert_eq!(Histogram::bucket_of(4), 3);
        assert_eq!(Histogram::bucket_floor(0), 0);
        assert_eq!(Histogram::bucket_floor(1), 1);
        assert_eq!(Histogram::bucket_floor(4), 8);
        assert_eq!(Histogram::bucket_of(u64::MAX), super::HIST_BUCKETS - 1);
        let mut h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.dominant_floor(), 0);
        h.record_n(5, 3);
        h.record(100);
        assert_eq!(h.total(), 4);
        assert_eq!(h.dominant_bucket(), Some(Histogram::bucket_of(5)));
        assert_eq!(h.dominant_floor(), 4);
        h.clear();
        assert!(h.is_empty());
    }

    #[test]
    fn trip_hist_records_per_invocation_trips() {
        let mut e = Engine::new(module_with_square_and_driver()).unwrap();
        let mut mem = Memory::new();
        let h = mem.from_i32(&[1, 2, 3, 4, 5]);
        e.call("driver", &mut mem, &[Val::P(h), Val::I(5)]).unwrap();
        let d = e.func_index("driver").unwrap();
        assert_eq!(e.profile(d).counters.loop_trips, 5);
        let hist = e.trip_hist(d);
        assert_eq!(hist.total(), 1);
        assert_eq!(hist.dominant_bucket(), Some(Histogram::bucket_of(5)));
        // Leaf function has no loops: all observations in bucket 0.
        let s = e.func_index("square").unwrap();
        assert_eq!(e.trip_hist(s).dominant_bucket(), Some(0));
        assert_eq!(e.trip_hist(s).total(), 5);
    }

    #[test]
    fn take_profile_snapshots_and_resets_one_row() {
        let mut e = Engine::new(module_with_square_and_driver()).unwrap();
        let mut mem = Memory::new();
        let h = mem.from_i32(&[1, 2, 3]);
        e.call("driver", &mut mem, &[Val::P(h), Val::I(3)]).unwrap();
        let d = e.func_index("driver").unwrap();
        let s = e.func_index("square").unwrap();
        let snap = e.take_profile(d);
        assert_eq!(snap.counters.invocations, 1);
        assert!(snap.counters.cycles > 0);
        assert_eq!(e.profile(d).counters, FnCounters::default());
        // Other rows untouched.
        assert_eq!(e.profile(s).counters.invocations, 3);
    }
}

//! Bytecode: the "JIT-compiled" form of a mini-IR function.
//!
//! The real system JIT-compiles LLVM-IR to native code; here the analogue
//! is a one-pass lowering of the IR CFG to a linear register bytecode with
//! resolved jump offsets, executed by a threaded interpreter
//! ([`super::interp`]). The cost model (cycles per op, memory accesses)
//! feeds the perf_event-style monitor.

use std::collections::HashMap;

use crate::ir::func::Function;
use crate::ir::instr::{BinOp, BlockId, CmpPred, Inst, Term, Ty};

/// Program counter within a bytecode body.
pub type Pc = u32;

/// Flattened instruction. Register operands are frame-slot indices.
#[derive(Clone, Debug, PartialEq)]
pub enum Bc {
    ConstI32 { dst: u32, v: i32 },
    ConstF32 { dst: u32, v: f32 },
    BinI32 { dst: u32, op: BinOp, a: u32, b: u32 },
    BinF32 { dst: u32, op: BinOp, a: u32, b: u32 },
    CmpI32 { dst: u32, pred: CmpPred, a: u32, b: u32 },
    CmpF32 { dst: u32, pred: CmpPred, a: u32, b: u32 },
    Select { dst: u32, c: u32, t: u32, f: u32 },
    LoadI32 { dst: u32, base: u32, idx: u32 },
    LoadF32 { dst: u32, base: u32, idx: u32 },
    StoreI32 { base: u32, idx: u32, val: u32 },
    StoreF32 { base: u32, idx: u32, val: u32 },
    IToF { dst: u32, a: u32 },
    FToI { dst: u32, a: u32 },
    Mov { dst: u32, a: u32 },
    /// Call through the engine's patchable table.
    Call { dst: Option<u32>, func: u32, args: Vec<u32> },
    Syscall,
    Jmp { to: Pc },
    JmpIf { c: u32, t: Pc, f: Pc },
    Ret { v: Option<u32> },
}

impl Bc {
    /// Cost model: abstract cycles per instruction (ALU 1, mul 3, div 12,
    /// memory 4, call 8). Mirrors the relative costs a perf counter would
    /// observe on the host.
    pub fn cost(&self) -> u64 {
        match self {
            Bc::BinI32 { op, .. } | Bc::BinF32 { op, .. } => match op {
                BinOp::Mul => 3,
                BinOp::Div | BinOp::Rem => 12,
                _ => 1,
            },
            Bc::LoadI32 { .. }
            | Bc::LoadF32 { .. }
            | Bc::StoreI32 { .. }
            | Bc::StoreF32 { .. } => 4,
            Bc::Call { .. } => 8,
            Bc::Syscall => 50,
            _ => 1,
        }
    }

    pub fn is_mem(&self) -> bool {
        matches!(
            self,
            Bc::LoadI32 { .. } | Bc::LoadF32 { .. } | Bc::StoreI32 { .. } | Bc::StoreF32 { .. }
        )
    }
}

/// A compiled function body.
#[derive(Clone, Debug)]
pub struct CompiledFn {
    pub name: String,
    pub n_slots: u32,
    pub n_params: usize,
    pub code: Vec<Bc>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CompileError {
    Unterminated(BlockId),
    UnknownCallee(String),
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CompileError::Unterminated(b) => write!(f, "block {b} lacks a terminator"),
            CompileError::UnknownCallee(c) => write!(f, "unknown callee @{c}"),
        }
    }
}

impl std::error::Error for CompileError {}

/// Lower one function. `resolve` maps callee names to function-table
/// indices (the engine's patchable call table).
pub fn compile_fn(
    f: &Function,
    resolve: &dyn Fn(&str) -> Option<u32>,
) -> Result<CompiledFn, CompileError> {
    // First pass: block -> start pc. Each IR inst is 1 bc; each terminator 1.
    let mut block_pc: HashMap<BlockId, Pc> = HashMap::new();
    let mut pc: Pc = 0;
    for (i, b) in f.blocks.iter().enumerate() {
        block_pc.insert(BlockId(i as u32), pc);
        pc += b.insts.len() as Pc + 1;
    }

    let mut code: Vec<Bc> = Vec::with_capacity(pc as usize);
    for (i, b) in f.blocks.iter().enumerate() {
        for inst in &b.insts {
            code.push(lower_inst(inst, resolve)?);
        }
        let term = b.term.as_ref().ok_or(CompileError::Unterminated(BlockId(i as u32)))?;
        code.push(match term {
            Term::Br(t) => Bc::Jmp { to: block_pc[t] },
            Term::CondBr { c, t, f: fb } => {
                Bc::JmpIf { c: c.0, t: block_pc[t], f: block_pc[fb] }
            }
            Term::Ret(v) => Bc::Ret { v: v.map(|r| r.0) },
        });
    }
    Ok(CompiledFn {
        name: f.name.clone(),
        n_slots: f.n_regs,
        n_params: f.params.len(),
        code,
    })
}

fn lower_inst(inst: &Inst, resolve: &dyn Fn(&str) -> Option<u32>) -> Result<Bc, CompileError> {
    Ok(match inst {
        Inst::ConstI32 { dst, v } => Bc::ConstI32 { dst: dst.0, v: *v },
        Inst::ConstF32 { dst, v } => Bc::ConstF32 { dst: dst.0, v: *v },
        Inst::Bin { dst, op, ty, a, b } => match ty {
            Ty::F32 => Bc::BinF32 { dst: dst.0, op: *op, a: a.0, b: b.0 },
            _ => Bc::BinI32 { dst: dst.0, op: *op, a: a.0, b: b.0 },
        },
        Inst::Cmp { dst, pred, ty, a, b } => match ty {
            Ty::F32 => Bc::CmpF32 { dst: dst.0, pred: *pred, a: a.0, b: b.0 },
            _ => Bc::CmpI32 { dst: dst.0, pred: *pred, a: a.0, b: b.0 },
        },
        Inst::Select { dst, c, t, f } => {
            Bc::Select { dst: dst.0, c: c.0, t: t.0, f: f.0 }
        }
        Inst::Load { dst, ty, base, idx } => match ty {
            Ty::F32 => Bc::LoadF32 { dst: dst.0, base: base.0, idx: idx.0 },
            _ => Bc::LoadI32 { dst: dst.0, base: base.0, idx: idx.0 },
        },
        Inst::Store { ty, base, idx, val } => match ty {
            Ty::F32 => Bc::StoreF32 { base: base.0, idx: idx.0, val: val.0 },
            _ => Bc::StoreI32 { base: base.0, idx: idx.0, val: val.0 },
        },
        Inst::IToF { dst, a } => Bc::IToF { dst: dst.0, a: a.0 },
        Inst::FToI { dst, a } => Bc::FToI { dst: dst.0, a: a.0 },
        Inst::Mov { dst, a } => Bc::Mov { dst: dst.0, a: a.0 },
        Inst::Call { dst, callee, args } => {
            let func =
                resolve(callee).ok_or_else(|| CompileError::UnknownCallee(callee.clone()))?;
            Bc::Call {
                dst: dst.map(|d| d.0),
                func,
                args: args.iter().map(|r| r.0).collect(),
            }
        }
        Inst::Syscall { .. } => Bc::Syscall,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::func::FuncBuilder;
    use crate::ir::instr::Ty;

    #[test]
    fn compiles_loop_shape() {
        let mut b = FuncBuilder::new("f", &[("n", Ty::I32)]);
        let n = b.param(0);
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |_, _| {});
        let f = b.ret(None);
        let c = compile_fn(&f, &|_| None).unwrap();
        assert_eq!(c.n_params, 1);
        assert!(c.code.iter().any(|bc| matches!(bc, Bc::JmpIf { .. })));
        assert!(c.code.iter().any(|bc| matches!(bc, Bc::Jmp { .. })));
        assert!(matches!(c.code.last(), Some(Bc::Ret { .. })));
    }

    #[test]
    fn unknown_callee_fails() {
        use crate::ir::instr::Inst;
        let mut b = FuncBuilder::new("f", &[]);
        b.push(Inst::Call { dst: None, callee: "ghost".into(), args: vec![] });
        let f = b.ret(None);
        assert!(matches!(
            compile_fn(&f, &|_| None),
            Err(CompileError::UnknownCallee(c)) if c == "ghost"
        ));
    }

    #[test]
    fn cost_model_sane() {
        assert_eq!(Bc::Mov { dst: 0, a: 1 }.cost(), 1);
        assert_eq!(Bc::LoadI32 { dst: 0, base: 1, idx: 2 }.cost(), 4);
        assert!(Bc::StoreF32 { base: 0, idx: 1, val: 2 }.is_mem());
        assert_eq!(
            Bc::BinI32 { dst: 0, op: BinOp::Div, a: 1, b: 2 }.cost(),
            12
        );
    }
}

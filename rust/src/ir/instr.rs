//! Mini-IR instruction set.
//!
//! A register-machine IR standing in for LLVM-IR (see DESIGN.md
//! §Substitutions): unlimited virtual registers, basic blocks with
//! explicit terminators, typed i32/f32 arithmetic, array load/store
//! through pointer parameters, calls and an explicit syscall marker.
//!
//! The instruction surface is deliberately shaped so the paper's legality
//! screen is expressible: integer div/rem *exist* (so `adi`, `lu`, ... are
//! representable and get rejected for DFE offload), f32 arithmetic exists
//! (so `jacobi-*`, `fdtd-2d` are representable and rejected), and
//! syscalls/calls mark non-offloadable regions.

use std::fmt;

/// Value types. `Ptr` is an opaque array handle indexed by element.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Ty {
    I32,
    F32,
    Ptr,
}

impl fmt::Display for Ty {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Ty::I32 => "i32",
            Ty::F32 => "f32",
            Ty::Ptr => "ptr",
        })
    }
}

/// Virtual register.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Reg(pub u32);

impl fmt::Display for Reg {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "r{}", self.0)
    }
}

/// Basic-block id.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId(pub u32);

impl fmt::Display for BlockId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "bb{}", self.0)
    }
}

/// Binary ALU operations (type-generic; `Div`/`Rem` only legal on the CPU
/// path, `F*` only on f32 — both rejected by the DFE legality screen).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Rem,
    Min,
    Max,
    And,
    Or,
    Xor,
    Shl,
    Shr,
}

impl BinOp {
    pub fn name(self) -> &'static str {
        match self {
            BinOp::Add => "add",
            BinOp::Sub => "sub",
            BinOp::Mul => "mul",
            BinOp::Div => "div",
            BinOp::Rem => "rem",
            BinOp::Min => "min",
            BinOp::Max => "max",
            BinOp::And => "and",
            BinOp::Or => "or",
            BinOp::Xor => "xor",
            BinOp::Shl => "shl",
            BinOp::Shr => "shr",
        }
    }

    /// Whether the DFE has a functional unit for this op (paper §III-A:
    /// no integer division nor remainder).
    pub fn dfe_supported(self) -> bool {
        !matches!(self, BinOp::Div | BinOp::Rem)
    }
}

/// Comparison predicates (signed).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CmpPred {
    Lt,
    Gt,
    Le,
    Ge,
    Eq,
    Ne,
}

impl CmpPred {
    pub fn name(self) -> &'static str {
        match self {
            CmpPred::Lt => "lt",
            CmpPred::Gt => "gt",
            CmpPred::Le => "le",
            CmpPred::Ge => "ge",
            CmpPred::Eq => "eq",
            CmpPred::Ne => "ne",
        }
    }

    pub fn eval_i32(self, a: i32, b: i32) -> bool {
        match self {
            CmpPred::Lt => a < b,
            CmpPred::Gt => a > b,
            CmpPred::Le => a <= b,
            CmpPred::Ge => a >= b,
            CmpPred::Eq => a == b,
            CmpPred::Ne => a != b,
        }
    }

    pub fn eval_f32(self, a: f32, b: f32) -> bool {
        match self {
            CmpPred::Lt => a < b,
            CmpPred::Gt => a > b,
            CmpPred::Le => a <= b,
            CmpPred::Ge => a >= b,
            CmpPred::Eq => a == b,
            CmpPred::Ne => a != b,
        }
    }
}

/// Non-terminator instructions.
#[derive(Clone, Debug, PartialEq)]
pub enum Inst {
    /// `dst = const`
    ConstI32 { dst: Reg, v: i32 },
    ConstF32 { dst: Reg, v: f32 },
    /// `dst = a <op> b` (both operands of type `ty`).
    Bin { dst: Reg, op: BinOp, ty: Ty, a: Reg, b: Reg },
    /// `dst = (a <pred> b) as i32` over operands of `ty`.
    Cmp { dst: Reg, pred: CmpPred, ty: Ty, a: Reg, b: Reg },
    /// `dst = c != 0 ? t : f`
    Select { dst: Reg, c: Reg, t: Reg, f: Reg },
    /// `dst = base[idx]` — element load through a Ptr register.
    Load { dst: Reg, ty: Ty, base: Reg, idx: Reg },
    /// `base[idx] = val`
    Store { ty: Ty, base: Reg, idx: Reg, val: Reg },
    /// `dst = i32->f32` / `f32->i32` conversions.
    IToF { dst: Reg, a: Reg },
    FToI { dst: Reg, a: Reg },
    /// Copy.
    Mov { dst: Reg, a: Reg },
    /// Direct call; `dst` receives the i32 return value if any.
    Call { dst: Option<Reg>, callee: String, args: Vec<Reg> },
    /// Opaque system call — poisons any enclosing region for offload.
    Syscall { name: String },
}

impl Inst {
    pub fn dst(&self) -> Option<Reg> {
        match self {
            Inst::ConstI32 { dst, .. }
            | Inst::ConstF32 { dst, .. }
            | Inst::Bin { dst, .. }
            | Inst::Cmp { dst, .. }
            | Inst::Select { dst, .. }
            | Inst::Load { dst, .. }
            | Inst::IToF { dst, .. }
            | Inst::FToI { dst, .. }
            | Inst::Mov { dst, .. } => Some(*dst),
            Inst::Call { dst, .. } => *dst,
            Inst::Store { .. } | Inst::Syscall { .. } => None,
        }
    }

    /// Registers read by this instruction.
    pub fn uses(&self) -> Vec<Reg> {
        match self {
            Inst::ConstI32 { .. } | Inst::ConstF32 { .. } | Inst::Syscall { .. } => vec![],
            Inst::Bin { a, b, .. } | Inst::Cmp { a, b, .. } => vec![*a, *b],
            Inst::Select { c, t, f, .. } => vec![*c, *t, *f],
            Inst::Load { base, idx, .. } => vec![*base, *idx],
            Inst::Store { base, idx, val, .. } => vec![*base, *idx, *val],
            Inst::IToF { a, .. } | Inst::FToI { a, .. } | Inst::Mov { a, .. } => vec![*a],
            Inst::Call { args, .. } => args.clone(),
        }
    }
}

impl fmt::Display for Inst {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Inst::ConstI32 { dst, v } => write!(f, "{dst} = const.i32 {v}"),
            Inst::ConstF32 { dst, v } => write!(f, "{dst} = const.f32 {v}"),
            Inst::Bin { dst, op, ty, a, b } => {
                write!(f, "{dst} = {}.{ty} {a}, {b}", op.name())
            }
            Inst::Cmp { dst, pred, ty, a, b } => {
                write!(f, "{dst} = cmp.{}.{ty} {a}, {b}", pred.name())
            }
            Inst::Select { dst, c, t, f: fv } => write!(f, "{dst} = select {c}, {t}, {fv}"),
            Inst::Load { dst, ty, base, idx } => write!(f, "{dst} = load.{ty} {base}[{idx}]"),
            Inst::Store { ty, base, idx, val } => write!(f, "store.{ty} {base}[{idx}], {val}"),
            Inst::IToF { dst, a } => write!(f, "{dst} = itof {a}"),
            Inst::FToI { dst, a } => write!(f, "{dst} = ftoi {a}"),
            Inst::Mov { dst, a } => write!(f, "{dst} = mov {a}"),
            Inst::Call { dst: Some(d), callee, args } => {
                write!(f, "{d} = call @{callee}({args:?})")
            }
            Inst::Call { dst: None, callee, args } => write!(f, "call @{callee}({args:?})"),
            Inst::Syscall { name } => write!(f, "syscall @{name}"),
        }
    }
}

/// Block terminators.
#[derive(Clone, Debug, PartialEq)]
pub enum Term {
    Br(BlockId),
    CondBr { c: Reg, t: BlockId, f: BlockId },
    Ret(Option<Reg>),
}

impl Term {
    pub fn successors(&self) -> Vec<BlockId> {
        match self {
            Term::Br(b) => vec![*b],
            Term::CondBr { t, f, .. } => vec![*t, *f],
            Term::Ret(_) => vec![],
        }
    }
}

impl fmt::Display for Term {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Term::Br(b) => write!(f, "br {b}"),
            Term::CondBr { c, t, f: fb } => write!(f, "condbr {c}, {t}, {fb}"),
            Term::Ret(Some(r)) => write!(f, "ret {r}"),
            Term::Ret(None) => write!(f, "ret"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dfe_support_surface() {
        assert!(BinOp::Add.dfe_supported());
        assert!(BinOp::Shl.dfe_supported());
        assert!(!BinOp::Div.dfe_supported());
        assert!(!BinOp::Rem.dfe_supported());
    }

    #[test]
    fn uses_and_dst() {
        let i = Inst::Bin { dst: Reg(3), op: BinOp::Add, ty: Ty::I32, a: Reg(1), b: Reg(2) };
        assert_eq!(i.dst(), Some(Reg(3)));
        assert_eq!(i.uses(), vec![Reg(1), Reg(2)]);
        let s = Inst::Store { ty: Ty::I32, base: Reg(0), idx: Reg(1), val: Reg(2) };
        assert_eq!(s.dst(), None);
        assert_eq!(s.uses().len(), 3);
    }

    #[test]
    fn display_forms() {
        let i = Inst::Load { dst: Reg(5), ty: Ty::I32, base: Reg(0), idx: Reg(4) };
        assert_eq!(i.to_string(), "r5 = load.i32 r0[r4]");
        assert_eq!(Term::Br(BlockId(2)).to_string(), "br bb2");
    }
}

//! Functions, basic blocks and modules, plus the builder API the workload
//! library uses to author PolyBench kernels.

use std::collections::HashMap;
use std::fmt;

use super::instr::{BinOp, BlockId, CmpPred, Inst, Reg, Term, Ty};

#[derive(Clone, Debug, Default)]
pub struct Block {
    pub insts: Vec<Inst>,
    pub term: Option<Term>,
}

/// Function parameter.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Param {
    pub name: String,
    pub ty: Ty,
}

#[derive(Clone, Debug)]
pub struct Function {
    pub name: String,
    pub params: Vec<Param>,
    pub blocks: Vec<Block>,
    pub entry: BlockId,
    pub n_regs: u32,
}

impl Function {
    pub fn block(&self, id: BlockId) -> &Block {
        &self.blocks[id.0 as usize]
    }

    pub fn param_reg(&self, i: usize) -> Reg {
        // Convention: parameters occupy r0..r{n_params-1}.
        debug_assert!(i < self.params.len());
        Reg(i as u32)
    }

    /// CFG successors of each block.
    pub fn successors(&self, id: BlockId) -> Vec<BlockId> {
        self.block(id).term.as_ref().map(|t| t.successors()).unwrap_or_default()
    }

    /// CFG predecessors map.
    pub fn predecessors(&self) -> HashMap<BlockId, Vec<BlockId>> {
        let mut preds: HashMap<BlockId, Vec<BlockId>> = HashMap::new();
        for b in 0..self.blocks.len() {
            let id = BlockId(b as u32);
            for s in self.successors(id) {
                preds.entry(s).or_default().push(id);
            }
        }
        preds
    }

    /// Static instruction count (profiling/report metric).
    pub fn n_insts(&self) -> usize {
        self.blocks.iter().map(|b| b.insts.len() + 1).sum()
    }
}

impl fmt::Display for Function {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "func @{}(", self.name)?;
        for (i, p) in self.params.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{}: {}", p.name, p.ty)?;
        }
        writeln!(f, ") {{")?;
        for (i, b) in self.blocks.iter().enumerate() {
            writeln!(f, "bb{i}:")?;
            for inst in &b.insts {
                writeln!(f, "  {inst}")?;
            }
            if let Some(t) = &b.term {
                writeln!(f, "  {t}")?;
            }
        }
        writeln!(f, "}}")
    }
}

/// A module: named functions (the JIT resolves `Call` by name).
#[derive(Clone, Debug, Default)]
pub struct Module {
    pub funcs: Vec<Function>,
}

impl Module {
    pub fn new() -> Module {
        Module::default()
    }

    pub fn add(&mut self, f: Function) -> usize {
        self.funcs.push(f);
        self.funcs.len() - 1
    }

    pub fn get(&self, name: &str) -> Option<&Function> {
        self.funcs.iter().find(|f| f.name == name)
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.funcs.iter().position(|f| f.name == name)
    }
}

/// Imperative function builder. Registers `r0..rN-1` are bound to
/// parameters; fresh registers come from [`FuncBuilder::fresh`].
pub struct FuncBuilder {
    name: String,
    params: Vec<Param>,
    blocks: Vec<Block>,
    cur: BlockId,
    next_reg: u32,
}

impl FuncBuilder {
    pub fn new(name: &str, params: &[(&str, Ty)]) -> FuncBuilder {
        let params: Vec<Param> =
            params.iter().map(|(n, t)| Param { name: n.to_string(), ty: *t }).collect();
        FuncBuilder {
            name: name.to_string(),
            next_reg: params.len() as u32,
            params,
            blocks: vec![Block::default()],
            cur: BlockId(0),
        }
    }

    pub fn param(&self, i: usize) -> Reg {
        debug_assert!(i < self.params.len());
        Reg(i as u32)
    }

    pub fn fresh(&mut self) -> Reg {
        let r = Reg(self.next_reg);
        self.next_reg += 1;
        r
    }

    pub fn new_block(&mut self) -> BlockId {
        self.blocks.push(Block::default());
        BlockId(self.blocks.len() as u32 - 1)
    }

    pub fn switch_to(&mut self, b: BlockId) {
        self.cur = b;
    }

    pub fn cur_block(&self) -> BlockId {
        self.cur
    }

    pub fn push(&mut self, inst: Inst) {
        let b = &mut self.blocks[self.cur.0 as usize];
        debug_assert!(b.term.is_none(), "emitting into terminated block");
        b.insts.push(inst);
    }

    pub fn terminate(&mut self, t: Term) {
        let b = &mut self.blocks[self.cur.0 as usize];
        debug_assert!(b.term.is_none(), "block already terminated");
        b.term = Some(t);
    }

    // ---- convenience emitters ----

    pub fn const_i32(&mut self, v: i32) -> Reg {
        let dst = self.fresh();
        self.push(Inst::ConstI32 { dst, v });
        dst
    }

    pub fn const_f32(&mut self, v: f32) -> Reg {
        let dst = self.fresh();
        self.push(Inst::ConstF32 { dst, v });
        dst
    }

    pub fn bin(&mut self, op: BinOp, ty: Ty, a: Reg, b: Reg) -> Reg {
        let dst = self.fresh();
        self.push(Inst::Bin { dst, op, ty, a, b });
        dst
    }

    pub fn add(&mut self, a: Reg, b: Reg) -> Reg {
        self.bin(BinOp::Add, Ty::I32, a, b)
    }

    pub fn sub(&mut self, a: Reg, b: Reg) -> Reg {
        self.bin(BinOp::Sub, Ty::I32, a, b)
    }

    pub fn mul(&mut self, a: Reg, b: Reg) -> Reg {
        self.bin(BinOp::Mul, Ty::I32, a, b)
    }

    pub fn fadd(&mut self, a: Reg, b: Reg) -> Reg {
        self.bin(BinOp::Add, Ty::F32, a, b)
    }

    pub fn fmul(&mut self, a: Reg, b: Reg) -> Reg {
        self.bin(BinOp::Mul, Ty::F32, a, b)
    }

    pub fn cmp(&mut self, pred: CmpPred, a: Reg, b: Reg) -> Reg {
        let dst = self.fresh();
        self.push(Inst::Cmp { dst, pred, ty: Ty::I32, a, b });
        dst
    }

    pub fn select(&mut self, c: Reg, t: Reg, f: Reg) -> Reg {
        let dst = self.fresh();
        self.push(Inst::Select { dst, c, t, f });
        dst
    }

    pub fn load(&mut self, ty: Ty, base: Reg, idx: Reg) -> Reg {
        let dst = self.fresh();
        self.push(Inst::Load { dst, ty, base, idx });
        dst
    }

    pub fn store(&mut self, ty: Ty, base: Reg, idx: Reg, val: Reg) {
        self.push(Inst::Store { ty, base, idx, val });
    }

    pub fn mov(&mut self, a: Reg) -> Reg {
        let dst = self.fresh();
        self.push(Inst::Mov { dst, a });
        dst
    }

    pub fn mov_into(&mut self, dst: Reg, a: Reg) {
        self.push(Inst::Mov { dst, a });
    }

    /// Emit a canonical counted loop `for iv in lb..ub` and run `body`.
    /// Produces the standard header/body/latch/exit shape the SCoP
    /// detector recognizes. Returns after switching to the exit block.
    pub fn counted_loop(
        &mut self,
        lb: Reg,
        ub: Reg,
        mut body: impl FnMut(&mut FuncBuilder, Reg),
    ) {
        let iv = self.fresh();
        self.mov_into(iv, lb);
        let header = self.new_block();
        let body_bb = self.new_block();
        let exit = self.new_block();
        self.terminate(Term::Br(header));

        self.switch_to(header);
        let c = self.cmp(CmpPred::Lt, iv, ub);
        self.terminate(Term::CondBr { c, t: body_bb, f: exit });

        self.switch_to(body_bb);
        body(self, iv);
        // Latch: iv += 1; back to header. (Latch folded into body block
        // tail — canonical rotated-loop shape.)
        let one = self.const_i32(1);
        let next = self.add(iv, one);
        self.mov_into(iv, next);
        self.terminate(Term::Br(header));

        self.switch_to(exit);
    }

    pub fn ret(mut self, v: Option<Reg>) -> Function {
        self.terminate(Term::Ret(v));
        self.finish()
    }

    pub fn finish(self) -> Function {
        Function {
            name: self.name,
            params: self.params,
            blocks: self.blocks,
            entry: BlockId(0),
            n_regs: self.next_reg,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build the Fig-2 kernel: for i in 0..n { C[i] = A[i] + 3*B[i] + 1 }.
    pub fn fig2_func() -> Function {
        let mut b = FuncBuilder::new(
            "fig2",
            &[("C", Ty::Ptr), ("A", Ty::Ptr), ("B", Ty::Ptr), ("n", Ty::I32)],
        );
        let (c, a, bb, n) = (b.param(0), b.param(1), b.param(2), b.param(3));
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, i| {
            let av = b.load(Ty::I32, a, i);
            let bv = b.load(Ty::I32, bb, i);
            let c3 = b.const_i32(3);
            let t = b.mul(bv, c3);
            let s = b.add(av, t);
            let c1 = b.const_i32(1);
            let r = b.add(s, c1);
            b.store(Ty::I32, c, i, r);
        });
        b.ret(None)
    }

    #[test]
    fn builder_produces_canonical_loop() {
        let f = fig2_func();
        assert_eq!(f.blocks.len(), 4); // entry, header, body, exit
        assert!(f.to_string().contains("cmp.lt"));
        // header has condbr to body/exit
        let header = &f.blocks[1];
        assert!(matches!(header.term, Some(Term::CondBr { .. })));
        // body's last terminator branches back to header
        let body = &f.blocks[2];
        assert_eq!(body.term, Some(Term::Br(BlockId(1))));
    }

    #[test]
    fn predecessors_computed() {
        let f = fig2_func();
        let preds = f.predecessors();
        // header (bb1) has preds: entry (bb0) and body (bb2)
        let mut p = preds[&BlockId(1)].clone();
        p.sort();
        assert_eq!(p, vec![BlockId(0), BlockId(2)]);
    }

    #[test]
    fn module_lookup() {
        let mut m = Module::new();
        m.add(fig2_func());
        assert!(m.get("fig2").is_some());
        assert_eq!(m.index_of("fig2"), Some(0));
        assert!(m.get("nope").is_none());
    }
}

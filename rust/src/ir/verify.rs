//! Structural IR verification (a lightweight `opt -verify`).

use std::fmt;

use super::func::{Function, Module};
use super::instr::{BlockId, Term};

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum VerifyError {
    Unterminated(BlockId),
    BadTarget { from: BlockId, to: BlockId },
    RegOutOfRange { block: BlockId, reg: u32, max: u32 },
    UnknownCallee { block: BlockId, callee: String },
    EmptyFunction,
}

impl fmt::Display for VerifyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            VerifyError::Unterminated(b) => write!(f, "block {b} lacks a terminator"),
            VerifyError::BadTarget { from, to } => {
                write!(f, "branch {from} -> {to} targets a missing block")
            }
            VerifyError::RegOutOfRange { block, reg, max } => {
                write!(f, "register r{reg} out of range (max {max}) in {block}")
            }
            VerifyError::UnknownCallee { block, callee } => {
                write!(f, "call to unknown function @{callee} in {block}")
            }
            VerifyError::EmptyFunction => write!(f, "function has no blocks"),
        }
    }
}

impl std::error::Error for VerifyError {}

/// Verify one function (callee resolution needs the module; pass `None`
/// to skip it).
pub fn verify_function(f: &Function, module: Option<&Module>) -> Result<(), VerifyError> {
    if f.blocks.is_empty() {
        return Err(VerifyError::EmptyFunction);
    }
    let n_blocks = f.blocks.len() as u32;
    for (i, b) in f.blocks.iter().enumerate() {
        let id = BlockId(i as u32);
        let term = b.term.as_ref().ok_or(VerifyError::Unterminated(id))?;
        for t in term.successors() {
            if t.0 >= n_blocks {
                return Err(VerifyError::BadTarget { from: id, to: t });
            }
        }
        let mut check = |r: u32| {
            if r >= f.n_regs {
                Err(VerifyError::RegOutOfRange { block: id, reg: r, max: f.n_regs })
            } else {
                Ok(())
            }
        };
        for inst in &b.insts {
            if let Some(d) = inst.dst() {
                check(d.0)?;
            }
            for u in inst.uses() {
                check(u.0)?;
            }
            if let super::instr::Inst::Call { callee, .. } = inst {
                if let Some(m) = module {
                    if m.get(callee).is_none() {
                        return Err(VerifyError::UnknownCallee {
                            block: id,
                            callee: callee.clone(),
                        });
                    }
                }
            }
        }
        if let Term::CondBr { c, .. } = term {
            check(c.0)?;
        }
        if let Term::Ret(Some(r)) = term {
            check(r.0)?;
        }
    }
    Ok(())
}

/// Verify every function in a module.
pub fn verify_module(m: &Module) -> Result<(), (String, VerifyError)> {
    for f in &m.funcs {
        verify_function(f, Some(m)).map_err(|e| (f.name.clone(), e))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::func::FuncBuilder;
    use crate::ir::instr::{Inst, Reg, Term, Ty};

    #[test]
    fn accepts_wellformed() {
        let mut b = FuncBuilder::new("ok", &[("n", Ty::I32)]);
        let n = b.param(0);
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |_, _| {});
        let f = b.ret(None);
        verify_function(&f, None).unwrap();
    }

    #[test]
    fn rejects_unterminated() {
        let b = FuncBuilder::new("bad", &[]);
        let f = b.finish(); // entry block never terminated
        assert!(matches!(verify_function(&f, None), Err(VerifyError::Unterminated(_))));
    }

    #[test]
    fn rejects_bad_target() {
        let mut b = FuncBuilder::new("bad", &[]);
        b.terminate(Term::Br(BlockId(7)));
        let f = b.finish();
        assert!(matches!(verify_function(&f, None), Err(VerifyError::BadTarget { .. })));
    }

    #[test]
    fn rejects_out_of_range_reg() {
        let mut b = FuncBuilder::new("bad", &[]);
        b.push(Inst::Mov { dst: Reg(99), a: Reg(98) });
        b.terminate(Term::Ret(None));
        let f = b.finish();
        assert!(matches!(
            verify_function(&f, None),
            Err(VerifyError::RegOutOfRange { .. })
        ));
    }

    #[test]
    fn rejects_unknown_callee() {
        use crate::ir::func::Module;
        let mut b = FuncBuilder::new("caller", &[]);
        b.push(Inst::Call { dst: None, callee: "ghost".into(), args: vec![] });
        b.terminate(Term::Ret(None));
        let mut m = Module::new();
        m.add(b.finish());
        assert!(verify_module(&m).is_err());
    }
}

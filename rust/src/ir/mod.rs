//! Mini-IR substrate: the stand-in for LLVM-IR (DESIGN.md §Substitutions).

pub mod func;
pub mod instr;
pub mod verify;

pub use func::{Block, FuncBuilder, Function, Module, Param};
pub use instr::{BinOp, BlockId, CmpPred, Inst, Reg, Term, Ty};
pub use verify::{verify_function, verify_module, VerifyError};

//! Las-Vegas place & route (paper §III-B): stochastic placement with
//! Dijkstra net routing over the DFE fabric, plus the compile service
//! (racing seed portfolios + background compilation + warm starts).
pub mod lasvegas;
pub mod route;
pub mod service;
pub use lasvegas::{
    place_and_route, place_and_route_seeded, ParError, ParParams, ParResult, ParSeed,
    ParStats, RaceCtl, RaceState,
};
pub use route::{RouteError, RouteOutcome, RouteTarget, Router};
pub use service::{
    derive_seed, place_and_route_portfolio, CompileDone, CompileJob, CompileService,
    LapOutcome, PortfolioOutcome, PortfolioParams, SeedLap,
};

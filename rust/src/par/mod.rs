//! Las-Vegas place & route (paper §III-B): stochastic placement with
//! Dijkstra net routing over the DFE fabric.
pub mod lasvegas;
pub mod route;
pub use lasvegas::{place_and_route, ParError, ParParams, ParResult, ParStats};
pub use route::{RouteError, RouteOutcome, RouteTarget, Router};

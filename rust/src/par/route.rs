//! Incremental net routing over the DFE fabric.
//!
//! A *net* is the value produced by one DFG node (a placed FU result or an
//! external input). Routing a net to a new consumer runs Dijkstra from
//! every point where the net is already visible ("from the node to all the
//! DFE's cells where the desired variable is replicated, selecting then
//! the closest option" — paper §III-B) through free cell output faces,
//! building a branching distribution tree. Costs are hop counts: every
//! routing stage is one pipeline register, so shortest paths minimize both
//! resource use and pipeline depth.
//!
//! Resource model: each cell output face carries at most one net (it is a
//! single registered wire into the facing neighbor); forks happen inside
//! cells (one input face can feed several output faces and the FU at
//! once). Border input faces each carry one external input stream; border
//! output faces are tapped once for one external output.

use std::collections::{BinaryHeap, HashMap};

use crate::dfe::config::{FuSrc, GridConfig, IoAssign, OutSrc};
use crate::dfe::grid::{CellCoord, Dir, Grid, DIRS};
use crate::dfg::graph::NodeId;

/// Producer of a net.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NetSource {
    /// FU result of the cell where the producer DFG node is placed.
    Fu(CellCoord),
    /// External input stream `j`, bound (or not yet) to a border in-face.
    ExtIn(usize),
}

/// Where a routed value must arrive.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouteTarget {
    /// An input face of `cell` (for an FU operand); any direction works.
    CellInput(CellCoord),
    /// Any free border output face (for an external output tap).
    BorderOut,
}

/// Outcome of a successful route.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RouteOutcome {
    /// Net now visible at input face `(cell, dir)`.
    AtInput(CellCoord, Dir),
    /// Net tapped at border output face `(cell, dir)`.
    AtBorderOut(CellCoord, Dir),
}

/// Routing state layered over a [`GridConfig`] under construction.
#[derive(Clone, Debug)]
pub struct Router {
    pub cfg: GridConfig,
    /// Net visible at input face (cell,dir). Derived from out-face muxes
    /// plus external input bindings; kept incrementally for speed.
    in_net: HashMap<(CellCoord, Dir), NodeId>,
    /// Border in-face already bound to an external input.
    in_face_bound: HashMap<(CellCoord, Dir), usize>,
    /// For each net: the input faces where it is currently visible.
    visible: HashMap<NodeId, Vec<(CellCoord, Dir)>>,
    /// Source of each net.
    pub sources: HashMap<NodeId, NetSource>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RouteError {
    NoPath,
    UnknownNet(NodeId),
}

/// Dijkstra search state: net visible at the input face of a cell, or the
/// virtual producer state.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum SState {
    At(CellCoord, Dir),
    ProducerFu(CellCoord),
    /// Virtual: unbound external input that may enter at any free border
    /// in-face (materialized on commit).
    ExtInUnbound,
}

#[derive(Clone, Copy, Debug)]
struct PredEdge {
    prev: SState,
    /// Cell whose out face is being used by this hop.
    via_cell: CellCoord,
    /// Out face direction used.
    via_out: Dir,
    /// Out mux setting: pass from this in dir, or Fu.
    via_src: OutSrc,
}

#[derive(Clone, Copy, PartialEq, Eq)]
struct QItem {
    cost: u32,
    state: SState,
    tiebreak: u32,
}

impl Ord for QItem {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Min-heap by cost.
        other
            .cost
            .cmp(&self.cost)
            .then_with(|| other.tiebreak.cmp(&self.tiebreak))
    }
}

impl PartialOrd for QItem {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Router {
    pub fn new(grid: Grid) -> Router {
        Router {
            cfg: GridConfig::empty(grid),
            in_net: HashMap::new(),
            in_face_bound: HashMap::new(),
            visible: HashMap::new(),
            sources: HashMap::new(),
        }
    }

    pub fn grid(&self) -> Grid {
        self.cfg.grid
    }

    /// Register a net produced by the FU placed at `cell`.
    pub fn add_fu_net(&mut self, net: NodeId, cell: CellCoord) {
        self.sources.insert(net, NetSource::Fu(cell));
        self.visible.entry(net).or_default();
    }

    /// Register an external-input net.
    pub fn add_input_net(&mut self, net: NodeId, index: usize) {
        self.sources.insert(net, NetSource::ExtIn(index));
        self.visible.entry(net).or_default();
    }

    /// Net currently visible at `(cell, dir)`, if any.
    pub fn net_at(&self, cell: CellCoord, dir: Dir) -> Option<NodeId> {
        self.in_net.get(&(cell, dir)).copied()
    }

    /// Whether `net` is already visible at some input face of `cell`.
    pub fn visible_at_cell(&self, net: NodeId, cell: CellCoord) -> Option<Dir> {
        self.visible
            .get(&net)?
            .iter()
            .find(|(p, _)| *p == cell)
            .map(|&(_, d)| d)
    }

    fn out_free(&self, p: CellCoord, d: Dir) -> bool {
        self.cfg.cell(p).out[d.index()] == OutSrc::None
    }

    fn border_in_free(&self, p: CellCoord, d: Dir) -> bool {
        self.cfg.grid.is_border_face(p, d) && !self.in_face_bound.contains_key(&(p, d))
    }

    /// Route `net` to `target`. On success commits all mux settings and
    /// visibility updates and returns where the value landed.
    pub fn route(&mut self, net: NodeId, target: RouteTarget) -> Result<RouteOutcome, RouteError> {
        let source = *self.sources.get(&net).ok_or(RouteError::UnknownNet(net))?;

        // Fast path: already visible at the consumer cell.
        if let RouteTarget::CellInput(t) = target {
            if let Some(d) = self.visible_at_cell(net, t) {
                return Ok(RouteOutcome::AtInput(t, d));
            }
        }

        let grid = self.cfg.grid;
        // Pre-size for the worst case (~4 face states per cell): route()
        // is the innermost operation of every P&R search, and with the
        // portfolio racer running K of them concurrently, rehash churn
        // here is pure wall-time loss.
        let states = grid.n_cells() * 4 + 2;
        let mut dist: HashMap<SState, u32> = HashMap::with_capacity(states);
        let mut pred: HashMap<SState, PredEdge> = HashMap::with_capacity(states);
        let mut heap = BinaryHeap::with_capacity(states);
        let mut tiebreak = 0u32;

        let mut push = |heap: &mut BinaryHeap<QItem>,
                        dist: &mut HashMap<SState, u32>,
                        tiebreak: &mut u32,
                        state: SState,
                        cost: u32| {
            let better = dist.get(&state).map_or(true, |&c| cost < c);
            if better {
                dist.insert(state, cost);
                *tiebreak += 1;
                heap.push(QItem { cost, state, tiebreak: *tiebreak });
                true
            } else {
                false
            }
        };

        // Seed: existing visibility (cost 0)...
        if let Some(vis) = self.visible.get(&net) {
            for &(p, d) in vis {
                push(&mut heap, &mut dist, &mut tiebreak, SState::At(p, d), 0);
            }
        }
        // ...plus the producer itself.
        match source {
            NetSource::Fu(q) => {
                push(&mut heap, &mut dist, &mut tiebreak, SState::ProducerFu(q), 0);
            }
            NetSource::ExtIn(j) => {
                if let Some(&(p, d)) =
                    self.in_face_bound.iter().find(|(_, &jj)| jj == j).map(|(k, _)| k)
                {
                    // Already bound: visibility set covers it, but be safe.
                    push(&mut heap, &mut dist, &mut tiebreak, SState::At(p, d), 0);
                } else {
                    push(&mut heap, &mut dist, &mut tiebreak, SState::ExtInUnbound, 0);
                }
            }
        }

        // Search.
        let mut reached: Option<(SState, RouteOutcome, Option<(CellCoord, Dir, OutSrc)>)> = None;
        while let Some(QItem { cost, state, .. }) = heap.pop() {
            if dist.get(&state).map_or(true, |&c| cost > c) {
                continue;
            }
            // Goal tests on dequeue (At-states only for CellInput).
            match (&target, state) {
                (RouteTarget::CellInput(t), SState::At(p, d)) if p == *t => {
                    reached = Some((state, RouteOutcome::AtInput(p, d), None));
                    break;
                }
                _ => {}
            }

            // Expansions.
            match state {
                SState::At(p, din) => {
                    for d in DIRS {
                        if !self.out_free(p, d) {
                            continue;
                        }
                        match grid.neighbor(p, d) {
                            Some(q) => {
                                let ns = SState::At(q, d.opposite());
                                if push(&mut heap, &mut dist, &mut tiebreak, ns, cost + 1) {
                                    pred.insert(
                                        ns,
                                        PredEdge {
                                            prev: state,
                                            via_cell: p,
                                            via_out: d,
                                            via_src: OutSrc::In(din),
                                        },
                                    );
                                }
                            }
                            None => {
                                if target == RouteTarget::BorderOut {
                                    reached = Some((
                                        state,
                                        RouteOutcome::AtBorderOut(p, d),
                                        Some((p, d, OutSrc::In(din))),
                                    ));
                                }
                            }
                        }
                        if reached.is_some() {
                            break;
                        }
                    }
                }
                SState::ProducerFu(q) => {
                    for d in DIRS {
                        if !self.out_free(q, d) {
                            continue;
                        }
                        match grid.neighbor(q, d) {
                            Some(r) => {
                                let ns = SState::At(r, d.opposite());
                                if push(&mut heap, &mut dist, &mut tiebreak, ns, cost + 1) {
                                    pred.insert(
                                        ns,
                                        PredEdge {
                                            prev: state,
                                            via_cell: q,
                                            via_out: d,
                                            via_src: OutSrc::Fu,
                                        },
                                    );
                                }
                            }
                            None => {
                                if target == RouteTarget::BorderOut {
                                    reached = Some((
                                        state,
                                        RouteOutcome::AtBorderOut(q, d),
                                        Some((q, d, OutSrc::Fu)),
                                    ));
                                }
                            }
                        }
                        if reached.is_some() {
                            break;
                        }
                    }
                }
                SState::ExtInUnbound => {
                    // Materialize at any free border in-face.
                    for (p, d) in grid.border_faces() {
                        if !self.border_in_free(p, d) {
                            continue;
                        }
                        let ns = SState::At(p, d);
                        if push(&mut heap, &mut dist, &mut tiebreak, ns, cost + 1) {
                            pred.insert(
                                ns,
                                PredEdge {
                                    prev: state,
                                    // Sentinel: no out face used; commit
                                    // recognizes prev == ExtInUnbound.
                                    via_cell: p,
                                    via_out: d,
                                    via_src: OutSrc::None,
                                },
                            );
                        }
                    }
                }
            }
            if reached.is_some() {
                break;
            }
        }

        let (end_state, outcome, final_hop) = reached.ok_or(RouteError::NoPath)?;

        // Commit: walk predecessors, setting out muxes and visibility.
        let mut hops: Vec<PredEdge> = Vec::new();
        if let Some((p, d, src)) = final_hop {
            hops.push(PredEdge { prev: end_state, via_cell: p, via_out: d, via_src: src });
        }
        let mut cur = end_state;
        while let Some(&e) = pred.get(&cur) {
            hops.push(e);
            cur = e.prev;
        }
        // `cur` is now a seed state; apply hops source-first.
        for e in hops.iter().rev() {
            match e.prev {
                SState::ExtInUnbound => {
                    // Bind external input at border in-face (via_cell/out
                    // reused as the face coordinates).
                    let j = match source {
                        NetSource::ExtIn(j) => j,
                        _ => unreachable!("ExtInUnbound only for ExtIn nets"),
                    };
                    self.in_face_bound.insert((e.via_cell, e.via_out), j);
                    self.cfg.inputs.push(IoAssign { cell: e.via_cell, dir: e.via_out, index: j });
                    self.mark_visible(net, e.via_cell, e.via_out);
                }
                _ => {
                    debug_assert!(self.out_free(e.via_cell, e.via_out));
                    self.cfg.cell_mut(e.via_cell).out[e.via_out.index()] = e.via_src;
                    if let Some(q) = self.cfg.grid.neighbor(e.via_cell, e.via_out) {
                        self.mark_visible(net, q, e.via_out.opposite());
                    }
                    // Border-out hops create no new visibility.
                }
            }
        }
        Ok(outcome)
    }

    fn mark_visible(&mut self, net: NodeId, p: CellCoord, d: Dir) {
        self.in_net.insert((p, d), net);
        self.visible.entry(net).or_default().push((p, d));
    }

    /// Set an FU operand mux after a successful route to the cell.
    pub fn bind_fu_operand(&mut self, cell: CellCoord, which: u8, dir: Dir) {
        let c = self.cfg.cell_mut(cell);
        let slot = match which {
            0 => &mut c.fu1,
            1 => &mut c.fu2,
            _ => &mut c.fsel,
        };
        *slot = FuSrc::In(dir);
    }

    /// Tap a border out face as external output `j`.
    pub fn bind_output(&mut self, cell: CellCoord, dir: Dir, j: usize) {
        self.cfg.outputs.push(IoAssign { cell, dir, index: j });
    }

    /// Free out faces remaining (congestion metric for stats/benches).
    pub fn free_out_faces(&self) -> usize {
        self.cfg
            .grid
            .iter_coords()
            .map(|p| self.cfg.cell(p).free_outs().count())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfe::opcodes::Op;

    /// Manual placement of Fig 2 using the router: MUL at (0,0), ADD at
    /// (1,0), ADD at (1,1). Nets: input B (j=1) -> MUL; input A (j=0) ->
    /// ADD1; MUL -> ADD1; ADD1 -> ADD2; ADD2 -> output 0.
    #[test]
    fn routes_fig2_manually() {
        let grid = Grid::new(2, 2);
        let mut r = Router::new(grid);
        let (c00, c10, c11) =
            (CellCoord::new(0, 0), CellCoord::new(1, 0), CellCoord::new(1, 1));

        // Nets keyed by arbitrary ids.
        let (net_a, net_b, net_mul, net_add1, net_add2) = (0, 1, 2, 3, 4);
        r.add_input_net(net_a, 0);
        r.add_input_net(net_b, 1);

        // Place MUL at (0,0): operand B routed from border.
        r.cfg.cell_mut(c00).op = Some(Op::Mul);
        r.cfg.cell_mut(c00).fu2 = FuSrc::Const(3);
        let out = r.route(net_b, RouteTarget::CellInput(c00)).unwrap();
        let RouteOutcome::AtInput(p, d) = out else { panic!() };
        assert_eq!(p, c00);
        r.bind_fu_operand(c00, 0, d);
        r.add_fu_net(net_mul, c00);

        // Place ADD1 at (1,0): operands A (border) and MUL result.
        r.cfg.cell_mut(c10).op = Some(Op::Add);
        let RouteOutcome::AtInput(_, da) = r.route(net_a, RouteTarget::CellInput(c10)).unwrap()
        else {
            panic!()
        };
        r.bind_fu_operand(c10, 0, da);
        let RouteOutcome::AtInput(_, dm) =
            r.route(net_mul, RouteTarget::CellInput(c10)).unwrap()
        else {
            panic!()
        };
        r.bind_fu_operand(c10, 1, dm);
        r.add_fu_net(net_add1, c10);

        // Place ADD2 at (1,1).
        r.cfg.cell_mut(c11).op = Some(Op::Add);
        r.cfg.cell_mut(c11).fu2 = FuSrc::Const(1);
        let RouteOutcome::AtInput(_, ds) =
            r.route(net_add1, RouteTarget::CellInput(c11)).unwrap()
        else {
            panic!()
        };
        r.bind_fu_operand(c11, 0, ds);
        r.add_fu_net(net_add2, c11);

        // Output.
        let RouteOutcome::AtBorderOut(pc, pd) =
            r.route(net_add2, RouteTarget::BorderOut).unwrap()
        else {
            panic!()
        };
        r.bind_output(pc, pd, 0);

        let img = r.cfg.to_image().unwrap();
        for (a, b) in [(10, 5), (-3, 8)] {
            assert_eq!(img.eval_scalar(&[a, b]), vec![a + 3 * b + 1]);
        }
    }

    #[test]
    fn reuses_visibility_for_fanout() {
        // One input consumed by two cells: second route should be free or
        // cheap and must not double-bind the border face.
        let grid = Grid::new(2, 2);
        let mut r = Router::new(grid);
        let net = 7;
        r.add_input_net(net, 0);
        let c00 = CellCoord::new(0, 0);
        let c01 = CellCoord::new(0, 1);
        r.cfg.cell_mut(c00).op = Some(Op::Pass);
        r.cfg.cell_mut(c01).op = Some(Op::Pass);
        let RouteOutcome::AtInput(p0, d0) = r.route(net, RouteTarget::CellInput(c00)).unwrap()
        else {
            panic!()
        };
        assert_eq!(p0, c00);
        r.bind_fu_operand(c00, 0, d0);
        let RouteOutcome::AtInput(p1, _) = r.route(net, RouteTarget::CellInput(c01)).unwrap()
        else {
            panic!()
        };
        assert_eq!(p1, c01);
        assert_eq!(r.cfg.inputs.len(), 1, "input bound exactly once");
    }

    #[test]
    fn no_path_when_saturated() {
        // 1x1 grid: all four out faces consumed -> no route for a new net.
        let grid = Grid::new(1, 1);
        let mut r = Router::new(grid);
        let p = CellCoord::new(0, 0);
        r.cfg.cell_mut(p).op = Some(Op::Add);
        for d in DIRS {
            r.cfg.cell_mut(p).out[d.index()] = OutSrc::Fu;
        }
        let net = 3;
        r.add_input_net(net, 0);
        // All border in-faces are free, but the consumer needs an in-face;
        // route CAN succeed (in faces are not blocked by out faces).
        assert!(r.route(net, RouteTarget::CellInput(p)).is_ok());
        // A second distinct net to the same cell must use another face.
        let net2 = 4;
        r.add_input_net(net2, 1);
        assert!(r.route(net2, RouteTarget::CellInput(p)).is_ok());
        // Border-out is impossible: all out faces taken.
        let net3 = 5;
        r.add_fu_net(net3, p);
        assert_eq!(r.route(net3, RouteTarget::BorderOut), Err(RouteError::NoPath));
    }

    #[test]
    fn border_out_via_pass_through() {
        // Producer in the middle of a 3x3; border tap requires one hop
        // through a neighboring cell's pass-through.
        let grid = Grid::new(3, 3);
        let mut r = Router::new(grid);
        let mid = CellCoord::new(1, 1);
        r.cfg.cell_mut(mid).op = Some(Op::Add);
        let net = 9;
        r.add_fu_net(net, mid);
        let RouteOutcome::AtBorderOut(p, _) = r.route(net, RouteTarget::BorderOut).unwrap()
        else {
            panic!()
        };
        assert_ne!(p, mid, "tap must be on a border cell");
        assert!(grid.border_dist(p) == 0);
    }
}

//! The compile service: racing seed-portfolio place & route, off the hot
//! path.
//!
//! The paper's Las-Vegas P&R "can require several seconds ... 1.18 s" for
//! the convolution DFG, and its runtime distribution is heavy-tailed: a
//! restart-laden unlucky seed costs many times the median. Two levers make
//! routed artifacts cheap and their production invisible:
//!
//! * **Racing seed portfolio** ([`place_and_route_portfolio`]): K
//!   independently-seeded searches race on a worker pool; the expected
//!   latency of the *minimum* of K heavy-tailed draws sits far below the
//!   single-seed mean (cf. Best-Effort FPGA Programming's parallel
//!   backend sweeps). Ranking is by the searches' deterministic step
//!   counts — not wall time — so the winning artifact is a pure function
//!   of `(base seed, K, warm hint)` (all entrants share the hint): losers
//!   abort as soon as their own step count provably orders after the
//!   published best, which cancels the race in wall time without ever
//!   changing its outcome.
//! * **Background compilation** ([`CompileService`]): jobs are submitted
//!   by cache key and compiled on `std::thread` workers while the
//!   submitter keeps executing its current tier (software or the previous
//!   specialization); finished artifacts are collected with a
//!   non-blocking [`CompileService::poll`] and swapped in at a round
//!   boundary. A tenant never blocks on place & route.
//!
//! Warm starts ([`ParSeed::Warm`]) compose with both: every entrant
//! replays the prior tier's placement before searching, so a
//! respecialization re-places only the DFG delta (RapidWright-style
//! pre-implemented reuse, in overlay form).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::dfe::grid::Grid;
use crate::dfg::graph::Dfg;
use crate::util::prng::Rng;

use super::lasvegas::{
    place_and_route_seeded, ParError, ParParams, ParResult, ParSeed, RaceCtl, RaceState,
};

/// Fixed seed-derivation rule (SplitMix64 finalizer over `base ^ f(k)`):
/// entrant `k` of a portfolio anchored at `base` always searches with the
/// same PRNG stream, which is what makes the race winner reproducible for
/// a given `(base, K)` — the cache key is the natural anchor, so a cached
/// artifact no longer depends on the order compiles happened to run in.
pub fn derive_seed(base: u64, entrant: usize) -> u64 {
    let mut z = base ^ (entrant as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Portfolio tunables.
#[derive(Clone, Copy, Debug)]
pub struct PortfolioParams {
    /// Seeds raced (K >= 1; 1 degenerates to a single seeded search).
    pub k: usize,
    /// Seed-derivation anchor — the artifact's cache key in the offload
    /// paths.
    pub base_seed: u64,
    /// Worker threads for the race (<= 1 runs entrants sequentially; the
    /// winner is identical either way).
    pub threads: usize,
}

/// How one entrant's search ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LapOutcome {
    /// Found a routed configuration (its steps competed for the win).
    Routed,
    /// Cancelled: could no longer beat the published best.
    Aborted,
    /// Exhausted its restart budget.
    Failed,
}

/// Per-entrant race telemetry (the bench's honest per-seed latency).
#[derive(Clone, Copy, Debug)]
pub struct SeedLap {
    pub entrant: usize,
    pub seed: u64,
    /// Deterministic step count at the finish line (0 unless `Routed`).
    pub steps: u64,
    /// Wall time this entrant ran before finishing or aborting.
    pub elapsed: Duration,
    pub outcome: LapOutcome,
}

/// A decided portfolio race.
#[derive(Clone, Debug)]
pub struct PortfolioOutcome {
    /// The winning search's artifact (deterministic for `(base_seed, K)`).
    pub result: ParResult,
    /// Winning entrant index and its derived seed.
    pub entrant: usize,
    pub seed: u64,
    /// All entrants' laps, sorted by entrant index.
    pub laps: Vec<SeedLap>,
}

/// Winner slot: packed `(steps, entrant)` key plus the artifact.
type WinnerSlot = (u64, ParResult, usize, u64);

struct RaceBook {
    race: RaceState,
    winner: Mutex<Option<WinnerSlot>>,
    laps: Mutex<Vec<SeedLap>>,
    first_err: Mutex<Option<ParError>>,
}

impl RaceBook {
    fn new() -> RaceBook {
        RaceBook {
            race: RaceState::new(),
            winner: Mutex::new(None),
            laps: Mutex::new(Vec::new()),
            first_err: Mutex::new(None),
        }
    }

    fn decide(&self, max_restarts: usize) -> Result<PortfolioOutcome, ParError> {
        let winner = self.winner.lock().unwrap().take();
        let mut laps = std::mem::take(&mut *self.laps.lock().unwrap());
        laps.sort_by_key(|l| l.entrant);
        match winner {
            Some((_, result, entrant, seed)) => {
                Ok(PortfolioOutcome { result, entrant, seed, laps })
            }
            None => Err(self
                .first_err
                .lock()
                .unwrap()
                .take()
                .unwrap_or(ParError::Unroutable { restarts: max_restarts })),
        }
    }
}

/// Run one portfolio entrant to completion or abort, folding its outcome
/// into the shared book. Pure with respect to scheduling: the book's
/// final winner does not depend on the order entrants run in.
fn run_entrant(
    dfg: &Dfg,
    grid: Grid,
    params: &ParParams,
    warm: &ParSeed,
    base_seed: u64,
    book: &RaceBook,
    entrant: usize,
) {
    let seed = derive_seed(base_seed, entrant);
    let mut rng = Rng::new(seed);
    let t0 = Instant::now();
    let res = place_and_route_seeded(
        dfg,
        grid,
        params,
        &mut rng,
        warm,
        Some(RaceCtl { state: &book.race, entrant }),
    );
    let lap = match res {
        Ok(result) => {
            let steps = result.stats.search_steps();
            let key = book.race.publish(steps, entrant);
            let mut w = book.winner.lock().unwrap();
            if w.as_ref().map_or(true, |(best, ..)| key < *best) {
                *w = Some((key, result, entrant, seed));
            }
            SeedLap { entrant, seed, steps, elapsed: t0.elapsed(), outcome: LapOutcome::Routed }
        }
        Err(ParError::Aborted) => SeedLap {
            entrant,
            seed,
            steps: 0,
            elapsed: t0.elapsed(),
            outcome: LapOutcome::Aborted,
        },
        Err(e) => {
            let mut slot = book.first_err.lock().unwrap();
            if slot.is_none() {
                *slot = Some(e);
            }
            SeedLap {
                entrant,
                seed,
                steps: 0,
                elapsed: t0.elapsed(),
                outcome: LapOutcome::Failed,
            }
        }
    };
    book.laps.lock().unwrap().push(lap);
}

/// Race K independently-seeded searches and return the deterministic
/// winner. Blocking (the caller waits for the race); the async wrapper is
/// [`CompileService`]. Fails only when *every* entrant exhausts its
/// restart budget — K seeds strengthen, never weaken, the Las-Vegas
/// completeness property.
pub fn place_and_route_portfolio(
    dfg: &Dfg,
    grid: Grid,
    params: &ParParams,
    warm: &ParSeed,
    pf: &PortfolioParams,
) -> Result<PortfolioOutcome, ParError> {
    let k = pf.k.max(1);
    let book = RaceBook::new();
    if k == 1 || pf.threads <= 1 {
        for entrant in 0..k {
            run_entrant(dfg, grid, params, warm, pf.base_seed, &book, entrant);
        }
    } else {
        let next = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..pf.threads.min(k) {
                s.spawn(|| loop {
                    let entrant = next.fetch_add(1, Ordering::Relaxed);
                    if entrant >= k {
                        break;
                    }
                    run_entrant(dfg, grid, params, warm, pf.base_seed, &book, entrant);
                });
            }
        });
    }
    book.decide(params.max_restarts)
}

// ---------------------------------------------------------------------------
// Background compile service
// ---------------------------------------------------------------------------

/// One compile request: a DFG to route on a grid, identified by the cache
/// key its artifact will be stored under (also the portfolio seed anchor).
pub struct CompileJob {
    pub key: u64,
    /// Seed-derivation anchor (usually `key`, optionally mixed with a
    /// configured seed) — must match what a blocking race for the same
    /// artifact would use, so foreground and background compiles of one
    /// key yield the identical winner.
    pub base_seed: u64,
    pub dfg: Dfg,
    pub grid: Grid,
    pub params: ParParams,
    /// Seeds to race (K).
    pub portfolio: usize,
    /// Warm placement hint (the prior tier's), or `Cold`.
    pub warm: ParSeed,
    /// Queue priority (higher races first; the serve layer stamps tenant
    /// hotness here so hot tenants' respecializations land soonest). Only
    /// *scheduling* moves — each job's winner stays the pure function of
    /// `(base_seed, K)`, so priority can never change an artifact. 0 (the
    /// default everywhere else) degenerates to plain FIFO.
    pub priority: u64,
}

/// A finished compile job, delivered by [`CompileService::poll`].
pub struct CompileDone {
    pub key: u64,
    pub outcome: Result<PortfolioOutcome, ParError>,
    /// Submit-to-finish background wall time (the latency the submitter
    /// did *not* stall for).
    pub wall: Duration,
}

struct JobState {
    key: u64,
    base_seed: u64,
    t0: Instant,
    dfg: Dfg,
    grid: Grid,
    params: ParParams,
    warm: ParSeed,
    book: RaceBook,
    remaining: AtomicUsize,
    priority: u64,
}

/// Task queue shared with the workers: per-entrant tasks plus a shutdown
/// flag (set on drop, which also discards queued tasks; each worker
/// finishes at most its in-flight entrant, then exits).
struct TaskQueue {
    tasks: Mutex<(VecDeque<(Arc<JobState>, usize)>, bool)>,
    cv: Condvar,
}

/// A pool of `threads` place-&-route workers. Jobs fan out into one task
/// per portfolio entrant, so a single job still races in parallel and
/// several jobs share the pool fairly (FIFO by entrant). Completion order
/// is wall-clock (poll returns whatever has landed); each job's *content*
/// is deterministic per `(key, portfolio)`.
pub struct CompileService {
    queue: Arc<TaskQueue>,
    done_rx: Receiver<CompileDone>,
    workers: Vec<JoinHandle<()>>,
    submitted: usize,
}

impl CompileService {
    pub fn new(threads: usize) -> CompileService {
        let threads = threads.max(1);
        let queue = Arc::new(TaskQueue {
            tasks: Mutex::new((VecDeque::new(), false)),
            cv: Condvar::new(),
        });
        let (done_tx, done_rx) = channel::<CompileDone>();
        let workers = (0..threads)
            .map(|_| {
                let queue = queue.clone();
                let tx: Sender<CompileDone> = done_tx.clone();
                std::thread::spawn(move || worker_loop(&queue, &tx))
            })
            .collect();
        CompileService { queue, done_rx, workers, submitted: 0 }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Jobs submitted over the service's lifetime.
    pub fn submitted(&self) -> usize {
        self.submitted
    }

    /// Enqueue a job (non-blocking). Key dedup is the caller's business —
    /// the offload layers track in-flight keys so one artifact is never
    /// compiled twice concurrently.
    pub fn submit(&mut self, job: CompileJob) {
        let k = job.portfolio.max(1);
        let state = Arc::new(JobState {
            key: job.key,
            base_seed: job.base_seed,
            t0: Instant::now(),
            dfg: job.dfg,
            grid: job.grid,
            params: job.params,
            warm: job.warm,
            book: RaceBook::new(),
            remaining: AtomicUsize::new(k),
            priority: job.priority,
        });
        {
            let mut g = self.queue.tasks.lock().unwrap();
            // The k-entrant block jumps ahead of every queued task of
            // strictly lower priority, but never splits or reorders equal
            // priorities — all-default (0) submissions keep the exact
            // FIFO order the pre-priority service had.
            let at = g
                .0
                .iter()
                .position(|(s, _)| s.priority < state.priority)
                .unwrap_or(g.0.len());
            for entrant in 0..k {
                g.0.insert(at + entrant, (state.clone(), entrant));
            }
        }
        self.queue.cv.notify_all();
        self.submitted += 1;
    }

    /// Drain every finished job without blocking.
    pub fn poll(&mut self) -> Vec<CompileDone> {
        self.done_rx.try_iter().collect()
    }

    /// Wait up to `timeout` for one finished job (test/drain barriers —
    /// the serving hot path only ever uses [`Self::poll`]).
    pub fn recv_timeout(&mut self, timeout: Duration) -> Option<CompileDone> {
        self.done_rx.recv_timeout(timeout).ok()
    }
}

impl Drop for CompileService {
    fn drop(&mut self) {
        {
            let mut g = self.queue.tasks.lock().unwrap();
            // Discard queued-but-unstarted tasks: nobody can receive their
            // results anymore, and a full Las-Vegas compile per entrant is
            // exactly the shutdown stall this service exists to avoid.
            // Workers finish only the entrant they are currently running.
            g.0.clear();
            g.1 = true;
        }
        self.queue.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(queue: &TaskQueue, done: &Sender<CompileDone>) {
    loop {
        let task = {
            let mut g = queue.tasks.lock().unwrap();
            loop {
                if let Some(t) = g.0.pop_front() {
                    break Some(t);
                }
                if g.1 {
                    break None;
                }
                g = queue.cv.wait(g).unwrap();
            }
        };
        let Some((job, entrant)) = task else { return };
        run_entrant(
            &job.dfg,
            job.grid,
            &job.params,
            &job.warm,
            job.base_seed,
            &job.book,
            entrant,
        );
        if job.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Last entrant across the whole pool: decide and deliver.
            let outcome = job.book.decide(job.params.max_restarts);
            // A send error just means the service handle is gone mid-drop.
            let _ = done.send(CompileDone {
                key: job.key,
                outcome,
                wall: job.t0.elapsed(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::graph::{fig2_dfg, listing1_dfg};

    #[test]
    fn derive_seed_is_stable_and_spreads() {
        assert_eq!(derive_seed(42, 0), derive_seed(42, 0));
        assert_ne!(derive_seed(42, 0), derive_seed(42, 1));
        assert_ne!(derive_seed(42, 0), derive_seed(43, 0));
    }

    #[test]
    fn portfolio_winner_is_deterministic_across_thread_counts() {
        let dfg = listing1_dfg();
        let run = |threads: usize| {
            place_and_route_portfolio(
                &dfg,
                Grid::new(4, 4),
                &ParParams::default(),
                &ParSeed::Cold,
                &PortfolioParams { k: 4, base_seed: 0xBEEF, threads },
            )
            .expect("routable")
        };
        let a = run(1);
        let b = run(4);
        let c = run(4);
        assert_eq!(a.entrant, b.entrant, "winner depends on scheduling");
        assert_eq!(a.result.config, b.result.config);
        assert_eq!(a.result.placement, b.result.placement);
        assert_eq!(b.result.config, c.result.config);
        assert_eq!(a.seed, derive_seed(0xBEEF, a.entrant));
    }

    #[test]
    fn portfolio_of_one_equals_seeded_single_search() {
        let dfg = fig2_dfg();
        let pf = PortfolioParams { k: 1, base_seed: 7, threads: 4 };
        let a = place_and_route_portfolio(
            &dfg,
            Grid::new(4, 4),
            &ParParams::default(),
            &ParSeed::Cold,
            &pf,
        )
        .unwrap();
        let mut rng = Rng::new(derive_seed(7, 0));
        let b = place_and_route_seeded(
            &dfg,
            Grid::new(4, 4),
            &ParParams::default(),
            &mut rng,
            &ParSeed::Cold,
            None,
        )
        .unwrap();
        assert_eq!(a.result.config, b.config);
    }

    #[test]
    fn service_compiles_in_background_and_delivers() {
        let mut svc = CompileService::new(2);
        for key in [11u64, 22, 33] {
            svc.submit(CompileJob {
                key,
                base_seed: key,
                dfg: fig2_dfg(),
                grid: Grid::new(4, 4),
                params: ParParams::default(),
                portfolio: 2,
                warm: ParSeed::Cold,
                priority: 0,
            });
        }
        let mut got = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(30);
        while got.len() < 3 && Instant::now() < deadline {
            if let Some(d) = svc.recv_timeout(Duration::from_millis(200)) {
                got.push(d);
            }
        }
        assert_eq!(got.len(), 3, "all jobs must land");
        got.sort_by_key(|d| d.key);
        assert_eq!(got.iter().map(|d| d.key).collect::<Vec<_>>(), vec![11, 22, 33]);
        for d in &got {
            let o = d.outcome.as_ref().expect("fig2 routes");
            assert!(!o.result.placement.is_empty());
            assert_eq!(o.laps.len(), 2);
            // Same key -> same deterministic winner as a foreground race.
            let fg = place_and_route_portfolio(
                &fig2_dfg(),
                Grid::new(4, 4),
                &ParParams::default(),
                &ParSeed::Cold,
                &PortfolioParams { k: 2, base_seed: d.key, threads: 1 },
            )
            .unwrap();
            assert_eq!(fg.result.config, o.result.config);
            assert_eq!(fg.entrant, o.entrant);
        }
    }
}

//! The Las-Vegas place & route algorithm (paper §III-B).
//!
//! "A stochastic algorithm that ends with a correct solution — if this
//! solution exists." One DFG node is handled at a time:
//!   * node order is random, biased toward nodes adjacent to external
//!     inputs/outputs (border interfaces are scarce — their count equals
//!     the grid perimeter);
//!   * a candidate cell is drawn from a position distribution built from a
//!     narrow Gaussian over the grid plus an attraction term that pulls a
//!     node next to already-placed producers/consumers ("altered to group
//!     nodes together, particularly so if two given nodes share an input
//!     or output");
//!   * all nets to/from already-placed nodes are routed with Dijkstra
//!     (see [`super::route`]); on routing failure the placement backtracks
//!     and retries another position (excluding failed ones);
//!   * after too many failures on a node the algorithm backtracks a random
//!     number of steps; a bounded number of full restarts keeps the
//!     Las-Vegas property while making termination decidable in practice.
//!
//! Because the runtime is stochastic, the paper reports it as "can require
//! several seconds ... 1.18 s" for the 17-in/1-out/16-calc convolution DFG
//! — bench `par_bench` reproduces that distribution shape.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::dfe::config::{FuSrc, GridConfig};
use crate::dfe::grid::{CellCoord, Grid};
use crate::dfe::image::ExecImage;

use crate::dfg::graph::{Dfg, DfgError, NodeId, NodeKind};
use crate::util::prng::Rng;

use super::route::{RouteOutcome, RouteTarget, Router};

/// Tunables for the stochastic search.
#[derive(Clone, Copy, Debug)]
pub struct ParParams {
    /// Candidate positions tried per node before giving up on it.
    pub max_pos_attempts: usize,
    /// Node give-ups before backtracking a random number of steps.
    pub max_node_failures: usize,
    /// Full restarts before declaring the DFG unroutable on this grid.
    pub max_restarts: usize,
    /// Gaussian width of the position prior, as a fraction of grid side.
    pub sigma_frac: f64,
    /// Attraction width for grouping connected nodes.
    pub attract_sigma: f64,
    /// Extra selection weight for I/O-adjacent nodes.
    pub io_bias: f64,
}

impl Default for ParParams {
    fn default() -> Self {
        ParParams {
            max_pos_attempts: 24,
            max_node_failures: 12,
            max_restarts: 40,
            sigma_frac: 0.35,
            attract_sigma: 1.6,
            io_bias: 3.0,
        }
    }
}

/// Statistics of one P&R run (the Las-Vegas behaviour the paper reports).
#[derive(Clone, Copy, Debug, Default)]
pub struct ParStats {
    pub placements: u64,
    pub route_calls: u64,
    pub pos_retries: u64,
    pub backtracks: u64,
    pub restarts: u64,
    /// Cumulative wall time across every restart of this search.
    pub elapsed: Duration,
    /// Wall time of the final attempt alone (the successful one, or the
    /// last restart on failure). `elapsed` folds all prior restarts in;
    /// per-attempt latency must not — the portfolio racer reports honest
    /// per-seed numbers from this field.
    pub attempt_elapsed: Duration,
    /// Nodes successfully replayed from a [`ParSeed::Warm`] placement
    /// before the stochastic search took over.
    pub warm_placed: u64,
}

impl ParStats {
    /// Deterministic progress metric of the search: position attempts plus
    /// net-route calls. Wall-clock independent, monotone while the search
    /// runs — the portfolio racer decides winners on it so the winning
    /// artifact for a given `(base seed, K)` is reproducible regardless of
    /// thread scheduling.
    pub fn search_steps(&self) -> u64 {
        self.placements + self.route_calls
    }
}

/// How the stochastic search is seeded (incremental placement reuse).
#[derive(Clone, Debug, Default)]
pub enum ParSeed {
    /// Start from scratch (the paper's behaviour).
    #[default]
    Cold,
    /// Replay a prior artifact's placement first — respecializing unroll
    /// tier N→N+1 re-places only the DFG delta. Pairs that no longer fit
    /// (unknown node, occupied cell, failed route) are dropped one by one,
    /// a placement off this grid poisons the whole seed, and restarts > 0
    /// always run cold, so the Las-Vegas completeness property survives:
    /// a bad warm seed costs one attempt, never an error.
    Warm(Vec<(NodeId, CellCoord)>),
}

/// Shared state of one portfolio race: the best published
/// `(search_steps, entrant)` pair, packed so a single atomic min decides
/// the winner. An entrant aborts once its own deterministic step count can
/// no longer beat the published best — cancellation cuts wall time while
/// the winner stays a pure function of the seeds.
#[derive(Debug)]
pub struct RaceState {
    /// Packed `(steps << ENTRANT_BITS) | entrant`; `u64::MAX` = no winner.
    best: AtomicU64,
}

impl Default for RaceState {
    fn default() -> Self {
        RaceState::new()
    }
}

const ENTRANT_BITS: u32 = 16;
const STEPS_MAX: u64 = (1 << (64 - ENTRANT_BITS)) - 1;

fn pack_race(steps: u64, entrant: usize) -> u64 {
    (steps.min(STEPS_MAX) << ENTRANT_BITS) | (entrant as u64 & ((1 << ENTRANT_BITS) - 1))
}

impl RaceState {
    pub fn new() -> RaceState {
        RaceState { best: AtomicU64::new(u64::MAX) }
    }

    /// Publish a finished search. Returns the packed key.
    pub fn publish(&self, steps: u64, entrant: usize) -> u64 {
        let key = pack_race(steps, entrant);
        self.best.fetch_min(key, Ordering::AcqRel);
        key
    }

    /// Current best packed key (`u64::MAX` until someone succeeds).
    pub fn best(&self) -> u64 {
        self.best.load(Ordering::Acquire)
    }
}

/// One entrant's handle on a [`RaceState`].
#[derive(Clone, Copy)]
pub struct RaceCtl<'a> {
    pub state: &'a RaceState,
    pub entrant: usize,
}

impl RaceCtl<'_> {
    /// Whether this entrant can no longer win: its partial step count
    /// already orders after the published best. Partial steps only grow,
    /// so an aborted entrant provably loses to the final winner — which
    /// is why aborting keeps the race outcome deterministic.
    fn lost(&self, steps: u64) -> bool {
        pack_race(steps, self.entrant) > self.state.best()
    }
}

#[derive(Clone, Debug)]
pub struct ParResult {
    pub config: GridConfig,
    pub image: ExecImage,
    pub stats: ParStats,
    /// Cell chosen for each placed calc node.
    pub placement: Vec<(NodeId, CellCoord)>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ParError {
    /// More calc nodes than grid cells — can never fit.
    TooLarge { calc: usize, cells: usize },
    /// Unsupported DFG shape (validation failed).
    BadDfg(DfgError),
    /// Gave up after the restart budget (paper: heat-3d on 24x18).
    Unroutable { restarts: usize },
    /// Cancelled by the portfolio race: another seed already won with a
    /// lower step count (never surfaced outside the racer).
    Aborted,
}

impl std::fmt::Display for ParError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParError::TooLarge { calc, cells } => {
                write!(f, "DFG has {calc} calc nodes but the grid only {cells} cells")
            }
            ParError::BadDfg(e) => write!(f, "invalid DFG: {e}"),
            ParError::Unroutable { restarts } => {
                write!(f, "place&route failed after {restarts} restarts")
            }
            ParError::Aborted => write!(f, "place&route cancelled by a winning race entrant"),
        }
    }
}

impl std::error::Error for ParError {}

/// Place & route `dfg` on `grid`. Deterministic for a given `rng` state.
pub fn place_and_route(
    dfg: &Dfg,
    grid: Grid,
    params: &ParParams,
    rng: &mut Rng,
) -> Result<ParResult, ParError> {
    place_and_route_seeded(dfg, grid, params, rng, &ParSeed::Cold, None)
}

/// [`place_and_route`] with an explicit placement seed and optional race
/// membership. Still deterministic for a given `(rng, seed)` pair; `race`
/// only ever turns a would-be result into [`ParError::Aborted`].
pub fn place_and_route_seeded(
    dfg: &Dfg,
    grid: Grid,
    params: &ParParams,
    rng: &mut Rng,
    seed: &ParSeed,
    race: Option<RaceCtl<'_>>,
) -> Result<ParResult, ParError> {
    dfg.validate().map_err(ParError::BadDfg)?;
    let t0 = Instant::now();
    // Normalize: an external output fed directly by a constant gets a PASS
    // cell (constant-masked operand) so it flows through the fabric like
    // everything else.
    let mut normalized;
    let dfg = {
        let needs = dfg.nodes.iter().any(|n| {
            matches!(n.kind, NodeKind::Output(_))
                && matches!(dfg.nodes[n.srcs[0]].kind, NodeKind::Const(_))
        });
        if needs {
            normalized = dfg.clone();
            for id in 0..normalized.nodes.len() {
                if matches!(normalized.nodes[id].kind, NodeKind::Output(_)) {
                    let src = normalized.nodes[id].srcs[0];
                    if matches!(normalized.nodes[src].kind, NodeKind::Const(_)) {
                        let pass = normalized.add(
                            NodeKind::Calc(crate::dfe::opcodes::Op::Pass),
                            vec![src, src],
                        );
                        normalized.nodes[id].srcs[0] = pass;
                    }
                }
            }
            &normalized
        } else {
            dfg
        }
    };
    let calc_nodes: Vec<NodeId> = (0..dfg.len())
        .filter(|&id| matches!(dfg.nodes[id].kind, NodeKind::Calc(_)))
        .collect();
    if calc_nodes.len() > grid.n_cells() {
        return Err(ParError::TooLarge { calc: calc_nodes.len(), cells: grid.n_cells() });
    }

    // Consumers of each node (calc-level fanout), and whether a calc node
    // touches external I/O (for the selection bias).
    let n = dfg.len();
    let mut consumers: Vec<Vec<(NodeId, u8)>> = vec![Vec::new(); n]; // (consumer, operand slot)
    let mut feeds_output: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (id, node) in dfg.nodes.iter().enumerate() {
        match &node.kind {
            NodeKind::Calc(_) => {
                for (slot, &s) in node.srcs.iter().enumerate() {
                    consumers[s].push((id, slot as u8));
                }
            }
            NodeKind::Output(j) => feeds_output[node.srcs[0]].push(*j),
            _ => {}
        }
    }
    let io_adjacent: Vec<bool> = (0..n)
        .map(|id| {
            if !matches!(dfg.nodes[id].kind, NodeKind::Calc(_)) {
                return false;
            }
            let reads_input = dfg.nodes[id]
                .srcs
                .iter()
                .any(|&s| matches!(dfg.nodes[s].kind, NodeKind::Input(_)));
            reads_input || !feeds_output[id].is_empty()
        })
        .collect();

    let mut stats = ParStats::default();
    let sigma = (grid.rows.max(grid.cols) as f64 * params.sigma_frac).max(0.8);

    // A warm placement referencing cells off this grid is poisoned as a
    // whole (an artifact routed for different geometry can't guide this
    // search); an in-bounds one is replayed pair by pair on the first
    // attempt only — restarts always run cold.
    let warm: &[(NodeId, CellCoord)] = match seed {
        ParSeed::Warm(p) if p.iter().all(|&(_, c)| grid.contains(c)) => p,
        _ => &[],
    };

    let mut t_attempt = t0;
    'restart: for restart in 0..=params.max_restarts {
        stats.restarts = restart as u64;
        t_attempt = Instant::now();
        let mut state = SearchState::new(dfg, grid);
        let mut node_failures = 0usize;
        if restart == 0 && !warm.is_empty() {
            stats.warm_placed =
                replay_warm(&mut state, dfg, warm, &consumers, &feeds_output, &mut stats);
        }

        while !state.unplaced.is_empty() {
            if let Some(rc) = race {
                if rc.lost(stats.search_steps()) {
                    stats.elapsed = t0.elapsed();
                    stats.attempt_elapsed = t_attempt.elapsed();
                    return Err(ParError::Aborted);
                }
            }
            // --- node selection: weighted toward I/O-adjacent nodes ---
            let weights: Vec<f64> = state
                .unplaced
                .iter()
                .map(|&id| if io_adjacent[id] { params.io_bias } else { 1.0 })
                .collect();
            let pick = rng.weighted(&weights);
            let node = state.unplaced[pick];

            // Snapshot for node-level backtracking.
            let snapshot = state.clone();
            let mut placed_ok = false;
            let mut tried: Vec<CellCoord> = Vec::new();

            for _attempt in 0..params.max_pos_attempts {
                let Some(cell) =
                    sample_position(&state, grid, node, dfg, params, sigma, &tried, rng)
                else {
                    break;
                };
                tried.push(cell);
                stats.placements += 1;
                match try_place(&mut state, dfg, node, cell, &consumers, &feeds_output, &mut stats)
                {
                    Ok(()) => {
                        placed_ok = true;
                        break;
                    }
                    Err(_) => {
                        stats.pos_retries += 1;
                        state = snapshot.clone();
                    }
                }
            }

            if !placed_ok {
                node_failures += 1;
                stats.backtracks += 1;
                if node_failures > params.max_node_failures {
                    continue 'restart;
                }
                // Backtrack a random number of already-placed nodes.
                let depth = state.placed_order.len();
                if depth == 0 {
                    continue 'restart;
                }
                let back = 1 + rng.below(depth.min(4));
                state.rewind(dfg, back, grid);
            }
        }

        // All calc nodes placed; route remaining external outputs fed
        // directly by inputs (pass-through DFGs) — rare but legal.
        if state.route_passthrough_outputs(dfg).is_err() {
            continue 'restart;
        }

        let config = state.router.cfg.clone();
        match config.to_image() {
            Ok(image) => {
                stats.elapsed = t0.elapsed();
                stats.attempt_elapsed = t_attempt.elapsed();
                return Ok(ParResult {
                    config,
                    image,
                    stats,
                    placement: state.placed_order.clone(),
                });
            }
            Err(_) => continue 'restart,
        }
    }
    stats.elapsed = t0.elapsed();
    stats.attempt_elapsed = t_attempt.elapsed();
    Err(ParError::Unroutable { restarts: params.max_restarts })
}

/// Replay a warm placement onto a fresh search state. Each pair is
/// validated against the *current* DFG and grid: unknown or non-calc
/// nodes, already-used cells and failed routes are simply skipped, so a
/// stale hint degrades to fewer pre-placed nodes, never to an error.
/// Returns how many nodes were placed from the hint.
fn replay_warm(
    state: &mut SearchState,
    dfg: &Dfg,
    warm: &[(NodeId, CellCoord)],
    consumers: &[Vec<(NodeId, u8)>],
    feeds_output: &[Vec<usize>],
    stats: &mut ParStats,
) -> u64 {
    let mut placed = 0u64;
    for &(node, cell) in warm {
        if node >= dfg.len()
            || !matches!(dfg.nodes[node].kind, NodeKind::Calc(_))
            || !state.unplaced.contains(&node)
            || state.cell_used[state.router.grid().index(cell)]
        {
            continue;
        }
        let snapshot = state.clone();
        stats.placements += 1;
        match try_place(state, dfg, node, cell, consumers, feeds_output, stats) {
            Ok(()) => placed += 1,
            Err(()) => {
                stats.pos_retries += 1;
                *state = snapshot;
            }
        }
    }
    placed
}

/// Mutable search state: router + placement bookkeeping. Cloned for
/// snapshots (grids are small; the paper snapshots "previous settings").
#[derive(Clone)]
struct SearchState {
    router: Router,
    unplaced: Vec<NodeId>,
    placed_order: Vec<(NodeId, CellCoord)>,
    cell_used: Vec<bool>,
}

impl SearchState {
    fn new(dfg: &Dfg, grid: Grid) -> SearchState {
        let mut router = Router::new(grid);
        for (id, node) in dfg.nodes.iter().enumerate() {
            if let NodeKind::Input(j) = node.kind {
                router.add_input_net(id, j);
            }
        }
        let unplaced = (0..dfg.len())
            .filter(|&id| matches!(dfg.nodes[id].kind, NodeKind::Calc(_)))
            .collect();
        SearchState {
            router,
            unplaced,
            placed_order: Vec::new(),
            cell_used: vec![false; grid.n_cells()],
        }
    }

    /// Rebuild the state with the last `back` placements undone.
    /// (Routing state is not incrementally reversible; replay is simpler
    /// and the paper's own backtracking "starts from scratch from a
    /// previous setting".)
    fn rewind(&mut self, dfg: &Dfg, back: usize, grid: Grid) {
        let keep = self.placed_order.len().saturating_sub(back);
        let kept: Vec<(NodeId, CellCoord)> = self.placed_order[..keep].to_vec();
        *self = SearchState::new(dfg, grid);
        // Replay kept placements; they were legal before, so they stay
        // legal (the fabric only had *more* nets then).
        let mut consumers: Vec<Vec<(NodeId, u8)>> = vec![Vec::new(); dfg.len()];
        let mut feeds_output: Vec<Vec<usize>> = vec![Vec::new(); dfg.len()];
        for (id, node) in dfg.nodes.iter().enumerate() {
            match &node.kind {
                NodeKind::Calc(_) => {
                    for (slot, &s) in node.srcs.iter().enumerate() {
                        consumers[s].push((id, slot as u8));
                    }
                }
                NodeKind::Output(j) => feeds_output[node.srcs[0]].push(*j),
                _ => {}
            }
        }
        let mut dummy = ParStats::default();
        for (node, cell) in kept {
            let _ = try_place(self, dfg, node, cell, &consumers, &feeds_output, &mut dummy);
        }
    }

    /// Route Input -> Output pass-through pairs (no calc node in between).
    fn route_passthrough_outputs(&mut self, dfg: &Dfg) -> Result<(), ()> {
        for node in &dfg.nodes {
            if let NodeKind::Output(j) = node.kind {
                let src = node.srcs[0];
                if matches!(dfg.nodes[src].kind, NodeKind::Input(_)) {
                    match self.router.route(src, RouteTarget::BorderOut) {
                        Ok(RouteOutcome::AtBorderOut(p, d)) => {
                            self.router.bind_output(p, d, j);
                        }
                        _ => return Err(()),
                    }
                }
            }
        }
        Ok(())
    }
}

/// Position sampling: Gaussian prior over the grid (narrow, centered per
/// the paper) multiplied by an attraction term toward already-placed
/// neighbours; border-adjusted for I/O nodes. Excludes used and
/// previously-failed cells.
#[allow(clippy::too_many_arguments)]
fn sample_position(
    state: &SearchState,
    grid: Grid,
    node: NodeId,
    dfg: &Dfg,
    params: &ParParams,
    sigma: f64,
    exclude: &[CellCoord],
    rng: &mut Rng,
) -> Option<CellCoord> {
    let (cr, cc) = grid.center();
    // Placed neighbours of `node` (producers it reads, consumers reading it).
    let mut anchors: Vec<CellCoord> = Vec::new();
    for &(placed, cell) in &state.placed_order {
        let reads = dfg.nodes[node].srcs.contains(&placed);
        let read_by = dfg.nodes[placed].srcs.contains(&node);
        if reads || read_by {
            anchors.push(cell);
        }
    }
    let touches_io = dfg.nodes[node]
        .srcs
        .iter()
        .any(|&s| matches!(dfg.nodes[s].kind, NodeKind::Input(_)));

    let mut cells = Vec::new();
    let mut weights = Vec::new();
    for p in grid.iter_coords() {
        if state.cell_used[grid.index(p)] || exclude.contains(&p) {
            continue;
        }
        let dr = p.r as f64 - cr;
        let dc = p.c as f64 - cc;
        let d_center2 = dr * dr + dc * dc;
        let mut w = (-d_center2 / (2.0 * sigma * sigma)).exp().max(1e-9);
        if touches_io {
            // Favor the border (scarce interfaces, shorter input paths).
            let bd = grid.border_dist(p) as f64;
            w *= (-(bd * bd) / (2.0 * 1.0)).exp().max(1e-6);
        }
        for a in &anchors {
            let d = p.dist(*a) as f64;
            w *= (-(d * d) / (2.0 * params.attract_sigma * params.attract_sigma))
                .exp()
                .max(1e-6);
        }
        cells.push(p);
        weights.push(w);
    }
    if cells.is_empty() {
        return None;
    }
    Some(cells[rng.weighted(&weights)])
}

/// Try to place `node`'s FU at `cell` and route every net touching an
/// already-placed neighbour (paper: "all previously-placed nodes are
/// checked to see if either they provide an input to the current node, or
/// if they take the node's output as input").
fn try_place(
    state: &mut SearchState,
    dfg: &Dfg,
    node: NodeId,
    cell: CellCoord,
    consumers: &[Vec<(NodeId, u8)>],
    feeds_output: &[Vec<usize>],
    stats: &mut ParStats,
) -> Result<(), ()> {
    let NodeKind::Calc(op) = dfg.nodes[node].kind else {
        return Err(());
    };
    let grid = state.router.grid();
    if state.cell_used[grid.index(cell)] {
        return Err(());
    }
    state.cell_used[grid.index(cell)] = true;
    state.router.cfg.cell_mut(cell).op = Some(op);
    state.router.add_fu_net(node, cell);

    // 1. Operands: consts mask locally; inputs and placed producers route.
    let srcs = dfg.nodes[node].srcs.clone();
    for (slot, &src) in srcs.iter().enumerate() {
        let required = match slot {
            0 => true,
            1 => op.uses_rhs(),
            _ => op.uses_sel(),
        };
        if !required {
            continue;
        }
        match dfg.nodes[src].kind {
            NodeKind::Const(v) => {
                let c = state.router.cfg.cell_mut(cell);
                match slot {
                    0 => c.fu1 = FuSrc::Const(v),
                    1 => c.fu2 = FuSrc::Const(v),
                    _ => c.fsel = FuSrc::Const(v),
                }
            }
            NodeKind::Input(_) => {
                stats.route_calls += 1;
                match state.router.route(src, RouteTarget::CellInput(cell)) {
                    Ok(RouteOutcome::AtInput(_, d)) => {
                        state.router.bind_fu_operand(cell, slot as u8, d)
                    }
                    _ => return Err(()),
                }
            }
            NodeKind::Calc(_) => {
                // Route only if the producer is already placed.
                if state.placed_order.iter().any(|&(id, _)| id == src) {
                    stats.route_calls += 1;
                    match state.router.route(src, RouteTarget::CellInput(cell)) {
                        Ok(RouteOutcome::AtInput(_, d)) => {
                            state.router.bind_fu_operand(cell, slot as u8, d)
                        }
                        _ => return Err(()),
                    }
                }
            }
            NodeKind::Output(_) => return Err(()),
        }
    }

    // 2. Already-placed consumers of this node's result.
    for &(consumer, slot) in &consumers[node] {
        if let Some(&(_, ccell)) =
            state.placed_order.iter().find(|&&(id, _)| id == consumer)
        {
            stats.route_calls += 1;
            match state.router.route(node, RouteTarget::CellInput(ccell)) {
                Ok(RouteOutcome::AtInput(_, d)) => {
                    state.router.bind_fu_operand(ccell, slot, d)
                }
                _ => return Err(()),
            }
        }
    }

    // 3. External outputs fed by this node.
    for &j in &feeds_output[node] {
        stats.route_calls += 1;
        match state.router.route(node, RouteTarget::BorderOut) {
            Ok(RouteOutcome::AtBorderOut(p, d)) => state.router.bind_output(p, d, j),
            _ => return Err(()),
        }
    }

    state.placed_order.push((node, cell));
    state.unplaced.retain(|&id| id != node);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::graph::{fig2_dfg, listing1_dfg};

    fn check_par(dfg: &Dfg, grid: Grid, seed: u64) -> ParResult {
        let mut rng = Rng::new(seed);
        let res = place_and_route(dfg, grid, &ParParams::default(), &mut rng)
            .expect("place&route should succeed");
        // Routed config must evaluate identically to the DFG.
        for trial in 0..8 {
            let mut t = Rng::new(seed ^ (trial + 1));
            let n_in = dfg.max_input_index().map(|m| m + 1).unwrap_or(0);
            let inputs: Vec<i32> = (0..n_in).map(|_| t.range_i64(-1000, 1000) as i32).collect();
            let want = dfg.eval(&inputs).unwrap();
            let got = res.image.eval_scalar(&inputs);
            assert_eq!(got, want, "seed {seed} trial {trial}");
        }
        res
    }

    #[test]
    fn fig2_on_2x2() {
        let res = check_par(&fig2_dfg(), Grid::new(2, 2), 1);
        assert_eq!(res.placement.len(), 3);
    }

    #[test]
    fn fig2_on_8x8_many_seeds() {
        for seed in 0..10 {
            check_par(&fig2_dfg(), Grid::new(8, 8), seed);
        }
    }

    #[test]
    fn listing1_on_4x4() {
        for seed in 0..5 {
            let res = check_par(&listing1_dfg(), Grid::new(4, 4), seed);
            assert_eq!(res.placement.len(), 8);
        }
    }

    #[test]
    fn too_large_rejected_immediately() {
        let g = listing1_dfg(); // 8 calc nodes
        let err = place_and_route(
            &g,
            Grid::new(2, 2),
            &ParParams::default(),
            &mut Rng::new(0),
        )
        .unwrap_err();
        assert_eq!(err, ParError::TooLarge { calc: 8, cells: 4 });
    }

    #[test]
    fn tight_fit_exercises_backtracking() {
        // 8 calc nodes on a 3x3: tight but feasible; the stochastic search
        // must still succeed within the restart budget.
        for seed in 0..3 {
            check_par(&listing1_dfg(), Grid::new(3, 3), 100 + seed);
        }
    }

    #[test]
    fn stats_populated() {
        let res = check_par(&fig2_dfg(), Grid::new(4, 4), 3);
        assert!(res.stats.placements >= 3);
        assert!(res.stats.route_calls >= 4);
        assert_eq!(res.stats.search_steps(), res.stats.placements + res.stats.route_calls);
        assert!(
            res.stats.attempt_elapsed <= res.stats.elapsed,
            "per-attempt time can never exceed the cumulative time"
        );
    }

    #[test]
    fn warm_seed_replays_prior_placement() {
        let dfg = listing1_dfg();
        let mut rng = Rng::new(9);
        let cold =
            place_and_route(&dfg, Grid::new(4, 4), &ParParams::default(), &mut rng).unwrap();
        let mut rng2 = Rng::new(10);
        let warm = place_and_route_seeded(
            &dfg,
            Grid::new(4, 4),
            &ParParams::default(),
            &mut rng2,
            &ParSeed::Warm(cold.placement.clone()),
            None,
        )
        .expect("warm-started search must still succeed");
        assert!(warm.stats.warm_placed >= 1, "a same-grid hint must pre-place nodes");
        let inputs: Vec<i32> = (0..dfg.max_input_index().unwrap() + 1)
            .map(|i| i as i32 * 3 - 7)
            .collect();
        assert_eq!(warm.image.eval_scalar(&inputs), dfg.eval(&inputs).unwrap());
    }

    #[test]
    fn poisoned_warm_seed_falls_back_to_cold() {
        // Placement cells off this grid: the whole hint is discarded and
        // the search runs cold instead of erroring.
        let dfg = fig2_dfg();
        let poisoned = ParSeed::Warm(vec![(2, CellCoord::new(10, 10))]);
        let mut rng = Rng::new(3);
        let res = place_and_route_seeded(
            &dfg,
            Grid::new(2, 2),
            &ParParams::default(),
            &mut rng,
            &poisoned,
            None,
        )
        .expect("poisoned seed must fall back, not error");
        assert_eq!(res.stats.warm_placed, 0);
        let mut rng2 = Rng::new(3);
        let cold =
            place_and_route(&dfg, Grid::new(2, 2), &ParParams::default(), &mut rng2).unwrap();
        assert_eq!(res.config, cold.config, "poisoned warm run must equal the cold run");
    }

    #[test]
    fn race_abort_when_best_already_published() {
        let state = RaceState::new();
        // Entrant 0 "won" instantly with 0 steps: entrant 1 must abort.
        state.publish(0, 0);
        let mut rng = Rng::new(5);
        let err = place_and_route_seeded(
            &fig2_dfg(),
            Grid::new(4, 4),
            &ParParams::default(),
            &mut rng,
            &ParSeed::Cold,
            Some(RaceCtl { state: &state, entrant: 1 }),
        )
        .unwrap_err();
        assert_eq!(err, ParError::Aborted);
    }
}

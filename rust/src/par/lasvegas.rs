//! The Las-Vegas place & route algorithm (paper §III-B).
//!
//! "A stochastic algorithm that ends with a correct solution — if this
//! solution exists." One DFG node is handled at a time:
//!   * node order is random, biased toward nodes adjacent to external
//!     inputs/outputs (border interfaces are scarce — their count equals
//!     the grid perimeter);
//!   * a candidate cell is drawn from a position distribution built from a
//!     narrow Gaussian over the grid plus an attraction term that pulls a
//!     node next to already-placed producers/consumers ("altered to group
//!     nodes together, particularly so if two given nodes share an input
//!     or output");
//!   * all nets to/from already-placed nodes are routed with Dijkstra
//!     (see [`super::route`]); on routing failure the placement backtracks
//!     and retries another position (excluding failed ones);
//!   * after too many failures on a node the algorithm backtracks a random
//!     number of steps; a bounded number of full restarts keeps the
//!     Las-Vegas property while making termination decidable in practice.
//!
//! Because the runtime is stochastic, the paper reports it as "can require
//! several seconds ... 1.18 s" for the 17-in/1-out/16-calc convolution DFG
//! — bench `par_bench` reproduces that distribution shape.

use std::time::{Duration, Instant};

use crate::dfe::config::{FuSrc, GridConfig};
use crate::dfe::grid::{CellCoord, Grid};
use crate::dfe::image::ExecImage;

use crate::dfg::graph::{Dfg, DfgError, NodeId, NodeKind};
use crate::util::prng::Rng;

use super::route::{RouteOutcome, RouteTarget, Router};

/// Tunables for the stochastic search.
#[derive(Clone, Copy, Debug)]
pub struct ParParams {
    /// Candidate positions tried per node before giving up on it.
    pub max_pos_attempts: usize,
    /// Node give-ups before backtracking a random number of steps.
    pub max_node_failures: usize,
    /// Full restarts before declaring the DFG unroutable on this grid.
    pub max_restarts: usize,
    /// Gaussian width of the position prior, as a fraction of grid side.
    pub sigma_frac: f64,
    /// Attraction width for grouping connected nodes.
    pub attract_sigma: f64,
    /// Extra selection weight for I/O-adjacent nodes.
    pub io_bias: f64,
}

impl Default for ParParams {
    fn default() -> Self {
        ParParams {
            max_pos_attempts: 24,
            max_node_failures: 12,
            max_restarts: 40,
            sigma_frac: 0.35,
            attract_sigma: 1.6,
            io_bias: 3.0,
        }
    }
}

/// Statistics of one P&R run (the Las-Vegas behaviour the paper reports).
#[derive(Clone, Copy, Debug, Default)]
pub struct ParStats {
    pub placements: u64,
    pub route_calls: u64,
    pub pos_retries: u64,
    pub backtracks: u64,
    pub restarts: u64,
    pub elapsed: Duration,
}

#[derive(Clone, Debug)]
pub struct ParResult {
    pub config: GridConfig,
    pub image: ExecImage,
    pub stats: ParStats,
    /// Cell chosen for each placed calc node.
    pub placement: Vec<(NodeId, CellCoord)>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ParError {
    /// More calc nodes than grid cells — can never fit.
    TooLarge { calc: usize, cells: usize },
    /// Unsupported DFG shape (validation failed).
    BadDfg(DfgError),
    /// Gave up after the restart budget (paper: heat-3d on 24x18).
    Unroutable { restarts: usize },
}

impl std::fmt::Display for ParError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParError::TooLarge { calc, cells } => {
                write!(f, "DFG has {calc} calc nodes but the grid only {cells} cells")
            }
            ParError::BadDfg(e) => write!(f, "invalid DFG: {e}"),
            ParError::Unroutable { restarts } => {
                write!(f, "place&route failed after {restarts} restarts")
            }
        }
    }
}

impl std::error::Error for ParError {}

/// Place & route `dfg` on `grid`. Deterministic for a given `rng` state.
pub fn place_and_route(
    dfg: &Dfg,
    grid: Grid,
    params: &ParParams,
    rng: &mut Rng,
) -> Result<ParResult, ParError> {
    dfg.validate().map_err(ParError::BadDfg)?;
    let t0 = Instant::now();
    // Normalize: an external output fed directly by a constant gets a PASS
    // cell (constant-masked operand) so it flows through the fabric like
    // everything else.
    let mut normalized;
    let dfg = {
        let needs = dfg.nodes.iter().any(|n| {
            matches!(n.kind, NodeKind::Output(_))
                && matches!(dfg.nodes[n.srcs[0]].kind, NodeKind::Const(_))
        });
        if needs {
            normalized = dfg.clone();
            for id in 0..normalized.nodes.len() {
                if matches!(normalized.nodes[id].kind, NodeKind::Output(_)) {
                    let src = normalized.nodes[id].srcs[0];
                    if matches!(normalized.nodes[src].kind, NodeKind::Const(_)) {
                        let pass = normalized.add(
                            NodeKind::Calc(crate::dfe::opcodes::Op::Pass),
                            vec![src, src],
                        );
                        normalized.nodes[id].srcs[0] = pass;
                    }
                }
            }
            &normalized
        } else {
            dfg
        }
    };
    let calc_nodes: Vec<NodeId> = (0..dfg.len())
        .filter(|&id| matches!(dfg.nodes[id].kind, NodeKind::Calc(_)))
        .collect();
    if calc_nodes.len() > grid.n_cells() {
        return Err(ParError::TooLarge { calc: calc_nodes.len(), cells: grid.n_cells() });
    }

    // Consumers of each node (calc-level fanout), and whether a calc node
    // touches external I/O (for the selection bias).
    let n = dfg.len();
    let mut consumers: Vec<Vec<(NodeId, u8)>> = vec![Vec::new(); n]; // (consumer, operand slot)
    let mut feeds_output: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (id, node) in dfg.nodes.iter().enumerate() {
        match &node.kind {
            NodeKind::Calc(_) => {
                for (slot, &s) in node.srcs.iter().enumerate() {
                    consumers[s].push((id, slot as u8));
                }
            }
            NodeKind::Output(j) => feeds_output[node.srcs[0]].push(*j),
            _ => {}
        }
    }
    let io_adjacent: Vec<bool> = (0..n)
        .map(|id| {
            if !matches!(dfg.nodes[id].kind, NodeKind::Calc(_)) {
                return false;
            }
            let reads_input = dfg.nodes[id]
                .srcs
                .iter()
                .any(|&s| matches!(dfg.nodes[s].kind, NodeKind::Input(_)));
            reads_input || !feeds_output[id].is_empty()
        })
        .collect();

    let mut stats = ParStats::default();
    let sigma = (grid.rows.max(grid.cols) as f64 * params.sigma_frac).max(0.8);

    'restart: for restart in 0..=params.max_restarts {
        stats.restarts = restart as u64;
        let mut state = SearchState::new(dfg, grid);
        let mut node_failures = 0usize;

        while !state.unplaced.is_empty() {
            // --- node selection: weighted toward I/O-adjacent nodes ---
            let weights: Vec<f64> = state
                .unplaced
                .iter()
                .map(|&id| if io_adjacent[id] { params.io_bias } else { 1.0 })
                .collect();
            let pick = rng.weighted(&weights);
            let node = state.unplaced[pick];

            // Snapshot for node-level backtracking.
            let snapshot = state.clone();
            let mut placed_ok = false;
            let mut tried: Vec<CellCoord> = Vec::new();

            for _attempt in 0..params.max_pos_attempts {
                let Some(cell) =
                    sample_position(&state, grid, node, dfg, params, sigma, &tried, rng)
                else {
                    break;
                };
                tried.push(cell);
                stats.placements += 1;
                match try_place(&mut state, dfg, node, cell, &consumers, &feeds_output, &mut stats)
                {
                    Ok(()) => {
                        placed_ok = true;
                        break;
                    }
                    Err(_) => {
                        stats.pos_retries += 1;
                        state = snapshot.clone();
                    }
                }
            }

            if !placed_ok {
                node_failures += 1;
                stats.backtracks += 1;
                if node_failures > params.max_node_failures {
                    continue 'restart;
                }
                // Backtrack a random number of already-placed nodes.
                let depth = state.placed_order.len();
                if depth == 0 {
                    continue 'restart;
                }
                let back = 1 + rng.below(depth.min(4));
                state.rewind(dfg, back, grid);
            }
        }

        // All calc nodes placed; route remaining external outputs fed
        // directly by inputs (pass-through DFGs) — rare but legal.
        if state.route_passthrough_outputs(dfg).is_err() {
            continue 'restart;
        }

        let config = state.router.cfg.clone();
        match config.to_image() {
            Ok(image) => {
                stats.elapsed = t0.elapsed();
                return Ok(ParResult {
                    config,
                    image,
                    stats,
                    placement: state.placed_order.clone(),
                });
            }
            Err(_) => continue 'restart,
        }
    }
    stats.elapsed = t0.elapsed();
    Err(ParError::Unroutable { restarts: params.max_restarts })
}

/// Mutable search state: router + placement bookkeeping. Cloned for
/// snapshots (grids are small; the paper snapshots "previous settings").
#[derive(Clone)]
struct SearchState {
    router: Router,
    unplaced: Vec<NodeId>,
    placed_order: Vec<(NodeId, CellCoord)>,
    cell_used: Vec<bool>,
}

impl SearchState {
    fn new(dfg: &Dfg, grid: Grid) -> SearchState {
        let mut router = Router::new(grid);
        for (id, node) in dfg.nodes.iter().enumerate() {
            if let NodeKind::Input(j) = node.kind {
                router.add_input_net(id, j);
            }
        }
        let unplaced = (0..dfg.len())
            .filter(|&id| matches!(dfg.nodes[id].kind, NodeKind::Calc(_)))
            .collect();
        SearchState {
            router,
            unplaced,
            placed_order: Vec::new(),
            cell_used: vec![false; grid.n_cells()],
        }
    }

    /// Rebuild the state with the last `back` placements undone.
    /// (Routing state is not incrementally reversible; replay is simpler
    /// and the paper's own backtracking "starts from scratch from a
    /// previous setting".)
    fn rewind(&mut self, dfg: &Dfg, back: usize, grid: Grid) {
        let keep = self.placed_order.len().saturating_sub(back);
        let kept: Vec<(NodeId, CellCoord)> = self.placed_order[..keep].to_vec();
        *self = SearchState::new(dfg, grid);
        // Replay kept placements; they were legal before, so they stay
        // legal (the fabric only had *more* nets then).
        let mut consumers: Vec<Vec<(NodeId, u8)>> = vec![Vec::new(); dfg.len()];
        let mut feeds_output: Vec<Vec<usize>> = vec![Vec::new(); dfg.len()];
        for (id, node) in dfg.nodes.iter().enumerate() {
            match &node.kind {
                NodeKind::Calc(_) => {
                    for (slot, &s) in node.srcs.iter().enumerate() {
                        consumers[s].push((id, slot as u8));
                    }
                }
                NodeKind::Output(j) => feeds_output[node.srcs[0]].push(*j),
                _ => {}
            }
        }
        let mut dummy = ParStats::default();
        for (node, cell) in kept {
            let _ = try_place(self, dfg, node, cell, &consumers, &feeds_output, &mut dummy);
        }
    }

    /// Route Input -> Output pass-through pairs (no calc node in between).
    fn route_passthrough_outputs(&mut self, dfg: &Dfg) -> Result<(), ()> {
        for node in &dfg.nodes {
            if let NodeKind::Output(j) = node.kind {
                let src = node.srcs[0];
                if matches!(dfg.nodes[src].kind, NodeKind::Input(_)) {
                    match self.router.route(src, RouteTarget::BorderOut) {
                        Ok(RouteOutcome::AtBorderOut(p, d)) => {
                            self.router.bind_output(p, d, j);
                        }
                        _ => return Err(()),
                    }
                }
            }
        }
        Ok(())
    }
}

/// Position sampling: Gaussian prior over the grid (narrow, centered per
/// the paper) multiplied by an attraction term toward already-placed
/// neighbours; border-adjusted for I/O nodes. Excludes used and
/// previously-failed cells.
#[allow(clippy::too_many_arguments)]
fn sample_position(
    state: &SearchState,
    grid: Grid,
    node: NodeId,
    dfg: &Dfg,
    params: &ParParams,
    sigma: f64,
    exclude: &[CellCoord],
    rng: &mut Rng,
) -> Option<CellCoord> {
    let (cr, cc) = grid.center();
    // Placed neighbours of `node` (producers it reads, consumers reading it).
    let mut anchors: Vec<CellCoord> = Vec::new();
    for &(placed, cell) in &state.placed_order {
        let reads = dfg.nodes[node].srcs.contains(&placed);
        let read_by = dfg.nodes[placed].srcs.contains(&node);
        if reads || read_by {
            anchors.push(cell);
        }
    }
    let touches_io = dfg.nodes[node]
        .srcs
        .iter()
        .any(|&s| matches!(dfg.nodes[s].kind, NodeKind::Input(_)));

    let mut cells = Vec::new();
    let mut weights = Vec::new();
    for p in grid.iter_coords() {
        if state.cell_used[grid.index(p)] || exclude.contains(&p) {
            continue;
        }
        let dr = p.r as f64 - cr;
        let dc = p.c as f64 - cc;
        let d_center2 = dr * dr + dc * dc;
        let mut w = (-d_center2 / (2.0 * sigma * sigma)).exp().max(1e-9);
        if touches_io {
            // Favor the border (scarce interfaces, shorter input paths).
            let bd = grid.border_dist(p) as f64;
            w *= (-(bd * bd) / (2.0 * 1.0)).exp().max(1e-6);
        }
        for a in &anchors {
            let d = p.dist(*a) as f64;
            w *= (-(d * d) / (2.0 * params.attract_sigma * params.attract_sigma))
                .exp()
                .max(1e-6);
        }
        cells.push(p);
        weights.push(w);
    }
    if cells.is_empty() {
        return None;
    }
    Some(cells[rng.weighted(&weights)])
}

/// Try to place `node`'s FU at `cell` and route every net touching an
/// already-placed neighbour (paper: "all previously-placed nodes are
/// checked to see if either they provide an input to the current node, or
/// if they take the node's output as input").
fn try_place(
    state: &mut SearchState,
    dfg: &Dfg,
    node: NodeId,
    cell: CellCoord,
    consumers: &[Vec<(NodeId, u8)>],
    feeds_output: &[Vec<usize>],
    stats: &mut ParStats,
) -> Result<(), ()> {
    let NodeKind::Calc(op) = dfg.nodes[node].kind else {
        return Err(());
    };
    let grid = state.router.grid();
    if state.cell_used[grid.index(cell)] {
        return Err(());
    }
    state.cell_used[grid.index(cell)] = true;
    state.router.cfg.cell_mut(cell).op = Some(op);
    state.router.add_fu_net(node, cell);

    // 1. Operands: consts mask locally; inputs and placed producers route.
    let srcs = dfg.nodes[node].srcs.clone();
    for (slot, &src) in srcs.iter().enumerate() {
        let required = match slot {
            0 => true,
            1 => op.uses_rhs(),
            _ => op.uses_sel(),
        };
        if !required {
            continue;
        }
        match dfg.nodes[src].kind {
            NodeKind::Const(v) => {
                let c = state.router.cfg.cell_mut(cell);
                match slot {
                    0 => c.fu1 = FuSrc::Const(v),
                    1 => c.fu2 = FuSrc::Const(v),
                    _ => c.fsel = FuSrc::Const(v),
                }
            }
            NodeKind::Input(_) => {
                stats.route_calls += 1;
                match state.router.route(src, RouteTarget::CellInput(cell)) {
                    Ok(RouteOutcome::AtInput(_, d)) => {
                        state.router.bind_fu_operand(cell, slot as u8, d)
                    }
                    _ => return Err(()),
                }
            }
            NodeKind::Calc(_) => {
                // Route only if the producer is already placed.
                if state.placed_order.iter().any(|&(id, _)| id == src) {
                    stats.route_calls += 1;
                    match state.router.route(src, RouteTarget::CellInput(cell)) {
                        Ok(RouteOutcome::AtInput(_, d)) => {
                            state.router.bind_fu_operand(cell, slot as u8, d)
                        }
                        _ => return Err(()),
                    }
                }
            }
            NodeKind::Output(_) => return Err(()),
        }
    }

    // 2. Already-placed consumers of this node's result.
    for &(consumer, slot) in &consumers[node] {
        if let Some(&(_, ccell)) =
            state.placed_order.iter().find(|&&(id, _)| id == consumer)
        {
            stats.route_calls += 1;
            match state.router.route(node, RouteTarget::CellInput(ccell)) {
                Ok(RouteOutcome::AtInput(_, d)) => {
                    state.router.bind_fu_operand(ccell, slot, d)
                }
                _ => return Err(()),
            }
        }
    }

    // 3. External outputs fed by this node.
    for &j in &feeds_output[node] {
        stats.route_calls += 1;
        match state.router.route(node, RouteTarget::BorderOut) {
            Ok(RouteOutcome::AtBorderOut(p, d)) => state.router.bind_output(p, d, j),
            _ => return Err(()),
        }
    }

    state.placed_order.push((node, cell));
    state.unplaced.retain(|&id| id != node);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::graph::{fig2_dfg, listing1_dfg};

    fn check_par(dfg: &Dfg, grid: Grid, seed: u64) -> ParResult {
        let mut rng = Rng::new(seed);
        let res = place_and_route(dfg, grid, &ParParams::default(), &mut rng)
            .expect("place&route should succeed");
        // Routed config must evaluate identically to the DFG.
        for trial in 0..8 {
            let mut t = Rng::new(seed ^ (trial + 1));
            let n_in = dfg.max_input_index().map(|m| m + 1).unwrap_or(0);
            let inputs: Vec<i32> = (0..n_in).map(|_| t.range_i64(-1000, 1000) as i32).collect();
            let want = dfg.eval(&inputs).unwrap();
            let got = res.image.eval_scalar(&inputs);
            assert_eq!(got, want, "seed {seed} trial {trial}");
        }
        res
    }

    #[test]
    fn fig2_on_2x2() {
        let res = check_par(&fig2_dfg(), Grid::new(2, 2), 1);
        assert_eq!(res.placement.len(), 3);
    }

    #[test]
    fn fig2_on_8x8_many_seeds() {
        for seed in 0..10 {
            check_par(&fig2_dfg(), Grid::new(8, 8), seed);
        }
    }

    #[test]
    fn listing1_on_4x4() {
        for seed in 0..5 {
            let res = check_par(&listing1_dfg(), Grid::new(4, 4), seed);
            assert_eq!(res.placement.len(), 8);
        }
    }

    #[test]
    fn too_large_rejected_immediately() {
        let g = listing1_dfg(); // 8 calc nodes
        let err = place_and_route(
            &g,
            Grid::new(2, 2),
            &ParParams::default(),
            &mut Rng::new(0),
        )
        .unwrap_err();
        assert_eq!(err, ParError::TooLarge { calc: 8, cells: 4 });
    }

    #[test]
    fn tight_fit_exercises_backtracking() {
        // 8 calc nodes on a 3x3: tight but feasible; the stochastic search
        // must still succeed within the restart budget.
        for seed in 0..3 {
            check_par(&listing1_dfg(), Grid::new(3, 3), 100 + seed);
        }
    }

    #[test]
    fn stats_populated() {
        let res = check_par(&fig2_dfg(), Grid::new(4, 4), 3);
        assert!(res.stats.placements >= 3);
        assert!(res.stats.route_calls >= 4);
    }
}

//! Structured diagnostics for the static artifact verifier
//! (`analysis::verifier`).
//!
//! Every verifier pass reports through one shape — `Diag { pass,
//! severity, location, message }` — so the CLI (`tlo lint`), the
//! debug-build sanitizer hooks and the mutation self-test harness all
//! consume the same stream. Ordering is deterministic: diagnostics sort
//! by (pass, severity, location, message), so two runs over the same
//! artifact render byte-identical tables (locked by proptest `p12_`).

use std::fmt;

/// The verifier passes, in pipeline order. See DESIGN.md §11 for what
/// each pass re-derives.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Pass {
    /// IR ↔ DFG consistency (extraction boundary).
    V1IrDfg,
    /// Grid-configuration legality, re-proved independently of P&R.
    V2GridLegality,
    /// Wave-schedule hazard analysis on `CompiledFabric`.
    V3WaveHazard,
    /// Tiled-execution-plan soundness.
    V4PlanSoundness,
    /// Persisted-snapshot integrity (load-time re-verification).
    V5SnapshotIntegrity,
    /// Lowered-batch-kernel equivalence: translation validation of the
    /// folding/aliasing/fusion decisions against the wave schedule.
    V6LoweredKernel,
}

impl Pass {
    pub fn name(self) -> &'static str {
        match self {
            Pass::V1IrDfg => "V1",
            Pass::V2GridLegality => "V2",
            Pass::V3WaveHazard => "V3",
            Pass::V4PlanSoundness => "V4",
            Pass::V5SnapshotIntegrity => "V5",
            Pass::V6LoweredKernel => "V6",
        }
    }

    pub fn title(self) -> &'static str {
        match self {
            Pass::V1IrDfg => "IR/DFG consistency",
            Pass::V2GridLegality => "grid-config legality",
            Pass::V3WaveHazard => "wave-schedule hazards",
            Pass::V4PlanSoundness => "tiled-plan soundness",
            Pass::V5SnapshotIntegrity => "snapshot integrity",
            Pass::V6LoweredKernel => "lowered-kernel equivalence",
        }
    }
}

impl fmt::Display for Pass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Errors fail verification (the sanitizer rejects the artifact);
/// warnings flag convention drift that cannot corrupt numerics. `Error`
/// orders first so sorted output leads with what matters.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    Error,
    Warning,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
        })
    }
}

/// One finding: which pass, how severe, where (a human-readable artifact
/// coordinate like `cell (1,0)` or `tile 2 sink 0`), and what.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Diag {
    pub pass: Pass,
    pub severity: Severity,
    pub location: String,
    pub message: String,
}

impl Diag {
    pub fn error(pass: Pass, location: impl Into<String>, message: impl Into<String>) -> Diag {
        Diag { pass, severity: Severity::Error, location: location.into(), message: message.into() }
    }

    pub fn warning(pass: Pass, location: impl Into<String>, message: impl Into<String>) -> Diag {
        Diag {
            pass,
            severity: Severity::Warning,
            location: location.into(),
            message: message.into(),
        }
    }
}

impl fmt::Display for Diag {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}/{}] {}: {}", self.pass, self.severity, self.location, self.message)
    }
}

/// Canonical deterministic order: pass, then severity (errors first),
/// then location, then message. Every verifier entry point returns its
/// findings already sorted through this.
pub fn sort_diags(diags: &mut [Diag]) {
    diags.sort();
}

pub fn has_errors(diags: &[Diag]) -> bool {
    diags.iter().any(|d| d.severity == Severity::Error)
}

pub fn error_count(diags: &[Diag]) -> usize {
    diags.iter().filter(|d| d.severity == Severity::Error).count()
}

/// Render a sorted diagnostic stream as an aligned table (the `tlo lint`
/// output format). Empty input renders an empty string.
pub fn render_table(diags: &[Diag]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let loc_w = diags.iter().map(|d| d.location.len()).max().unwrap_or(0).max(8);
    for d in diags {
        let _ = writeln!(
            out,
            "  {:<2} {:<7} {:<loc_w$}  {}",
            d.pass.name(),
            d.severity.to_string(),
            d.location,
            d.message,
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_is_pass_severity_location_message() {
        let mut v = vec![
            Diag::warning(Pass::V2GridLegality, "b", "w"),
            Diag::error(Pass::V3WaveHazard, "a", "x"),
            Diag::error(Pass::V2GridLegality, "a", "y"),
            Diag::error(Pass::V2GridLegality, "a", "x"),
        ];
        sort_diags(&mut v);
        let rendered: Vec<String> = v.iter().map(|d| d.to_string()).collect();
        assert_eq!(
            rendered,
            vec![
                "[V2/error] a: x",
                "[V2/error] a: y",
                "[V2/warning] b: w",
                "[V3/error] a: x",
            ]
        );
        assert!(has_errors(&v));
        assert_eq!(error_count(&v), 3);
    }

    #[test]
    fn table_renders_aligned_rows_and_empty_input_is_empty() {
        assert_eq!(render_table(&[]), "");
        let v = [Diag::error(Pass::V5SnapshotIntegrity, "entry 0x1", "truncated")];
        let t = render_table(&v);
        assert!(t.contains("V5") && t.contains("error") && t.contains("truncated"));
    }
}

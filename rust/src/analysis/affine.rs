//! Affine (+ parametric-stride) expressions over loop induction variables
//! and function parameters — the currency of SCoP detection (paper §III:
//! "a custom-made automatic parallelizer inspired by Polly").
//!
//! Multi-dimensional array subscripts linearize as `i*n + j` — bilinear in
//! an induction variable and a *parameter*. Classic affine forms cannot
//! express that (Polly recovers it by delinearization); here the form
//! carries explicit `iv x param` cross terms:
//!
//! `k + Σ c_d·iv_d + Σ c_p·param_p + Σ c_{d,p}·iv_d·param_p`

use std::collections::BTreeMap;
use std::fmt;

use crate::ir::instr::Reg;

#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct Affine {
    pub k: i64,
    /// loop depth (0 = outermost of the enclosing nest) -> coefficient.
    pub iv: BTreeMap<usize, i64>,
    /// parameter register -> coefficient.
    pub params: BTreeMap<Reg, i64>,
    /// (loop depth, parameter) -> coefficient of the product term.
    pub cross: BTreeMap<(usize, Reg), i64>,
}

impl Affine {
    pub fn constant(k: i64) -> Affine {
        Affine { k, ..Default::default() }
    }

    pub fn iv(depth: usize) -> Affine {
        let mut m = BTreeMap::new();
        m.insert(depth, 1);
        Affine { iv: m, ..Default::default() }
    }

    pub fn param(r: Reg) -> Affine {
        let mut m = BTreeMap::new();
        m.insert(r, 1);
        Affine { params: m, ..Default::default() }
    }

    pub fn is_constant(&self) -> bool {
        self.iv.is_empty() && self.params.is_empty() && self.cross.is_empty()
    }

    pub fn as_constant(&self) -> Option<i64> {
        self.is_constant().then_some(self.k)
    }

    /// Free of induction variables (a pure parameter expression)?
    pub fn is_param_only(&self) -> bool {
        self.iv.is_empty() && self.cross.is_empty()
    }

    /// Free of parameters (ivs and constant only)?
    pub fn is_iv_only(&self) -> bool {
        self.params.is_empty() && self.cross.is_empty()
    }

    pub fn add(&self, other: &Affine) -> Affine {
        let mut r = self.clone();
        r.k += other.k;
        for (&d, &c) in &other.iv {
            *r.iv.entry(d).or_insert(0) += c;
        }
        for (&p, &c) in &other.params {
            *r.params.entry(p).or_insert(0) += c;
        }
        for (&dp, &c) in &other.cross {
            *r.cross.entry(dp).or_insert(0) += c;
        }
        r.normalize()
    }

    pub fn sub(&self, other: &Affine) -> Affine {
        self.add(&other.scale(-1))
    }

    pub fn scale(&self, c: i64) -> Affine {
        Affine {
            k: self.k * c,
            iv: self.iv.iter().map(|(&d, &v)| (d, v * c)).collect(),
            params: self.params.iter().map(|(&p, &v)| (p, v * c)).collect(),
            cross: self.cross.iter().map(|(&dp, &v)| (dp, v * c)).collect(),
        }
        .normalize()
    }

    /// Product. Defined when one side is constant, or when one side is a
    /// pure iv form and the other a pure parameter form (producing cross
    /// terms). Anything higher-order returns `None` (non-affine).
    pub fn mul(&self, other: &Affine) -> Option<Affine> {
        if let Some(c) = other.as_constant() {
            return Some(self.scale(c));
        }
        if let Some(c) = self.as_constant() {
            return Some(other.scale(c));
        }
        let (ivs, pars) = if self.is_iv_only() && other.is_param_only() {
            (self, other)
        } else if other.is_iv_only() && self.is_param_only() {
            (other, self)
        } else {
            return None;
        };
        // (k1 + Σ c_d iv_d) * (k2 + Σ c_p p) =
        //   k1k2 + Σ k2·c_d·iv_d + Σ k1·c_p·p + Σ c_d·c_p·iv_d·p
        let mut r = Affine::constant(ivs.k * pars.k);
        for (&d, &cd) in &ivs.iv {
            *r.iv.entry(d).or_insert(0) += cd * pars.k;
            for (&p, &cp) in &pars.params {
                *r.cross.entry((d, p)).or_insert(0) += cd * cp;
            }
        }
        for (&p, &cp) in &pars.params {
            *r.params.entry(p).or_insert(0) += cp * ivs.k;
        }
        Some(r.normalize())
    }

    fn normalize(mut self) -> Affine {
        self.iv.retain(|_, c| *c != 0);
        self.params.retain(|_, c| *c != 0);
        self.cross.retain(|_, c| *c != 0);
        self
    }

    /// Does loop dimension `d` influence this expression at all?
    /// (cross terms count: their parameter strides are nonzero at run
    /// time for any non-degenerate array).
    pub fn depends_on_iv(&self, d: usize) -> bool {
        self.iv.contains_key(&d) || self.cross.keys().any(|&(dd, _)| dd == d)
    }

    /// Plain (parameter-free) coefficient of dimension `d`.
    pub fn iv_coeff(&self, d: usize) -> i64 {
        self.iv.get(&d).copied().unwrap_or(0)
    }

    /// Substitute `iv_d := iv_d + delta` (unrolling shift).
    pub fn shift_iv(&self, d: usize, delta: i64) -> Affine {
        let mut r = self.clone();
        r.k += self.iv_coeff(d) * delta;
        for (&(dd, p), &c) in &self.cross {
            if dd == d {
                *r.params.entry(p).or_insert(0) += c * delta;
            }
        }
        r.normalize()
    }

    /// Evaluate with concrete iv values and parameter values.
    pub fn eval(&self, ivs: &[i64], params: &dyn Fn(Reg) -> i64) -> i64 {
        let mut v = self.k;
        for (&d, &c) in &self.iv {
            v += c * ivs.get(d).copied().unwrap_or(0);
        }
        for (&p, &c) in &self.params {
            v += c * params(p);
        }
        for (&(d, p), &c) in &self.cross {
            v += c * ivs.get(d).copied().unwrap_or(0) * params(p);
        }
        v
    }
}

impl fmt::Display for Affine {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        let mut term = |f: &mut fmt::Formatter<'_>, s: String| -> fmt::Result {
            if !first {
                write!(f, " + ")?;
            }
            first = false;
            write!(f, "{s}")
        };
        if self.k != 0 || (self.iv.is_empty() && self.params.is_empty() && self.cross.is_empty())
        {
            term(f, format!("{}", self.k))?;
        }
        for (&d, &c) in &self.iv {
            term(f, if c == 1 { format!("i{d}") } else { format!("{c}*i{d}") })?;
        }
        for (&p, &c) in &self.params {
            term(f, if c == 1 { format!("{p}") } else { format!("{c}*{p}") })?;
        }
        for (&(d, p), &c) in &self.cross {
            term(
                f,
                if c == 1 { format!("i{d}*{p}") } else { format!("{c}*i{d}*{p}") },
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let a = Affine::iv(0).scale(3).add(&Affine::constant(5)); // 3*i0 + 5
        let b = Affine::iv(1).add(&Affine::constant(-2)); // i1 - 2
        let s = a.add(&b);
        assert_eq!(s.k, 3);
        assert_eq!(s.iv_coeff(0), 3);
        assert_eq!(s.iv_coeff(1), 1);
        let d = s.sub(&b);
        assert_eq!(d, a);
    }

    #[test]
    fn parametric_stride_product() {
        // i*n + j  — the canonical 2-D subscript.
        let n = Reg(4);
        let sub = Affine::iv(0).mul(&Affine::param(n)).unwrap().add(&Affine::iv(1));
        assert!(sub.depends_on_iv(0));
        assert!(sub.depends_on_iv(1));
        assert_eq!(sub.iv_coeff(1), 1);
        let v = sub.eval(&[2, 3], &|_| 10);
        assert_eq!(v, 23);
    }

    #[test]
    fn higher_order_rejected() {
        let a = Affine::iv(0);
        assert!(a.mul(&a).is_none()); // iv*iv
        let n = Reg(1);
        let p = Affine::param(n);
        assert!(p.mul(&p).is_none()); // param*param
        // (i*n) * j would be cubic-ish: iv_only? lhs has cross -> neither
        let i_n = Affine::iv(0).mul(&p).unwrap();
        assert!(i_n.mul(&Affine::iv(1)).is_none());
    }

    #[test]
    fn shift_for_unroll_with_cross_terms() {
        // (i*n + j) shifted in dim 1 by 3 -> i*n + j + 3
        let n = Reg(4);
        let sub = Affine::iv(0).mul(&Affine::param(n)).unwrap().add(&Affine::iv(1));
        let s = sub.shift_iv(1, 3);
        assert_eq!(s.k, 3);
        // (i*n) shifted in dim 0 by 2 -> i*n + 2n
        let s2 = Affine::iv(0).mul(&Affine::param(n)).unwrap().shift_iv(0, 2);
        assert_eq!(s2.params.get(&n), Some(&2));
        assert_eq!(s2.eval(&[1], &|_| 10), 30);
    }

    #[test]
    fn eval_with_params() {
        let n = Reg(1);
        let a = Affine::iv(0)
            .add(&Affine::iv(1))
            .add(&Affine::param(n).mul(&Affine::constant(10)).unwrap());
        let v = a.eval(&[2, 3], &|r| if r == n { 7 } else { 0 });
        assert_eq!(v, 2 + 3 + 70);
    }

    #[test]
    fn zero_coeffs_normalized() {
        let a = Affine::iv(0).sub(&Affine::iv(0));
        assert!(a.is_constant());
        assert_eq!(a.as_constant(), Some(0));
    }
}

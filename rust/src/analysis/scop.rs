//! SCoP detection (paper §III: "a custom-made automatic parallelizer
//! inspired by Polly").
//!
//! The detector abstract-interprets a function's CFG: it tracks an affine
//! environment (register → affine expression over enclosing induction
//! variables and parameters), recognizes canonical counted loops
//! (preheader `mov iv, lb; br header` / header `cmp.lt iv, ub; condbr`),
//! recurses into nests, and records every *innermost* loop whose bounds
//! are affine as a SCoP candidate. Rejections are classified the way
//! Table I reports them:
//!   * no/non-canonical loops or non-affine bounds/subscripts → "no SCoP"
//!     (`nussinov`, `floyd-warshall`);
//!   * control-flow diamonds whose arms have side effects cannot be
//!     if-converted to MUX nodes → `BadMux` (the paper's two "problem
//!     managing MUX nodes" failures);
//!   * calls/syscalls in a body poison the region (no optimization
//!     opportunity, §III).

use std::collections::{HashMap, HashSet};
use std::time::{Duration, Instant};

use crate::ir::func::Function;
use crate::ir::instr::{BinOp, BlockId, CmpPred, Inst, Reg, Term, Ty};

use super::affine::Affine;

/// One loop of an enclosing nest, outermost first.
#[derive(Clone, Debug)]
pub struct LoopInfo {
    pub iv: Reg,
    pub lb: Affine,
    pub ub: Affine,
    pub header: BlockId,
    pub body_entry: BlockId,
    pub depth: usize,
}

/// An innermost-loop SCoP candidate.
#[derive(Clone, Debug)]
pub struct ScopInfo {
    pub func_name: String,
    /// Enclosing nest including the innermost loop (last element).
    pub nest: Vec<LoopInfo>,
    /// Entry block of the innermost body.
    pub body_entry: BlockId,
    /// Innermost header (blocks branching back to it are latches).
    pub header: BlockId,
}

impl ScopInfo {
    pub fn innermost(&self) -> &LoopInfo {
        self.nest.last().expect("nest non-empty")
    }

    pub fn depth(&self) -> usize {
        self.nest.len()
    }
}

/// Why a region failed SCoP detection / offload pre-screening.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ScopReject {
    NoLoops,
    NonCanonical(&'static str),
    NonAffineBound,
    HasCall,
    HasSyscall,
    /// Diamond with side-effecting arms: cannot if-convert to MUX.
    BadMux,
}

impl ScopReject {
    /// Table-I style label.
    pub fn label(&self) -> &'static str {
        match self {
            ScopReject::NoLoops | ScopReject::NonCanonical(_) | ScopReject::NonAffineBound => {
                "no SCoP"
            }
            ScopReject::HasCall | ScopReject::HasSyscall => "calls/syscalls",
            ScopReject::BadMux => "MUX handling",
        }
    }
}

#[derive(Clone, Debug)]
pub struct FuncAnalysis {
    pub scops: Vec<ScopInfo>,
    pub rejects: Vec<ScopReject>,
    pub elapsed: Duration,
}

impl FuncAnalysis {
    pub fn detected(&self) -> bool {
        !self.scops.is_empty()
    }
}

/// Affine environment: `None` = known non-affine.
type Env = HashMap<Reg, Option<Affine>>;

struct Parser<'a> {
    f: &'a Function,
    scops: Vec<ScopInfo>,
    rejects: Vec<ScopReject>,
    /// Written-register sets per block (for post-loop kills).
    writes: Vec<HashSet<Reg>>,
}

/// What a region walk stopped on.
enum StopKind {
    /// Reached a block ending `br <latch_header>`.
    Latch(BlockId),
    /// Function return.
    Ret,
}

impl<'a> Parser<'a> {
    fn new(f: &'a Function) -> Parser<'a> {
        let writes = f
            .blocks
            .iter()
            .map(|b| b.insts.iter().filter_map(|i| i.dst()).collect::<HashSet<_>>())
            .collect();
        Parser { f, scops: Vec::new(), rejects: Vec::new(), writes }
    }

    fn resolve(env: &Env, r: Reg) -> Option<Affine> {
        env.get(&r).cloned().flatten()
    }

    /// Interpret one instruction into the affine env. Returns whether the
    /// instruction is a call / syscall (poison markers handled by caller).
    fn step_inst(env: &mut Env, inst: &Inst) {
        match inst {
            Inst::ConstI32 { dst, v } => {
                env.insert(*dst, Some(Affine::constant(*v as i64)));
            }
            Inst::Mov { dst, a } => {
                let v = Self::resolve(env, *a);
                env.insert(*dst, v);
            }
            Inst::Bin { dst, op, ty: Ty::I32, a, b } => {
                let va = Self::resolve(env, *a);
                let vb = Self::resolve(env, *b);
                let r = match (va, vb, op) {
                    (Some(x), Some(y), BinOp::Add) => Some(x.add(&y)),
                    (Some(x), Some(y), BinOp::Sub) => Some(x.sub(&y)),
                    (Some(x), Some(y), BinOp::Mul) => x.mul(&y),
                    (Some(x), Some(y), BinOp::Shl) => {
                        y.as_constant().filter(|s| (0..31).contains(s)).map(|s| x.scale(1 << s))
                    }
                    _ => None,
                };
                env.insert(*dst, r);
            }
            _ => {
                if let Some(dst) = inst.dst() {
                    env.insert(dst, None);
                }
            }
        }
    }

    /// Is `h` shaped like a canonical loop header? Returns (iv, ub_reg).
    fn header_shape(&self, h: BlockId) -> Option<(Reg, Reg)> {
        let block = self.f.block(h);
        let Some(Term::CondBr { c, .. }) = &block.term else { return None };
        let Some(Inst::Cmp { dst, pred: CmpPred::Lt, ty: Ty::I32, a, b }) = block.insts.last()
        else {
            return None;
        };
        (dst == c).then_some((*a, *b))
    }

    /// Walk a straight-line-with-diamonds-and-loops region starting at
    /// `entry`, stopping at a latch branch to `stop_header` (if inside a
    /// loop) or at `ret`. Returns rejection on malformed shapes.
    fn parse_region(
        &mut self,
        entry: BlockId,
        stop_header: Option<BlockId>,
        env: &mut Env,
        nest: &mut Vec<LoopInfo>,
        contains_loop: &mut bool,
        poison: &mut Option<ScopReject>,
    ) -> Result<StopKind, ScopReject> {
        let mut cur = entry;
        let mut guard = 0usize;
        loop {
            guard += 1;
            if guard > self.f.blocks.len() * 4 {
                return Err(ScopReject::NonCanonical("region does not terminate"));
            }
            let block = self.f.block(cur).clone();
            for inst in &block.insts {
                match inst {
                    Inst::Call { .. } => *poison = Some(ScopReject::HasCall),
                    Inst::Syscall { .. } => *poison = Some(ScopReject::HasSyscall),
                    _ => {}
                }
                Self::step_inst(env, inst);
            }
            match block.term.clone().ok_or(ScopReject::NonCanonical("unterminated"))? {
                Term::Ret(_) => return Ok(StopKind::Ret),
                Term::Br(next) => {
                    if Some(next) == stop_header {
                        return Ok(StopKind::Latch(cur));
                    }
                    if let Some((iv, ub_reg)) = self.header_shape(next) {
                        // Canonical loop: iv must be the dst of the last
                        // Mov in the current (preheader) block.
                        let lb = match block.insts.iter().rev().find_map(|i| match i {
                            Inst::Mov { dst, a } if *dst == iv => Some(*a),
                            _ => None,
                        }) {
                            Some(lb_reg) => Self::resolve(env, lb_reg),
                            None => None,
                        };
                        let ub = Self::resolve(env, ub_reg);
                        let (Some(lb), Some(ub)) = (lb, ub) else {
                            // Bounds not affine: not a SCoP; skip the loop
                            // body entirely by following the exit edge.
                            self.rejects.push(ScopReject::NonAffineBound);
                            let Term::CondBr { f: exit, t: body, .. } =
                                self.f.block(next).term.clone().unwrap()
                            else {
                                unreachable!("header_shape checked");
                            };
                            // Kill everything written in the (skipped)
                            // loop; conservative: kill all writes in all
                            // blocks reachable before exit.
                            self.kill_reachable_writes(body, next, env);
                            env.insert(iv, None);
                            cur = exit;
                            *contains_loop = true;
                            continue;
                        };
                        let depth = nest.len();
                        let Term::CondBr { t: body_entry, f: exit, .. } =
                            self.f.block(next).term.clone().unwrap()
                        else {
                            unreachable!();
                        };
                        let info = LoopInfo {
                            iv,
                            lb,
                            ub,
                            header: next,
                            body_entry,
                            depth,
                        };
                        // Parse the body with iv bound to the symbolic dim.
                        let mut body_env = env.clone();
                        body_env.insert(iv, Some(Affine::iv(depth)));
                        nest.push(info);
                        let mut inner_has_loop = false;
                        let mut inner_poison = None;
                        let body_result = self.parse_region(
                            body_entry,
                            Some(next),
                            &mut body_env,
                            nest,
                            &mut inner_has_loop,
                            &mut inner_poison,
                        );
                        match body_result {
                            Ok(StopKind::Latch(latch)) => {
                                self.validate_latch(latch, iv)?;
                                if !inner_has_loop {
                                    // Innermost: record as SCoP candidate
                                    // unless poisoned.
                                    match inner_poison {
                                        None => self.scops.push(ScopInfo {
                                            func_name: self.f.name.clone(),
                                            nest: nest.clone(),
                                            body_entry,
                                            header: next,
                                        }),
                                        Some(p) => self.rejects.push(p),
                                    }
                                } else if let Some(p) = inner_poison {
                                    self.rejects.push(p);
                                }
                            }
                            Ok(StopKind::Ret) => {
                                return Err(ScopReject::NonCanonical("ret inside loop"))
                            }
                            Err(e) => {
                                nest.pop();
                                return Err(e);
                            }
                        }
                        nest.pop();
                        *contains_loop = true;
                        // Post-loop env: kill iv and body writes.
                        env.insert(iv, None);
                        self.kill_reachable_writes(body_entry, next, env);
                        cur = exit;
                        continue;
                    }
                    cur = next;
                }
                Term::CondBr { c, t, f } => {
                    // Not a loop header here: expect an if-conversion
                    // diamond with single-block arms joining immediately.
                    let join_t = self.single_br_target(t);
                    let join_f = self.single_br_target(f);
                    let _ = c;
                    match (join_t, join_f) {
                        (Some(jt), Some(jf)) if jt == jf => {
                            // Arms with side effects cannot become MUXes.
                            for arm in [t, f] {
                                for inst in &self.f.block(arm).insts {
                                    if matches!(
                                        inst,
                                        Inst::Store { .. } | Inst::Call { .. } | Inst::Syscall { .. }
                                    ) {
                                        *poison = Some(ScopReject::BadMux);
                                    }
                                }
                            }
                            // Merge environments (non-equal values -> mux
                            // -> non-affine as subscripts).
                            let mut env_t = env.clone();
                            for i in &self.f.block(t).insts {
                                Self::step_inst(&mut env_t, i);
                            }
                            let mut env_f = env.clone();
                            for i in &self.f.block(f).insts {
                                Self::step_inst(&mut env_f, i);
                            }
                            let keys: HashSet<Reg> =
                                env_t.keys().chain(env_f.keys()).copied().collect();
                            for k in keys {
                                let vt = Self::resolve(&env_t, k);
                                let vf = Self::resolve(&env_f, k);
                                env.insert(k, if vt == vf { vt } else { None });
                            }
                            cur = jt;
                        }
                        _ => {
                            return Err(ScopReject::NonCanonical(
                                "unstructured control flow",
                            ))
                        }
                    }
                }
            }
        }
    }

    /// If `b` is a single block ending in `br x`, return `x`.
    fn single_br_target(&self, b: BlockId) -> Option<BlockId> {
        match &self.f.block(b).term {
            Some(Term::Br(x)) => Some(*x),
            _ => None,
        }
    }

    /// Latch must end `const 1; add next, iv, 1; mov iv, next`.
    fn validate_latch(&self, latch: BlockId, iv: Reg) -> Result<(), ScopReject> {
        let insts = &self.f.block(latch).insts;
        let n = insts.len();
        if n < 3 {
            return Err(ScopReject::NonCanonical("latch too short"));
        }
        let ok = matches!(
            (&insts[n - 3], &insts[n - 2], &insts[n - 1]),
            (
                Inst::ConstI32 { v: 1, dst: one },
                Inst::Bin { op: BinOp::Add, a, b, dst: next1, .. },
                Inst::Mov { dst, a: next2 },
            ) if *dst == iv && *a == iv && b == one && next1 == next2
        );
        if ok {
            Ok(())
        } else {
            Err(ScopReject::NonCanonical("non-unit loop step"))
        }
    }

    /// Conservatively kill every register written in blocks reachable from
    /// `start` without passing through `stop`.
    fn kill_reachable_writes(&self, start: BlockId, stop: BlockId, env: &mut Env) {
        let mut seen = HashSet::new();
        let mut stack = vec![start];
        while let Some(b) = stack.pop() {
            if b == stop || !seen.insert(b) {
                continue;
            }
            for r in &self.writes[b.0 as usize] {
                env.insert(*r, None);
            }
            stack.extend(self.f.successors(b));
        }
    }
}

/// Analyze one function: find innermost-loop SCoPs, classify rejections,
/// measure the analysis time (Table I's last column).
pub fn analyze_function(f: &Function) -> FuncAnalysis {
    let t0 = Instant::now();
    let mut parser = Parser::new(f);
    let mut env: Env = HashMap::new();
    for (i, p) in f.params.iter().enumerate() {
        if p.ty == Ty::I32 {
            env.insert(Reg(i as u32), Some(Affine::param(Reg(i as u32))));
        }
    }
    let mut nest = Vec::new();
    let mut has_loop = false;
    let mut poison = None;
    let result = parser.parse_region(f.entry, None, &mut env, &mut nest, &mut has_loop, &mut poison);
    let mut scops = std::mem::take(&mut parser.scops);
    let mut rejects = std::mem::take(&mut parser.rejects);
    match result {
        Ok(_) => {
            if !has_loop && scops.is_empty() {
                rejects.push(ScopReject::NoLoops);
            }
        }
        Err(e) => {
            scops.clear();
            rejects.push(e);
        }
    }
    FuncAnalysis { scops, rejects, elapsed: t0.elapsed() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::func::FuncBuilder;
    use crate::ir::instr::Ty;

    fn fig2_func() -> Function {
        let mut b = FuncBuilder::new(
            "fig2",
            &[("C", Ty::Ptr), ("A", Ty::Ptr), ("B", Ty::Ptr), ("n", Ty::I32)],
        );
        let (c, a, bb, n) = (b.param(0), b.param(1), b.param(2), b.param(3));
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, i| {
            let av = b.load(Ty::I32, a, i);
            let bv = b.load(Ty::I32, bb, i);
            let c3 = b.const_i32(3);
            let t = b.mul(bv, c3);
            let s = b.add(av, t);
            let c1 = b.const_i32(1);
            let r = b.add(s, c1);
            b.store(Ty::I32, c, i, r);
        });
        b.ret(None)
    }

    #[test]
    fn detects_single_loop_scop() {
        let f = fig2_func();
        let an = analyze_function(&f);
        assert!(an.detected(), "{:?}", an.rejects);
        assert_eq!(an.scops.len(), 1);
        let s = &an.scops[0];
        assert_eq!(s.depth(), 1);
        assert_eq!(s.innermost().lb.as_constant(), Some(0));
        assert!(s.innermost().ub.params.len() == 1);
    }

    #[test]
    fn detects_nested_scop_with_inner_only() {
        // for i in 0..n { for j in 0..m { A[i*m+j] += 1 } }
        let mut b = FuncBuilder::new(
            "nest",
            &[("A", Ty::Ptr), ("n", Ty::I32), ("m", Ty::I32)],
        );
        let (a, n, m) = (b.param(0), b.param(1), b.param(2));
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, i| {
            let z2 = b.const_i32(0);
            b.counted_loop(z2, m, |b, j| {
                let row = b.mul(i, m);
                let idx = b.add(row, j);
                let v = b.load(Ty::I32, a, idx);
                let one = b.const_i32(1);
                let w = b.add(v, one);
                b.store(Ty::I32, a, idx, w);
            });
        });
        let f = b.ret(None);
        let an = analyze_function(&f);
        assert_eq!(an.scops.len(), 1, "{:?}", an.rejects);
        assert_eq!(an.scops[0].depth(), 2);
    }

    #[test]
    fn two_sequential_loops_two_scops() {
        let mut b = FuncBuilder::new("seq", &[("A", Ty::Ptr), ("n", Ty::I32)]);
        let (a, n) = (b.param(0), b.param(1));
        for _ in 0..2 {
            let zero = b.const_i32(0);
            b.counted_loop(zero, n, |b, i| {
                let v = b.load(Ty::I32, a, i);
                let w = b.add(v, v);
                b.store(Ty::I32, a, i, w);
            });
        }
        let f = b.ret(None);
        let an = analyze_function(&f);
        assert_eq!(an.scops.len(), 2);
    }

    #[test]
    fn data_dependent_bound_rejected() {
        // ub loaded from memory -> non-affine bound -> no SCoP.
        let mut b = FuncBuilder::new("dd", &[("A", Ty::Ptr)]);
        let a = b.param(0);
        let zero = b.const_i32(0);
        let ub = b.load(Ty::I32, a, zero);
        let z = b.const_i32(0);
        b.counted_loop(z, ub, |b, i| {
            let v = b.load(Ty::I32, a, i);
            b.store(Ty::I32, a, i, v);
        });
        let f = b.ret(None);
        let an = analyze_function(&f);
        assert!(!an.detected());
        assert!(an.rejects.contains(&ScopReject::NonAffineBound), "{:?}", an.rejects);
    }

    #[test]
    fn call_poisons_scop() {
        use crate::ir::instr::Inst;
        let mut b = FuncBuilder::new("c", &[("n", Ty::I32)]);
        let n = b.param(0);
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, _| {
            b.push(Inst::Call { dst: None, callee: "x".into(), args: vec![] });
        });
        let f = b.ret(None);
        let an = analyze_function(&f);
        assert!(!an.detected());
        assert!(an.rejects.contains(&ScopReject::HasCall));
    }

    #[test]
    fn diamond_with_store_is_bad_mux() {
        use crate::ir::instr::{CmpPred, Term};
        let mut b = FuncBuilder::new("dm", &[("A", Ty::Ptr), ("n", Ty::I32)]);
        let (a, n) = (b.param(0), b.param(1));
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, i| {
            let v = b.load(Ty::I32, a, i);
            let c = b.cmp(CmpPred::Gt, v, zero);
            let then_bb = b.new_block();
            let else_bb = b.new_block();
            let join = b.new_block();
            b.terminate(Term::CondBr { c, t: then_bb, f: else_bb });
            b.switch_to(then_bb);
            b.store(Ty::I32, a, i, v); // side effect in arm
            b.terminate(Term::Br(join));
            b.switch_to(else_bb);
            b.terminate(Term::Br(join));
            b.switch_to(join);
        });
        let f = b.ret(None);
        let an = analyze_function(&f);
        assert!(!an.detected());
        assert!(an.rejects.contains(&ScopReject::BadMux), "{:?}", an.rejects);
    }

    #[test]
    fn pure_diamond_is_fine() {
        use crate::ir::instr::{CmpPred, Term};
        let mut b = FuncBuilder::new("pd", &[("A", Ty::Ptr), ("n", Ty::I32)]);
        let (a, n) = (b.param(0), b.param(1));
        let zero = b.const_i32(0);
        b.counted_loop(zero, n, |b, i| {
            let v = b.load(Ty::I32, a, i);
            let c = b.cmp(CmpPred::Gt, v, zero);
            let r = b.fresh();
            let then_bb = b.new_block();
            let else_bb = b.new_block();
            let join = b.new_block();
            b.terminate(Term::CondBr { c, t: then_bb, f: else_bb });
            b.switch_to(then_bb);
            let t1 = b.add(v, v);
            b.mov_into(r, t1);
            b.terminate(Term::Br(join));
            b.switch_to(else_bb);
            let t2 = b.sub(v, v);
            b.mov_into(r, t2);
            b.terminate(Term::Br(join));
            b.switch_to(join);
            b.store(Ty::I32, a, i, r);
        });
        let f = b.ret(None);
        let an = analyze_function(&f);
        assert!(an.detected(), "{:?}", an.rejects);
    }

    #[test]
    fn straightline_no_loops() {
        let mut b = FuncBuilder::new("s", &[]);
        let _ = b.const_i32(1);
        let f = b.ret(None);
        let an = analyze_function(&f);
        assert!(!an.detected());
        assert_eq!(an.rejects, vec![ScopReject::NoLoops]);
    }

    #[test]
    fn analysis_time_recorded() {
        let an = analyze_function(&fig2_func());
        assert!(an.elapsed.as_nanos() > 0);
    }
}

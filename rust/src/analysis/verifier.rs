//! Static artifact verifier & hazard analyzer (DESIGN.md §11).
//!
//! The runtime — not a human — extracts DFGs, routes them, lowers wave
//! schedules, cuts tiled plans and persists the lot; a single bad
//! artifact silently corrupts tenant numerics at serve time. In the
//! translation-validation spirit of *Best-Effort FPGA Programming*
//! (Cong et al.), this module re-derives every pipeline invariant from
//! scratch and cross-checks it against what the pipeline actually
//! produced, instead of trusting the producer's own bookkeeping:
//!
//!   * **V1** — IR ↔ DFG consistency at the extraction boundary
//!     ([`verify_offload`]): the source function passes IR verification,
//!     the DFG is a well-formed DAG, and its stream bindings are dense
//!     and 1:1 with the extraction's `StreamIn`/`StreamOut` tables.
//!   * **V2** — grid-configuration legality re-proved independently of
//!     P&R ([`verify_config`]): I/O pads on border faces with no face
//!     double-booked, every route edge present in the `Grid` topology,
//!     FU opcodes within cell capability with all used operands
//!     configured, pass-through routing acyclic, pad counts within the
//!     perimeter budget.
//!   * **V3** — wave-schedule hazard analysis ([`verify_fabric`]): every
//!     FU firing reads only slots already defined (the re-derived
//!     topological order agrees with the stored schedule), destination
//!     slots never alias, all slot indices in bounds, and the fill
//!     latency / drain depth / II re-computed from the configuration
//!     match the numbers the artifact advertises.
//!   * **V4** — tiled-plan soundness ([`verify_plan`],
//!     [`verify_plan_with_provenance`]): every spill slot written exactly
//!     once and only read by strictly later tiles, external outputs
//!     landed exactly once, stream arities match each tile's image,
//!     `config_words()` accounting consistent, and — with provenance —
//!     positional `tile_key`s match the plan key and the cut covers the
//!     source DFG exactly once (calc-node conservation plus a
//!     deterministic semantic probe).
//!   * **V5** — persisted-snapshot integrity ([`snapshot_gate`]):
//!     `dfe/persist.rs` re-runs V2–V4 on every freshly parsed "tlo-cache
//!     v1" artifact, so a byte-valid but semantically corrupt snapshot is
//!     rejected at load instead of served.
//!   * **V6** — lowered-batch-kernel equivalence ([`verify_lowered`]):
//!     translation validation of `dfe::lower`'s folding, aliasing and
//!     fusion decisions — the abstract constant/alias state re-derived
//!     from the wave schedule, prefill soundness + completeness, a
//!     scoreboard scan proving every step reads only defined slots
//!     strictly below its destination, fingerprint integrity, and a
//!     deterministic probe diffed bit-for-bit against the wave executor.
//!
//! All entry points are pure (`&`-only, no interior mutability) and
//! return diagnostics in the canonical deterministic order
//! ([`crate::analysis::diag::sort_diags`]); determinism and cleanliness
//! on every routed artifact are locked by proptest `p12_` and the
//! mutation self-test harness in `tests/verifier.rs`.

use std::collections::{BTreeSet, HashMap};

use crate::analysis::diag::{error_count, has_errors, sort_diags, Diag, Pass, Severity};
use crate::dfe::cache::{dfg_key, CachedConfig};
use crate::dfe::config::{FuSrc, GridConfig, OutSrc};
use crate::dfe::exec::CompiledFabric;
use crate::dfe::lower::{LoweredKernel, Scratch, Src, Step};
use crate::dfe::grid::{CellCoord, Dir, DIRS};
use crate::dfe::opcodes::Op;
use crate::dfe::plan::{tile_key, ExecutionPlan};
use crate::dfg::extract::OffloadDfg;
use crate::dfg::graph::{Dfg, NodeKind};
use crate::dfg::partition::{TileBudget, TileSink, TileSource, TiledDfg};
use crate::ir::func::Function;

// ---------------------------------------------------------------- V1 --

/// V1: the extraction boundary. The source function must pass IR
/// verification, the extracted DFG must be a well-formed DAG, and its
/// `Input(j)`/`Output(j)` bindings must be dense and 1:1 with the
/// extraction's stream tables (the offload stub indexes both by `j`).
pub fn verify_offload(func: &Function, off: &OffloadDfg) -> Vec<Diag> {
    let mut diags = Vec::new();
    if let Err(e) = crate::ir::verify::verify_function(func, None) {
        diags.push(Diag::error(
            Pass::V1IrDfg,
            format!("fn {}", func.name),
            format!("source function fails IR verification: {e}"),
        ));
    }
    verify_dfg_into(&off.dfg, Some((off.inputs.len(), off.outputs.len())), &mut diags);
    sort_diags(&mut diags);
    diags
}

/// Structural DFG re-derivation shared by V1 and the provenance side of
/// V4. `expected_io` pins the dense stream-binding counts when the
/// caller knows them.
fn verify_dfg_into(dfg: &Dfg, expected_io: Option<(usize, usize)>, diags: &mut Vec<Diag>) {
    let n = dfg.nodes.len();
    let mut ins: Vec<usize> = Vec::new();
    let mut outs: Vec<usize> = Vec::new();
    for (i, node) in dfg.nodes.iter().enumerate() {
        let loc = format!("dfg node {i}");
        for &s in &node.srcs {
            if s >= n {
                diags.push(Diag::error(
                    Pass::V1IrDfg,
                    loc.clone(),
                    format!("value edge dangles: source {s} of {n} nodes"),
                ));
            }
        }
        let want = match &node.kind {
            NodeKind::Input(j) => {
                ins.push(*j);
                0
            }
            NodeKind::Const(_) => 0,
            NodeKind::Calc(op) => {
                if *op == Op::Mux {
                    3
                } else {
                    2
                }
            }
            NodeKind::Output(j) => {
                outs.push(*j);
                1
            }
        };
        if node.srcs.len() != want {
            diags.push(Diag::error(
                Pass::V1IrDfg,
                loc,
                format!("{:?} carries {} sources, wants {want}", node.kind, node.srcs.len()),
            ));
        }
    }
    if dfg.topo_order().is_err() {
        diags.push(Diag::error(Pass::V1IrDfg, "dfg", "graph is not acyclic"));
    }
    for (what, idxs) in [("input", &mut ins), ("output", &mut outs)] {
        idxs.sort_unstable();
        for w in idxs.windows(2) {
            if w[0] == w[1] {
                diags.push(Diag::error(
                    Pass::V1IrDfg,
                    "dfg",
                    format!("{what} stream {} bound by two nodes", w[0]),
                ));
            }
        }
    }
    if let Some((n_in, n_out)) = expected_io {
        for (what, idxs, expect) in [("input", &ins, n_in), ("output", &outs, n_out)] {
            if idxs.len() != expect || idxs.iter().enumerate().any(|(k, &j)| j != k) {
                diags.push(Diag::error(
                    Pass::V1IrDfg,
                    "dfg",
                    format!(
                        "{what} streams {idxs:?} are not dense 0..{expect} \
                         (extraction table has {expect})"
                    ),
                ));
            }
        }
    }
}

// ---------------------------------------------------------------- V2 --

/// V2: grid-configuration legality, re-proved from the `Grid` topology
/// without calling `GridConfig::validate` (the point is to catch drift
/// in the producer's own checks, not to repeat them).
pub fn verify_config(cfg: &GridConfig) -> Vec<Diag> {
    let mut diags = Vec::new();
    verify_config_into(cfg, &mut diags);
    sort_diags(&mut diags);
    diags
}

fn verify_config_into(cfg: &GridConfig, diags: &mut Vec<Diag>) {
    let grid = cfg.grid;
    let err = |loc: String, msg: String| Diag::error(Pass::V2GridLegality, loc, msg);

    if cfg.cells.len() != grid.n_cells() {
        diags.push(err(
            "grid".into(),
            format!("{} cell configs for a {}x{} grid", cfg.cells.len(), grid.rows, grid.cols),
        ));
        return; // cell indexing below would be meaningless
    }

    // I/O pads: on-grid, border, no face double-booked across both
    // groups, output streams claimed at most once, pad count within the
    // perimeter (and, advisory, within the partitioner's eff_io budget).
    let mut faces: HashMap<(CellCoord, Dir), &'static str> = HashMap::new();
    for (group, pads) in [("input", &cfg.inputs), ("output", &cfg.outputs)] {
        for io in pads {
            let loc = format!("{group} pad {}{}", io.cell, io.dir);
            if !grid.contains(io.cell) {
                diags.push(err(loc, format!("pad cell off the {}x{} grid", grid.rows, grid.cols)));
                continue;
            }
            if !grid.is_border_face(io.cell, io.dir) {
                diags.push(err(loc.clone(), "pad face is not on the border".into()));
            }
            if let Some(prev) = faces.insert((io.cell, io.dir), group) {
                diags.push(err(loc, format!("face already bound as an {prev} pad")));
            }
        }
    }
    let mut out_idx: Vec<usize> = cfg.outputs.iter().map(|io| io.index).collect();
    out_idx.sort_unstable();
    for w in out_idx.windows(2) {
        if w[0] == w[1] {
            diags.push(err(
                format!("output stream {}", w[0]),
                "double-booked: two pads claim the same output stream".into(),
            ));
        }
    }
    let budget = TileBudget::for_grid(grid);
    let pads = cfg.inputs.len() + cfg.outputs.len();
    if pads > budget.io {
        diags.push(err(
            "io".into(),
            format!("{pads} pads exceed the {} border faces of the grid", budget.io),
        ));
    } else if pads > budget.eff_io() {
        diags.push(Diag::warning(
            Pass::V2GridLegality,
            "io",
            format!("{pads} pads exceed the partitioner's eff_io budget {}", budget.eff_io()),
        ));
    }

    // Per-cell FU legality: opcode within capability, every operand the
    // opcode uses configured, FU result consumed; op-less cells carry no
    // FU state.
    for p in grid.iter_coords() {
        let c = cfg.cell(p);
        let loc = format!("cell {p}");
        match c.op {
            Some(op) => {
                if Op::from_i32(op.code()) != Some(op) {
                    diags.push(err(loc.clone(), format!("opcode {op:?} outside cell capability")));
                }
                if matches!(c.fu1, FuSrc::None) {
                    diags.push(err(loc.clone(), format!("op {} missing operand a", op.name())));
                }
                if op.uses_rhs() && matches!(c.fu2, FuSrc::None) {
                    diags.push(err(loc.clone(), format!("op {} missing operand b", op.name())));
                }
                if op.uses_sel() && matches!(c.fsel, FuSrc::None) {
                    diags.push(err(loc.clone(), format!("op {} missing operand sel", op.name())));
                }
                if !c.out.iter().any(|o| *o == OutSrc::Fu) {
                    diags.push(err(loc, "FU result reaches no output face".into()));
                }
            }
            None => {
                if !matches!(c.fu1, FuSrc::None)
                    || !matches!(c.fu2, FuSrc::None)
                    || !matches!(c.fsel, FuSrc::None)
                {
                    diags.push(err(loc.clone(), "operand mux configured on an op-less cell".into()));
                }
                if c.out.iter().any(|o| *o == OutSrc::Fu) {
                    diags.push(err(loc, "output face routes an FU result but the cell has no op".into()));
                }
            }
        }
    }

    // Route edges: every consumed input face must have a driver that
    // exists in the grid topology — a bound external pad on a border
    // face, or the adjacent neighbor's facing output register.
    for p in grid.iter_coords() {
        let c = cfg.cell(p);
        let mut consumed: Vec<Dir> = Vec::new();
        for s in [c.fu1, c.fu2, c.fsel] {
            if let FuSrc::In(d) = s {
                consumed.push(d);
            }
        }
        for d in DIRS {
            if let OutSrc::In(d2) = c.out[d.index()] {
                consumed.push(d2);
            }
        }
        consumed.sort_by_key(|d| d.index());
        consumed.dedup();
        for d in consumed {
            let loc = format!("cell {p} input {d}");
            match grid.neighbor(p, d) {
                None => {
                    if !cfg.inputs.iter().any(|io| io.cell == p && io.dir == d) {
                        diags.push(err(loc, "border face consumed but no input pad bound".into()));
                    }
                }
                Some(q) => {
                    let qd = d.opposite();
                    if cfg.cell(q).out[qd.index()] == OutSrc::None {
                        diags.push(err(
                            loc,
                            format!("reads neighbor {q}{qd}, which drives nothing"),
                        ));
                    }
                }
            }
        }
    }

    // Output pads tap a driven face.
    for io in &cfg.outputs {
        if grid.contains(io.cell) && cfg.cell(io.cell).out[io.dir.index()] == OutSrc::None {
            diags.push(err(
                format!("output pad {}{}", io.cell, io.dir),
                "taps an undriven output face".into(),
            ));
        }
    }

    // Pass-through routing must be acyclic: out[d] = In(d2) chains form a
    // graph over (cell, input face) nodes; any cycle deadlocks the
    // elastic pipeline and is unlowerable.
    let mut state: HashMap<(CellCoord, Dir), u8> = HashMap::new(); // 1 visiting, 2 done
    fn walk(
        cfg: &GridConfig,
        node: (CellCoord, Dir),
        state: &mut HashMap<(CellCoord, Dir), u8>,
        diags: &mut Vec<Diag>,
    ) {
        match state.get(&node) {
            Some(1) => {
                diags.push(Diag::error(
                    Pass::V2GridLegality,
                    format!("cell {} input {}", node.0, node.1),
                    "pass-through routing cycle",
                ));
                return;
            }
            Some(_) => return,
            None => {}
        }
        state.insert(node, 1);
        if let Some(q) = cfg.grid.neighbor(node.0, node.1) {
            if let OutSrc::In(d2) = cfg.cell(q).out[node.1.opposite().index()] {
                walk(cfg, (q, d2), state, diags);
            }
        }
        state.insert(node, 2);
    }
    for p in grid.iter_coords() {
        for d in DIRS {
            walk(cfg, (p, d), &mut state, diags);
        }
    }
}

// ---------------------------------------------------------------- V3 --

/// V3: wave-schedule hazard analysis. Checks the stored schedule of a
/// [`CompiledFabric`] against a topological order and timing model
/// re-derived here from the configuration alone.
pub fn verify_fabric(cfg: &GridConfig, fabric: &CompiledFabric) -> Vec<Diag> {
    let mut diags = Vec::new();
    verify_fabric_into(cfg, fabric, &mut diags);
    sort_diags(&mut diags);
    diags
}

fn verify_fabric_into(cfg: &GridConfig, fab: &CompiledFabric, diags: &mut Vec<Diag>) {
    let err = |loc: String, msg: String| Diag::error(Pass::V3WaveHazard, loc, msg);
    let n_slots = fab.n_slots;
    if n_slots == 0 {
        diags.push(err("slots".into(), "schedule has no value slots (missing zero slot)".into()));
        return;
    }

    // Slot definition map: zero slot, constants, external inputs.
    let mut defined = vec![false; n_slots];
    defined[0] = true;
    for &(slot, _) in &fab.consts {
        match defined.get_mut(slot) {
            None => diags.push(err(
                format!("const slot {slot}"),
                format!("out of bounds for {n_slots} slots"),
            )),
            Some(d) if *d => {
                diags.push(err(format!("const slot {slot}"), "aliases another pre-image slot".into()))
            }
            Some(d) => *d = true,
        }
    }
    let mut ext_streams: BTreeSet<usize> = BTreeSet::new();
    for &(slot, j) in &fab.ext_ins {
        if j >= fab.n_inputs {
            diags.push(err(
                format!("ext slot {slot}"),
                format!("binds stream {j} beyond n_inputs {}", fab.n_inputs),
            ));
        }
        ext_streams.insert(j);
        match defined.get_mut(slot) {
            None => diags.push(err(
                format!("ext slot {slot}"),
                format!("out of bounds for {n_slots} slots"),
            )),
            Some(d) if *d => {
                diags.push(err(format!("ext slot {slot}"), "aliases another pre-image slot".into()))
            }
            Some(d) => *d = true,
        }
    }

    // External bindings must mirror the configuration's pads exactly.
    let cfg_streams: BTreeSet<usize> = cfg.inputs.iter().map(|io| io.index).collect();
    if ext_streams != cfg_streams {
        diags.push(err(
            "ext".into(),
            format!("schedule reads streams {ext_streams:?}, config binds {cfg_streams:?}"),
        ));
    }
    let want_n_inputs = cfg.inputs.iter().map(|io| io.index + 1).max().unwrap_or(0);
    if fab.n_inputs != want_n_inputs {
        diags.push(err(
            "ext".into(),
            format!("n_inputs {} vs {} re-derived from the config", fab.n_inputs, want_n_inputs),
        ));
    }

    // Hazard scan: in stored order, every firing may read only slots
    // already defined (zero/const/ext or an earlier firing's dst), and
    // must define a fresh, in-bounds destination.
    let n_op_cells = cfg.op_cells().count();
    if fab.ops.len() != n_op_cells {
        diags.push(err(
            "schedule".into(),
            format!("{} firings for {} op cells in the config", fab.ops.len(), n_op_cells),
        ));
    }
    for (i, op) in fab.ops.iter().enumerate() {
        let loc = format!("firing {i:03} ({})", op.op.name());
        for (name, slot, used) in [
            ("a", op.a, true),
            ("b", op.b, op.op.uses_rhs()),
            ("s", op.s, op.op.uses_sel()),
        ] {
            if slot >= n_slots {
                diags.push(err(
                    loc.clone(),
                    format!("operand {name} slot {slot} out of bounds ({n_slots} slots)"),
                ));
            } else if used && !defined[slot] {
                diags.push(err(
                    loc.clone(),
                    format!("operand {name} reads slot {slot} before any producer defines it"),
                ));
            }
        }
        if op.dst >= n_slots {
            diags.push(err(loc, format!("dst slot {} out of bounds ({n_slots} slots)", op.dst)));
        } else if defined[op.dst] {
            diags.push(err(loc, format!("dst slot {} aliases an already-defined slot", op.dst)));
        } else {
            defined[op.dst] = true;
        }
    }

    // Output taps: strictly ascending stream order, defined slots,
    // stream count consistent with the config.
    let mut prev_stream: Option<usize> = None;
    for &(stream, slot) in &fab.outs {
        let loc = format!("out stream {stream}");
        if let Some(p) = prev_stream {
            if stream <= p {
                diags.push(err(loc.clone(), format!("tap order not ascending (after {p})")));
            }
        }
        prev_stream = Some(stream);
        if stream >= fab.n_out_streams {
            diags.push(err(
                loc.clone(),
                format!("beyond n_out_streams {}", fab.n_out_streams),
            ));
        }
        if slot >= n_slots {
            diags.push(err(loc, format!("taps slot {slot} out of bounds ({n_slots} slots)")));
        } else if !defined[slot] {
            diags.push(err(loc, format!("taps slot {slot} that nothing defines")));
        }
    }
    let want_out_streams = cfg.outputs.iter().map(|io| io.index + 1).max().unwrap_or(0);
    if fab.n_out_streams != want_out_streams {
        diags.push(err(
            "out".into(),
            format!(
                "n_out_streams {} vs {} re-derived from the config",
                fab.n_out_streams, want_out_streams
            ),
        ));
    }

    // Timing: re-derive registered-stage depths from the configuration
    // alone and diff against the stored fill latency / drain depth / II.
    // Skipped (silently — V2 reports the cause) if the routing is not
    // resolvable.
    if let Some(taps) = tap_depths(cfg) {
        if !taps.is_empty() {
            let fill = 1 + taps.iter().copied().min().unwrap_or(0);
            let drain = 1 + taps.iter().copied().max().unwrap_or(0);
            if fab.fill_latency != fill {
                diags.push(err(
                    "timing".into(),
                    format!("fill latency {} stored, {fill} re-derived", fab.fill_latency),
                ));
            }
            if fab.drain_depth != drain {
                diags.push(err(
                    "timing".into(),
                    format!("drain depth {} stored, {drain} re-derived", fab.drain_depth),
                ));
            }
        }
    }
    if fab.initiation_interval != 1.0 {
        diags.push(err(
            "timing".into(),
            format!(
                "II {} stored; a feed-forward overlay pipelines at the analytic 1.0",
                fab.initiation_interval
            ),
        ));
    }
}

/// Producer endpoints of the re-derived timing model (mirrors the wave
/// lowering's `Producer` without sharing its code — the point is an
/// independent derivation).
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
enum Prod {
    Out(CellCoord, Dir),
    Fu(CellCoord),
}

/// Registered-stage depth of every tapped output face, walking the
/// routing fabric from the configuration alone: external inputs are
/// depth 0, every FU register and routed output register costs one
/// stage. `None` when the routing cannot be resolved (undriven face or
/// cycle — V2 territory).
fn tap_depths(cfg: &GridConfig) -> Option<Vec<u64>> {
    let mut memo: HashMap<Prod, Option<u64>> = HashMap::new();

    fn face_depth(
        cfg: &GridConfig,
        p: CellCoord,
        d: Dir,
        memo: &mut HashMap<Prod, Option<u64>>,
    ) -> Option<u64> {
        match cfg.grid.neighbor(p, d) {
            None => cfg
                .inputs
                .iter()
                .any(|io| io.cell == p && io.dir == d)
                .then_some(0),
            Some(q) => depth_of(cfg, Prod::Out(q, d.opposite()), memo),
        }
    }

    fn operand_depth(
        cfg: &GridConfig,
        p: CellCoord,
        s: FuSrc,
        memo: &mut HashMap<Prod, Option<u64>>,
    ) -> Option<u64> {
        match s {
            FuSrc::None | FuSrc::Const(_) => Some(0),
            FuSrc::In(d) => face_depth(cfg, p, d, memo),
        }
    }

    fn depth_of(
        cfg: &GridConfig,
        prod: Prod,
        memo: &mut HashMap<Prod, Option<u64>>,
    ) -> Option<u64> {
        if let Some(&cached) = memo.get(&prod) {
            return cached; // `None` doubles as the in-progress marker: a
                           // cycle resolves to None, never recurses.
        }
        memo.insert(prod, None);
        let depth = match prod {
            Prod::Out(p, d) => match cfg.cell(p).out[d.index()] {
                OutSrc::None => None,
                OutSrc::Fu => depth_of(cfg, Prod::Fu(p), memo).map(|x| 1 + x),
                OutSrc::In(d2) => face_depth(cfg, p, d2, memo).map(|x| 1 + x),
            },
            Prod::Fu(p) => {
                let c = cfg.cell(p);
                let mut worst = 0u64;
                for s in [c.fu1, c.fu2, c.fsel] {
                    worst = worst.max(operand_depth(cfg, p, s, memo)?);
                }
                Some(1 + worst)
            }
        };
        memo.insert(prod, depth);
        depth
    }

    let mut taps = Vec::with_capacity(cfg.outputs.len());
    for io in &cfg.outputs {
        if !cfg.grid.contains(io.cell) {
            return None;
        }
        // The pad reads the face's *output register*: its stage is the
        // `1 + ...` inside depth_of for the Out producer itself.
        taps.push(depth_of(cfg, Prod::Out(io.cell, io.dir), &mut memo)?);
    }
    Some(taps)
}

// ---------------------------------------------------------- artifacts --

/// The single-tile sanitizer: V2 on the configuration, an image-drift
/// cross-check, and V3 on the compiled wave schedule (when the artifact
/// carries one). This is what the debug-build verify-on-insert hook in
/// [`crate::dfe::cache::ConfigCache`] runs.
pub fn verify_artifact(cached: &CachedConfig) -> Vec<Diag> {
    let mut diags = Vec::new();
    verify_artifact_into(cached, &mut diags);
    sort_diags(&mut diags);
    diags
}

fn verify_artifact_into(cached: &CachedConfig, diags: &mut Vec<Diag>) {
    verify_config_into(&cached.config, diags);
    match cached.config.to_image() {
        Ok(img) => {
            if img != cached.image {
                diags.push(Diag::error(
                    Pass::V2GridLegality,
                    "image",
                    "cached execution image drifted from its configuration",
                ));
            }
        }
        Err(e) => diags.push(Diag::error(
            Pass::V2GridLegality,
            "image",
            format!("configuration no longer lowers to an image: {e}"),
        )),
    }
    match &cached.fabric {
        Some(f) => verify_fabric_into(&cached.config, f, diags),
        None => diags.push(Diag::warning(
            Pass::V3WaveHazard,
            "fabric",
            "no compiled wave schedule (CycleSim fallback artifact)",
        )),
    }
    match (&cached.fabric, &cached.lowered) {
        (Some(f), Some(k)) => verify_lowered_into(f, k, diags),
        (Some(_), None) => diags.push(Diag::warning(
            Pass::V6LoweredKernel,
            "lowered",
            "wave schedule present but no lowered batch kernel (wave-executor fallback)",
        )),
        (None, Some(_)) => diags.push(Diag::error(
            Pass::V6LoweredKernel,
            "lowered",
            "lowered kernel present without its source wave schedule",
        )),
        (None, None) => {}
    }
}

// ---------------------------------------------------------------- V6 --

/// V6: translation validation of the lowered batch kernels
/// (`dfe::lower`) against the wave schedule they were specialized from.
/// Re-derives the folding/aliasing abstract state independently from the
/// fabric's firing list, then holds the kernel to it: slot-space
/// identity, prefill soundness *and* completeness, output taps resolved
/// through the re-derived alias map, a scoreboard scan proving every
/// step reads only defined slots strictly below its destination (the
/// invariant the executor's `split_at_mut` carve relies on), fingerprint
/// integrity, and a deterministic end-to-end probe diffed bit-for-bit
/// against the wave executor.
pub fn verify_lowered(fab: &CompiledFabric, k: &LoweredKernel) -> Vec<Diag> {
    let mut diags = Vec::new();
    verify_lowered_into(fab, k, &mut diags);
    sort_diags(&mut diags);
    diags
}

fn verify_lowered_into(fab: &CompiledFabric, k: &LoweredKernel, diags: &mut Vec<Diag>) {
    let err = |loc: String, msg: String| Diag::error(Pass::V6LoweredKernel, loc, msg);

    // ---- slot-space identity (the lowering never renumbers) ----
    let n_slots = fab.n_slots;
    if k.n_slots != n_slots {
        diags.push(err(
            "slots".into(),
            format!("kernel has {} slots, wave schedule has {n_slots}", k.n_slots),
        ));
        return; // everything below indexes by slot
    }
    if k.n_inputs != fab.n_inputs {
        diags.push(err(
            "ext".into(),
            format!("n_inputs {} vs the schedule's {}", k.n_inputs, fab.n_inputs),
        ));
    }
    if k.ext_ins != fab.ext_ins {
        diags.push(err(
            "ext".into(),
            "external input bindings differ from the wave schedule".into(),
        ));
    }

    // ---- independent re-derivation of the folding abstract state ----
    // `known[s]` = compile-time constant in slot `s`; `alias[s]` = the
    // slot holding `s`'s run-time value. Derived from the fabric's
    // firing list and `Op::eval` alone — not from the kernel.
    let mut known: Vec<Option<i32>> = vec![None; n_slots];
    if n_slots == 0 {
        diags.push(err("slots".into(), "schedule has no value slots".into()));
        return;
    }
    known[0] = Some(0);
    for &(slot, v) in &fab.consts {
        if let Some(kn) = known.get_mut(slot) {
            *kn = Some(v);
        }
    }
    let mut alias: Vec<usize> = (0..n_slots).collect();
    // Slots a surviving (unfolded, unfoldable) firing must still write.
    let mut must_write = vec![false; n_slots];
    for w in &fab.ops {
        if w.dst >= n_slots || w.a >= n_slots || w.b >= n_slots || w.s >= n_slots {
            // V3 reports schedule bounds; nothing sound to derive here.
            return;
        }
        let (a, b, s) = (alias[w.a], alias[w.b], alias[w.s]);
        match w.op {
            Op::Nop => {
                alias[w.dst] = 0;
                known[w.dst] = Some(0);
            }
            Op::Pass => {
                alias[w.dst] = a;
                known[w.dst] = known[a];
            }
            op => {
                if let (Some(ka), Some(kb), Some(ks)) = (known[a], known[b], known[s]) {
                    known[w.dst] = Some(op.eval(ka, kb, ks));
                } else {
                    must_write[w.dst] = true;
                }
            }
        }
    }

    // ---- output taps through the re-derived alias map ----
    if k.outs.len() != fab.outs.len() {
        diags.push(err(
            "outs".into(),
            format!("{} taps vs the schedule's {}", k.outs.len(), fab.outs.len()),
        ));
    } else {
        for (i, (&(kj, kslot), &(fj, fslot))) in k.outs.iter().zip(&fab.outs).enumerate() {
            if kj != fj || kslot != alias[fslot] {
                diags.push(err(
                    format!("out {i}"),
                    format!(
                        "tap (stream {kj}, slot {kslot}) vs re-derived \
                         (stream {fj}, slot {})",
                        alias[fslot]
                    ),
                ));
            }
        }
    }

    // ---- step destinations: exactly the surviving firings ----
    let mut written = vec![false; n_slots];
    let mut fused_away = 0usize;
    for (i, step) in k.steps.iter().enumerate() {
        let dst = match step {
            Step::Sweep { dst, .. } => *dst,
            Step::Chain { ops, dst } => {
                // Chain members beyond the tail correspond to fused
                // producers whose slots legitimately go unwritten.
                fused_away += ops.len().saturating_sub(1);
                *dst
            }
        };
        if dst >= n_slots {
            diags.push(err(format!("step {i}"), format!("dst slot {dst} out of bounds")));
            return;
        }
        if written[dst] {
            diags.push(err(format!("step {i}"), format!("slot {dst} written twice")));
        }
        written[dst] = true;
        if !must_write[dst] {
            diags.push(err(
                format!("step {i}"),
                format!("writes slot {dst}, which the re-derivation folds away"),
            ));
        }
    }
    let surviving = must_write.iter().filter(|&&w| w).count();
    let emitted = written.iter().filter(|&&w| w).count();
    if emitted + fused_away != surviving {
        diags.push(err(
            "steps".into(),
            format!(
                "{emitted} step writes + {fused_away} fused intermediates \
                 cover {surviving} surviving firings"
            ),
        ));
    }

    // ---- prefill soundness + completeness ----
    let mut prefilled = vec![false; n_slots];
    for &(slot, v) in &k.prefill {
        if slot >= n_slots {
            diags.push(err(format!("prefill slot {slot}"), "out of bounds".into()));
            continue;
        }
        if prefilled[slot] {
            diags.push(err(format!("prefill slot {slot}"), "prefilled twice".into()));
        }
        prefilled[slot] = true;
        if known[slot] != Some(v) {
            diags.push(err(
                format!("prefill slot {slot}"),
                format!("holds {v}, re-derivation says {:?}", known[slot]),
            ));
        }
        if written[slot] {
            diags.push(err(
                format!("prefill slot {slot}"),
                "also written by a step (prime-once reuse would corrupt it)".into(),
            ));
        }
    }

    // ---- scoreboard: defined-before-use, operands strictly below dst ----
    let mut defined = vec![false; n_slots];
    defined[0] = true;
    for slot in 0..n_slots {
        if prefilled[slot] {
            defined[slot] = true;
        }
    }
    for &(slot, _) in &k.ext_ins {
        if let Some(d) = defined.get_mut(slot) {
            *d = true;
        }
    }
    // Completeness rider inside the read check: a read of a re-derived
    // constant must have been prefilled (ext/step-written slots are
    // never constants in the re-derivation).
    fn check_read(
        diags: &mut Vec<Diag>,
        defined: &[bool],
        known: &[Option<i32>],
        prefilled: &[bool],
        i: usize,
        slot: usize,
        dst: usize,
        what: &str,
    ) {
        let err = |loc: String, msg: String| Diag::error(Pass::V6LoweredKernel, loc, msg);
        let n_slots = defined.len();
        if slot >= n_slots {
            diags.push(err(format!("step {i}"), format!("{what} slot {slot} out of bounds")));
            return;
        } else if !defined[slot] {
            diags.push(err(
                format!("step {i}"),
                format!("{what} reads slot {slot} before it is defined"),
            ));
        } else if slot >= dst {
            diags.push(err(
                format!("step {i}"),
                format!("{what} slot {slot} not strictly below dst {dst} (aliasing hazard)"),
            ));
        }
        if known[slot].is_some() && slot != 0 && !prefilled[slot] {
            diags.push(err(
                format!("step {i}"),
                format!("reads constant slot {slot} missing from the prefill image"),
            ));
        }
    }
    for (i, step) in k.steps.iter().enumerate() {
        match step {
            Step::Sweep { dst, a, b, s, .. } => {
                check_read(diags, &defined, &known, &prefilled, i, *a, *dst, "operand a");
                check_read(diags, &defined, &known, &prefilled, i, *b, *dst, "operand b");
                check_read(diags, &defined, &known, &prefilled, i, *s, *dst, "operand s");
                defined[*dst] = true;
            }
            Step::Chain { ops, dst } => {
                if ops.len() < 2 {
                    diags.push(err(
                        format!("step {i}"),
                        format!("chain of {} member(s) — fusion requires at least 2", ops.len()),
                    ));
                }
                for (m, c) in ops.iter().enumerate() {
                    let mut accs = 0usize;
                    for (src, what) in
                        [(c.a, "operand a"), (c.b, "operand b"), (c.s, "operand s")]
                    {
                        match src {
                            Src::Buf(slot) => check_read(
                                diags, &defined, &known, &prefilled, i, slot, *dst, what,
                            ),
                            Src::Acc => {
                                accs += 1;
                                if m == 0 {
                                    diags.push(err(
                                        format!("step {i}"),
                                        "chain head reads the accumulator".into(),
                                    ));
                                }
                            }
                        }
                    }
                    if m > 0 && accs != 1 {
                        diags.push(err(
                            format!("step {i}"),
                            format!("chain member {m} reads the accumulator {accs} times"),
                        ));
                    }
                }
                defined[*dst] = true;
            }
        }
    }
    // Taps must read defined (or prefilled-constant) slots.
    for (i, &(_, slot)) in k.outs.iter().enumerate() {
        if slot < n_slots && !defined[slot] {
            diags.push(err(format!("out {i}"), format!("taps undefined slot {slot}")));
        }
        if slot < n_slots && known[slot].is_some() && slot != 0 && !prefilled[slot] {
            diags.push(err(
                format!("out {i}"),
                format!("taps constant slot {slot} missing from the prefill image"),
            ));
        }
    }

    // ---- fingerprint integrity (the scratch-arena priming key) ----
    if k.fingerprint != k.structural_hash() {
        diags.push(err(
            "fingerprint".into(),
            "stored fingerprint drifted from the kernel structure \
             (a stale scratch arena could skip re-priming)"
                .into(),
        ));
    }

    // ---- deterministic end-to-end probe against the wave executor ----
    if !has_errors(diags) {
        let lanes = 67usize;
        let probe: Vec<i32> = (0..fab.n_inputs * lanes)
            .map(|i| (i as i32).wrapping_mul(-1640531527).wrapping_add(40503))
            .collect();
        let want = fab.run_batch(&probe, lanes);
        let got = k.run_batch(&probe, lanes, &mut Scratch::new());
        if got != want {
            diags.push(err(
                "probe".into(),
                "lowered kernel diverges from the wave executor on the probe vector".into(),
            ));
        }
    }
}

// ---------------------------------------------------------------- V4 --

/// V4 without provenance: everything a plan must satisfy regardless of
/// which DFG it was cut from. Runs the single-tile sanitizer on every
/// tile. This is the verify-on-insert hook for the plan store.
pub fn verify_plan(plan: &ExecutionPlan) -> Vec<Diag> {
    let mut diags = Vec::new();
    verify_plan_into(plan, &mut diags);
    sort_diags(&mut diags);
    diags
}

fn verify_plan_into(plan: &ExecutionPlan, diags: &mut Vec<Diag>) {
    let err = |loc: String, msg: String| Diag::error(Pass::V4PlanSoundness, loc, msg);
    if plan.tiles.is_empty() {
        diags.push(err("plan".into(), "no tiles".into()));
        return;
    }

    // Per-tile: stream arities match the tile's image; the tile artifact
    // itself passes V2/V3 (locations prefixed with the tile index).
    for (i, t) in plan.tiles.iter().enumerate() {
        if t.sources.len() != t.cached.image.n_inputs {
            diags.push(err(
                format!("tile {i}"),
                format!(
                    "{} local sources for an image reading {} input streams",
                    t.sources.len(),
                    t.cached.image.n_inputs
                ),
            ));
        }
        if t.sinks.len() != t.cached.image.out_sel.len() {
            diags.push(err(
                format!("tile {i}"),
                format!(
                    "{} local sinks for an image producing {} output streams",
                    t.sinks.len(),
                    t.cached.image.out_sel.len()
                ),
            ));
        }
        let mut sub = Vec::new();
        verify_artifact_into(&t.cached, &mut sub);
        for d in sub {
            diags.push(Diag {
                pass: d.pass,
                severity: d.severity,
                location: format!("tile {i} {}", d.location),
                message: d.message,
            });
        }
    }

    // Spill discipline: each slot written exactly once, by its producer
    // tile; read only by strictly later tiles; slots dense.
    let mut writer: Vec<Option<usize>> = vec![None; plan.n_spills];
    let mut ext_writer: HashMap<usize, usize> = HashMap::new();
    let mut spill_sink_order: Vec<usize> = Vec::new();
    for (i, t) in plan.tiles.iter().enumerate() {
        for (jj, sink) in t.sinks.iter().enumerate() {
            match *sink {
                TileSink::Spill(k) => {
                    spill_sink_order.push(k);
                    if k >= plan.n_spills {
                        diags.push(err(
                            format!("tile {i} sink {jj}"),
                            format!("spill slot {k} beyond n_spills {}", plan.n_spills),
                        ));
                    } else if let Some(w) = writer[k] {
                        diags.push(err(
                            format!("tile {i} sink {jj}"),
                            format!("spill slot {k} already written by tile {w}"),
                        ));
                    } else {
                        writer[k] = Some(i);
                    }
                }
                TileSink::External(j) => {
                    if let Some(w) = ext_writer.insert(j, i) {
                        diags.push(err(
                            format!("tile {i} sink {jj}"),
                            format!("external output {j} already written by tile {w}"),
                        ));
                    }
                }
            }
        }
    }
    for (k, w) in writer.iter().enumerate() {
        if w.is_none() {
            diags.push(err(format!("spill {k}"), "slot is never written by any tile".into()));
        }
    }
    let mut read = vec![false; plan.n_spills];
    for (i, t) in plan.tiles.iter().enumerate() {
        for (jj, src) in t.sources.iter().enumerate() {
            if let TileSource::Spill(k) = *src {
                if k >= plan.n_spills {
                    diags.push(err(
                        format!("tile {i} source {jj}"),
                        format!("spill slot {k} beyond n_spills {}", plan.n_spills),
                    ));
                    continue;
                }
                read[k] = true;
                match writer[k] {
                    Some(w) if w < i => {}
                    Some(w) => diags.push(err(
                        format!("tile {i} source {jj}"),
                        format!("reads spill {k} which tile {w} writes — not strictly earlier"),
                    )),
                    None => {} // unwritten slot already reported above
                }
            }
        }
    }
    for (k, r) in read.iter().enumerate() {
        if !*r && writer[k].is_some() {
            diags.push(Diag::warning(
                Pass::V4PlanSoundness,
                format!("spill {k}"),
                "slot is written but never read",
            ));
        }
    }
    // The partitioner assigns spill slots in producer topological order;
    // drift is harmless at execution time but flags a convention break.
    if spill_sink_order.iter().enumerate().any(|(k, &s)| s != k) {
        diags.push(Diag::warning(
            Pass::V4PlanSoundness,
            "spills",
            format!("sink slots {spill_sink_order:?} not in dense producer order"),
        ));
    }

    // config_words accounting: the plan's own total must equal an
    // independent per-tile recount from raw cell state.
    let independent: u64 = plan.tiles.iter().map(|t| recount_config_words(&t.cached.config)).sum();
    if plan.config_words() != independent {
        diags.push(err(
            "config-words".into(),
            format!("plan reports {} words, independent recount gives {independent}", plan.config_words()),
        ));
    }
}

/// Independent re-derivation of the configuration word count (the
/// transport/timing model's download size): 8 mux words per non-empty
/// cell, one payload word per constant operand, one word per I/O pad.
fn recount_config_words(cfg: &GridConfig) -> u64 {
    let mut words = (cfg.inputs.len() + cfg.outputs.len()) as u64;
    for c in &cfg.cells {
        if c.is_empty() {
            continue;
        }
        words += 8;
        words += [c.fu1, c.fu2, c.fsel]
            .iter()
            .filter(|s| matches!(s, FuSrc::Const(_)))
            .count() as u64;
    }
    words
}

/// V4 with provenance: everything [`verify_plan`] checks, plus the
/// cross-checks that need the source DFG and its cut — positional
/// `tile_key` identity against the plan key, source/sink tables matching
/// the partitioner's, calc-node conservation (the cut partitions the
/// DFG exactly once) and a deterministic semantic probe through
/// `TiledDfg::eval`.
pub fn verify_plan_with_provenance(
    plan: &ExecutionPlan,
    plan_key: u64,
    dfg: &Dfg,
    tiled: &TiledDfg,
) -> Vec<Diag> {
    let mut diags = Vec::new();
    verify_plan_into(plan, &mut diags);
    let err = |loc: String, msg: String| Diag::error(Pass::V4PlanSoundness, loc, msg);

    if plan.tiles.len() != tiled.tiles.len() {
        diags.push(err(
            "plan".into(),
            format!("{} tiles assembled from a {}-tile cut", plan.tiles.len(), tiled.tiles.len()),
        ));
    }
    if plan.n_spills != tiled.n_spills {
        diags.push(err(
            "plan".into(),
            format!("{} spill slots for a cut with {}", plan.n_spills, tiled.n_spills),
        ));
    }
    for (i, (pt, tt)) in plan.tiles.iter().zip(&tiled.tiles).enumerate() {
        let expect = tile_key(plan_key, i, dfg_key(&tt.dfg));
        if pt.key != expect {
            diags.push(err(
                format!("tile {i}"),
                format!(
                    "tile_key provenance mismatch: stored {:#018x}, derived {expect:#018x}",
                    pt.key
                ),
            ));
        }
        if pt.sources != tt.sources {
            diags.push(err(format!("tile {i}"), "source table differs from the cut's".into()));
        }
        if pt.sinks != tt.sinks {
            diags.push(err(format!("tile {i}"), "sink table differs from the cut's".into()));
        }
    }

    // The cut covers the DFG exactly once: calc-node conservation…
    let cut_calc: usize = tiled.tiles.iter().map(|t| t.dfg.stats().calc).sum();
    let want_calc = dfg.stats().calc;
    if cut_calc != want_calc {
        diags.push(err(
            "cut".into(),
            format!("tiles carry {cut_calc} calc nodes, the source DFG has {want_calc}"),
        ));
    }
    // …and a deterministic semantic probe (a partition that duplicates or
    // drops work diverges on almost any input).
    let n_in = dfg.stats().inputs;
    let probe: Vec<i32> =
        (0..n_in).map(|i| (i as i32).wrapping_mul(-1640531527).wrapping_add(12345)).collect();
    match (dfg.eval(&probe), tiled.eval(&probe)) {
        (Ok(want), Ok(got)) => {
            if want != got {
                diags.push(err(
                    "cut".into(),
                    "tiled evaluation diverges from the source DFG on the probe vector".into(),
                ));
            }
        }
        (Err(e), _) => diags.push(err("cut".into(), format!("source DFG fails to evaluate: {e}"))),
        (_, Err(e)) => diags.push(err("cut".into(), format!("tiled cut fails to evaluate: {e}"))),
    }

    sort_diags(&mut diags);
    diags
}

// ---------------------------------------------------------------- V5 --

/// V5: the load-time gate for "tlo-cache v1" snapshots. `what` names the
/// artifact class (`"entry"` / `"plan"`); `diags` is the V2–V4 stream
/// re-derived from the freshly parsed artifact. Errors reject the load
/// (the snapshot is semantically corrupt even if it parsed); warnings
/// pass. The returned message leads with the V5 banner and quotes the
/// first underlying diagnostic, so callers surface both the gate and the
/// root cause.
pub fn snapshot_gate(what: &str, key: u64, diags: &[Diag]) -> Result<(), String> {
    if !has_errors(diags) {
        return Ok(());
    }
    let first = diags
        .iter()
        .find(|d| d.severity == Severity::Error)
        .expect("has_errors implies an error diagnostic");
    Err(format!(
        "V5 snapshot integrity: {what} {key:#018x} failed re-verification \
         ({} error(s); first: {first})",
        error_count(diags)
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfe::config::fig2_config;

    // The deep mutation harness lives in tests/verifier.rs; these unit
    // tests pin the in-crate surface the harness builds on.

    #[test]
    fn fig2_artifact_verifies_clean() {
        let config = fig2_config();
        let image = config.to_image().expect("fig2 lowers");
        let cached = CachedConfig::new(config, image, "unit".into());
        assert!(cached.fabric.is_some(), "fig2 compiles to a wave schedule");
        let diags = verify_artifact(&cached);
        assert!(diags.is_empty(), "{}", crate::analysis::diag::render_table(&diags));
    }

    #[test]
    fn timing_rederivation_matches_the_lowering_on_fig2() {
        let config = fig2_config();
        let fab = CompiledFabric::compile(&config).expect("fig2 compiles");
        let taps = tap_depths(&config).expect("fig2 routing resolves");
        assert_eq!(1 + taps.iter().min().unwrap(), fab.fill_latency);
    }

    #[test]
    fn snapshot_gate_passes_clean_and_quotes_the_first_error() {
        assert!(snapshot_gate("entry", 7, &[]).is_ok());
        let warn = [Diag::warning(Pass::V2GridLegality, "io", "advisory")];
        assert!(snapshot_gate("entry", 7, &warn).is_ok(), "warnings must not block a load");
        let diags = [
            Diag::warning(Pass::V4PlanSoundness, "spill 0", "unread"),
            Diag::error(Pass::V2GridLegality, "cell (0,0)", "boom"),
        ];
        let msg = snapshot_gate("plan", 0xAB, &diags).unwrap_err();
        assert!(msg.contains("V5") && msg.contains("V2") && msg.contains("boom"), "{msg}");
    }
}

//! Analysis phase (paper §III): SCoP detection, affine machinery and the
//! DFE legality screen driving Table I.
pub mod affine;
pub mod scop;
pub use affine::Affine;
pub use scop::{analyze_function, FuncAnalysis, LoopInfo, ScopInfo, ScopReject};

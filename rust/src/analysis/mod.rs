//! Analysis phase (paper §III): SCoP detection, affine machinery and the
//! DFE legality screen driving Table I.
pub mod affine;
pub mod diag;
pub mod scop;
pub mod verifier;
pub use affine::Affine;
pub use diag::{render_table, sort_diags, Diag, Pass, Severity};
pub use scop::{analyze_function, FuncAnalysis, LoopInfo, ScopInfo, ScopReject};
pub use verifier::{
    snapshot_gate, verify_artifact, verify_config, verify_fabric, verify_offload, verify_plan,
    verify_plan_with_provenance,
};

//! Differential fuzz suite for the compiled wave executor (`dfe::exec`):
//! seeded, deterministic random legal feed-forward configurations (random
//! DFGs through the Las-Vegas P&R, so every case is a configuration the
//! real offload path could produce) driven with random streams on both
//! engines.
//!
//! Contract under test (the "documented tolerance" of dfe/exec.rs):
//!   * outputs are **bit-identical** to `CycleSim` on every legal
//!     feed-forward configuration, at every chunk boundary;
//!   * the analytic fill latency matches the measured elastic fill to
//!     within ±1 cycle (exact in every traced case; the slack only guards
//!     the assertion against future elastic-model refinements);
//!   * the measured initiation interval is ≥ the analytic 1.0 and ≤ the
//!     pipeline drain depth + slack — `CycleSim`'s 1-deep elastic buffers
//!     throttle reconvergent forks with depth imbalance (slack mismatch),
//!     which the physical overlay's deeper elastic FIFOs absorb, so II
//!     beyond 1.0 is an artifact of the conservative elastic model, never
//!     larger than one round trip;
//!   * a configuration the lowering cannot prove acyclic refuses to
//!     compile and `execute` falls back to `CycleSim` — never mis-lowers;
//!   * absent/short input streams error identically in both engines;
//!   * the **lowered batch kernels** (`dfe::lower`, the specialized
//!     straight-line form the offload hot path executes by default) are
//!     bit-identical to both engines on every routed configuration —
//!     including folded `Nop`/`Pass`/constant firings, fused
//!     producer→consumer chains, multi-tile plans, and scratch-arena
//!     reuse across artifacts.

use tlo::dfe::config::{GridConfig, IoAssign, OutSrc};
use tlo::dfe::exec::{execute, CompileError, CompiledFabric};
use tlo::dfe::{LoweredKernel, Scratch};
use tlo::dfe::grid::{CellCoord, Dir, Grid};
use tlo::dfe::opcodes::{Op, ALL_OPS};
use tlo::dfe::sim::CycleSim;
use tlo::dfe::ConfigError;
use tlo::dfg::graph::Dfg;
use tlo::par::{place_and_route, ParParams};
use tlo::util::prng::Rng;

/// Random DAG-shaped DFG (same shape as tests/proptests.rs): `n_in`
/// inputs, `n_calc` real compute ops, 1..3 outputs biased toward late
/// nodes.
fn random_dfg(rng: &mut Rng, n_in: usize, n_calc: usize) -> Dfg {
    let mut g = Dfg::new();
    let mut pool: Vec<usize> = (0..n_in).map(|j| g.input(j)).collect();
    for _ in 0..rng.below(3) {
        pool.push(g.constant(rng.range_i64(-50, 50) as i32));
    }
    for _ in 0..n_calc {
        let op = loop {
            let op = ALL_OPS[rng.below(ALL_OPS.len())];
            if !matches!(op, Op::Nop | Op::Pass) {
                break op;
            }
        };
        let a = pool[rng.below(pool.len())];
        let b = pool[rng.below(pool.len())];
        let id = if op == Op::Mux {
            let s = pool[rng.below(pool.len())];
            g.mux(a, b, s)
        } else {
            g.calc(op, a, b)
        };
        pool.push(id);
    }
    let n_out = 1 + rng.below(2);
    for j in 0..n_out {
        let pick = pool[pool.len() - 1 - rng.below(pool.len().min(4))];
        g.output(j, pick);
    }
    g.prune_dead()
}

/// Route random DFGs into legal configurations, yielding `(config, n_in)`
/// for each case the Las-Vegas router solved.
fn routed_cases(base_seed: u64, cases: u64) -> Vec<(GridConfig, usize)> {
    let mut rng = Rng::new(base_seed);
    let mut out = Vec::new();
    for case in 0..cases {
        let n_in = 1 + rng.below(3);
        let n_calc = 1 + rng.below(8);
        let dfg = random_dfg(&mut rng, n_in, n_calc);
        if dfg.stats().outputs == 0 || dfg.stats().calc == 0 {
            continue;
        }
        let mut prng = Rng::new(base_seed * 1000 + case);
        if let Ok(res) = place_and_route(&dfg, Grid::new(6, 6), &ParParams::default(), &mut prng)
        {
            out.push((res.config, n_in));
        }
    }
    out
}

fn random_streams(seed: u64, n_in: usize, n: usize) -> Vec<Vec<i32>> {
    let mut t = Rng::new(seed);
    (0..n_in).map(|_| (0..n).map(|_| t.any_i32()).collect()).collect()
}

#[test]
fn fuzz_wave_matches_cyclesim_bit_for_bit() {
    let cases = routed_cases(9001, 40);
    assert!(cases.len() >= 15, "only {} routed cases — fuzz too weak", cases.len());
    for (case, (config, n_in)) in cases.iter().enumerate() {
        let fabric = CompiledFabric::compile(config)
            .unwrap_or_else(|e| panic!("case {case}: routed config must lower: {e}"));
        // 64 exercises the common path; 300 crosses the CHUNK boundary.
        for n in [64usize, 300] {
            let streams = random_streams(case as u64 * 77 + n as u64, *n_in, n);
            let wave = fabric.run_stream(&streams, n).expect("wave run");
            let cyc = CycleSim::new(config)
                .expect("legal config")
                .run_stream(&streams, n)
                .expect("no deadlock on a feed-forward config");
            assert_eq!(wave.outputs, cyc.outputs, "case {case} n {n}: outputs diverge");
            // Documented timing tolerance (dfe/exec.rs): analytic fill
            // within ±1 cycle of the measured elastic fill (the first
            // wavefront never sees backpressure); measured II in
            // [1.0, drain_depth + 4] against the analytic 1.0 (slack
            // mismatch on reconvergent forks throttles the 1-deep
            // elastic model by at most one pipeline round trip).
            let (af, mf) = (wave.fill_latency as i64, cyc.fill_latency as i64);
            assert!(
                (af - mf).abs() <= 1,
                "case {case}: analytic fill {af} vs measured {mf}"
            );
            let drain = (wave.cycles - (n as u64 - 1)) as f64;
            assert!(
                cyc.initiation_interval >= 1.0
                    && cyc.initiation_interval <= drain + 4.0,
                "case {case}: measured II {} outside [1, drain {drain} + 4]",
                cyc.initiation_interval
            );
            assert_eq!(wave.initiation_interval, 1.0);
        }
    }
}

#[test]
fn fuzz_run_batch_matches_image_eval_batch() {
    // The offload stub executes through run_batch; hold it bit-identical
    // to the execution image (the PJRT-ABI oracle) on the same configs.
    for (case, (config, _)) in routed_cases(7321, 25).iter().enumerate() {
        let fabric = CompiledFabric::compile(config).expect("routed config lowers");
        let image = config.to_image().expect("routed config images");
        assert_eq!(fabric.n_inputs, image.n_inputs, "case {case}");
        let lanes = 130; // not a CHUNK multiple
        let mut t = Rng::new(case as u64 + 5);
        let x: Vec<i32> =
            (0..image.n_inputs * lanes).map(|_| t.any_i32()).collect();
        assert_eq!(
            fabric.run_batch(&x, lanes),
            image.eval_batch(&x, lanes),
            "case {case}"
        );
    }
}

/// A legal feed-forward datapath plus a dead two-cell routing ring that
/// never carries a token: `CycleSim` runs it (the ring simply never
/// fires), the wave lowering must refuse rather than mis-schedule it, and
/// `execute` must fall back with identical outputs.
#[test]
fn cyclic_config_falls_back_to_cyclesim() {
    let grid = Grid::new(2, 3);
    let mut cfg = GridConfig::empty(grid);
    let c00 = CellCoord::new(0, 0);
    let c01 = CellCoord::new(0, 1);
    let c02 = CellCoord::new(0, 2);
    {
        let cell = cfg.cell_mut(c00);
        cell.op = Some(Op::Mul);
        cell.fu1 = tlo::dfe::FuSrc::In(Dir::W);
        cell.fu2 = tlo::dfe::FuSrc::Const(3);
        cell.out[Dir::E.index()] = OutSrc::Fu;
    }
    {
        let cell = cfg.cell_mut(c01);
        cell.op = Some(Op::Add);
        cell.fu1 = tlo::dfe::FuSrc::In(Dir::W);
        cell.fu2 = tlo::dfe::FuSrc::Const(-1);
        cell.out[Dir::E.index()] = OutSrc::Fu;
    }
    cfg.cell_mut(c02).out[Dir::E.index()] = OutSrc::In(Dir::W);
    cfg.inputs.push(IoAssign { cell: c00, dir: Dir::W, index: 0 });
    cfg.outputs.push(IoAssign { cell: c02, dir: Dir::E, index: 0 });
    // The dead ring on row 1: (1,0).E out ← its own E input ← (1,1).W out
    // ← (1,1)'s W input ← (1,0).E out.
    cfg.cell_mut(CellCoord::new(1, 0)).out[Dir::E.index()] = OutSrc::In(Dir::E);
    cfg.cell_mut(CellCoord::new(1, 1)).out[Dir::W.index()] = OutSrc::In(Dir::W);

    assert!(
        matches!(
            CompiledFabric::compile(&cfg),
            Err(CompileError::NotFeedForward { .. })
        ),
        "lowering must refuse the ring"
    );

    let n = 50;
    let a: Vec<i32> = (0..n as i32).map(|v| v * 13 - 7).collect();
    let via_execute = execute(&cfg, &[a.clone()], n).expect("fallback path runs");
    let via_cyclesim = CycleSim::new(&cfg)
        .expect("CycleSim accepts the config")
        .run_stream(&[a.clone()], n)
        .expect("ring is dead, datapath flows");
    assert_eq!(via_execute.outputs, via_cyclesim.outputs);
    let want: Vec<i32> = a.iter().map(|&v| v.wrapping_mul(3).wrapping_add(-1)).collect();
    assert_eq!(via_execute.outputs[0], want);
    // Fallback also reports the *measured* timing, not the analytic one.
    assert_eq!(via_execute.fill_latency, via_cyclesim.fill_latency);
}

#[test]
fn fuzz_chunked_submission_matches_one_shot_batches() {
    // The async transport pipeline submits each offloaded batch as
    // chunked `run_batch` calls (transport::chunk_plan). Chunking may
    // only re-time the batch: reassembling random chunked submissions
    // must be bit-identical to the one-shot batch on every routed config.
    for (case, (config, _)) in routed_cases(31337, 25).iter().enumerate() {
        let fabric = CompiledFabric::compile(config).expect("routed config lowers");
        let n_in = fabric.n_inputs;
        let mut t = Rng::new(case as u64 * 13 + 7);
        let lanes = 50 + t.below(300);
        let x: Vec<i32> = (0..n_in * lanes).map(|_| t.any_i32()).collect();
        let want = fabric.run_batch(&x, lanes);
        let n_out = want.len() / lanes;

        // Random chunk boundaries (1..=5 chunks), plus the production
        // plan from the transport pipeline.
        let mut plans: Vec<Vec<(usize, usize)>> = Vec::new();
        plans.push(tlo::transport::chunk_plan(
            lanes,
            tlo::transport::TransportMode::Async { depth: 1 + t.below(3) },
        ));
        let mut cuts = vec![0usize, lanes];
        for _ in 0..t.below(4) {
            cuts.push(t.below(lanes));
        }
        cuts.sort_unstable();
        cuts.dedup();
        plans.push(cuts.windows(2).map(|w| (w[0], w[1] - w[0])).collect());

        for (pi, plan) in plans.iter().enumerate() {
            let total: usize = plan.iter().map(|&(_, m)| m).sum();
            assert_eq!(total, lanes, "case {case} plan {pi} must cover the batch");
            let mut got = vec![0i32; n_out * lanes];
            for &(start, m) in plan {
                if m == 0 {
                    continue;
                }
                let mut xc = vec![0i32; n_in * m];
                for j in 0..n_in {
                    xc[j * m..(j + 1) * m]
                        .copy_from_slice(&x[j * lanes + start..j * lanes + start + m]);
                }
                let oc = fabric.run_batch(&xc, m);
                for j in 0..n_out {
                    got[j * lanes + start..j * lanes + start + m]
                        .copy_from_slice(&oc[j * m..(j + 1) * m]);
                }
            }
            assert_eq!(got, want, "case {case} plan {pi}: chunked submission diverges");
        }
    }
}

#[test]
fn fuzz_tiled_plans_match_the_untiled_wave_executor() {
    // Random cut points: partition random DFGs under random cell budgets,
    // route every tile independently, and drive the multi-pass schedule
    // (host-staged spills between passes, exactly like the plan stub)
    // against the un-tiled wave executor on the whole graph. Tiling may
    // only re-time the work — outputs must be bit-identical.
    use tlo::dfg::partition::{partition, TileBudget, TileSink, TileSource};

    let mut rng = Rng::new(0x711E);
    let mut exercised = 0usize;
    for case in 0..60u64 {
        let n_in = 2 + rng.below(3);
        let n_calc = 4 + rng.below(10);
        let dfg = random_dfg(&mut rng, n_in, n_calc);
        let st = dfg.stats();
        if st.outputs == 0 || st.calc < 2 {
            continue;
        }
        // Un-tiled oracle: the whole graph routed on one big grid.
        let mut prng = Rng::new(0xBEEF + case);
        let Ok(whole) = place_and_route(&dfg, Grid::new(6, 6), &ParParams::default(), &mut prng)
        else {
            continue;
        };
        let oracle = CompiledFabric::compile(&whole.config).expect("routed config lowers");

        // Random cut budget that forces more than one tile (eff_cells is
        // cells/3 floored at 1, so any budget below 3*calc can cut).
        let cells = 1 + rng.below((3 * st.calc).saturating_sub(2));
        let budget = TileBudget { cells, io: 24 };
        let Ok(tiled) = partition(&dfg, budget) else {
            continue; // infeasible fan-in under a tiny io budget is legal
        };
        if tiled.n_tiles() < 2 {
            continue;
        }
        let mut fabrics = Vec::new();
        for (i, t) in tiled.tiles.iter().enumerate() {
            let mut prng = Rng::new(0xF00D + case * 131 + i as u64);
            let Ok(r) = place_and_route(&t.dfg, Grid::new(6, 6), &ParParams::default(), &mut prng)
            else {
                break;
            };
            fabrics.push(CompiledFabric::compile(&r.config).expect("tile lowers"));
        }
        if fabrics.len() != tiled.n_tiles() {
            continue;
        }
        exercised += 1;

        let n = 37 + rng.below(64);
        let streams = random_streams(case * 31 + 5, n_in, n);
        let want = oracle.run_stream(&streams, n).expect("untiled run").outputs;

        // Multi-pass schedule: every spill slot is a full host-staged
        // stream; external sinks land rows at their output index.
        let mut spills: Vec<Vec<i32>> = vec![vec![0; n]; tiled.n_spills];
        let mut got: Vec<Vec<i32>> = vec![Vec::new(); want.len()];
        for (tile, fabric) in tiled.tiles.iter().zip(&fabrics) {
            let local: Vec<Vec<i32>> = tile
                .sources
                .iter()
                .map(|s| match *s {
                    TileSource::External(j) => streams[j].clone(),
                    TileSource::Spill(k) => spills[k].clone(),
                })
                .collect();
            let out = fabric.run_stream(&local, n).expect("tile run").outputs;
            for (jj, sink) in tile.sinks.iter().enumerate() {
                match *sink {
                    TileSink::Spill(k) => spills[k] = out[jj].clone(),
                    TileSink::External(j) => got[j] = out[jj].clone(),
                }
            }
        }
        assert_eq!(
            got, want,
            "case {case}: {}-tile plan (cells {cells}) diverges from the un-tiled executor",
            tiled.n_tiles()
        );
    }
    assert!(exercised >= 8, "only {exercised} tiled cases exercised — fuzz too weak");
}

/// Tentpole differential lane: the lowered batch kernel must be
/// bit-identical to the wave executor AND to `CycleSim` on every routed
/// configuration, at every chunk boundary, through ONE reused scratch
/// arena — so the fingerprint-keyed re-priming between distinct
/// artifacts is stressed on every case transition.
#[test]
fn fuzz_lowered_matches_wave_and_cyclesim_bit_for_bit() {
    let cases = routed_cases(60061, 40);
    assert!(cases.len() >= 15, "only {} routed cases — fuzz too weak", cases.len());
    let mut scratch = Scratch::new();
    for (case, (config, n_in)) in cases.iter().enumerate() {
        let fabric = CompiledFabric::compile(config)
            .unwrap_or_else(|e| panic!("case {case}: routed config must lower: {e}"));
        let k = LoweredKernel::lower(&fabric);
        // 64 exercises the common path; 300 crosses the CHUNK boundary.
        for lanes in [64usize, 300] {
            let streams = random_streams(case as u64 * 91 + lanes as u64, *n_in, lanes);
            let mut x = vec![0i32; fabric.n_inputs * lanes];
            for j in 0..fabric.n_inputs {
                x[j * lanes..(j + 1) * lanes].copy_from_slice(&streams[j]);
            }
            let wave = fabric.run_batch(&x, lanes);
            let lowered = k.run_batch(&x, lanes, &mut scratch);
            assert_eq!(lowered, wave, "case {case} lanes {lanes}: lowered diverges from wave");
            // `CompiledFabric::outs` is sorted by bound output index, so
            // run_batch rows concatenate in CycleSim's stream order.
            let cyc = CycleSim::new(config)
                .expect("legal config")
                .run_stream(&streams, lanes)
                .expect("no deadlock on a feed-forward config");
            let flat_cyc: Vec<i32> = cyc.outputs.concat();
            assert_eq!(
                lowered, flat_cyc,
                "case {case} lanes {lanes}: lowered diverges from CycleSim"
            );
        }
    }
}

/// `Nop` firings and all-constant-operand firings fold away at lowering
/// time: a pipeline whose tail only sees a `Nop`-zeroed value reduces to
/// a prefill constant, and the lowered output still matches both
/// reference engines bit for bit.
#[test]
fn fuzz_lowered_folds_nop_and_constant_pipelines() {
    // 1x3 row: Add(in, 5) → Nop → Add(·, 7) → out. The Nop zeroes its
    // lane, so the tail Add const-folds to 7 and the kernel's output is
    // the prefill image — no surviving step feeds the tap.
    let grid = Grid::new(1, 3);
    let mut cfg = GridConfig::empty(grid);
    let c0 = CellCoord::new(0, 0);
    let c1 = CellCoord::new(0, 1);
    let c2 = CellCoord::new(0, 2);
    cfg.inputs.push(IoAssign { cell: c0, dir: Dir::W, index: 0 });
    {
        let cell = cfg.cell_mut(c0);
        cell.op = Some(Op::Add);
        cell.fu1 = tlo::dfe::FuSrc::In(Dir::W);
        cell.fu2 = tlo::dfe::FuSrc::Const(5);
        cell.out[Dir::E.index()] = OutSrc::Fu;
    }
    {
        let cell = cfg.cell_mut(c1);
        cell.op = Some(Op::Nop);
        cell.fu1 = tlo::dfe::FuSrc::In(Dir::W);
        cell.fu2 = tlo::dfe::FuSrc::Const(0);
        cell.out[Dir::E.index()] = OutSrc::Fu;
    }
    {
        let cell = cfg.cell_mut(c2);
        cell.op = Some(Op::Add);
        cell.fu1 = tlo::dfe::FuSrc::In(Dir::W);
        cell.fu2 = tlo::dfe::FuSrc::Const(7);
        cell.out[Dir::E.index()] = OutSrc::Fu;
    }
    cfg.outputs.push(IoAssign { cell: c2, dir: Dir::E, index: 0 });

    let fabric = CompiledFabric::compile(&cfg).expect("feed-forward row compiles");
    let k = LoweredKernel::lower(&fabric);
    assert!(k.folded >= 2, "Nop and the downstream constant Add must fold, got {}", k.folded);

    let lanes = 300; // crosses the CHUNK boundary
    let streams = random_streams(11, 1, lanes);
    let mut scratch = Scratch::new();
    let lowered = k.run_batch(&streams[0], lanes, &mut scratch);
    assert_eq!(lowered, fabric.run_batch(&streams[0], lanes));
    let cyc = CycleSim::new(&cfg).unwrap().run_stream(&streams, lanes).unwrap();
    assert_eq!(lowered, cyc.outputs.concat());
    assert!(lowered.iter().all(|&v| v == 7), "folded pipeline must emit the constant 7");
}

/// Fused producer→single-consumer chains: a straight pipeline with a
/// folded `Pass` in the middle collapses to ONE chain step, and the
/// chain's windowed accumulator execution is bit-identical to both
/// engines (including wrapping arithmetic at the lane edges).
#[test]
fn fuzz_lowered_fused_chains_match_both_engines() {
    // 1x4 row: Sub(in, 2) → Pass → Mul(·, 3) → Xor(·, -1) → out.
    let grid = Grid::new(1, 4);
    let mut cfg = GridConfig::empty(grid);
    let cells: Vec<CellCoord> = (0..4).map(|c| CellCoord::new(0, c)).collect();
    cfg.inputs.push(IoAssign { cell: cells[0], dir: Dir::W, index: 0 });
    let stages: [(Op, Option<i32>); 4] =
        [(Op::Sub, Some(2)), (Op::Pass, None), (Op::Mul, Some(3)), (Op::Xor, Some(-1))];
    for (i, &(op, konst)) in stages.iter().enumerate() {
        let cell = cfg.cell_mut(cells[i]);
        cell.op = Some(op);
        cell.fu1 = tlo::dfe::FuSrc::In(Dir::W);
        if let Some(v) = konst {
            cell.fu2 = tlo::dfe::FuSrc::Const(v);
        }
        cell.out[Dir::E.index()] = OutSrc::Fu;
    }
    cfg.outputs.push(IoAssign { cell: cells[3], dir: Dir::E, index: 0 });

    let fabric = CompiledFabric::compile(&cfg).expect("feed-forward row compiles");
    let k = LoweredKernel::lower(&fabric);
    assert!(k.folded >= 1, "the Pass must fold");
    assert!(k.fused >= 2, "Sub→Mul→Xor must fuse twice, got {}", k.fused);
    assert_eq!(k.n_steps(), 1, "the whole pipeline must collapse to one chain step");

    let lanes = 2 * 256 + 19; // two full chunks + a partial LANE_W tail
    let streams = random_streams(23, 1, lanes);
    let mut scratch = Scratch::new();
    let lowered = k.run_batch(&streams[0], lanes, &mut scratch);
    assert_eq!(lowered, fabric.run_batch(&streams[0], lanes));
    let cyc = CycleSim::new(&cfg).unwrap().run_stream(&streams, lanes).unwrap();
    assert_eq!(lowered, cyc.outputs.concat());
    let want: Vec<i32> =
        streams[0].iter().map(|&v| v.wrapping_sub(2).wrapping_mul(3) ^ -1).collect();
    assert_eq!(lowered, want, "closed form disagrees");
}

/// Multi-tile execution plans through the lowered path: every tile's
/// fabric is lowered and driven via `LoweredKernel::run_batch` with a
/// single shared scratch arena (re-primed on every tile switch, exactly
/// the worst case for the fingerprint key), and the host-staged spill
/// schedule must still match the un-tiled wave oracle bit for bit.
#[test]
fn fuzz_lowered_tiled_plans_match_the_untiled_oracle() {
    use tlo::dfg::partition::{partition, TileBudget, TileSink, TileSource};

    let mut rng = Rng::new(0x10EE);
    let mut exercised = 0usize;
    let mut scratch = Scratch::new();
    for case in 0..50u64 {
        let n_in = 2 + rng.below(3);
        let n_calc = 4 + rng.below(10);
        let dfg = random_dfg(&mut rng, n_in, n_calc);
        let st = dfg.stats();
        if st.outputs == 0 || st.calc < 2 {
            continue;
        }
        let mut prng = Rng::new(0xACE + case);
        let Ok(whole) = place_and_route(&dfg, Grid::new(6, 6), &ParParams::default(), &mut prng)
        else {
            continue;
        };
        let oracle = CompiledFabric::compile(&whole.config).expect("routed config lowers");

        let cells = 1 + rng.below((3 * st.calc).saturating_sub(2));
        let budget = TileBudget { cells, io: 24 };
        let Ok(tiled) = partition(&dfg, budget) else {
            continue;
        };
        if tiled.n_tiles() < 2 {
            continue;
        }
        let mut kernels = Vec::new();
        for (i, t) in tiled.tiles.iter().enumerate() {
            let mut prng = Rng::new(0xDEED + case * 131 + i as u64);
            let Ok(r) = place_and_route(&t.dfg, Grid::new(6, 6), &ParParams::default(), &mut prng)
            else {
                break;
            };
            let fab = CompiledFabric::compile(&r.config).expect("tile lowers");
            kernels.push(LoweredKernel::lower(&fab));
        }
        if kernels.len() != tiled.n_tiles() {
            continue;
        }
        exercised += 1;

        let n = 37 + rng.below(64);
        let streams = random_streams(case * 37 + 3, n_in, n);
        let want = oracle.run_stream(&streams, n).expect("untiled run").outputs;

        let mut spills: Vec<Vec<i32>> = vec![vec![0; n]; tiled.n_spills];
        let mut got: Vec<Vec<i32>> = vec![Vec::new(); want.len()];
        for (tile, kernel) in tiled.tiles.iter().zip(&kernels) {
            // Flatten the tile's local streams into the batch ABI.
            let mut x = vec![0i32; tile.sources.len() * n];
            for (j, s) in tile.sources.iter().enumerate() {
                let row = match *s {
                    TileSource::External(e) => &streams[e],
                    TileSource::Spill(k) => &spills[k],
                };
                x[j * n..(j + 1) * n].copy_from_slice(row);
            }
            let out = kernel.run_batch(&x, n, &mut scratch);
            for (jj, sink) in tile.sinks.iter().enumerate() {
                let row = out[jj * n..(jj + 1) * n].to_vec();
                match *sink {
                    TileSink::Spill(k) => spills[k] = row,
                    TileSink::External(j) => got[j] = row,
                }
            }
        }
        assert_eq!(
            got, want,
            "case {case}: lowered {}-tile plan (cells {cells}) diverges from the oracle",
            tiled.n_tiles()
        );
    }
    assert!(exercised >= 6, "only {exercised} tiled cases exercised — fuzz too weak");
}

/// Regression (ISSUE 10 satellite): the constant prefill is a
/// once-per-artifact cost. Repeated invocations through one scratch
/// arena must not refill constants or reallocate the wave buffer.
#[test]
fn fuzz_lowered_scratch_fills_consts_once_per_artifact() {
    let cases = routed_cases(424243, 10);
    let (config, n_in) = cases.first().expect("at least one routed case");
    let fabric = CompiledFabric::compile(config).expect("routed config lowers");
    let k = LoweredKernel::lower(&fabric);
    let mut scratch = Scratch::new();
    let lanes = 130;
    for round in 0..5u64 {
        let streams = random_streams(round, *n_in, lanes);
        let mut x = vec![0i32; fabric.n_inputs * lanes];
        for j in 0..fabric.n_inputs {
            x[j * lanes..(j + 1) * lanes].copy_from_slice(&streams[j]);
        }
        assert_eq!(k.run_batch(&x, lanes, &mut scratch), fabric.run_batch(&x, lanes));
    }
    assert_eq!(scratch.const_fills, 1, "prefill must run once across 5 invocations");
}

#[test]
fn fuzz_short_streams_error_identically_in_both_engines() {
    for (case, (config, n_in)) in routed_cases(4242, 15).iter().enumerate() {
        let fabric = CompiledFabric::compile(config).expect("routed config lowers");
        let n = 20;
        let full = random_streams(case as u64, *n_in, n);

        // Truncate the highest bound stream index.
        let max_idx = config.inputs.iter().map(|io| io.index).max().unwrap();
        let mut short = full.clone();
        short[max_idx].truncate(n - 1);
        let we = fabric.run_stream(&short, n).unwrap_err();
        let ce = CycleSim::new(config).unwrap().run_stream(&short, n).unwrap_err();
        assert_eq!(we, ce, "case {case}: engines disagree on the error");
        assert!(
            matches!(we, ConfigError::StreamTooShort { need: 20, got: 19, .. }),
            "case {case}: {we:?}"
        );

        // Drop the stream entirely.
        let absent: Vec<Vec<i32>> = full[..max_idx].to_vec();
        let we = fabric.run_stream(&absent, n).unwrap_err();
        let ce = CycleSim::new(config).unwrap().run_stream(&absent, n).unwrap_err();
        assert_eq!(we, ce);
        assert!(matches!(we, ConfigError::StreamTooShort { got: 0, .. }));
    }
}
